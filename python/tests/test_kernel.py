"""L1 correctness: the Pallas matmul kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes and value scales; every case asserts allclose
against `kernels.ref` — the core correctness signal for the AOT path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as pallas_mm
from compile.kernels import ref


def _rand(key, shape, scale=1.0, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_small_shapes(m, k, n, seed):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = _rand(kx, (m, k))
    y = _rand(ky, (k, n))
    got = pallas_mm.matmul(x, y)
    want = ref.matmul_ref(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(
    m=st.sampled_from([128, 200, 256, 300]),
    k=st.sampled_from([64, 128, 160]),
    n=st.sampled_from([96, 128, 257]),
)
def test_matmul_matches_ref_multi_tile(m, k, n):
    kx, ky = jax.random.split(jax.random.PRNGKey(m * 10007 + k * 101 + n))
    x = _rand(kx, (m, k))
    y = _rand(ky, (k, n))
    got = pallas_mm.matmul(x, y)
    want = ref.matmul_ref(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=12, deadline=None)
@given(scale=st.sampled_from([1e-3, 1.0, 1e3]), seed=st.integers(0, 1000))
def test_matmul_value_scales(scale, seed):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = _rand(kx, (33, 17), scale)
    y = _rand(ky, (17, 9), scale)
    got = pallas_mm.matmul(x, y)
    want = ref.matmul_ref(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * scale * scale)


def test_matmul_bf16_inputs_accumulate_in_f32():
    kx, ky = jax.random.split(jax.random.PRNGKey(7))
    x = _rand(kx, (64, 64), dtype=jnp.bfloat16)
    y = _rand(ky, (64, 64), dtype=jnp.bfloat16)
    got = pallas_mm.matmul(x, y)
    assert got.dtype == jnp.bfloat16
    want = jnp.matmul(x.astype(jnp.float32), y.astype(jnp.float32))
    np.testing.assert_allclose(
        got.astype(jnp.float32), want, rtol=2e-2, atol=2e-2
    )


def test_matmul_rejects_contraction_mismatch():
    with pytest.raises(AssertionError):
        pallas_mm.matmul(jnp.zeros((4, 5)), jnp.zeros((6, 7)))


def test_matmul_identity():
    x = jnp.eye(37, dtype=jnp.float32)
    y = _rand(jax.random.PRNGKey(1), (37, 13))
    np.testing.assert_allclose(pallas_mm.matmul(x, y), y, rtol=1e-6, atol=1e-6)


def test_matmul_zeros_padding_is_sound():
    # A shape that forces padding in every dim.
    x = _rand(jax.random.PRNGKey(2), (129, 130))
    y = _rand(jax.random.PRNGKey(3), (130, 131))
    got = pallas_mm.matmul(x, y)
    want = ref.matmul_ref(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(2, 9),
    cin=st.integers(1, 20),
    cout=st.integers(1, 20),
    seed=st.integers(0, 1000),
)
def test_pointwise_conv_matches_ref(b, h, cin, cout, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = _rand(k1, (b, h, h, cin))
    w = _rand(k2, (cin, cout))
    bias = _rand(k3, (cout,))
    got = pallas_mm.pointwise_conv(x, w, bias)
    want = ref.pointwise_conv_ref(x, w, bias)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# --- GAP reduction kernel ---

from compile.kernels import gap as pallas_gap  # noqa: E402


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4),
    hw=st.integers(1, 300),
    c=st.integers(1, 70),
    seed=st.integers(0, 2**31 - 1),
)
def test_gap_matches_ref(b, hw, c, seed):
    x = _rand(jax.random.PRNGKey(seed), (b, hw, c))
    got = pallas_gap.global_avg_pool(x)
    want = ref.global_avg_pool_ref(x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_gap_multi_tile_exact():
    # forces tiling on both axes
    x = _rand(jax.random.PRNGKey(9), (2, 513, 257))
    np.testing.assert_allclose(
        pallas_gap.global_avg_pool(x),
        ref.global_avg_pool_ref(x),
        rtol=1e-4,
        atol=1e-5,
    )


def test_gap_constant_input():
    x = jnp.full((1, 77, 5), 3.25, jnp.float32)
    np.testing.assert_allclose(
        pallas_gap.global_avg_pool(x), jnp.full((1, 5), 3.25), rtol=1e-6
    )
