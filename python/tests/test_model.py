"""L2 correctness: model shapes, pallas-vs-oracle equivalence, AOT lowering."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def params():
    return model.init_params(0)


def test_params_are_deterministic(params):
    again = model.init_params(0)
    for k in params:
        np.testing.assert_array_equal(params[k], again[k])


@pytest.mark.parametrize("batch", [1, 2, 4])
def test_forward_shapes_and_simplex(params, batch):
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, model.HW, model.HW, 3))
    probs = model.forward(params, x)
    assert probs.shape == (batch, model.CLASSES)
    np.testing.assert_allclose(np.sum(np.asarray(probs), axis=-1), 1.0, rtol=1e-5)
    assert np.all(np.asarray(probs) >= 0)


def test_pallas_path_matches_oracle_path(params):
    """The whole model with pallas pointwise convs == with jnp oracle."""
    x = jax.random.normal(jax.random.PRNGKey(2), (2, model.HW, model.HW, 3))
    with_pallas = model.forward(params, x, use_pallas=True)
    with_ref = model.forward(params, x, use_pallas=False)
    np.testing.assert_allclose(with_pallas, with_ref, rtol=1e-5, atol=1e-6)


def test_batch_consistency(params):
    """Each sample's output is independent of its batch neighbours."""
    x = jax.random.normal(jax.random.PRNGKey(3), (4, model.HW, model.HW, 3))
    batched = model.forward(params, x)
    singles = jnp.concatenate([model.forward(params, x[i : i + 1]) for i in range(4)])
    np.testing.assert_allclose(batched, singles, rtol=1e-5, atol=1e-6)


def test_serving_fn_returns_tuple(params):
    fn, spec = model.serving_fn(params, 2)
    assert spec.shape == (2, model.HW, model.HW, 3)
    out = fn(jnp.zeros(spec.shape, spec.dtype))
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (2, model.CLASSES)


def test_aot_hlo_text_roundtrips(tmp_path, params):
    """Lowered HLO text parses back through xla_client and preserves the
    computation's numbers (the exact interchange the Rust loader uses)."""
    from jax._src.lib import xla_client as xc

    fn, spec = model.serving_fn(params, 1)
    lowered = jax.jit(fn).lower(spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # Round-trip: text -> computation -> execute on the CPU client.
    comp = xc._xla.hlo_module_from_text(text)
    # (parse succeeded; executing the parsed module is covered by the rust
    # integration test rust/tests/pjrt_integration.rs)
    assert comp is not None


def test_build_artifacts_writes_variants(tmp_path):
    paths = aot.build_artifacts(str(tmp_path), [1, 2])
    names = sorted(p.split("/")[-1] for p in paths)
    assert names == ["model_b1.hlo.txt", "model_b2.hlo.txt"]
    for p in paths:
        content = open(p).read()
        assert content.startswith("HloModule") or "HloModule" in content
