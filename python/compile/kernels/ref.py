"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the pytest suite checks every kernel against
(`python/tests/test_kernel.py`); they are also used by the L2 model tests to
cross-check the pallas-backed model against a kernel-free twin.
"""

import jax.numpy as jnp


def matmul_ref(x, y):
    """Reference for kernels.matmul: plain matmul in f32."""
    return jnp.matmul(x, y)


def global_avg_pool_ref(x):
    """Reference for kernels.gap: mean over the HW axis of [B, HW, C]."""
    return jnp.mean(x, axis=1)


def pointwise_conv_ref(x, w, b):
    """Reference for the 1x1-conv-as-matmul path.

    x: [B, H, W, C_in]; w: [C_in, C_out]; b: [C_out].
    """
    bsz, h, wd, cin = x.shape
    flat = x.reshape(bsz * h * wd, cin)
    out = jnp.matmul(flat, w) + b
    return out.reshape(bsz, h, wd, w.shape[1])
