"""L1: global-average-pool as a Pallas reduction kernel.

Complements the matmul kernel with the other fundamental Pallas pattern —
a grid-striped *reduction*: the spatial axis is tiled, each grid step adds
its tile's partial sums into the output block, and the running-sum trick
(`o += x.sum(axis)` with an init step) keeps everything in VMEM-sized
blocks. The L2 model's GAP layer routes through this kernel.

interpret=True as everywhere (CPU PJRT cannot run Mosaic custom-calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_HW = 256
BLOCK_C = 128


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _gap_kernel(x_ref, o_ref, *, inv_hw):
    """Grid (b, hw_tile, c_tile): accumulate mean contributions."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Padding rows are zero, so adding them is harmless; scaling by the
    # *true* 1/HW happens here, keeping the kernel one-pass.
    o_ref[...] += jnp.sum(x_ref[...], axis=1) * inv_hw


@functools.partial(jax.jit, static_argnames=("bhw", "bc"))
def global_avg_pool(x, *, bhw: int = BLOCK_HW, bc: int = BLOCK_C):
    """`[B, HW, C] -> [B, C]` mean over the HW axis via Pallas."""
    b, hw, c = x.shape
    bhw = min(bhw, _round_up(hw, 8))
    bc = min(bc, _round_up(c, 8))
    hwp, cp = _round_up(hw, bhw), _round_up(c, bc)
    xp = jnp.pad(x, ((0, 0), (0, hwp - hw), (0, cp - c)))
    grid = (b, hwp // bhw, cp // bc)
    out = pl.pallas_call(
        functools.partial(_gap_kernel, inv_hw=1.0 / hw),
        grid=grid,
        in_specs=[pl.BlockSpec((1, bhw, bc), lambda i, j, k: (i, j, k))],
        out_specs=pl.BlockSpec((1, bc), lambda i, j, k: (i, k)),
        out_shape=jax.ShapeDtypeStruct((b, cp), jnp.float32),
        interpret=True,
    )(xp.astype(jnp.float32))
    return out[:, :c].astype(x.dtype)
