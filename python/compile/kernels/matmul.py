"""L1: blocked matmul as a Pallas kernel.

The serving model's compute hot-spot is the pointwise (1x1) convolution,
which is exactly a `[B*H*W, C_in] x [C_in, C_out]` matmul. This kernel
expresses it MXU-style: a 3D grid over (M, N, K) tiles, each step loading a
`(bm, bk)` LHS tile and a `(bk, bn)` RHS tile into VMEM (via BlockSpec) and
accumulating into the `(bm, bn)` output tile — the HBM<->VMEM schedule a TPU
would run. See DESIGN.md "Hardware-Adaptation" for the VMEM/MXU estimate.

`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, so the kernel lowers to plain HLO. Real-TPU lowering would
only change the `pallas_call` flag.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-shaped tiles. f32 VMEM footprint per grid step:
# 128*128*3 words * 4 B = 192 KiB << 16 MiB VMEM, leaving room for
# double-buffering (see DESIGN.md §Perf).
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (i, j, k) grid step: o[i,j] (+)= x[i,k] @ y[k,j]."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, *, bm: int = BLOCK_M, bn: int = BLOCK_N, bk: int = BLOCK_K):
    """Blocked Pallas matmul: `[M, K] @ [K, N] -> [M, N]` (f32 accumulate).

    Inputs are zero-padded up to tile multiples (zeros contribute nothing to
    the products) and the result is sliced back, so any M/N/K works.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {y.shape}"
    # Shrink tiles for small problems (keep lane-friendly multiples of 8).
    bm = min(bm, _round_up(m, 8))
    bn = min(bn, _round_up(n, 8))
    bk = min(bk, _round_up(k, 8))
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(xp.astype(jnp.float32), yp.astype(jnp.float32))
    return out[:m, :n].astype(x.dtype)


def pointwise_conv(x, w, b):
    """1x1 convolution via the Pallas matmul: the L2 model's hot path.

    x: [B, H, W, C_in]; w: [C_in, C_out]; b: [C_out].
    """
    bsz, h, wd, cin = x.shape
    flat = x.reshape(bsz * h * wd, cin)
    out = matmul(flat, w) + b
    return out.reshape(bsz, h, wd, w.shape[1])
