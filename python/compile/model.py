"""L2: the serving CNN in JAX, calling the L1 Pallas kernel.

MobileNet-v1-flavoured classifier, 32x32x3 -> 10 classes. Every pointwise
(1x1) convolution routes through `kernels.matmul.pointwise_conv` — the
Pallas hot path — so the AOT artifact exercises all three layers.

KEEP IN SYNC with `rust/src/models/l2_cnn.rs`: the Rust twin mirrors this
graph op-for-op so the serving coordinator can plan its arena and the CPU
executor can cross-check plans behaviourally.

Build-time only: this module is never imported on the request path.
"""

import jax
import jax.numpy as jnp

from .kernels import gap as pallas_gap
from .kernels import matmul as pallas_mm
from .kernels import ref as kernels_ref

HW = 32
CLASSES = 10
# (out_channels, stride) of the 4 depthwise-separable blocks.
BLOCKS = ((32, 2), (32, 1), (64, 2), (64, 1))
STEM_C = 16


def init_params(seed: int = 0):
    """Deterministic parameters (baked into the AOT artifact as constants)."""
    key = jax.random.PRNGKey(seed)
    params = {}

    def nxt():
        nonlocal key
        key, sub = jax.random.split(key)
        return sub

    def conv_init(kh, kw, cin, cout):
        fan = kh * kw * cin
        return jax.random.normal(nxt(), (kh, kw, cin, cout), jnp.float32) / jnp.sqrt(fan)

    params["stem_w"] = conv_init(3, 3, 3, STEM_C)
    params["stem_b"] = jnp.zeros((STEM_C,), jnp.float32)
    cin = STEM_C
    for i, (cout, _s) in enumerate(BLOCKS):
        # depthwise HWIO with feature_group_count=C: [3, 3, 1, C]
        params[f"dw{i}_w"] = conv_init(3, 3, 1, cin)
        params[f"dw{i}_b"] = jnp.zeros((cin,), jnp.float32)
        params[f"pw{i}_w"] = (
            jax.random.normal(nxt(), (cin, cout), jnp.float32) / jnp.sqrt(cin)
        )
        params[f"pw{i}_b"] = jnp.zeros((cout,), jnp.float32)
        cin = cout
    params["fc_w"] = jax.random.normal(nxt(), (cin, CLASSES), jnp.float32) / jnp.sqrt(cin)
    params["fc_b"] = jnp.zeros((CLASSES,), jnp.float32)
    return params


def _conv(x, w, b, stride):
    out = jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + b


def _dwconv(x, w, b, stride):
    c = x.shape[-1]
    out = jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )
    return out + b


def relu6(x):
    return jnp.clip(x, 0.0, 6.0)


def forward(params, x, *, use_pallas: bool = True):
    """Forward pass. `x`: [B, 32, 32, 3] -> probabilities [B, 10].

    `use_pallas=False` swaps the pointwise convs to the pure-jnp oracle —
    the model-level kernel cross-check used by pytest.
    """
    pw = pallas_mm.pointwise_conv if use_pallas else kernels_ref.pointwise_conv_ref
    h = relu6(_conv(x, params["stem_w"], params["stem_b"], 1))
    for i, (_cout, s) in enumerate(BLOCKS):
        h = relu6(_dwconv(h, params[f"dw{i}_w"], params[f"dw{i}_b"], s))
        h = relu6(pw(h, params[f"pw{i}_w"], params[f"pw{i}_b"]))
    # global average pool: the L1 reduction kernel
    bsz, hh, ww, cc = h.shape
    flat = h.reshape(bsz, hh * ww, cc)
    h = pallas_gap.global_avg_pool(flat) if use_pallas \
        else kernels_ref.global_avg_pool_ref(flat)
    logits = pallas_mm.matmul(h, params["fc_w"]) + params["fc_b"] if use_pallas \
        else jnp.matmul(h, params["fc_w"]) + params["fc_b"]
    return jax.nn.softmax(logits, axis=-1)


def serving_fn(params, batch: int):
    """The function AOT-lowered per batch size. Returns a 1-tuple (the HLO
    loader on the Rust side unwraps with `to_tuple1`)."""

    def fn(x):
        return (forward(params, x),)

    spec = jax.ShapeDtypeStruct((batch, HW, HW, 3), jnp.float32)
    return fn, spec
