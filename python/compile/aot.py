"""AOT pipeline: lower the L2 model to HLO text per batch-size variant.

Usage: python -m compile.aot [--out-dir ../artifacts] [--batches 1,2,4,8]

HLO *text* is the interchange format — `lowered.compiler_ir("stablehlo")`
converted via `mlir_module_to_xla_computation(...).as_hlo_text()` — NOT
`.serialize()`: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids
which the pinned xla_extension 0.5.1 (the `xla` rust crate's backend)
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Python runs once, here; the Rust binary is self-contained afterwards.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """Lowered jax function -> XLA HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_artifacts(out_dir: str, batches, seed: int = 0) -> list[str]:
    """Lower each batch variant; returns the written paths."""
    os.makedirs(out_dir, exist_ok=True)
    params = model.init_params(seed)
    written = []
    for b in batches:
        fn, spec = model.serving_fn(params, b)
        lowered = jax.jit(fn).lower(spec)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"model_b{b}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        written.append(path)
        print(f"wrote {path} ({len(text)} chars)")
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batches", default="1,2,4,8")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    batches = [int(b) for b in args.batches.split(",") if b]
    build_artifacts(args.out_dir, batches, args.seed)
    # Stamp for the Makefile's no-op rebuild check.
    with open(os.path.join(args.out_dir, ".stamp"), "w") as f:
        f.write("ok\n")


if __name__ == "__main__":
    main()
