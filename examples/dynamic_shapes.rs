//! §7: multi-pass planning when some tensor sizes resolve only at run time
//! (e.g. LSTM sequence lengths).
//!
//! ```sh
//! cargo run --release --offline --example dynamic_shapes
//! ```
//!
//! Synthesizes an RNN-ish workload where a fraction of tensors' sizes become
//! known mid-inference, runs the paper's multi-pass protocol, and reports
//! the footprint penalty relative to a size-omniscient oracle.

use tensorarena::planner::dynamic::{DynamicRecord, MultiPassPlanner};
use tensorarena::records::{UsageRecord, UsageRecords};
use tensorarena::rng::SplitMix64;

fn synth(seed: u64, n_ops: usize, dynamic_fraction: f64) -> Vec<DynamicRecord> {
    let mut rng = SplitMix64::new(seed);
    let mut recs = Vec::new();
    for i in 0..n_ops {
        // chain tensor i -> i+1
        let size = 64 * rng.next_range(1, 64);
        // ~dynamic_fraction of tensors resolve after their producer's
        // predecessor executes (a decode-step length becoming known).
        let known_at = if (rng.next_u64() as f64 / u64::MAX as f64) < dynamic_fraction && i > 0 {
            i - 1
        } else {
            0
        };
        recs.push(DynamicRecord {
            record: UsageRecord {
                id: recs.len(),
                tensor: None,
                first_op: i,
                last_op: (i + 1).min(n_ops - 1),
                size,
            },
            known_at,
        });
        // occasional skip connection
        if i % 7 == 3 {
            let span = rng.next_range(2, 5);
            recs.push(DynamicRecord {
                record: UsageRecord {
                    id: recs.len(),
                    tensor: None,
                    first_op: i,
                    last_op: (i + span).min(n_ops - 1),
                    size: 64 * rng.next_range(1, 16),
                },
                known_at: 0,
            });
        }
    }
    recs
}

fn main() {
    println!("== §7: multi-pass planning for dynamically-sized tensors ==\n");
    println!("{:>8} {:>8} {:>12} {:>12} {:>9}", "dyn frac", "passes", "multi (KiB)", "oracle (KiB)", "penalty");
    for &frac in &[0.0, 0.1, 0.25, 0.5, 0.9] {
        let mut penalty_sum = 0.0;
        let mut passes = 0;
        let mut multi_kib = 0.0;
        let mut oracle_kib = 0.0;
        let trials = 20;
        for seed in 0..trials {
            let dynamic = synth(seed, 64, frac);
            let num_ops = 64;
            let mp = MultiPassPlanner.plan(&dynamic, num_ops);
            let records = UsageRecords {
                records: dynamic.iter().map(|d| d.record).collect(),
                num_ops,
            };
            mp.plan.validate(&records).expect("multi-pass plan feasible");
            let oracle = tensorarena::planner::OffsetPlanner::plan(
                &tensorarena::planner::offset::GreedyBySize,
                &records,
            );
            penalty_sum += mp.plan.total_size() as f64 / oracle.total_size() as f64;
            passes += mp.passes;
            multi_kib += mp.plan.total_size() as f64 / 1024.0;
            oracle_kib += oracle.total_size() as f64 / 1024.0;
        }
        let t = trials as f64;
        println!(
            "{:>8.2} {:>8.1} {:>12.1} {:>12.1} {:>8.3}x",
            frac,
            passes as f64 / t,
            multi_kib / t,
            oracle_kib / t,
            penalty_sum / t
        );
    }
    println!("\npenalty = multi-pass arena / oracle single-pass arena (1.0 = no cost).");
}
