//! §7: multi-pass planning when some tensor sizes resolve only at run time
//! (e.g. LSTM sequence lengths) — and the plan-cache amortization that
//! makes it servable.
//!
//! ```sh
//! cargo run --release --offline --example dynamic_shapes
//! ```
//!
//! Three acts:
//! 1. the overhead-vs-oracle table on synthetic RNN-ish workloads (the
//!    offline `dynamic-ablation` story);
//! 2. a decode loop through [`PlanService`]: the first sequence pays one
//!    multi-pass planner invocation per resolved prefix, every repeat is
//!    a cache hit — zero planner invocations;
//! 3. a wave-aware [`ExecutorEngine`] serving a real zoo model end to end
//!    with the arena sized at the worst-wave peak.

use tensorarena::coordinator::Engine;
use tensorarena::coordinator::ExecutorEngine;
use tensorarena::planner::dynamic::{DynamicRecord, DynamicRecords, MultiPassPlanner};
use tensorarena::planner::{DynamicMode, PlanRequest, PlanService};
use tensorarena::records::UsageRecord;
use tensorarena::rng::SplitMix64;

fn synth(seed: u64, n_ops: usize, dynamic_fraction: f64) -> DynamicRecords {
    let mut rng = SplitMix64::new(seed);
    let mut recs = Vec::new();
    for i in 0..n_ops {
        // chain tensor i -> i+1
        let size = 64 * rng.next_range(1, 64);
        // ~dynamic_fraction of tensors resolve after their producer's
        // predecessor executes (a decode-step length becoming known).
        let known_at = if (rng.next_u64() as f64 / u64::MAX as f64) < dynamic_fraction && i > 0 {
            i - 1
        } else {
            0
        };
        recs.push(DynamicRecord {
            record: UsageRecord {
                id: recs.len(),
                tensor: None,
                first_op: i,
                last_op: (i + 1).min(n_ops - 1),
                size,
            },
            known_at,
        });
        // occasional skip connection
        if i % 7 == 3 {
            let span = rng.next_range(2, 5);
            recs.push(DynamicRecord {
                record: UsageRecord {
                    id: recs.len(),
                    tensor: None,
                    first_op: i,
                    last_op: (i + span).min(n_ops - 1),
                    size: 64 * rng.next_range(1, 16),
                },
                known_at: 0,
            });
        }
    }
    DynamicRecords::new(recs, n_ops)
}

fn main() {
    println!("== §7: multi-pass planning for dynamically-sized tensors ==\n");
    println!(
        "{:>8} {:>8} {:>12} {:>12} {:>9}",
        "dyn frac", "passes", "multi (KiB)", "oracle (KiB)", "penalty"
    );
    for &frac in &[0.0, 0.1, 0.25, 0.5, 0.9] {
        let mut penalty_sum = 0.0;
        let mut passes = 0;
        let mut multi_kib = 0.0;
        let mut oracle_kib = 0.0;
        let trials = 20;
        for seed in 0..trials {
            let dynamic = synth(seed, 64, frac);
            let mp = MultiPassPlanner.plan(&dynamic);
            let records = dynamic.final_records();
            mp.offset_plan()
                .expect("complete plan")
                .validate(&records)
                .expect("multi-pass plan feasible");
            let oracle = tensorarena::planner::OffsetPlanner::plan(
                &tensorarena::planner::offset::GreedyBySize,
                &records,
            )
            .total_size();
            penalty_sum += if oracle == 0 { 1.0 } else { mp.peak as f64 / oracle as f64 };
            passes += mp.passes;
            multi_kib += mp.peak as f64 / 1024.0;
            oracle_kib += oracle as f64 / 1024.0;
        }
        let t = trials as f64;
        println!(
            "{:>8.2} {:>8.1} {:>12.1} {:>12.1} {:>8.3}x",
            frac,
            passes as f64 / t,
            multi_kib / t,
            oracle_kib / t,
            penalty_sum / t
        );
    }
    println!("\npenalty = multi-pass arena / oracle single-pass arena (1.0 = no cost).");

    // --- act 2: the decode loop through the plan cache ---
    println!("\n== decode-step re-plans through the PlanService cache ==\n");
    let service = PlanService::shared();
    let dynamic = synth(7, 64, 0.5);
    for sequence in 0..3 {
        for step in 0..dynamic.num_ops {
            service
                .plan_dynamic(
                    &dynamic,
                    &service.request().with_dynamic(DynamicMode::Resolved(step)),
                )
                .expect("decode-step plan");
        }
        let st = service.stats();
        println!(
            "sequence {}: {} decode steps -> dynamic cache {} hit / {} re-plan",
            sequence + 1,
            dynamic.num_ops,
            st.dynamic_hits,
            st.dynamic_misses,
        );
    }
    println!(
        "(re-plans stop growing after sequence 1: an unchanged resolved prefix \
         costs zero planner invocations.)"
    );

    // --- act 3: wave-aware serving of a real model ---
    println!("\n== wave-aware ExecutorEngine on blazeface ==\n");
    let g = tensorarena::models::blazeface();
    let decode_from = g.num_ops() / 2;
    let service = PlanService::shared();
    let mut engine = ExecutorEngine::for_request_dynamic(
        &g,
        std::sync::Arc::clone(&service),
        &PlanRequest::new(),
        decode_from,
        42,
    )
    .expect("engine");
    let x = vec![0.1f32; 2 * engine.in_elems()];
    engine.run_batch(&x, 2).expect("inference");
    engine.run_batch(&x, 2).expect("inference");
    let stats = engine.arena_stats();
    println!(
        "{}",
        tensorarena::coordinator::render_arena_stats(&stats)
    );
    println!(
        "worst-wave peak hosts the whole decode ({} waves); budget admission caps at \
         max_servable_batch = {:?} for a 4x budget",
        stats.waves,
        engine.max_servable_batch(4 * engine.planned_peak(1).unwrap()),
    );
}
