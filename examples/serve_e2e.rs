//! End-to-end serving driver: the full three-layer stack on a real
//! workload.
//!
//! ```sh
//! make artifacts && cargo run --release --offline --example serve_e2e
//! ```
//!
//! Loads the AOT-compiled JAX/Pallas model (all batch variants), spins up
//! the Rust coordinator (router + dynamic batcher), replays an open-loop
//! Poisson-ish arrival trace at several rates, and reports
//! latency/throughput per rate plus the planner's arena accounting — the
//! serving-facing version of the paper's evaluation. Results are recorded
//! in EXPERIMENTS.md §E2E.

use std::sync::Arc;
use std::time::{Duration, Instant};
use tensorarena::coordinator::engine::PjrtEngine;
use tensorarena::coordinator::{BatchPolicy, Router};
use tensorarena::models;
use tensorarena::planner::{PlanRequest, PlanService};
use tensorarena::records::UsageRecords;
use tensorarena::rng::SplitMix64;
use tensorarena::runtime::{Runtime, VariantSet};

const IN_ELEMS: usize = 32 * 32 * 3;

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());

    // --- Planner story for the served model (L2 twin), through the one
    // shared PlanService every engine replica below also uses ---
    let service = PlanService::shared();
    let req = PlanRequest::new().with_batch(8);
    let twin = models::l2_cnn();
    let recs = UsageRecords::from_graph(&twin);
    let plan = service.plan(&recs, &req.with_batch(1)).map_err(anyhow::Error::msg)?;
    println!(
        "serving model: l2_cnn ({} ops); arena {:.1} KiB vs naive {:.1} KiB = {:.2}x reduction",
        twin.num_ops(),
        plan.total_size() as f64 / 1024.0,
        recs.naive_total() as f64 / 1024.0,
        recs.naive_total() as f64 / plan.total_size().max(1) as f64,
    );

    // --- Sanity: batch variants agree with each other ---
    {
        let rt = Runtime::cpu()?;
        let vs = VariantSet::load(&rt, std::path::Path::new(&dir), "model", &[32, 32, 3], 10)?;
        println!(
            "PJRT {} | variants: {:?}",
            rt.platform().0,
            vs.variants.iter().map(|v| v.batch).collect::<Vec<_>>()
        );
        let mut rng = SplitMix64::new(7);
        let mut sample = vec![0f32; IN_ELEMS];
        rng.fill_f32(&mut sample, 1.0);
        let b1 = vs.pick(1).run(&sample)?;
        let mut four = sample.clone();
        four.extend_from_slice(&sample);
        four.extend_from_slice(&sample);
        four.extend_from_slice(&sample);
        let b4 = vs.pick(4).run(&four)?;
        for i in 0..10 {
            assert!(
                (b1[i] - b4[i]).abs() < 1e-5,
                "batch-1 vs batch-4 disagree at {i}: {} vs {}",
                b1[i],
                b4[i]
            );
        }
        let s: f32 = b1.iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "softmax output must be a simplex");
        println!("variant cross-check: b1 == b4 per-sample, output is a simplex ✓");
    }

    // --- Open-loop load sweep through the coordinator ---
    println!("\n{:>9} {:>8} {:>10} {:>10} {:>10} {:>10} {:>11}",
        "rate r/s", "sent", "ok", "p50 ms", "p95 ms", "p99 ms", "mean batch");
    for &rate in &[100usize, 300, 600, 1200] {
        let mut router = Router::new();
        let dir_owned = dir.clone();
        let engine_service = Arc::clone(&service);
        let engine_recs = recs.clone();
        router.register(
            "cnn",
            move || {
                let rt = Runtime::cpu().expect("PJRT");
                let vs = VariantSet::load(&rt, std::path::Path::new(&dir_owned), "model", &[32, 32, 3], 10)
                    .expect("artifacts");
                Box::new(
                    PjrtEngine::with_request(vs, engine_service, engine_recs, &req)
                        .expect("twin plan"),
                )
            },
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2), ..BatchPolicy::default() },
        );

        let n = (rate / 2).max(64); // ~0.5s of traffic
        let gap = Duration::from_nanos(1_000_000_000u64 / rate as u64);
        let mut rng = SplitMix64::new(rate as u64);
        let mut input = vec![0f32; IN_ELEMS];
        let mut pending = Vec::with_capacity(n);
        let start = Instant::now();
        for i in 0..n {
            rng.fill_f32(&mut input, 1.0);
            pending.push(router.submit("cnn", input.clone()));
            // open loop: next arrival at start + (i+1)*gap
            let next = start + gap * (i as u32 + 1);
            if let Some(sleep) = next.checked_duration_since(Instant::now()) {
                std::thread::sleep(sleep);
            }
        }
        let mut ok = 0usize;
        for rx in pending {
            if matches!(rx.recv(), Ok(Ok(_))) {
                ok += 1;
            }
        }
        let snap = router.server("cnn").unwrap().metrics().snapshot();
        println!(
            "{:>9} {:>8} {:>10} {:>10.2} {:>10.2} {:>10.2} {:>11.2}",
            rate,
            n,
            ok,
            snap.p50_us as f64 / 1000.0,
            snap.p95_us as f64 / 1000.0,
            snap.p99_us as f64 / 1000.0,
            snap.mean_batch
        );
        router.shutdown();
    }
    let st = service.stats();
    println!(
        "\nshared plan cache across every rate's engine replica: {} miss(es), {} hit(s)",
        st.cache_misses, st.cache_hits
    );
    println!("(see EXPERIMENTS.md §E2E for the recorded run)");
    Ok(())
}
