//! Quickstart: the paper's running example (Figures 1–6) end to end.
//!
//! ```sh
//! cargo run --release --offline --example quickstart
//! ```
//!
//! Builds the Figure-1 network, extracts §3 usage records and operator
//! profiles, runs every §4/§5 strategy, and prints the resulting
//! assignments the way Figures 3–6 draw them.

use tensorarena::models::{example_records, EXAMPLE_UNIT};
use tensorarena::planner::{table1_strategies, table2_strategies};

fn main() {
    let recs = example_records();
    let profiles = recs.profiles();

    println!("== Figure 1/2: the example network ==");
    println!("(sizes in the figure's abstract units; 1 unit = {EXAMPLE_UNIT} B)");
    println!("\ntensor usage records (§3):");
    for r in &recs.records {
        println!("  t{}: first_op={} last_op={} size={}", r.id, r.first_op, r.last_op, r.size);
    }
    println!("\noperator profiles (sizes, descending) and breadths:");
    for op in 0..profiles.num_ops() {
        let sizes: Vec<usize> = profiles
            .profile(op)
            .iter()
            .map(|&i| recs.records[i].size)
            .collect();
        println!("  op{}: {:?} breadth={}", op, sizes, profiles.breadth(op));
    }
    println!("\npositional maximums: {:?}", profiles.positional_maximums());
    println!(
        "shared-objects lower bound (sum) = {}, offset lower bound (max breadth) = {}",
        profiles.shared_objects_lower_bound(),
        profiles.offset_lower_bound()
    );

    println!("\n== §4 Shared Objects (Figures 3-5) ==");
    for strat in table1_strategies() {
        let plan = strat.plan(&recs);
        plan.validate(&recs).expect("feasible");
        let mut members: Vec<String> = Vec::new();
        for (i, &sz) in plan.object_sizes.iter().enumerate() {
            let ts: Vec<String> = recs
                .records
                .iter()
                .filter(|r| plan.assignment[r.id] == i)
                .map(|r| format!("t{}", r.id))
                .collect();
            members.push(format!("obj{i}[{sz}]={{{}}}", ts.join(",")));
        }
        println!(
            "  {:<34} total={:<4} {}",
            strat.name(),
            plan.total_size(),
            members.join(" ")
        );
    }

    println!("\n== §5 Offset Calculation (Figure 6) ==");
    for strat in table2_strategies() {
        let plan = strat.plan(&recs);
        plan.validate(&recs).expect("feasible");
        let spans: Vec<String> = recs
            .records
            .iter()
            .map(|r| format!("t{}@{}", r.id, plan.offsets[r.id]))
            .collect();
        println!(
            "  {:<38} arena={:<4} {}",
            strat.name(),
            plan.total_size(),
            spans.join(" ")
        );
    }

    println!("\nDone. Try `cargo run --release --example plan_models` for Tables 1-2.");
}
