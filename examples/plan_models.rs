//! Regenerate the paper's Tables 1 and 2 over the six evaluation networks,
//! plus the §1 headline ratios.
//!
//! ```sh
//! cargo run --release --offline --example plan_models
//! ```

use tensorarena::models;
use tensorarena::records::UsageRecords;
use tensorarena::report;

fn main() {
    let t1 = report::table1();
    print!("{}", t1.render());
    println!();
    let t2 = report::table2();
    print!("{}", t2.render());

    // §1: "up to 10.5x smaller memory footprint than running inference
    // without one" — naive / best offset strategy.
    println!("\nNaive / best-offset-strategy ratio (paper: up to 10.5x):");
    let naive = &t2.rows.last().unwrap().1;
    for (i, col) in t2.columns.iter().enumerate() {
        let best = t2
            .rows
            .iter()
            .filter(|(n, _)| n != "Naive" && n != "Lower Bound")
            .map(|(_, v)| v[i])
            .fold(f64::INFINITY, f64::min);
        println!("  {col:>14}: {:>5.1}x", naive[i] / best);
    }

    // Lower-bound attainment, the paper's §6 discussion.
    println!("\nGreedy-by-Size offset plan vs lower bound (1.00 = optimal):");
    for g in models::all_zoo() {
        let recs = UsageRecords::from_graph(&g);
        let plan =
            tensorarena::planner::OffsetPlanner::plan(&tensorarena::planner::offset::GreedyBySize, &recs);
        let lb = recs.profiles().offset_lower_bound();
        println!(
            "  {:>14}: {:.3}",
            g.name,
            plan.total_size() as f64 / lb as f64
        );
    }

    // Quantized-deployment study: the paper's size_t is *aligned* bytes, so
    // F16/U8 arenas do not shrink by exactly 2x/4x on small-tensor nets.
    println!("\nGreedy-by-Size arena by dtype (MiB; reduction vs F32 in parens):");
    use tensorarena::graph::DType;
    use tensorarena::planner::{offset::GreedyBySize, OffsetPlanner};
    const MIB: f64 = 1024.0 * 1024.0;
    for g in models::all_zoo() {
        let mut row = format!("  {:>14}:", g.name);
        let f32_size = {
            let recs = UsageRecords::from_graph(&g);
            GreedyBySize.plan(&recs).total_size()
        };
        for dt in [DType::F32, DType::F16, DType::U8] {
            let gq = models::with_dtype(&g, dt);
            let recs = UsageRecords::from_graph(&gq);
            let sz = GreedyBySize.plan(&recs).total_size();
            row.push_str(&format!(
                " {:>7.3} ({:.2}x)",
                sz as f64 / MIB,
                f32_size as f64 / sz as f64
            ));
        }
        println!("{row}");
    }
}
