//! Bench + regeneration of **Table 2**: Offset Calculation strategies over
//! the six evaluation networks, plus the §1 naive ratios ("up to 10.5x").
//!
//! ```sh
//! cargo bench --offline --bench table2_offset_calculation
//! ```

#[path = "harness.rs"]
mod harness;

use tensorarena::models;
use tensorarena::planner::registry;
use tensorarena::records::UsageRecords;
use tensorarena::report;

fn main() {
    let t = report::table2();
    print!("{}", t.render());

    println!("\nNaive / best-strategy ratio per network (paper: up to 10.5x):");
    let naive = &t.rows.last().unwrap().1;
    for (i, col) in t.columns.iter().enumerate() {
        let best = t
            .rows
            .iter()
            .filter(|(n, _)| n != "Naive" && n != "Lower Bound")
            .map(|(_, v)| v[i])
            .fold(f64::INFINITY, f64::min);
        println!("  {col:>14}: {:>5.1}x", naive[i] / best);
    }

    println!("\nplanner wall time (median of 10):");
    for g in models::all_zoo() {
        let recs = UsageRecords::from_graph(&g);
        for strat in registry::offset_strategies() {
            let name = format!("{} / {}", g.name, strat.name());
            let stats = harness::bench(2, 10, || {
                harness::black_box(strat.plan(&recs));
            });
            harness::report(&name, stats);
        }
    }
}
