//! Coordinator micro + macro benchmarks.
//!
//! ```sh
//! cargo bench --offline --bench serving
//! ```
//!
//! * micro: request round-trip overhead through router + batcher with a
//!   trivial engine (isolates L3 from compute);
//! * batching: throughput vs `max_batch` with a fixed-cost engine;
//! * plan reuse: ExecutorEngine replicas behind one PlanService — reports
//!   the plan-cache hit rate and arena-pool reuse that make replica spin-up
//!   and batch swaps cheap;
//! * macro (with the `pjrt` feature and `artifacts/`): PJRT closed-loop
//!   storm, the same measurement as `tensorarena serve`.

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;
use std::time::Duration;
use tensorarena::coordinator::engine::ExecutorEngine;
use tensorarena::coordinator::{
    render_arena_stats, ArenaStats, BatchPolicy, EchoEngine, Engine, Router,
};
use tensorarena::planner::PlanService;
use tensorarena::records::UsageRecords;
use tensorarena::rng::SplitMix64;

/// Engine with a fixed per-batch cost, to expose batching wins.
struct FixedCostEngine {
    elems: usize,
    cost: Duration,
}

impl Engine for FixedCostEngine {
    fn in_elems(&self) -> usize {
        self.elems
    }
    fn out_elems(&self) -> usize {
        self.elems
    }
    fn max_batch(&self) -> usize {
        64
    }
    fn run_batch(&mut self, input: &[f32], n: usize) -> anyhow::Result<Vec<f32>> {
        std::thread::sleep(self.cost);
        Ok(input[..n * self.elems].to_vec())
    }
}

fn main() {
    // --- micro: round-trip overhead ---
    {
        let mut router = Router::new();
        router.register(
            "echo",
            || Box::new(EchoEngine::new(8, 8)),
            BatchPolicy { max_batch: 1, max_wait: Duration::from_micros(1) },
        );
        let input = vec![1.0f32; 8];
        let st = harness::bench(100, 2000, || {
            let rx = router.submit("echo", input.clone());
            harness::black_box(rx.recv().unwrap().unwrap());
        });
        harness::report("round-trip overhead (batch=1, echo engine)", st);
        router.shutdown();
    }

    // --- batching win: fixed 1ms engine cost, varying max_batch ---
    println!("\nthroughput vs max_batch (engine cost 1 ms/batch, 256 closed-loop requests):");
    for max_batch in [1usize, 2, 4, 8, 16, 32] {
        let mut router = Router::new();
        router.register(
            "fixed",
            move || Box::new(FixedCostEngine { elems: 4, cost: Duration::from_millis(1) }),
            BatchPolicy { max_batch, max_wait: Duration::from_micros(200) },
        );
        let mut rng = SplitMix64::new(1);
        let mut input = vec![0f32; 4];
        let t = std::time::Instant::now();
        let pending: Vec<_> = (0..256)
            .map(|_| {
                rng.fill_f32(&mut input, 1.0);
                router.submit("fixed", input.clone())
            })
            .collect();
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        let wall = t.elapsed();
        println!(
            "  max_batch {max_batch:>3}: {:>8.0} req/s ({:?} total)",
            256.0 / wall.as_secs_f64(),
            wall
        );
        router.shutdown();
    }

    // --- plan reuse: replicas + batch swaps through one PlanService ---
    {
        let service = PlanService::shared();
        let model = "blazeface";
        let g = tensorarena::models::by_name(model).unwrap();
        let in_elems = g.tensor(g.inputs[0]).num_elements();
        let recs = UsageRecords::from_graph(&g);
        let naive = recs.naive_total();
        let planned = service
            .plan_records(&recs, 1, Some("greedy-size"))
            .expect("plan")
            .total;
        println!("\nplan reuse: 3 {model} replicas, bursts at batch 1/2/4, then a replica restart:");
        let mut rng = SplitMix64::new(3);
        let mut input = vec![0f32; in_elems];
        // Phase 1 spins the replicas up and grows their arenas; phase 2
        // restarts them — every plan is a cache hit and every arena buffer
        // comes back out of the pool.
        for phase in 0..2 {
            let mut router = Router::new();
            for i in 0..3 {
                let service = Arc::clone(&service);
                router.register(
                    format!("{model}-{i}"),
                    move || {
                        let g = tensorarena::models::by_name("blazeface").unwrap();
                        Box::new(ExecutorEngine::new(&g, service, "greedy-size", 7).expect("engine"))
                    },
                    BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
                );
            }
            for burst in [1usize, 2, 4, 2, 1] {
                for i in 0..3 {
                    let pending: Vec<_> = (0..burst)
                        .map(|_| {
                            rng.fill_f32(&mut input, 1.0);
                            router.submit(&format!("{model}-{i}"), input.clone())
                        })
                        .collect();
                    for rx in pending {
                        rx.recv().unwrap().unwrap();
                    }
                }
            }
            router.shutdown();
            let st = service.stats();
            println!(
                "  phase {}: cache {} hit / {} miss, pool {} reused / {} allocated",
                phase + 1,
                st.cache_hits,
                st.cache_misses,
                st.pool_reused,
                st.pool_allocated,
            );
        }
        let st = service.stats();
        let stats = ArenaStats::from_service(planned, naive, "greedy-size", st);
        println!("  {}", render_arena_stats(&stats));
        println!(
            "  cache hit rate {:.1}% | pool reuse {}/{} acquisitions",
            st.cache_hit_rate() * 100.0,
            st.pool_reused,
            st.pool_reused + st.pool_allocated,
        );
    }

    // --- macro: PJRT artifacts, if built ---
    #[cfg(feature = "pjrt")]
    let dir = std::path::Path::new("artifacts");
    #[cfg(feature = "pjrt")]
    if tensorarena::runtime::Runtime::discover_variants(dir, "model").is_ok() {
        use tensorarena::coordinator::engine::PjrtEngine;
        use tensorarena::runtime::{Runtime, VariantSet};
        println!("\nPJRT closed-loop storm (256 requests):");
        for max_batch in [1usize, 8] {
            let mut router = Router::new();
            router.register(
                "cnn",
                move || {
                    let rt = Runtime::cpu().expect("PJRT");
                    let vs = VariantSet::load(&rt, std::path::Path::new("artifacts"), "model", &[32, 32, 3], 10)
                        .expect("artifacts");
                    Box::new(PjrtEngine::new(vs, ArenaStats::default()))
                },
                BatchPolicy { max_batch, max_wait: Duration::from_millis(2) },
            );
            let mut rng = SplitMix64::new(2);
            let mut input = vec![0f32; 32 * 32 * 3];
            let t = std::time::Instant::now();
            let pending: Vec<_> = (0..256)
                .map(|_| {
                    rng.fill_f32(&mut input, 1.0);
                    router.submit("cnn", input.clone())
                })
                .collect();
            let ok = pending
                .into_iter()
                .filter(|rx| matches!(rx.recv(), Ok(Ok(_))))
                .count();
            let wall = t.elapsed();
            let snap = router.server("cnn").unwrap().metrics().snapshot();
            println!(
                "  max_batch {max_batch:>2}: {ok}/256 ok, {:>7.1} req/s, p50 {:.2} ms, mean batch {:.2}",
                ok as f64 / wall.as_secs_f64(),
                snap.p50_us as f64 / 1000.0,
                snap.mean_batch
            );
            router.shutdown();
        }
    } else {
        println!("\n(artifacts/ missing: run `make artifacts` for the PJRT macro bench)");
    }
}
