//! Coordinator micro + macro benchmarks.
//!
//! ```sh
//! cargo bench --offline --bench serving
//! ```
//!
//! * micro: request round-trip overhead through router + batcher with a
//!   trivial engine (isolates L3 from compute);
//! * batching: throughput vs `max_batch` with a fixed-cost engine;
//! * plan reuse: ExecutorEngine replicas behind one PlanService — reports
//!   the plan-cache hit rate and arena-pool reuse that make replica spin-up
//!   and batch swaps cheap;
//! * budgeted admission: a byte budget below the batch-8 planned peak —
//!   the server clamps batches and refuses an oversized burst instead of
//!   OOMing;
//! * spilled admission: the same starved budget served under both spill
//!   policies (`serve --spill-policy`) — the refuse policy rejects the
//!   over-budget burst, the spill policy serves it through the compressed
//!   tier, with evictions / reloads / compression ratio / reload-stall p99
//!   recorded per policy;
//! * order ablation: the same model served under the natural vs the
//!   annealed execution order — peak arena, breadth delta, and throughput
//!   side by side (the `serve --order` path);
//! * decode loop: the same model served wave-aware (`serve --dynamic`) —
//!   the first burst pays one multi-pass planner invocation per resolved
//!   prefix, the second runs entirely off the dynamic plan cache;
//! * paged decode loop: the same model with the decode tail paged through
//!   the shared block pool (`serve --paged`) — resident bytes strictly
//!   below the worst-wave preallocation, block high-water mark and
//!   fragmentation reported, outputs asserted bit-identical on the
//!   sequential and 4-thread paths;
//! * continuous vs drain: the same paged decode loop served by the
//!   batch-and-drain scheduler and by the continuous scheduler
//!   (`serve --continuous`) under one Poisson closed-loop storm —
//!   p50/p95 latency, throughput, and the count of requests admitted
//!   into in-flight decode loops, outputs asserted bit-identical to the
//!   sequential resident path;
//! * quantized size classes: the same model planned and run at the i8/f16
//!   `PlanRequest` dtype (`serve --dtype`) — planned footprint shrink vs
//!   f32, end-to-end output drift, and the admission cap a fixed byte
//!   budget resolves under each size class;
//! * warm vs cold start: planner invocations and time-to-planned across a
//!   plan-directory restart (`persist_dir` → `warm_start`);
//! * kernel/thread trajectory: raw `Executor::run_batch` on mobilenet_v2
//!   across kernels (scalar reference vs vectorized) × threads (1 vs 4) ×
//!   batch — the recorded perf trajectory behind `BENCH_serving.json`;
//! * macro (with the `pjrt` feature and `artifacts/`): PJRT closed-loop
//!   storm, the same measurement as `tensorarena serve`.
//!
//! Pass `--smoke` (CI tier-2) to shrink every closed loop to a seconds-long
//! correctness pass. `--json PATH` writes the trajectory as JSON;
//! `--check PATH` re-parses a committed `BENCH_*.json` and fails on *schema*
//! drift (case shape, identity fields) while letting timings float.

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;
use std::time::Duration;
use tensorarena::coordinator::engine::ExecutorEngine;
use tensorarena::coordinator::{
    render_arena_stats, ArenaStats, BatchPolicy, EchoEngine, Engine, Router,
};
use tensorarena::planner::{registry, PlanRequest, PlanService};
use tensorarena::records::UsageRecords;
use tensorarena::rng::SplitMix64;

/// Engine with a fixed per-batch cost, to expose batching wins.
struct FixedCostEngine {
    elems: usize,
    cost: Duration,
}

impl Engine for FixedCostEngine {
    fn in_elems(&self) -> usize {
        self.elems
    }
    fn out_elems(&self) -> usize {
        self.elems
    }
    fn max_batch(&self) -> usize {
        64
    }
    fn run_batch(&mut self, input: &[f32], n: usize) -> anyhow::Result<Vec<f32>> {
        std::thread::sleep(self.cost);
        Ok(input[..n * self.elems].to_vec())
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    // --smoke (CI tier-2): same code paths, seconds-long loops.
    let smoke = argv.iter().any(|a| a == "--smoke");
    let flag_value = |name: &str| {
        argv.iter().position(|a| a == name).and_then(|i| argv.get(i + 1)).cloned()
    };
    let json_out = flag_value("--json");
    let check_path = flag_value("--check");

    // --- micro: round-trip overhead ---
    {
        let mut router = Router::new();
        router.register(
            "echo",
            || Box::new(EchoEngine::new(8, 8)),
            BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_micros(1),
                ..BatchPolicy::default()
            },
        )
        .expect("register");
        let input = vec![1.0f32; 8];
        let (warmup, iters) = if smoke { (10, 100) } else { (100, 2000) };
        let st = harness::bench(warmup, iters, || {
            let rx = router.submit("echo", input.clone());
            harness::black_box(rx.recv().unwrap().unwrap());
        });
        harness::report("round-trip overhead (batch=1, echo engine)", st);
        router.shutdown();
    }

    // --- kernel/thread trajectory: raw run_batch sweep (BENCH_serving.json) ---
    let mut cases: Vec<harness::json::Value> = Vec::new();
    {
        use harness::json::Value;
        use tensorarena::exec::{Executor, KernelMode};
        use tensorarena::planner::offset::GreedyBySize;
        let model = "mobilenet_v2";
        let g = tensorarena::models::by_name(model).unwrap();
        let in_elems = g.tensor(g.inputs[0]).num_elements();
        let batches: &[usize] = if smoke { &[1, 4] } else { &[1, 2, 4, 8] };
        let (warmup, iters) = if smoke { (0, 1) } else { (1, 5) };
        let configs: &[(&str, KernelMode, usize)] = &[
            ("reference", KernelMode::Reference, 1),
            ("vectorized", KernelMode::Vectorized, 1),
            ("vectorized", KernelMode::Vectorized, 4),
        ];
        println!("\nrun_batch trajectory ({model}, kernels x threads x batch):");
        let mut rng = SplitMix64::new(13);
        let mut meds: Vec<(&str, usize, usize, f64)> = Vec::new();
        for &(kname, mode, threads) in configs {
            let mut exec = Executor::new(&g, &GreedyBySize, 7).expect("executor");
            exec.set_kernel_mode(mode);
            exec.set_threads(threads);
            for &b in batches {
                let mut input = vec![0f32; in_elems * b];
                rng.fill_f32(&mut input, 1.0);
                let st = harness::bench(warmup, iters, || {
                    harness::black_box(exec.run_batch(&input, b).expect("run_batch"));
                });
                harness::report(&format!("run_batch {kname} t{threads} b{b}"), st);
                meds.push((kname, threads, b, st.median_us()));
                cases.push(Value::Obj(vec![
                    ("name".into(), Value::Str(format!("run_batch/{kname}/t{threads}/b{b}"))),
                    ("kernels".into(), Value::Str(kname.into())),
                    ("threads".into(), Value::Num(threads as f64)),
                    ("batch".into(), Value::Num(b as f64)),
                    ("median_us".into(), Value::Num(st.median_us())),
                    ("min_us".into(), Value::Num(st.min_us())),
                    ("mean_us".into(), Value::Num(st.mean_us())),
                    ("samples_per_s".into(), Value::Num(b as f64 / (st.median_us() / 1e6))),
                ]));
            }
        }
        // The headline number the trajectory records: vectorized kernels on
        // 4 workers vs the scalar single-thread baseline, median over the
        // batch sweep.
        let mut speedups: Vec<f64> = Vec::new();
        for &b in batches {
            let find = |k: &str, t: usize| {
                meds.iter().find(|m| m.0 == k && m.1 == t && m.2 == b).map(|m| m.3)
            };
            if let (Some(base), Some(par)) = (find("reference", 1), find("vectorized", 4)) {
                if par > 0.0 {
                    speedups.push(base / par);
                }
            }
        }
        speedups.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if !speedups.is_empty() {
            println!(
                "  vectorized t4 vs reference t1: median speedup {:.2}x over the batch sweep",
                speedups[speedups.len() / 2]
            );
        }
    }

    // --- batching win: fixed 1ms engine cost, varying max_batch ---
    let storm = if smoke { 64 } else { 256 };
    let caps: &[usize] = if smoke { &[1, 8] } else { &[1, 2, 4, 8, 16, 32] };
    println!("\nthroughput vs max_batch (engine cost 1 ms/batch, {storm} closed-loop requests):");
    for &max_batch in caps {
        let mut router = Router::new();
        router.register(
            "fixed",
            move || Box::new(FixedCostEngine { elems: 4, cost: Duration::from_millis(1) }),
            BatchPolicy {
                max_batch,
                max_wait: Duration::from_micros(200),
                ..BatchPolicy::default()
            },
        )
        .expect("register");
        let mut rng = SplitMix64::new(1);
        let mut input = vec![0f32; 4];
        let t = std::time::Instant::now();
        let pending: Vec<_> = (0..storm)
            .map(|_| {
                rng.fill_f32(&mut input, 1.0);
                router.submit("fixed", input.clone())
            })
            .collect();
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        let wall = t.elapsed();
        println!(
            "  max_batch {max_batch:>3}: {:>8.0} req/s ({:?} total)",
            storm as f64 / wall.as_secs_f64(),
            wall
        );
        router.shutdown();
    }

    // --- plan reuse: replicas + batch swaps through one PlanService ---
    {
        let service = PlanService::shared();
        let model = "blazeface";
        let g = tensorarena::models::by_name(model).unwrap();
        let in_elems = g.tensor(g.inputs[0]).num_elements();
        let recs = UsageRecords::from_graph(&g);
        let naive = recs.naive_total();
        let planned = service.plan(&recs, &service.request()).expect("plan").total;
        println!("\nplan reuse: 3 {model} replicas, bursts at batch 1/2/4, then a replica restart:");
        let mut rng = SplitMix64::new(3);
        let mut input = vec![0f32; in_elems];
        // Phase 1 spins the replicas up and grows their arenas; phase 2
        // restarts them — every plan is a cache hit and every arena buffer
        // comes back out of the pool.
        for phase in 0..2 {
            let mut router = Router::new();
            for i in 0..3 {
                let service = Arc::clone(&service);
                router.register(
                    format!("{model}-{i}"),
                    move || {
                        let g = tensorarena::models::by_name("blazeface").unwrap();
                        Box::new(ExecutorEngine::new(&g, service, "greedy-size", 7).expect("engine"))
                    },
                    BatchPolicy {
                        max_batch: 4,
                        max_wait: Duration::from_millis(1),
                        ..BatchPolicy::default()
                    },
                )
                .expect("register");
            }
            for burst in [1usize, 2, 4, 2, 1] {
                for i in 0..3 {
                    let pending: Vec<_> = (0..burst)
                        .map(|_| {
                            rng.fill_f32(&mut input, 1.0);
                            router.submit(&format!("{model}-{i}"), input.clone())
                        })
                        .collect();
                    for rx in pending {
                        rx.recv().unwrap().unwrap();
                    }
                }
            }
            router.shutdown();
            let st = service.stats();
            println!(
                "  phase {}: cache {} hit / {} miss, pool {} reused / {} allocated",
                phase + 1,
                st.cache_hits,
                st.cache_misses,
                st.pool_reused,
                st.pool_allocated,
            );
        }
        let st = service.stats();
        let stats = ArenaStats::from_service(planned, naive, "greedy-size", st);
        println!("  {}", render_arena_stats(&stats));
        println!(
            "  cache hit rate {:.1}% | pool reuse {}/{} acquisitions",
            st.cache_hit_rate() * 100.0,
            st.pool_reused,
            st.pool_reused + st.pool_allocated,
        );
    }

    // --- budgeted admission: clamp + refuse instead of OOM ---
    {
        let service = PlanService::shared();
        let g = tensorarena::models::by_name("blazeface").unwrap();
        let in_elems = g.tensor(g.inputs[0]).num_elements();
        let recs = UsageRecords::from_graph(&g);
        let t1 = service.plan(&recs, &service.request()).expect("plan").total;
        // ~3.5x the batch-1 arena: well below the batch-8 planned peak, so
        // an 8-cap policy must be clamped by the budget.
        let budget = 3 * t1 + t1 / 2;
        println!(
            "\nbudgeted admission: blazeface, budget {:.1} KiB (~3.5x batch-1 arena), policy max_batch 8:",
            budget as f64 / 1024.0
        );
        let mut router = Router::new();
        {
            let service = Arc::clone(&service);
            router.register(
                "blaze",
                move || {
                    let g = tensorarena::models::by_name("blazeface").unwrap();
                    Box::new(ExecutorEngine::new(&g, service, "greedy-size", 7).expect("engine"))
                },
                BatchPolicy {
                    max_batch: 8,
                    max_wait: Duration::from_millis(1),
                    mem_budget: Some(budget),
                    ..BatchPolicy::default()
                },
            )
            .expect("register");
        }
        let burst = if smoke { 16 } else { 64 };
        let mut rng = SplitMix64::new(5);
        let mut input = vec![0f32; in_elems];
        let t = std::time::Instant::now();
        let pending: Vec<_> = (0..burst)
            .map(|_| {
                rng.fill_f32(&mut input, 1.0);
                router.submit("blaze", input.clone())
            })
            .collect();
        let ok = pending
            .into_iter()
            .filter(|rx| matches!(rx.recv(), Ok(Ok(_))))
            .count();
        let wall = t.elapsed();
        // One pre-batched burst at the nominal cap: must be refused, typed.
        let refusal = router
            .submit("blaze", vec![0f32; 8 * in_elems])
            .recv()
            .expect("worker alive");
        let snap = router.server("blaze").unwrap().metrics().snapshot();
        println!(
            "  {ok}/{burst} singles served in {:?} at max batch {} (<= budget cap), {} rejected",
            wall, snap.max_batch_seen, snap.rejected
        );
        match refusal {
            Err(e) => println!("  oversized burst of 8: refused — {e}"),
            Ok(_) => println!("  oversized burst of 8: UNEXPECTEDLY admitted"),
        }
        router.shutdown();
    }

    // --- spilled admission: serve past the resident budget via the tier ---
    {
        use harness::json::Value;
        use tensorarena::arena::spill::SpillTier;
        use tensorarena::coordinator::{ModelServer, SpillPolicy};
        let model = "blazeface";
        let g = tensorarena::models::by_name(model).unwrap();
        let in_elems = g.tensor(g.inputs[0]).num_elements();
        let recs = UsageRecords::from_graph(&g);
        let singles = if smoke { 8 } else { 32 };
        println!(
            "\nspilled admission ({model}, budget ~1.5x batch-1 arena, {singles} singles then a \
             burst of 3):"
        );
        // The same storm under both policies: the refuse policy rejects the
        // burst (today's behavior), the spill policy serves it through the
        // compressed tier — the `serve --spill-policy spill` acceptance
        // contrast, with the tier counters recorded per policy.
        for (mode, policy) in [("refuse", SpillPolicy::Refuse), ("spill", SpillPolicy::Spill)] {
            let service = PlanService::shared();
            let tier = Arc::new(SpillTier::new());
            service.pool().configure_spill(Arc::clone(&tier), 0);
            let budget = service.plan(&recs, &service.request()).expect("plan").total * 3 / 2;
            let server = {
                let service = Arc::clone(&service);
                ModelServer::spawn(
                    move || {
                        let g = tensorarena::models::by_name("blazeface").unwrap();
                        Box::new(
                            ExecutorEngine::new(&g, service, "greedy-size", 7)
                                .expect("engine")
                                .with_max_batch(4),
                        )
                    },
                    BatchPolicy {
                        max_batch: 4,
                        max_wait: Duration::from_millis(1),
                        mem_budget: Some(budget),
                        spill: policy,
                        ..BatchPolicy::default()
                    },
                )
                .expect("spawn")
            };
            let mut rng = SplitMix64::new(37);
            let mut input = vec![0f32; in_elems];
            let t = std::time::Instant::now();
            let pending: Vec<_> = (0..singles)
                .map(|_| {
                    rng.fill_f32(&mut input, 1.0);
                    server.submit(input.clone())
                })
                .collect();
            let ok = pending
                .into_iter()
                .filter(|rx| matches!(rx.recv(), Ok(Ok(_))))
                .count();
            let mut burst = vec![0f32; 3 * in_elems];
            rng.fill_f32(&mut burst, 1.0);
            let burst_admitted =
                matches!(server.submit(burst).recv().expect("worker alive"), Ok(_));
            // One more single after the burst: the batch shrink re-acquires
            // a small buffer, which under the spill policy reloads the one
            // evicted at the burst's resize — the stall the p99 records.
            rng.fill_f32(&mut input, 1.0);
            let tail_ok =
                matches!(server.submit(input.clone()).recv().expect("worker alive"), Ok(_));
            assert!(tail_ok, "post-burst single must serve under either policy");
            let wall = t.elapsed();
            let snap = server.metrics().snapshot();
            server.shutdown();
            let stats = tier.stats();
            println!(
                "  policy {mode:>6}: {ok}/{singles} singles ok, burst of 3 {} | {} spill \
                 admission(s), {} eviction(s) / {} reload(s), {:.2}x compressed, reload p99 {} us",
                if burst_admitted { "ADMITTED" } else { "refused" },
                snap.spill_admissions,
                stats.evictions,
                stats.reloads,
                tier.compression_ratio(),
                stats.stall_p99_us,
            );
            cases.push(Value::Obj(vec![
                ("name".into(), Value::Str(format!("spilled_admission/{mode}"))),
                ("policy".into(), Value::Str(mode.into())),
                ("budget_kib".into(), Value::Num(budget as f64 / 1024.0)),
                ("singles_ok".into(), Value::Num(ok as f64)),
                ("burst_admitted".into(), Value::Bool(burst_admitted)),
                ("spill_admissions".into(), Value::Num(snap.spill_admissions as f64)),
                ("evictions".into(), Value::Num(stats.evictions as f64)),
                ("reloads".into(), Value::Num(stats.reloads as f64)),
                ("compression_ratio".into(), Value::Num(tier.compression_ratio())),
                ("reload_stall_p99_us".into(), Value::Num(stats.stall_p99_us as f64)),
                ("throughput_rps".into(), Value::Num(ok as f64 / wall.as_secs_f64())),
            ]));
        }
    }

    // --- order ablation: the same model served under two orders ---
    {
        use tensorarena::planner::order::apply_order;
        let model = "blazeface";
        let g = tensorarena::models::by_name(model).unwrap();
        let in_elems = g.tensor(g.inputs[0]).num_elements();
        println!("\norder-ablation serving ({model}, greedy-size, batch cap 4):");
        let burst = if smoke { 16 } else { 128 };
        for key in ["natural", "annealed-s42-t100"] {
            let order = registry::order_strategy(key).expect("order key");
            let service = PlanService::shared();
            let mut router = Router::new();
            {
                let service = Arc::clone(&service);
                router.register(
                    model,
                    move || {
                        let g = tensorarena::models::by_name("blazeface").unwrap();
                        Box::new(
                            ExecutorEngine::for_request(
                                &g,
                                service,
                                &PlanRequest::new().with_order(order),
                                7,
                            )
                            .expect("engine")
                            .with_max_batch(4),
                        )
                    },
                    BatchPolicy {
                        max_batch: 4,
                        max_wait: Duration::from_millis(1),
                        ..BatchPolicy::default()
                    },
                )
                .expect("register");
            }
            let mut rng = SplitMix64::new(9);
            let mut input = vec![0f32; in_elems];
            let t = std::time::Instant::now();
            let pending: Vec<_> = (0..burst)
                .map(|_| {
                    rng.fill_f32(&mut input, 1.0);
                    router.submit(model, input.clone())
                })
                .collect();
            let ok = pending
                .into_iter()
                .filter(|rx| matches!(rx.recv(), Ok(Ok(_))))
                .count();
            let wall = t.elapsed();
            router.shutdown();
            // The served order and records are re-derived deterministically,
            // so these stats describe exactly what the engine hosted.
            let (og, applied) = apply_order(&g, order);
            let orecs = UsageRecords::from_graph(&og);
            let peak = service
                .plan(&orecs, &service.request().with_batch(4).with_order(order))
                .expect("plan")
                .total;
            let stats = ArenaStats::from_service(
                peak,
                orecs.naive_total() * 4,
                "greedy-size",
                service.stats(),
            )
            .with_order(applied.key(), applied.natural_breadth, applied.order_breadth);
            println!(
                "  order {key:>18}: {ok}/{burst} ok, {:>8.0} req/s\n    {}",
                ok as f64 / wall.as_secs_f64(),
                render_arena_stats(&stats),
            );
        }
    }

    // --- decode loop: dynamic shapes (§7) through the plan cache ---
    {
        let model = "blazeface";
        let g = tensorarena::models::by_name(model).unwrap();
        let in_elems = g.tensor(g.inputs[0]).num_elements();
        let decode_from = g.num_ops() / 2;
        let service = PlanService::shared();
        let burst = if smoke { 16 } else { 128 };
        println!(
            "\ndecode-loop dynamic serving ({model}, tail resolves from op {decode_from}, batch cap 4):"
        );
        let mut router = Router::new();
        {
            let service = Arc::clone(&service);
            router.register(
                model,
                move || {
                    let g = tensorarena::models::by_name("blazeface").unwrap();
                    Box::new(
                        ExecutorEngine::for_request_dynamic(
                            &g,
                            service,
                            &PlanRequest::new(),
                            decode_from,
                            7,
                        )
                        .expect("engine")
                        .with_max_batch(4),
                    )
                },
                BatchPolicy {
                    max_batch: 4,
                    max_wait: Duration::from_millis(1),
                    ..BatchPolicy::default()
                },
            )
            .expect("register");
        }
        let mut rng = SplitMix64::new(11);
        let mut input = vec![0f32; in_elems];
        // Two identical decode bursts: the first pays one multi-pass
        // planner invocation per resolved prefix; the second sees only
        // cache hits — the §7 amortization the ISSUE's acceptance test
        // pins down.
        for phase in 0..2 {
            let t = std::time::Instant::now();
            let pending: Vec<_> = (0..burst)
                .map(|_| {
                    rng.fill_f32(&mut input, 1.0);
                    router.submit(model, input.clone())
                })
                .collect();
            let ok = pending
                .into_iter()
                .filter(|rx| matches!(rx.recv(), Ok(Ok(_))))
                .count();
            let wall = t.elapsed();
            let st = service.stats();
            println!(
                "  burst {}: {ok}/{burst} ok, {:>8.0} req/s | dynamic cache {} hit / {} re-plan",
                phase + 1,
                ok as f64 / wall.as_secs_f64(),
                st.dynamic_hits,
                st.dynamic_misses,
            );
        }
        router.shutdown();
        let st = service.stats();
        println!(
            "  ({} re-plans total — once every batch size has been seen, steady-state decode \
             costs zero planner invocations)",
            st.dynamic_misses
        );
    }

    // --- paged decode loop: prefix-resident arena + shared block pool ---
    {
        use harness::json::Value;
        use tensorarena::arena::paged::BLOCK_WORDS;
        use tensorarena::planner::{DynamicMode, DynamicRecords};
        let model = "blazeface";
        let g = tensorarena::models::by_name(model).unwrap();
        let in_elems = g.tensor(g.inputs[0]).num_elements();
        let recs = UsageRecords::from_graph(&g);
        // Pick the first decode split whose tail strictly grows the
        // worst-wave peak above the static prefix — the regime where paging
        // the tail pays (early-dominated splits keep the two peaks equal
        // and are skipped).
        let probe = PlanService::shared();
        let mut pick = None;
        for from in 2..g.num_ops() {
            let d = DynamicRecords::decode_tail(&recs, from);
            if d.num_dynamic() == 0 {
                continue;
            }
            let full = probe
                .plan_dynamic(&d, &PlanRequest::new().with_dynamic(DynamicMode::FullyResolved))
                .expect("plan")
                .peak;
            let prefix = probe
                .plan_dynamic(&d, &PlanRequest::new().with_dynamic(DynamicMode::Resolved(0)))
                .expect("plan")
                .peak;
            if full > prefix {
                pick = Some((from, d));
                break;
            }
        }
        let (decode_from, dyn_recs) =
            pick.expect("a decode split whose tail grows the worst-wave peak");
        println!(
            "\npaged decode loop ({model}, tail resolves from op {decode_from}, \
             {} B blocks, batch sweep 1/2/4):",
            BLOCK_WORDS * 4
        );
        let res_svc = PlanService::shared();
        let paged_svc = PlanService::shared();
        let mut resident = ExecutorEngine::for_request_dynamic(
            &g,
            Arc::clone(&res_svc),
            &PlanRequest::new(),
            decode_from,
            7,
        )
        .expect("engine")
        .with_max_batch(4);
        let mut paged = ExecutorEngine::for_request_paged(
            &g,
            Arc::clone(&paged_svc),
            &PlanRequest::new(),
            decode_from,
            7,
        )
        .expect("engine")
        .with_max_batch(4);
        let mut threaded = ExecutorEngine::for_request_paged(
            &g,
            PlanService::shared(),
            &PlanRequest::new(),
            decode_from,
            7,
        )
        .expect("engine")
        .with_max_batch(4)
        .with_threads(4);
        let reps = if smoke { 1 } else { 4 };
        let mut rng = SplitMix64::new(17);
        for &b in &[1usize, 2, 4] {
            let mut identical = true;
            let mut input = vec![0f32; in_elems * b];
            for _ in 0..reps {
                rng.fill_f32(&mut input, 1.0);
                let want = resident.run_batch(&input, b).expect("resident");
                identical &= paged.run_batch(&input, b).expect("paged") == want;
                identical &= threaded.run_batch(&input, b).expect("threaded") == want;
            }
            assert!(identical, "paging the decode tail changed the numbers at batch {b}");
            let req_b = PlanRequest::new().with_batch(b);
            let resident_bytes = paged_svc
                .plan_dynamic(&dyn_recs, &req_b.with_dynamic(DynamicMode::Resolved(0)))
                .expect("plan")
                .peak;
            let full_bytes = paged_svc
                .plan_dynamic(&dyn_recs, &req_b.with_dynamic(DynamicMode::FullyResolved))
                .expect("plan")
                .peak;
            assert!(
                resident_bytes < full_bytes,
                "paged mode must keep strictly fewer bytes resident at batch {b}"
            );
            let blocks = paged_svc.pool().blocks();
            println!(
                "  batch {b}: resident {:.1} KiB vs {:.1} KiB worst-wave | paged {} block(s) \
                 peak, {:.0}% fragmentation | outputs identical (seq + 4 threads)",
                resident_bytes as f64 / 1024.0,
                full_bytes as f64 / 1024.0,
                blocks.peak_blocks(),
                blocks.fragmentation() * 100.0,
            );
            cases.push(Value::Obj(vec![
                ("name".into(), Value::Str(format!("paged_decode/b{b}"))),
                ("batch".into(), Value::Num(b as f64)),
                ("resident_kib".into(), Value::Num(resident_bytes as f64 / 1024.0)),
                ("peak_kib".into(), Value::Num(full_bytes as f64 / 1024.0)),
                ("blocks_peak".into(), Value::Num(blocks.peak_blocks() as f64)),
                ("fragmentation".into(), Value::Num(blocks.fragmentation())),
                ("identical".into(), Value::Bool(identical)),
            ]));
        }
        // Between bursts every tail block is back in the shared pool.
        assert_eq!(paged_svc.pool().blocks().blocks_in_use(), 0);
    }

    // --- continuous vs drain: admissions into in-flight decode loops ---
    {
        use harness::json::Value;
        use std::collections::VecDeque;
        use tensorarena::coordinator::ModelServer;
        let model = "blazeface";
        let g = tensorarena::models::by_name(model).unwrap();
        let in_elems = g.tensor(g.inputs[0]).num_elements();
        let decode_from = g.num_ops() / 2;
        let total = if smoke { 24 } else { 96 };
        let window = if smoke { 4 } else { 8 };
        let mean_us = 250.0f64;
        // One deterministic request stream and its reference outputs from a
        // sequential resident engine: identity must hold under either
        // scheduler, whatever interleaving the arrival jitter produces.
        let mut rng = SplitMix64::new(23);
        let mut reference =
            ExecutorEngine::new(&g, PlanService::shared(), "greedy-size", 7).expect("engine");
        let mut inputs = Vec::with_capacity(total);
        let mut wants = Vec::with_capacity(total);
        for _ in 0..total {
            let mut input = vec![0f32; in_elems];
            rng.fill_f32(&mut input, 1.0);
            wants.push(reference.run_batch(&input, 1).expect("reference"));
            inputs.push(input);
        }
        println!(
            "\ncontinuous vs drain ({model}, paged tail from op {decode_from}, {total} Poisson \
             arrivals, {window} closed-loop clients):"
        );
        for (mode, continuous) in [("drain", false), ("continuous", true)] {
            let svc = PlanService::shared();
            let server = {
                let svc = Arc::clone(&svc);
                ModelServer::spawn(
                    move || {
                        let g = tensorarena::models::by_name("blazeface").unwrap();
                        let engine = ExecutorEngine::for_request_paged(
                            &g,
                            svc,
                            &PlanRequest::new(),
                            decode_from,
                            7,
                        )
                        .expect("engine")
                        .with_max_batch(4);
                        if continuous {
                            Box::new(engine.with_continuous())
                        } else {
                            Box::new(engine)
                        }
                    },
                    BatchPolicy {
                        max_batch: 4,
                        max_wait: Duration::from_micros(200),
                        continuous,
                        queue_depth: 64,
                        ..BatchPolicy::default()
                    },
                )
                .expect("spawn")
            };
            let mut arrive = SplitMix64::new(29);
            let mut lat_us: Vec<f64> = Vec::with_capacity(total);
            let mut identical = true;
            let mut pending = VecDeque::new();
            let t = std::time::Instant::now();
            for (i, input) in inputs.iter().enumerate() {
                if pending.len() >= window {
                    let (j, sent, rx): (usize, std::time::Instant, _) =
                        pending.pop_front().expect("window is non-empty");
                    let got = rx.recv().expect("worker alive").expect("served");
                    lat_us.push(sent.elapsed().as_secs_f64() * 1e6);
                    identical &= got == wants[j];
                }
                pending.push_back((i, std::time::Instant::now(), server.submit(input.clone())));
                // Exponential inter-arrival gaps make the storm Poisson.
                let u = (arrive.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
                std::thread::sleep(Duration::from_micros((-(1.0 - u).ln() * mean_us) as u64));
            }
            while let Some((j, sent, rx)) = pending.pop_front() {
                let got = rx.recv().expect("worker alive").expect("served");
                lat_us.push(sent.elapsed().as_secs_f64() * 1e6);
                identical &= got == wants[j];
            }
            let wall = t.elapsed();
            assert!(identical, "{mode} scheduling changed the numbers");
            lat_us.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
            let p50 = lat_us[lat_us.len() / 2];
            let p95 = lat_us[(lat_us.len() - 1) * 95 / 100];
            let snap = server.metrics().snapshot();
            let rps = total as f64 / wall.as_secs_f64();
            println!(
                "  {mode:>10}: p50 {p50:>7.0} us, p95 {p95:>7.0} us, {rps:>6.0} req/s, \
                 {} mid-flight admission(s), outputs identical",
                snap.continuous_admissions
            );
            cases.push(Value::Obj(vec![
                ("name".into(), Value::Str(format!("continuous_decode/{mode}"))),
                ("mode".into(), Value::Str(mode.into())),
                ("clients".into(), Value::Num(window as f64)),
                ("requests".into(), Value::Num(total as f64)),
                ("p50_us".into(), Value::Num(p50)),
                ("p95_us".into(), Value::Num(p95)),
                ("throughput_rps".into(), Value::Num(rps)),
                ("continuous_admissions".into(), Value::Num(snap.continuous_admissions as f64)),
                ("identical".into(), Value::Bool(identical)),
            ]));
            server.shutdown();
        }
    }

    // --- quantized size classes: i8/f16 footprint + admission ---
    {
        use harness::json::Value;
        use tensorarena::exec::Executor;
        use tensorarena::planner::Dtype;
        let model = "mobilenet_v2";
        let g = tensorarena::models::by_name(model).unwrap();
        let in_elems = g.tensor(g.inputs[0]).num_elements();
        let recs = UsageRecords::from_graph(&g);
        let svc = PlanService::shared();
        let batches: &[usize] = if smoke { &[1, 4] } else { &[1, 2, 4, 8] };
        println!("\nquantized size classes ({model}, i8/f16 vs f32, batch sweep {batches:?}):");
        let f32_req = PlanRequest::new();
        let mut f32_exec =
            Executor::with_request(&g, Arc::clone(&svc), &f32_req, None, 7).expect("executor");
        for (dtype, drift) in [(Dtype::I8, 0.25f32), (Dtype::F16, 0.05f32)] {
            let req = PlanRequest::new().with_dtype(dtype);
            let mut q_exec =
                Executor::with_request(&g, Arc::clone(&svc), &req, None, 7).expect("executor");
            let mut rng = SplitMix64::new(31);
            for &b in batches {
                let planned = svc.plan(&recs, &req.with_batch(b)).expect("plan").total;
                let f32_planned = svc.plan(&recs, &f32_req.with_batch(b)).expect("plan").total;
                let shrink = f32_planned as f64 / planned.max(1) as f64;
                let mut input = vec![0f32; in_elems * b];
                rng.fill_f32(&mut input, 1.0);
                let want = f32_exec.run_batch(&input, b).expect("f32 run");
                let got = q_exec.run_batch(&input, b).expect("quantized run");
                let max_abs_err =
                    want.iter().zip(&got).map(|(a, c)| (a - c).abs()).fold(0f32, f32::max);
                let within_drift = max_abs_err <= drift;
                assert!(
                    within_drift,
                    "{dtype} outputs drifted {max_abs_err} (> {drift}) at batch {b}"
                );
                println!(
                    "  {dtype} b{b}: planned {:.1} KiB vs f32 {:.1} KiB ({shrink:.2}x), \
                     max |err| {max_abs_err:.4}",
                    planned as f64 / 1024.0,
                    f32_planned as f64 / 1024.0,
                );
                cases.push(Value::Obj(vec![
                    ("name".into(), Value::Str(format!("quantized/{dtype}/b{b}"))),
                    ("dtype".into(), Value::Str(dtype.key().into())),
                    ("batch".into(), Value::Num(b as f64)),
                    ("planned_kib".into(), Value::Num(planned as f64 / 1024.0)),
                    ("f32_planned_kib".into(), Value::Num(f32_planned as f64 / 1024.0)),
                    ("shrink".into(), Value::Num(shrink)),
                    ("max_abs_err".into(), Value::Num(f64::from(max_abs_err))),
                    ("within_drift".into(), Value::Bool(within_drift)),
                ]));
            }
        }
        // Admission: the same byte budget must resolve a strictly larger
        // i8 cap — the `serve --dtype i8 --mem-budget` acceptance property.
        let budget = svc.plan(&recs, &f32_req.with_batch(2)).expect("plan").total;
        let cap_f32 = svc.max_servable_batch(&recs, &f32_req, budget).expect("cap");
        let cap_i8 = svc
            .max_servable_batch(&recs, &PlanRequest::new().with_dtype(Dtype::I8), budget)
            .expect("cap");
        assert!(cap_i8 > cap_f32, "i8 must admit a larger batch under the same budget");
        println!(
            "  admission under {:.1} KiB: f32 cap {cap_f32} vs i8 cap {cap_i8}",
            budget as f64 / 1024.0
        );
        cases.push(Value::Obj(vec![
            ("name".into(), Value::Str("quantized/admission".into())),
            ("budget_kib".into(), Value::Num(budget as f64 / 1024.0)),
            ("cap_f32".into(), Value::Num(cap_f32 as f64)),
            ("cap_i8".into(), Value::Num(cap_i8 as f64)),
            ("larger".into(), Value::Bool(cap_i8 > cap_f32)),
        ]));
    }

    // --- warm vs cold start: a plan-directory restart ---
    {
        let model = if smoke { "blazeface" } else { "mobilenet_v1" };
        let dir = std::env::temp_dir().join(format!(
            "tensorarena-bench-plandir-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let g = tensorarena::models::by_name(model).unwrap();
        let recs = UsageRecords::from_graph(&g);
        let batches = [1usize, 2, 4, 8];
        println!("\nwarm vs cold start ({model}, batches {batches:?}):");

        let cold = PlanService::new();
        let t = std::time::Instant::now();
        for &b in &batches {
            cold.plan(&recs, &cold.request().with_batch(b)).expect("plan");
        }
        let cold_time = t.elapsed();
        let persisted = cold.persist_dir(&dir).expect("persist");
        println!(
            "  cold: {cold_time:?}, {} planner invocations ({} plans persisted)",
            cold.stats().cache_misses,
            persisted.written
        );

        let warm = PlanService::new();
        let t = std::time::Instant::now();
        let report = warm.warm_start(&dir, &recs, &warm.request()).expect("warm start");
        for &b in &batches {
            warm.plan(&recs, &warm.request().with_batch(b)).expect("plan");
        }
        let warm_time = t.elapsed();
        println!(
            "  warm: {warm_time:?}, {} planner invocations ({} plans loaded, {} skipped)",
            warm.stats().cache_misses,
            report.loaded,
            report.skipped()
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    // --- macro: PJRT artifacts, if built ---
    #[cfg(feature = "pjrt")]
    let dir = std::path::Path::new("artifacts");
    #[cfg(feature = "pjrt")]
    if tensorarena::runtime::Runtime::discover_variants(dir, "model").is_ok() {
        use tensorarena::coordinator::engine::PjrtEngine;
        use tensorarena::runtime::{Runtime, VariantSet};
        println!("\nPJRT closed-loop storm (256 requests):");
        for max_batch in [1usize, 8] {
            let engine_service = PlanService::shared();
            let twin_recs =
                UsageRecords::from_graph(&tensorarena::models::l2_cnn());
            let mut router = Router::new();
            router.register(
                "cnn",
                move || {
                    let rt = Runtime::cpu().expect("PJRT");
                    let vs = VariantSet::load(&rt, std::path::Path::new("artifacts"), "model", &[32, 32, 3], 10)
                        .expect("artifacts");
                    Box::new(
                        PjrtEngine::with_request(
                            vs,
                            engine_service,
                            twin_recs,
                            &PlanRequest::new().with_batch(max_batch),
                        )
                        .expect("twin plan"),
                    )
                },
                BatchPolicy { max_batch, max_wait: Duration::from_millis(2), ..BatchPolicy::default() },
            )
            .expect("register");
            let mut rng = SplitMix64::new(2);
            let mut input = vec![0f32; 32 * 32 * 3];
            let t = std::time::Instant::now();
            let pending: Vec<_> = (0..256)
                .map(|_| {
                    rng.fill_f32(&mut input, 1.0);
                    router.submit("cnn", input.clone())
                })
                .collect();
            let ok = pending
                .into_iter()
                .filter(|rx| matches!(rx.recv(), Ok(Ok(_))))
                .count();
            let wall = t.elapsed();
            let snap = router.server("cnn").unwrap().metrics().snapshot();
            println!(
                "  max_batch {max_batch:>2}: {ok}/256 ok, {:>7.1} req/s, p50 {:.2} ms, mean batch {:.2}",
                ok as f64 / wall.as_secs_f64(),
                snap.p50_us as f64 / 1000.0,
                snap.mean_batch
            );
            router.shutdown();
        }
    } else {
        println!("\n(artifacts/ missing: run `make artifacts` for the PJRT macro bench)");
    }

    // --- BENCH_*.json: emit and/or schema-check the recorded trajectory ---
    {
        use harness::json::Value;
        let doc = Value::Obj(vec![
            ("bench".into(), Value::Str("serving".into())),
            ("schema_version".into(), Value::Num(1.0)),
            ("model".into(), Value::Str("mobilenet_v2".into())),
            ("smoke".into(), Value::Bool(smoke)),
            ("cases".into(), Value::Arr(cases)),
        ]);
        if let Some(path) = &json_out {
            std::fs::write(path, doc.render() + "\n")
                .unwrap_or_else(|e| panic!("--json {path}: {e}"));
            println!("\nwrote {path}");
        }
        if let Some(path) = &check_path {
            let text = std::fs::read_to_string(path)
                .unwrap_or_else(|e| panic!("--check {path}: {e}"));
            let committed = harness::json::parse(&text)
                .unwrap_or_else(|e| panic!("--check {path}: {e}"));
            let mut drift = Vec::new();
            // Identity fields must match exactly; everything else — most of
            // all the timings — is compared by *shape* only, so a slow CI
            // box can never fail the check.
            for key in ["bench", "schema_version", "model"] {
                if doc.get(key) != committed.get(key) {
                    drift.push(format!("identity field '{key}' differs"));
                }
            }
            let (got, want) = (doc.schema(), committed.schema());
            if got != want {
                drift.push(format!("schema drift:\n    fresh:     {got}\n    committed: {want}"));
            }
            if drift.is_empty() {
                println!("schema check vs {path}: OK");
            } else {
                eprintln!("schema check vs {path} FAILED:");
                for d in &drift {
                    eprintln!("  {d}");
                }
                std::process::exit(1);
            }
        }
    }
}
