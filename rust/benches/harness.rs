//! Minimal shared bench harness (the offline vendored registry has no
//! criterion). Each bench binary includes this via `#[path]`.
//!
//! Reports median / min / mean over `iters` timed runs after `warmup`
//! untimed ones, criterion-style enough for EXPERIMENTS.md.

#![allow(dead_code)]

use std::time::{Duration, Instant};

/// Timing statistics of one benchmark case.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub median: Duration,
    pub min: Duration,
    pub mean: Duration,
}

/// Run `f` `iters` times (after `warmup` warmups) and report stats.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    Stats {
        median: samples[iters / 2],
        min: samples[0],
        mean,
    }
}

/// Pretty-print one case line.
pub fn report(name: &str, stats: Stats) {
    println!(
        "{name:<52} median {:>10.3?} min {:>10.3?} mean {:>10.3?}",
        stats.median, stats.min, stats.mean
    );
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
