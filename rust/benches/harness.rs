//! Minimal shared bench harness (the offline vendored registry has no
//! criterion). Each bench binary includes this via `#[path]`.
//!
//! Reports median / min / mean over `iters` timed runs after `warmup`
//! untimed ones, criterion-style enough for EXPERIMENTS.md. The [`json`]
//! module is the hand-rolled emitter/parser behind the committed
//! `BENCH_*.json` trajectory files: benches render their results as a
//! [`json::Value`] tree, and CI re-parses the committed baseline to compare
//! *schemas* (names and keys), never timings — see `docs/ARCHITECTURE.md`.

#![allow(dead_code)]

use std::time::{Duration, Instant};

/// Timing statistics of one benchmark case.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub median: Duration,
    pub min: Duration,
    pub mean: Duration,
}

impl Stats {
    /// Median in microseconds — the unit the JSON trajectory records.
    pub fn median_us(&self) -> f64 {
        self.median.as_secs_f64() * 1e6
    }
    /// Minimum in microseconds.
    pub fn min_us(&self) -> f64 {
        self.min.as_secs_f64() * 1e6
    }
    /// Mean in microseconds.
    pub fn mean_us(&self) -> f64 {
        self.mean.as_secs_f64() * 1e6
    }
}

/// Run `f` `iters` times (after `warmup` warmups) and report stats.
///
/// `iters` is clamped to at least one timed run: a smoke configuration that
/// scales iteration counts down (e.g. `iters / 100`) must degrade to a
/// 1-sample measurement, not a panic on an empty sample set.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    debug_assert!(iters > 0, "bench called with iters == 0; clamping to 1");
    let iters = iters.max(1);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    Stats {
        median: samples[iters / 2],
        min: samples[0],
        mean,
    }
}

/// Pretty-print one case line.
pub fn report(name: &str, stats: Stats) {
    println!(
        "{name:<52} median {:>10.3?} min {:>10.3?} mean {:>10.3?}",
        stats.median, stats.min, stats.mean
    );
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Hand-rolled JSON for the `BENCH_*.json` trajectory files (the offline
/// vendored registry has no serde). Small by design: objects are ordered
/// key/value vectors, numbers are `f64`, and the only consumer is the
/// bench emitter plus the CI schema check.
pub mod json {
    /// One JSON value.
    #[derive(Debug, Clone, PartialEq)]
    pub enum Value {
        Null,
        Bool(bool),
        Num(f64),
        Str(String),
        Arr(Vec<Value>),
        Obj(Vec<(String, Value)>),
    }

    impl Value {
        /// Look up a key in an object (None for non-objects).
        pub fn get(&self, key: &str) -> Option<&Value> {
            match self {
                Value::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
                _ => None,
            }
        }

        /// The string payload, if this is a string.
        pub fn as_str(&self) -> Option<&str> {
            match self {
                Value::Str(s) => Some(s),
                _ => None,
            }
        }

        /// The numeric payload, if this is a number.
        pub fn as_num(&self) -> Option<f64> {
            match self {
                Value::Num(n) => Some(*n),
                _ => None,
            }
        }

        /// The element list, if this is an array.
        pub fn as_arr(&self) -> Option<&[Value]> {
            match self {
                Value::Arr(a) => Some(a),
                _ => None,
            }
        }

        /// Render as compact JSON text (keys in insertion order).
        pub fn render(&self) -> String {
            let mut out = String::new();
            self.render_into(&mut out, 0);
            out
        }

        fn render_into(&self, out: &mut String, indent: usize) {
            match self {
                Value::Null => out.push_str("null"),
                Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
                Value::Num(n) => out.push_str(&render_num(*n)),
                Value::Str(s) => {
                    out.push('"');
                    out.push_str(&escape(s));
                    out.push('"');
                }
                Value::Arr(items) => {
                    out.push('[');
                    for (i, v) in items.iter().enumerate() {
                        if i > 0 {
                            out.push(',');
                        }
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                        v.render_into(out, indent + 1);
                    }
                    if !items.is_empty() {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent));
                    }
                    out.push(']');
                }
                Value::Obj(kv) => {
                    out.push('{');
                    for (i, (k, v)) in kv.iter().enumerate() {
                        if i > 0 {
                            out.push(',');
                        }
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                        out.push('"');
                        out.push_str(&escape(k));
                        out.push_str("\": ");
                        v.render_into(out, indent + 1);
                    }
                    if !kv.is_empty() {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent));
                    }
                    out.push('}');
                }
            }
        }

        /// Canonical *schema* string of this value: keys sorted, timings and
        /// every other leaf collapsed to its type. Two bench runs drift in
        /// numbers but must agree here — this is what CI compares.
        pub fn schema(&self) -> String {
            match self {
                Value::Null => "null".into(),
                Value::Bool(_) => "bool".into(),
                Value::Num(_) => "num".into(),
                Value::Str(_) => "str".into(),
                Value::Arr(items) => {
                    // Element schemas, deduplicated in sorted order: an
                    // array of homogeneous cases collapses to one entry.
                    let mut elems: Vec<String> = items.iter().map(|v| v.schema()).collect();
                    elems.sort();
                    elems.dedup();
                    format!("[{}]", elems.join("|"))
                }
                Value::Obj(kv) => {
                    let mut fields: Vec<String> =
                        kv.iter().map(|(k, v)| format!("{}:{}", k, v.schema())).collect();
                    fields.sort();
                    format!("{{{}}}", fields.join(","))
                }
            }
        }
    }

    fn escape(s: &str) -> String {
        let mut out = String::with_capacity(s.len());
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\t' => out.push_str("\\t"),
                '\r' => out.push_str("\\r"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out
    }

    fn render_num(n: f64) -> String {
        if n.fract() == 0.0 && n.abs() < 1e15 {
            (n as i64).to_string()
        } else {
            n.to_string()
        }
    }

    /// Parse JSON text. Supports the full value grammar the emitter
    /// produces (no `\u` surrogate pairs beyond the BMP).
    pub fn parse(s: &str) -> Result<Value, String> {
        let b = s.as_bytes();
        let mut i = 0;
        let v = parse_value(b, &mut i)?;
        skip_ws(b, &mut i);
        if i != b.len() {
            return Err(format!("trailing bytes at offset {i}"));
        }
        Ok(v)
    }

    fn skip_ws(b: &[u8], i: &mut usize) {
        while *i < b.len() && matches!(b[*i], b' ' | b'\t' | b'\n' | b'\r') {
            *i += 1;
        }
    }

    fn parse_value(b: &[u8], i: &mut usize) -> Result<Value, String> {
        skip_ws(b, i);
        match b.get(*i) {
            None => Err("unexpected end of input".into()),
            Some(b'{') => parse_obj(b, i),
            Some(b'[') => parse_arr(b, i),
            Some(b'"') => Ok(Value::Str(parse_str(b, i)?)),
            Some(b't') => parse_lit(b, i, "true", Value::Bool(true)),
            Some(b'f') => parse_lit(b, i, "false", Value::Bool(false)),
            Some(b'n') => parse_lit(b, i, "null", Value::Null),
            Some(_) => parse_num(b, i),
        }
    }

    fn parse_lit(b: &[u8], i: &mut usize, lit: &str, v: Value) -> Result<Value, String> {
        if b[*i..].starts_with(lit.as_bytes()) {
            *i += lit.len();
            Ok(v)
        } else {
            Err(format!("expected '{lit}' at offset {i}", i = *i))
        }
    }

    fn parse_num(b: &[u8], i: &mut usize) -> Result<Value, String> {
        let start = *i;
        while *i < b.len() && matches!(b[*i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
            *i += 1;
        }
        std::str::from_utf8(&b[start..*i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or_else(|| format!("bad number at offset {start}"))
    }

    fn parse_str(b: &[u8], i: &mut usize) -> Result<String, String> {
        *i += 1; // opening quote
        let mut out = String::new();
        while *i < b.len() {
            match b[*i] {
                b'"' => {
                    *i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    *i += 1;
                    match b.get(*i) {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = b
                                .get(*i + 1..*i + 5)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .and_then(char::from_u32)
                                .ok_or_else(|| format!("bad \\u escape at offset {i}", i = *i))?;
                            out.push(hex);
                            *i += 4;
                        }
                        _ => return Err(format!("bad escape at offset {i}", i = *i)),
                    }
                    *i += 1;
                }
                _ => {
                    // Consume one UTF-8 scalar (multi-byte sequences pass
                    // through unmodified).
                    let rest = std::str::from_utf8(&b[*i..])
                        .map_err(|_| format!("invalid UTF-8 at offset {i}", i = *i))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    *i += c.len_utf8();
                }
            }
        }
        Err("unterminated string".into())
    }

    fn parse_arr(b: &[u8], i: &mut usize) -> Result<Value, String> {
        *i += 1; // '['
        let mut items = Vec::new();
        loop {
            skip_ws(b, i);
            if b.get(*i) == Some(&b']') {
                *i += 1;
                return Ok(Value::Arr(items));
            }
            if !items.is_empty() {
                if b.get(*i) != Some(&b',') {
                    return Err(format!("expected ',' in array at offset {i}", i = *i));
                }
                *i += 1;
            }
            items.push(parse_value(b, i)?);
        }
    }

    fn parse_obj(b: &[u8], i: &mut usize) -> Result<Value, String> {
        *i += 1; // '{'
        let mut kv = Vec::new();
        loop {
            skip_ws(b, i);
            if b.get(*i) == Some(&b'}') {
                *i += 1;
                return Ok(Value::Obj(kv));
            }
            if !kv.is_empty() {
                if b.get(*i) != Some(&b',') {
                    return Err(format!("expected ',' in object at offset {i}", i = *i));
                }
                *i += 1;
                skip_ws(b, i);
            }
            if b.get(*i) != Some(&b'"') {
                return Err(format!("expected key at offset {i}", i = *i));
            }
            let k = parse_str(b, i)?;
            skip_ws(b, i);
            if b.get(*i) != Some(&b':') {
                return Err(format!("expected ':' at offset {i}", i = *i));
            }
            *i += 1;
            let v = parse_value(b, i)?;
            kv.push((k, v));
        }
    }
}
