//! §1 locality claim: "Efficiently reusing memory buffers leads to improved
//! cache hit rate that can also translate to up to 10% improvement in
//! inference speed."
//!
//! ```sh
//! cargo bench --offline --bench locality
//! ```
//!
//! Two measurements per network:
//! 1. **Stack-distance simulation** (hardware-independent): LRU hit rate of
//!    the inference memory trace under the planned arena vs the naive
//!    layout, across cache sizes.
//! 2. **Wall time** of the CPU executor under both plans (same kernels,
//!    same numbers — only buffer placement differs).

#[path = "harness.rs"]
mod harness;

use tensorarena::exec::{cachesim, Executor};
use tensorarena::models;
use tensorarena::planner::offset::{GreedyBySize, NaiveOffset};
use tensorarena::planner::OffsetPlanner;
use tensorarena::records::UsageRecords;
use tensorarena::rng::SplitMix64;

fn main() {
    println!("== LRU hit-rate simulation: Greedy-by-Size arena vs Naive ==\n");
    println!(
        "{:<14} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "network", "256K pl", "256K nv", "1M pl", "1M nv", "4M pl", "4M nv"
    );
    for g in models::all_zoo() {
        let recs = UsageRecords::from_graph(&g);
        let pl = cachesim::simulate(&g, &recs, &GreedyBySize.plan(&recs));
        let nv = cachesim::simulate(&g, &recs, &NaiveOffset.plan(&recs));
        println!(
            "{:<14} {:>9.4} {:>9.4} {:>9.4} {:>9.4} {:>9.4} {:>9.4}",
            g.name,
            pl.hit_rate(256 << 10),
            nv.hit_rate(256 << 10),
            pl.hit_rate(1 << 20),
            nv.hit_rate(1 << 20),
            pl.hit_rate(4 << 20),
            nv.hit_rate(4 << 20),
        );
    }

    println!("\n== Executor wall time per inference (planned vs naive arena) ==\n");
    // Smaller nets run enough iterations to matter; the large ones once.
    for (name, iters) in [("blazeface", 10), ("l2_cnn", 30), ("mobilenet_v1", 2)] {
        let g = models::by_name(name).unwrap();
        let n_in = g.tensor(g.inputs[0]).num_elements();
        let mut rng = SplitMix64::new(5);
        let mut x = vec![0f32; n_in];
        rng.fill_f32(&mut x, 1.0);

        let mut planned = Executor::new(&g, &GreedyBySize, 7).unwrap();
        let mut naive = Executor::new(&g, &NaiveOffset, 7).unwrap();
        let sp = harness::bench(1, iters, || {
            harness::black_box(planned.run(&[&x]));
        });
        let sn = harness::bench(1, iters, || {
            harness::black_box(naive.run(&[&x]));
        });
        println!(
            "{name:<14} planned {:>10.3?} naive {:>10.3?} speedup {:>5.1}% (arena {} KiB vs {} KiB)",
            sp.median,
            sn.median,
            (sn.median.as_secs_f64() / sp.median.as_secs_f64() - 1.0) * 100.0,
            planned.arena_bytes() / 1024,
            naive.arena_bytes() / 1024,
        );
    }
}
