//! §7.1 ablation: how much does execution-order choice move the footprint?
//!
//! ```sh
//! cargo bench --offline --bench ordering
//! ```
//!
//! For every zoo network: arena size (offset Greedy by Size) under the
//! stored TFLite-style order, the memory-aware greedy order, and 100
//! ε-greedy annealing trials — the paper's named future-work direction,
//! implemented in `planner::order`.

#[path = "harness.rs"]
mod harness;

use tensorarena::models;
use tensorarena::planner::order::{anneal_order, apply_order, memory_aware_order, order_ablation};
use tensorarena::planner::{registry, PlanService};
use tensorarena::records::UsageRecords;

fn main() {
    const MIB: f64 = 1024.0 * 1024.0;
    println!(
        "{:<14} {:>12} {:>12} {:>12} {:>8}",
        "network", "stored MiB", "greedy MiB", "anneal MiB", "delta"
    );
    for g in models::all_zoo() {
        let (base, greedy, annealed) = order_ablation(&g, 42, 100);
        println!(
            "{:<14} {:>12.3} {:>12.3} {:>12.3} {:>+7.2}%",
            g.name,
            base as f64 / MIB,
            greedy as f64 / MIB,
            annealed as f64 / MIB,
            (annealed as f64 / base as f64 - 1.0) * 100.0
        );
    }

    // The same ablation through the serving stack's registry keys: one
    // PlanService, order-keyed cache slots, breadth deltas as ArenaStats
    // would report them. This is the path `serve --order` takes.
    println!("\nregistry order strategies through the PlanService (greedy-size arena):");
    println!(
        "{:<14} {:>18} {:>12} {:>12} {:>12}",
        "network", "order", "breadth MiB", "arena MiB", "delta br"
    );
    for g in models::all_zoo() {
        let service = PlanService::shared();
        for key in ["natural", "memory-aware", "annealed-s42-t100"] {
            let order = registry::order_strategy(key).expect("registry order key");
            let (ordered, applied) = apply_order(&g, order);
            let recs = UsageRecords::from_graph(&ordered);
            let plan = service
                .plan(&recs, &service.request().with_order(order))
                .expect("plan");
            println!(
                "{:<14} {:>18} {:>12.3} {:>12.3} {:>+11.3}",
                g.name,
                key,
                applied.order_breadth as f64 / MIB,
                plan.total_size() as f64 / MIB,
                applied.breadth_delta() as f64 / MIB,
            );
        }
        let st = service.stats();
        assert_eq!(st.cache_hits, 0, "each order key must be a distinct slot");
    }

    println!("\nscheduler wall time:");
    for g in models::all_zoo() {
        let stats = harness::bench(1, 5, || {
            harness::black_box(memory_aware_order(&g));
        });
        harness::report(&format!("{} / memory-aware order", g.name), stats);
    }
    let g = models::inception_v3();
    let stats = harness::bench(0, 3, || {
        harness::black_box(anneal_order(&g, 1, 20));
    });
    harness::report("inception_v3 / anneal (20 trials)", stats);
}
