//! §7.1 ablation: how much does execution-order choice move the footprint?
//!
//! ```sh
//! cargo bench --offline --bench ordering
//! ```
//!
//! For every zoo network: arena size (offset Greedy by Size) under the
//! stored TFLite-style order, the memory-aware greedy order, and 100
//! ε-greedy annealing trials — the paper's named future-work direction,
//! implemented in `planner::order`.

#[path = "harness.rs"]
mod harness;

use tensorarena::models;
use tensorarena::planner::order::{anneal_order, memory_aware_order, order_ablation};

fn main() {
    const MIB: f64 = 1024.0 * 1024.0;
    println!(
        "{:<14} {:>12} {:>12} {:>12} {:>8}",
        "network", "stored MiB", "greedy MiB", "anneal MiB", "delta"
    );
    for g in models::all_zoo() {
        let (base, greedy, annealed) = order_ablation(&g, 42, 100);
        println!(
            "{:<14} {:>12.3} {:>12.3} {:>12.3} {:>+7.2}%",
            g.name,
            base as f64 / MIB,
            greedy as f64 / MIB,
            annealed as f64 / MIB,
            (annealed as f64 / base as f64 - 1.0) * 100.0
        );
    }

    println!("\nscheduler wall time:");
    for g in models::all_zoo() {
        let stats = harness::bench(1, 5, || {
            harness::black_box(memory_aware_order(&g));
        });
        harness::report(&format!("{} / memory-aware order", g.name), stats);
    }
    let g = models::inception_v3();
    let stats = harness::bench(0, 3, || {
        harness::black_box(anneal_order(&g, 1, 20));
    });
    harness::report("inception_v3 / anneal (20 trials)", stats);
}
