//! Planner complexity scaling on synthetic graphs: §4.2 claims O(kn²)
//! naive / O(kn log n) with interval trees — this bench shows how each
//! strategy's wall time grows with the number of intermediate tensors.
//!
//! ```sh
//! cargo bench --offline --bench planner_scaling
//! ```

#[path = "harness.rs"]
mod harness;

use tensorarena::planner::{table1_strategies, table2_strategies};
use tensorarena::records::UsageRecords;
use tensorarena::rng::SplitMix64;

/// Synthetic residual-network-like usage records: a chain with skip
/// connections and size variety (same generator family as the property
/// tests).
fn synth(seed: u64, n: usize) -> UsageRecords {
    let mut rng = SplitMix64::new(seed);
    let mut triples = Vec::with_capacity(n);
    let mut op = 0usize;
    for i in 0..n {
        let span = if i % 5 == 4 {
            rng.next_range(2, 8) // skip connection
        } else {
            1
        };
        triples.push((op, op + span, 64 * rng.next_range(1, 256)));
        op += 1;
    }
    UsageRecords::from_triples(&triples)
}

fn main() {
    println!("strategy wall time vs record count (median of 5, ms):\n");
    let sizes = [64usize, 128, 256, 512, 1024];
    print!("{:<40}", "strategy \\ n");
    for n in sizes {
        print!("{n:>10}");
    }
    println!();
    for strat in table1_strategies() {
        if strat.name() == "Min-cost Flow (Lee et al., 2019)" {
            continue; // measured separately below (quadratic edges)
        }
        print!("{:<40}", format!("[shared] {}", strat.name()));
        for n in sizes {
            let recs = synth(42, n);
            let st = harness::bench(1, 5, || {
                harness::black_box(strat.plan(&recs));
            });
            print!("{:>10.2}", st.median.as_secs_f64() * 1e3);
        }
        println!();
    }
    // Min-cost flow only up to 512 (O(n^2) edges, SSP augmentations).
    {
        let strat: Box<dyn tensorarena::planner::SharedObjectPlanner> =
            Box::new(tensorarena::planner::shared::MinCostFlow);
        print!("{:<40}", "[shared] Min-cost Flow (Lee et al., 2019)");
        for n in sizes {
            if n > 512 {
                print!("{:>10}", "-");
                continue;
            }
            let recs = synth(42, n);
            let st = harness::bench(0, 3, || {
                harness::black_box(strat.plan(&recs));
            });
            print!("{:>10.2}", st.median.as_secs_f64() * 1e3);
        }
        println!();
    }
    for strat in table2_strategies() {
        print!("{:<40}", format!("[offset] {}", strat.name()));
        for n in sizes {
            let recs = synth(42, n);
            let st = harness::bench(1, 5, || {
                harness::black_box(strat.plan(&recs));
            });
            print!("{:>10.2}", st.median.as_secs_f64() * 1e3);
        }
        println!();
    }
}
