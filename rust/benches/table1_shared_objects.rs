//! Bench + regeneration of **Table 1**: Shared Objects strategies over the
//! six evaluation networks.
//!
//! ```sh
//! cargo bench --offline --bench table1_shared_objects
//! ```
//!
//! Prints the table in the paper's layout (MiB, best-in-column starred)
//! followed by planner wall-times per network.

#[path = "harness.rs"]
mod harness;

use tensorarena::models;
use tensorarena::planner::registry;
use tensorarena::records::UsageRecords;
use tensorarena::report;

fn main() {
    // The table itself (identical to `tensorarena table1`).
    print!("{}", report::table1().render());

    println!("\nplanner wall time (median of 10):");
    for g in models::all_zoo() {
        let recs = UsageRecords::from_graph(&g);
        for strat in registry::shared_strategies() {
            let name = format!("{} / {}", g.name, strat.name());
            let stats = harness::bench(2, 10, || {
                harness::black_box(strat.plan(&recs));
            });
            harness::report(&name, stats);
        }
    }
}
