//! Adversarial and acceptance tests for the spill tier: the codec must
//! round-trip arbitrary word streams bit-exactly without ever beating the
//! stored-raw bound, a spill directory must survive truncation, bit flips,
//! wrong lengths, and stale formats by *skipping* (counted, typed) — never
//! by corrupting a reload — and the serve-level acceptance: a request the
//! refuse policy rejects is admitted under `--spill-policy spill` and
//! served bit-identically, with nonzero eviction/reload counters.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;
use tensorarena::arena::spill::{compress, decompress, SpillTier};
use tensorarena::coordinator::engine::ExecutorEngine;
use tensorarena::coordinator::{BatchPolicy, ModelServer, ServeError, SpillPolicy};
use tensorarena::models;
use tensorarena::planner::PlanService;
use tensorarena::records::UsageRecords;
use tensorarena::rng::SplitMix64;

/// Fresh scratch directory under the system temp dir (no tempfile crate in
/// the offline registry); each test uses its own tag.
fn scratch_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("tensorarena-spill-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn tmp_leftovers(dir: &std::path::Path) -> Vec<String> {
    std::fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .filter(|n| n.ends_with(".tmp"))
        .collect()
}

#[test]
fn codec_property_random_streams_roundtrip_within_the_raw_bound() {
    // Seeded pseudo-random streams across lengths and sparsity profiles:
    // every one must round-trip bit-exactly and never exceed the
    // stored-raw bound of 1 + 4·words bytes.
    let mut rng = SplitMix64::new(0xC0FFEE);
    for len in [0usize, 1, 2, 7, 64, 255, 1024, 4097] {
        for sparsity in [0usize, 2, 7, 100] {
            let mut words = vec![0f32; len];
            rng.fill_f32(&mut words, 1.0);
            if sparsity > 0 {
                for (i, w) in words.iter_mut().enumerate() {
                    if i % sparsity != 0 {
                        *w = 0.0;
                    }
                }
            }
            let c = compress(&words);
            assert!(
                c.len() <= 1 + 4 * len,
                "len {len} sparsity {sparsity}: compressed {} > raw bound {}",
                c.len(),
                1 + 4 * len
            );
            let back = decompress(&c).expect("own output must decode");
            assert_eq!(back.len(), words.len(), "len {len} sparsity {sparsity}");
            for (a, b) in words.iter().zip(&back) {
                assert_eq!(a.to_bits(), b.to_bits(), "len {len} sparsity {sparsity}");
            }
        }
    }
    // The bit patterns f32 equality would mangle: NaN payloads and -0.0.
    let odd = [f32::from_bits(0x7fc0_dead), -0.0, f32::from_bits(0xff80_0001), 0.0];
    let back = decompress(&compress(&odd)).unwrap();
    for (a, b) in odd.iter().zip(&back) {
        assert_eq!(a.to_bits(), b.to_bits(), "NaN payloads / signed zeros must survive");
    }
}

#[test]
fn codec_rejects_adversarial_byte_streams_without_panicking() {
    // Deterministic garbage of many lengths under both tags (and no tag):
    // decompress must return None or a valid buffer — never panic. Bytes
    // stay below 0x80 so garbage run lengths decode as single-byte varints
    // and a "valid" accidental stream stays small.
    for len in [0usize, 1, 3, 5, 17, 255, 1000] {
        for tag in [0u8, 1, 2, 0xff] {
            let mut bytes = vec![tag];
            bytes.extend((0..len).map(|i| (i as u32 * 2654435761 % 120) as u8));
            if let Some(decoded) = decompress(&bytes) {
                // Accepting is fine (raw payloads of aligned garbage are
                // valid) — but then re-encoding must round-trip it.
                let back = decompress(&compress(&decoded)).unwrap();
                assert_eq!(decoded.len(), back.len());
            }
        }
    }
}

#[test]
fn disk_entries_survive_a_process_handoff_bit_exactly() {
    // Tier A persists; a fresh tier B (a "restarted process") adopts and
    // reloads the same bytes. The reloaded buffer must be bit-identical,
    // and the reload must remove the disk file.
    let dir = scratch_dir("handoff");
    let ramp: Vec<f32> = (0..500).map(|i| (i as f32).sin()).collect();
    {
        let a = SpillTier::with_dir(&dir).unwrap();
        a.spill(ramp.clone());
        a.spill(vec![0.0; 2000]);
        assert_eq!(a.disk_write_errors(), 0);
    }
    assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 2, "two persisted entries");

    let b = SpillTier::with_dir(&dir).unwrap();
    let report = b.load_dir().unwrap();
    assert_eq!(report.loaded, 2, "{report:?}");
    assert_eq!(report.skipped(), 0, "{report:?}");
    let got = b.reload(500).expect("adopted entry must reload");
    assert_eq!(got.len(), 500);
    for (x, y) in ramp.iter().zip(&got) {
        assert_eq!(x.to_bits(), y.to_bits(), "handoff must be bit-exact");
    }
    assert_eq!(
        std::fs::read_dir(&dir).unwrap().count(),
        1,
        "a reload must remove its disk file"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn damaged_spill_files_are_skipped_with_typed_counters() {
    // One undamaged entry plus one file per damage class. The adoption
    // must load exactly the undamaged one, count each damage class in its
    // own counter, and the single reload must return the undamaged bytes.
    let dir = scratch_dir("damage");
    {
        let a = SpillTier::with_dir(&dir).unwrap();
        for len in [100usize, 200, 300, 400, 500, 600] {
            a.spill((0..len).map(|i| i as f32 * 0.5).collect());
        }
    }
    let mut names: Vec<PathBuf> = std::fs::read_dir(&dir).unwrap().map(|e| e.unwrap().path()).collect();
    names.sort();
    assert_eq!(names.len(), 6);
    // names[0] (w100): keep undamaged.
    // names[1] (w200): truncate mid-payload (short of the declared bytes).
    let data = std::fs::read(&names[1]).unwrap();
    std::fs::write(&names[1], &data[..data.len() - 3]).unwrap();
    // names[2] (w300): flip one payload bit — checksum must catch it.
    let mut data = std::fs::read(&names[2]).unwrap();
    let last = data.len() - 1;
    data[last] ^= 0x40;
    std::fs::write(&names[2], data).unwrap();
    // names[3] (w400): append a byte past the declared length.
    let mut data = std::fs::read(&names[3]).unwrap();
    data.push(0);
    std::fs::write(&names[3], data).unwrap();
    // names[4] (w500): a header word count the payload does not decode to.
    let mut data = std::fs::read(&names[4]).unwrap();
    let forged = data
        .windows("words 500 ".len())
        .position(|w| w == b"words 500 ")
        .expect("fixture drifted: header must declare 'words 500'");
    data[forged + 6..forged + 9].copy_from_slice(b"501");
    std::fs::write(&names[4], data).unwrap();
    // names[5] (w600): a future format version.
    let data = std::fs::read(&names[5]).unwrap();
    let mut forged = b"tensorarena-spill v9".to_vec();
    forged.extend_from_slice(&data["tensorarena-spill v1".len()..]);
    std::fs::write(&names[5], forged).unwrap();
    // Plus pure noise the listing must ignore entirely.
    std::fs::write(dir.join("README.txt"), "not a spill entry").unwrap();
    std::fs::write(dir.join(".spill-junk.tmp"), "torn").unwrap();

    let b = SpillTier::with_dir(&dir).unwrap();
    let report = b.load_dir().unwrap();
    assert_eq!(report.loaded, 1, "{report:?}");
    assert_eq!(report.skipped_truncated, 1, "{report:?}");
    assert_eq!(report.skipped_corrupt, 1, "{report:?}");
    assert_eq!(report.skipped_wrong_length, 2, "{report:?}");
    assert_eq!(report.skipped_stale_format, 1, "{report:?}");
    assert_eq!(report.skipped(), 5);
    let got = b.reload(100).expect("the undamaged entry");
    assert_eq!(got.len(), 100);
    for (i, v) in got.iter().enumerate() {
        assert_eq!(v.to_bits(), (i as f32 * 0.5).to_bits(), "reload corrupted word {i}");
    }
    assert!(b.reload(450).is_none(), "damaged entries must not be servable");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn failed_spill_persist_leaves_no_tmp_and_keeps_the_entry() {
    // Force the atomic rename to fail by planting a *directory* at the
    // entry's final path (tests run as root, so permission tricks cannot
    // force a write failure). The spill must count the disk error, leave
    // no `.tmp` behind, and keep serving the in-memory copy.
    let dir = scratch_dir("no-tmp");
    let tier = SpillTier::with_dir(&dir).unwrap();
    std::fs::create_dir(dir.join("spill-0000000000000000-w64.spill")).unwrap();
    tier.spill(vec![4.5f32; 64]);
    assert_eq!(tier.disk_write_errors(), 1, "the failed write must be counted");
    assert_eq!(tmp_leftovers(&dir), Vec::<String>::new(), "no .tmp may survive a failure");
    let got = tier.reload(64).expect("the in-memory copy stays authoritative");
    assert!(got.iter().all(|&v| v == 4.5));
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn failed_plan_persist_leaves_no_tmp() {
    // The same hygiene for the plan directory: plant a directory at the
    // plan's final file name; persist_dir must fail *and* clean its tmp.
    use tensorarena::planner::serialize::{self, plan_file_name};
    use tensorarena::planner::{PlanCache, PlanRequest};
    let dir = scratch_dir("plan-no-tmp");
    let recs = UsageRecords::from_graph(&models::blazeface());
    let cache = PlanCache::new();
    cache.get_or_plan(&recs, &PlanRequest::new()).unwrap();
    let name = plan_file_name(serialize::records_fingerprint(&recs), &PlanRequest::new());
    std::fs::create_dir(dir.join(&name)).unwrap();
    assert!(cache.persist_dir(&dir).is_err(), "rename onto a directory must fail");
    assert_eq!(tmp_leftovers(&dir), Vec::<String>::new(), "no .tmp may survive a failure");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Build a blazeface server over `service` with the given policy knobs.
fn spawn_blaze(
    service: &Arc<PlanService>,
    mem_budget: Option<usize>,
    spill: SpillPolicy,
) -> ModelServer {
    let service = Arc::clone(service);
    let req = service.request();
    ModelServer::spawn(
        move || {
            let g = models::blazeface();
            Box::new(
                ExecutorEngine::for_request(&g, service, &req, 7)
                    .expect("engine")
                    .with_max_batch(4),
            )
        },
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            mem_budget,
            spill,
            ..BatchPolicy::default()
        },
    )
    .expect("spawn")
}

#[test]
fn spill_policy_turns_a_refusal_into_a_bit_identical_serve() {
    // The PR's acceptance scenario. A budget that fits batch 1 but not
    // batch 3: under the default refuse policy the 3-sample burst gets the
    // typed refusal; under `--spill-policy spill` (same service, same
    // budget, tier attached) it is admitted, served, and every output is
    // bit-identical to an unbudgeted reference server — while the pool's
    // eviction/reload counters prove the arena actually cycled through
    // the compressed tier.
    let g = models::blazeface();
    let in_elems = g.tensor(g.inputs[0]).num_elements();
    let recs = UsageRecords::from_graph(&g);
    let mut rng = SplitMix64::new(42);
    let mut single = vec![0f32; in_elems];
    rng.fill_f32(&mut single, 1.0);
    let mut burst = vec![0f32; in_elems * 3];
    rng.fill_f32(&mut burst, 1.0);

    // Reference: unbudgeted, tierless.
    let ref_service = PlanService::shared();
    let reference = spawn_blaze(&ref_service, None, SpillPolicy::Refuse);
    let ref_single = reference.submit(single.clone()).recv().unwrap().unwrap();
    let ref_burst = reference.submit(burst.clone()).recv().unwrap().unwrap();
    reference.shutdown();

    // The budgeted service, spill tier attached with an aggressive (zero)
    // watermark: every idle release compresses into the tier.
    let service = PlanService::shared();
    let tier = Arc::new(SpillTier::new());
    service.pool().configure_spill(Arc::clone(&tier), 0);
    let budget = service.plan(&recs, &service.request()).unwrap().total * 3 / 2;

    // Refuse policy: the burst is the typed refusal — the tier's presence
    // alone must not widen admission.
    let refuse = spawn_blaze(&service, Some(budget), SpillPolicy::Refuse);
    assert_eq!(refuse.submit(single.clone()).recv().unwrap().unwrap(), ref_single);
    match refuse.submit(burst.clone()).recv().unwrap() {
        Err(ServeError::BudgetExceeded { batch: 3, .. }) => {}
        other => panic!("expected the typed refusal, got {other:?}"),
    }
    refuse.shutdown();

    // Spill policy: the same burst is admitted and bit-identical, and the
    // batch churn (1 → 3 → 1) cycles arena buffers through the tier.
    let spill = spawn_blaze(&service, Some(budget), SpillPolicy::Spill);
    assert_eq!(spill.submit(single.clone()).recv().unwrap().unwrap(), ref_single);
    assert_eq!(
        spill.submit(burst.clone()).recv().unwrap().unwrap(),
        ref_burst,
        "a spill-admitted burst must serve bit-identically"
    );
    assert_eq!(spill.submit(single.clone()).recv().unwrap().unwrap(), ref_single);
    let snap = spill.metrics().snapshot();
    assert!(snap.spill_admissions >= 1, "the over-budget serve must be counted: {snap:?}");
    assert_eq!(snap.rejected, 0, "nothing may be refused under the elastic bound");
    spill.shutdown();
    let stats = tier.stats();
    assert!(stats.evictions >= 2, "batch churn must evict idle buffers: {stats:?}");
    assert!(stats.reloads >= 1, "re-acquiring an evicted class must reload: {stats:?}");
    assert!(stats.bytes_after <= stats.bytes_before, "the codec never inflates: {stats:?}");
    // And the serving stats surface the same counters.
    let svc_stats = service.stats();
    assert_eq!(svc_stats.spill_evictions, stats.evictions);
    assert_eq!(svc_stats.spill_reloads, stats.reloads);
}

#[test]
#[ignore = "tier-2: serves every zoo network under a starved budget with the spill policy; run with --ignored"]
fn spill_soak_zoo_bit_identical_under_starved_budget() {
    // The tier-2 soak: for every zoo model, a budget *below* the batch-1
    // f32 admission floor — the refuse policy would serve nothing at all —
    // must still serve everything under `--spill-policy spill`, with
    // outputs bit-identical to an unbudgeted reference and the eviction
    // counter proving tier traffic.
    for name in models::ZOO {
        let g = models::by_name(name).unwrap();
        let in_elems = g.tensor(g.inputs[0]).num_elements();
        let recs = UsageRecords::from_graph(&g);
        let mut rng = SplitMix64::new(31);
        let mut single = vec![0f32; in_elems];
        rng.fill_f32(&mut single, 1.0);
        let mut burst = vec![0f32; in_elems * 2];
        rng.fill_f32(&mut burst, 1.0);

        let ref_service = PlanService::shared();
        let reference = {
            let service = Arc::clone(&ref_service);
            let req = service.request();
            let model = name.to_string();
            ModelServer::spawn(
                move || {
                    let g = models::by_name(&model).unwrap();
                    Box::new(
                        ExecutorEngine::for_request(&g, service, &req, 7)
                            .expect("engine")
                            .with_max_batch(2),
                    )
                },
                BatchPolicy {
                    max_batch: 2,
                    max_wait: Duration::from_millis(1),
                    ..BatchPolicy::default()
                },
            )
            .expect("spawn")
        };
        let ref_single = reference.submit(single.clone()).recv().unwrap().unwrap();
        let ref_burst = reference.submit(burst.clone()).recv().unwrap().unwrap();
        reference.shutdown();

        let service = PlanService::shared();
        let tier = Arc::new(SpillTier::new());
        service.pool().configure_spill(Arc::clone(&tier), 0);
        let floor = service.plan(&recs, &service.request()).unwrap().total;
        let budget = floor.saturating_sub(1);
        let server = {
            let service = Arc::clone(&service);
            let req = service.request();
            let model = name.to_string();
            ModelServer::spawn(
                move || {
                    let g = models::by_name(&model).unwrap();
                    Box::new(
                        ExecutorEngine::for_request(&g, service, &req, 7)
                            .expect("engine")
                            .with_max_batch(2),
                    )
                },
                BatchPolicy {
                    max_batch: 2,
                    max_wait: Duration::from_millis(1),
                    mem_budget: Some(budget),
                    spill: SpillPolicy::Spill,
                    ..BatchPolicy::default()
                },
            )
            .expect("spawn")
        };
        assert_eq!(
            server.submit(single.clone()).recv().unwrap().unwrap(),
            ref_single,
            "{name}: starved-budget single diverged"
        );
        assert_eq!(
            server.submit(burst.clone()).recv().unwrap().unwrap(),
            ref_burst,
            "{name}: starved-budget burst diverged"
        );
        assert_eq!(
            server.submit(single.clone()).recv().unwrap().unwrap(),
            ref_single,
            "{name}: post-churn single diverged"
        );
        let snap = server.metrics().snapshot();
        assert!(snap.spill_admissions >= 3, "{name}: every serve is over-budget: {snap:?}");
        assert_eq!(snap.rejected, 0, "{name}: nothing may be refused: {snap:?}");
        server.shutdown();
        assert!(tier.evictions() >= 1, "{name}: batch churn must reach the tier");
    }
}
