//! Property tests for execution-order strategies — the ordering test tier.
//!
//! On randomized branchy DAGs (and the real zoo), every order the
//! schedulers emit must be a valid topological order that preserves the op
//! set exactly, and [`anneal_order`] must never report a higher max
//! operator breadth than the natural order (it is seeded from the natural
//! order and only accepts improvements). Determinism is load-bearing too:
//! order-keyed plan-cache persistence is only sound if the same
//! `(graph, seed, budget)` always reproduces byte-identical orders — and
//! therefore stable record fingerprints — across runs.
//!
//! Same conventions as `planner_properties.rs`: hand-rolled SplitMix64
//! generators (no proptest in the offline registry), every failure prints
//! its seed, and the `#[ignore]`d sweep runs in CI tier-2 via
//! `cargo test --release -- --include-ignored`.

use std::sync::Arc;
use tensorarena::coordinator::engine::ExecutorEngine;
use tensorarena::coordinator::Engine;
use tensorarena::graph::{Activation, DType, Graph, GraphBuilder, Padding};
use tensorarena::models;
use tensorarena::planner::order::{
    anneal_order, apply_order, is_valid_order, memory_aware_order, natural_order,
    order_max_breadth, reorder_graph,
};
use tensorarena::planner::serialize::records_fingerprint;
use tensorarena::planner::{registry, OrderStrategy, PlanRequest, PlanService};
use tensorarena::records::UsageRecords;
use tensorarena::rng::SplitMix64;

/// Random branchy DAG: a pool of same-shape `[1, 8, 8, 4]` tensors grown
/// by random conv / dwconv / residual-add / concat+project ops. Keeping
/// every pool tensor channel-compatible means any two ends can merge, so
/// the generator reaches diamond, fan-out, and skip-connection shapes —
/// the graphs where order choice actually moves the footprint.
fn random_dag(seed: u64) -> Graph {
    let mut rng = SplitMix64::new(seed);
    let mut b = GraphBuilder::new(format!("rand{seed}"), DType::F32);
    let x = b.input("x", vec![1, 8, 8, 4]);
    let stem = b.conv2d("stem", x, 4, (1, 1), (1, 1), Padding::Same, Activation::Relu);
    let mut pool = vec![stem];
    let n_ops = rng.next_range(4, 24);
    for i in 0..n_ops {
        let pick = pool[rng.next_below(pool.len())];
        let t = match rng.next_below(4) {
            0 => b.conv2d(
                format!("c{i}"),
                pick,
                4,
                (3, 3),
                (1, 1),
                Padding::Same,
                Activation::Relu,
            ),
            1 => b.dwconv2d(
                format!("d{i}"),
                pick,
                (3, 3),
                (1, 1),
                Padding::Same,
                Activation::Relu,
            ),
            2 => {
                let other = pool[rng.next_below(pool.len())];
                b.add(format!("a{i}"), pick, other, Activation::None)
            }
            _ => {
                let other = pool[rng.next_below(pool.len())];
                let cat = b.concat(format!("k{i}"), &[pick, other]);
                b.conv2d(
                    format!("kp{i}"),
                    cat,
                    4,
                    (1, 1),
                    (1, 1),
                    Padding::Same,
                    Activation::None,
                )
            }
        };
        pool.push(t);
        // Occasionally retire an end so branches terminate instead of
        // fanning out forever.
        if pool.len() > 3 && rng.next_below(3) == 0 {
            pool.remove(rng.next_below(pool.len()));
        }
    }
    // Merge every live end into a single output.
    let mut acc = pool[0];
    for (j, &t) in pool.iter().enumerate().skip(1) {
        acc = b.add(format!("m{j}"), acc, t, Activation::None);
    }
    b.mark_output(acc);
    b.finish()
}

/// Sorted op indices of an order — for the op-set-preservation check.
fn op_multiset(order: &tensorarena::planner::order::ExecutionOrder) -> Vec<usize> {
    let mut ops: Vec<usize> = order.0.iter().map(|o| o.0).collect();
    ops.sort_unstable();
    ops
}

/// The ordering properties for one graph: validity, exact op-set
/// preservation, and the annealing never-regress-natural invariant.
fn check_order_properties(seed: u64, g: &Graph) {
    let identity: Vec<usize> = (0..g.num_ops()).collect();
    let nat_breadth = order_max_breadth(g, &natural_order(g));

    let greedy = memory_aware_order(g);
    assert!(is_valid_order(g, &greedy), "seed {seed}: memory-aware order invalid");
    assert_eq!(
        op_multiset(&greedy),
        identity,
        "seed {seed}: memory-aware order dropped or duplicated ops"
    );

    let ann = anneal_order(g, seed, 30);
    assert!(is_valid_order(g, &ann), "seed {seed}: annealed order invalid");
    assert_eq!(
        op_multiset(&ann),
        identity,
        "seed {seed}: annealed order dropped or duplicated ops"
    );
    let ann_breadth = order_max_breadth(g, &ann);
    assert!(
        ann_breadth <= nat_breadth,
        "seed {seed}: annealed breadth {ann_breadth} regressed natural {nat_breadth}"
    );

    // Reordering round-trips: the rebuilt graph validates, and the usage
    // records keep the same size multiset (only lifetimes move).
    let re = reorder_graph(g, &ann);
    re.validate().unwrap_or_else(|e| panic!("seed {seed}: reordered graph invalid: {e}"));
    let a = UsageRecords::from_graph(g);
    let b = UsageRecords::from_graph(&re);
    assert_eq!(a.len(), b.len(), "seed {seed}: record count changed");
    assert_eq!(a.naive_total(), b.naive_total(), "seed {seed}: sizes changed");
    let mut sa: Vec<usize> = a.records.iter().map(|r| r.size).collect();
    let mut sb: Vec<usize> = b.records.iter().map(|r| r.size).collect();
    sa.sort_unstable();
    sb.sort_unstable();
    assert_eq!(sa, sb, "seed {seed}: size multiset changed");
}

#[test]
fn order_properties_hold_on_random_dags() {
    for seed in 0..12 {
        check_order_properties(seed, &random_dag(seed));
    }
}

#[test]
fn order_properties_hold_on_the_zoo() {
    for g in models::all_zoo() {
        check_order_properties(999, &g);
    }
}

#[test]
#[ignore = "slow annealing sweep; run in CI tier-2 via --include-ignored"]
fn order_properties_hold_across_many_seeds() {
    for seed in 12..120 {
        check_order_properties(seed, &random_dag(seed));
    }
}

#[test]
fn annealing_is_deterministic_and_fingerprints_are_stable() {
    // Byte-identical orders for equal (graph, seed, budget) — the
    // prerequisite for order-keyed plan-cache persistence: a restarted
    // server must re-derive the exact records (and fingerprint) its plan
    // directory was written under.
    for g in [models::blazeface(), random_dag(77)] {
        let a = anneal_order(&g, 7, 40);
        let b = anneal_order(&g, 7, 40);
        assert_eq!(a, b, "{}: same seed/budget diverged", g.name);
        let fa = records_fingerprint(&UsageRecords::from_graph(&reorder_graph(&g, &a)));
        let fb = records_fingerprint(&UsageRecords::from_graph(&reorder_graph(&g, &b)));
        assert_eq!(fa, fb, "{}: fingerprints diverged", g.name);

        // The same holds through the registry strategy / apply_order path
        // the serving stack uses.
        let order = OrderStrategy::Annealed { seed: 7, budget: 40 };
        let (ga, ia) = apply_order(&g, order);
        let (gb, ib) = apply_order(&g, order);
        assert_eq!(ia, ib, "{}: applied-order receipts diverged", g.name);
        assert_eq!(
            records_fingerprint(&UsageRecords::from_graph(&ga)),
            records_fingerprint(&UsageRecords::from_graph(&gb)),
            "{}: apply_order fingerprints diverged",
            g.name
        );
        assert_eq!(records_fingerprint(&UsageRecords::from_graph(&ga)), fa, "{}", g.name);
    }
    // Different parameterizations stay keyed apart even if their orders
    // happened to coincide.
    assert_ne!(
        OrderStrategy::Annealed { seed: 7, budget: 40 }.key(),
        OrderStrategy::Annealed { seed: 8, budget: 40 }.key()
    );
}

#[test]
fn stable_fingerprints_give_order_keyed_cache_hits() {
    // Two engines for the same (model, strategy, order) must share one
    // order-keyed plan: the second construction is a pure cache hit.
    let g = models::blazeface();
    let svc = PlanService::shared();
    let order = OrderStrategy::Annealed { seed: 3, budget: 20 };
    let req = PlanRequest::new().with_order(order);
    let _a = ExecutorEngine::for_request(&g, Arc::clone(&svc), &req, 1).unwrap();
    let _b = ExecutorEngine::for_request(&g, Arc::clone(&svc), &req, 2).unwrap();
    let st = svc.stats();
    assert_eq!(st.cache_misses, 1, "second ordered engine re-ran the planner");
    assert_eq!(st.cache_hits, 1);
}

#[test]
fn registry_order_keys_reach_every_scheduler() {
    // Each registry key resolves to an order that satisfies the validity
    // property on a random DAG, and keys round-trip through parsing.
    let g = random_dag(5);
    for key in ["natural", "memory-aware", "annealed", "annealed-s9-t15"] {
        let order = registry::order_strategy(key).unwrap_or_else(|| panic!("key {key}"));
        let (re, applied) = apply_order(&g, order);
        assert!(re.validate().is_ok(), "{key}");
        assert_eq!(re.num_ops(), g.num_ops(), "{key}");
        assert_eq!(registry::order_strategy(&applied.key()), Some(order), "{key}");
    }
    assert!(registry::order_strategy("annealed-s9").is_none());
}

#[test]
fn ordered_execution_is_numerically_identical() {
    // Reordering changes when ops run, never what they compute: the same
    // random DAG under natural and annealed engines must produce
    // bit-identical outputs (same synthesized weights, same DAG).
    let g = random_dag(21);
    let order = OrderStrategy::Annealed { seed: 13, budget: 25 };
    let mut nat = ExecutorEngine::new(&g, PlanService::shared(), "greedy-size", 5).unwrap();
    let mut ann = ExecutorEngine::for_request(
        &g,
        PlanService::shared(),
        &PlanRequest::new().with_order(order),
        5,
    )
    .unwrap();
    let mut rng = SplitMix64::new(1);
    let mut x = vec![0f32; 2 * nat.in_elems()];
    rng.fill_f32(&mut x, 1.0);
    let a = nat.run_batch(&x, 2).unwrap();
    let b = ann.run_batch(&x, 2).unwrap();
    assert_eq!(a, b, "reordered execution changed the numbers");
    assert!(a.iter().all(|v| v.is_finite()));
}
