//! Adversarial tests for the plan-directory format: a serving fleet must
//! warm-start from whatever it finds on disk — truncated files, flipped
//! fingerprint bytes, strategies that no longer exist, plans written under
//! a different execution order, pre-bump v1 files — by *skipping* the
//! damage (counted, warned) and never by crashing or serving a corrupt
//! plan. Plus the restart acceptance tests: a second cold start against
//! the same plan dir — natural or order-keyed — performs zero planner
//! invocations.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;
use tensorarena::coordinator::engine::ExecutorEngine;
use tensorarena::coordinator::{BatchPolicy, ModelServer};
use tensorarena::models;
use tensorarena::planner::serialize::{self, plan_file_name, LoadError};
use tensorarena::planner::{
    apply_order, DynamicMode, DynamicRecords, OrderStrategy, PlanCache, PlanRequest, PlanService,
    WarmStartReport,
};
use tensorarena::records::UsageRecords;

/// Fresh scratch directory under the system temp dir (no tempfile crate in
/// the offline registry); each test uses its own tag.
fn scratch_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "tensorarena-persist-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn example() -> UsageRecords {
    UsageRecords::from_graph(&models::blazeface())
}

/// Batch-1 greedy-size @ natural — the test workhorse.
fn req() -> PlanRequest {
    PlanRequest::new()
}

/// The request a `(batch, strategy)` pair names under the natural order —
/// what the golden file names are built from.
fn named(batch: usize, strategy: &str) -> PlanRequest {
    PlanRequest::new().with_strategy(strategy).unwrap().with_batch(batch)
}

/// Populate a directory with genuine plans for `recs`.
fn populate(recs: &UsageRecords, dir: &std::path::Path, batches: &[usize]) -> usize {
    let cache = PlanCache::new();
    for &b in batches {
        cache.get_or_plan(recs, &req().with_batch(b)).unwrap();
    }
    cache.persist_dir(dir).unwrap().written
}

#[test]
fn directory_roundtrip_golden() {
    // Golden-path roundtrip: persist N plans, warm-start a fresh cache,
    // re-request every key — zero planner invocations, byte-identical
    // plans, and the directory contains exactly the expected file names.
    let dir = scratch_dir("golden");
    let recs = example();
    let warm = PlanCache::new();
    for b in [1usize, 2, 8] {
        warm.get_or_plan(&recs, &req().with_batch(b)).unwrap();
    }
    warm.get_or_plan(&recs, &named(1, "greedy-breadth")).unwrap();
    let report = warm.persist_dir(&dir).unwrap();
    assert_eq!(report.written, 4);

    let fp = serialize::records_fingerprint(&recs);
    let mut names: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    names.sort();
    let mut expected = vec![
        plan_file_name(fp, &named(1, "greedy-size")),
        plan_file_name(fp, &named(2, "greedy-size")),
        plan_file_name(fp, &named(8, "greedy-size")),
        plan_file_name(fp, &named(1, "greedy-breadth")),
    ];
    expected.sort();
    assert_eq!(names, expected, "directory layout is the golden format");

    let cold = PlanCache::new();
    let report = cold.warm_start(&dir, &recs, &req()).unwrap();
    assert_eq!(
        report,
        WarmStartReport { loaded: 4, ..WarmStartReport::default() }
    );
    let keys = [(1, "greedy-size"), (2, "greedy-size"), (8, "greedy-size"), (1, "greedy-breadth")];
    for (b, s) in keys {
        assert_eq!(
            *cold.get_or_plan(&recs, &named(b, s)).unwrap(),
            *warm.get_or_plan(&recs, &named(b, s)).unwrap(),
            "plan ({b}, {s}) diverged across the restart"
        );
    }
    assert_eq!(cold.misses(), 0, "roundtrip must avoid every planner invocation");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn truncated_file_is_skipped_not_served() {
    let dir = scratch_dir("truncated");
    let recs = example();
    assert_eq!(populate(&recs, &dir, &[1, 2]), 2);
    // Truncate the batch-2 file mid-body.
    let victim = dir.join(plan_file_name(
        serialize::records_fingerprint(&recs),
        &named(2, "greedy-size"),
    ));
    let text = std::fs::read_to_string(&victim).unwrap();
    std::fs::write(&victim, &text[..text.len() / 2]).unwrap();

    let cache = PlanCache::new();
    let report = cache.warm_start(&dir, &recs, &req()).unwrap();
    assert_eq!(report.loaded, 1, "{report:?}");
    assert_eq!(report.skipped_corrupt, 1, "{report:?}");
    assert_eq!(cache.warm_skipped(), 1, "skip must surface in the counters");
    // The undamaged plan serves from cache; the damaged one re-plans.
    cache.get_or_plan(&recs, &req()).unwrap();
    assert_eq!(cache.misses(), 0);
    let replanned = cache.get_or_plan(&recs, &req().with_batch(2)).unwrap();
    assert_eq!(cache.misses(), 1, "corrupt file must cost a re-plan, not a crash");
    replanned.validate(&recs.scaled(2)).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn flipped_fingerprint_byte_is_skipped_as_foreign() {
    let dir = scratch_dir("flipped-fp");
    let recs = example();
    assert_eq!(populate(&recs, &dir, &[1]), 1);
    let fp = serialize::records_fingerprint(&recs);
    let original = dir.join(plan_file_name(fp, &req()));
    // Flip one hex digit of the file-name fingerprint (keep it well-formed):
    // the file now claims to belong to some other model.
    let flipped = dir.join(plan_file_name(fp ^ 0xf, &req()));
    std::fs::rename(&original, &flipped).unwrap();

    let cache = PlanCache::new();
    let report = cache.warm_start(&dir, &recs, &req()).unwrap();
    assert_eq!(report.loaded, 0, "{report:?}");
    assert_eq!(report.skipped_foreign, 1, "{report:?}");
    assert!(cache.is_empty(), "a mis-fingerprinted plan must never be served");

    // And the file's *content* cannot be smuggled in under the wrong key
    // either: loading it against different records is rejected.
    let text = std::fs::read_to_string(&flipped).unwrap();
    let mut other = recs.clone();
    other.records[0].size += 64;
    assert!(
        cache.load(&text, &other, &req()).is_err(),
        "PlanCache::load must re-validate the records, not trust the caller's key"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn stale_strategy_file_is_skipped_with_counter() {
    let dir = scratch_dir("stale-strategy");
    let recs = example();
    assert_eq!(populate(&recs, &dir, &[1]), 1);
    let fp = serialize::records_fingerprint(&recs);
    // A plan persisted by a build whose strategy has since been removed
    // from the registry ("belady" does not exist).
    let genuine = dir.join(plan_file_name(fp, &req()));
    // The typed name builder cannot spell an unregistered strategy, which
    // is the point — the stale name is what an *older build* wrote.
    let stale = dir.join(format!("{fp:016x}-b1-belady@natural.plan"));
    std::fs::copy(&genuine, &stale).unwrap();

    let cache = PlanCache::new();
    let report = cache.warm_start(&dir, &recs, &req()).unwrap();
    assert_eq!(report.loaded, 1, "{report:?}");
    assert_eq!(report.skipped_stale_strategy, 1, "{report:?}");
    assert_eq!(report.skipped(), 1);
    assert_eq!(cache.warm_skipped(), 1);
    assert_eq!(cache.len(), 1, "only the registered strategy's plan is resident");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn checksum_corrupt_and_junk_files_are_skipped() {
    let dir = scratch_dir("corrupt-mixed");
    let recs = example();
    assert_eq!(populate(&recs, &dir, &[1, 4]), 2);
    let fp = serialize::records_fingerprint(&recs);
    // Corrupt the batch-4 file's body (checksum now mismatches).
    let victim = dir.join(plan_file_name(fp, &named(4, "greedy-size")));
    let mut text = std::fs::read_to_string(&victim).unwrap();
    text = text.replacen("offset", "OFFSET", 1);
    std::fs::write(&victim, text).unwrap();
    // Junk that merely *looks* like a plan file, plus ignorable noise.
    std::fs::write(dir.join("zz-not-a-key-b1-x@natural.plan"), "garbage").unwrap();
    std::fs::write(dir.join("README.txt"), "not a plan").unwrap();
    let torn = dir.join(format!(".{}.tmp", plan_file_name(fp, &named(9, "greedy-size"))));
    std::fs::write(torn, "torn").unwrap();

    let cache = PlanCache::new();
    let report = cache.warm_start(&dir, &recs, &req()).unwrap();
    assert_eq!(report.loaded, 1, "{report:?}");
    // Corrupt body + unparseable name; README/tmp are silently ignored.
    assert_eq!(report.skipped_corrupt, 2, "{report:?}");
    assert_eq!(cache.warm_loaded(), 1);
    assert_eq!(cache.warm_skipped(), 2);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn annealed_order_plan_is_skipped_when_warm_starting_natural() {
    // A plan directory written by an `annealed`-order server must never
    // seed a natural-order service: the file is skipped with the dedicated
    // stale-order counter (and left intact for the annealed server), while
    // a warm start under the matching order loads it with zero planner
    // invocations.
    let dir = scratch_dir("stale-order");
    let g = models::blazeface();
    let order = OrderStrategy::Annealed { seed: 7, budget: 25 };
    let (ordered, _) = apply_order(&g, order);
    let ordered_recs = UsageRecords::from_graph(&ordered);
    let warm = PlanCache::new();
    warm.get_or_plan(&ordered_recs, &req().with_order(order)).unwrap();
    assert_eq!(warm.persist_dir(&dir).unwrap().written, 1);
    let written: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    assert!(
        written.iter().all(|n| n.ends_with("@annealed-s7-t25.plan")),
        "order key must be in the file name: {written:?}"
    );

    // Natural warm start: skipped with the new counter, nothing served.
    // Like a foreign file, the skip is not *suspect* (it belongs to the
    // annealed configuration sharing the directory) — no warm_skipped.
    let natural_recs = UsageRecords::from_graph(&g);
    let cache = PlanCache::new();
    let report = cache.warm_start(&dir, &natural_recs, &req()).unwrap();
    assert_eq!(report.loaded, 0, "{report:?}");
    assert_eq!(report.skipped_stale_order, 1, "{report:?}");
    assert_eq!(report.skipped(), 0);
    assert_eq!(cache.warm_skipped(), 0);
    assert!(cache.is_empty(), "a stale-order plan must never be served");
    // The file is left intact for its own configuration.
    let cache = PlanCache::new();
    let report = cache.warm_start(&dir, &ordered_recs, &req().with_order(order)).unwrap();
    assert_eq!(report.loaded, 1, "{report:?}");
    cache.get_or_plan(&ordered_recs, &req().with_order(order)).unwrap();
    assert_eq!(cache.misses(), 0, "order-keyed warm start must avoid the planner");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn pre_bump_version_file_is_rejected_cleanly() {
    let dir = scratch_dir("pre-bump");
    let recs = example();
    assert_eq!(populate(&recs, &dir, &[1]), 1);
    let fp = serialize::records_fingerprint(&recs);
    let genuine = dir.join(plan_file_name(fp, &req()));
    let text = std::fs::read_to_string(&genuine).unwrap();

    // (a) A v1-era *file name* (no @<order> segment) does not parse:
    // skipped as corrupt, never loaded, never fatal.
    std::fs::write(dir.join(format!("{fp:016x}-b2-greedy-size.plan")), &text).unwrap();
    // (b) A v1 *header* under a well-formed v2 name: rejected by version
    // with a recomputed, self-consistent checksum — the structural check,
    // not the checksum, must catch it.
    let headerless = text
        .replacen("tensorarena-plan v2", "tensorarena-plan v1", 1)
        .replacen(" natural\n", "\n", 1);
    let body = &headerless[..headerless.rfind("checksum ").unwrap()];
    let sum = serialize::fnv1a(body.as_bytes());
    let v1_text = format!("{body}checksum {sum:016x}\n");
    assert_eq!(
        serialize::offset_plan_from_str(&v1_text, &recs, &req()),
        Err(LoadError::UnsupportedVersion("v1".into())),
        "the loader must name the version"
    );
    std::fs::write(dir.join(plan_file_name(fp, &named(4, "greedy-size"))), &v1_text).unwrap();

    let cache = PlanCache::new();
    let report = cache.warm_start(&dir, &recs, &req()).unwrap();
    assert_eq!(report.loaded, 1, "{report:?}");
    assert_eq!(report.skipped_corrupt, 2, "{report:?}");
    assert_eq!(cache.len(), 1, "only the genuine v2 plan is resident");
    // The pre-bump keys cost a re-plan, not a crash.
    cache.get_or_plan(&recs, &named(4, "greedy-size")).unwrap();
    assert_eq!(cache.misses(), 1);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn warm_start_isolates_models_sharing_one_directory() {
    // Two models persist into one fleet-wide directory; each warm start
    // loads only its own plans and reports the other's as foreign.
    let dir = scratch_dir("shared-dir");
    let blaze = UsageRecords::from_graph(&models::blazeface());
    let mobile = UsageRecords::from_graph(&models::mobilenet_v1());
    assert_eq!(populate(&blaze, &dir, &[1, 2]), 2);
    assert_eq!(populate(&mobile, &dir, &[1]), 1);

    let cache = PlanCache::new();
    let report = cache.warm_start(&dir, &blaze, &req()).unwrap();
    assert_eq!((report.loaded, report.skipped_foreign), (2, 1), "{report:?}");
    let cache = PlanCache::new();
    let report = cache.warm_start(&dir, &mobile, &req()).unwrap();
    assert_eq!((report.loaded, report.skipped_foreign), (1, 2), "{report:?}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn stale_resolved_prefix_is_a_miss_and_never_persists() {
    // Decode-step caching (§7) at the cache layer: a second pass over the
    // same resolved-size prefix performs zero planner invocations, while a
    // *stale* prefix — the same wave structure resolving a different size,
    // e.g. the next sequence's longer decode step — misses and re-plans
    // instead of serving the previous sequence's plan. Dynamic plans also
    // never leak into the on-disk plan directory (their resolved sizes are
    // transient): persist_dir writes only static plans.
    let dir = scratch_dir("stale-prefix");
    let recs = example();
    let cache = PlanCache::new();
    // Sequence A: tail sizes as extracted; sequence B: one decode step
    // resolved 64 bytes larger.
    let from_op = recs.num_ops / 2;
    let seq_a = DynamicRecords::decode_tail(&recs, from_op);
    let mut bigger = recs.clone();
    let grown_id = seq_a
        .records
        .iter()
        .find(|d| d.known_at > 0)
        .map(|d| d.record.id)
        .expect("decode tail has a dynamic record");
    bigger.records[grown_id].size += 64;
    let seq_b = DynamicRecords::decode_tail(&bigger, from_op);
    let boundary = seq_a.records[grown_id].known_at;

    // A full decode pass for sequence A, repeated: second pass plans
    // nothing.
    for step in 0..recs.num_ops {
        cache
            .get_or_plan_dynamic(&seq_a, &req().with_dynamic(DynamicMode::Resolved(step)))
            .unwrap();
    }
    let after_first = cache.dynamic_misses();
    for step in 0..recs.num_ops {
        cache
            .get_or_plan_dynamic(&seq_a, &req().with_dynamic(DynamicMode::Resolved(step)))
            .unwrap();
    }
    assert_eq!(
        cache.dynamic_misses(),
        after_first,
        "unchanged resolved prefix must be pure cache hits"
    );
    // Sequence B at the boundary where its resolved size differs: a miss.
    cache
        .get_or_plan_dynamic(&seq_b, &req().with_dynamic(DynamicMode::Resolved(boundary)))
        .unwrap();
    assert_eq!(
        cache.dynamic_misses(),
        after_first + 1,
        "a stale resolved prefix must re-plan, never reuse the old sizes"
    );
    // Dynamic plans stay in memory: nothing to persist, nothing on disk.
    let report = cache.persist_dir(&dir).unwrap();
    assert_eq!(report.written, 0, "dynamic plans must not reach the plan directory");
    assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 0);
    // Static plans still persist alongside untouched.
    cache.get_or_plan(&recs, &req()).unwrap();
    assert_eq!(cache.persist_dir(&dir).unwrap().written, 1);
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------------
// Restart acceptance: zero planner invocations on the second start.
// ---------------------------------------------------------------------------

/// One serving "process lifetime": spawn a budget-capped server for
/// `order` against `dir`, run a burst, persist the cache back, and return
/// the number of planner invocations the run needed.
fn serve_once(dir: &std::path::Path, burst: usize, order: OrderStrategy) -> u64 {
    let g = models::blazeface();
    let in_elems = g.tensor(g.inputs[0]).num_elements();
    // The served records are the order-applied ones — the same ones the
    // engine derives — so warm start, budget, and persistence agree.
    let (ordered, _) = apply_order(&g, order);
    let recs = UsageRecords::from_graph(&ordered);
    let service = PlanService::shared();
    let sreq = service.request().with_order(order);
    service.warm_start(dir, &recs, &sreq).unwrap();
    let budget = 3 * service.plan(&recs, &sreq).unwrap().total;
    let server = {
        let service = Arc::clone(&service);
        ModelServer::spawn(
            move || {
                let g = models::blazeface();
                Box::new(
                    ExecutorEngine::for_request(&g, service, &sreq, 7)
                        .expect("engine")
                        .with_max_batch(8),
                )
            },
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                mem_budget: Some(budget),
                ..BatchPolicy::default()
            },
        )
        .expect("spawn")
    };
    let pending: Vec<_> = (0..burst)
        .map(|i| server.submit(vec![(i % 7) as f32 * 0.1; in_elems]))
        .collect();
    for rx in pending {
        rx.recv().unwrap().unwrap();
    }
    server.shutdown();
    service.persist_dir(dir).unwrap();
    service.stats().cache_misses
}

#[test]
fn second_cold_start_against_plan_dir_plans_nothing() {
    let dir = scratch_dir("restart");
    // First lifetime: plans everything it needs (batch-1 at engine build,
    // the budget binary-search probes, every batch the burst formed).
    let cold_misses = serve_once(&dir, 64, OrderStrategy::Natural);
    assert!(cold_misses >= 1, "first start must actually plan");
    // Second lifetime, fresh PlanService, same directory: every plan —
    // including the max_servable_batch probes — is warm-started, so the
    // planner-invocation counter stays at zero.
    let warm_misses = serve_once(&dir, 64, OrderStrategy::Natural);
    assert_eq!(
        warm_misses, 0,
        "a restarted server must re-plan nothing for previously-seen shapes"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn second_cold_start_under_annealed_order_plans_nothing() {
    // The ISSUE's acceptance scenario: `serve --order annealed` with a plan
    // dir. The annealed order is re-derived deterministically on restart,
    // so the order-keyed files warm-start the cache and the second
    // lifetime performs zero planner invocations.
    let dir = scratch_dir("restart-ordered");
    let order = OrderStrategy::Annealed { seed: 42, budget: 40 };
    let cold_misses = serve_once(&dir, 48, order);
    assert!(cold_misses >= 1, "first start must actually plan");
    let warm_misses = serve_once(&dir, 48, order);
    assert_eq!(
        warm_misses, 0,
        "a restarted annealed-order server must re-plan nothing"
    );
    // And the directory cannot leak into a natural-order restart.
    let natural_misses = serve_once(&dir, 48, OrderStrategy::Natural);
    assert!(
        natural_misses >= 1,
        "a natural-order server must not consume annealed-order plans"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}
