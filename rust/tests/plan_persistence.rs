//! Adversarial tests for the plan-directory format: a serving fleet must
//! warm-start from whatever it finds on disk — truncated files, flipped
//! fingerprint bytes, strategies that no longer exist — by *skipping* the
//! damage (counted, warned) and never by crashing or serving a corrupt
//! plan. Plus the restart acceptance test: a second cold start against the
//! same plan dir performs zero planner invocations.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;
use tensorarena::coordinator::engine::ExecutorEngine;
use tensorarena::coordinator::{BatchPolicy, ModelServer};
use tensorarena::models;
use tensorarena::planner::serialize::{self, plan_file_name};
use tensorarena::planner::{PlanCache, PlanService, WarmStartReport};
use tensorarena::records::UsageRecords;

/// Fresh scratch directory under the system temp dir (no tempfile crate in
/// the offline registry); each test uses its own tag.
fn scratch_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "tensorarena-persist-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn example() -> UsageRecords {
    UsageRecords::from_graph(&models::blazeface())
}

/// Populate a directory with genuine plans for `recs`.
fn populate(recs: &UsageRecords, dir: &std::path::Path, batches: &[usize]) -> usize {
    let cache = PlanCache::new();
    for &b in batches {
        cache.get_or_plan(recs, b, "greedy-size").unwrap();
    }
    cache.persist_dir(dir).unwrap().written
}

#[test]
fn directory_roundtrip_golden() {
    // Golden-path roundtrip: persist N plans, warm-start a fresh cache,
    // re-request every key — zero planner invocations, byte-identical
    // plans, and the directory contains exactly the expected file names.
    let dir = scratch_dir("golden");
    let recs = example();
    let warm = PlanCache::new();
    for b in [1usize, 2, 8] {
        warm.get_or_plan(&recs, b, "greedy-size").unwrap();
    }
    warm.get_or_plan(&recs, 1, "greedy-breadth").unwrap();
    let report = warm.persist_dir(&dir).unwrap();
    assert_eq!(report.written, 4);

    let fp = serialize::records_fingerprint(&recs);
    let mut names: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    names.sort();
    let mut expected = vec![
        plan_file_name(fp, 1, "greedy-size"),
        plan_file_name(fp, 2, "greedy-size"),
        plan_file_name(fp, 8, "greedy-size"),
        plan_file_name(fp, 1, "greedy-breadth"),
    ];
    expected.sort();
    assert_eq!(names, expected, "directory layout is the golden format");

    let cold = PlanCache::new();
    let report = cold.warm_start(&dir, &recs).unwrap();
    assert_eq!(
        report,
        WarmStartReport { loaded: 4, ..WarmStartReport::default() }
    );
    let keys = [(1, "greedy-size"), (2, "greedy-size"), (8, "greedy-size"), (1, "greedy-breadth")];
    for (b, s) in keys {
        assert_eq!(
            *cold.get_or_plan(&recs, b, s).unwrap(),
            *warm.get_or_plan(&recs, b, s).unwrap(),
            "plan ({b}, {s}) diverged across the restart"
        );
    }
    assert_eq!(cold.misses(), 0, "roundtrip must avoid every planner invocation");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn truncated_file_is_skipped_not_served() {
    let dir = scratch_dir("truncated");
    let recs = example();
    assert_eq!(populate(&recs, &dir, &[1, 2]), 2);
    // Truncate the batch-2 file mid-body.
    let victim = dir.join(plan_file_name(
        serialize::records_fingerprint(&recs),
        2,
        "greedy-size",
    ));
    let text = std::fs::read_to_string(&victim).unwrap();
    std::fs::write(&victim, &text[..text.len() / 2]).unwrap();

    let cache = PlanCache::new();
    let report = cache.warm_start(&dir, &recs).unwrap();
    assert_eq!(report.loaded, 1, "{report:?}");
    assert_eq!(report.skipped_corrupt, 1, "{report:?}");
    assert_eq!(cache.warm_skipped(), 1, "skip must surface in the counters");
    // The undamaged plan serves from cache; the damaged one re-plans.
    cache.get_or_plan(&recs, 1, "greedy-size").unwrap();
    assert_eq!(cache.misses(), 0);
    let replanned = cache.get_or_plan(&recs, 2, "greedy-size").unwrap();
    assert_eq!(cache.misses(), 1, "corrupt file must cost a re-plan, not a crash");
    replanned.validate(&recs.scaled(2)).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn flipped_fingerprint_byte_is_skipped_as_foreign() {
    let dir = scratch_dir("flipped-fp");
    let recs = example();
    assert_eq!(populate(&recs, &dir, &[1]), 1);
    let fp = serialize::records_fingerprint(&recs);
    let original = dir.join(plan_file_name(fp, 1, "greedy-size"));
    // Flip one hex digit of the file-name fingerprint (keep it well-formed):
    // the file now claims to belong to some other model.
    let flipped = dir.join(plan_file_name(fp ^ 0xf, 1, "greedy-size"));
    std::fs::rename(&original, &flipped).unwrap();

    let cache = PlanCache::new();
    let report = cache.warm_start(&dir, &recs).unwrap();
    assert_eq!(report.loaded, 0, "{report:?}");
    assert_eq!(report.skipped_foreign, 1, "{report:?}");
    assert!(cache.is_empty(), "a mis-fingerprinted plan must never be served");

    // And the file's *content* cannot be smuggled in under the wrong key
    // either: loading it against different records is rejected.
    let text = std::fs::read_to_string(&flipped).unwrap();
    let mut other = recs.clone();
    other.records[0].size += 64;
    assert!(
        cache.load(&text, &other, 1, "greedy-size").is_err(),
        "PlanCache::load must re-validate the records, not trust the caller's key"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn stale_strategy_file_is_skipped_with_counter() {
    let dir = scratch_dir("stale-strategy");
    let recs = example();
    assert_eq!(populate(&recs, &dir, &[1]), 1);
    let fp = serialize::records_fingerprint(&recs);
    // A plan persisted by a build whose strategy has since been removed
    // from the registry ("annealed" does not exist).
    let genuine = dir.join(plan_file_name(fp, 1, "greedy-size"));
    let stale = dir.join(plan_file_name(fp, 1, "annealed"));
    std::fs::copy(&genuine, &stale).unwrap();

    let cache = PlanCache::new();
    let report = cache.warm_start(&dir, &recs).unwrap();
    assert_eq!(report.loaded, 1, "{report:?}");
    assert_eq!(report.skipped_stale_strategy, 1, "{report:?}");
    assert_eq!(report.skipped(), 1);
    assert_eq!(cache.warm_skipped(), 1);
    assert_eq!(cache.len(), 1, "only the registered strategy's plan is resident");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn checksum_corrupt_and_junk_files_are_skipped() {
    let dir = scratch_dir("corrupt-mixed");
    let recs = example();
    assert_eq!(populate(&recs, &dir, &[1, 4]), 2);
    let fp = serialize::records_fingerprint(&recs);
    // Corrupt the batch-4 file's body (checksum now mismatches).
    let victim = dir.join(plan_file_name(fp, 4, "greedy-size"));
    let mut text = std::fs::read_to_string(&victim).unwrap();
    text = text.replacen("offset", "OFFSET", 1);
    std::fs::write(&victim, text).unwrap();
    // Junk that merely *looks* like a plan file, plus ignorable noise.
    std::fs::write(dir.join("zz-not-a-key-b1-x.plan"), "garbage").unwrap();
    std::fs::write(dir.join("README.txt"), "not a plan").unwrap();
    let torn = dir.join(format!(".{}.tmp", plan_file_name(fp, 9, "greedy-size")));
    std::fs::write(torn, "torn").unwrap();

    let cache = PlanCache::new();
    let report = cache.warm_start(&dir, &recs).unwrap();
    assert_eq!(report.loaded, 1, "{report:?}");
    // Corrupt body + unparseable name; README/tmp are silently ignored.
    assert_eq!(report.skipped_corrupt, 2, "{report:?}");
    assert_eq!(cache.warm_loaded(), 1);
    assert_eq!(cache.warm_skipped(), 2);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn warm_start_isolates_models_sharing_one_directory() {
    // Two models persist into one fleet-wide directory; each warm start
    // loads only its own plans and reports the other's as foreign.
    let dir = scratch_dir("shared-dir");
    let blaze = UsageRecords::from_graph(&models::blazeface());
    let mobile = UsageRecords::from_graph(&models::mobilenet_v1());
    assert_eq!(populate(&blaze, &dir, &[1, 2]), 2);
    assert_eq!(populate(&mobile, &dir, &[1]), 1);

    let cache = PlanCache::new();
    let report = cache.warm_start(&dir, &blaze).unwrap();
    assert_eq!((report.loaded, report.skipped_foreign), (2, 1), "{report:?}");
    let cache = PlanCache::new();
    let report = cache.warm_start(&dir, &mobile).unwrap();
    assert_eq!((report.loaded, report.skipped_foreign), (1, 2), "{report:?}");
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------------
// Restart acceptance: zero planner invocations on the second start.
// ---------------------------------------------------------------------------

/// One serving "process lifetime": spawn a budget-capped server against
/// `dir`, run a burst, persist the cache back, and return the number of
/// planner invocations the run needed.
fn serve_once(dir: &std::path::Path, burst: usize) -> u64 {
    let g = models::blazeface();
    let in_elems = g.tensor(g.inputs[0]).num_elements();
    let recs = UsageRecords::from_graph(&g);
    let service = PlanService::shared();
    service.warm_start(dir, &recs).unwrap();
    let budget = 3 * service.plan_records(&recs, 1, None).unwrap().total;
    let server = {
        let service = Arc::clone(&service);
        ModelServer::spawn(
            move || {
                let g = models::blazeface();
                Box::new(
                    ExecutorEngine::new(&g, service, "greedy-size", 7)
                        .expect("engine")
                        .with_max_batch(8),
                )
            },
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                mem_budget: Some(budget),
            },
        )
    };
    let pending: Vec<_> = (0..burst)
        .map(|i| server.submit(vec![(i % 7) as f32 * 0.1; in_elems]))
        .collect();
    for rx in pending {
        rx.recv().unwrap().unwrap();
    }
    server.shutdown();
    service.persist_dir(dir).unwrap();
    service.stats().cache_misses
}

#[test]
fn second_cold_start_against_plan_dir_plans_nothing() {
    let dir = scratch_dir("restart");
    // First lifetime: plans everything it needs (batch-1 at engine build,
    // the budget binary-search probes, every batch the burst formed).
    let cold_misses = serve_once(&dir, 64);
    assert!(cold_misses >= 1, "first start must actually plan");
    // Second lifetime, fresh PlanService, same directory: every plan —
    // including the max_servable_batch probes — is warm-started, so the
    // planner-invocation counter stays at zero.
    let warm_misses = serve_once(&dir, 64);
    assert_eq!(
        warm_misses, 0,
        "a restarted server must re-plan nothing for previously-seen shapes"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}
