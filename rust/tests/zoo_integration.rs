//! Integration over the model zoo: the paper's *relational* evaluation
//! claims (who wins where, lower-bound attainment, naive ratios) plus
//! behavioural plan validation through the CPU executor.

use tensorarena::exec::Executor;
use tensorarena::models;
use tensorarena::planner::offset::{self, GreedyBySize as OffGS, NaiveOffset};
use tensorarena::planner::shared;
use tensorarena::planner::{OffsetPlanner, SharedObjectPlanner};
use tensorarena::records::UsageRecords;
use tensorarena::rng::SplitMix64;

fn recs_of(name: &str) -> UsageRecords {
    UsageRecords::from_graph(&models::by_name(name).unwrap())
}

#[test]
fn table2_greedy_by_size_hits_lower_bound_on_most_networks() {
    // Paper §6: "It achieves the theoretical lower bound on all selected
    // neural networks, except DeepLab v3, where it still falls within 8%".
    let mut at_bound = 0;
    for name in models::ZOO {
        let recs = recs_of(name);
        let plan = OffGS.plan(&recs);
        let lb = recs.profiles().offset_lower_bound();
        let ratio = plan.total_size() as f64 / lb as f64;
        assert!(
            ratio < 1.10,
            "{name}: Greedy by Size at {ratio:.3}x of lower bound (paper: ≤1.08)"
        );
        if plan.total_size() == lb {
            at_bound += 1;
        }
    }
    assert!(
        at_bound >= 4,
        "Greedy by Size reached the offset lower bound on only {at_bound}/6 networks"
    );
}

#[test]
fn paper_strategies_beat_prior_work_in_aggregate() {
    // Table 1's qualitative claim: the paper's best strategy ≤ both prior
    // rows on every network (ties allowed), strictly better somewhere.
    let mut strictly_better = 0;
    for name in models::ZOO {
        let recs = recs_of(name);
        let ours = [
            shared::GreedyBySize.plan(&recs).total_size(),
            shared::GreedyBySizeImproved.plan(&recs).total_size(),
            shared::GreedyByBreadth.plan(&recs).total_size(),
        ]
        .into_iter()
        .min()
        .unwrap();
        let prior = [
            shared::TfLiteGreedy.plan(&recs).total_size(),
            shared::MinCostFlow.plan(&recs).total_size(),
        ]
        .into_iter()
        .min()
        .unwrap();
        assert!(
            ours <= prior,
            "{name}: best paper strategy {ours} worse than prior work {prior}"
        );
        if ours < prior {
            strictly_better += 1;
        }
    }
    assert!(strictly_better >= 2, "paper strategies never strictly beat prior work");
}

#[test]
fn offset_beats_or_ties_shared_everywhere() {
    // §5: offset solutions subsume shared-objects solutions.
    for name in models::ZOO {
        let recs = recs_of(name);
        let off = OffGS.plan(&recs).total_size();
        let sh = shared::GreedyBySizeImproved.plan(&recs).total_size();
        assert!(off <= sh, "{name}: offset {off} > shared {sh}");
    }
}

#[test]
fn naive_ratio_matches_paper_scale() {
    // §1/§6: naive is 5–10.5x worse than the best offset strategy. Exact
    // per-net ratios differ with our reconstructions; the *scale* must hold.
    let mut max_ratio: f64 = 0.0;
    for name in models::ZOO {
        let recs = recs_of(name);
        let best = OffGS.plan(&recs).total_size();
        let ratio = recs.naive_total() as f64 / best as f64;
        assert!(
            ratio > 2.0,
            "{name}: naive only {ratio:.2}x worse — planning broken?"
        );
        max_ratio = max_ratio.max(ratio);
    }
    assert!(
        max_ratio > 5.0,
        "max naive ratio {max_ratio:.2} — paper reports up to 10.5x"
    );
}

#[test]
fn greedy_size_improved_recommended_default_for_shared() {
    // §6: "it is recommended to default to Greedy by Size Improved" — it is
    // best-or-tied on all networks except possibly MobileNet v2 (where the
    // paper itself shows Greedy by Breadth winning).
    for name in models::ZOO {
        let recs = recs_of(name);
        let gsi = shared::GreedyBySizeImproved.plan(&recs).total_size();
        let others = [
            shared::GreedyBySize.plan(&recs).total_size(),
            shared::GreedyByBreadth.plan(&recs).total_size(),
        ];
        let best = others.into_iter().min().unwrap().min(gsi);
        if name == "mobilenet_v2" {
            continue; // paper: GbB wins here
        }
        assert!(
            gsi as f64 <= best as f64 * 1.02,
            "{name}: GSI {gsi} notably worse than best {best}"
        );
    }
}

#[test]
fn executors_agree_between_planned_and_naive_arenas() {
    // Behavioural check on two real networks: identical outputs under the
    // planned arena and the naive arena, with poisoning on.
    for name in ["blazeface", "l2_cnn"] {
        let g = models::by_name(name).unwrap();
        let n_in = g.tensor(g.inputs[0]).num_elements();
        let mut rng = SplitMix64::new(11);
        let mut x = vec![0f32; n_in];
        rng.fill_f32(&mut x, 1.0);
        let mut planned = Executor::new(&g, &OffGS, 99).unwrap();
        planned.set_poison_dead(true);
        let mut naive = Executor::new(&g, &NaiveOffset, 99).unwrap();
        let a = planned.run(&[&x]);
        let b = naive.run(&[&x]);
        assert_eq!(a, b, "{name}: planned arena changed results");
        for out in &a {
            assert!(out.iter().all(|v| v.is_finite()), "{name}: NaN leaked");
        }
    }
}

#[test]
fn every_offset_strategy_is_behaviourally_sound_on_l2_cnn() {
    let g = models::by_name("l2_cnn").unwrap();
    let n_in = g.tensor(g.inputs[0]).num_elements();
    let mut rng = SplitMix64::new(13);
    let mut x = vec![0f32; n_in];
    rng.fill_f32(&mut x, 1.0);
    let reference = Executor::new(&g, &NaiveOffset, 5).unwrap().run(&[&x]);
    for strat in tensorarena::planner::table2_strategies() {
        let mut ex = Executor::new(&g, strat.as_ref(), 5).unwrap();
        ex.set_poison_dead(true);
        let out = ex.run(&[&x]);
        assert_eq!(out, reference, "strategy {} corrupted data", strat.name());
    }
}

#[test]
fn repeated_runs_reuse_arena_without_stale_state() {
    // Two consecutive inferences with different inputs: the second must not
    // see the first's data even though every buffer is recycled.
    let g = models::by_name("l2_cnn").unwrap();
    let n_in = g.tensor(g.inputs[0]).num_elements();
    let mut rng = SplitMix64::new(17);
    let mut x1 = vec![0f32; n_in];
    let mut x2 = vec![0f32; n_in];
    rng.fill_f32(&mut x1, 1.0);
    rng.fill_f32(&mut x2, 1.0);
    let mut ex = Executor::new(&g, &OffGS, 23).unwrap();
    let y1 = ex.run(&[&x1]);
    let y2 = ex.run(&[&x2]);
    let y1_again = ex.run(&[&x1]);
    assert_eq!(y1, y1_again, "executor is stateful across runs");
    assert_ne!(y1, y2, "different inputs gave identical outputs");
}

#[test]
fn shared_object_count_is_small_like_the_paper_says() {
    // §4.2: "k is often at lower tens, whereby n is one or two magnitudes
    // larger in a typical neural network."
    for name in models::ZOO {
        let recs = recs_of(name);
        let plan = shared::GreedyBySizeImproved.plan(&recs);
        assert!(
            plan.num_objects() <= 40,
            "{name}: {} shared objects for {} tensors",
            plan.num_objects(),
            recs.len()
        );
        assert!(recs.len() >= 2 * plan.num_objects());
    }
}

#[test]
#[ignore = "tier-2: plans and runs every zoo network at three size classes; run with --ignored"]
fn quantized_size_classes_shrink_every_zoo_network_within_drift() {
    // The dtype dimension across the whole zoo: an i8 request must plan a
    // ≥3.5x smaller peak than f32 on every network (f16 ≥1.9x) — the
    // element width survives alignment on real tensor populations, not
    // just on mobilenet_v2 — and the end-to-end quantized outputs must
    // stay within a drift bound scaled to each model's own output range.
    use std::sync::Arc;
    use tensorarena::planner::{Dtype, PlanRequest, PlanService};

    for name in models::ZOO {
        let g = models::by_name(name).unwrap();
        let recs = recs_of(name);
        let svc = PlanService::shared();
        let f32_req = PlanRequest::new();
        let f32_peak = svc.plan(&recs, &f32_req).unwrap().total;

        let mut rng = SplitMix64::new(29);
        let inputs: Vec<Vec<f32>> = g
            .inputs
            .iter()
            .map(|&t| {
                let mut v = vec![0f32; g.tensor(t).num_elements()];
                rng.fill_f32(&mut v, 1.0);
                v
            })
            .collect();
        let input_refs: Vec<&[f32]> = inputs.iter().map(Vec::as_slice).collect();
        let mut f32_exec =
            Executor::with_request(&g, Arc::clone(&svc), &f32_req, None, 41).unwrap();
        let reference = f32_exec.run(&input_refs);
        let out_scale = reference
            .iter()
            .flat_map(|out| out.iter())
            .fold(1f32, |m, &v| m.max(v.abs()));

        for (dtype, floor, drift_frac) in
            [(Dtype::I8, 3.5f64, 0.25f32), (Dtype::F16, 1.9f64, 0.05f32)]
        {
            let req = f32_req.with_dtype(dtype);
            let peak = svc.plan(&recs, &req).unwrap().total;
            let shrink = f32_peak as f64 / peak.max(1) as f64;
            assert!(
                shrink >= floor,
                "{name}: {dtype} planned peak shrank only {shrink:.2}x (< {floor}x)"
            );

            let mut q_exec = Executor::with_request(&g, Arc::clone(&svc), &req, None, 41).unwrap();
            let got = q_exec.run(&input_refs);
            assert_eq!(got.len(), reference.len(), "{name}: {dtype} output arity changed");
            let drift = drift_frac * out_scale;
            for (o, (q, f)) in got.iter().zip(reference.iter()).enumerate() {
                assert_eq!(q.len(), f.len(), "{name}: {dtype} output {o} length changed");
                for (i, (&qv, &fv)) in q.iter().zip(f.iter()).enumerate() {
                    assert!(qv.is_finite(), "{name}: {dtype} output {o} elem {i} not finite");
                    assert!(
                        (qv - fv).abs() <= drift,
                        "{name}: {dtype} output {o} elem {i} drifted {} (> {drift})",
                        (qv - fv).abs()
                    );
                }
            }
        }
    }
}

#[test]
fn cachesim_planned_wins_on_every_zoo_network() {
    use tensorarena::exec::cachesim::simulate;
    for g in models::all_zoo() {
        let recs = UsageRecords::from_graph(&g);
        let pl = simulate(&g, &recs, &OffGS.plan(&recs));
        let nv = simulate(&g, &recs, &offset::NaiveOffset.plan(&recs));
        let (hp, hn) = (pl.hit_rate(1 << 20), nv.hit_rate(1 << 20));
        assert!(
            hp >= hn,
            "{}: planned hit rate {hp:.4} below naive {hn:.4} at 1 MiB",
            g.name
        );
    }
}
