//! PJRT integration: the AOT artifacts round-trip through the Rust runtime
//! and the serving coordinator.
//!
//! These tests need the `pjrt` feature and `artifacts/` (run
//! `make artifacts`); they are skipped with a message otherwise so
//! `cargo test` stays green in a fresh clone.

#![cfg(feature = "pjrt")]

use std::path::Path;
use tensorarena::coordinator::engine::PjrtEngine;
use tensorarena::coordinator::{ArenaStats, BatchPolicy, ModelServer};
use tensorarena::rng::SplitMix64;
use tensorarena::runtime::{Runtime, VariantSet};

const DIMS: [usize; 3] = [32, 32, 3];
const IN_ELEMS: usize = 32 * 32 * 3;
const OUT: usize = 10;

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if Runtime::discover_variants(p, "model").is_ok() {
        Some(p)
    } else {
        eprintln!("skipping PJRT test: run `make artifacts` first");
        None
    }
}

#[test]
fn load_and_execute_b1() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let vs = VariantSet::load(&rt, dir, "model", &DIMS, OUT).unwrap();
    let mut rng = SplitMix64::new(1);
    let mut x = vec![0f32; IN_ELEMS];
    rng.fill_f32(&mut x, 1.0);
    let out = vs.pick(1).run(&x).unwrap();
    assert_eq!(out.len(), OUT);
    let s: f32 = out.iter().sum();
    assert!((s - 1.0).abs() < 1e-4, "softmax sum {s}");
    assert!(out.iter().all(|v| *v >= 0.0 && v.is_finite()));
}

#[test]
fn batch_variants_agree_per_sample() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let vs = VariantSet::load(&rt, dir, "model", &DIMS, OUT).unwrap();
    let mut rng = SplitMix64::new(2);
    let mut samples = vec![0f32; 4 * IN_ELEMS];
    rng.fill_f32(&mut samples, 1.0);
    let b4 = vs.pick(4).run(&samples).unwrap();
    for i in 0..4 {
        let one = vs.pick(1).run(&samples[i * IN_ELEMS..(i + 1) * IN_ELEMS]).unwrap();
        for j in 0..OUT {
            assert!(
                (one[j] - b4[i * OUT + j]).abs() < 1e-5,
                "sample {i} class {j}: {} vs {}",
                one[j],
                b4[i * OUT + j]
            );
        }
    }
}

#[test]
fn pick_selects_smallest_sufficient_variant() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let vs = VariantSet::load(&rt, dir, "model", &DIMS, OUT).unwrap();
    assert_eq!(vs.pick(1).batch, 1);
    assert_eq!(vs.pick(2).batch, 2);
    assert_eq!(vs.pick(3).batch, 4);
    assert_eq!(vs.pick(8).batch, 8);
    assert_eq!(vs.pick(99).batch, vs.max_batch());
}

#[test]
fn pjrt_engine_pads_partial_batches() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let vs = VariantSet::load(&rt, dir, "model", &DIMS, OUT).unwrap();
    let mut engine = PjrtEngine::new(vs, ArenaStats::default());
    use tensorarena::coordinator::Engine;
    let mut rng = SplitMix64::new(3);
    let mut x = vec![0f32; 3 * IN_ELEMS];
    rng.fill_f32(&mut x, 1.0);
    // n=3 -> padded onto the b4 executable; results for 3 samples returned
    let out = engine.run_batch(&x, 3).unwrap();
    assert_eq!(out.len(), 3 * OUT);
    for i in 0..3 {
        let s: f32 = out[i * OUT..(i + 1) * OUT].iter().sum();
        assert!((s - 1.0).abs() < 1e-4);
    }
}

#[test]
fn full_serving_path_through_coordinator() {
    let Some(_) = artifacts() else { return };
    let server = ModelServer::spawn(
        || {
            let rt = Runtime::cpu().expect("PJRT");
            let vs = VariantSet::load(&rt, Path::new("artifacts"), "model", &DIMS, OUT)
                .expect("artifacts");
            Box::new(PjrtEngine::new(vs, ArenaStats::default()))
        },
        BatchPolicy { max_batch: 4, max_wait: std::time::Duration::from_millis(2), ..BatchPolicy::default() },
    );
    let mut rng = SplitMix64::new(4);
    let mut input = vec![0f32; IN_ELEMS];
    let pending: Vec<_> = (0..16)
        .map(|_| {
            rng.fill_f32(&mut input, 1.0);
            server.submit(input.clone())
        })
        .collect();
    for rx in pending {
        let out = rx.recv().unwrap().expect("inference ok");
        assert_eq!(out.len(), OUT);
    }
    let snap = server.metrics().snapshot();
    assert_eq!(snap.completed, 16);
    server.shutdown();
}
