//! PJRT integration: the AOT artifacts round-trip through the Rust runtime
//! and the serving coordinator.
//!
//! These tests need the `pjrt` feature and `artifacts/` (run
//! `make artifacts`); they are skipped with a message otherwise so
//! `cargo test` stays green in a fresh clone.

#![cfg(feature = "pjrt")]

use std::path::Path;
use std::sync::Arc;
use tensorarena::coordinator::engine::PjrtEngine;
use tensorarena::coordinator::{BatchPolicy, Engine, ModelServer};
use tensorarena::models;
use tensorarena::planner::{PlanRequest, PlanService};
use tensorarena::records::UsageRecords;
use tensorarena::rng::SplitMix64;
use tensorarena::runtime::{Runtime, VariantSet};

const DIMS: [usize; 3] = [32, 32, 3];
const IN_ELEMS: usize = 32 * 32 * 3;
const OUT: usize = 10;

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if Runtime::discover_variants(p, "model").is_ok() {
        Some(p)
    } else {
        eprintln!("skipping PJRT test: run `make artifacts` first");
        None
    }
}

#[test]
fn load_and_execute_b1() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let vs = VariantSet::load(&rt, dir, "model", &DIMS, OUT).unwrap();
    let mut rng = SplitMix64::new(1);
    let mut x = vec![0f32; IN_ELEMS];
    rng.fill_f32(&mut x, 1.0);
    let out = vs.pick(1).run(&x).unwrap();
    assert_eq!(out.len(), OUT);
    let s: f32 = out.iter().sum();
    assert!((s - 1.0).abs() < 1e-4, "softmax sum {s}");
    assert!(out.iter().all(|v| *v >= 0.0 && v.is_finite()));
}

#[test]
fn batch_variants_agree_per_sample() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let vs = VariantSet::load(&rt, dir, "model", &DIMS, OUT).unwrap();
    let mut rng = SplitMix64::new(2);
    let mut samples = vec![0f32; 4 * IN_ELEMS];
    rng.fill_f32(&mut samples, 1.0);
    let b4 = vs.pick(4).run(&samples).unwrap();
    for i in 0..4 {
        let one = vs.pick(1).run(&samples[i * IN_ELEMS..(i + 1) * IN_ELEMS]).unwrap();
        for j in 0..OUT {
            assert!(
                (one[j] - b4[i * OUT + j]).abs() < 1e-5,
                "sample {i} class {j}: {} vs {}",
                one[j],
                b4[i * OUT + j]
            );
        }
    }
}

#[test]
fn pick_selects_smallest_sufficient_variant() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let vs = VariantSet::load(&rt, dir, "model", &DIMS, OUT).unwrap();
    assert_eq!(vs.pick(1).batch, 1);
    assert_eq!(vs.pick(2).batch, 2);
    assert_eq!(vs.pick(3).batch, 4);
    assert_eq!(vs.pick(8).batch, 8);
    assert_eq!(vs.pick(99).batch, vs.max_batch());
}

/// The PJRT engine's planner twin: the L2 CNN's batch-1 usage records.
fn twin_records() -> UsageRecords {
    UsageRecords::from_graph(&models::l2_cnn())
}

#[test]
fn pjrt_engine_pads_partial_batches() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let vs = VariantSet::load(&rt, dir, "model", &DIMS, OUT).unwrap();
    let mut engine =
        PjrtEngine::with_request(vs, PlanService::shared(), twin_records(), &PlanRequest::new())
            .unwrap();
    let mut rng = SplitMix64::new(3);
    let mut x = vec![0f32; 3 * IN_ELEMS];
    rng.fill_f32(&mut x, 1.0);
    // n=3 -> padded onto the b4 executable; results for 3 samples returned
    let out = engine.run_batch(&x, 3).unwrap();
    assert_eq!(out.len(), 3 * OUT);
    for i in 0..3 {
        let s: f32 = out[i * OUT..(i + 1) * OUT].iter().sum();
        assert!((s - 1.0).abs() < 1e-4);
    }
}

#[test]
fn pjrt_engine_accounting_resolves_through_the_shared_plan_cache() {
    // The ROADMAP item this PR pays down: the AOT engine no longer carries
    // a frozen ArenaStats snapshot — planned_peak and max_servable_batch
    // go through the same PlanService as the pure-Rust path, so probes hit
    // the shared cache and the reported stats carry live counters.
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let vs = VariantSet::load(&rt, dir, "model", &DIMS, OUT).unwrap();
    let service = PlanService::shared();
    let recs = twin_records();
    let req = PlanRequest::new();
    let engine =
        PjrtEngine::with_request(vs, Arc::clone(&service), recs.clone(), &req).unwrap();
    // Peaks come from real plans and grow with batch.
    let p1 = engine.planned_peak(1).expect("planner-managed engine answers");
    let p4 = engine.planned_peak(4).expect("planner-managed engine answers");
    assert!(p1 > 0 && p4 > p1);
    assert_eq!(p1, service.plan(&recs, &req).unwrap().total, "peak must be the cached plan");
    // The budget query is tight and resolves through the same cache.
    let cap = engine.max_servable_batch(2 * p1).expect("budget query answered");
    assert!(cap >= 1);
    assert!(engine.planned_peak(cap).unwrap() <= 2 * p1);
    assert!(engine.planned_peak(cap + 1).unwrap() > 2 * p1);
    // Every probe above landed in the shared cache: repeating the whole
    // sequence performs zero further planner invocations.
    let misses = service.stats().cache_misses;
    let _ = engine.planned_peak(1);
    let _ = engine.planned_peak(4);
    let _ = engine.max_servable_batch(2 * p1);
    assert_eq!(
        service.stats().cache_misses,
        misses,
        "repeated probes must be pure cache hits"
    );
    // And the stats line reports live service counters, not a snapshot.
    let stats = engine.arena_stats();
    assert_eq!(stats.strategy, "greedy-size");
    assert!(stats.cache_misses >= 1 && stats.cache_hits >= 1);
    assert!(stats.planned_bytes > 0 && stats.naive_bytes >= stats.planned_bytes);
}

#[test]
fn full_serving_path_through_coordinator() {
    let Some(_) = artifacts() else { return };
    let service = PlanService::shared();
    let server = ModelServer::spawn(
        {
            let service = Arc::clone(&service);
            move || {
                let rt = Runtime::cpu().expect("PJRT");
                let vs = VariantSet::load(&rt, Path::new("artifacts"), "model", &DIMS, OUT)
                    .expect("artifacts");
                Box::new(
                    PjrtEngine::with_request(
                        vs,
                        service,
                        twin_records(),
                        &PlanRequest::new().with_batch(4),
                    )
                    .expect("twin plan"),
                )
            }
        },
        BatchPolicy { max_batch: 4, max_wait: std::time::Duration::from_millis(2), ..BatchPolicy::default() },
    )
    .expect("spawn");
    let mut rng = SplitMix64::new(4);
    let mut input = vec![0f32; IN_ELEMS];
    let pending: Vec<_> = (0..16)
        .map(|_| {
            rng.fill_f32(&mut input, 1.0);
            server.submit(input.clone())
        })
        .collect();
    for rx in pending {
        let out = rx.recv().unwrap().expect("inference ok");
        assert_eq!(out.len(), OUT);
    }
    let snap = server.metrics().snapshot();
    assert_eq!(snap.completed, 16);
    server.shutdown();
}
