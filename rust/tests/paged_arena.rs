//! Tier-1 acceptance tests for paged decode-tail arenas: exact size-class
//! boundary behaviour of the shared [`ArenaPool`], block-pool round-trip
//! properties under randomized churn, and — the headline invariant — bit
//! identity of paged execution against the resident wave-aware executor
//! over randomized decode-tail workloads, on the sequential and
//! `--threads` paths alike.
//!
//! [`ArenaPool`]: tensorarena::arena::ArenaPool

use std::sync::Arc;
use tensorarena::arena::paged::{BLOCK_WORDS, PagedArena};
use tensorarena::arena::ArenaPool;
use tensorarena::exec::Executor;
use tensorarena::models;
use tensorarena::planner::{DynamicRecords, PlanRequest, PlanService};
use tensorarena::records::UsageRecords;
use tensorarena::rng::SplitMix64;

#[test]
fn pool_class_boundaries_are_exact() {
    let pool = ArenaPool::new();
    // 1024 and 1025 words share class 10, but a shelved 1024-word buffer
    // cannot cover the larger request — the pool must allocate fresh,
    // never hand back a short buffer.
    pool.release(vec![0f32; 1024]);
    let over = pool.acquire(1025);
    assert_eq!(over.len(), 1025);
    assert_eq!((pool.allocated(), pool.reused()), (1, 0));
    // The exact length is served from the shelf.
    let exact = pool.acquire(1024);
    assert_eq!(exact.len(), 1024);
    assert_eq!((pool.allocated(), pool.reused()), (1, 1));
    // 2047 words still sit in class 10, so a 1024-word request may take
    // that buffer...
    pool.release(vec![0f32; 2047]);
    assert_eq!(pool.acquire(1024).len(), 2047);
    // ...and 2048 starts class 11, one class up, which acquire probes too.
    pool.release(vec![0f32; 2048]);
    assert_eq!(pool.acquire(1024).len(), 2048, "acquire must probe one class up");
    // Two classes up is out of reach: a shelved 4096-word buffer (class
    // 12) must not serve a 512-word request (class 9).
    pool.release(vec![0f32; 4096]);
    assert_eq!(pool.acquire(512).len(), 512, "probing must stop one class above");
    assert_eq!((pool.allocated(), pool.reused()), (2, 3));
}

#[test]
fn randomized_pool_churn_covers_zeroes_and_conserves_buffers() {
    // Random acquire/release interleavings: every handed-out buffer covers
    // the request with its payload zeroed, and the counters conserve flow
    // (acquires split into reuses + fresh allocations; releases split into
    // shelved-now + reused-later + dropped-at-cap).
    for seed in 0..8u64 {
        let pool = ArenaPool::new();
        let mut rng = SplitMix64::new(0xBADB10C + seed);
        let mut held: Vec<Vec<f32>> = Vec::new();
        let mut acquires = 0u64;
        let mut releases = 0u64;
        for _ in 0..300 {
            if held.is_empty() || rng.next_below(2) == 0 {
                let words = rng.next_range(1, 6000);
                let mut buf = pool.acquire(words);
                assert!(buf.len() >= words, "seed {seed}: asked {words}, got {}", buf.len());
                assert!(
                    buf[..words].iter().all(|&v| v == 0.0),
                    "seed {seed}: dirty payload for {words}-word request"
                );
                // Dirty the buffer so the zeroing assertion above is
                // meaningful when this one comes back around.
                buf.fill(f32::NAN);
                held.push(buf);
                acquires += 1;
            } else {
                let i = rng.next_below(held.len());
                pool.release(held.swap_remove(i));
                releases += 1;
            }
        }
        assert_eq!(pool.reused() + pool.allocated(), acquires, "seed {seed}: acquire flow");
        // Every reuse pops one shelf entry, and every shelf entry comes
        // from a release (the pool starts empty): each release is dropped
        // at the cap, still shelved, or was consumed by a later reuse.
        assert_eq!(
            pool.idle_buffers() as u64 + pool.reused() + pool.dropped(),
            releases,
            "seed {seed}: release flow"
        );
    }
}

#[test]
fn paged_arenas_share_blocks_through_one_pool() {
    // The coordinator's normal state: several executors on one ArenaPool.
    // Blocks freed by one arena's dying tail tensor are immediately
    // servable to another arena on the same pool.
    let pool = Arc::new(ArenaPool::new());
    let mut a = PagedArena::new(Arc::clone(&pool), 2);
    let mut b = PagedArena::new(Arc::clone(&pool), 2);
    a.map(0, 3 * BLOCK_WORDS);
    assert_eq!(pool.blocks().blocks_in_use(), 3);
    a.unmap(0);
    b.map(1, 3 * BLOCK_WORDS);
    assert_eq!(pool.blocks().reused(), 3, "freed blocks must recycle across arenas");
    assert_eq!(pool.blocks().allocated(), 3);
    b.unmap(1);
    assert_eq!(pool.blocks().blocks_in_use(), 0);
    // Whole-block regions at the peak leave no internal fragmentation.
    assert_eq!(pool.blocks().fragmentation(), 0.0);
}

#[test]
fn randomized_block_regions_round_trip_cleanly() {
    let pool = ArenaPool::new();
    let blocks = pool.blocks();
    let mut rng = SplitMix64::new(0x9A6ED);
    let mut held: Vec<(Vec<Vec<f32>>, usize)> = Vec::new();
    for _ in 0..200 {
        if held.is_empty() || rng.next_below(2) == 0 {
            let words = rng.next_range(1, 5 * BLOCK_WORDS);
            let region = blocks.acquire_region(words);
            assert_eq!(region.len(), words.div_ceil(BLOCK_WORDS));
            assert!(region.iter().all(|b| b.len() == BLOCK_WORDS));
            held.push((region, words));
        } else {
            let i = rng.next_below(held.len());
            let (region, words) = held.swap_remove(i);
            blocks.release_region(region, words);
        }
    }
    let outstanding: usize = held.iter().map(|(r, _)| r.len()).sum();
    assert_eq!(blocks.blocks_in_use(), outstanding, "gauge must track live regions exactly");
    for (region, words) in held {
        blocks.release_region(region, words);
    }
    assert_eq!(blocks.blocks_in_use(), 0);
    assert!(blocks.reused() > 0, "churn must recycle blocks through the freelist");
    let frag = blocks.fragmentation();
    assert!((0.0..1.0).contains(&frag), "fragmentation {frag} out of [0, 1)");
}

/// Splits of `g` whose decode tail actually holds dynamic records.
fn dynamic_splits(g: &tensorarena::graph::Graph, recs: &UsageRecords) -> Vec<usize> {
    (2..g.num_ops())
        .filter(|&f| DynamicRecords::decode_tail(recs, f).num_dynamic() > 0)
        .collect()
}

#[test]
fn paged_execution_is_bit_identical_to_resident_over_random_decode_tails() {
    let g = models::blazeface();
    let in_elems = g.tensor(g.inputs[0]).num_elements();
    let recs = UsageRecords::from_graph(&g);
    let candidates = dynamic_splits(&g, &recs);
    assert!(!candidates.is_empty(), "blazeface must offer non-trivial decode splits");
    let mut rng = SplitMix64::new(0xDEC0DE);
    for trial in 0..4 {
        let from = candidates[rng.next_below(candidates.len())];
        let d = DynamicRecords::decode_tail(&recs, from);
        let batch = rng.next_range(1, 4);
        let req = PlanRequest::new();
        let mut resident =
            Executor::with_request(&g, PlanService::shared(), &req, Some(d.clone()), 7).unwrap();
        let svc = PlanService::shared();
        let mut paged = Executor::with_request_paged(&g, Arc::clone(&svc), &req, d, 7).unwrap();
        assert!(paged.is_paged());
        let mut input = vec![0f32; batch * in_elems];
        rng.fill_f32(&mut input, 1.0);
        let want = resident.run_batch(&input, batch).unwrap();
        let got = paged.run_batch(&input, batch).unwrap();
        assert_eq!(want, got, "trial {trial}: paged diverged (from {from}, batch {batch})");
        // The paged executor's resident arena hosts only the static
        // prefix — never more than the worst-wave resident arena.
        assert!(
            paged.arena_bytes() <= resident.arena_bytes(),
            "trial {trial}: paged arena {} > resident {}",
            paged.arena_bytes(),
            resident.arena_bytes()
        );
        // Steady state: every tail block went back to the shared pool.
        assert_eq!(
            svc.pool().blocks().blocks_in_use(),
            0,
            "trial {trial}: leaked blocks after run (from {from})"
        );
    }
}

#[test]
fn threaded_paged_execution_matches_sequential_paged_and_resident() {
    let g = models::blazeface();
    let in_elems = g.tensor(g.inputs[0]).num_elements();
    let from = g.num_ops() / 2;
    let d = DynamicRecords::decode_tail(&UsageRecords::from_graph(&g), from);
    assert!(d.num_dynamic() > 0);
    let req = PlanRequest::new();
    let mut resident =
        Executor::with_request(&g, PlanService::shared(), &req, Some(d.clone()), 11).unwrap();
    let svc = PlanService::shared();
    let mut paged = Executor::with_request_paged(&g, Arc::clone(&svc), &req, d, 11).unwrap();
    paged.set_threads(4);
    assert_eq!(paged.threads(), 4);
    let mut rng = SplitMix64::new(5);
    for round in 0..2 {
        for batch in [1usize, 3] {
            let mut input = vec![0f32; batch * in_elems];
            rng.fill_f32(&mut input, 1.0);
            let want = resident.run_batch(&input, batch).unwrap();
            let got = paged.run_batch(&input, batch).unwrap();
            assert_eq!(want, got, "round {round} batch {batch}: threaded paged diverged");
        }
    }
    assert_eq!(svc.pool().blocks().blocks_in_use(), 0, "leaked blocks after threaded runs");
    assert!(svc.pool().blocks().reused() > 0, "later rounds must recycle tail blocks");
}

#[test]
#[ignore = "tier-2: broad randomized identity sweep across zoo models (slow)"]
fn paged_identity_sweep_across_zoo_models() {
    for name in ["blazeface", "mobilenet_v1"] {
        let g = models::by_name(name).unwrap();
        let in_elems = g.tensor(g.inputs[0]).num_elements();
        let recs = UsageRecords::from_graph(&g);
        let candidates = dynamic_splits(&g, &recs);
        assert!(!candidates.is_empty(), "{name}: no dynamic splits");
        let mut rng = SplitMix64::new(0xC0FFEE);
        for trial in 0..3 {
            let from = candidates[rng.next_below(candidates.len())];
            let d = DynamicRecords::decode_tail(&recs, from);
            let batch = rng.next_range(1, 2);
            let req = PlanRequest::new();
            let mut resident =
                Executor::with_request(&g, PlanService::shared(), &req, Some(d.clone()), 13)
                    .unwrap();
            let svc = PlanService::shared();
            let mut paged =
                Executor::with_request_paged(&g, Arc::clone(&svc), &req, d, 13).unwrap();
            if trial % 2 == 1 {
                paged.set_threads(4);
            }
            let mut input = vec![0f32; batch * in_elems];
            rng.fill_f32(&mut input, 1.0);
            let want = resident.run_batch(&input, batch).unwrap();
            let got = paged.run_batch(&input, batch).unwrap();
            assert_eq!(want, got, "{name} trial {trial}: paged diverged (from {from})");
            assert_eq!(svc.pool().blocks().blocks_in_use(), 0, "{name}: leaked blocks");
        }
    }
}
