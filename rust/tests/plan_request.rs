//! The `PlanRequest` grammar tier: the typed plan identity must roundtrip
//! through the `.plan` v2 file-name/header grammar for random
//! strategy/order/batch/dynamic/dtype combinations, pre-redesign v2
//! directories must keep warm-starting byte-for-byte (zero planner
//! invocations — f32 renders no dtype segment at all), and v1/stale names
//! must still be rejected with the existing skip counters.
//!
//! Property tests use the same hand-rolled SplitMix64 generator as
//! `planner_properties.rs` (the offline registry has no proptest); every
//! failure prints its seed.

use std::path::PathBuf;
use tensorarena::models;
use tensorarena::planner::serialize::{
    self, offset_plan_from_str, offset_plan_to_string, parse_plan_file_name, plan_file_name,
};
use tensorarena::planner::{
    registry, Dtype, DynamicMode, OrderStrategy, ParseRequestError, PlanCache, PlanRequest,
    PlanService,
};
use tensorarena::records::UsageRecords;
use tensorarena::rng::SplitMix64;

fn scratch_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "tensorarena-request-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A random request drawn from the full identity space.
fn random_request(rng: &mut SplitMix64) -> PlanRequest {
    let strategy = registry::OFFSET_KEYS[rng.next_below(registry::OFFSET_KEYS.len())];
    let order = match rng.next_below(3) {
        0 => OrderStrategy::Natural,
        1 => OrderStrategy::MemoryAware,
        _ => OrderStrategy::Annealed {
            seed: rng.next_u64() % 1000,
            budget: rng.next_range(1, 500),
        },
    };
    let dynamic = match rng.next_below(3) {
        0 => DynamicMode::Static,
        1 => DynamicMode::Resolved(rng.next_below(10_000)),
        _ => DynamicMode::FullyResolved,
    };
    let dtype = Dtype::ALL[rng.next_below(Dtype::ALL.len())];
    PlanRequest::new()
        .with_strategy(strategy)
        .unwrap()
        .with_order(order)
        .with_batch(rng.next_range(1, 10_000))
        .with_dynamic(dynamic)
        .with_dtype(dtype)
}

#[test]
fn request_grammar_roundtrips_for_random_combinations() {
    // The acceptance property: Display ∘ FromStr is the identity over the
    // whole request space, both bare and embedded in a plan file name.
    let mut rng = SplitMix64::new(42);
    for case in 0..500 {
        let req = random_request(&mut rng);
        let text = req.to_string();
        assert_eq!(
            text.parse::<PlanRequest>().as_ref(),
            Ok(&req),
            "case {case}: '{text}' did not roundtrip"
        );
        let fp = rng.next_u64();
        let name = plan_file_name(fp, &req);
        assert_eq!(
            parse_plan_file_name(&name),
            Ok((fp, req)),
            "case {case}: file name '{name}' did not roundtrip"
        );
    }
}

#[test]
fn request_header_grammar_roundtrips_through_serialized_plans() {
    // The content half of the grammar: a plan serialized under a random
    // (static) request loads back only under a request with the same
    // order, for every strategy/order/batch combination.
    let recs = UsageRecords::from_graph(&models::blazeface());
    let cache = PlanCache::new();
    let mut rng = SplitMix64::new(7);
    for case in 0..40 {
        let req = random_request(&mut rng)
            .with_dynamic(DynamicMode::Static)
            .with_batch(rng.next_range(1, 6));
        let plan = cache.get_or_plan(&recs, &req).unwrap();
        let scaled = recs.scaled_for(req.batch(), req.dtype());
        let text = offset_plan_to_string(&plan, &scaled, &req);
        assert_eq!(
            offset_plan_from_str(&text, &scaled, &req).unwrap(),
            *plan,
            "case {case}: serialized plan diverged for '{req}'"
        );
        // A different order in the expecting request rejects the text.
        let other_order = if req.order().is_natural() {
            OrderStrategy::MemoryAware
        } else {
            OrderStrategy::Natural
        };
        assert!(
            offset_plan_from_str(&text, &scaled, &req.with_order(other_order)).is_err(),
            "case {case}: order mismatch must reject"
        );
    }
}

#[test]
fn pre_redesign_v2_directory_still_warm_starts_with_zero_planner_invocations() {
    // The backwards-compatibility acceptance criterion: a plan directory
    // whose file names were written by the pre-PlanRequest formatting
    // (`format!("{fp:016x}-b{batch}-{strategy}@{order}.plan")`, spelled
    // out here so a change to the typed Display breaks this test) must
    // warm-start a fresh service with zero planner invocations.
    let dir = scratch_dir("pre-redesign");
    let recs = UsageRecords::from_graph(&models::blazeface());
    let fp = serialize::records_fingerprint(&recs);
    let warm = PlanCache::new();
    for (batch, strategy) in [(1usize, "greedy-size"), (4, "greedy-size"), (1, "greedy-breadth")] {
        let req = PlanRequest::new().with_strategy(strategy).unwrap().with_batch(batch);
        let plan = warm.get_or_plan(&recs, &req).unwrap();
        // Write name *and* header with the historical string formatting.
        let old_name = format!("{fp:016x}-b{batch}-{strategy}@natural.plan");
        let text = offset_plan_to_string(&plan, &recs.scaled(batch), &req);
        assert!(
            text.starts_with(&format!("tensorarena-plan v2 offset {} ", recs.len())),
            "header layout drifted from the v2 grammar"
        );
        assert_eq!(
            plan_file_name(fp, &req),
            old_name,
            "static file names must stay byte-identical to the pre-redesign grammar"
        );
        std::fs::write(dir.join(old_name), text).unwrap();
    }

    let service = PlanService::new();
    let report = service.warm_start(&dir, &recs, &service.request()).unwrap();
    assert_eq!(report.loaded, 3, "{report:?}");
    assert_eq!(report.skipped(), 0, "{report:?}");
    for (batch, strategy) in [(1usize, "greedy-size"), (4, "greedy-size"), (1, "greedy-breadth")] {
        let req = service.request().with_strategy(strategy).unwrap().with_batch(batch);
        service.plan(&recs, &req).unwrap();
    }
    assert_eq!(
        service.stats().cache_misses,
        0,
        "a pre-redesign directory must warm-start without any planner invocation"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn warm_start_via_request_is_order_gated() {
    // The warm-start-via-request acceptance test: the request's order
    // dimension decides which files seed the cache; batch and strategy do
    // not gate (the whole envelope loads).
    let dir = scratch_dir("order-gated");
    let recs = UsageRecords::from_graph(&models::blazeface());
    let writer = PlanService::new();
    let ordered = writer.request().with_order(OrderStrategy::MemoryAware);
    // NB: records for MemoryAware would differ on a real serving path; the
    // key point here is the *gating*, so one record set suffices.
    writer.plan(&recs, &ordered).unwrap();
    writer.plan(&recs, &ordered.with_batch(2)).unwrap();
    writer.plan(&recs, &writer.request()).unwrap(); // one natural plan
    writer.persist_dir(&dir).unwrap();

    // A natural-order request loads only the natural file...
    let natural = PlanService::new();
    let report = natural.warm_start(&dir, &recs, &natural.request()).unwrap();
    assert_eq!((report.loaded, report.skipped_stale_order), (1, 2), "{report:?}");
    // ...the ordered request loads both ordered batches, regardless of the
    // request's own batch, and re-planning them costs nothing.
    let svc = PlanService::new();
    let report = svc.warm_start(&dir, &recs, &svc.request().with_order(OrderStrategy::MemoryAware)).unwrap();
    assert_eq!((report.loaded, report.skipped_stale_order), (2, 1), "{report:?}");
    svc.plan(&recs, &svc.request().with_order(OrderStrategy::MemoryAware)).unwrap();
    svc.plan(&recs, &svc.request().with_order(OrderStrategy::MemoryAware).with_batch(2)).unwrap();
    assert_eq!(svc.stats().cache_misses, 0);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn v1_and_stale_names_keep_their_skip_counters() {
    // The redesign must not reshuffle the skip taxonomy: v1-era names and
    // unparseable junk stay `skipped_corrupt` (suspect, warm_skipped),
    // unregistered strategies stay `skipped_stale_strategy` (suspect),
    // other orders stay `skipped_stale_order` (not suspect), other models
    // stay `skipped_foreign` (not suspect).
    let dir = scratch_dir("skip-taxonomy");
    let recs = UsageRecords::from_graph(&models::blazeface());
    let fp = serialize::records_fingerprint(&recs);
    let cache = PlanCache::new();
    let plan = cache.get_or_plan(&recs, &PlanRequest::new()).unwrap();
    let text = offset_plan_to_string(&plan, &recs, &PlanRequest::new());
    // v1-era name (no @<order> segment).
    std::fs::write(dir.join(format!("{fp:016x}-b1-greedy-size.plan")), &text).unwrap();
    // Unparseable junk.
    std::fs::write(dir.join("junk.plan"), "garbage").unwrap();
    // Unregistered strategy under a well-formed grammar.
    std::fs::write(dir.join(format!("{fp:016x}-b1-belady@natural.plan")), &text).unwrap();
    // Unregistered strategy in a file that is not ours to warn about:
    // order and fingerprint still gate before the strategy check, exactly
    // as before the typed parse.
    std::fs::write(dir.join(format!("{fp:016x}-b1-belady@memory-aware.plan")), &text).unwrap();
    std::fs::write(
        dir.join(format!("{:016x}-b1-belady@natural.plan", fp ^ 2)),
        &text,
    )
    .unwrap();
    // Another order (valid configuration sharing the directory).
    std::fs::write(
        dir.join(format!("{fp:016x}-b1-greedy-size@memory-aware.plan")),
        &text,
    )
    .unwrap();
    // An order key this build does not know (a newer build's plans):
    // forward compatibility demands the same silent stale-order gate.
    std::fs::write(
        dir.join(format!("{fp:016x}-b1-greedy-size@profile-guided.plan")),
        &text,
    )
    .unwrap();
    // Another model's fingerprint.
    std::fs::write(
        dir.join(format!("{:016x}-b1-greedy-size@natural.plan", fp ^ 1)),
        &text,
    )
    .unwrap();
    // A dynamic-mode name, which must never exist on disk: corrupt.
    std::fs::write(
        dir.join(format!("{fp:016x}-b1-greedy-size@natural+full.plan")),
        &text,
    )
    .unwrap();
    // And one genuine file.
    std::fs::write(dir.join(plan_file_name(fp, &PlanRequest::new())), &text).unwrap();

    let cold = PlanCache::new();
    let report = cold.warm_start(&dir, &recs, &PlanRequest::new()).unwrap();
    assert_eq!(report.loaded, 1, "{report:?}");
    assert_eq!(report.skipped_corrupt, 3, "{report:?}"); // v1 name, junk, dynamic name
    assert_eq!(report.skipped_stale_strategy, 1, "{report:?}");
    assert_eq!(report.skipped_stale_order, 3, "{report:?}"); // incl. other-order belady + unknown order
    assert_eq!(report.skipped_foreign, 2, "{report:?}"); // incl. foreign belady
    assert_eq!(report.skipped(), 4, "suspect = corrupt + stale-strategy");
    assert_eq!(cold.warm_skipped(), 4);
    // The parse layer agrees with the taxonomy.
    assert!(matches!(
        parse_plan_file_name(&format!("{fp:016x}-b1-greedy-size.plan")),
        Err(ParseRequestError::Malformed(_))
    ));
    assert!(matches!(
        parse_plan_file_name(&format!("{fp:016x}-b1-belady@natural.plan")),
        Err(ParseRequestError::UnknownStrategy(_))
    ));
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn quantized_names_warm_start_and_unknown_dtype_keys_gate_silently() {
    // The dtype dimension joins the name grammar as `~<key>` after the
    // order; f32 renders no segment at all (the pre-redesign test above
    // pins that byte-identity). Known quantized classes load under any
    // request of the same order — they plan the same lifetimes, just
    // narrower — and an unknown key (a newer build's size class sharing
    // the directory) gates silently in its own counter, never suspect.
    let dir = scratch_dir("dtype");
    let recs = UsageRecords::from_graph(&models::blazeface());
    let fp = serialize::records_fingerprint(&recs);
    let cache = PlanCache::new();
    for (dtype, key) in [(Dtype::I8, "i8"), (Dtype::F16, "f16")] {
        let req = PlanRequest::new().with_dtype(dtype).with_batch(2);
        let plan = cache.get_or_plan(&recs, &req).unwrap();
        // Spell the quantized name out so a Display drift breaks loudly.
        let name = plan_file_name(fp, &req);
        assert_eq!(name, format!("{fp:016x}-b2-greedy-size@natural~{key}.plan"));
        let text = offset_plan_to_string(&plan, &recs.scaled_for(2, dtype), &req);
        std::fs::write(dir.join(name), text).unwrap();
    }
    // A dtype key this build does not know: skipped at the name parse,
    // before the file is ever read, so the content is irrelevant.
    std::fs::write(
        dir.join(format!("{fp:016x}-b1-greedy-size@natural~i4.plan")),
        "a newer build's plan",
    )
    .unwrap();

    let svc = PlanService::new();
    let report = svc.warm_start(&dir, &recs, &svc.request()).unwrap();
    assert_eq!(report.loaded, 2, "{report:?}");
    assert_eq!(report.skipped_stale_dtype, 1, "{report:?}");
    assert_eq!(report.skipped(), 0, "an unknown size class is never suspect");
    // Re-planning the warm-started quantized requests costs nothing.
    for dtype in [Dtype::I8, Dtype::F16] {
        svc.plan(&recs, &svc.request().with_dtype(dtype).with_batch(2)).unwrap();
    }
    assert_eq!(
        svc.stats().cache_misses,
        0,
        "quantized plans must warm-start without any planner invocation"
    );
    // The parse layer names the unknown key in its typed error.
    assert!(matches!(
        parse_plan_file_name(&format!("{fp:016x}-b1-greedy-size@natural~i4.plan")),
        Err(ParseRequestError::UnknownDtype(key)) if key == "i4"
    ));
    std::fs::remove_dir_all(&dir).unwrap();
}
