//! Differential quantization tests: the i8/f16 kernel family in
//! `exec::ops::quant` against the straight-loop `f32` references in
//! `exec::ops::scalar`, over the same randomized geometries as
//! `kernel_diff.rs`.
//!
//! The budget here is *one quantization step*, not an ulp: every
//! quantized kernel round-trips its activations through the dtype's grid
//! ([`quant::round_trip`]) and runs the vectorized `f32` kernel on the
//! dequantized values, so the only admissible divergence from the scalar
//! oracle — run on the *same* round-tripped operands — is the output's
//! own re-quantization. Both sides are therefore compared on the grid of
//! the wrapper's returned [`QParams`]: the oracle's raw output is
//! re-quantized under those exact parameters, and each element must land
//! on the same grid point or, when the raw value straddles a
//! round-to-nearest boundary and the families' 1-ulp divergence tips it
//! the other way, the adjacent one. That is `quant::step(dtype, qp, raw)`
//! exactly; the 1% headroom only absorbs the `f32` arithmetic of the
//! comparison itself.
//!
//! `Dtype::F32` requests take the identity path: the wrappers must return
//! [`QParams::IDENTITY`] and match the oracle within the 1-ulp budget of
//! `kernel_diff.rs` — quantization must cost f32 callers nothing.
//!
//! Property tests use the same hand-rolled SplitMix64 generator as
//! `kernel_diff.rs`; every failure prints its seed, dtype, and geometry.

use tensorarena::exec::ops::quant::{self, QParams};
use tensorarena::exec::ops::{scalar, Geom};
use tensorarena::graph::{Activation, Padding};
use tensorarena::planner::Dtype;
use tensorarena::rng::SplitMix64;

/// The quantized size classes under differential test. `Dtype::F32` is
/// covered separately by the identity-path test.
const QUANTIZED: [Dtype; 2] = [Dtype::I8, Dtype::F16];

/// Map f32 bits onto a monotone integer line, so ulp distance is integer
/// distance (same encoding as `kernel_diff.rs`).
fn ordered(x: f32) -> i64 {
    let b = x.to_bits();
    (if b & 0x8000_0000 != 0 { !b } else { b | 0x8000_0000 }) as i64
}

fn ulp_dist(a: f32, b: f32) -> u64 {
    assert!(!a.is_nan() && !b.is_nan(), "NaN in kernel output: {a} vs {b}");
    (ordered(a) - ordered(b)).unsigned_abs()
}

fn assert_ulp(got: &[f32], want: &[f32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length mismatch");
    for (i, (&g, &w)) in got.iter().zip(want.iter()).enumerate() {
        let d = ulp_dist(g, w);
        assert!(d <= 1, "{ctx}: elem {i}: quant-f32 {g} vs scalar {w} ({d} ulp)");
    }
}

/// Re-quantize `raw` under `qp` — the grid the wrapper's output lives on.
fn on_grid(dtype: Dtype, qp: QParams, raw: &[f32]) -> Vec<f32> {
    let mut packed = vec![0f32; quant::packed_words(dtype, raw.len())];
    quant::quantize_into(dtype, qp, raw, &mut packed);
    let mut grid = vec![0f32; raw.len()];
    quant::dequantize_from(dtype, qp, &packed, &mut grid);
    grid
}

/// Assert every element of `got` is within one quantization step of the
/// oracle's raw output re-quantized under the wrapper's own parameters.
fn assert_step(dtype: Dtype, qp: QParams, got: &[f32], oracle_raw: &[f32], ctx: &str) {
    assert_eq!(got.len(), oracle_raw.len(), "{ctx}: length mismatch");
    let grid = on_grid(dtype, qp, oracle_raw);
    for (i, (&g, (&o, &raw))) in got.iter().zip(grid.iter().zip(oracle_raw.iter())).enumerate() {
        assert!(!g.is_nan() && !o.is_nan(), "{ctx}: elem {i}: NaN ({g} vs {o})");
        let budget = quant::step(dtype, qp, raw) * 1.01;
        let err = (g - o).abs();
        assert!(
            err <= budget,
            "{ctx}: elem {i}: quantized {g} vs oracle-on-grid {o} (raw {raw}): \
             err {err} > step budget {budget}"
        );
    }
}

fn pick_act(rng: &mut SplitMix64) -> Activation {
    match rng.next_below(3) {
        0 => Activation::None,
        1 => Activation::Relu,
        _ => Activation::Relu6,
    }
}

/// Random conv/pool geometry (same sweep as `kernel_diff.rs`): dims,
/// kernel, stride, dilation, padding. `dilated` enables dilation > 1.
fn pick_geom(rng: &mut SplitMix64, dilated: bool) -> Geom {
    loop {
        let kh = rng.next_range(1, 4);
        let kw = rng.next_range(1, 4);
        let sh = rng.next_range(1, 3);
        let sw = rng.next_range(1, 3);
        let dh = if dilated { rng.next_range(1, 3) } else { 1 };
        let dw = if dilated { rng.next_range(1, 3) } else { 1 };
        let h = rng.next_range(3, 11);
        let w = rng.next_range(3, 11);
        let (eff_kh, eff_kw) = ((kh - 1) * dh + 1, (kw - 1) * dw + 1);
        let padding = if rng.next_below(2) == 0 { Padding::Same } else { Padding::Valid };
        let (oh, ow) = match padding {
            Padding::Same => (h.div_ceil(sh), w.div_ceil(sw)),
            Padding::Valid => {
                if h < eff_kh || w < eff_kw {
                    continue; // kernel doesn't fit; redraw
                }
                ((h - eff_kh) / sh + 1, (w - eff_kw) / sw + 1)
            }
        };
        return Geom::new(h, w, oh, ow, (kh, kw), (sh, sw), (dh, dw), padding);
    }
}

/// Signed fill in [-1, 1): exercises the i8 affine zero point away from
/// the range edge and gives ReLU clamps real work.
fn fill(rng: &mut SplitMix64, n: usize) -> Vec<f32> {
    let mut v = vec![0f32; n];
    rng.fill_f32(&mut v, 1.0);
    v
}

/// The wrapper's input protocol, replayed for the oracle: round-trip the
/// activation through the dtype's grid (weights and bias stay f32).
fn quantized_input(dtype: Dtype, x: &[f32]) -> Vec<f32> {
    let mut xq = x.to_vec();
    quant::round_trip(dtype, &mut xq);
    xq
}

#[test]
fn quant_conv2d_stays_within_one_step_of_the_scalar_oracle() {
    for seed in 0..60u64 {
        let mut rng = SplitMix64::new(seed);
        let g = pick_geom(&mut rng, true);
        let ic = rng.next_range(1, 8);
        let oc = rng.next_range(1, 12);
        let act = pick_act(&mut rng);
        let x = fill(&mut rng, g.h * g.w * ic);
        let w = fill(&mut rng, g.kh * g.kw * ic * oc);
        let b = fill(&mut rng, oc);
        for dtype in QUANTIZED {
            let mut got = vec![0f32; g.oh * g.ow * oc];
            let qp = quant::conv2d(&x, &w, &b, &mut got, ic, oc, &g, act, dtype);
            let xq = quantized_input(dtype, &x);
            let mut oracle = vec![0f32; got.len()];
            scalar::conv2d(&xq, &w, &b, &mut oracle, ic, oc, &g, act);
            let ctx = format!(
                "conv2d seed {seed} {dtype}: {}x{}x{ic} -> {}x{}x{oc}, k{}x{} s{}x{} d{}x{}",
                g.h, g.w, g.oh, g.ow, g.kh, g.kw, g.sh, g.sw, g.dh, g.dw
            );
            assert_step(dtype, qp, &got, &oracle, &ctx);
        }
    }
}

#[test]
fn quant_dwconv2d_stays_within_one_step_of_the_scalar_oracle() {
    for seed in 0..60u64 {
        let mut rng = SplitMix64::new(0x1000 + seed);
        let g = pick_geom(&mut rng, true);
        let c = rng.next_range(1, 12);
        let act = pick_act(&mut rng);
        let x = fill(&mut rng, g.h * g.w * c);
        let w = fill(&mut rng, g.kh * g.kw * c);
        let b = fill(&mut rng, c);
        for dtype in QUANTIZED {
            let mut got = vec![0f32; g.oh * g.ow * c];
            let qp = quant::dwconv2d(&x, &w, &b, &mut got, c, &g, act, dtype);
            let xq = quantized_input(dtype, &x);
            let mut oracle = vec![0f32; got.len()];
            scalar::dwconv2d(&xq, &w, &b, &mut oracle, c, &g, act);
            let ctx = format!(
                "dwconv2d seed {seed} {dtype}: {}x{}x{c}, k{}x{} s{}x{} d{}x{}",
                g.h, g.w, g.kh, g.kw, g.sh, g.sw, g.dh, g.dw
            );
            assert_step(dtype, qp, &got, &oracle, &ctx);
        }
    }
}

#[test]
fn quant_pools_stay_within_one_step_of_the_scalar_oracle() {
    for seed in 0..60u64 {
        let mut rng = SplitMix64::new(0x2000 + seed);
        let g = pick_geom(&mut rng, false);
        let c = rng.next_range(1, 12);
        let x = fill(&mut rng, g.h * g.w * c);
        for dtype in QUANTIZED {
            let xq = quantized_input(dtype, &x);
            let mut got = vec![0f32; g.oh * g.ow * c];
            let mut oracle = vec![0f32; got.len()];

            let qp = quant::maxpool2d(&x, &mut got, c, &g, dtype);
            scalar::maxpool2d(&xq, &mut oracle, c, &g);
            assert_step(dtype, qp, &got, &oracle, &format!("maxpool2d seed {seed} {dtype}"));

            let qp = quant::avgpool2d(&x, &mut got, c, &g, dtype);
            scalar::avgpool2d(&xq, &mut oracle, c, &g);
            assert_step(dtype, qp, &got, &oracle, &format!("avgpool2d seed {seed} {dtype}"));

            let hw = g.h * g.w;
            let mut got_g = vec![0f32; c];
            let mut oracle_g = vec![0f32; c];
            let qp = quant::global_avg_pool(&x, &mut got_g, hw, c, dtype);
            scalar::global_avg_pool(&xq, &mut oracle_g, hw, c);
            assert_step(dtype, qp, &got_g, &oracle_g, &format!("gap seed {seed} {dtype}"));
        }
    }
}

#[test]
fn quant_fully_connected_stays_within_one_step_of_the_scalar_oracle() {
    for seed in 0..60u64 {
        let mut rng = SplitMix64::new(0x3000 + seed);
        let ind = rng.next_range(1, 48);
        let outd = rng.next_range(1, 48);
        let act = pick_act(&mut rng);
        let x = fill(&mut rng, ind);
        let w = fill(&mut rng, ind * outd);
        let b = fill(&mut rng, outd);
        for dtype in QUANTIZED {
            let mut got = vec![0f32; outd];
            let qp = quant::fully_connected(&x, &w, &b, &mut got, ind, outd, act, dtype);
            let xq = quantized_input(dtype, &x);
            let mut oracle = vec![0f32; outd];
            scalar::fully_connected(&xq, &w, &b, &mut oracle, ind, outd, act);
            let ctx = format!("fc seed {seed} {dtype}: {ind}->{outd}");
            assert_step(dtype, qp, &got, &oracle, &ctx);
        }
    }
}

#[test]
fn quant_elementwise_stays_within_one_step_of_the_scalar_oracle() {
    for seed in 0..40u64 {
        let mut rng = SplitMix64::new(0x4000 + seed);
        let n = rng.next_range(1, 200);
        let a = fill(&mut rng, n);
        let b = fill(&mut rng, n);
        let act = pick_act(&mut rng);
        let max = if seed % 2 == 0 { None } else { Some(6.0) };
        for dtype in QUANTIZED {
            let aq = quantized_input(dtype, &a);
            let bq = quantized_input(dtype, &b);
            let mut got = vec![0f32; n];
            let mut oracle = vec![0f32; n];

            let qp = quant::add(&a, &b, &mut got, act, dtype);
            scalar::add(&aq, &bq, &mut oracle, act);
            assert_step(dtype, qp, &got, &oracle, &format!("add seed {seed} {dtype}"));

            let qp = quant::mul(&a, &b, &mut got, dtype);
            scalar::mul(&aq, &bq, &mut oracle);
            assert_step(dtype, qp, &got, &oracle, &format!("mul seed {seed} {dtype}"));

            let qp = quant::relu(&a, &mut got, max, dtype);
            scalar::relu(&aq, &mut oracle, max);
            assert_step(dtype, qp, &got, &oracle, &format!("relu seed {seed} {dtype}"));

            let qp = quant::sigmoid(&a, &mut got, dtype);
            scalar::sigmoid(&aq, &mut oracle);
            assert_step(dtype, qp, &got, &oracle, &format!("sigmoid seed {seed} {dtype}"));
        }
    }
}

#[test]
fn round_trip_error_is_bounded_by_one_quantization_step() {
    for seed in 0..60u64 {
        let mut rng = SplitMix64::new(0x5000 + seed);
        let n = rng.next_range(1, 300);
        let scale = [0.01f32, 1.0, 100.0][rng.next_below(3)];
        let mut x = vec![0f32; n];
        rng.fill_f32(&mut x, scale);
        for dtype in QUANTIZED {
            let mut q = x.clone();
            let qp = quant::round_trip(dtype, &mut q);
            for (i, (&orig, &rt)) in x.iter().zip(q.iter()).enumerate() {
                let budget = quant::step(dtype, qp, orig) * 1.01;
                let err = (rt - orig).abs();
                assert!(
                    err <= budget,
                    "round_trip seed {seed} {dtype} elem {i}: {orig} -> {rt}, \
                     err {err} > step budget {budget}"
                );
            }
        }
        // f16 narrowing is idempotent: a second trip is bit-exact (i8 is
        // not — its grid is re-derived from the round-tripped range).
        let mut once = x.clone();
        quant::round_trip(Dtype::F16, &mut once);
        let mut twice = once.clone();
        quant::round_trip(Dtype::F16, &mut twice);
        let same = once.iter().zip(twice.iter()).all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "f16 round_trip not idempotent at seed {seed}");
    }
}

#[test]
fn quantized_kernels_are_deterministic_across_invocations() {
    for dtype in QUANTIZED {
        let mut rng = SplitMix64::new(0x6000);
        let g = pick_geom(&mut rng, true);
        let ic = rng.next_range(1, 8);
        let oc = rng.next_range(1, 12);
        let x = fill(&mut rng, g.h * g.w * ic);
        let w = fill(&mut rng, g.kh * g.kw * ic * oc);
        let b = fill(&mut rng, oc);
        let mut out1 = vec![0f32; g.oh * g.ow * oc];
        let mut out2 = vec![0f32; g.oh * g.ow * oc];
        let qp1 = quant::conv2d(&x, &w, &b, &mut out1, ic, oc, &g, Activation::Relu, dtype);
        let qp2 = quant::conv2d(&x, &w, &b, &mut out2, ic, oc, &g, Activation::Relu, dtype);
        assert_eq!(qp1, qp2, "{dtype}: conv2d QParams drifted between invocations");
        let same = out1.iter().zip(out2.iter()).all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "{dtype}: conv2d output not bit-identical between invocations");

        let n = 64;
        let a = fill(&mut rng, n);
        let c = fill(&mut rng, n);
        let mut e1 = vec![0f32; n];
        let mut e2 = vec![0f32; n];
        let qa = quant::add(&a, &c, &mut e1, Activation::None, dtype);
        let qb = quant::add(&a, &c, &mut e2, Activation::None, dtype);
        assert_eq!(qa, qb, "{dtype}: add QParams drifted between invocations");
        let same = e1.iter().zip(e2.iter()).all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "{dtype}: add output not bit-identical between invocations");
    }
}

#[test]
fn f32_requests_pass_through_the_quantized_family_unchanged() {
    for seed in 0..40u64 {
        let mut rng = SplitMix64::new(0x7000 + seed);
        let g = pick_geom(&mut rng, true);
        let ic = rng.next_range(1, 8);
        let oc = rng.next_range(1, 12);
        let act = pick_act(&mut rng);
        let x = fill(&mut rng, g.h * g.w * ic);
        let w = fill(&mut rng, g.kh * g.kw * ic * oc);
        let b = fill(&mut rng, oc);
        let mut got = vec![0f32; g.oh * g.ow * oc];
        let mut oracle = vec![0f32; got.len()];
        let qp = quant::conv2d(&x, &w, &b, &mut got, ic, oc, &g, act, Dtype::F32);
        scalar::conv2d(&x, &w, &b, &mut oracle, ic, oc, &g, act);
        assert_eq!(qp, QParams::IDENTITY, "f32 conv2d must take the identity path");
        assert_ulp(&got, &oracle, &format!("f32 conv2d seed {seed}"));

        let n = rng.next_range(1, 100);
        let a = fill(&mut rng, n);
        let c = fill(&mut rng, n);
        let mut e_got = vec![0f32; n];
        let mut e_oracle = vec![0f32; n];
        let qp = quant::add(&a, &c, &mut e_got, act, Dtype::F32);
        scalar::add(&a, &c, &mut e_oracle, act);
        assert_eq!(qp, QParams::IDENTITY, "f32 add must take the identity path");
        assert_ulp(&e_got, &e_oracle, &format!("f32 add seed {seed}"));

        let qp = quant::sigmoid(&a, &mut e_got, Dtype::F32);
        scalar::sigmoid(&a, &mut e_oracle);
        assert_eq!(qp, QParams::IDENTITY, "f32 sigmoid must take the identity path");
        assert_ulp(&e_got, &e_oracle, &format!("f32 sigmoid seed {seed}"));
    }
}
