//! Integration tests for the PlanService stack: the batch-aware plan
//! cache, batch scaling, the spill/load path, and the memory-budget query.
//!
//! Property tests use the same hand-rolled SplitMix64 generator as
//! `planner_properties.rs` (the offline registry has no proptest); every
//! failure prints its seed.

use std::sync::Arc;
use tensorarena::models;
use tensorarena::planner::{registry, OffsetPlanner, PlanCache, PlanRequest, PlanService};
use tensorarena::records::UsageRecords;
use tensorarena::rng::SplitMix64;

/// Random usage records resembling real nets (64-byte-aligned sizes).
fn random_records(seed: u64) -> UsageRecords {
    let mut rng = SplitMix64::new(seed);
    let n = rng.next_range(1, 60);
    let mut triples = Vec::with_capacity(n);
    let mut op = 0usize;
    for _ in 0..n {
        let span = match rng.next_below(10) {
            0..=6 => 1,
            7 | 8 => rng.next_range(2, 6),
            _ => rng.next_range(6, 12),
        };
        let size = 64 * rng.next_range(1, 256);
        triples.push((op, op + span, size));
        if rng.next_below(3) != 0 {
            op += 1;
        }
    }
    UsageRecords::from_triples(&triples)
}

#[test]
fn cache_hit_plans_are_byte_identical_to_fresh_plans_for_every_strategy() {
    use tensorarena::planner::serialize::offset_plan_to_string;
    for seed in 0..40u64 {
        let recs = random_records(seed);
        let cache = PlanCache::new();
        for key in registry::OFFSET_KEYS {
            let planner = registry::offset_strategy(key).unwrap();
            let fresh = planner.plan(&recs);
            let req = PlanRequest::new().with_strategy(key).unwrap();
            let warm = cache.get_or_plan(&recs, &req).unwrap();
            let hit = cache.get_or_plan(&recs, &req).unwrap();
            assert!(Arc::ptr_eq(&warm, &hit), "seed {seed}, {key}: hit re-planned");
            assert_eq!(*hit, fresh, "seed {seed}, {key}: cached plan diverged");
            // Byte-identical through the wire format too.
            assert_eq!(
                offset_plan_to_string(&hit, &recs, &req),
                offset_plan_to_string(&fresh, &recs, &req),
                "seed {seed}, {key}: serialized plans differ"
            );
        }
        assert_eq!(cache.misses(), registry::OFFSET_KEYS.len() as u64);
        assert_eq!(cache.hits(), registry::OFFSET_KEYS.len() as u64);
    }
}

#[test]
fn scaled_plans_validate_against_scaled_records_for_every_strategy() {
    for seed in 0..40u64 {
        let recs = random_records(seed);
        let cache = PlanCache::new();
        for key in registry::OFFSET_KEYS {
            for batch in [2usize, 3, 8] {
                let req = PlanRequest::new().with_strategy(key).unwrap().with_batch(batch);
                let plan = cache.get_or_plan(&recs, &req).unwrap();
                let scaled = recs.scaled(batch);
                plan.validate(&scaled)
                    .unwrap_or_else(|e| panic!("seed {seed}, {key}, batch {batch}: {e}"));
                assert!(
                    plan.total >= batch * recs.profiles().offset_lower_bound(),
                    "seed {seed}, {key}, batch {batch}: below scaled lower bound"
                );
                assert!(
                    plan.total <= scaled.naive_total(),
                    "seed {seed}, {key}, batch {batch}: worse than naive"
                );
            }
        }
    }
}

#[test]
fn fingerprint_isolates_different_models_in_one_cache() {
    let a = random_records(1);
    let b = random_records(2);
    let cache = PlanCache::new();
    let pa = cache.get_or_plan(&a, &PlanRequest::new()).unwrap();
    let pb = cache.get_or_plan(&b, &PlanRequest::new()).unwrap();
    assert_eq!(cache.misses(), 2, "distinct record sets shared a slot");
    pa.validate(&a).unwrap();
    pb.validate(&b).unwrap();
}

#[test]
fn spill_load_roundtrips_across_caches_at_batch() {
    let recs = random_records(7);
    let warm = PlanCache::new();
    for batch in [1usize, 4] {
        let req = PlanRequest::new().with_batch(batch);
        let text = warm.spill(&recs, &req).unwrap();
        let cold = PlanCache::new();
        let loaded = cold.load(&text, &recs, &req).unwrap();
        assert_eq!(*loaded, *warm.get_or_plan(&recs, &req).unwrap());
        assert_eq!(cold.misses(), 0, "load should seed, not plan");
    }
}

#[test]
fn max_servable_batch_fits_budget_on_mobilenet_v1() {
    // Acceptance: the largest batch whose *planned* footprint fits a byte
    // budget — planned, not naive, which is the whole point of planning.
    let recs = UsageRecords::from_graph(&models::mobilenet_v1());
    let cache = PlanCache::new();
    let req = PlanRequest::new(); // greedy-size @ natural
    let t1 = cache.get_or_plan(&recs, &req).unwrap().total;
    let budget = t1 * 3 + t1 / 2; // ~3.5x the batch-1 arena

    let b = cache.max_servable_batch(&recs, &req, budget).unwrap();
    assert!(b >= 3, "3.5x budget only fits batch {b}");
    // Maximality: b fits, b+1 does not.
    assert!(cache.get_or_plan(&recs, &req.with_batch(b)).unwrap().total <= budget);
    assert!(cache.get_or_plan(&recs, &req.with_batch(b + 1)).unwrap().total > budget);
    // The naive layout could not serve batch b in this budget (MobileNet's
    // naive footprint is >2x its planned arena).
    assert!(
        recs.naive_total() * b > budget,
        "naive would also fit batch {b} — budget not planner-bound"
    );
    // Degenerate budgets.
    assert_eq!(cache.max_servable_batch(&recs, &req, 0).unwrap(), 0);
    assert_eq!(cache.max_servable_batch(&recs, &req, t1 - 1).unwrap(), 0);
}

#[test]
fn service_default_strategy_flows_through_max_servable_batch() {
    let svc = PlanService::new();
    let recs = UsageRecords::from_graph(&models::blazeface());
    let t1 = svc.plan(&recs, &svc.request()).unwrap().total;
    let b = svc.max_servable_batch(&recs, &svc.request(), 8 * t1).unwrap();
    assert!(b >= 8, "8x budget only fits batch {b}");
    let st = svc.stats();
    assert!(st.cache_misses >= 1);
}
