//! Property tests over the planners (hand-rolled generator — the offline
//! registry has no proptest; SplitMix64 seeds make every case reproducible:
//! a failure prints its seed).
//!
//! Invariants checked on hundreds of random graphs:
//! * every strategy's plan is feasible (independent O(n²) validator);
//! * lower bound ≤ plan ≤ naive, for both approaches;
//! * Greedy by Size never grows an object (§4.3);
//! * Greedy by Size Improved ≤ Greedy by Size (§4.4: "better or the same");
//! * offset Greedy by Size ≤ every shared-objects strategy converted to
//!   offsets (§5: shared solutions embed into offset solutions);
//! * plans are deterministic;
//! * the multi-pass dynamic planner stays feasible.

use tensorarena::planner::{table1_strategies, table2_strategies};
use tensorarena::records::UsageRecords;
use tensorarena::rng::SplitMix64;

/// Random usage records resembling real nets: a chain with skips, varied
/// sizes, occasional same-size runs (to exercise GSI stages).
fn random_records(seed: u64) -> UsageRecords {
    let mut rng = SplitMix64::new(seed);
    let n = rng.next_range(1, 80);
    let mut triples = Vec::with_capacity(n);
    let mut op = 0usize;
    for i in 0..n {
        let span = match rng.next_below(10) {
            0..=6 => 1,
            7 | 8 => rng.next_range(2, 6),
            _ => rng.next_range(6, 12),
        };
        let size = match rng.next_below(4) {
            0 => 64, // repeated size
            1 => 64 * rng.next_range(1, 4),
            2 => 64 * rng.next_range(1, 64),
            _ => 64 * rng.next_range(32, 512),
        };
        triples.push((op, op + span, size));
        if rng.next_below(3) != 0 {
            op += 1;
        }
        let _ = i;
    }
    UsageRecords::from_triples(&triples)
}

#[test]
fn all_shared_strategies_feasible_and_bounded() {
    for seed in 0..300u64 {
        let recs = random_records(seed);
        let p = recs.profiles();
        let lb = p.shared_objects_lower_bound();
        let naive = recs.naive_total();
        for strat in table1_strategies() {
            let plan = strat.plan(&recs);
            plan.validate(&recs)
                .unwrap_or_else(|e| panic!("seed {seed}, {}: {e}", strat.name()));
            assert!(
                plan.total_size() >= lb,
                "seed {seed}, {}: {} < lower bound {lb}",
                strat.name(),
                plan.total_size()
            );
            assert!(
                plan.total_size() <= naive,
                "seed {seed}, {}: {} > naive {naive}",
                strat.name(),
                plan.total_size()
            );
        }
    }
}

#[test]
fn all_offset_strategies_feasible_and_bounded() {
    for seed in 0..300u64 {
        let recs = random_records(seed);
        let p = recs.profiles();
        let lb = p.offset_lower_bound();
        let naive = recs.naive_total();
        for strat in table2_strategies() {
            let plan = strat.plan(&recs);
            plan.validate(&recs)
                .unwrap_or_else(|e| panic!("seed {seed}, {}: {e}", strat.name()));
            assert!(plan.total_size() >= lb, "seed {seed}, {}", strat.name());
            assert!(plan.total_size() <= naive, "seed {seed}, {}", strat.name());
        }
    }
}

#[test]
fn greedy_by_size_improved_never_loses_to_greedy_by_size() {
    use tensorarena::planner::shared::{GreedyBySize, GreedyBySizeImproved};
    use tensorarena::planner::SharedObjectPlanner;
    let mut improved_strictly = 0;
    for seed in 0..500u64 {
        let recs = random_records(seed);
        let gsi = GreedyBySizeImproved.plan(&recs).total_size();
        let gs = GreedyBySize.plan(&recs).total_size();
        assert!(
            gsi <= gs,
            "seed {seed}: GSI {gsi} > GS {gs} — §4.4 claims better-or-equal"
        );
        if gsi < gs {
            improved_strictly += 1;
        }
    }
    // The improvement must actually fire sometimes, or the stages are dead
    // code.
    assert!(improved_strictly > 0, "GSI never improved on GS in 500 graphs");
}

#[test]
fn shared_plans_embed_into_offset_plans() {
    // §5: any Shared-Objects solution converts to an equal-size Offset
    // solution (always checked); the offset *heuristic* usually — but not
    // provably — beats converted shared plans, so that part is aggregate.
    use tensorarena::planner::offset::GreedyBySize as OffGS;
    use tensorarena::planner::OffsetPlanner;
    let mut off_wins = 0usize;
    let mut comparisons = 0usize;
    for seed in 0..200u64 {
        let recs = random_records(seed);
        let off = OffGS.plan(&recs);
        for strat in table1_strategies() {
            let shared = strat.plan(&recs);
            let converted = shared.to_offset_plan(&recs);
            converted
                .validate(&recs)
                .unwrap_or_else(|e| panic!("seed {seed}, {} converted: {e}", strat.name()));
            assert_eq!(converted.total_size(), shared.total_size());
            comparisons += 1;
            if off.total_size() <= converted.total_size() {
                off_wins += 1;
            }
        }
    }
    assert!(
        off_wins * 100 >= comparisons * 95,
        "offset Greedy by Size beat converted shared plans only {off_wins}/{comparisons} times"
    );
}

#[test]
fn plans_are_deterministic() {
    for seed in [3u64, 77, 1234] {
        let recs = random_records(seed);
        for strat in table1_strategies() {
            assert_eq!(strat.plan(&recs), strat.plan(&recs), "{}", strat.name());
        }
        for strat in table2_strategies() {
            assert_eq!(strat.plan(&recs), strat.plan(&recs), "{}", strat.name());
        }
    }
}

#[test]
fn multi_pass_dynamic_planner_feasible_on_random_resolution_orders() {
    use tensorarena::planner::dynamic::{DynamicRecord, DynamicRecords, MultiPassPlanner};
    for seed in 0..100u64 {
        let mut rng = SplitMix64::new(seed ^ 0xD15EA5E);
        let recs = random_records(seed);
        if recs.is_empty() {
            continue;
        }
        let dynamic = DynamicRecords::new(
            recs.records
                .iter()
                .map(|r| DynamicRecord {
                    record: *r,
                    known_at: if rng.next_below(3) == 0 {
                        rng.next_below(r.first_op + 1)
                    } else {
                        0
                    },
                })
                .collect(),
            recs.num_ops,
        );
        let mp = MultiPassPlanner.plan(&dynamic);
        assert!(mp.is_complete(), "seed {seed}: full plan left a record unplaced");
        mp.offset_plan()
            .unwrap()
            .validate(&recs)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        // growth is monotone across passes and peaks at the arena total
        for w in mp.growth.windows(2) {
            assert!(w[0] <= w[1], "seed {seed}: arena shrank between passes");
        }
        assert_eq!(mp.peak, *mp.growth.last().unwrap(), "seed {seed}");
        // the overhead ratio is defined for every workload (1.0 when the
        // oracle arena is empty)
        assert!(MultiPassPlanner.overhead_vs_oracle(&dynamic).is_finite());
    }
}

#[test]
fn degenerate_records() {
    // single tensor, zero-size tensor, all-overlapping, all-disjoint
    let cases: Vec<Vec<(usize, usize, usize)>> = vec![
        vec![(0, 0, 64)],
        vec![(0, 3, 0), (1, 2, 64)],
        vec![(0, 9, 64), (0, 9, 128), (0, 9, 192)],
        (0..20).map(|i| (2 * i, 2 * i + 1, 64)).collect(),
    ];
    for (ci, triples) in cases.iter().enumerate() {
        let recs = UsageRecords::from_triples(triples);
        for strat in table1_strategies() {
            let plan = strat.plan(&recs);
            plan.validate(&recs)
                .unwrap_or_else(|e| panic!("case {ci} {}: {e}", strat.name()));
        }
        for strat in table2_strategies() {
            let plan = strat.plan(&recs);
            plan.validate(&recs)
                .unwrap_or_else(|e| panic!("case {ci} {}: {e}", strat.name()));
        }
    }
}
