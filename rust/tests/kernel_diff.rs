//! Differential kernel tests: the vectorized kernel family in
//! `exec::ops` against the retained straight-loop references in
//! `exec::ops::scalar`, over randomized geometries (stride / dilation /
//! padding / channel sweeps).
//!
//! Both families accumulate each output element bias-first, then kernel
//! taps ascending in `(ky, kx, c)`, so the tolerance here is *1 ulp*, not
//! an epsilon: the only admissible divergences are sign-of-zero artifacts
//! (the reference's `x == 0.0` skip). A real reassociation shows up as a
//! many-ulp gap and fails loudly with its seed.
//!
//! Property tests use the same hand-rolled SplitMix64 generator as
//! `planner_properties.rs` (the offline registry has no proptest); every
//! failure prints its seed and geometry.

use tensorarena::exec::ops::{self, scalar, Geom};
use tensorarena::graph::{Activation, Padding};
use tensorarena::rng::SplitMix64;

/// Map f32 bits onto a monotone integer line, so ulp distance is integer
/// distance. `-0.0` and `+0.0` land 1 apart, which the 1-ulp budget admits.
fn ordered(x: f32) -> i64 {
    let b = x.to_bits();
    (if b & 0x8000_0000 != 0 { !b } else { b | 0x8000_0000 }) as i64
}

fn ulp_dist(a: f32, b: f32) -> u64 {
    assert!(!a.is_nan() && !b.is_nan(), "NaN in kernel output: {a} vs {b}");
    (ordered(a) - ordered(b)).unsigned_abs()
}

fn assert_ulp(got: &[f32], want: &[f32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length mismatch");
    for (i, (&g, &w)) in got.iter().zip(want.iter()).enumerate() {
        let d = ulp_dist(g, w);
        assert!(d <= 1, "{ctx}: elem {i}: vectorized {g} vs scalar {w} ({d} ulp)");
    }
}

fn pick_act(rng: &mut SplitMix64) -> Activation {
    match rng.next_below(3) {
        0 => Activation::None,
        1 => Activation::Relu,
        _ => Activation::Relu6,
    }
}

/// Random conv/pool geometry: dims, kernel, stride, dilation, padding.
/// `dilated` enables dilation > 1 (pools don't dilate).
fn pick_geom(rng: &mut SplitMix64, dilated: bool) -> Geom {
    loop {
        let kh = rng.next_range(1, 4);
        let kw = rng.next_range(1, 4);
        let sh = rng.next_range(1, 3);
        let sw = rng.next_range(1, 3);
        let dh = if dilated { rng.next_range(1, 3) } else { 1 };
        let dw = if dilated { rng.next_range(1, 3) } else { 1 };
        let h = rng.next_range(3, 11);
        let w = rng.next_range(3, 11);
        let (eff_kh, eff_kw) = ((kh - 1) * dh + 1, (kw - 1) * dw + 1);
        let padding = if rng.next_below(2) == 0 { Padding::Same } else { Padding::Valid };
        let (oh, ow) = match padding {
            Padding::Same => (h.div_ceil(sh), w.div_ceil(sw)),
            Padding::Valid => {
                if h < eff_kh || w < eff_kw {
                    continue; // kernel doesn't fit; redraw
                }
                ((h - eff_kh) / sh + 1, (w - eff_kw) / sw + 1)
            }
        };
        return Geom::new(h, w, oh, ow, (kh, kw), (sh, sw), (dh, dw), padding);
    }
}

fn fill(rng: &mut SplitMix64, n: usize) -> Vec<f32> {
    let mut v = vec![0f32; n];
    rng.fill_f32(&mut v, 1.0);
    v
}

#[test]
fn conv2d_matches_scalar_across_random_geometries() {
    for seed in 0..120u64 {
        let mut rng = SplitMix64::new(seed);
        let g = pick_geom(&mut rng, true);
        let ic = rng.next_range(1, 10);
        let oc = rng.next_range(1, 20);
        let act = pick_act(&mut rng);
        let x = fill(&mut rng, g.h * g.w * ic);
        let w = fill(&mut rng, g.kh * g.kw * ic * oc);
        let b = fill(&mut rng, oc);
        let mut vec_out = vec![0f32; g.oh * g.ow * oc];
        let mut ref_out = vec![0f32; g.oh * g.ow * oc];
        ops::conv2d(&x, &w, &b, &mut vec_out, ic, oc, &g, act);
        scalar::conv2d(&x, &w, &b, &mut ref_out, ic, oc, &g, act);
        let ctx = format!(
            "conv2d seed {seed}: {}x{}x{ic} -> {}x{}x{oc}, k{}x{} s{}x{} d{}x{} p{},{}",
            g.h, g.w, g.oh, g.ow, g.kh, g.kw, g.sh, g.sw, g.dh, g.dw, g.ph, g.pw
        );
        assert_ulp(&vec_out, &ref_out, &ctx);
    }
}

#[test]
fn pointwise_conv_lowering_matches_scalar() {
    // The 1x1 stride-1 unpadded case lowers to the register-blocked
    // matmul — sweep it specifically, including ragged m/n tails.
    for seed in 0..60u64 {
        let mut rng = SplitMix64::new(0x1000 + seed);
        let h = rng.next_range(1, 9);
        let w = rng.next_range(1, 9);
        let g = Geom::new(h, w, h, w, (1, 1), (1, 1), (1, 1), Padding::Valid);
        let ic = rng.next_range(1, 24);
        let oc = rng.next_range(1, 24);
        let act = pick_act(&mut rng);
        let x = fill(&mut rng, h * w * ic);
        let wt = fill(&mut rng, ic * oc);
        let b = fill(&mut rng, oc);
        let mut vec_out = vec![0f32; h * w * oc];
        let mut ref_out = vec![0f32; h * w * oc];
        ops::conv2d(&x, &wt, &b, &mut vec_out, ic, oc, &g, act);
        scalar::conv2d(&x, &wt, &b, &mut ref_out, ic, oc, &g, act);
        assert_ulp(&vec_out, &ref_out, &format!("pointwise seed {seed}: {h}x{w} {ic}->{oc}"));
    }
}

#[test]
fn dwconv2d_matches_scalar_across_random_geometries() {
    for seed in 0..120u64 {
        let mut rng = SplitMix64::new(0x2000 + seed);
        let g = pick_geom(&mut rng, true);
        let c = rng.next_range(1, 16);
        let act = pick_act(&mut rng);
        let x = fill(&mut rng, g.h * g.w * c);
        let w = fill(&mut rng, g.kh * g.kw * c);
        let b = fill(&mut rng, c);
        let mut vec_out = vec![0f32; g.oh * g.ow * c];
        let mut ref_out = vec![0f32; g.oh * g.ow * c];
        ops::dwconv2d(&x, &w, &b, &mut vec_out, c, &g, act);
        scalar::dwconv2d(&x, &w, &b, &mut ref_out, c, &g, act);
        let ctx = format!(
            "dwconv2d seed {seed}: {}x{}x{c}, k{}x{} s{}x{} d{}x{}",
            g.h, g.w, g.kh, g.kw, g.sh, g.sw, g.dh, g.dw
        );
        assert_ulp(&vec_out, &ref_out, &ctx);
    }
}

#[test]
fn pools_match_scalar_across_random_geometries() {
    for seed in 0..120u64 {
        let mut rng = SplitMix64::new(0x3000 + seed);
        let g = pick_geom(&mut rng, false);
        let c = rng.next_range(1, 16);
        let x = fill(&mut rng, g.h * g.w * c);
        let mut vec_out = vec![0f32; g.oh * g.ow * c];
        let mut ref_out = vec![0f32; g.oh * g.ow * c];
        ops::maxpool2d(&x, &mut vec_out, c, &g);
        scalar::maxpool2d(&x, &mut ref_out, c, &g);
        assert_ulp(&vec_out, &ref_out, &format!("maxpool2d seed {seed}"));
        ops::avgpool2d(&x, &mut vec_out, c, &g);
        scalar::avgpool2d(&x, &mut ref_out, c, &g);
        assert_ulp(&vec_out, &ref_out, &format!("avgpool2d seed {seed}"));
    }
}

#[test]
fn fully_connected_matches_scalar_across_random_shapes() {
    for seed in 0..120u64 {
        let mut rng = SplitMix64::new(0x4000 + seed);
        let ind = rng.next_range(1, 48);
        let outd = rng.next_range(1, 48);
        let act = pick_act(&mut rng);
        let x = fill(&mut rng, ind);
        let w = fill(&mut rng, ind * outd);
        let b = fill(&mut rng, outd);
        let mut vec_out = vec![0f32; outd];
        let mut ref_out = vec![0f32; outd];
        ops::fully_connected(&x, &w, &b, &mut vec_out, ind, outd, act);
        scalar::fully_connected(&x, &w, &b, &mut ref_out, ind, outd, act);
        assert_ulp(&vec_out, &ref_out, &format!("fc seed {seed}: {ind}->{outd}"));
    }
}

#[test]
fn elementwise_and_reductions_match_scalar() {
    for seed in 0..60u64 {
        let mut rng = SplitMix64::new(0x5000 + seed);
        let n = rng.next_range(1, 200);
        let a = fill(&mut rng, n);
        let b = fill(&mut rng, n);
        let act = pick_act(&mut rng);
        let mut vec_out = vec![0f32; n];
        let mut ref_out = vec![0f32; n];
        ops::add(&a, &b, &mut vec_out, act);
        scalar::add(&a, &b, &mut ref_out, act);
        assert_ulp(&vec_out, &ref_out, &format!("add seed {seed}"));
        ops::mul(&a, &b, &mut vec_out);
        scalar::mul(&a, &b, &mut ref_out);
        assert_ulp(&vec_out, &ref_out, &format!("mul seed {seed}"));
        ops::relu(&a, &mut vec_out, if seed % 2 == 0 { None } else { Some(6.0) });
        scalar::relu(&a, &mut ref_out, if seed % 2 == 0 { None } else { Some(6.0) });
        assert_ulp(&vec_out, &ref_out, &format!("relu seed {seed}"));
        ops::sigmoid(&a, &mut vec_out);
        scalar::sigmoid(&a, &mut ref_out);
        assert_ulp(&vec_out, &ref_out, &format!("sigmoid seed {seed}"));

        let hw = rng.next_range(1, 20);
        let c = rng.next_range(1, 16);
        let x = fill(&mut rng, hw * c);
        let mut vec_g = vec![0f32; c];
        let mut ref_g = vec![0f32; c];
        ops::global_avg_pool(&x, &mut vec_g, hw, c);
        scalar::global_avg_pool(&x, &mut ref_g, hw, c);
        assert_ulp(&vec_g, &ref_g, &format!("gap seed {seed}"));
    }
}

#[test]
fn matmul_bias_matches_a_straight_triple_loop() {
    for seed in 0..60u64 {
        let mut rng = SplitMix64::new(0x6000 + seed);
        // Cover full MRxNR tiles, ragged tails, and degenerate edges.
        let m = rng.next_range(1, 20);
        let k = rng.next_range(1, 20);
        let n = rng.next_range(1, 40);
        let a = fill(&mut rng, m * k);
        let w = fill(&mut rng, k * n);
        let b = fill(&mut rng, n);
        let mut got = vec![0f32; m * n];
        ops::matmul_bias(&a, k, &w, &b, &mut got, n, m, k, n);
        for r in 0..m {
            for c in 0..n {
                let mut acc = b[c];
                for kk in 0..k {
                    acc += a[r * k + kk] * w[kk * n + c];
                }
                let d = ulp_dist(got[r * n + c], acc);
                assert!(d <= 1, "matmul seed {seed} ({m}x{k}x{n}) at ({r},{c}): {d} ulp");
            }
        }
    }
}
