//! Tier-1 acceptance tests for dynamic-shape serving through the plan
//! cache (§7): repeated decode-step plans with an unchanged resolved-size
//! prefix must hit the cache with **zero planner invocations** (verified
//! by counter), wave-aware execution must not change the numbers, and
//! budget admission must resolve under the worst-wave multi-pass peak.

use std::sync::Arc;
use std::time::Duration;
use tensorarena::coordinator::engine::ExecutorEngine;
use tensorarena::coordinator::{BatchPolicy, Engine, ModelServer};
use tensorarena::models;
use tensorarena::planner::{
    DynamicMode, DynamicRecord, DynamicRecords, MultiPassPlanner, PlanRequest, PlanService,
};
use tensorarena::records::{UsageRecord, UsageRecords};
use tensorarena::rng::SplitMix64;

/// A synthetic decode workload: a chain whose tail sizes resolve one op
/// before their producer, sizes drawn deterministically from `seed`.
fn synth_decode(seed: u64, n_ops: usize, from_op: usize) -> DynamicRecords {
    let mut rng = SplitMix64::new(seed);
    let mut triples = Vec::new();
    for i in 0..n_ops {
        triples.push((i, (i + 1).min(n_ops - 1), 64 * rng.next_range(1, 64)));
    }
    DynamicRecords::decode_tail(&UsageRecords::from_triples(&triples), from_op)
}

#[test]
fn second_decode_pass_over_the_same_prefix_plans_nothing() {
    // The ISSUE's acceptance criterion, end to end at the service layer: a
    // decode loop touches every resolved prefix once; a second pass over
    // the same prefixes performs zero planner invocations.
    let svc = PlanService::shared();
    let dynamic = synth_decode(3, 48, 24);
    assert!(dynamic.num_dynamic() > 0);
    for step in 0..dynamic.num_ops {
        svc.plan_dynamic(&dynamic, &svc.request().with_dynamic(DynamicMode::Resolved(step)))
            .unwrap();
    }
    let first_pass_misses = svc.stats().dynamic_misses;
    assert!(
        first_pass_misses >= 2,
        "a decode tail must actually create multiple prefixes"
    );
    for step in 0..dynamic.num_ops {
        svc.plan_dynamic(&dynamic, &svc.request().with_dynamic(DynamicMode::Resolved(step)))
            .unwrap();
    }
    let st = svc.stats();
    assert_eq!(
        st.dynamic_misses, first_pass_misses,
        "second pass over the same resolved prefixes must plan nothing"
    );
    assert_eq!(st.dynamic_hits as usize, 2 * dynamic.num_ops - first_pass_misses as usize);
}

#[test]
fn prefix_plans_are_frozen_prefixes_across_random_workloads() {
    // The freeze invariant that makes prefix-keyed caching sound, over
    // randomized decode workloads: every wave-w prefix plan places exactly
    // the resolved records, at the offsets the full plan gives them.
    for seed in 0..20u64 {
        let dynamic = synth_decode(seed, 40, 12 + (seed as usize % 16));
        let full = MultiPassPlanner.plan(&dynamic);
        assert!(full.is_complete());
        full.offset_plan()
            .unwrap()
            .validate(&dynamic.final_records())
            .unwrap();
        // Growth is monotone and peaks at the arena total.
        assert!(full.growth.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(full.peak, *full.growth.last().unwrap());
        for &w in &dynamic.waves() {
            let prefix = MultiPassPlanner.plan_resolved(&dynamic, DynamicMode::Resolved(w));
            for d in &dynamic.records {
                let id = d.record.id;
                if d.known_at <= w {
                    assert_eq!(
                        prefix.offset_of(id),
                        full.offset_of(id),
                        "seed {seed}: wave-{w} prefix moved record {id}"
                    );
                } else {
                    assert_eq!(prefix.offset_of(id), None);
                }
            }
        }
    }
}

#[test]
fn wave_aware_serving_is_bit_identical_and_amortized() {
    // A wave-aware server fed fixed-size pre-batched bursts (so every
    // executed batch is deterministic): outputs match the static engine
    // bit for bit, and the second burst performs zero planner invocations
    // — static or dynamic.
    let g = models::blazeface();
    let in_elems = g.tensor(g.inputs[0]).num_elements();
    let decode_from = g.num_ops() / 2;
    let svc = PlanService::shared();
    let server = {
        let svc = Arc::clone(&svc);
        ModelServer::spawn(
            move || {
                let g = models::blazeface();
                Box::new(
                    ExecutorEngine::for_request_dynamic(
                        &g,
                        svc,
                        &PlanRequest::new(),
                        decode_from,
                        7,
                    )
                    .expect("engine")
                    .with_max_batch(4),
                )
            },
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                mem_budget: None,
                ..BatchPolicy::default()
            },
        )
        .expect("spawn")
    };
    // Reference outputs from a static engine with the same weights seed.
    let mut reference = ExecutorEngine::new(&g, PlanService::shared(), "greedy-size", 7).unwrap();
    // Each request is a pre-batched burst of exactly 4 samples: it closes
    // a batch by itself, so every engine execution is a batch of 4.
    let burst: Vec<f32> = (0..4)
        .flat_map(|i| vec![(i % 5) as f32 * 0.2; in_elems])
        .collect();
    let expected = reference.run_batch(&burst, 4).unwrap();
    for round in 0..3 {
        let out = server.submit(burst.clone()).recv().unwrap().unwrap();
        assert_eq!(out, expected, "round {round} diverged under wave-aware serving");
    }
    let (static_misses, dynamic_misses) = {
        let st = svc.stats();
        (st.cache_misses, st.dynamic_misses)
    };
    // Steady state: everything — batch plans, decode-step re-plans — comes
    // from the cache.
    for _ in 0..3 {
        server.submit(burst.clone()).recv().unwrap().unwrap();
    }
    let st = svc.stats();
    assert_eq!(st.cache_misses, static_misses, "static plans re-planned");
    assert_eq!(st.dynamic_misses, dynamic_misses, "decode-step re-plans not amortized");
    assert!(st.dynamic_hits > 0);
    server.shutdown();
}

#[test]
fn dynamic_budget_admission_refuses_over_peak_bursts() {
    // Budget resolved under the worst-wave peak: a burst whose multi-pass
    // peak exceeds the budget is refused typed, never OOMed; admitted
    // batches stay within the dynamic cap.
    let g = models::blazeface();
    let in_elems = g.tensor(g.inputs[0]).num_elements();
    let decode_from = g.num_ops() / 2;
    let svc = PlanService::shared();
    let dyn_recs = DynamicRecords::decode_tail(&UsageRecords::from_graph(&g), decode_from);
    let full = svc.request().with_dynamic(DynamicMode::FullyResolved);
    let peak1 = svc.plan_dynamic(&dyn_recs, &full).unwrap().peak;
    let budget = 2 * peak1;
    let cap = svc
        .max_servable_batch_dynamic(&dyn_recs, &svc.request(), budget)
        .unwrap();
    assert!(cap >= 1 && cap < 8, "budget must bind below the policy cap (cap {cap})");
    let server = {
        let svc = Arc::clone(&svc);
        ModelServer::spawn(
            move || {
                let g = models::blazeface();
                Box::new(
                    ExecutorEngine::for_request_dynamic(
                        &g,
                        svc,
                        &PlanRequest::new(),
                        decode_from,
                        7,
                    )
                    .expect("engine")
                    .with_max_batch(8),
                )
            },
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                mem_budget: Some(budget),
                ..BatchPolicy::default()
            },
        )
        .expect("spawn")
    };
    // An oversized pre-batched burst is refused with the typed error.
    let refusal = server.submit(vec![0.1f32; 8 * in_elems]).recv().unwrap();
    match refusal {
        Err(tensorarena::coordinator::ServeError::BudgetExceeded {
            batch,
            planned_bytes,
            budget_bytes,
        }) => {
            assert_eq!(batch, 8);
            assert!(planned_bytes > budget_bytes);
            assert_eq!(budget_bytes, budget);
        }
        other => panic!("expected BudgetExceeded, got {other:?}"),
    }
    // Singles still serve, clamped to the dynamic cap.
    let pending: Vec<_> = (0..16usize)
        .map(|_| server.submit(vec![0.1f32; in_elems]))
        .collect();
    for rx in pending {
        rx.recv().unwrap().unwrap();
    }
    let snap = server.metrics().snapshot();
    assert_eq!(snap.completed, 16);
    assert!(
        snap.max_batch_seen <= cap,
        "batch {} formed over the worst-wave-peak cap {cap}",
        snap.max_batch_seen
    );
    assert_eq!(snap.rejected, 1);
    server.shutdown();
}

#[test]
fn stale_resolved_sizes_miss_instead_of_serving_the_wrong_plan() {
    // Two sequences that agree on the wave structure but resolve a
    // *different* size for the same wave must occupy different cache slots
    // — a stale prefix is a miss, never a wrong-plan hit.
    let svc = PlanService::shared();
    let base = |late_size: usize| {
        DynamicRecords::new(
            vec![
                DynamicRecord {
                    record: UsageRecord { id: 0, tensor: None, first_op: 0, last_op: 2, size: 128 },
                    known_at: 0,
                },
                DynamicRecord {
                    record: UsageRecord {
                        id: 1,
                        tensor: None,
                        first_op: 2,
                        last_op: 4,
                        size: late_size,
                    },
                    known_at: 1,
                },
            ],
            5,
        )
    };
    let seq_a = base(64);
    let seq_b = base(256);
    let step1 = svc.request().with_dynamic(DynamicMode::Resolved(1));
    let a = svc.plan_dynamic(&seq_a, &step1).unwrap();
    let b = svc.plan_dynamic(&seq_b, &step1).unwrap();
    assert_eq!(svc.stats().dynamic_misses, 2, "the stale prefix must be a miss");
    assert_ne!(a.peak, b.peak, "the two sequences need different arenas");
    // Before wave 1 resolves, the sequences are indistinguishable — and
    // share a slot (the unresolved size is not part of the prefix).
    let step0 = svc.request().with_dynamic(DynamicMode::Resolved(0));
    let pa = svc.plan_dynamic(&seq_a, &step0).unwrap();
    let pb = svc.plan_dynamic(&seq_b, &step0).unwrap();
    assert_eq!(svc.stats().dynamic_misses, 3, "shared unresolved prefix plans once");
    assert!(Arc::ptr_eq(&pa, &pb));
}

#[test]
fn dynamic_plans_are_order_and_strategy_keyed() {
    // The full cache key is (resolved prefix, batch, strategy, order):
    // coinciding record sets under different orders or strategy namespaces
    // must not cross-contaminate.
    use tensorarena::planner::OrderStrategy;
    let svc = PlanService::shared();
    let dynamic = synth_decode(9, 24, 12);
    let full = svc.request().with_dynamic(DynamicMode::FullyResolved);
    svc.plan_dynamic(&dynamic, &full).unwrap();
    svc.plan_dynamic(&dynamic, &full.with_order(OrderStrategy::MemoryAware))
        .unwrap();
    svc.plan_dynamic(&dynamic, &full.with_strategy("greedy-breadth").unwrap())
        .unwrap();
    svc.plan_dynamic(&dynamic, &full.with_batch(2)).unwrap();
    assert_eq!(svc.stats().dynamic_misses, 4, "four distinct keys, four slots");
    svc.plan_dynamic(&dynamic, &full).unwrap();
    assert_eq!(svc.stats().dynamic_misses, 4);
}

#[test]
fn dynamic_engine_planned_peaks_drive_the_envelope() {
    // The Engine-trait view: planned_peak is the worst-wave peak and grows
    // monotonically with batch, so ModelServer's spawn-time envelope
    // pre-resolution works unchanged for dynamic engines.
    let g = models::blazeface();
    let e = ExecutorEngine::for_request_dynamic(
        &g,
        PlanService::shared(),
        &PlanRequest::new(),
        g.num_ops() / 2,
        3,
    )
    .unwrap();
    let p1 = e.planned_peak(1).unwrap();
    let p2 = e.planned_peak(2).unwrap();
    let p4 = e.planned_peak(4).unwrap();
    assert!(p1 > 0 && p1 < p2 && p2 < p4);
    assert_eq!(e.planned_peak(0), Some(0));
    assert_eq!(p2, 2 * p1, "uniform scaling scales the worst-wave peak");
}
