//! Tier-1 coverage for the shared bench harness (`benches/harness.rs`),
//! which bench binaries include via `#[path]` and which therefore never
//! runs under `cargo test` on its own: the `iters == 0` clamp and the
//! hand-rolled JSON emitter/parser behind the `BENCH_*.json` trajectory.

#[path = "../benches/harness.rs"]
mod harness;

use harness::json::{parse, Value};
use std::time::Duration;

#[test]
#[cfg_attr(debug_assertions, should_panic(expected = "iters == 0"))]
fn bench_with_zero_iters_degrades_instead_of_panicking() {
    // A smoke config that scales a count down (e.g. `iters / 100`) can
    // reach zero. Debug builds flag the bug loudly; release builds (the
    // bench profile) clamp to one timed sample and keep going — the old
    // code died on `samples[0]` of an empty vector.
    let mut runs = 0u32;
    let st = harness::bench(0, 0, || runs += 1);
    assert_eq!(runs, 1, "clamped bench should time exactly one run");
    assert_eq!(st.median, st.min);
    assert_eq!(st.median, st.mean);
}

#[test]
fn bench_counts_warmup_and_timed_runs() {
    let mut runs = 0u32;
    let st = harness::bench(2, 5, || runs += 1);
    assert_eq!(runs, 7, "2 warmup + 5 timed");
    assert!(st.min <= st.median && st.median >= Duration::ZERO);
    assert!(st.median_us() >= st.min_us());
    assert!(st.mean_us() >= 0.0);
}

#[test]
fn json_render_parse_roundtrip() {
    let doc = Value::Obj(vec![
        ("bench".into(), Value::Str("serving".into())),
        ("schema_version".into(), Value::Num(1.0)),
        ("ok".into(), Value::Bool(true)),
        ("nothing".into(), Value::Null),
        (
            "cases".into(),
            Value::Arr(vec![
                Value::Obj(vec![
                    ("name".into(), Value::Str("run_batch/t4/b8 \"quoted\"\n".into())),
                    ("median_us".into(), Value::Num(219284.6)),
                ]),
                Value::Obj(vec![
                    ("name".into(), Value::Str("x".into())),
                    ("median_us".into(), Value::Num(3.0)),
                ]),
            ]),
        ),
    ]);
    let text = doc.render();
    let back = parse(&text).expect("emitter output parses");
    assert_eq!(back, doc, "render → parse is not the identity");
    // Accessors the bench's --check mode relies on.
    assert_eq!(back.get("bench").and_then(|v| v.as_str()), Some("serving"));
    assert_eq!(back.get("schema_version").and_then(|v| v.as_num()), Some(1.0));
    assert_eq!(back.get("cases").and_then(|v| v.as_arr()).map(|a| a.len()), Some(2));
}

#[test]
fn json_schema_ignores_timings_but_not_shape() {
    let case = |median: f64, extra: bool| {
        let mut kv = vec![
            ("name".into(), Value::Str("a".into())),
            ("median_us".into(), Value::Num(median)),
        ];
        if extra {
            kv.push(("p99_us".into(), Value::Num(1.0)));
        }
        Value::Obj(kv)
    };
    let doc = |median: f64, extra: bool| {
        Value::Obj(vec![
            ("bench".into(), Value::Str("serving".into())),
            ("cases".into(), Value::Arr(vec![case(median, extra)])),
        ])
    };
    // Timing drift: same schema.
    assert_eq!(doc(100.0, false).schema(), doc(9999.9, false).schema());
    // A renamed/added field: different schema.
    assert_ne!(doc(100.0, false).schema(), doc(100.0, true).schema());
    // Key order does not matter — schemas sort keys.
    let reordered = Value::Obj(vec![
        ("cases".into(), Value::Arr(vec![case(1.0, false)])),
        ("bench".into(), Value::Str("serving".into())),
    ]);
    assert_eq!(reordered.schema(), doc(2.0, false).schema());
    // Homogeneous case arrays collapse, so smoke runs (fewer cases) keep
    // the committed schema.
    let two = Value::Arr(vec![case(1.0, false), case(2.0, false)]);
    let one = Value::Arr(vec![case(3.0, false)]);
    assert_eq!(two.schema(), one.schema());
}

#[test]
fn json_parse_rejects_garbage() {
    assert!(parse("").is_err());
    assert!(parse("{").is_err());
    assert!(parse("{\"a\": 1,}").is_err());
    assert!(parse("[1 2]").is_err());
    assert!(parse("\"unterminated").is_err());
    assert!(parse("{\"a\": 1} trailing").is_err());
    assert!(parse("truthy").is_err());
}

#[test]
fn json_parses_the_committed_trajectory_file() {
    // The committed baseline must stay parseable by the checker that
    // guards it.
    let text = std::fs::read_to_string(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_serving.json"),
    )
    .expect("BENCH_serving.json exists at the repo root");
    let doc = parse(&text).expect("committed trajectory parses");
    assert_eq!(doc.get("bench").and_then(|v| v.as_str()), Some("serving"));
    let cases = doc.get("cases").and_then(|v| v.as_arr()).expect("cases array");
    assert!(!cases.is_empty());
    // Every case shares one shape — the property the CI schema check
    // leans on.
    let first = cases[0].schema();
    for c in cases {
        assert_eq!(c.schema(), first, "heterogeneous case shape in committed file");
    }
}
