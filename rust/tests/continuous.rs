//! Tier-1 acceptance tests for the continuous-batching scheduler: a
//! request submitted mid-decode joins the in-flight loop before it drains
//! (observable via the `continuous_admissions` metric), per-request
//! outputs stay bit-identical to the sequential resident path under
//! randomized arrivals, the bounded queue refuses overload typed, and the
//! budget-resolved lane cap holds at every wave boundary.

use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::time::Duration;
use tensorarena::coordinator::engine::ExecutorEngine;
use tensorarena::coordinator::{BatchPolicy, Engine, ModelServer, ServeError};
use tensorarena::models;
use tensorarena::planner::{PlanRequest, PlanService};
use tensorarena::rng::SplitMix64;

/// What the scripted engine observed, in order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Ev {
    Admit(u32),
    Finish(u32),
}

/// Scripted lane engine: identity-times-two over one element, a fixed
/// number of `lane_advance` waves per request, and — to pin down
/// "mid-decode" without racing the scheduler — the *first* advance ever
/// blocks until the test sends a tick. Every admission and finish is
/// logged so the test can assert interleaving, not just final outputs.
struct GateEngine {
    lanes: Vec<Option<(u32, usize)>>,
    events: Arc<Mutex<Vec<Ev>>>,
    gate: Option<Receiver<()>>,
    waves: usize,
    max_lanes: usize,
}

impl GateEngine {
    fn new(max_lanes: usize, waves: usize, events: Arc<Mutex<Vec<Ev>>>, gate: Receiver<()>) -> Self {
        GateEngine { lanes: Vec::new(), events, gate: Some(gate), waves, max_lanes }
    }
}

impl Engine for GateEngine {
    fn in_elems(&self) -> usize {
        1
    }
    fn out_elems(&self) -> usize {
        1
    }
    fn max_batch(&self) -> usize {
        self.max_lanes
    }
    fn run_batch(&mut self, _input: &[f32], _n: usize) -> anyhow::Result<Vec<f32>> {
        anyhow::bail!("this engine only serves lanes")
    }
    fn supports_lanes(&self) -> bool {
        true
    }
    fn lane_prepare(&mut self, lanes: usize) -> anyhow::Result<()> {
        self.lanes.resize_with(lanes, || None);
        Ok(())
    }
    fn lane_begin(&mut self, lane: usize, input: &[f32]) -> anyhow::Result<()> {
        let tag = input[0] as u32;
        anyhow::ensure!(self.lanes[lane].is_none(), "lane {lane} already open");
        self.events.lock().unwrap().push(Ev::Admit(tag));
        self.lanes[lane] = Some((tag, self.waves));
        Ok(())
    }
    fn lane_advance(&mut self, lane: usize) -> anyhow::Result<bool> {
        if let Some(gate) = self.gate.take() {
            // Hold the decode loop mid-flight until the test releases it.
            let _ = gate.recv();
        }
        let (_, remaining) = self.lanes[lane].as_mut().expect("advance on an idle lane");
        *remaining -= 1;
        Ok(*remaining == 0)
    }
    fn lane_finish(&mut self, lane: usize) -> anyhow::Result<Vec<f32>> {
        let (tag, _) = self.lanes[lane].take().expect("finish on an idle lane");
        self.events.lock().unwrap().push(Ev::Finish(tag));
        Ok(vec![tag as f32 * 2.0])
    }
    fn lane_abort(&mut self, lane: usize) {
        self.lanes[lane] = None;
    }
}

/// Block until `events` satisfies `pred` (bounded, so a scheduler bug
/// fails the test instead of hanging CI).
fn wait_for(events: &Arc<Mutex<Vec<Ev>>>, pred: impl Fn(&[Ev]) -> bool) {
    for _ in 0..2000 {
        if pred(events.lock().unwrap().as_slice()) {
            return;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    panic!("timed out waiting for scheduler events: {:?}", events.lock().unwrap());
}

#[test]
fn request_submitted_mid_decode_joins_the_inflight_loop() {
    // The tentpole's observable claim: request B, submitted while request
    // A is mid-decode, is admitted into A's in-flight loop — before A
    // finishes, without waiting for the batch to drain.
    let events = Arc::new(Mutex::new(Vec::new()));
    let (tick, gate) = channel::<()>();
    let server = {
        let events = Arc::clone(&events);
        ModelServer::spawn(
            move || Box::new(GateEngine::new(2, 4, events, gate)),
            BatchPolicy { max_batch: 2, continuous: true, ..BatchPolicy::default() },
        )
        .expect("spawn")
    };
    let rx_a = server.submit(vec![1.0]);
    // A is admitted and its decode loop is now blocked inside its first
    // wave (the gate) — in flight by construction.
    wait_for(&events, |ev| ev.contains(&Ev::Admit(1)));
    let rx_b = server.submit(vec![2.0]);
    tick.send(()).expect("worker waiting on the gate");
    assert_eq!(rx_a.recv().unwrap().unwrap(), vec![2.0]);
    assert_eq!(rx_b.recv().unwrap().unwrap(), vec![4.0]);
    let ev = events.lock().unwrap().clone();
    let admit_b = ev.iter().position(|e| *e == Ev::Admit(2)).expect("B admitted");
    let finish_a = ev.iter().position(|e| *e == Ev::Finish(1)).expect("A finished");
    assert!(
        admit_b < finish_a,
        "B must join while A is still decoding, got {ev:?}"
    );
    let snap = server.metrics().snapshot();
    assert_eq!(snap.completed, 2);
    assert_eq!(
        snap.continuous_admissions, 1,
        "exactly B was admitted into an in-flight loop"
    );
    server.shutdown();
}

#[test]
fn bounded_queue_refuses_overload_with_queue_full() {
    // Backpressure: one lane, queue depth one. With the lane gated
    // mid-wave, two more submissions arrive; the first fills the queue,
    // the second must be refused typed — the backlog never grows past the
    // configured depth.
    let events = Arc::new(Mutex::new(Vec::new()));
    let (tick, gate) = channel::<()>();
    let server = {
        let events = Arc::clone(&events);
        ModelServer::spawn(
            move || Box::new(GateEngine::new(1, 2, events, gate)),
            BatchPolicy {
                max_batch: 1,
                continuous: true,
                queue_depth: 1,
                ..BatchPolicy::default()
            },
        )
        .expect("spawn")
    };
    let rx_a = server.submit(vec![1.0]);
    wait_for(&events, |ev| ev.contains(&Ev::Admit(1)));
    // The worker is blocked inside A's first wave: both arrive before the
    // next queue drain, deterministically.
    let rx_b = server.submit(vec![2.0]);
    let rx_c = server.submit(vec![3.0]);
    tick.send(()).expect("worker waiting on the gate");
    assert_eq!(rx_a.recv().unwrap().unwrap(), vec![2.0]);
    assert_eq!(rx_b.recv().unwrap().unwrap(), vec![4.0]);
    match rx_c.recv().unwrap() {
        Err(ServeError::QueueFull { depth: 1 }) => {}
        other => panic!("expected QueueFull at depth 1, got {other:?}"),
    }
    let snap = server.metrics().snapshot();
    assert_eq!(snap.completed, 2);
    assert_eq!(snap.rejected, 1);
    server.shutdown();
}

#[test]
fn continuous_outputs_match_the_resident_path_under_random_arrivals() {
    // Bit-identity under racing admissions: a paged continuous server and
    // a sequential resident executor must agree per request, byte for
    // byte, whatever interleaving the arrival jitter produces.
    let g = models::blazeface();
    let in_elems = g.tensor(g.inputs[0]).num_elements();
    let decode_from = g.num_ops() / 2;
    let svc = PlanService::shared();
    let server = {
        let svc = Arc::clone(&svc);
        ModelServer::spawn(
            move || {
                let g = models::blazeface();
                Box::new(
                    ExecutorEngine::for_request_paged(
                        &g,
                        svc,
                        &PlanRequest::new(),
                        decode_from,
                        7,
                    )
                    .expect("engine")
                    .with_max_batch(4)
                    .with_continuous(),
                )
            },
            BatchPolicy {
                max_batch: 4,
                continuous: true,
                queue_depth: 32,
                ..BatchPolicy::default()
            },
        )
        .expect("spawn")
    };
    // Reference outputs from a sequential resident engine, same weights
    // seed. (How many requests overlapped is timing-dependent; identity
    // must hold regardless, so no admission count is asserted here.)
    let mut reference = ExecutorEngine::new(&g, PlanService::shared(), "greedy-size", 7).unwrap();
    let mut rng = SplitMix64::new(11);
    let mut pending = Vec::new();
    for _ in 0..24 {
        let v = rng.next_range(0, 9) as f32 * 0.1;
        let input = vec![v; in_elems];
        let want = reference.run_batch(&input, 1).unwrap();
        pending.push((server.submit(input), want));
        if rng.next_below(3) == 0 {
            std::thread::sleep(Duration::from_micros(rng.next_range(50, 500) as u64));
        }
    }
    for (i, (rx, want)) in pending.into_iter().enumerate() {
        let got = rx.recv().expect("worker alive").expect("served");
        assert_eq!(got, want, "request {i} diverged from the resident path");
    }
    let snap = server.metrics().snapshot();
    assert_eq!(snap.completed, 24);
    assert!(
        snap.max_batch_seen <= 4,
        "live lanes {} exceeded the policy cap",
        snap.max_batch_seen
    );
    server.shutdown();
}

#[test]
fn continuous_budget_cap_bounds_live_lanes_at_every_wave_boundary() {
    // Budget correctness: a continuous engine charges
    // `prefix peak + tail_block_demand × live lanes`, so a budget set at
    // the 2-lane peak must resolve a lane cap of exactly 2 — and the
    // scheduler must never hold more than 2 lanes live at any wave
    // boundary (observable as the concurrency recorded per retirement).
    let g = models::blazeface();
    let in_elems = g.tensor(g.inputs[0]).num_elements();
    let decode_from = g.num_ops() / 2;
    let svc = PlanService::shared();
    let probe = ExecutorEngine::for_request_paged(
        &g,
        Arc::clone(&svc),
        &PlanRequest::new(),
        decode_from,
        7,
    )
    .expect("probe engine")
    .with_max_batch(8)
    .with_continuous();
    let peak2 = probe.planned_peak(2).expect("paged engines report peaks");
    let peak3 = probe.planned_peak(3).expect("paged engines report peaks");
    assert!(peak3 > peak2, "per-lane charge must grow with the lane count");
    let budget = peak2;
    assert_eq!(probe.max_servable_batch(budget), Some(2), "budget must cap at 2 lanes");
    drop(probe);

    let server = {
        let svc = Arc::clone(&svc);
        ModelServer::spawn(
            move || {
                let g = models::blazeface();
                Box::new(
                    ExecutorEngine::for_request_paged(
                        &g,
                        svc,
                        &PlanRequest::new(),
                        decode_from,
                        7,
                    )
                    .expect("engine")
                    .with_max_batch(8)
                    .with_continuous(),
                )
            },
            BatchPolicy {
                max_batch: 8,
                mem_budget: Some(budget),
                continuous: true,
                queue_depth: 64,
                ..BatchPolicy::default()
            },
        )
        .expect("spawn")
    };
    let pending: Vec<_> = (0..12)
        .map(|i| server.submit(vec![(i % 5) as f32 * 0.2; in_elems]))
        .collect();
    for (i, rx) in pending.into_iter().enumerate() {
        let resp = rx.recv().expect("worker alive");
        assert!(resp.is_ok(), "request {i} failed under the lane budget: {resp:?}");
    }
    let snap = server.metrics().snapshot();
    assert_eq!(snap.completed, 12);
    assert!(
        snap.max_batch_seen <= 2,
        "{} lanes were live at a wave boundary, over the budget cap of 2",
        snap.max_batch_seen
    );
    server.shutdown();
}
