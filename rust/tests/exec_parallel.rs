//! Parallel-executor property tests: `run_batch` on a multi-thread
//! executor must be **bit-identical** to the sequential executor — not
//! approximately equal — for both parallel paths:
//!
//! * lockstep batch lanes (`threads > 1`, `n > 1`): workers own lane
//!   chunks and march through the step list behind a barrier;
//! * level-scheduled single samples (`threads > 1`, `n == 1`): independent
//!   ops of one dataflow level run concurrently when the resident plan
//!   proves their byte ranges disjoint.
//!
//! Bit-identity is the contract that makes `--threads` safe to flip on in
//! serving: results cannot drift with the worker count, batch size, or
//! which path dispatch picks. Comparisons are on `f32::to_bits`, so even a
//! sign-of-zero difference fails with its seed.
//!
//! Property tests use the same hand-rolled SplitMix64 generator as
//! `tests/plan_service.rs` (the offline registry has no proptest).

use tensorarena::exec::{Executor, KernelMode};
use tensorarena::models;
use tensorarena::planner::offset::GreedyBySize;
use tensorarena::rng::SplitMix64;

fn assert_bits_eq(a: &[f32], b: &[f32], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{ctx}: elem {i} differs: {x} vs {y}"
        );
    }
}

fn batch_input(rng: &mut SplitMix64, in_elems: usize, n: usize) -> Vec<f32> {
    let mut v = vec![0f32; in_elems * n];
    rng.fill_f32(&mut v, 1.0);
    v
}

#[test]
fn parallel_run_batch_is_bit_identical_to_sequential() {
    // The property: for random batch sizes and worker counts, a threaded
    // executor's payload equals the sequential one's, bit for bit —
    // covering the lockstep path (n > 1), the scheduled path (n = 1), and
    // arena growth across calls.
    for name in ["l2_cnn", "blazeface"] {
        let g = models::by_name(name).unwrap();
        let in_elems = g.tensor(g.inputs[0]).num_elements();
        let mut seq = Executor::new(&g, &GreedyBySize, 7).unwrap();
        let mut par = Executor::new(&g, &GreedyBySize, 7).unwrap();
        par.set_poison_dead(true); // stress: scribble NaNs on dead records
        let mut rng = SplitMix64::new(0xC0FFEE);
        for trial in 0..6u64 {
            let n = rng.next_range(1, 5);
            let threads = rng.next_range(2, 6);
            par.set_threads(threads);
            let input = batch_input(&mut rng, in_elems, n);
            let a = seq.run_batch(&input, n).unwrap();
            let b = par.run_batch(&input, n).unwrap();
            assert_bits_eq(&a, &b, &format!("{name} trial {trial}: n={n} threads={threads}"));
        }
    }
}

#[test]
fn scheduled_single_sample_parallelism_counts_and_matches() {
    // blazeface has real dataflow width (parallel residual branches): the
    // scheduled path must actually dispatch ops to workers and still agree
    // with the sequential executor bit for bit.
    let g = models::by_name("blazeface").unwrap();
    let in_elems = g.tensor(g.inputs[0]).num_elements();
    let mut rng = SplitMix64::new(99);
    let mut x = vec![0f32; in_elems];
    rng.fill_f32(&mut x, 1.0);
    let mut seq = Executor::new(&g, &GreedyBySize, 7).unwrap();
    let mut par = Executor::new(&g, &GreedyBySize, 7).unwrap();
    par.set_threads(4);
    let a = seq.run_batch(&x, 1).unwrap();
    let b = par.run_batch(&x, 1).unwrap();
    assert_bits_eq(&a, &b, "blazeface single-sample");
    assert!(par.levels() > 0, "level sets should exist for a DAG");
    // Whether ops actually ran in parallel depends on the plan proving
    // byte-disjointness (schedule_safe) and the groups having width; either
    // way the payload above must not drift. The counter is monotone:
    let before = par.ops_parallel();
    let b2 = par.run_batch(&x, 1).unwrap();
    assert_bits_eq(&b, &b2, "blazeface repeat run");
    assert!(par.ops_parallel() >= before, "ops_parallel went backwards");
}

#[test]
fn reference_kernels_compose_with_parallelism() {
    // Kernel mode and parallelism are orthogonal knobs: the scalar
    // reference kernels must also be bit-identical across thread counts.
    let g = models::by_name("l2_cnn").unwrap();
    let in_elems = g.tensor(g.inputs[0]).num_elements();
    let mut rng = SplitMix64::new(0xBEEF);
    let input = batch_input(&mut rng, in_elems, 3);
    let mut seq = Executor::new(&g, &GreedyBySize, 7).unwrap();
    seq.set_kernel_mode(KernelMode::Reference);
    let mut par = Executor::new(&g, &GreedyBySize, 7).unwrap();
    par.set_kernel_mode(KernelMode::Reference);
    par.set_threads(3);
    let a = seq.run_batch(&input, 3).unwrap();
    let b = par.run_batch(&input, 3).unwrap();
    assert_bits_eq(&a, &b, "reference kernels, n=3 threads=3");
}

#[test]
fn shrinking_and_growing_batches_stay_bit_identical() {
    // The resident arena only grows; smaller batches run in the first
    // lanes. The threaded executor must agree through the whole
    // grow/shrink sequence, including the schedule rebuild on every swap.
    let g = models::by_name("l2_cnn").unwrap();
    let in_elems = g.tensor(g.inputs[0]).num_elements();
    let mut seq = Executor::new(&g, &GreedyBySize, 7).unwrap();
    let mut par = Executor::new(&g, &GreedyBySize, 7).unwrap();
    par.set_threads(4);
    let mut rng = SplitMix64::new(5);
    for (i, n) in [1usize, 4, 2, 5, 1, 3].into_iter().enumerate() {
        let input = batch_input(&mut rng, in_elems, n);
        let a = seq.run_batch(&input, n).unwrap();
        let b = par.run_batch(&input, n).unwrap();
        assert_bits_eq(&a, &b, &format!("step {i}: n={n}"));
    }
}
