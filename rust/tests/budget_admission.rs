//! Budget-driven admission, end to end: the `max_servable_batch` query
//! that resolves a byte budget into a batch cap (property-tested across
//! every registry strategy and randomized budgets), and the coordinator
//! behaviour it drives — clamped batches, typed refusals, counted
//! rejections, never an OOM.
//!
//! Property tests use the same hand-rolled SplitMix64 generator as
//! `planner_properties.rs` (the offline registry has no proptest); every
//! failure prints its seed. The quick tier runs a few seeds; the `#[ignore]`d
//! tier (CI tier-2: `cargo test --release -- --include-ignored`) sweeps
//! many more.

use std::sync::Arc;
use std::time::Duration;
use tensorarena::coordinator::engine::ExecutorEngine;
use tensorarena::coordinator::{BatchPolicy, EchoEngine, Engine, ModelServer, ServeError};
use tensorarena::models;
use tensorarena::planner::{
    apply_order, registry, OrderStrategy, PlanCache, PlanRequest, PlanService,
};
use tensorarena::records::UsageRecords;
use tensorarena::rng::SplitMix64;

/// Random usage records resembling real nets (64-byte-aligned sizes).
fn random_records(seed: u64) -> UsageRecords {
    let mut rng = SplitMix64::new(seed);
    let n = rng.next_range(1, 40);
    let mut triples = Vec::with_capacity(n);
    let mut op = 0usize;
    for _ in 0..n {
        let span = match rng.next_below(10) {
            0..=6 => 1,
            7 | 8 => rng.next_range(2, 6),
            _ => rng.next_range(6, 12),
        };
        let size = 64 * rng.next_range(1, 256);
        triples.push((op, op + span, size));
        if rng.next_below(3) != 0 {
            op += 1;
        }
    }
    UsageRecords::from_triples(&triples)
}

/// The three properties the admission cap must satisfy for one
/// `(records, strategy, budgets)` case:
/// 1. never admits over budget: `planned(cap) <= budget` whenever `cap >= 1`;
/// 2. agrees with direct per-batch planning: `planned(cap + 1) > budget`
///    (maximality) and a `cap` of 0 means even batch 1 does not fit;
/// 3. monotone in budget: more bytes never shrink the admitted batch.
fn check_admission_properties(seed: u64, recs: &UsageRecords, strategy: &str, budgets: &[usize]) {
    let cache = PlanCache::new();
    let req = PlanRequest::new().with_strategy(strategy).unwrap();
    let mut sorted: Vec<usize> = budgets.to_vec();
    sorted.sort_unstable();
    let mut last_cap = 0usize;
    let mut last_budget = 0usize;
    for &budget in &sorted {
        let cap = cache
            .max_servable_batch(recs, &req, budget)
            .unwrap_or_else(|e| panic!("seed {seed}, {strategy}, budget {budget}: {e}"));
        // (3) monotone in budget.
        assert!(
            cap >= last_cap,
            "seed {seed}, {strategy}: budget {last_budget} admits {last_cap} but larger \
             budget {budget} admits only {cap}"
        );
        if cap == usize::MAX {
            // Degenerate all-zero-size records: anything fits, nothing to plan.
            continue;
        }
        if cap >= 1 {
            // (1) the admitted batch's *planned* peak fits.
            let planned = cache.get_or_plan(recs, &req.with_batch(cap)).unwrap().total;
            assert!(
                planned <= budget,
                "seed {seed}, {strategy}: admitted batch {cap} needs {planned} > budget {budget}"
            );
        }
        // (2) maximality: one more sample would not fit (direct planning).
        let over = cache.get_or_plan(recs, &req.with_batch(cap + 1)).unwrap().total;
        assert!(
            over > budget,
            "seed {seed}, {strategy}: batch {} fits {over} <= {budget} but only {cap} admitted",
            cap + 1
        );
        last_cap = cap;
        last_budget = budget;
    }
}

fn sweep_admission(seeds: std::ops::Range<u64>) {
    for seed in seeds {
        let recs = random_records(seed);
        let mut rng = SplitMix64::new(seed ^ 0x9e3779b97f4a7c15);
        for key in registry::OFFSET_KEYS {
            let t1 = PlanCache::new()
                .get_or_plan(&recs, &PlanRequest::new().with_strategy(key).unwrap())
                .unwrap()
                .total;
            // Randomized budgets around the interesting region: below the
            // batch-1 arena up to ~9x it, plus exact boundaries.
            let mut budgets = vec![0, t1 - 1, t1, t1 + 1, 4 * t1];
            for _ in 0..4 {
                budgets.push(rng.next_range(1, 9) * t1 + rng.next_below(t1));
            }
            check_admission_properties(seed, &recs, key, &budgets);
        }
    }
}

#[test]
fn admission_cap_is_monotone_tight_and_within_budget() {
    sweep_admission(0..8);
}

#[test]
#[ignore = "slow sweep; run in CI tier-2 via --include-ignored"]
fn admission_cap_properties_hold_across_many_seeds() {
    sweep_admission(8..64);
}

#[test]
fn admission_agrees_with_service_level_query_on_real_models() {
    // The PlanService wrapper and the raw cache answer identically, on a
    // real model, for every strategy.
    let recs = UsageRecords::from_graph(&models::blazeface());
    for key in registry::OFFSET_KEYS {
        let svc = PlanService::with_default_strategy(key).unwrap();
        let cache = PlanCache::new();
        let req = PlanRequest::new().with_strategy(key).unwrap();
        let t1 = cache.get_or_plan(&recs, &req).unwrap().total;
        for budget in [0, t1, 2 * t1 + t1 / 2, 10 * t1] {
            assert_eq!(
                svc.max_servable_batch(&recs, &svc.request(), budget).unwrap(),
                cache.max_servable_batch(&recs, &req, budget).unwrap(),
                "{key}, budget {budget}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Coordinator behaviour under a budget (the ISSUE's acceptance scenario).
// ---------------------------------------------------------------------------

#[test]
fn server_under_budget_clamps_batches_and_counts_refusals() {
    // Budget ~3.5x the batch-1 arena: below the batch-8 planned peak, so
    // the 8-cap policy is budget-clamped. A 64-request burst completes
    // with zero OOMs (all served, in clamped batches); an oversized
    // pre-batched burst is refused with the typed error and counted.
    let service = PlanService::shared();
    let g = models::blazeface();
    let in_elems = g.tensor(g.inputs[0]).num_elements();
    let recs = UsageRecords::from_graph(&g);
    let t1 = service.plan(&recs, &service.request()).unwrap().total;
    let budget = 3 * t1 + t1 / 2;
    let peak8 = service.plan(&recs, &service.request().with_batch(8)).unwrap().total;
    assert!(budget < peak8, "budget must sit below the batch-8 peak for this test");
    let cap = service.max_servable_batch(&recs, &service.request(), budget).unwrap();
    assert!((1..8).contains(&cap), "unexpected budget cap {cap}");

    let server = {
        let service = Arc::clone(&service);
        ModelServer::spawn(
            move || {
                let g = models::blazeface();
                Box::new(
                    ExecutorEngine::new(&g, service, "greedy-size", 7)
                        .expect("engine")
                        .with_max_batch(8),
                )
            },
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                mem_budget: Some(budget),
                ..BatchPolicy::default()
            },
        )
        .expect("spawn")
    };
    let pending: Vec<_> = (0..64)
        .map(|i| server.submit(vec![(i as f32) / 64.0; in_elems]))
        .collect();
    for (i, rx) in pending.into_iter().enumerate() {
        let resp = rx.recv().expect("worker alive");
        assert!(resp.is_ok(), "request {i} failed under budget: {resp:?}");
    }

    let oversized = server.submit(vec![0.1f32; 8 * in_elems]);
    match oversized.recv().expect("worker alive") {
        Err(ServeError::BudgetExceeded { batch, planned_bytes, budget_bytes }) => {
            assert_eq!(batch, 8);
            assert_eq!(budget_bytes, budget);
            assert!(planned_bytes > budget);
        }
        other => panic!("oversized burst must be refused with BudgetExceeded, got {other:?}"),
    }

    let snap = server.metrics().snapshot();
    assert_eq!(snap.completed, 64, "the whole burst must complete");
    assert!(
        snap.max_batch_seen <= cap,
        "executed batch {} exceeds the budget cap {cap}",
        snap.max_batch_seen
    );
    assert_eq!(snap.rejected, 1, "Metrics::snapshot must count the refusal");

    // The served arena actually fit the budget: the resident plan at the
    // largest executed batch is within it.
    let peak_served = service
        .plan(&recs, &service.request().with_batch(snap.max_batch_seen.max(1)))
        .unwrap()
        .total;
    assert!(peak_served <= budget);
    server.shutdown();
}

#[test]
fn annealed_order_serving_peak_and_admission_resolve_under_the_order() {
    // The serving face of profile-guided ordering. Two guarantees:
    //
    // 1. Annealing is seeded from the natural order and only accepts
    //    improvements, so its §5.1 breadth never regresses — and on the
    //    zoo, the planned arena follows it (equality whenever no better
    //    order exists, since the reordered graph is then identical).
    // 2. Budget admission resolves its batch cap *under the served order*:
    //    the cap's ordered plan fits, the next batch's does not, and the
    //    engine behind a budgeted server answers with the same numbers.
    let order = OrderStrategy::Annealed { seed: 42, budget: 60 };
    let mut improved_or_equal = 0usize;
    for name in ["blazeface", "mobilenet_v2", "inception_v3"] {
        let g = models::by_name(name).unwrap();
        let svc = PlanService::shared();
        let (ordered, applied) = apply_order(&g, order);
        assert!(
            applied.order_breadth <= applied.natural_breadth,
            "{name}: annealed breadth regressed natural"
        );
        let ordered_recs = UsageRecords::from_graph(&ordered);
        let natural_recs = UsageRecords::from_graph(&g);
        let annealed_peak = svc
            .plan(&ordered_recs, &svc.request().with_order(order))
            .unwrap()
            .total;
        let natural_peak = svc.plan(&natural_recs, &svc.request()).unwrap().total;
        if annealed_peak <= natural_peak {
            improved_or_equal += 1;
        }
        // The planned peak can never undercut the order's own lower bound.
        assert!(annealed_peak >= applied.order_breadth, "{name}");
    }
    assert!(
        improved_or_equal >= 1,
        "annealed-order serving must not inflate the planned peak on every zoo model"
    );

    // Budget admission under the served order, engine- and service-level.
    let g = models::blazeface();
    let svc = PlanService::shared();
    let (ordered, _) = apply_order(&g, order);
    let recs = UsageRecords::from_graph(&ordered);
    let oreq = svc.request().with_order(order);
    let t1 = svc.plan(&recs, &oreq).unwrap().total;
    let budget = 3 * t1 + t1 / 2;
    let cap = svc.max_servable_batch(&recs, &oreq, budget).unwrap();
    assert!(cap >= 1, "a 3.5x budget must admit at least batch 1");
    let at_cap = svc.plan(&recs, &oreq.with_batch(cap)).unwrap().total;
    let above = svc.plan(&recs, &oreq.with_batch(cap + 1)).unwrap().total;
    assert!(at_cap <= budget && above > budget, "cap {cap} not tight under the order");
    let engine = ExecutorEngine::for_request(&g, Arc::clone(&svc), &oreq, 7).unwrap();
    assert_eq!(
        engine.max_servable_batch(budget),
        Some(cap),
        "the engine must resolve the admission cap under its served order"
    );
    assert_eq!(engine.planned_peak(1), Some(t1));

    // And a budgeted server built on that engine clamps batches to it.
    let in_elems = g.tensor(g.inputs[0]).num_elements();
    let server = {
        let svc = Arc::clone(&svc);
        ModelServer::spawn(
            move || {
                let g = models::blazeface();
                Box::new(
                    ExecutorEngine::for_request(
                        &g,
                        svc,
                        &PlanRequest::new().with_order(order),
                        7,
                    )
                    .expect("engine")
                    .with_max_batch(8),
                )
            },
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                mem_budget: Some(budget),
                ..BatchPolicy::default()
            },
        )
        .expect("spawn")
    };
    let pending: Vec<_> = (0..32)
        .map(|i| server.submit(vec![(i as f32) / 32.0; in_elems]))
        .collect();
    for (i, rx) in pending.into_iter().enumerate() {
        let resp = rx.recv().expect("worker alive");
        assert!(resp.is_ok(), "request {i} failed under the ordered budget: {resp:?}");
    }
    let snap = server.metrics().snapshot();
    assert_eq!(snap.completed, 32);
    assert!(
        snap.max_batch_seen <= cap,
        "executed batch {} exceeds the order-resolved cap {cap}",
        snap.max_batch_seen
    );
    server.shutdown();
}

#[test]
fn echo_server_budget_cap_is_exact() {
    // Deterministic linear engine: budget 350, 100 B/sample -> cap 3.
    let server = ModelServer::spawn(
        || Box::new(EchoEngine::new(1, 64).with_peak_per_sample(100)),
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            mem_budget: Some(350),
            ..BatchPolicy::default()
        },
    )
    .expect("spawn");
    let pending: Vec<_> = (0..32).map(|i| server.submit(vec![i as f32])).collect();
    for rx in pending {
        rx.recv().unwrap().unwrap();
    }
    // Batch 4 would need 400 B > 350: it must never form.
    let snap = server.metrics().snapshot();
    assert!(snap.max_batch_seen <= 3, "formed batch {}", snap.max_batch_seen);
    // A pre-batched burst of exactly the cap is admitted...
    assert!(server.submit(vec![0.0; 3]).recv().unwrap().is_ok());
    // ...one more sample is refused.
    assert!(matches!(
        server.submit(vec![0.0; 4]).recv().unwrap(),
        Err(ServeError::BudgetExceeded { batch: 4, planned_bytes: 400, budget_bytes: 350 })
    ));
    server.shutdown();
}
