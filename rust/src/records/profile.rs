//! Operator profiles, breadths, and positional maximums (§3, Figure 2b).

use super::{UsageRecord, UsageRecords};

/// Precomputed per-operator views over a set of usage records.
#[derive(Debug, Clone)]
pub struct OperatorProfiles {
    /// `profiles[op]` = record ids alive at `op`, sorted by size descending
    /// (ties: record id ascending, for determinism).
    profiles: Vec<Vec<usize>>,
    /// `breadth[op]` = sum of sizes in `profiles[op]` (§3 "Operator Breadth").
    breadths: Vec<usize>,
    /// `positional_maximums[i]` = max over ops of the i-th largest size in
    /// each profile (§3 "Positional Maximum"). Length = max profile length.
    positional_maximums: Vec<usize>,
}

impl OperatorProfiles {
    /// Build profiles for all `num_ops` operators.
    pub fn new(records: &UsageRecords) -> Self {
        let mut profiles: Vec<Vec<usize>> = vec![Vec::new(); records.num_ops];
        for r in &records.records {
            for profile in profiles.iter_mut().take(r.last_op + 1).skip(r.first_op) {
                profile.push(r.id);
            }
        }
        for p in &mut profiles {
            p.sort_by(|&a, &b| {
                let (ra, rb) = (&records.records[a], &records.records[b]);
                rb.size.cmp(&ra.size).then(ra.id.cmp(&rb.id))
            });
        }
        let breadths = profiles
            .iter()
            .map(|p| p.iter().map(|&i| records.records[i].size).sum())
            .collect::<Vec<_>>();
        let depth = profiles.iter().map(Vec::len).max().unwrap_or(0);
        let mut positional_maximums = vec![0usize; depth];
        for p in &profiles {
            for (i, &rid) in p.iter().enumerate() {
                positional_maximums[i] = positional_maximums[i].max(records.records[rid].size);
            }
        }
        OperatorProfiles {
            profiles,
            breadths,
            positional_maximums,
        }
    }

    /// Record ids alive at `op`, sorted by size descending.
    pub fn profile(&self, op: usize) -> &[usize] {
        &self.profiles[op]
    }

    /// Operator breadth of `op`.
    pub fn breadth(&self, op: usize) -> usize {
        self.breadths[op]
    }

    /// All breadths, indexed by op.
    pub fn breadths(&self) -> &[usize] {
        &self.breadths
    }

    /// The positional-maximum vector.
    pub fn positional_maximums(&self) -> &[usize] {
        &self.positional_maximums
    }

    /// §4.1 — the theoretical lower bound of the Shared Objects problem: the
    /// sum of positional maximums. "May not be achievable for some networks."
    pub fn shared_objects_lower_bound(&self) -> usize {
        self.positional_maximums.iter().sum()
    }

    /// §5.1 — the theoretical lower bound of the Offset Calculation problem:
    /// the maximum operator breadth.
    pub fn offset_lower_bound(&self) -> usize {
        self.breadths.iter().copied().max().unwrap_or(0)
    }

    /// Number of operators.
    pub fn num_ops(&self) -> usize {
        self.profiles.len()
    }

    /// Operators sorted by non-increasing breadth (ties: op index ascending)
    /// — the iteration order of Greedy by Breadth (§4.2 L.4).
    pub fn ops_by_breadth_desc(&self) -> Vec<usize> {
        let mut ops: Vec<usize> = (0..self.profiles.len()).collect();
        ops.sort_by(|&a, &b| self.breadths[b].cmp(&self.breadths[a]).then(a.cmp(&b)));
        ops
    }
}

/// Sort record indices in the canonical "non-increasing size" order used by
/// the greedy-by-size planners (§4.3 L.1): size descending, then interval
/// start ascending, then id — fully deterministic.
pub fn sort_ids_by_size_desc(records: &[UsageRecord], ids: &mut [usize]) {
    ids.sort_by(|&a, &b| {
        let (ra, rb) = (&records[a], &records[b]);
        rb.size
            .cmp(&ra.size)
            .then(ra.first_op.cmp(&rb.first_op))
            .then(ra.id.cmp(&rb.id))
    });
}

#[cfg(test)]
mod tests {
    use crate::models::example_records;

    #[test]
    fn figure_2_profiles() {
        let recs = example_records();
        let p = recs.profiles();
        // Figure 2(b): operator #3 has profile sizes {36, 28, 16},
        // breadth 80.
        let sizes: Vec<usize> = p.profile(3).iter().map(|&i| recs.records[i].size).collect();
        assert_eq!(sizes, vec![36, 28, 16]);
        assert_eq!(p.breadth(3), 80);
        // "the third positional maximum ... is equal to max(16,16,16,10)=16"
        assert_eq!(p.positional_maximums()[2], 16);
        let thirds: Vec<usize> = (0..p.num_ops())
            .filter(|&op| p.profile(op).len() >= 3)
            .map(|op| recs.records[p.profile(op)[2]].size)
            .collect();
        let mut sorted = thirds.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(sorted, vec![16, 16, 16, 10]);
    }

    #[test]
    fn lower_bounds_on_example() {
        let recs = example_records();
        let p = recs.profiles();
        // positional maxima: 64, 40, 16
        assert_eq!(p.positional_maximums(), &[64, 40, 16]);
        assert_eq!(p.shared_objects_lower_bound(), 120);
        // max breadth is op5: 64 + 40 + 10 = 114
        assert_eq!(p.offset_lower_bound(), 114);
    }

    #[test]
    fn breadth_ordering_is_deterministic() {
        let recs = example_records();
        let p = recs.profiles();
        let order = p.ops_by_breadth_desc();
        // breadths: op0=32, op1=84, op2=80, op3=80, op4=80, op5=114, op6=50
        assert_eq!(order[0], 5);
        assert_eq!(order[1], 1);
        // ties among ops 2,3,4 (80) break by index
        assert_eq!(&order[2..5], &[2, 3, 4]);
        assert_eq!(p.breadth(0), 32);
        assert_eq!(p.breadth(6), 50);
    }

    #[test]
    fn empty_records() {
        let recs = crate::records::UsageRecords::from_triples(&[]);
        let p = recs.profiles();
        assert_eq!(p.shared_objects_lower_bound(), 0);
        assert_eq!(p.offset_lower_bound(), 0);
        assert!(p.positional_maximums().is_empty());
    }
}
