//! Tensor usage records and operator profiles — §3 of the paper.
//!
//! * **Tensor usage interval** of tensor *t*: `{first_op_t, last_op_t}`, the
//!   indices of the first and last operator using *t* as input or output.
//! * **Tensor usage record**: the triple `{first_op_t, last_op_t, size_t}`
//!   with `size_t` the aligned byte size.
//! * **Operator profile** of op *op*: all records whose interval contains
//!   *op*.
//! * **Operator breadth**: the sum of sizes in the profile.
//! * **Positional maximum** *i*: max over ops of the *i*-th largest size in
//!   each profile.
//!
//! These are the only planner inputs; both planning approaches consume a
//! `&UsageRecords` and nothing else from the graph.

pub mod profile;

pub use profile::OperatorProfiles;

use crate::graph::{Graph, TensorId, TensorKind};
use crate::planner::Dtype;

/// Align `bytes` up to the 64-byte grid every record size lives on.
#[inline]
fn align64(bytes: usize) -> usize {
    bytes.div_ceil(64) * 64
}


/// One tensor usage record (§3). `id` is a dense index into the records
/// vector (not the graph tensor id); `tensor` links back to the graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UsageRecord {
    /// Dense record index.
    pub id: usize,
    /// Originating graph tensor, if the records came from a graph.
    pub tensor: Option<TensorId>,
    /// Index of the first op using this tensor (as output, for intermediates).
    pub first_op: usize,
    /// Index of the last op using this tensor as input.
    pub last_op: usize,
    /// Aligned size in bytes.
    pub size: usize,
}

impl UsageRecord {
    /// True if the two usage *intervals* intersect. Two tensors whose
    /// intervals intersect may never share memory (§3).
    #[inline]
    pub fn overlaps(&self, other: &UsageRecord) -> bool {
        self.first_op.max(other.first_op) <= self.last_op.min(other.last_op)
    }

    /// Distance between two non-overlapping intervals (the "gap" used by
    /// Greedy by Size Improved, §4.4); `None` if they overlap.
    #[inline]
    pub fn gap_to(&self, other: &UsageRecord) -> Option<usize> {
        if self.overlaps(other) {
            None
        } else if self.last_op < other.first_op {
            Some(other.first_op - self.last_op)
        } else {
            Some(self.first_op - other.last_op)
        }
    }
}

/// The full set of usage records of a graph, plus the number of ops —
/// everything a planner needs.
#[derive(Debug, Clone)]
pub struct UsageRecords {
    /// The records; `records[i].id == i` (dense).
    pub records: Vec<UsageRecord>,
    /// Number of ops in the graph the records were extracted from.
    pub num_ops: usize,
}

impl UsageRecords {
    /// Extract usage records for the intermediate tensors of a graph.
    ///
    /// `first_op` of an intermediate tensor is its producing op; `last_op`
    /// is its last consumer (or the producer itself if the value is unused —
    /// it must still exist while the op runs). Input/Output/Weight tensors
    /// are excluded per the paper.
    pub fn from_graph(graph: &Graph) -> Self {
        let mut first = vec![usize::MAX; graph.tensors.len()];
        let mut last = vec![0usize; graph.tensors.len()];
        for op in &graph.ops {
            for &t in op.inputs.iter().chain(op.outputs.iter()) {
                let i = op.id.0;
                first[t.0] = first[t.0].min(i);
                last[t.0] = last[t.0].max(i);
            }
        }
        let mut records = Vec::new();
        for t in graph.tensors.iter() {
            if t.kind != TensorKind::Intermediate || first[t.id.0] == usize::MAX {
                continue;
            }
            records.push(UsageRecord {
                id: records.len(),
                tensor: Some(t.id),
                first_op: first[t.id.0],
                last_op: last[t.id.0],
                size: t.aligned_size(),
            });
        }
        UsageRecords {
            records,
            num_ops: graph.ops.len(),
        }
    }

    /// Build records directly from `(first_op, last_op, size)` triples —
    /// used by tests, property tests, and synthetic workloads.
    pub fn from_triples(triples: &[(usize, usize, usize)]) -> Self {
        let num_ops = triples
            .iter()
            .map(|&(_, l, _)| l + 1)
            .max()
            .unwrap_or(0);
        let records = triples
            .iter()
            .enumerate()
            .map(|(i, &(f, l, s))| {
                assert!(f <= l, "record {i}: first_op {f} > last_op {l}");
                UsageRecord {
                    id: i,
                    tensor: None,
                    first_op: f,
                    last_op: l,
                    size: s,
                }
            })
            .collect();
        UsageRecords { records, num_ops }
    }

    /// The same records with every size multiplied by `batch` — what a
    /// batched inference uses per intermediate tensor (§3's records are
    /// per-sample; batching scales sizes, not liveness). Planners run on
    /// the scaled records; `crate::arena::Arena` then stripes each region
    /// into `batch` lanes.
    pub fn scaled(&self, batch: usize) -> UsageRecords {
        assert!(batch > 0, "batch must be positive");
        UsageRecords {
            records: self
                .records
                .iter()
                .map(|r| UsageRecord {
                    size: r.size.checked_mul(batch).expect("batch-scaled size overflows"),
                    ..*r
                })
                .collect(),
            num_ops: self.num_ops,
        }
    }

    /// The records scaled for `batch` lanes of `dtype` elements. The base
    /// (per-sample, f32) size first shrinks by the dtype's element width —
    /// re-aligned up to the 64-byte grid [`UsageRecords::from_graph`]
    /// sizes live on — and the quantized per-sample size then multiplies
    /// by `batch` exactly like [`UsageRecords::scaled`].
    /// [`Dtype::F32`] is the identity: `scaled_for(b, F32) == scaled(b)`.
    pub fn scaled_for(&self, batch: usize, dtype: Dtype) -> UsageRecords {
        if dtype == Dtype::F32 {
            return self.scaled(batch);
        }
        assert!(batch > 0, "batch must be positive");
        let divisor = 4 / dtype.element_bytes();
        UsageRecords {
            records: self
                .records
                .iter()
                .map(|r| UsageRecord {
                    size: align64(r.size.div_ceil(divisor))
                        .checked_mul(batch)
                        .expect("batch-scaled size overflows"),
                    ..*r
                })
                .collect(),
            num_ops: self.num_ops,
        }
    }

    /// Number of records.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// True if there are no records.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// The paper's **Naive** baseline: every intermediate tensor keeps its
    /// own buffer; footprint is the plain sum of sizes.
    pub fn naive_total(&self) -> usize {
        self.records.iter().map(|r| r.size).sum()
    }

    /// Compute operator profiles (cached views are in [`OperatorProfiles`]).
    pub fn profiles(&self) -> OperatorProfiles {
        OperatorProfiles::new(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::example_records;

    /// The paper's Figure 1/2 example: tensor sizes and intervals.
    #[test]
    fn example_net_records_match_figure_2() {
        let recs = example_records();
        // 8 intermediate tensors (#0..#7); #8 is the output.
        assert_eq!(recs.len(), 8);
        let by_tensor: Vec<(usize, usize, usize)> = recs
            .records
            .iter()
            .map(|r| (r.first_op, r.last_op, r.size))
            .collect();
        // Figure 2a: tensor #2 has usage record {1, 3, 36}.
        assert!(by_tensor.contains(&(1, 3, 36)));
        // all intervals are within op range
        for r in &recs.records {
            assert!(r.first_op <= r.last_op);
            assert!(r.last_op < recs.num_ops);
        }
    }

    #[test]
    fn overlap_and_gap() {
        let a = UsageRecord { id: 0, tensor: None, first_op: 0, last_op: 2, size: 1 };
        let b = UsageRecord { id: 1, tensor: None, first_op: 2, last_op: 4, size: 1 };
        let c = UsageRecord { id: 2, tensor: None, first_op: 5, last_op: 7, size: 1 };
        assert!(a.overlaps(&b));
        assert!(!a.overlaps(&c));
        assert_eq!(a.gap_to(&b), None);
        assert_eq!(a.gap_to(&c), Some(3));
        assert_eq!(c.gap_to(&a), Some(3));
        assert_eq!(b.gap_to(&c), Some(1));
    }

    #[test]
    fn from_triples_roundtrip() {
        let r = UsageRecords::from_triples(&[(0, 1, 32), (1, 2, 28), (2, 5, 8)]);
        assert_eq!(r.len(), 3);
        assert_eq!(r.num_ops, 6);
        assert_eq!(r.naive_total(), 68);
    }

    #[test]
    #[should_panic]
    fn from_triples_rejects_inverted_interval() {
        UsageRecords::from_triples(&[(3, 1, 32)]);
    }

    #[test]
    fn scaled_multiplies_sizes_only() {
        let r = UsageRecords::from_triples(&[(0, 1, 32), (1, 2, 28), (2, 5, 8)]);
        let s = r.scaled(4);
        assert_eq!(s.num_ops, r.num_ops);
        assert_eq!(s.naive_total(), 4 * r.naive_total());
        for (a, b) in r.records.iter().zip(s.records.iter()) {
            assert_eq!((a.id, a.tensor, a.first_op, a.last_op), (b.id, b.tensor, b.first_op, b.last_op));
            assert_eq!(b.size, 4 * a.size);
        }
        // batch 1 is the identity
        assert_eq!(r.scaled(1).naive_total(), r.naive_total());
    }

    #[test]
    #[should_panic(expected = "batch must be positive")]
    fn scaled_rejects_zero_batch() {
        UsageRecords::from_triples(&[(0, 1, 32)]).scaled(0);
    }

    #[test]
    fn scaled_for_shrinks_by_element_width_and_keeps_alignment() {
        let r = UsageRecords::from_triples(&[(0, 1, 256), (1, 2, 64), (2, 5, 192)]);
        // i8: /4, re-aligned to 64, then ×batch.
        let i8x2 = r.scaled_for(2, Dtype::I8);
        assert_eq!(
            i8x2.records.iter().map(|r| r.size).collect::<Vec<_>>(),
            vec![128, 128, 128] // (64, 16→64, 48→64) × 2
        );
        // f16: /2, re-aligned to 64.
        let f16x1 = r.scaled_for(1, Dtype::F16);
        assert_eq!(
            f16x1.records.iter().map(|r| r.size).collect::<Vec<_>>(),
            vec![128, 64, 128] // 128, 32→64, 96→128
        );
        // Liveness and identity fields never change.
        for (a, b) in r.records.iter().zip(i8x2.records.iter()) {
            assert_eq!(
                (a.id, a.tensor, a.first_op, a.last_op),
                (b.id, b.tensor, b.first_op, b.last_op)
            );
        }
        // Every quantized size stays on the 64-byte grid.
        for rec in i8x2.records.iter().chain(f16x1.records.iter()) {
            assert_eq!(rec.size % 64, 0);
        }
        // F32 is exactly scaled().
        for batch in [1, 3] {
            let a = r.scaled_for(batch, Dtype::F32);
            let b = r.scaled(batch);
            assert_eq!(
                a.records.iter().map(|r| r.size).collect::<Vec<_>>(),
                b.records.iter().map(|r| r.size).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    #[should_panic(expected = "batch must be positive")]
    fn scaled_for_rejects_zero_batch() {
        UsageRecords::from_triples(&[(0, 1, 32)]).scaled_for(0, Dtype::I8);
    }
}
