//! # tensorarena
//!
//! A production-oriented reproduction of **"Efficient Memory Management for
//! Deep Neural Net Inference"** (Pisarchyk & Lee, MLSys/SysML 2020) as a
//! three-layer Rust + JAX + Pallas inference stack.
//!
//! The paper's contribution — static memory planners that share buffers among
//! the intermediate tensors of a DNN inference graph — is implemented in
//! [`planner`], fed by the usage-record machinery of [`records`], over the
//! graph IR in [`graph`]. The planners are exercised three ways:
//!
//! 1. **Statically**, against the paper's six evaluation networks rebuilt
//!    layer-by-layer in [`models`] (Tables 1 and 2).
//! 2. **Behaviourally**, by the CPU graph executor in [`exec`] which runs a
//!    whole network with every intermediate tensor living inside the planned
//!    [`arena`] — an overlap bug corrupts real activations and is caught.
//! 3. **In serving**, by the [`coordinator`] which batches requests and runs
//!    AOT-compiled JAX models through the PJRT [`runtime`], with per-batch
//!    working memory sized by the planner.
//!
//! ## Quick start
//!
//! ```no_run
//! use tensorarena::models;
//! use tensorarena::records::UsageRecords;
//! use tensorarena::planner::{offset, shared, OffsetPlanner, SharedObjectPlanner};
//!
//! let graph = models::mobilenet_v1();
//! let records = UsageRecords::from_graph(&graph);
//! let plan = offset::GreedyBySize::default().plan(&records);
//! assert!(plan.validate(&records).is_ok());
//! println!("arena: {} bytes (naive {} bytes)",
//!          plan.total_size(), records.naive_total());
//! let shared = shared::GreedyBySizeImproved::default().plan(&records);
//! assert!(shared.validate(&records).is_ok());
//! ```
//!
//! For serving, go through the [`planner::PlanService`] instead of a
//! planner directly: every plan is identified by one typed
//! [`planner::PlanRequest`] — strategy, execution order, batch, and §7
//! dynamic resolution state as a single builder-style value — which is
//! simultaneously the cache key, the `.plan` file-name grammar, and the
//! construction argument of every engine:
//!
//! ```no_run
//! use tensorarena::models;
//! use tensorarena::planner::PlanService;
//! use tensorarena::records::UsageRecords;
//!
//! let service = PlanService::shared();
//! let records = UsageRecords::from_graph(&models::mobilenet_v1());
//! let req = service.request().with_batch(8); // default strategy, natural order
//! // Plan batch 8 once; every executor sharing the handle reuses it.
//! let plan = service.plan(&records, &req).unwrap();
//! println!("batch-8 arena: {} bytes", plan.total_size());
//! // Largest batch whose *planned* footprint fits a 64 MiB budget.
//! let max = service.max_servable_batch(&records, &req, 64 << 20).unwrap();
//! println!("max servable batch in 64 MiB: {max}");
//! println!("{:?}", service.stats());
//! ```
//!
//! The budget query drives admission in the [`coordinator`]: a
//! [`coordinator::BatchPolicy`] with `mem_budget` set clamps batches to the
//! planned envelope and refuses oversized bursts with a typed
//! [`coordinator::ServeError::BudgetExceeded`] instead of OOMing. The plan
//! cache itself persists to a *plan directory*
//! ([`planner::PlanCache::persist_dir`] /
//! [`planner::PlanCache::warm_start`], format documented in
//! [`planner::serialize`]), so a restarted server performs zero planner
//! invocations for shapes it has already served.
//!
//! Dynamically-sized tensors (§7) serve through the same cache: a
//! [`planner::DynamicRecords`] profile marks which sizes resolve
//! mid-inference, the §7 [`planner::MultiPassPlanner`] plans them in
//! frozen waves, and decode-step re-plans — requests carrying
//! [`planner::DynamicMode::Resolved`] — are keyed by the fingerprint of
//! the *resolved-size prefix*, so repeats cost zero planner invocations
//! ([`planner::PlanService::plan_dynamic`]) and budget admission resolves
//! under the worst-wave peak.
//!
//! The full architecture — layer dataflow, the plan-cache key, the
//! arena-pool lifecycle, and the normative `.plan` v2 directory format —
//! is documented in `docs/ARCHITECTURE.md` at the repository root.

#![warn(missing_docs)]

pub mod arena;
pub mod coordinator;
pub mod exec;
pub mod graph;
pub mod models;
pub mod planner;
pub mod records;
pub mod report;
pub mod rng;
/// PJRT runtime (needs the vendored `xla` crate; enable the `pjrt`
/// feature).
#[cfg(feature = "pjrt")]
pub mod runtime;

/// Byte alignment applied to every tensor buffer, matching TFLite's default
/// arena alignment. The paper defines `size_t` as the tensor's *aligned* size
/// in bytes.
pub const TENSOR_ALIGNMENT: usize = 64;

/// Round `n` up to [`TENSOR_ALIGNMENT`].
#[inline]
pub fn align(n: usize) -> usize {
    (n + TENSOR_ALIGNMENT - 1) / TENSOR_ALIGNMENT * TENSOR_ALIGNMENT
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn align_rounds_up_to_64() {
        assert_eq!(align(0), 0);
        assert_eq!(align(1), 64);
        assert_eq!(align(64), 64);
        assert_eq!(align(65), 128);
        assert_eq!(align(4 * 112 * 112 * 32), 4 * 112 * 112 * 32);
    }
}
