//! Level scheduling for the parallel executor: which compiled steps may run
//! concurrently, proven from the planner's lifetime intervals plus the
//! records' arena offset ranges.
//!
//! [`crate::graph::topo_levels`] gives dataflow-independent level sets, but
//! dataflow independence is *not* enough over a planned arena: the planner
//! deliberately aliases records with disjoint usage intervals, and both the
//! concurrency inside a level and the reordering *between* levels (level
//! order is a permutation of the sequential op order) could put a write on
//! top of bytes another still-live record owns. The schedule is therefore
//! built in two passes:
//!
//! 1. **Within a level**: steps are greedily packed into groups whose
//!    members' arena byte ranges are pairwise non-conflicting — no write
//!    range may intersect another member's write *or* read range (write
//!    ranges are tracked in a [`DisjointIntervalSet`], the planner's own
//!    interval structure; its insert assert doubles as a proof obligation).
//!    For records whose usage intervals overlap, plan validation already
//!    guarantees byte-disjointness, so a detected intersection can only
//!    involve lifetime-disjoint (aliased) records — exactly the pairs that
//!    must be serialized.
//! 2. **Across the whole schedule**: a liveness replay walks the groups in
//!    execution order, keeping the byte ranges of live records; if any
//!    produced record's range intersects a concurrently-live record, the
//!    level *order* itself would corrupt an aliased placement and the
//!    schedule is marked unsafe — the executor then falls back to
//!    sequential execution for that plan (outputs are unaffected either
//!    way; this is purely a go/no-go for parallel dispatch).
//!
//! A safe schedule executes groups in order, members of one group
//! concurrently, and yields outputs bit-identical to sequential execution:
//! every read observes exactly the bytes its producer wrote, and the
//! kernels themselves are deterministic.

use super::{Loc, Step};
use crate::planner::interval_tree::DisjointIntervalSet;

/// One concurrency group: steps that run at the same time (singletons run
/// inline on the coordinating thread).
pub(super) struct Group {
    /// Step indices; all members have arena outputs when `len() > 1`.
    pub(super) members: Vec<usize>,
    /// Records whose *schedule-order* death is this group — poisoned after
    /// the group completes when poisoning is enabled. (The sequential
    /// per-step `dies` table cannot be used here: level order may run a
    /// record's highest-id consumer before a later-level lower-id one.)
    pub(super) poison: Vec<usize>,
}

/// The parallel execution schedule of one (plan, batch) residency.
pub(super) struct Schedule {
    /// Groups in execution order.
    pub(super) groups: Vec<Group>,
    /// Depth of the dataflow DAG (number of level sets).
    pub(super) levels: usize,
    /// Largest group size — 1 means the schedule has no parallelism.
    pub(super) width: usize,
    /// False if the liveness replay found the level order would violate an
    /// aliased placement; the executor must then run sequentially.
    pub(super) safe: bool,
}

/// Half-open byte-range intersection.
#[inline]
fn intersects(a: (usize, usize), b: (usize, usize)) -> bool {
    a.0 < b.1 && b.0 < a.1
}

/// In-progress group: members plus the byte ranges they touch. Writes live
/// in a [`DisjointIntervalSet`] (closed intervals), whose insert-time
/// assert re-proves pairwise write disjointness in debug builds.
struct GroupAcc {
    members: Vec<usize>,
    writes: DisjointIntervalSet,
    write_list: Vec<(usize, usize)>,
    reads: Vec<(usize, usize)>,
}

impl GroupAcc {
    fn new() -> Self {
        GroupAcc {
            members: Vec::new(),
            writes: DisjointIntervalSet::new(),
            write_list: Vec::new(),
            reads: Vec::new(),
        }
    }

    /// May a step writing `w` and reading `reads` join this group?
    fn fits(&self, w: (usize, usize), reads: &[(usize, usize)]) -> bool {
        debug_assert!(w.0 < w.1, "empty write range");
        if self.writes.overlaps(w.0, w.1 - 1) {
            return false;
        }
        if self.reads.iter().any(|&r| intersects(r, w)) {
            return false;
        }
        reads
            .iter()
            .all(|&(s, e)| e == s || !self.writes.overlaps(s, e - 1))
    }

    fn push(&mut self, si: usize, w: (usize, usize), reads: Vec<(usize, usize)>) {
        self.members.push(si);
        self.writes.insert(w.0, w.1 - 1);
        self.write_list.push(w);
        self.reads.extend(reads);
    }
}

/// Build the schedule for `steps` over the level sets of the graph, with
/// `span_of` mapping a record id to its byte range in the resident arena
/// (all lanes — conservative for any single lane).
pub(super) fn build_schedule(
    steps: &[Step],
    level_sets: &[Vec<usize>],
    num_records: usize,
    span_of: &dyn Fn(usize) -> (usize, usize),
) -> Schedule {
    let mut groups: Vec<Vec<usize>> = Vec::new();
    let mut width = 1usize;
    for level in level_sets {
        let mut accs: Vec<GroupAcc> = Vec::new();
        let mut io_out: Vec<usize> = Vec::new();
        for &si in level {
            let step = &steps[si];
            let Loc::Arena(orec) = step.out else {
                // Io-output steps mutate executor-owned buffers; they run
                // inline as singleton groups after the level's arena work.
                io_out.push(si);
                continue;
            };
            let w = span_of(orec);
            let reads: Vec<(usize, usize)> = step
                .ins
                .iter()
                .filter_map(|l| match l {
                    Loc::Arena(r) => Some(span_of(*r)),
                    _ => None,
                })
                .collect();
            match accs.iter_mut().find(|acc| acc.fits(w, &reads)) {
                Some(acc) => acc.push(si, w, reads),
                None => {
                    let mut acc = GroupAcc::new();
                    acc.push(si, w, reads);
                    accs.push(acc);
                }
            }
        }
        for acc in accs {
            width = width.max(acc.members.len());
            groups.push(acc.members);
        }
        for si in io_out {
            groups.push(vec![si]);
        }
    }

    // Positions, then per-record produce/death groups in schedule order.
    let mut pos_of = vec![0usize; steps.len()];
    for (g, members) in groups.iter().enumerate() {
        for &si in members {
            pos_of[si] = g;
        }
    }
    let mut produced_at: Vec<Option<usize>> = vec![None; num_records];
    let mut death_at: Vec<usize> = vec![0; num_records];
    for (si, step) in steps.iter().enumerate() {
        if let Loc::Arena(orec) = step.out {
            produced_at[orec] = Some(pos_of[si]);
            death_at[orec] = death_at[orec].max(pos_of[si]);
        }
        for l in &step.ins {
            if let Loc::Arena(r) = l {
                death_at[*r] = death_at[*r].max(pos_of[si]);
            }
        }
    }

    // Liveness replay: would this execution order write over a live
    // (aliased) record?
    let mut live: Vec<(usize, usize, usize)> = Vec::new();
    let mut safe = true;
    for (g, members) in groups.iter().enumerate() {
        let mut produced_now: Vec<(usize, usize, usize)> = Vec::new();
        for &si in members {
            if let Loc::Arena(orec) = steps[si].out {
                let (s, e) = span_of(orec);
                if live
                    .iter()
                    .any(|&(r, ls, le)| r != orec && intersects((ls, le), (s, e)))
                {
                    safe = false;
                }
                produced_now.push((orec, s, e));
            }
        }
        live.extend(produced_now);
        live.retain(|&(r, _, _)| death_at[r] != g);
    }

    let poison_of = |g: usize| -> Vec<usize> {
        (0..num_records)
            .filter(|&r| produced_at[r].is_some() && death_at[r] == g)
            .collect()
    };
    let groups = groups
        .into_iter()
        .enumerate()
        .map(|(g, members)| Group { members, poison: poison_of(g) })
        .collect();
    Schedule { groups, levels: level_sets.len(), width, safe }
}

#[cfg(test)]
mod tests {
    use super::super::Instr;
    use super::*;

    fn step(ins: Vec<Loc>, out: Loc) -> Step {
        Step { instr: Instr::CopyThrough, ins, out, dies: Vec::new() }
    }

    /// Spans from a table: record id -> (start, end).
    fn spans(table: Vec<(usize, usize)>) -> impl Fn(usize) -> (usize, usize) {
        move |r| table[r]
    }

    #[test]
    fn chain_graph_is_all_singletons_and_safe() {
        // in(io) -> r0 -> r1 -> out(io), one op per level.
        let steps = vec![
            step(vec![Loc::Io(0)], Loc::Arena(0)),
            step(vec![Loc::Arena(0)], Loc::Arena(1)),
            step(vec![Loc::Arena(1)], Loc::Io(1)),
        ];
        let levels = vec![vec![0], vec![1], vec![2]];
        let span = spans(vec![(0, 64), (64, 128)]);
        let sched = build_schedule(&steps, &levels, 2, &span);
        assert!(sched.safe);
        assert_eq!(sched.levels, 3);
        assert_eq!(sched.width, 1);
        assert_eq!(sched.groups.len(), 3);
        // Record 0 dies at the group running step 1; record 1 at step 2's.
        assert_eq!(sched.groups[1].poison, vec![0]);
        assert_eq!(sched.groups[2].poison, vec![1]);
    }

    #[test]
    fn independent_disjoint_ops_share_a_group() {
        // Two towers off one input, disjoint spans, then a join.
        let steps = vec![
            step(vec![Loc::Io(0)], Loc::Arena(0)),
            step(vec![Loc::Arena(0)], Loc::Arena(1)),
            step(vec![Loc::Arena(0)], Loc::Arena(2)),
            step(vec![Loc::Arena(1), Loc::Arena(2)], Loc::Arena(3)),
        ];
        let levels = vec![vec![0], vec![1, 2], vec![3]];
        let span = spans(vec![(0, 64), (64, 128), (128, 192), (0, 64)]);
        let sched = build_schedule(&steps, &levels, 4, &span);
        assert!(sched.safe);
        assert_eq!(sched.width, 2);
        let wide: Vec<_> = sched.groups.iter().filter(|g| g.members.len() == 2).collect();
        assert_eq!(wide.len(), 1);
        assert_eq!(wide[0].members, vec![1, 2]);
    }

    #[test]
    fn aliased_same_level_writes_are_serialized() {
        // Steps 1 and 2 are dataflow-independent but their output byte
        // ranges overlap: they must not share a group. Record 1 is never
        // read afterwards (it dies at its producer), so the *serialized*
        // order is still safe — the replay keeps the schedule usable.
        let steps = vec![
            step(vec![Loc::Io(0)], Loc::Arena(0)),
            step(vec![Loc::Arena(0)], Loc::Arena(1)),
            step(vec![Loc::Arena(0)], Loc::Arena(2)),
        ];
        let levels = vec![vec![0], vec![1, 2]];
        // records 1 and 2 overlap in bytes
        let span = spans(vec![(0, 64), (64, 128), (96, 160)]);
        let sched = build_schedule(&steps, &levels, 3, &span);
        assert!(sched.groups.iter().all(|g| g.members.len() == 1));
        assert!(sched.safe, "serialized aliased writes with no later reader are safe");
    }

    #[test]
    fn reader_of_aliased_bytes_is_serialized_after_the_writer() {
        // Step 2 writes bytes that step 1 reads (record 0 aliases record
        // 2): same level, must not run concurrently.
        let steps = vec![
            step(vec![Loc::Io(0)], Loc::Arena(0)),
            step(vec![Loc::Arena(0)], Loc::Arena(1)),
            step(vec![Loc::Io(0)], Loc::Arena(2)),
        ];
        let levels = vec![vec![0], vec![1, 2]];
        let span = spans(vec![(0, 64), (64, 128), (0, 64)]);
        let sched = build_schedule(&steps, &levels, 3, &span);
        let wide = sched.groups.iter().find(|g| g.members.len() > 1);
        assert!(wide.is_none(), "aliased reader/writer grouped together");
    }

    #[test]
    fn cross_level_alias_marks_schedule_unsafe() {
        // A plan that is valid *sequentially* but broken under level order:
        // record 0 lives over ops [0, 2]; record 3 (same bytes) is written
        // at op 3, strictly after — disjoint lifetimes, legal alias. But
        // op 3 reads only the graph input, so its *level* is 0, and level
        // order runs it before op 2 reads record 0. The replay must refuse
        // this schedule.
        let steps = vec![
            step(vec![Loc::Io(0)], Loc::Arena(0)),
            step(vec![Loc::Arena(0)], Loc::Arena(1)),
            step(vec![Loc::Arena(0), Loc::Arena(1)], Loc::Arena(2)),
            step(vec![Loc::Io(0)], Loc::Arena(3)),
        ];
        let levels = vec![vec![0, 3], vec![1], vec![2]];
        let span = spans(vec![(0, 64), (64, 128), (128, 192), (0, 64)]);
        let sched = build_schedule(&steps, &levels, 4, &span);
        // Ops 0 and 3 were kept apart (overlapping writes) ...
        assert!(sched.groups.iter().all(|g| g.members.len() == 1));
        // ... but serialization cannot help: record 3's write still lands
        // before record 0's last read.
        assert!(!sched.safe, "live-range clobber not detected");
    }
}
