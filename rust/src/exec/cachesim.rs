//! Stack-distance cache simulator for the paper's locality claim.
//!
//! §1: "Efficiently reusing memory buffers leads to improved cache hit rate
//! that can also translate to up to 10% improvement in inference speed."
//! The authors measured wall-clock on phones; we substitute a classic
//! Mattson stack-distance simulation over the executor's memory trace: one
//! pass computes the LRU hit rate for *every* cache size at once, so the
//! naive-vs-planned comparison needs no hardware at all.
//!
//! The trace walks the graph in execution order; for each op it touches the
//! cache lines of its activation inputs, weights, and output — exactly the
//! access pattern of `exec::Executor`. Arena placements give different line
//! addresses under different plans, which is the entire effect under test.
//! Intermediate footprints come from the *usage records*, so the quantized
//! size classes ([`crate::planner::Dtype`], via
//! [`UsageRecords::scaled_for`]) shrink the trace exactly as they shrink
//! the arena; records smaller than one line still round up to a full line.

use crate::graph::{Graph, TensorKind};
use crate::planner::OffsetPlan;
use crate::records::UsageRecords;
use std::collections::HashMap;

/// Cache line size used by the simulator (bytes).
pub const LINE: usize = 64;

/// Result of a simulation: the stack-distance histogram.
#[derive(Debug, Clone)]
pub struct DistanceHistogram {
    /// `counts[d]` = number of accesses with stack distance `d` (in lines);
    /// cold misses are in `cold`.
    counts: Vec<u64>,
    cold: u64,
    total: u64,
}

impl DistanceHistogram {
    /// LRU hit rate for a cache of `bytes` capacity.
    pub fn hit_rate(&self, bytes: usize) -> f64 {
        let lines = bytes / LINE;
        let hits: u64 = self.counts.iter().take(lines).sum();
        if self.total == 0 {
            0.0
        } else {
            hits as f64 / self.total as f64
        }
    }

    /// Total accesses (lines touched, with repetition).
    pub fn total_accesses(&self) -> u64 {
        self.total
    }

    /// LRU misses (cold plus capacity) for a cache of `bytes` capacity —
    /// the absolute complement of [`Self::hit_rate`].
    pub fn misses(&self, bytes: usize) -> u64 {
        let lines = bytes / LINE;
        let hits: u64 = self.counts.iter().take(lines).sum();
        self.total - hits
    }

    /// Compulsory (cold) misses.
    pub fn cold_misses(&self) -> u64 {
        self.cold
    }
}

/// Fenwick tree for counting distinct lines between accesses.
struct Fenwick {
    tree: Vec<i64>,
}

impl Fenwick {
    fn new(n: usize) -> Self {
        Fenwick { tree: vec![0; n + 1] }
    }
    fn add(&mut self, mut i: usize, v: i64) {
        i += 1;
        while i < self.tree.len() {
            self.tree[i] += v;
            i += i & i.wrapping_neg();
        }
    }
    fn prefix(&self, mut i: usize) -> i64 {
        // sum of [0, i)
        let mut s = 0;
        while i > 0 {
            s += self.tree[i];
            i -= i & i.wrapping_neg();
        }
        s
    }
}

/// Build the line-granular access trace of one inference and return its
/// stack-distance histogram. Address spaces: the arena occupies
/// `[0, plan.total)`; weights and graph I/O are laid out after it (they
/// exist exactly once regardless of plan, so they shift both plans' traces
/// identically).
pub fn simulate(graph: &Graph, records: &UsageRecords, plan: &OffsetPlan) -> DistanceHistogram {
    let order: Vec<usize> = (0..graph.ops.len()).collect();
    simulate_order(graph, records, plan, &order)
}

/// [`simulate`] under the parallel executor's *level-order* traversal: ops
/// are visited level set by level set ([`crate::graph::topo_levels`])
/// instead of sequential op order — the access pattern the level-scheduled
/// executor produces. Falls back to sequential order when the graph has no
/// level decomposition.
pub fn simulate_levels(
    graph: &Graph,
    records: &UsageRecords,
    plan: &OffsetPlan,
) -> DistanceHistogram {
    let order: Vec<usize> = match crate::graph::topo_levels(graph) {
        Some(ls) => ls.into_iter().flatten().map(|o| o.0).collect(),
        None => (0..graph.ops.len()).collect(),
    };
    simulate_order(graph, records, plan, &order)
}

/// Shared simulator core: build the trace by visiting ops in `order`.
fn simulate_order(
    graph: &Graph,
    records: &UsageRecords,
    plan: &OffsetPlan,
    order: &[usize],
) -> DistanceHistogram {
    // Line base address per tensor.
    let mut rec_of = vec![None; graph.tensors.len()];
    for r in &records.records {
        if let Some(t) = r.tensor {
            rec_of[t.0] = Some(r.id);
        }
    }
    let mut next_free = (plan.total + LINE - 1) / LINE;
    let mut base_lines = vec![0usize; graph.tensors.len()];
    let mut len_lines = vec![0usize; graph.tensors.len()];
    for t in &graph.tensors {
        // Intermediates take their footprint from the *records* — which
        // quantized size classes shrink (`UsageRecords::scaled_for`) —
        // not from the graph tensor; a record smaller than one line still
        // occupies a full line, hence the explicit round-up.
        let lines = match t.kind {
            TensorKind::Intermediate => {
                records.records[rec_of[t.id.0].unwrap()].size.div_ceil(LINE)
            }
            _ => t.aligned_size().div_ceil(LINE),
        };
        len_lines[t.id.0] = lines;
        base_lines[t.id.0] = match t.kind {
            TensorKind::Intermediate => plan.offsets[rec_of[t.id.0].unwrap()] / LINE,
            _ => {
                let b = next_free;
                next_free += lines;
                b
            }
        };
    }

    // Mattson single-pass: Fenwick over trace positions.
    // Trace length bound: sum of op I/O lines.
    let mut trace_len = 0usize;
    for op in &graph.ops {
        for &t in op.inputs.iter().chain(op.outputs.iter()) {
            trace_len += len_lines[t.0];
        }
    }
    let mut fen = Fenwick::new(trace_len + 1);
    let mut last_access: HashMap<usize, usize> = HashMap::new();
    let mut counts = vec![0u64; 1 << 20]; // up to 64 MiB distances, binned exactly
    let mut cold = 0u64;
    let mut total = 0u64;
    let mut now = 0usize;

    let mut touch = |line: usize, now: &mut usize, fen: &mut Fenwick, cold: &mut u64, total: &mut u64, counts: &mut Vec<u64>| {
        *total += 1;
        match last_access.insert(line, *now) {
            None => *cold += 1,
            Some(prev) => {
                // distinct lines touched in (prev, now)
                let d = (fen.prefix(*now) - fen.prefix(prev + 1)) as usize;
                if d < counts.len() {
                    counts[d] += 1;
                }
                fen.add(prev, -1);
            }
        }
        fen.add(*now, 1);
        *now += 1;
    };

    for &oi in order {
        let op = &graph.ops[oi];
        // Read inputs (activations then weights), then write the outputs —
        // the executor's order.
        for &t in &op.inputs {
            let b = base_lines[t.0];
            for l in 0..len_lines[t.0] {
                touch(b + l, &mut now, &mut fen, &mut cold, &mut total, &mut counts);
            }
        }
        for &t in &op.outputs {
            let b = base_lines[t.0];
            for l in 0..len_lines[t.0] {
                touch(b + l, &mut now, &mut fen, &mut cold, &mut total, &mut counts);
            }
        }
    }
    DistanceHistogram { counts, cold, total }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::offset::{GreedyBySize, NaiveOffset};
    use crate::planner::OffsetPlanner;

    #[test]
    fn planned_arena_beats_naive_at_cache_sized_working_sets() {
        let g = crate::models::blazeface();
        let recs = UsageRecords::from_graph(&g);
        let planned = simulate(&g, &recs, &GreedyBySize.plan(&recs));
        let naive = simulate(&g, &recs, &NaiveOffset.plan(&recs));
        assert_eq!(planned.total_accesses(), naive.total_accesses());
        // At an L2-ish 256 KiB, reuse must strictly help.
        let hp = planned.hit_rate(256 * 1024);
        let hn = naive.hit_rate(256 * 1024);
        assert!(
            hp > hn,
            "planned hit rate {hp:.4} should beat naive {hn:.4}"
        );
        // And naive has more cold misses (more distinct lines).
        assert!(planned.cold_misses() < naive.cold_misses());
    }

    #[test]
    fn planned_beats_naive_under_level_order_traversal() {
        // The level-scheduled executor permutes op order; the plan's
        // locality win must survive that traversal too.
        let g = crate::models::blazeface();
        let recs = UsageRecords::from_graph(&g);
        let planned = simulate_levels(&g, &recs, &GreedyBySize.plan(&recs));
        let naive = simulate_levels(&g, &recs, &NaiveOffset.plan(&recs));
        // Level order visits every op exactly once: same trace length as
        // the sequential simulation.
        let seq = simulate(&g, &recs, &GreedyBySize.plan(&recs));
        assert_eq!(planned.total_accesses(), seq.total_accesses());
        assert_eq!(planned.total_accesses(), naive.total_accesses());
        let hp = planned.hit_rate(256 * 1024);
        let hn = naive.hit_rate(256 * 1024);
        assert!(
            hp > hn,
            "planned hit rate {hp:.4} should beat naive {hn:.4} in level order"
        );
        assert!(planned.cold_misses() < naive.cold_misses());
    }

    #[test]
    fn hit_rate_monotone_in_cache_size() {
        let g = crate::models::example_net();
        let recs = UsageRecords::from_graph(&g);
        let h = simulate(&g, &recs, &GreedyBySize.plan(&recs));
        let mut prev = 0.0;
        for kb in [1, 4, 16, 64, 256] {
            let r = h.hit_rate(kb * 1024);
            assert!(r >= prev);
            prev = r;
        }
        assert!(prev <= 1.0);
    }

    #[test]
    fn sub_line_records_round_up_to_a_full_line() {
        // Records smaller than one cache line must still touch one line —
        // a floor would erase them from the trace entirely.
        let g = crate::models::example_net();
        let mut recs = UsageRecords::from_graph(&g);
        for r in &mut recs.records {
            r.size = 16;
        }
        let plan = NaiveOffset.plan(&recs);
        let h = simulate(&g, &recs, &plan);
        // Hand count: every intermediate touch is exactly one line; the
        // other tensors contribute their aligned line counts.
        let rec_tensors: std::collections::HashSet<usize> =
            recs.records.iter().filter_map(|r| r.tensor.map(|t| t.0)).collect();
        let mut expect = 0u64;
        for op in &g.ops {
            for &t in op.inputs.iter().chain(op.outputs.iter()) {
                expect += if rec_tensors.contains(&t.0) {
                    1
                } else {
                    g.tensor(t).aligned_size().div_ceil(LINE) as u64
                };
            }
        }
        assert_eq!(h.total_accesses(), expect);
        assert!(h.total_accesses() > 0);
    }

    #[test]
    fn i8_size_class_reduces_predicted_misses_on_the_same_strategy() {
        use crate::planner::Dtype;
        let g = crate::models::blazeface();
        let base = UsageRecords::from_graph(&g);
        let f32_recs = base.scaled_for(1, Dtype::F32);
        let i8_recs = base.scaled_for(1, Dtype::I8);
        let hf = simulate(&g, &f32_recs, &GreedyBySize.plan(&f32_recs));
        let hi = simulate(&g, &i8_recs, &GreedyBySize.plan(&i8_recs));
        // Quarter-width intermediates touch fewer lines, miss less cold,
        // and miss less at an L2-ish capacity.
        assert!(hi.total_accesses() < hf.total_accesses());
        assert!(hi.cold_misses() < hf.cold_misses());
        assert!(hi.misses(256 * 1024) < hf.misses(256 * 1024));
    }

    #[test]
    fn fenwick_prefix_sums() {
        let mut f = Fenwick::new(8);
        f.add(0, 1);
        f.add(3, 2);
        f.add(7, 5);
        assert_eq!(f.prefix(0), 0);
        assert_eq!(f.prefix(1), 1);
        assert_eq!(f.prefix(4), 3);
        assert_eq!(f.prefix(8), 8);
        f.add(3, -2);
        assert_eq!(f.prefix(8), 6);
    }
}
