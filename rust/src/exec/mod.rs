//! Behavioural executor: runs a whole graph with every intermediate tensor
//! living inside a planned arena.
//!
//! This is the second line of defence after `planner::validate`: a plan
//! that aliases two live tensors produces *wrong numbers* here, which the
//! integration tests catch by comparing against the same graph run under
//! the Naive plan (every tensor private). It is also the measurement
//! substrate for the paper's locality claim (§1: better buffer reuse →
//! better cache hit rate → up to 10% faster inference), see
//! [`cachesim`] and `benches/locality.rs`.
//!
//! The executor "compiles" the graph once into a flat instruction list with
//! pre-resolved buffer locations, then `run` is a tight interpret loop with
//! zero allocation besides the op kernels' work.
//!
//! **Parallel execution** (`set_threads` / `serve --threads`): with more
//! than one thread the executor runs batches in *lockstep* — worker threads
//! own contiguous lane chunks and synchronize per step, so at any instant
//! every thread executes the same op, whose tensors are simultaneously live
//! and therefore byte-disjoint by plan validation — and runs single-sample
//! inferences through a level schedule ([`levels`]) that proves same-level
//! ops non-aliasing from the planner's lifetime intervals plus their arena
//! offset ranges. Both modes produce outputs bit-identical to sequential
//! execution; both fall back to the sequential loop when the proof does not
//! hold (or in §7 wave mode, whose per-op re-resolution is inherently
//! sequential).
//!
//! **Quantized serving** (`PlanRequest::with_dtype`): arena payloads are
//! stored packed at the request's i8/f16 size class — the arena shrinks by
//! the element width — and every step runs the `f32` kernels on
//! dequantized scratch, re-quantizing arena outputs at their producing
//! step (see [`ops::quant`]). Quantized mode always executes sequentially
//! and serves statically (no wave, paged, or continuous modes).

pub mod cachesim;
mod levels;
pub mod ops;

use crate::arena::paged::PagedArena;
use crate::arena::{Arena, ArenaPool, ParallelArena};
use crate::graph::{topo_levels, Graph, OpKind, PoolKind, TensorKind};
use crate::planner::{
    registry, Dtype, DynamicMode, DynamicRecords, MultiPassPlan, OffsetPlan, OffsetPlanner,
    OrderStrategy, PlanError, PlanRequest, PlanService,
};
use crate::records::UsageRecords;
use crate::rng::SplitMix64;
use ops::Geom;
pub use ops::KernelMode;
use std::sync::Arc;

/// Where a tensor's storage lives at run time.
#[derive(Debug, Clone, Copy)]
enum Loc {
    /// Intermediate: record id inside the arena.
    Arena(usize),
    /// Graph input/output: index into the executor's private I/O buffers.
    Io(usize),
    /// Weight: index into the weight store.
    Weight(usize),
}

/// One compiled instruction.
enum Instr {
    Conv { ic: usize, oc: usize, geom: Geom, act: crate::graph::Activation },
    Dw { c: usize, geom: Geom, act: crate::graph::Activation },
    MaxPool { c: usize, geom: Geom },
    AvgPool { c: usize, geom: Geom },
    Gap { hw: usize, c: usize },
    Add { act: crate::graph::Activation },
    Mul,
    Concat { parts_c: Vec<usize>, pixels: usize },
    Fc { ind: usize, outd: usize, act: crate::graph::Activation },
    Softmax { cols: usize },
    Relu { max: Option<f32> },
    Sigmoid,
    Resize { h: usize, w: usize, oh: usize, ow: usize, c: usize },
    CopyThrough,
    Pad { h: usize, w: usize, c: usize, before: (usize, usize), after: (usize, usize) },
}

struct Step {
    instr: Instr,
    ins: Vec<Loc>,
    out: Loc,
    /// Records whose last use is this op (poisoned after execution when
    /// poisoning is enabled).
    dies: Vec<usize>,
}

/// State of the §7 wave-aware execution mode: the dynamic profile being
/// served, the op indices at which waves resolve, and the resident
/// complete multi-pass plan whose worst-wave peak sized the arena.
struct WaveState {
    /// Batch-1 dynamic records of the served graph.
    dynamic: DynamicRecords,
    /// Distinct non-zero `known_at` values, ascending: after executing op
    /// `boundaries[i]`, a wave of sizes resolves and offsets are
    /// re-resolved from the pre-resolved envelope below.
    boundaries: Vec<usize>,
    /// The resolved-prefix plan per boundary at the current batch, pulled
    /// through the service's dynamic cache by [`Executor::prewarm_waves`]
    /// at build and batch growth. Holding the `Arc`s here keeps the
    /// per-sample hot path free of hashing and cache locks (and immune to
    /// FIFO eviction); the cache remains the cross-executor amortization
    /// layer.
    prefix_plans: Vec<Arc<MultiPassPlan>>,
    /// The resident complete plan at the current batch — what wave
    /// re-resolutions are checked against (the §7 freeze invariant).
    full: Arc<MultiPassPlan>,
    /// Wave-boundary offset re-resolutions performed so far (each one is a
    /// decode-step plan lookup: a dynamic cache hit after the first
    /// inference).
    resolutions: u64,
}

/// State of the paged decode-tail execution mode: the resident arena
/// hosts only the *static prefix* of the §7 multi-pass plan, and every
/// dynamic-tail record maps its region onto fixed-size blocks from the
/// shared [`BlockPool`](crate::arena::paged::BlockPool) for exactly its
/// usage interval — acquired at its wave boundary, released (and
/// immediately servable to other executors on the pool) at its death.
struct PagedState {
    /// Batch-1 dynamic records of the served graph.
    dynamic: DynamicRecords,
    /// Per-record single-lane payload words for tail records (`Some` iff
    /// `known_at > 0`); resident-prefix records are `None`.
    tail_words: Vec<Option<usize>>,
    /// The block mapping of the sequential `run_batch` path, where lanes
    /// run one after another so at most one lane's tail stripes are
    /// mapped at any instant and the tail's block demand is
    /// batch-invariant. Continuous lanes ([`Executor::lane_open`]) do
    /// *not* share this mapping — each open lane carries a private
    /// [`PagedArena`] in its [`LaneRun`], so simultaneously-live lanes
    /// each contribute their own tail block demand.
    arena: PagedArena,
    /// Contiguous gather/scatter scratch, reused across paged steps.
    scratch: Vec<f32>,
    /// Pass count of the complete multi-pass plan (for stats parity with
    /// the resident wave mode).
    passes: usize,
    /// Tail block mappings performed so far — the paged analogue of wave
    /// offset re-resolutions.
    resolutions: u64,
    /// Per-sample naive total of the *real* records (the doctored
    /// resident records zero every tail size).
    naive1: usize,
}

/// One in-flight continuous-decode lane: a request admitted into the
/// paged executor mid-stream ([`Executor::lane_open`]), advancing one
/// wave at a time ([`Executor::lane_advance`]) interleaved with other
/// lanes. Everything a lane mutates is private — io buffers (the shared
/// slots are scratch the sequential loop reuses across lanes), the tail
/// block mapping (a [`PagedArena`] keys mappings by record id, so
/// simultaneously-live lanes need one each), and gather/scatter scratch
/// — while resident-prefix tensors use the lane's own byte-disjoint
/// arena stripes. Interleaving therefore cannot change any lane's
/// values: outputs are bit-identical to running the lane alone.
struct LaneRun {
    /// Private io buffers, cloned from the executor's prototype with the
    /// lane's input loaded (the lockstep path's per-lane rule).
    io: Vec<Vec<f32>>,
    /// Private tail-block mapping; dropped (blocks released to the
    /// shared pool) when the lane finishes or aborts.
    parena: PagedArena,
    /// Private contiguous gather/scatter scratch.
    scratch: Vec<f32>,
    /// Next step to execute; the lane is finished when this reaches the
    /// step count.
    next_step: usize,
}

/// State of the quantized size-class execution mode: arena stripes hold
/// activations packed at the request's dtype (4 `i8` codes or 2 `f16`
/// halves per `f32` word — see [`ops::quant`]), with per-record affine
/// parameters rewritten at each record's producing step from the values
/// just produced. Kernels still run in `f32`: every step dequantizes its
/// arena operands into contiguous scratch, dispatches the ordinary
/// kernel, and re-quantizes an arena output back into its element-width
/// shrunk stripe. Serving is sequential per lane, like wave mode.
struct QuantState {
    /// The size class (never [`Dtype::F32`] — f32 requests carry no state).
    dtype: Dtype,
    /// Per-record affine parameters, rewritten at the record's producer.
    qparams: Vec<ops::quant::QParams>,
    /// Per-record payload element counts — exact, excluding alignment
    /// padding (padding is never quantized).
    n_vals: Vec<usize>,
    /// Contiguous dequantize/requantize scratch, reused across steps.
    scratch: Vec<f32>,
}

/// Graph executor over a planned arena.
pub struct Executor {
    steps: Vec<Step>,
    arena: Arena,
    weights: Vec<Vec<f32>>,
    io: Vec<Vec<f32>>,
    /// io indices of graph inputs / outputs, in graph order.
    input_io: Vec<usize>,
    output_io: Vec<usize>,
    plan_total: usize,
    naive_total: usize,
    poison_dead: bool,
    /// Batch-1 records, kept for batch-scaled re-planning.
    base_records: UsageRecords,
    /// The typed plan identity every re-plan goes through: strategy and
    /// execution order as one [`PlanRequest`] (its batch tracks the
    /// resident batch; its dynamic mode is set per lookup). `None` for
    /// explicit plans — such executors cannot change batch size.
    request: Option<PlanRequest>,
    /// Shared plan cache, when constructed through one.
    service: Option<Arc<PlanService>>,
    /// Arena buffer pool (the service's, or a private one).
    pool: Arc<ArenaPool>,
    /// Current batch: the arena is planned for `base_records.scaled(batch)`
    /// and striped into `batch` lanes.
    batch: usize,
    /// §7 wave-aware mode (None = static serving). When set, the arena is
    /// sized at the worst-wave multi-pass peak and offsets are re-resolved
    /// through the plan cache at every wave boundary.
    waves: Option<WaveState>,
    /// Paged decode-tail mode (None = resident serving; mutually
    /// exclusive with `waves`): the arena hosts only the static prefix,
    /// tail records live on pooled blocks.
    paged: Option<PagedState>,
    /// Quantized size-class mode (None = f32 serving; mutually exclusive
    /// with `waves` and `paged`): arena payloads are packed at the
    /// request's dtype and steps run on dequantized scratch.
    quant: Option<QuantState>,
    /// Worker threads for `run`/`run_batch` (1 = sequential).
    threads: usize,
    /// Which kernel family `dispatch` routes hot ops to.
    mode: KernelMode,
    /// Step indices per dataflow level (batch-invariant; step index == op
    /// id). Empty if the graph had no valid level decomposition.
    level_sets: Vec<Vec<usize>>,
    /// The parallel schedule of the *resident* plan — rebuilt on every
    /// arena swap, since aliasing depends on the batch-scaled offsets.
    schedule: levels::Schedule,
    /// Op executions dispatched to parallel workers so far.
    ops_parallel: u64,
    /// Continuous-decode lanes in flight (paged mode only), indexed by
    /// arena lane. `Some` slots are open lanes; sized lazily to `batch`.
    lane_runs: Vec<Option<LaneRun>>,
}

impl Executor {
    /// Plan `graph` with `planner`, validate, allocate the arena, and
    /// synthesize deterministic weights from `seed`. If the planner is a
    /// registry strategy (by display name), batch re-plans stay possible;
    /// a custom planner pins the executor to batch 1 like an explicit
    /// plan.
    pub fn new(graph: &Graph, planner: &dyn OffsetPlanner, seed: u64) -> Result<Self, String> {
        let records = UsageRecords::from_graph(graph);
        let plan = planner.plan(&records);
        plan.validate(&records).map_err(|e| e.to_string())?;
        let request =
            registry::offset_key(planner.name()).map(|k| PlanRequest::new().with_strategy_key(k));
        Self::build(
            graph,
            records,
            &plan,
            seed,
            request,
            None,
            Arc::new(ArenaPool::new()),
            1,
        )
        .map_err(|e| e.to_string())
    }

    /// The one typed construction path: plan `graph` through a shared
    /// [`PlanService`] as the [`PlanRequest`] describes — the plan comes
    /// from the service's cache (one planner invocation per `(model,
    /// request)` across every executor sharing the handle) and the arena
    /// buffer from its pool. `graph` must already be reordered under
    /// `req.order()` (see [`crate::planner::apply_order`] — the
    /// coordinator's engines do this before construction), so this
    /// executor's steps run in that order and every plan lookup —
    /// construction, batch growth, budget probes — lands in the
    /// request-keyed cache slot. The arena is pre-sized for `req.batch()`.
    ///
    /// With a `dynamic` profile the executor serves **wave-aware** (§7):
    /// the arena is sized at the worst-wave peak of the complete
    /// multi-pass plan (so mid-inference growth is already hosted), and at
    /// every wave boundary the executor re-resolves the newly-known
    /// records' offsets through the service's resolved-prefix cache slot —
    /// a planner invocation on the first inference, a cache hit on every
    /// repeat (the decode-step amortization of §7). The request's own
    /// [`DynamicMode`] is normalized away: the executor derives the
    /// per-boundary `Resolved` modes itself. Without a profile the request
    /// must be static.
    pub fn with_request(
        graph: &Graph,
        service: Arc<PlanService>,
        req: &PlanRequest,
        dynamic: Option<DynamicRecords>,
        seed: u64,
    ) -> Result<Self, String> {
        let base = req.with_dynamic(DynamicMode::Static);
        match dynamic {
            Some(profile) => {
                if req.dtype() != Dtype::F32 {
                    return Err(format!(
                        "quantized request '{req}' cannot serve a dynamic profile: \
                         i8/f16 size classes are static-mode only"
                    ));
                }
                Self::build_dynamic(graph, service, base, profile, seed)
            }
            None => {
                if !req.dynamic().is_static() {
                    return Err(format!(
                        "dynamic request '{req}' needs a DynamicRecords profile"
                    ));
                }
                // Plan directly at the requested batch — exactly one
                // planner invocation and one arena acquisition at
                // construction, with no never-served batch-1 plan left
                // resident (or persisted) when the request asks for more.
                let records = UsageRecords::from_graph(graph);
                let plan = service.plan(&records, &base).map_err(|e| e.to_string())?;
                let pool = Arc::clone(service.pool());
                Self::build(
                    graph,
                    records,
                    &plan,
                    seed,
                    Some(base),
                    Some(service),
                    pool,
                    base.batch(),
                )
                .map_err(|e| e.to_string())
            }
        }
    }

    /// [`Self::with_request`] without an order or profile: plan `graph`
    /// through a shared [`PlanService`] under `strategy` (any registry key
    /// or display name), natural order, batch 1.
    pub fn with_service(
        graph: &Graph,
        service: Arc<PlanService>,
        strategy: &str,
        seed: u64,
    ) -> Result<Self, String> {
        let req = PlanRequest::new().with_strategy(strategy).map_err(|e| e.to_string())?;
        Self::with_request(graph, service, &req, None, seed)
    }

    /// [`Self::with_request`] with untyped `(strategy, order)` arguments.
    #[deprecated(since = "0.3.0", note = "build a PlanRequest and call with_request")]
    pub fn with_service_ordered(
        graph: &Graph,
        service: Arc<PlanService>,
        strategy: &str,
        order: OrderStrategy,
        seed: u64,
    ) -> Result<Self, String> {
        let req = PlanRequest::new()
            .with_strategy(strategy)
            .map_err(|e| e.to_string())?
            .with_order(order);
        Self::with_request(graph, service, &req, None, seed)
    }

    /// Build with an explicit (already validated) plan. Such executors are
    /// pinned to batch 1: without a registry strategy there is nothing to
    /// re-plan batch-scaled records with.
    pub fn with_plan(
        graph: &Graph,
        records: &UsageRecords,
        plan: &OffsetPlan,
        seed: u64,
    ) -> Result<Self, PlanError> {
        Self::build(
            graph,
            records.clone(),
            plan,
            seed,
            None,
            None,
            Arc::new(ArenaPool::new()),
            1,
        )
    }

    /// `plan` must be the plan of `base_records.scaled(batch)`; the arena
    /// is allocated at that batch, striped into `batch` lanes.
    #[allow(clippy::too_many_arguments)]
    fn build(
        graph: &Graph,
        base_records: UsageRecords,
        plan: &OffsetPlan,
        seed: u64,
        request: Option<PlanRequest>,
        service: Option<Arc<PlanService>>,
        pool: Arc<ArenaPool>,
        batch: usize,
    ) -> Result<Self, PlanError> {
        let records = &base_records;
        let dtype = request.map_or(Dtype::F32, |r| r.dtype());
        let scaled = records.scaled_for(batch, dtype);
        plan.validate(&scaled)?;
        // tensor id -> record id
        let mut rec_of = vec![None; graph.tensors.len()];
        for r in &records.records {
            if let Some(t) = r.tensor {
                rec_of[t.0] = Some(r.id);
            }
        }
        let mut rng = SplitMix64::new(seed);
        let mut weights: Vec<Vec<f32>> = Vec::new();
        let mut io: Vec<Vec<f32>> = Vec::new();
        let mut loc = vec![None; graph.tensors.len()];
        for t in &graph.tensors {
            loc[t.id.0] = Some(match t.kind {
                TensorKind::Intermediate => Loc::Arena(rec_of[t.id.0].expect("record")),
                TensorKind::Weight => {
                    let mut buf = vec![0f32; t.num_elements()];
                    // He-style init: scale by 1/sqrt(fan_in) so activation
                    // variance neither explodes nor dies across deep nets
                    // (a dead net would make behavioural plan checks
                    // vacuous — identical outputs for any input).
                    let fan_in: usize = if t.shape.len() > 1 {
                        t.shape[..t.shape.len() - 1].iter().product()
                    } else {
                        1
                    };
                    let scale = 1.6 / (fan_in as f32).sqrt();
                    rng.fill_f32(&mut buf, scale);
                    weights.push(buf);
                    Loc::Weight(weights.len() - 1)
                }
                TensorKind::Input | TensorKind::Output => {
                    io.push(vec![0f32; t.num_elements()]);
                    Loc::Io(io.len() - 1)
                }
            });
        }
        let loc = |tid: crate::graph::TensorId| loc[tid.0].unwrap();

        // Death table.
        let mut dies_at: Vec<Vec<usize>> = vec![Vec::new(); graph.ops.len()];
        for r in &records.records {
            dies_at[r.last_op].push(r.id);
        }

        let mut steps = Vec::with_capacity(graph.ops.len());
        for op in &graph.ops {
            if op.outputs.len() != 1 {
                return Err(PlanError::WrongArity { expected: 1, got: op.outputs.len() });
            }
            let out_id = op.outputs[0];
            let shape_of = |tid: crate::graph::TensorId| graph.tensor(tid).shape.clone();
            let in0 = shape_of(op.inputs[0]);
            let out_s = shape_of(out_id);
            let instr = match &op.kind {
                OpKind::Conv2d { kernel, stride, padding, dilation, activation } => Instr::Conv {
                    ic: in0[3],
                    oc: out_s[3],
                    geom: Geom::new(in0[1], in0[2], out_s[1], out_s[2], *kernel, *stride, *dilation, *padding),
                    act: *activation,
                },
                OpKind::DepthwiseConv2d { kernel, stride, padding, dilation, activation } => Instr::Dw {
                    c: in0[3],
                    geom: Geom::new(in0[1], in0[2], out_s[1], out_s[2], *kernel, *stride, *dilation, *padding),
                    act: *activation,
                },
                OpKind::Pool2d { kind, kernel, stride, padding } => {
                    let geom = Geom::new(in0[1], in0[2], out_s[1], out_s[2], *kernel, *stride, (1, 1), *padding);
                    match kind {
                        PoolKind::Max => Instr::MaxPool { c: in0[3], geom },
                        PoolKind::Average => Instr::AvgPool { c: in0[3], geom },
                    }
                }
                OpKind::GlobalAveragePool => Instr::Gap { hw: in0[1] * in0[2], c: in0[3] },
                OpKind::Add { activation } => Instr::Add { act: *activation },
                OpKind::Mul => Instr::Mul,
                OpKind::ConcatChannels => Instr::Concat {
                    parts_c: op
                        .inputs
                        .iter()
                        .map(|&t| *shape_of(t).last().unwrap())
                        .collect(),
                    pixels: out_s[..out_s.len() - 1].iter().product(),
                },
                OpKind::FullyConnected { activation } => Instr::Fc {
                    ind: in0.iter().skip(1).product(),
                    outd: out_s[1],
                    act: *activation,
                },
                OpKind::Softmax => Instr::Softmax { cols: *out_s.last().unwrap() },
                OpKind::Relu { max } => Instr::Relu { max: *max },
                OpKind::Sigmoid => Instr::Sigmoid,
                OpKind::ResizeBilinear { out } => Instr::Resize {
                    h: in0[1],
                    w: in0[2],
                    oh: out.0,
                    ow: out.1,
                    c: in0[3],
                },
                OpKind::Reshape | OpKind::Elementwise { .. } => Instr::CopyThrough,
                OpKind::Pad { before, after } => Instr::Pad {
                    h: in0[1],
                    w: in0[2],
                    c: in0[3],
                    before: *before,
                    after: *after,
                },
            };
            steps.push(Step {
                instr,
                ins: op.inputs.iter().map(|&t| loc(t)).collect(),
                out: loc(out_id),
                dies: std::mem::take(&mut dies_at[op.id.0]),
            });
        }

        let input_io = graph
            .inputs
            .iter()
            .map(|&t| match loc(t) {
                Loc::Io(i) => i,
                _ => unreachable!(),
            })
            .collect();
        let output_io = graph
            .outputs
            .iter()
            .map(|&t| match loc(t) {
                Loc::Io(i) => i,
                _ => unreachable!(),
            })
            .collect();

        let arena = Arena::from_pool(plan, &scaled, batch, &pool);
        let naive_total = scaled.naive_total();
        // Step index == op id (steps were built in graph order), so the
        // graph's dataflow levels map directly onto step indices. The
        // schedule additionally depends on the resident plan's offsets and
        // is rebuilt on every arena swap.
        let level_sets: Vec<Vec<usize>> = topo_levels(graph)
            .map(|ls| {
                ls.into_iter()
                    .map(|lv| lv.into_iter().map(|o| o.0).collect())
                    .collect()
            })
            .unwrap_or_default();
        let span_of = |r: usize| arena.record_span(r);
        let schedule = levels::build_schedule(&steps, &level_sets, base_records.len(), &span_of);
        // Quantized size classes store arena payloads packed; per-record
        // parameters start at identity and are rewritten at each record's
        // producing step.
        let quant = (dtype != Dtype::F32).then(|| QuantState {
            dtype,
            qparams: vec![ops::quant::QParams::IDENTITY; records.len()],
            n_vals: records
                .records
                .iter()
                .map(|r| {
                    let t = r.tensor.expect("quantized requests need graph-derived records");
                    graph.tensor(t).num_elements()
                })
                .collect(),
            scratch: Vec::new(),
        });
        Ok(Executor {
            steps,
            arena,
            weights,
            io,
            input_io,
            output_io,
            plan_total: plan.total,
            naive_total,
            poison_dead: false,
            base_records,
            request,
            service,
            pool,
            batch,
            waves: None,
            paged: None,
            quant,
            threads: 1,
            mode: KernelMode::default(),
            level_sets,
            schedule,
            ops_parallel: 0,
            lane_runs: Vec::new(),
        })
    }

    /// [`Self::with_request`] with untyped `(strategy, order)` arguments
    /// and a dynamic profile.
    #[deprecated(since = "0.3.0", note = "build a PlanRequest and call with_request")]
    pub fn with_service_dynamic(
        graph: &Graph,
        service: Arc<PlanService>,
        strategy: &str,
        order: OrderStrategy,
        dynamic: DynamicRecords,
        seed: u64,
    ) -> Result<Self, String> {
        let req = PlanRequest::new()
            .with_strategy(strategy)
            .map_err(|e| e.to_string())?
            .with_order(order);
        Self::with_request(graph, service, &req, Some(dynamic), seed)
    }

    /// The §7 wave-aware construction behind [`Self::with_request`]:
    /// `dynamic` assigns each of the graph's records a `known_at` op (see
    /// [`DynamicRecords`]); the request must already be normalized to
    /// static mode (the caller strips the dynamic dimension — this path
    /// derives its own resolution states).
    fn build_dynamic(
        graph: &Graph,
        service: Arc<PlanService>,
        req: PlanRequest,
        dynamic: DynamicRecords,
        seed: u64,
    ) -> Result<Self, String> {
        let records = UsageRecords::from_graph(graph);
        validate_dynamic_profile(&records, &dynamic)?;
        // Plan the complete multi-pass plan directly at the requested
        // batch: one planner invocation, one arena sized at that batch's
        // worst-wave peak, no never-served batch-1 plan.
        let full = service
            .plan_dynamic(&dynamic, &req.with_dynamic(DynamicMode::FullyResolved))
            .map_err(|e| e.to_string())?;
        let plan = full
            .offset_plan()
            .ok_or("complete dynamic plan left a record unplaced")?;
        let pool = Arc::clone(service.pool());
        let mut ex = Self::build(
            graph,
            records,
            &plan,
            seed,
            Some(req),
            Some(service),
            pool,
            req.batch(),
        )
        .map_err(|e| e.to_string())?;
        ex.waves = Some(WaveState {
            boundaries: dynamic.boundaries(),
            prefix_plans: Vec::new(),
            dynamic,
            full,
            resolutions: 0,
        });
        // Pre-resolve the wave envelope for the resident batch, so the
        // very first inference's boundaries already have resident prefix
        // plans.
        ex.prewarm_waves()?;
        Ok(ex)
    }

    /// Paged decode-tail construction: like [`Self::with_request`] with a
    /// dynamic profile, but instead of sizing the resident arena at the
    /// worst-wave peak, the arena hosts only the **static prefix** (the
    /// `Resolved(0)` wave of the multi-pass plan) and every dynamic-tail
    /// record maps onto fixed 64-byte-aligned blocks from the service
    /// pool's shared [`BlockPool`] for exactly its usage interval —
    /// acquired at its wave boundary, released the moment it dies, so its
    /// memory is immediately servable to other requests on the pool.
    /// Paged steps gather their operands into contiguous scratch, run
    /// the *same* kernels, and scatter back: outputs are bit-identical to
    /// the resident wave-aware path (and to static execution).
    ///
    /// [`BlockPool`]: crate::arena::paged::BlockPool
    pub fn with_request_paged(
        graph: &Graph,
        service: Arc<PlanService>,
        req: &PlanRequest,
        dynamic: DynamicRecords,
        seed: u64,
    ) -> Result<Self, String> {
        if req.dtype() != Dtype::F32 {
            return Err(format!(
                "quantized request '{req}' cannot serve paged: \
                 i8/f16 size classes are static-mode only"
            ));
        }
        let base = req.with_dynamic(DynamicMode::Static);
        let records = UsageRecords::from_graph(graph);
        validate_dynamic_profile(&records, &dynamic)?;
        // The complete plan is still consulted — its pass count feeds the
        // serving stats and its feasibility catches degenerate profiles —
        // but only the static-prefix plan sizes the resident arena.
        let full = service
            .plan_dynamic(&dynamic, &base.with_dynamic(DynamicMode::FullyResolved))
            .map_err(|e| e.to_string())?;
        let prefix = service
            .plan_dynamic(&dynamic, &base.with_dynamic(DynamicMode::Resolved(0)))
            .map_err(|e| e.to_string())?;
        // Doctor the resident records: tail records live on blocks, so
        // they occupy zero resident bytes (any offset is valid for a
        // zero-byte range — unresolved prefix offsets default to 0).
        let naive1 = records.naive_total();
        let tail_words: Vec<Option<usize>> = dynamic
            .records
            .iter()
            .map(|d| (d.known_at > 0).then_some(d.record.size / 4))
            .collect();
        let mut doctored = records;
        for (r, tw) in doctored.records.iter_mut().zip(&tail_words) {
            if tw.is_some() {
                r.size = 0;
            }
        }
        let plan = OffsetPlan {
            offsets: (0..doctored.len())
                .map(|id| prefix.offset_of(id).unwrap_or(0))
                .collect(),
            total: prefix.peak,
        };
        let pool = Arc::clone(service.pool());
        let num_records = doctored.len();
        let mut ex = Self::build(
            graph,
            doctored,
            &plan,
            seed,
            Some(base),
            Some(service),
            Arc::clone(&pool),
            base.batch(),
        )
        .map_err(|e| e.to_string())?;
        // The doctored records zeroed the tail; report the real naive
        // footprint.
        ex.naive_total = naive1 * base.batch();
        ex.paged = Some(PagedState {
            dynamic,
            tail_words,
            arena: PagedArena::new(pool, num_records),
            scratch: Vec::new(),
            passes: full.passes,
            resolutions: 0,
            naive1,
        });
        Ok(ex)
    }

    /// Pre-resolve every wave prefix for the resident batch through the
    /// service cache and pin the resulting plans in [`WaveState`] — the §7
    /// analogue of the batcher's spawn-time envelope pre-resolution: after
    /// this, the per-op wave boundaries on the hot path touch neither the
    /// planner nor the cache lock. No-op in static mode.
    fn prewarm_waves(&mut self) -> Result<(), String> {
        let Some(ws) = self.waves.as_mut() else { return Ok(()) };
        let Some(svc) = self.service.as_ref() else { return Ok(()) };
        let Some(req) = self.request else { return Ok(()) };
        let req = req.with_batch(self.batch);
        let mut plans = Vec::with_capacity(ws.boundaries.len());
        for &b in &ws.boundaries {
            plans.push(
                svc.plan_dynamic(&ws.dynamic, &req.with_dynamic(DynamicMode::Resolved(b)))
                    .map_err(|e| e.to_string())?,
            );
        }
        ws.prefix_plans = plans;
        Ok(())
    }

    /// Arena footprint in bytes (of the current batch's plan).
    pub fn arena_bytes(&self) -> usize {
        self.plan_total
    }

    /// What the Naive plan would have used at the current batch.
    pub fn naive_bytes(&self) -> usize {
        self.naive_total
    }

    /// Batch size the resident arena is planned for.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// The quantized element size class this executor serves under
    /// ([`Dtype::F32`] on the ordinary f32 path).
    pub fn dtype(&self) -> Dtype {
        self.quant.as_ref().map_or(Dtype::F32, |q| q.dtype)
    }

    /// The batch-1 usage records this executor was planned from — the
    /// input to budget queries ([`PlanService::max_servable_batch`]) and
    /// plan-directory warm starts.
    pub fn base_records(&self) -> &UsageRecords {
        &self.base_records
    }

    /// Enable poisoning of dead tensors: any read-after-free becomes NaN.
    pub fn set_poison_dead(&mut self, on: bool) {
        self.poison_dead = on;
    }

    /// Set the worker-thread count (clamped to at least 1). With more than
    /// one thread, `run_batch` runs lanes in lockstep across workers and
    /// single-sample runs use the level schedule when its aliasing proof
    /// holds; §7 wave mode and quantized mode always execute sequentially
    /// (per-op offset re-resolution and per-record re-quantization are
    /// order-dependent).
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    /// Worker-thread count for `run`/`run_batch`.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Route hot ops through the vectorized kernels (default) or the
    /// retained scalar references (`KernelMode::Reference`) — the baseline
    /// leg of the benchmark trajectory.
    pub fn set_kernel_mode(&mut self, mode: KernelMode) {
        self.mode = mode;
    }

    /// Which kernel family hot ops currently dispatch to.
    pub fn kernel_mode(&self) -> KernelMode {
        self.mode
    }

    /// Dataflow depth of the graph: number of level sets in the parallel
    /// schedule (0 if no level decomposition was possible).
    pub fn levels(&self) -> usize {
        self.schedule.levels
    }

    /// Whether the resident plan's level schedule passed its aliasing
    /// proof — if false, threaded single-sample runs fall back to the
    /// sequential loop.
    pub fn schedule_safe(&self) -> bool {
        self.schedule.safe
    }

    /// Op executions dispatched to parallel workers so far (monotonic,
    /// like [`Self::wave_resolutions`]).
    pub fn ops_parallel(&self) -> u64 {
        self.ops_parallel
    }

    /// Run one inference. `inputs` in graph-input order; returns outputs in
    /// graph-output order.
    pub fn run(&mut self, inputs: &[&[f32]]) -> Vec<Vec<f32>> {
        self.run_lane(inputs, 0)
    }

    /// Re-plan for `batch` (through the service cache when available) and
    /// swap the resident arena through the pool. No-op when the batch is
    /// already resident.
    pub fn ensure_batch(&mut self, batch: usize) -> Result<(), String> {
        if batch == 0 {
            return Err("batch must be positive".into());
        }
        if batch == self.batch {
            return Ok(());
        }
        if self.lanes_live() > 0 {
            // A re-plan swaps the resident arena out from under every
            // open lane's prefix stripes.
            return Err("cannot re-plan for a new batch while continuous lanes are open".into());
        }
        let scaled = self
            .base_records
            .scaled_for(batch, self.request.map_or(Dtype::F32, |r| r.dtype()));
        let plan: Arc<OffsetPlan> = match (&self.service, &self.request) {
            (Some(svc), Some(req)) => {
                let req = req.with_batch(batch);
                if let Some(ps) = &self.paged {
                    // Paged mode: the resident arena hosts only the
                    // static prefix; re-plan that prefix at the new batch
                    // and keep the tail on blocks (whose per-lane demand
                    // is batch-invariant).
                    let mp = svc
                        .plan_dynamic(
                            &ps.dynamic,
                            &req.with_dynamic(DynamicMode::Resolved(0)),
                        )
                        .map_err(|e| e.to_string())?;
                    Arc::new(OffsetPlan {
                        offsets: (0..self.base_records.len())
                            .map(|id| mp.offset_of(id).unwrap_or(0))
                            .collect(),
                        total: mp.peak,
                    })
                } else if let Some(ws) = &mut self.waves {
                    // Wave-aware mode: the new batch's arena is sized at
                    // the (batch-scaled) worst-wave peak, and the resident
                    // full plan swaps with it so wave re-resolutions keep
                    // checking against the right placements.
                    let mp = svc
                        .plan_dynamic(
                            &ws.dynamic,
                            &req.with_dynamic(DynamicMode::FullyResolved),
                        )
                        .map_err(|e| e.to_string())?;
                    let plan = Arc::new(
                        mp.offset_plan()
                            .ok_or("complete dynamic plan left a record unplaced")?,
                    );
                    ws.full = mp;
                    plan
                } else {
                    svc.plan(&self.base_records, &req).map_err(|e| e.to_string())?
                }
            }
            (None, Some(req)) => {
                // Typed key: the registry lookup cannot fail for a
                // canonical strategy key.
                let planner =
                    registry::offset_strategy(req.strategy()).expect("canonical key resolves");
                let p = planner.plan(&scaled);
                p.validate(&scaled).map_err(|e| e.to_string())?;
                Arc::new(p)
            }
            (Some(_), None) | (None, None) => {
                return Err(
                    "executor was built with an explicit plan; it cannot re-plan for a new batch"
                        .into(),
                )
            }
        };
        // Retire the old arena first so its buffer is available for the new
        // one when the size classes match.
        let old = std::mem::replace(&mut self.arena, Arena::empty());
        old.recycle(&self.pool);
        self.arena = Arena::from_pool(&plan, &scaled, batch, &self.pool);
        self.plan_total = plan.total;
        self.naive_total = scaled.naive_total();
        self.batch = batch;
        // Keep the stored identity in step with the resident batch.
        self.request = self.request.map(|r| r.with_batch(batch));
        // The parallel schedule proves non-aliasing against the resident
        // offsets, which just changed.
        let span_of = |r: usize| self.arena.record_span(r);
        self.schedule =
            levels::build_schedule(&self.steps, &self.level_sets, self.base_records.len(), &span_of);
        if let Some(ps) = &mut self.paged {
            // The doctored records zero the tail; the naive total must
            // come from the real per-sample records. Between batches no
            // tail mapping should survive — sweep defensively.
            self.naive_total = ps.naive1 * batch;
            ps.arena.release_all();
        }
        // Wave-aware mode: pre-resolve the new batch's wave envelope so
        // the post-swap hot path stays planner-free.
        self.prewarm_waves()?;
        Ok(())
    }

    /// Run a whole batch against one resident arena: the batch-scaled
    /// records are planned once (cached across executors when a
    /// [`PlanService`] is attached) and each sample executes in its own
    /// arena lane. The resident arena only ever *grows* — serving `n`
    /// smaller than the largest batch seen runs in the first `n` lanes, so
    /// fluctuating batch sizes cost no re-planning, no arena swap, and no
    /// buffer zeroing on the hot path. `input` holds `n` concatenated
    /// samples of the (single) graph input; returns the `n` concatenated
    /// first graph outputs — the serving payload.
    pub fn run_batch(&mut self, input: &[f32], n: usize) -> Result<Vec<f32>, String> {
        if n == 0 {
            return Err("batch must be positive".into());
        }
        if self.lanes_live() > 0 {
            // The sequential loop reuses the shared io scratch and lane
            // stripes continuous lanes may occupy.
            return Err("cannot run_batch while continuous lanes are open".into());
        }
        if self.input_io.len() != 1 {
            return Err(format!(
                "run_batch supports single-input graphs; this graph has {} inputs",
                self.input_io.len()
            ));
        }
        let in_elems = self.io[self.input_io[0]].len();
        let out_elems = self.io[self.output_io[0]].len();
        if input.len() != n * in_elems {
            return Err(format!(
                "batch input has {} elems, expected {n} x {in_elems}",
                input.len()
            ));
        }
        if n > self.batch {
            self.ensure_batch(n)?;
        }
        if self.threads > 1
            && n > 1
            && self.waves.is_none()
            && self.paged.is_none()
            && self.quant.is_none()
        {
            return self.run_batch_lockstep(input, n, in_elems, out_elems);
        }
        let mut out = Vec::with_capacity(n * out_elems);
        for i in 0..n {
            let sample = &input[i * in_elems..(i + 1) * in_elems];
            let res = self.run_lane(&[sample], i);
            out.extend_from_slice(&res[0]);
        }
        Ok(out)
    }

    /// Lockstep batch parallelism: workers own contiguous lane chunks and
    /// march through the step list synchronized per step by a barrier, so
    /// at any instant every thread executes the *same* op (on its own
    /// lanes). That is the whole aliasing proof: every record an op touches
    /// is live at that op, plan validation makes simultaneously-live
    /// records byte-disjoint, and same-record lane stripes are disjoint by
    /// the arena's striped layout — so no two threads can ever hold
    /// overlapping bytes. Free-running workers would not have this
    /// property: a thread at op `i` and another at op `j` can touch
    /// records whose spans alias (they are never live together
    /// *sequentially*). Each worker interprets its lanes against private
    /// io-buffer copies; outputs land in disjoint chunks of one payload
    /// vector, bit-identical to the sequential loop (same kernels, same
    /// per-lane step order).
    fn run_batch_lockstep(
        &mut self,
        input: &[f32],
        n: usize,
        in_elems: usize,
        out_elems: usize,
    ) -> Result<Vec<f32>, String> {
        let workers = self.threads.min(n);
        let poison = self.poison_dead;
        let mode = self.mode;
        let num_steps = self.steps.len();
        let steps = &self.steps;
        let weights = &self.weights;
        let io_proto = &self.io;
        let input_slot = self.input_io[0];
        let out_slot = self.output_io[0];
        let view = self.arena.parallel_view();
        let barrier = std::sync::Barrier::new(workers);
        let mut out = vec![0f32; n * out_elems];
        std::thread::scope(|s| {
            let mut rest: &mut [f32] = out.as_mut_slice();
            let mut lo = 0usize;
            for w in 0..workers {
                let hi = ((w + 1) * n) / workers;
                let (chunk, tail) = rest.split_at_mut((hi - lo) * out_elems);
                rest = tail;
                let barrier = &barrier;
                let view = &view;
                let lanes = lo..hi;
                s.spawn(move || {
                    // Private per-lane io buffers: io slots are scratch the
                    // sequential loop reuses across lanes, so concurrent
                    // lanes each need their own copy.
                    let mut ios: Vec<Vec<Vec<f32>>> =
                        lanes.clone().map(|_| io_proto.clone()).collect();
                    for (k, lane) in lanes.clone().enumerate() {
                        ios[k][input_slot]
                            .copy_from_slice(&input[lane * in_elems..(lane + 1) * in_elems]);
                    }
                    for step in steps.iter() {
                        for (k, lane) in lanes.clone().enumerate() {
                            exec_step_in_worker(step, &mut ios[k], weights, view, lane, mode);
                            if poison {
                                for &r in &step.dies {
                                    // SAFETY: `r` dies at this step, so it is
                                    // live here — its span is disjoint from
                                    // every other record concurrent workers
                                    // touch at this same step, and its own
                                    // stripes are per-lane disjoint.
                                    unsafe { view.poison_lane(r, lane) };
                                }
                            }
                        }
                        barrier.wait();
                    }
                    for (k, ios_k) in ios.iter().enumerate() {
                        chunk[k * out_elems..(k + 1) * out_elems].copy_from_slice(&ios_k[out_slot]);
                    }
                });
                lo = hi;
            }
        });
        drop(view);
        if workers > 1 {
            self.ops_parallel += (n * num_steps) as u64;
        }
        debug_assert!(self.arena.guards_intact(), "arena guard overwritten");
        Ok(out)
    }

    /// Run one sample in arena lane `lane` (see [`Arena::split_io_lane`]).
    fn run_lane(&mut self, inputs: &[&[f32]], lane: usize) -> Vec<Vec<f32>> {
        debug_assert!(lane < self.batch);
        assert_eq!(inputs.len(), self.input_io.len(), "wrong input count");
        for (&ioi, data) in self.input_io.iter().zip(inputs.iter()) {
            self.io[ioi].copy_from_slice(data);
        }
        if self.threads > 1
            && self.waves.is_none()
            && self.paged.is_none()
            && self.quant.is_none()
            && self.schedule.safe
            && self.schedule.width > 1
        {
            self.run_lane_scheduled(lane);
        } else if self.quant.is_some() {
            for si in 0..self.steps.len() {
                self.exec_step_quant(si, lane);
            }
        } else if self.paged.is_some() {
            for si in 0..self.steps.len() {
                self.exec_step_paged(si, lane);
            }
        } else {
            for si in 0..self.steps.len() {
                self.exec_step(si, lane);
                if self.waves.is_some() {
                    self.resolve_waves_after(si);
                }
            }
        }
        self.output_io
            .iter()
            .map(|&ioi| self.io[ioi].clone())
            .collect()
    }

    /// §7 wave boundary: if executing op `op` resolved a wave of sizes,
    /// re-resolve the newly-known records' offsets from the pre-resolved
    /// envelope ([`Self::prewarm_waves`] pulled each prefix plan through
    /// the service's resolved-prefix cache slot — one multi-pass planner
    /// invocation per prefix for the whole service lifetime, shared by
    /// every executor on the handle). Placements re-resolved here must
    /// agree with the resident full plan (the freeze invariant), which
    /// debug builds assert.
    fn resolve_waves_after(&mut self, op: usize) {
        let Some(ws) = self.waves.as_mut() else { return };
        let Ok(idx) = ws.boundaries.binary_search(&op) else { return };
        let prefix = &ws.prefix_plans[idx];
        ws.resolutions += 1;
        debug_assert!(
            prefix
                .wave_records
                .last()
                .map_or(true, |ids| {
                    ids.iter().all(|&id| prefix.offset_of(id) == ws.full.offset_of(id))
                }),
            "wave re-resolution moved a frozen placement (freeze invariant broken)"
        );
    }

    /// Planner passes of the resident §7 multi-pass plan (0 = static
    /// mode; in paged mode, the pass count of the complete plan the
    /// prefix was frozen from).
    pub fn wave_passes(&self) -> usize {
        self.waves
            .as_ref()
            .map(|w| w.full.passes)
            .or_else(|| self.paged.as_ref().map(|p| p.passes))
            .unwrap_or(0)
    }

    /// Wave-boundary offset re-resolutions performed so far (0 = static
    /// mode); each was a decode-step plan-cache lookup. In paged mode:
    /// tail block mappings performed at wave boundaries.
    pub fn wave_resolutions(&self) -> u64 {
        self.waves
            .as_ref()
            .map(|w| w.resolutions)
            .or_else(|| self.paged.as_ref().map(|p| p.resolutions))
            .unwrap_or(0)
    }

    /// True when this executor serves its decode tail from pooled blocks
    /// ([`Self::with_request_paged`]).
    pub fn is_paged(&self) -> bool {
        self.paged.is_some()
    }

    /// Lanes the resident arena can host concurrently — the continuous
    /// scheduler's admission cap (equal to [`Self::batch`]).
    pub fn lane_capacity(&self) -> usize {
        self.batch
    }

    /// Continuous-decode lanes currently open.
    pub fn lanes_live(&self) -> usize {
        self.lane_runs.iter().filter(|l| l.is_some()).count()
    }

    /// Admit one request into arena lane `lane` mid-stream: load `input`
    /// and set up the lane's private state (io buffers, tail block
    /// mapping, scratch — see [`LaneRun`]). Paged mode only; the lane
    /// must be idle and within [`Self::lane_capacity`]. The lane then
    /// advances one wave at a time through [`Self::lane_advance`],
    /// interleaved freely with other open lanes, and surrenders its
    /// output (and its tail blocks) at [`Self::lane_finish`].
    pub fn lane_open(&mut self, lane: usize, input: &[f32]) -> Result<(), String> {
        if self.paged.is_none() {
            return Err("continuous lanes require paged decode mode".into());
        }
        if self.input_io.len() != 1 {
            return Err(format!(
                "continuous lanes support single-input graphs; this graph has {} inputs",
                self.input_io.len()
            ));
        }
        if lane >= self.batch {
            return Err(format!("lane {lane} out of range (capacity {})", self.batch));
        }
        let in_elems = self.io[self.input_io[0]].len();
        if input.len() != in_elems {
            return Err(format!("lane input has {} elems, expected {in_elems}", input.len()));
        }
        if self.lane_runs.len() < self.batch {
            self.lane_runs.resize_with(self.batch, || None);
        }
        if self.lane_runs[lane].is_some() {
            return Err(format!("lane {lane} is already open"));
        }
        // Private io buffers: the shared slots are scratch the sequential
        // loop reuses across lanes (the lockstep path's per-lane rule).
        let mut io = self.io.clone();
        io[self.input_io[0]].copy_from_slice(input);
        self.lane_runs[lane] = Some(LaneRun {
            io,
            parena: PagedArena::new(Arc::clone(&self.pool), self.base_records.len()),
            scratch: Vec::new(),
            next_step: 0,
        });
        Ok(())
    }

    /// Advance an open lane through its next wave: execute steps up to
    /// and including the next §7 wave boundary (or to the end of the
    /// graph), per-lane step order identical to the sequential paged
    /// loop. Returns `Ok(true)` when the lane has executed every step
    /// and is ready for [`Self::lane_finish`]. This is the scheduler's
    /// preemption point — between two calls the executor is free to
    /// advance other lanes, admit new ones, or retire finished ones.
    pub fn lane_advance(&mut self, lane: usize) -> Result<bool, String> {
        let poison = self.poison_dead;
        let mode = self.mode;
        let mut lr = self
            .lane_runs
            .get_mut(lane)
            .and_then(Option::take)
            .ok_or_else(|| format!("lane {lane} is not open"))?;
        let done;
        {
            let Executor { steps, arena, weights, paged, .. } = self;
            let ps = paged.as_mut().expect("open lane outside paged mode");
            let end = steps.len();
            // Boundary `b` means "after executing op `b`, a wave of sizes
            // resolves" — and step index == op id — so this wave's chunk
            // is `next_step..=b`.
            let stop = ps
                .dynamic
                .boundaries()
                .into_iter()
                .find(|&b| b >= lr.next_step)
                .map_or(end, |b| (b + 1).min(end));
            for si in lr.next_step..stop {
                exec_paged_step_ctx(
                    steps,
                    arena,
                    weights,
                    &mut lr.io,
                    &ps.tail_words,
                    &mut lr.parena,
                    &mut lr.scratch,
                    &mut ps.resolutions,
                    si,
                    lane,
                    poison,
                    mode,
                );
            }
            lr.next_step = stop;
            done = stop >= end;
        }
        self.lane_runs[lane] = Some(lr);
        Ok(done)
    }

    /// Retire a finished lane: return its first graph output (the
    /// serving payload, matching [`Self::run_batch`]) and drop the
    /// lane's private state — any still-mapped tail blocks return to the
    /// shared pool, and the lane is immediately admissible again.
    pub fn lane_finish(&mut self, lane: usize) -> Result<Vec<f32>, String> {
        match self.lane_runs.get(lane).and_then(|s| s.as_ref()) {
            None => return Err(format!("lane {lane} is not open")),
            Some(lr) if lr.next_step < self.steps.len() => {
                return Err(format!(
                    "lane {lane} has not finished (step {} of {})",
                    lr.next_step,
                    self.steps.len()
                ))
            }
            Some(_) => {}
        }
        let mut lr = self.lane_runs[lane].take().expect("checked open above");
        Ok(std::mem::take(&mut lr.io[self.output_io[0]]))
    }

    /// Abandon an open lane without collecting output (admission error
    /// recovery): its private state is dropped and its tail blocks
    /// return to the shared pool. No-op on an idle lane.
    pub fn lane_abort(&mut self, lane: usize) {
        if let Some(slot) = self.lane_runs.get_mut(lane) {
            *slot = None;
        }
    }

    /// Run one lane through the level schedule: conflict-free groups of
    /// same-level steps execute concurrently on a `thread::scope` worker
    /// pool, each op writing its own validator-disjoint arena span through
    /// a [`ParallelArena`] view. Only entered when the schedule's liveness
    /// replay proved the group order safe ([`levels::build_schedule`]).
    /// Tensor deaths are poisoned per *group* (the schedule's recomputed
    /// death positions), not per step — within a group "after op i" has no
    /// meaning.
    fn run_lane_scheduled(&mut self, lane: usize) {
        let threads = self.threads;
        let mode = self.mode;
        let poison = self.poison_dead;
        for gi in 0..self.schedule.groups.len() {
            let members = self.schedule.groups[gi].members.len();
            if members == 1 {
                let si = self.schedule.groups[gi].members[0];
                self.exec_step_inner(si, lane, false);
            } else {
                let group = &self.schedule.groups[gi];
                let steps = &self.steps;
                let io = &self.io;
                let weights = &self.weights;
                let view = self.arena.parallel_view();
                let workers = threads.min(members);
                let chunk = members.div_ceil(workers);
                std::thread::scope(|s| {
                    for part in group.members.chunks(chunk) {
                        let view = &view;
                        s.spawn(move || {
                            // The group was built so that all member writes
                            // and reads are pairwise byte-disjoint, and the
                            // liveness replay proved no member overlaps a
                            // still-live earlier record.
                            for &si in part {
                                let step = &steps[si];
                                exec_arena_step_parallel(step, io, weights, view, lane, mode);
                            }
                        });
                    }
                });
                self.ops_parallel += members as u64;
            }
            if poison {
                let dead = self.schedule.groups[gi].poison.clone();
                for r in dead {
                    self.arena.poison_lane(r, lane);
                }
            }
            debug_assert!(self.arena.guards_intact(), "arena guard overwritten");
        }
    }

    fn exec_step(&mut self, si: usize, lane: usize) {
        self.exec_step_inner(si, lane, self.poison_dead)
    }

    /// One step of the paged sequential loop, against the executor-owned
    /// [`PagedState`] (see [`exec_paged_step_ctx`], which continuous
    /// lanes share verbatim).
    fn exec_step_paged(&mut self, si: usize, lane: usize) {
        let poison = self.poison_dead;
        let mode = self.mode;
        let Executor { steps, arena, weights, io, paged, .. } = self;
        let ps = paged.as_mut().expect("paged step outside paged mode");
        exec_paged_step_ctx(
            steps,
            arena,
            weights,
            io,
            &ps.tail_words,
            &mut ps.arena,
            &mut ps.scratch,
            &mut ps.resolutions,
            si,
            lane,
            poison,
            mode,
        );
    }

    fn exec_step_inner(&mut self, si: usize, lane: usize, poison: bool) {
        let mode = self.mode;
        let Executor { steps, arena, weights, io, .. } = self;
        exec_resident_step_ctx(steps, arena, weights, io, si, lane, poison, mode);
    }

    /// One step of the quantized sequential loop, against the
    /// executor-owned [`QuantState`] (see [`exec_quant_step_ctx`]).
    fn exec_step_quant(&mut self, si: usize, lane: usize) {
        let poison = self.poison_dead;
        let mode = self.mode;
        let Executor { steps, arena, weights, io, quant, .. } = self;
        let qs = quant.as_mut().expect("quantized step outside quantized mode");
        exec_quant_step_ctx(steps, arena, weights, io, qs, si, lane, poison, mode);
    }
}

impl Drop for Executor {
    /// Return the arena buffer to the pool, so a replaced or restarted
    /// executor (engine churn in the coordinator) hands its memory to the
    /// next one instead of the allocator.
    fn drop(&mut self) {
        std::mem::replace(&mut self.arena, Arena::empty()).recycle(&self.pool);
    }
}

/// Check a dynamic profile against the graph's own records: the cache
/// keys on the profile, so a drifted one would be a silent cross-model
/// cache pollution; and every dynamic record must resolve before its
/// producer runs.
fn validate_dynamic_profile(
    records: &UsageRecords,
    dynamic: &DynamicRecords,
) -> Result<(), String> {
    if dynamic.len() != records.len() || dynamic.num_ops != records.num_ops {
        return Err(format!(
            "dynamic profile describes {} records over {} ops; the graph has {} over {}",
            dynamic.len(),
            dynamic.num_ops,
            records.len(),
            records.num_ops
        ));
    }
    for (d, r) in dynamic.records.iter().zip(&records.records) {
        if d.record.first_op != r.first_op
            || d.record.last_op != r.last_op
            || d.record.size != r.size
        {
            return Err(format!(
                "dynamic record {} does not match the graph's usage record",
                r.id
            ));
        }
        if d.known_at > 0 && d.known_at >= d.record.first_op {
            return Err(format!(
                "record {} resolves after op {} but is produced at op {}: \
                 its offset would not exist in time",
                r.id, d.known_at, d.record.first_op
            ));
        }
    }
    Ok(())
}

/// One resident (non-paged) sequential step, parameterized over the io
/// buffers so the classic per-lane loop (`Executor::exec_step_inner`,
/// executor-owned io) and continuous lanes ([`LaneRun`]-private io) run
/// the *same* code — bit-identity between the paths follows from sharing
/// one implementation, not from keeping two in sync.
fn exec_resident_step_ctx(
    steps: &[Step],
    arena: &mut Arena,
    weights: &[Vec<f32>],
    io: &mut [Vec<f32>],
    si: usize,
    lane: usize,
    poison: bool,
    mode: KernelMode,
) {
    let step = &steps[si];

    // Resolve the output buffer and input slices. Two cases by output
    // location; weights/io inputs never alias anything.
    match step.out {
        Loc::Arena(orec) => {
            let arena_in: Vec<usize> = step
                .ins
                .iter()
                .filter_map(|l| match l {
                    Loc::Arena(r) => Some(*r),
                    _ => None,
                })
                .collect();
            let (out, arena_slices) = arena.split_io_lane(orec, &arena_in, lane);
            let mut it = arena_slices.into_iter();
            let ins: Vec<&[f32]> = step
                .ins
                .iter()
                .map(|l| match l {
                    Loc::Arena(_) => it.next().unwrap(),
                    Loc::Io(i) => io[*i].as_slice(),
                    Loc::Weight(w) => weights[*w].as_slice(),
                })
                .collect();
            dispatch(&step.instr, &ins, out, mode);
        }
        Loc::Io(oi) => {
            let mut out = std::mem::take(&mut io[oi]);
            {
                let ins: Vec<&[f32]> = step
                    .ins
                    .iter()
                    .map(|l| match l {
                        Loc::Arena(r) => arena.tensor_lane(*r, lane),
                        Loc::Io(i) => io[*i].as_slice(),
                        Loc::Weight(w) => weights[*w].as_slice(),
                    })
                    .collect();
                dispatch(&step.instr, &ins, &mut out, mode);
            }
            io[oi] = out;
        }
        Loc::Weight(_) => unreachable!("op writes to a weight"),
    }

    if poison {
        for r in steps[si].dies.clone() {
            arena.poison_lane(r, lane);
        }
    }
    debug_assert!(arena.guards_intact(), "arena guard overwritten");
}

/// One step of the paged loop, parameterized over the lane's io buffers
/// and tail mapping — shared verbatim by the sequential paged path
/// (`Executor::exec_step_paged`, executor-owned [`PagedState`]) and
/// continuous lanes (`Executor::lane_advance`, [`LaneRun`]-private
/// mapping). Steps touching no tail record run the ordinary resident
/// path; a step touching the tail maps its output's blocks (first touch
/// — by profile validation the record's wave boundary has already
/// passed), gathers paged operands into contiguous scratch, dispatches
/// the *same* kernel the resident path uses (bit-identity), scatters a
/// paged output back, and releases every record dying at this step —
/// tail blocks return to the shared pool immediately.
#[allow(clippy::too_many_arguments)]
fn exec_paged_step_ctx(
    steps: &[Step],
    arena: &mut Arena,
    weights: &[Vec<f32>],
    io: &mut [Vec<f32>],
    tail_words: &[Option<usize>],
    parena: &mut PagedArena,
    scratch: &mut Vec<f32>,
    resolutions: &mut u64,
    si: usize,
    lane: usize,
    poison: bool,
    mode: KernelMode,
) {
    let step = &steps[si];
    let is_tail = |l: &Loc| matches!(l, Loc::Arena(r) if tail_words[*r].is_some());
    if !step.ins.iter().any(is_tail) && !is_tail(&step.out) {
        return exec_resident_step_ctx(steps, arena, weights, io, si, lane, poison, mode);
    }
    let tail_of = |l: &Loc| match l {
        Loc::Arena(r) => tail_words[*r].map(|w| (*r, w)),
        _ => None,
    };

    // Map the output's blocks at its producing step: the record's
    // wave boundary has passed (`known_at < first_op`), so this is
    // the "tail tensors allocate incrementally at wave boundaries"
    // step of the paged protocol.
    if let Some((orec, w)) = tail_of(&step.out) {
        if !parena.is_mapped(orec) {
            parena.map(orec, w);
            *resolutions += 1;
        }
    }

    // Carve one contiguous scratch run per paged operand:
    // [out | in …], pairwise disjoint by construction.
    let out_words = tail_of(&step.out).map_or(0, |(_, w)| w);
    let in_words: usize = step.ins.iter().filter_map(|l| tail_of(l).map(|(_, w)| w)).sum();
    if scratch.len() < out_words + in_words {
        scratch.resize(out_words + in_words, 0.0);
    }
    let (out_scr, mut rest) = scratch.split_at_mut(out_words);
    let mut gathered: Vec<&[f32]> = Vec::new();
    for l in &step.ins {
        if let Some((r, w)) = tail_of(l) {
            let (chunk, r2) = rest.split_at_mut(w);
            parena.gather(r, chunk);
            gathered.push(&*chunk);
            rest = r2;
        }
    }
    let mut git = gathered.into_iter();

    match step.out {
        Loc::Arena(orec) if tail_words[orec].is_some() => {
            // Paged output: every other operand is read-only.
            let ins: Vec<&[f32]> = step
                .ins
                .iter()
                .map(|l| match l {
                    Loc::Arena(r) if tail_words[*r].is_some() => git.next().unwrap(),
                    Loc::Arena(r) => arena.tensor_lane(*r, lane),
                    Loc::Io(i) => io[*i].as_slice(),
                    Loc::Weight(w) => weights[*w].as_slice(),
                })
                .collect();
            dispatch(&step.instr, &ins, out_scr, mode);
            parena.scatter(orec, out_scr);
        }
        Loc::Arena(orec) => {
            // Resident output with paged inputs: split the resident
            // operands as usual, weave the gathered stripes back in
            // op-input order.
            let resident_in: Vec<usize> = step
                .ins
                .iter()
                .filter_map(|l| match l {
                    Loc::Arena(r) if tail_words[*r].is_none() => Some(*r),
                    _ => None,
                })
                .collect();
            let (out, resident_slices) = arena.split_io_lane(orec, &resident_in, lane);
            let mut rit = resident_slices.into_iter();
            let ins: Vec<&[f32]> = step
                .ins
                .iter()
                .map(|l| match l {
                    Loc::Arena(r) if tail_words[*r].is_some() => git.next().unwrap(),
                    Loc::Arena(_) => rit.next().unwrap(),
                    Loc::Io(i) => io[*i].as_slice(),
                    Loc::Weight(w) => weights[*w].as_slice(),
                })
                .collect();
            dispatch(&step.instr, &ins, out, mode);
        }
        Loc::Io(oi) => {
            let mut out = std::mem::take(&mut io[oi]);
            {
                let ins: Vec<&[f32]> = step
                    .ins
                    .iter()
                    .map(|l| match l {
                        Loc::Arena(r) if tail_words[*r].is_some() => git.next().unwrap(),
                        Loc::Arena(r) => arena.tensor_lane(*r, lane),
                        Loc::Io(i) => io[*i].as_slice(),
                        Loc::Weight(w) => weights[*w].as_slice(),
                    })
                    .collect();
                dispatch(&step.instr, &ins, &mut out, mode);
            }
            io[oi] = out;
        }
        Loc::Weight(_) => unreachable!("op writes to a weight"),
    }

    // Deaths: a tail record's blocks return to the shared pool at
    // once; resident records poison as usual (a tail record's last op
    // always consumes it, so tail deaths only ever occur here).
    for r in steps[si].dies.clone() {
        if tail_words[r].is_some() {
            parena.unmap(r);
        } else if poison {
            arena.poison_lane(r, lane);
        }
    }
    debug_assert!(arena.guards_intact(), "arena guard overwritten");
}

/// One step of the quantized sequential loop: arena-resident operands are
/// stored packed at the request's [`Dtype`] (see [`ops::quant`]), so the
/// step dequantizes its arena inputs into contiguous scratch under their
/// producers' parameters, dispatches the ordinary `f32` kernel, and
/// re-quantizes an arena output back into its element-width shrunk stripe
/// with parameters chosen from the freshly produced values — the
/// per-record wave boundary of the quantized path. Io outputs (graph
/// outputs) stay `f32`, so the serving payload representation never
/// changes. Scratch runs carve as `[out | in …]`, pairwise disjoint by
/// construction, exactly like the paged gather path.
#[allow(clippy::too_many_arguments)]
fn exec_quant_step_ctx(
    steps: &[Step],
    arena: &mut Arena,
    weights: &[Vec<f32>],
    io: &mut [Vec<f32>],
    qs: &mut QuantState,
    si: usize,
    lane: usize,
    poison: bool,
    mode: KernelMode,
) {
    let step = &steps[si];
    let QuantState { dtype, qparams, n_vals, scratch } = qs;
    let dtype = *dtype;
    let out_vals = match step.out {
        Loc::Arena(orec) => n_vals[orec],
        _ => 0,
    };
    let in_vals: usize = step
        .ins
        .iter()
        .map(|l| match l {
            Loc::Arena(r) => n_vals[*r],
            _ => 0,
        })
        .sum();
    if scratch.len() < out_vals + in_vals {
        scratch.resize(out_vals + in_vals, 0.0);
    }
    let (out_scr, mut rest) = scratch.split_at_mut(out_vals);
    let mut gathered: Vec<&[f32]> = Vec::new();
    for l in &step.ins {
        if let Loc::Arena(r) = l {
            let (chunk, tail) = rest.split_at_mut(n_vals[*r]);
            ops::quant::dequantize_from(dtype, qparams[*r], arena.tensor_lane(*r, lane), chunk);
            gathered.push(&*chunk);
            rest = tail;
        }
    }
    let mut git = gathered.into_iter();

    match step.out {
        Loc::Arena(orec) => {
            {
                let ins: Vec<&[f32]> = step
                    .ins
                    .iter()
                    .map(|l| match l {
                        Loc::Arena(_) => git.next().unwrap(),
                        Loc::Io(i) => io[*i].as_slice(),
                        Loc::Weight(w) => weights[*w].as_slice(),
                    })
                    .collect();
                dispatch(&step.instr, &ins, out_scr, mode);
            }
            // Re-quantize at the producing step: parameters come from the
            // values just produced, and only the exact payload (never the
            // stripe's alignment padding) enters the range.
            let (lo, hi) = ops::quant::min_max(out_scr);
            let qp = ops::quant::choose_qparams(dtype, lo, hi);
            let (stripe, _) = arena.split_io_lane(orec, &[], lane);
            ops::quant::quantize_into(dtype, qp, out_scr, stripe);
            qparams[orec] = qp;
        }
        Loc::Io(oi) => {
            let mut out = std::mem::take(&mut io[oi]);
            {
                let ins: Vec<&[f32]> = step
                    .ins
                    .iter()
                    .map(|l| match l {
                        Loc::Arena(_) => git.next().unwrap(),
                        Loc::Io(i) => io[*i].as_slice(),
                        Loc::Weight(w) => weights[*w].as_slice(),
                    })
                    .collect();
                dispatch(&step.instr, &ins, &mut out, mode);
            }
            io[oi] = out;
        }
        Loc::Weight(_) => unreachable!("op writes to a weight"),
    }

    if poison {
        for r in steps[si].dies.clone() {
            arena.poison_lane(r, lane);
        }
    }
    debug_assert!(arena.guards_intact(), "arena guard overwritten");
}

/// Execute one step through a [`ParallelArena`] view — the worker-thread
/// body of both parallel modes. `io` is read-only here: lockstep workers
/// pass their private per-lane copies (taking the output slot out first for
/// io-output steps), and level-scheduled groups contain arena-output steps
/// only.
///
/// # Safety contract (asserted by callers)
/// The caller guarantees that, for the duration of this call, no concurrent
/// thread holds bytes overlapping this step's output span in this lane:
/// lockstep by simultaneous liveness of same-step records, the level
/// schedule by its conflict grouping plus liveness replay.
fn exec_arena_step_parallel(
    step: &Step,
    io: &[Vec<f32>],
    weights: &[Vec<f32>],
    view: &ParallelArena<'_>,
    lane: usize,
    mode: KernelMode,
) {
    let Loc::Arena(orec) = step.out else {
        unreachable!("parallel groups contain arena-output steps only")
    };
    let arena_in: Vec<usize> = step
        .ins
        .iter()
        .filter_map(|l| match l {
            Loc::Arena(r) => Some(*r),
            _ => None,
        })
        .collect();
    // SAFETY: per the contract above; within the step itself, the view's
    // split re-checks that output and input spans do not overlap.
    let (out, arena_slices) = unsafe { view.split_io_lane(orec, &arena_in, lane) };
    let mut it = arena_slices.into_iter();
    let ins: Vec<&[f32]> = step
        .ins
        .iter()
        .map(|l| match l {
            Loc::Arena(_) => it.next().unwrap(),
            Loc::Io(i) => io[*i].as_slice(),
            Loc::Weight(w) => weights[*w].as_slice(),
        })
        .collect();
    dispatch(&step.instr, &ins, out, mode);
}

/// Lockstep worker body: one step, one lane, against the worker's private
/// io buffers. Io-output steps (graph outputs) write the private buffer;
/// arena-output steps go through [`exec_arena_step_parallel`].
fn exec_step_in_worker(
    step: &Step,
    io: &mut [Vec<f32>],
    weights: &[Vec<f32>],
    view: &ParallelArena<'_>,
    lane: usize,
    mode: KernelMode,
) {
    match step.out {
        Loc::Arena(_) => exec_arena_step_parallel(step, io, weights, view, lane, mode),
        Loc::Io(oi) => {
            let mut out = std::mem::take(&mut io[oi]);
            {
                let ins: Vec<&[f32]> = step
                    .ins
                    .iter()
                    .map(|l| match l {
                        // SAFETY: reads only — the record is live (this op
                        // consumes it), so no concurrent same-step writer
                        // overlaps it, and the lane stripe is this thread's.
                        Loc::Arena(r) => unsafe { view.tensor_lane(*r, lane) },
                        Loc::Io(i) => io[*i].as_slice(),
                        Loc::Weight(w) => weights[*w].as_slice(),
                    })
                    .collect();
                dispatch(&step.instr, &ins, &mut out, mode);
            }
            io[oi] = out;
        }
        Loc::Weight(_) => unreachable!("op writes to a weight"),
    }
}

/// Execute one instruction. `ins` are in op-input order (activations first,
/// then weights, per GraphBuilder convention). Hot ops dispatch by
/// [`KernelMode`]; structural ops (concat, softmax, resize, pad, copies)
/// have a single implementation.
fn dispatch(instr: &Instr, ins: &[&[f32]], out: &mut [f32], mode: KernelMode) {
    if mode == KernelMode::Reference {
        return dispatch_reference(instr, ins, out);
    }
    match instr {
        Instr::Conv { ic, oc, geom, act } => ops::conv2d(ins[0], ins[1], ins[2], out, *ic, *oc, geom, *act),
        Instr::Dw { c, geom, act } => ops::dwconv2d(ins[0], ins[1], ins[2], out, *c, geom, *act),
        Instr::MaxPool { c, geom } => ops::maxpool2d(ins[0], out, *c, geom),
        Instr::AvgPool { c, geom } => ops::avgpool2d(ins[0], out, *c, geom),
        Instr::Gap { hw, c } => ops::global_avg_pool(ins[0], out, *hw, *c),
        Instr::Add { act } => ops::add(ins[0], ins[1], out, *act),
        Instr::Mul => ops::mul(ins[0], ins[1], out),
        Instr::Concat { parts_c, pixels } => {
            let parts: Vec<(&[f32], usize)> = ins.iter().copied().zip(parts_c.iter().copied()).collect();
            ops::concat_channels(&parts, out, *pixels);
        }
        Instr::Fc { ind, outd, act } => ops::fully_connected(ins[0], ins[1], ins[2], out, *ind, *outd, *act),
        Instr::Softmax { cols } => ops::softmax(ins[0], out, *cols),
        Instr::Relu { max } => ops::relu(ins[0], out, *max),
        Instr::Sigmoid => ops::sigmoid(ins[0], out),
        Instr::Resize { h, w, oh, ow, c } => ops::resize_bilinear(ins[0], out, *h, *w, *oh, *ow, *c),
        Instr::CopyThrough => out.copy_from_slice(&ins[0][..out.len()]),
        Instr::Pad { h, w, c, before, after } => ops::pad_spatial(ins[0], out, *h, *w, *c, *before, *after),
    }
}

/// Reference-mode dispatch: hot ops route to the retained scalar kernels
/// ([`ops::scalar`]); structural ops share the default implementations.
fn dispatch_reference(instr: &Instr, ins: &[&[f32]], out: &mut [f32]) {
    match instr {
        Instr::Conv { ic, oc, geom, act } => {
            ops::scalar::conv2d(ins[0], ins[1], ins[2], out, *ic, *oc, geom, *act)
        }
        Instr::Dw { c, geom, act } => {
            ops::scalar::dwconv2d(ins[0], ins[1], ins[2], out, *c, geom, *act)
        }
        Instr::MaxPool { c, geom } => ops::scalar::maxpool2d(ins[0], out, *c, geom),
        Instr::AvgPool { c, geom } => ops::scalar::avgpool2d(ins[0], out, *c, geom),
        Instr::Gap { hw, c } => ops::scalar::global_avg_pool(ins[0], out, *hw, *c),
        Instr::Add { act } => ops::scalar::add(ins[0], ins[1], out, *act),
        Instr::Mul => ops::scalar::mul(ins[0], ins[1], out),
        Instr::Fc { ind, outd, act } => {
            ops::scalar::fully_connected(ins[0], ins[1], ins[2], out, *ind, *outd, *act)
        }
        Instr::Relu { max } => ops::scalar::relu(ins[0], out, *max),
        Instr::Sigmoid => ops::scalar::sigmoid(ins[0], out),
        other => dispatch(other, ins, out, KernelMode::Vectorized),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Activation, DType, GraphBuilder, Padding};
    use crate::planner::offset::{GreedyBySize, NaiveOffset};

    /// A small but representative net: conv, dw, residual, pool, fc, softmax.
    fn tiny_net() -> Graph {
        let mut b = GraphBuilder::new("tiny", DType::F32);
        let x = b.input("x", vec![1, 16, 16, 4]);
        let c1 = b.conv2d("c1", x, 8, (3, 3), (2, 2), Padding::Same, Activation::Relu6);
        let d1 = b.dwconv2d("d1", c1, (3, 3), (1, 1), Padding::Same, Activation::Relu6);
        let p1 = b.conv2d("p1", d1, 8, (1, 1), (1, 1), Padding::Same, Activation::None);
        let r = b.add("res", c1, p1, Activation::None);
        let g = b.global_avg_pool("gap", r);
        let f = b.reshape("flat", g, vec![1, 8]);
        let fc = b.fully_connected("fc", f, 10, Activation::None);
        let sm = b.softmax("sm", fc);
        b.mark_output(sm);
        b.finish()
    }

    fn input_for(g: &Graph, seed: u64) -> Vec<f32> {
        let n = g.tensor(g.inputs[0]).num_elements();
        let mut rng = SplitMix64::new(seed);
        let mut v = vec![0f32; n];
        rng.fill_f32(&mut v, 1.0);
        v
    }

    #[test]
    fn planned_arena_matches_naive_execution() {
        let g = tiny_net();
        let x = input_for(&g, 9);
        let mut planned = Executor::new(&g, &GreedyBySize, 7).unwrap();
        let mut naive = Executor::new(&g, &NaiveOffset, 7).unwrap();
        assert!(planned.arena_bytes() < naive.arena_bytes());
        let a = planned.run(&[&x]);
        let b = naive.run(&[&x]);
        assert_eq!(a, b, "planned arena changed the numbers");
        // softmax output sums to 1
        let s: f32 = a[0].iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
    }

    #[test]
    fn poisoning_dead_tensors_does_not_change_results() {
        // If the plan is correct, no op ever reads a dead tensor, so
        // poisoning must be invisible.
        let g = tiny_net();
        let x = input_for(&g, 10);
        let mut a = Executor::new(&g, &GreedyBySize, 7).unwrap();
        let mut b = Executor::new(&g, &GreedyBySize, 7).unwrap();
        b.set_poison_dead(true);
        let ra = a.run(&[&x]);
        let rb = b.run(&[&x]);
        assert_eq!(ra, rb);
        assert!(rb[0].iter().all(|v| v.is_finite()));
    }

    #[test]
    fn corrupt_plan_corrupts_output() {
        // Failure injection: force two overlapping live tensors to share
        // memory and watch the numbers change (or the overlap assert fire).
        let g = tiny_net();
        let records = UsageRecords::from_graph(&g);
        let good = GreedyBySize.plan(&records);
        // c1 (record 0) is live across d1..res; alias p1's output onto it.
        let mut bad = good.clone();
        // find two records with overlapping intervals
        let mut pair = None;
        'outer: for a in &records.records {
            for b in &records.records {
                if a.id < b.id && a.overlaps(b) {
                    pair = Some((a.id, b.id));
                    break 'outer;
                }
            }
        }
        let (ra, rb) = pair.unwrap();
        bad.offsets[rb] = bad.offsets[ra];
        assert!(bad.validate(&records).is_err(), "validator must flag the alias");
        let x = input_for(&g, 11);
        let mut good_exec = Executor::with_plan(&g, &records, &good, 7).unwrap();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // with_plan validates; bypass by building the arena-level pieces
            // via the error path
            Executor::with_plan(&g, &records, &bad, 7).map(|_| ())
        }));
        // Either with_plan rejects (expected) ...
        match r {
            Ok(Err(_)) => {}
            Ok(Ok(())) => panic!("corrupt plan accepted"),
            Err(_) => {} // ... or the overlap assert fired later
        }
        let _ = good_exec.run(&[&x]);
    }

    #[test]
    fn runs_every_zoo_network() {
        // Smoke: BlazeFace end-to-end (smallest zoo net with branches,
        // residuals, concat heads).
        let g = crate::models::blazeface();
        let x = input_for(&g, 3);
        let mut ex = Executor::new(&g, &GreedyBySize, 1).unwrap();
        let out = ex.run(&[&x]);
        assert_eq!(out.len(), 2);
        assert!(out[0].iter().all(|v| v.is_finite()));
        assert!(ex.arena_bytes() * 2 < ex.naive_bytes());
    }

    #[test]
    fn run_batch_matches_per_sample_runs() {
        let g = tiny_net();
        let n_in = g.tensor(g.inputs[0]).num_elements();
        let n = 3usize;
        let mut rng = SplitMix64::new(21);
        let mut flat = vec![0f32; n * n_in];
        rng.fill_f32(&mut flat, 1.0);

        let mut single = Executor::new(&g, &GreedyBySize, 7).unwrap();
        let mut batched = Executor::new(&g, &GreedyBySize, 7).unwrap();
        batched.set_poison_dead(true);
        let out = batched.run_batch(&flat, n).unwrap();
        assert_eq!(batched.batch(), n);
        let out_elems = out.len() / n;
        for i in 0..n {
            let expect = single.run(&[&flat[i * n_in..(i + 1) * n_in]]);
            assert_eq!(
                out[i * out_elems..(i + 1) * out_elems],
                expect[0][..],
                "sample {i} diverged in the batched arena"
            );
        }
        // The batched arena is one block planned for the scaled records.
        assert!(batched.arena_bytes() >= single.arena_bytes());
    }

    #[test]
    fn run_batch_grows_but_never_shrinks_the_resident_arena() {
        let g = tiny_net();
        let n_in = g.tensor(g.inputs[0]).num_elements();
        let svc = PlanService::shared();
        let mut ex = Executor::with_service(&g, Arc::clone(&svc), "greedy-size", 7).unwrap();
        let x = vec![0.25f32; 4 * n_in];
        ex.run_batch(&x[..2 * n_in], 2).unwrap();
        let grown = ex.arena_bytes();
        // A smaller batch runs in the first lane of the resident arena:
        // no re-plan, no swap.
        ex.run_batch(&x[..n_in], 1).unwrap();
        assert_eq!(ex.batch(), 2);
        assert_eq!(ex.arena_bytes(), grown);
        ex.run_batch(&x[..2 * n_in], 2).unwrap();
        let st = svc.stats();
        // Construction planned batch 1, the growth planned batch 2; the
        // fluctuating batch sizes afterwards planned nothing.
        assert_eq!(st.cache_misses, 2, "planner ran more than once per batch");
    }

    #[test]
    fn explicit_batch_swaps_recycle_arena_buffers() {
        let g = tiny_net();
        let svc = PlanService::shared();
        let mut ex = Executor::with_service(&g, Arc::clone(&svc), "greedy-size", 7).unwrap();
        ex.ensure_batch(2).unwrap();
        ex.ensure_batch(1).unwrap();
        ex.ensure_batch(2).unwrap();
        let st = svc.stats();
        // Batches 1 and 2 were each planned exactly once; the swaps back
        // hit the cache and reused pooled buffers.
        assert_eq!(st.cache_misses, 2, "planner ran more than once per batch");
        assert!(st.cache_hits >= 2);
        assert!(st.pool_reused >= 2, "arena pool never reused a buffer");
    }

    #[test]
    fn dropping_an_executor_returns_its_arena_to_the_pool() {
        let g = tiny_net();
        let svc = PlanService::shared();
        let a = Executor::with_service(&g, Arc::clone(&svc), "greedy-size", 7).unwrap();
        let bytes = a.arena_bytes();
        drop(a);
        // A restarted replica of the same model reuses the retired buffer.
        let b = Executor::with_service(&g, Arc::clone(&svc), "greedy-size", 8).unwrap();
        assert_eq!(b.arena_bytes(), bytes);
        let st = svc.stats();
        assert_eq!(st.cache_misses, 1);
        assert_eq!(st.cache_hits, 1);
        assert!(st.pool_reused >= 1, "restart did not reuse the retired arena");
    }

    #[test]
    fn wave_aware_execution_matches_static_numbers() {
        // Dynamic mode changes *where* tensors live (frozen multi-pass
        // placements) and *when* offsets resolve, never what the ops
        // compute: outputs must stay bit-identical to the static executor.
        let g = tiny_net();
        let x = input_for(&g, 17);
        let records = UsageRecords::from_graph(&g);
        let dynamic = DynamicRecords::decode_tail(&records, records.num_ops / 2);
        assert!(dynamic.num_dynamic() > 0, "the tail must actually be dynamic");
        let svc = PlanService::shared();
        let mut dynamic_ex = Executor::with_request(
            &g,
            Arc::clone(&svc),
            &PlanRequest::new(),
            Some(dynamic.clone()),
            7,
        )
        .unwrap();
        dynamic_ex.set_poison_dead(true);
        let mut static_ex = Executor::new(&g, &GreedyBySize, 7).unwrap();
        assert_eq!(dynamic_ex.run(&[&x]), static_ex.run(&[&x]));
        assert!(dynamic_ex.wave_passes() >= 2);
        assert_eq!(
            dynamic_ex.wave_resolutions(),
            dynamic.boundaries().len() as u64,
            "one re-resolution per wave boundary"
        );
        // The arena hosts the worst-wave peak.
        let mp = svc
            .plan_dynamic(
                &dynamic,
                &PlanRequest::new().with_dynamic(DynamicMode::FullyResolved),
            )
            .unwrap();
        assert_eq!(dynamic_ex.arena_bytes(), mp.peak);
    }

    #[test]
    fn repeat_inferences_resolve_waves_from_the_cache() {
        let g = tiny_net();
        let records = UsageRecords::from_graph(&g);
        let dynamic = DynamicRecords::decode_tail(&records, records.num_ops / 2);
        let boundaries = dynamic.boundaries().len() as u64;
        let svc = PlanService::shared();
        let mut ex = Executor::with_request(
            &g,
            Arc::clone(&svc),
            &PlanRequest::new(),
            Some(dynamic),
            7,
        )
        .unwrap();
        // Construction planned the full plan and pre-warmed each *proper*
        // prefix — the last boundary resolves every size, which is exactly
        // the full plan's fingerprint, so that pre-warm lookup already
        // hits. Nothing is left for the hot path to plan (or even to look
        // up: the envelope is pinned in the executor).
        let misses_at_build = svc.stats().dynamic_misses;
        assert_eq!(misses_at_build, boundaries);
        let x = input_for(&g, 18);
        ex.run(&[&x]);
        ex.run(&[&x]);
        ex.run(&[&x]);
        let st = svc.stats();
        assert_eq!(
            st.dynamic_misses, misses_at_build,
            "inferences must perform zero planner invocations"
        );
        assert_eq!(st.dynamic_hits, 1, "only the pre-warm touches the cache");
        assert_eq!(ex.wave_resolutions(), 3 * boundaries);
    }

    #[test]
    fn dynamic_profile_must_match_the_graph() {
        let g = tiny_net();
        let records = UsageRecords::from_graph(&g);
        let svc = PlanService::shared();
        // Wrong record count.
        let short = DynamicRecords::new(Vec::new(), records.num_ops);
        assert!(
            Executor::with_request(&g, Arc::clone(&svc), &PlanRequest::new(), Some(short), 7)
                .is_err()
        );
        // A record resolving at (or after) its producer cannot be served.
        let mut bad = DynamicRecords::decode_tail(&records, 1);
        if let Some(d) = bad.records.iter_mut().find(|d| d.record.first_op > 0) {
            d.known_at = d.record.first_op;
        }
        assert!(Executor::with_request(&g, svc, &PlanRequest::new(), Some(bad), 7).is_err());
    }

    #[test]
    fn explicit_plan_executor_cannot_change_batch() {
        let g = tiny_net();
        let records = UsageRecords::from_graph(&g);
        let plan = GreedyBySize.plan(&records);
        let mut ex = Executor::with_plan(&g, &records, &plan, 7).unwrap();
        assert!(ex.ensure_batch(2).is_err());
        assert!(ex.ensure_batch(1).is_ok()); // resident batch is fine
    }

    #[test]
    fn lockstep_batch_is_bit_identical_to_sequential() {
        let g = tiny_net();
        let n_in = g.tensor(g.inputs[0]).num_elements();
        let n = 5usize;
        let mut rng = SplitMix64::new(33);
        let mut flat = vec![0f32; n * n_in];
        rng.fill_f32(&mut flat, 1.0);

        let mut seq = Executor::new(&g, &GreedyBySize, 7).unwrap();
        let mut par = Executor::new(&g, &GreedyBySize, 7).unwrap();
        par.set_threads(4);
        par.set_poison_dead(true);
        assert_eq!(par.threads(), 4);
        let a = seq.run_batch(&flat, n).unwrap();
        let b = par.run_batch(&flat, n).unwrap();
        assert_eq!(a, b, "lockstep parallel batch diverged from sequential");
        assert!(par.ops_parallel() > 0, "no work was dispatched to workers");
        // Workers outnumbering lanes degrade gracefully.
        par.set_threads(16);
        assert_eq!(par.run_batch(&flat, n).unwrap(), a);
    }

    #[test]
    fn scheduled_single_sample_matches_sequential_on_branchy_net() {
        // BlazeFace has wide levels (parallel residual towers, two output
        // heads) — the level schedule actually engages.
        let g = crate::models::blazeface();
        let x = input_for(&g, 5);
        let mut seq = Executor::new(&g, &GreedyBySize, 1).unwrap();
        let mut par = Executor::new(&g, &GreedyBySize, 1).unwrap();
        par.set_threads(4);
        par.set_poison_dead(true);
        assert!(par.levels() > 0, "no level decomposition for a DAG");
        let a = seq.run(&[&x]);
        let b = par.run(&[&x]);
        assert_eq!(a, b, "level-scheduled run diverged from sequential");
    }

    #[test]
    fn kernel_mode_reference_agrees_with_vectorized() {
        // Exact agreement is the kernel_diff suite's job (1-ulp bound);
        // end-to-end through softmax a loose tolerance suffices here.
        let g = tiny_net();
        let x = input_for(&g, 41);
        let mut vec_ex = Executor::new(&g, &GreedyBySize, 7).unwrap();
        let mut ref_ex = Executor::new(&g, &GreedyBySize, 7).unwrap();
        assert_eq!(vec_ex.kernel_mode(), ops::KernelMode::Vectorized);
        ref_ex.set_kernel_mode(ops::KernelMode::Reference);
        let a = vec_ex.run(&[&x]);
        let b = ref_ex.run(&[&x]);
        for (va, vb) in a[0].iter().zip(&b[0]) {
            assert!((va - vb).abs() <= 1e-5, "kernel modes disagree: {va} vs {vb}");
        }
    }

    #[test]
    fn threaded_wave_mode_falls_back_to_sequential() {
        // §7 wave mode re-resolves offsets per op — inherently sequential.
        // Threads must not change its numbers (or deadlock).
        let g = tiny_net();
        let x = input_for(&g, 23);
        let records = UsageRecords::from_graph(&g);
        let dynamic = DynamicRecords::decode_tail(&records, records.num_ops / 2);
        let svc = PlanService::shared();
        let mut ex = Executor::with_request(
            &g,
            Arc::clone(&svc),
            &PlanRequest::new(),
            Some(dynamic),
            7,
        )
        .unwrap();
        let before = ex.run(&[&x]);
        ex.set_threads(4);
        assert_eq!(ex.run(&[&x]), before);
        assert_eq!(ex.ops_parallel(), 0, "wave mode must never dispatch workers");
    }

    #[test]
    fn batch_growth_rebuilds_the_schedule() {
        let g = crate::models::blazeface();
        let svc = PlanService::shared();
        let mut ex = Executor::with_service(&g, svc, "greedy-size", 7).unwrap();
        ex.set_threads(2);
        let depth = ex.levels();
        let n_in = g.tensor(g.inputs[0]).num_elements();
        let x = vec![0.5f32; 3 * n_in];
        ex.run_batch(&x, 3).unwrap();
        // Levels are a graph property: the rebuilt (batch-3) schedule keeps
        // the same depth even though every span moved.
        assert_eq!(ex.levels(), depth);
    }

    #[test]
    fn paged_execution_matches_static_numbers_below_the_worst_wave_peak() {
        // decode_tail from op 2 puts tensors big enough in the tail that
        // worst-wave preallocation strictly exceeds the static prefix —
        // exactly the regime paging targets. Outputs must not move.
        let g = tiny_net();
        let x = input_for(&g, 29);
        let records = UsageRecords::from_graph(&g);
        let dynamic = DynamicRecords::decode_tail(&records, 2);
        assert!(dynamic.num_dynamic() > 0, "the tail must actually be dynamic");
        let svc = PlanService::shared();
        let mut paged = Executor::with_request_paged(
            &g,
            Arc::clone(&svc),
            &PlanRequest::new(),
            dynamic.clone(),
            7,
        )
        .unwrap();
        assert!(paged.is_paged());
        paged.set_poison_dead(true);
        let mut static_ex = Executor::new(&g, &GreedyBySize, 7).unwrap();
        assert_eq!(paged.run(&[&x]), static_ex.run(&[&x]), "paging changed the numbers");
        // The resident arena hosts only the static prefix — strictly
        // below the worst-wave peak the resident dynamic mode allocates.
        let req = PlanRequest::new();
        let full = svc
            .plan_dynamic(&dynamic, &req.with_dynamic(DynamicMode::FullyResolved))
            .unwrap();
        let prefix = svc
            .plan_dynamic(&dynamic, &req.with_dynamic(DynamicMode::Resolved(0)))
            .unwrap();
        assert_eq!(paged.arena_bytes(), prefix.peak);
        assert!(
            paged.arena_bytes() < full.peak,
            "prefix arena ({}) must sit below the worst-wave peak ({})",
            paged.arena_bytes(),
            full.peak
        );
        // Every tail tensor mapped once and returned its blocks at death.
        assert_eq!(paged.wave_resolutions(), dynamic.num_dynamic() as u64);
        assert_eq!(svc.pool().blocks().blocks_in_use(), 0, "blocks leaked past the run");
        assert!(svc.pool().blocks().peak_blocks() > 0);
        assert!(paged.wave_passes() >= 2);
        // The doctored resident records must not distort the naive total.
        assert_eq!(paged.naive_bytes(), records.naive_total());
    }

    #[test]
    fn paged_run_batch_matches_resident_dynamic_and_stays_sequential() {
        let g = tiny_net();
        let n_in = g.tensor(g.inputs[0]).num_elements();
        let n = 4usize;
        let mut rng = SplitMix64::new(51);
        let mut flat = vec![0f32; n * n_in];
        rng.fill_f32(&mut flat, 1.0);
        let records = UsageRecords::from_graph(&g);
        let dynamic = DynamicRecords::decode_tail(&records, 2);
        let svc = PlanService::shared();
        let mut resident = Executor::with_request(
            &g,
            Arc::clone(&svc),
            &PlanRequest::new(),
            Some(dynamic.clone()),
            7,
        )
        .unwrap();
        let mut paged = Executor::with_request_paged(
            &g,
            Arc::clone(&svc),
            &PlanRequest::new(),
            dynamic,
            7,
        )
        .unwrap();
        paged.set_poison_dead(true);
        let a = resident.run_batch(&flat, n).unwrap();
        let b = paged.run_batch(&flat, n).unwrap();
        assert_eq!(a, b, "paged batch diverged from the resident dynamic path");
        assert_eq!(paged.batch(), n);
        assert!(paged.arena_bytes() < resident.arena_bytes());
        assert_eq!(paged.naive_bytes(), resident.naive_bytes());
        // Threads must not change the numbers: paged execution (like wave
        // mode) is inherently sequential and falls back.
        paged.set_threads(4);
        assert_eq!(paged.run_batch(&flat, n).unwrap(), a);
        assert_eq!(paged.ops_parallel(), 0, "paged mode must never dispatch workers");
        assert_eq!(svc.pool().blocks().blocks_in_use(), 0, "blocks leaked past the batch");
    }

    #[test]
    fn continuous_lanes_interleave_bit_identically() {
        let g = tiny_net();
        let n_in = g.tensor(g.inputs[0]).num_elements();
        let mut rng = SplitMix64::new(77);
        let mut flat = vec![0f32; 2 * n_in];
        rng.fill_f32(&mut flat, 1.0);
        let records = UsageRecords::from_graph(&g);
        let dynamic = DynamicRecords::decode_tail(&records, 2);
        let svc = PlanService::shared();
        let req = PlanRequest::new().with_batch(2);
        let mut resident =
            Executor::with_request(&g, Arc::clone(&svc), &req, Some(dynamic.clone()), 7).unwrap();
        let want = resident.run_batch(&flat, 2).unwrap();
        let out_elems = want.len() / 2;
        let mut ex =
            Executor::with_request_paged(&g, Arc::clone(&svc), &req, dynamic, 7).unwrap();
        ex.set_poison_dead(true);
        assert_eq!(ex.lane_capacity(), 2);
        // Open lane 0, run it one wave, then admit lane 1 mid-stream —
        // the wave-boundary admission the continuous scheduler performs.
        ex.lane_open(0, &flat[..n_in]).unwrap();
        let mut f0 = ex.lane_advance(0).unwrap();
        assert!(!f0, "tiny_net must have a wave boundary before the end");
        ex.lane_open(1, &flat[n_in..]).unwrap();
        assert_eq!(ex.lanes_live(), 2);
        // The shared io/arena paths are fenced off while lanes are open.
        assert!(ex.run_batch(&flat, 2).is_err());
        assert!(ex.ensure_batch(4).is_err());
        // Interleave both lanes to completion, the younger lane first.
        let mut f1 = false;
        for _ in 0..64 {
            if !f1 {
                f1 = ex.lane_advance(1).unwrap();
            }
            if !f0 {
                f0 = ex.lane_advance(0).unwrap();
            }
            if f0 && f1 {
                break;
            }
        }
        assert!(f0 && f1, "lanes did not finish within the step budget");
        let o1 = ex.lane_finish(1).unwrap();
        let o0 = ex.lane_finish(0).unwrap();
        assert_eq!(o0.as_slice(), &want[..out_elems], "lane 0 diverged from batch-and-drain");
        assert_eq!(o1.as_slice(), &want[out_elems..], "lane 1 diverged from batch-and-drain");
        assert_eq!(ex.lanes_live(), 0);
        assert_eq!(svc.pool().blocks().blocks_in_use(), 0, "lane blocks leaked");
        // A retired lane is immediately admissible again, and the shared
        // sequential path is usable once every lane has drained.
        ex.lane_open(0, &flat[n_in..]).unwrap();
        while !ex.lane_advance(0).unwrap() {}
        assert_eq!(ex.lane_finish(0).unwrap().as_slice(), &want[out_elems..]);
        assert_eq!(ex.run_batch(&flat, 2).unwrap(), want);
    }

    #[test]
    fn continuous_lane_misuse_is_refused() {
        let g = tiny_net();
        let x = input_for(&g, 3);
        let svc = PlanService::shared();
        // Lanes require paged mode.
        let mut resident = Executor::with_service(&g, Arc::clone(&svc), "greedy-size", 7).unwrap();
        assert!(resident.lane_open(0, &x).is_err());
        let records = UsageRecords::from_graph(&g);
        let dynamic = DynamicRecords::decode_tail(&records, 2);
        let mut ex =
            Executor::with_request_paged(&g, svc, &PlanRequest::new(), dynamic, 7).unwrap();
        // Out-of-range lane, wrong input width, double-open, idle-lane ops.
        assert!(ex.lane_open(1, &x).is_err(), "capacity is 1");
        assert!(ex.lane_open(0, &x[..x.len() - 1]).is_err());
        assert!(ex.lane_advance(0).is_err());
        assert!(ex.lane_finish(0).is_err());
        ex.lane_open(0, &x).unwrap();
        assert!(ex.lane_open(0, &x).is_err(), "lane is already open");
        assert!(ex.lane_finish(0).is_err(), "lane has not finished");
        // Abort releases the lane without output.
        ex.lane_abort(0);
        assert_eq!(ex.lanes_live(), 0);
        ex.lane_open(0, &x).unwrap();
        while !ex.lane_advance(0).unwrap() {}
        assert!(ex.lane_finish(0).is_ok());
    }

    #[test]
    fn paged_profile_must_match_the_graph() {
        let g = tiny_net();
        let records = UsageRecords::from_graph(&g);
        let svc = PlanService::shared();
        let short = DynamicRecords::new(Vec::new(), records.num_ops);
        assert!(Executor::with_request_paged(
            &g,
            Arc::clone(&svc),
            &PlanRequest::new(),
            short,
            7
        )
        .is_err());
        let mut bad = DynamicRecords::decode_tail(&records, 1);
        if let Some(d) = bad.records.iter_mut().find(|d| d.record.first_op > 0) {
            d.known_at = d.record.first_op;
        }
        assert!(Executor::with_request_paged(&g, svc, &PlanRequest::new(), bad, 7).is_err());
    }

    #[test]
    fn quantized_requests_shrink_the_arena_and_track_f32_outputs() {
        let g = tiny_net();
        let x = input_for(&g, 61);
        let svc = PlanService::shared();
        let mut f32_ex =
            Executor::with_request(&g, Arc::clone(&svc), &PlanRequest::new(), None, 7).unwrap();
        let want = f32_ex.run(&[&x]);
        // (dtype, minimum integral shrink factor, softmax drift bound)
        for (dtype, min_shrink, tol) in [(Dtype::I8, 3, 0.1f32), (Dtype::F16, 1, 1e-2)] {
            let req = PlanRequest::new().with_dtype(dtype);
            let mut q = Executor::with_request(&g, Arc::clone(&svc), &req, None, 7).unwrap();
            q.set_poison_dead(true);
            assert_eq!(q.dtype(), dtype);
            assert!(
                q.arena_bytes() * min_shrink <= f32_ex.arena_bytes()
                    && q.arena_bytes() < f32_ex.arena_bytes(),
                "{dtype:?} arena {} vs f32 {}",
                q.arena_bytes(),
                f32_ex.arena_bytes()
            );
            let got = q.run(&[&x]);
            let sum: f32 = got[0].iter().sum();
            assert!((sum - 1.0).abs() < 1e-3, "{dtype:?} softmax sum {sum}");
            for (i, (&a, &b)) in got[0].iter().zip(want[0].iter()).enumerate() {
                assert!(a.is_finite(), "{dtype:?} elem {i} not finite");
                assert!((a - b).abs() <= tol, "{dtype:?} elem {i}: {a} vs f32 {b}");
            }
            // Same request, same seed: quantized serving is deterministic.
            let again = q.run(&[&x]);
            assert_eq!(got, again, "{dtype:?} repeat run changed bits");
            let mut q2 = Executor::with_request(&g, Arc::clone(&svc), &req, None, 7).unwrap();
            assert_eq!(got, q2.run(&[&x]), "{dtype:?} fresh executor changed bits");
        }
    }

    #[test]
    fn quantized_batches_stay_sequential_and_bit_stable() {
        let g = tiny_net();
        let n = 3;
        let in_elems = g.tensor(g.inputs[0]).num_elements();
        let mut rng = SplitMix64::new(77);
        let mut flat = vec![0f32; n * in_elems];
        rng.fill_f32(&mut flat, 1.0);
        let svc = PlanService::shared();
        let req = PlanRequest::new().with_dtype(Dtype::I8);
        let mut a = Executor::with_request(&g, Arc::clone(&svc), &req, None, 7).unwrap();
        let mut b = Executor::with_request(&g, Arc::clone(&svc), &req, None, 7).unwrap();
        b.set_threads(4);
        b.set_poison_dead(true);
        let oa = a.run_batch(&flat, n).unwrap();
        let ob = b.run_batch(&flat, n).unwrap();
        assert_eq!(oa, ob, "threads changed quantized numbers");
        assert_eq!(b.ops_parallel(), 0, "quantized mode must never dispatch workers");
        // Sample 0 of the batch is bit-identical to the single-sample path
        // (quantization depends on values, not on stripe layout or batch).
        let single = a.run(&[&flat[..in_elems]]);
        let out_elems = oa.len() / n;
        assert_eq!(&oa[..out_elems], single[0].as_slice());
        // Growing the batch keeps the quantized arena quantized-sized.
        let f32_b = {
            let mut e =
                Executor::with_request(&g, Arc::clone(&svc), &PlanRequest::new(), None, 7)
                    .unwrap();
            e.ensure_batch(n).unwrap();
            e.arena_bytes()
        };
        assert!(a.arena_bytes() * 3 <= f32_b, "batched i8 arena lost its shrink");
    }

    #[test]
    fn quantized_requests_reject_dynamic_and_paged_serving() {
        let g = tiny_net();
        let records = UsageRecords::from_graph(&g);
        let dynamic = DynamicRecords::decode_tail(&records, records.num_ops / 2);
        let svc = PlanService::shared();
        let req = PlanRequest::new().with_dtype(Dtype::I8);
        let err = Executor::with_request(&g, Arc::clone(&svc), &req, Some(dynamic.clone()), 7)
            .err()
            .expect("dynamic profile must be rejected under i8");
        assert!(err.contains("static-mode only"), "unexpected error: {err}");
        let err = Executor::with_request_paged(&g, svc, &req, dynamic, 7)
            .err()
            .expect("paged serving must be rejected under i8");
        assert!(err.contains("static-mode only"), "unexpected error: {err}");
    }
}
