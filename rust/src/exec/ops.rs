//! Reference CPU kernels for the graph op set.
//!
//! These are deliberately simple NHWC loops: the executor's job in this
//! repo is *behavioural validation of memory plans* (and the locality
//! measurements of `benches/locality.rs`), not peak FLOPs — the optimized
//! compute path is the AOT-compiled XLA module run by `crate::runtime`.
//! The conv kernels still hoist bounds checks and iterate cache-friendly
//! (channels innermost) so whole-network runs stay in the tens of
//! milliseconds.

use crate::graph::{Activation, Padding};

/// Apply a fused activation in place.
#[inline]
pub fn activate(buf: &mut [f32], act: Activation) {
    match act {
        Activation::None => {}
        Activation::Relu => {
            for v in buf.iter_mut() {
                *v = v.max(0.0);
            }
        }
        Activation::Relu6 => {
            for v in buf.iter_mut() {
                *v = v.clamp(0.0, 6.0);
            }
        }
    }
}

/// Spatial geometry of a conv/pool op, precomputed once per call.
pub struct Geom {
    /// Input height.
    pub h: usize,
    /// Input width.
    pub w: usize,
    /// Output height.
    pub oh: usize,
    /// Output width.
    pub ow: usize,
    /// Kernel height.
    pub kh: usize,
    /// Kernel width.
    pub kw: usize,
    /// Vertical stride.
    pub sh: usize,
    /// Horizontal stride.
    pub sw: usize,
    /// Vertical dilation.
    pub dh: usize,
    /// Horizontal dilation.
    pub dw: usize,
    /// Top padding (negative never occurs; `isize` for the inner loops).
    pub ph: isize,
    /// Left padding.
    pub pw: isize,
}

impl Geom {
    /// Precompute the geometry of one conv/pool call.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        h: usize,
        w: usize,
        oh: usize,
        ow: usize,
        kernel: (usize, usize),
        stride: (usize, usize),
        dilation: (usize, usize),
        padding: Padding,
    ) -> Self {
        let (ph, pw) = match padding {
            Padding::Valid => (0, 0),
            Padding::Same => {
                let p = crate::graph::same_padding_pair(h, w, kernel, stride, dilation);
                (p.0 as isize, p.1 as isize)
            }
        };
        Geom {
            h,
            w,
            oh,
            ow,
            kh: kernel.0,
            kw: kernel.1,
            sh: stride.0,
            sw: stride.1,
            dh: dilation.0,
            dw: dilation.1,
            ph,
            pw,
        }
    }
}

/// Standard convolution, NHWC × [kh,kw,ic,oc] → NHWC. Batch 1.
#[allow(clippy::too_many_arguments)]
pub fn conv2d(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    out: &mut [f32],
    ic: usize,
    oc: usize,
    g: &Geom,
    act: Activation,
) {
    debug_assert_eq!(x.len() >= g.h * g.w * ic, true);
    for oy in 0..g.oh {
        for ox in 0..g.ow {
            let o_base = (oy * g.ow + ox) * oc;
            out[o_base..o_base + oc].copy_from_slice(&b[..oc]);
            for ky in 0..g.kh {
                let iy = oy as isize * g.sh as isize + ky as isize * g.dh as isize - g.ph;
                if iy < 0 || iy >= g.h as isize {
                    continue;
                }
                for kx in 0..g.kw {
                    let ix = ox as isize * g.sw as isize + kx as isize * g.dw as isize - g.pw;
                    if ix < 0 || ix >= g.w as isize {
                        continue;
                    }
                    let i_base = (iy as usize * g.w + ix as usize) * ic;
                    let w_base = (ky * g.kw + kx) * ic * oc;
                    for c in 0..ic {
                        let xv = x[i_base + c];
                        if xv == 0.0 {
                            continue;
                        }
                        let wrow = &w[w_base + c * oc..w_base + (c + 1) * oc];
                        let orow = &mut out[o_base..o_base + oc];
                        for (o, &wv) in orow.iter_mut().zip(wrow.iter()) {
                            *o += xv * wv;
                        }
                    }
                }
            }
        }
    }
    activate(out, act);
}

/// Depthwise convolution (multiplier 1), weights [kh,kw,c,1].
pub fn dwconv2d(x: &[f32], w: &[f32], b: &[f32], out: &mut [f32], c: usize, g: &Geom, act: Activation) {
    for oy in 0..g.oh {
        for ox in 0..g.ow {
            let o_base = (oy * g.ow + ox) * c;
            out[o_base..o_base + c].copy_from_slice(&b[..c]);
            for ky in 0..g.kh {
                let iy = oy as isize * g.sh as isize + ky as isize * g.dh as isize - g.ph;
                if iy < 0 || iy >= g.h as isize {
                    continue;
                }
                for kx in 0..g.kw {
                    let ix = ox as isize * g.sw as isize + kx as isize * g.dw as isize - g.pw;
                    if ix < 0 || ix >= g.w as isize {
                        continue;
                    }
                    let i_base = (iy as usize * g.w + ix as usize) * c;
                    let w_base = (ky * g.kw + kx) * c;
                    for ch in 0..c {
                        out[o_base + ch] += x[i_base + ch] * w[w_base + ch];
                    }
                }
            }
        }
    }
    activate(out, act);
}

/// Max pooling.
pub fn maxpool2d(x: &[f32], out: &mut [f32], c: usize, g: &Geom) {
    for oy in 0..g.oh {
        for ox in 0..g.ow {
            let o_base = (oy * g.ow + ox) * c;
            out[o_base..o_base + c].fill(f32::NEG_INFINITY);
            for ky in 0..g.kh {
                let iy = oy as isize * g.sh as isize + ky as isize - g.ph;
                if iy < 0 || iy >= g.h as isize {
                    continue;
                }
                for kx in 0..g.kw {
                    let ix = ox as isize * g.sw as isize + kx as isize - g.pw;
                    if ix < 0 || ix >= g.w as isize {
                        continue;
                    }
                    let i_base = (iy as usize * g.w + ix as usize) * c;
                    for ch in 0..c {
                        let v = x[i_base + ch];
                        if v > out[o_base + ch] {
                            out[o_base + ch] = v;
                        }
                    }
                }
            }
        }
    }
}

/// Average pooling (TFLite semantics: average over *valid* taps only).
pub fn avgpool2d(x: &[f32], out: &mut [f32], c: usize, g: &Geom) {
    for oy in 0..g.oh {
        for ox in 0..g.ow {
            let o_base = (oy * g.ow + ox) * c;
            out[o_base..o_base + c].fill(0.0);
            let mut count = 0f32;
            for ky in 0..g.kh {
                let iy = oy as isize * g.sh as isize + ky as isize - g.ph;
                if iy < 0 || iy >= g.h as isize {
                    continue;
                }
                for kx in 0..g.kw {
                    let ix = ox as isize * g.sw as isize + kx as isize - g.pw;
                    if ix < 0 || ix >= g.w as isize {
                        continue;
                    }
                    count += 1.0;
                    let i_base = (iy as usize * g.w + ix as usize) * c;
                    for ch in 0..c {
                        out[o_base + ch] += x[i_base + ch];
                    }
                }
            }
            let inv = 1.0 / count.max(1.0);
            for ch in 0..c {
                out[o_base + ch] *= inv;
            }
        }
    }
}

/// Global average pool: [h*w*c] -> [c].
pub fn global_avg_pool(x: &[f32], out: &mut [f32], hw: usize, c: usize) {
    out[..c].fill(0.0);
    for i in 0..hw {
        let base = i * c;
        for ch in 0..c {
            out[ch] += x[base + ch];
        }
    }
    let inv = 1.0 / hw as f32;
    for ch in out[..c].iter_mut() {
        *ch *= inv;
    }
}

/// Elementwise add with fused activation.
pub fn add(a: &[f32], b: &[f32], out: &mut [f32], act: Activation) {
    for ((o, &x), &y) in out.iter_mut().zip(a.iter()).zip(b.iter()) {
        *o = x + y;
    }
    activate(out, act);
}

/// Elementwise multiply.
pub fn mul(a: &[f32], b: &[f32], out: &mut [f32]) {
    for ((o, &x), &y) in out.iter_mut().zip(a.iter()).zip(b.iter()) {
        *o = x * y;
    }
}

/// Channel concat: interleave per-pixel channel runs.
pub fn concat_channels(parts: &[(&[f32], usize)], out: &mut [f32], pixels: usize) {
    let oc: usize = parts.iter().map(|&(_, c)| c).sum();
    for p in 0..pixels {
        let mut off = 0;
        for &(buf, c) in parts {
            out[p * oc + off..p * oc + off + c].copy_from_slice(&buf[p * c..(p + 1) * c]);
            off += c;
        }
    }
}

/// Fully connected: [in] × [in,out] + [out].
pub fn fully_connected(x: &[f32], w: &[f32], b: &[f32], out: &mut [f32], ind: usize, outd: usize, act: Activation) {
    out[..outd].copy_from_slice(&b[..outd]);
    for (i, &xv) in x[..ind].iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let wrow = &w[i * outd..(i + 1) * outd];
        for (o, &wv) in out[..outd].iter_mut().zip(wrow.iter()) {
            *o += xv * wv;
        }
    }
    activate(&mut out[..outd], act);
}

/// Softmax over the last axis of a [rows, cols] view.
pub fn softmax(x: &[f32], out: &mut [f32], cols: usize) {
    for (xr, or) in x.chunks_exact(cols).zip(out.chunks_exact_mut(cols)) {
        let m = xr.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for (o, &v) in or.iter_mut().zip(xr.iter()) {
            *o = (v - m).exp();
            sum += *o;
        }
        let inv = 1.0 / sum;
        for o in or.iter_mut() {
            *o *= inv;
        }
    }
}

/// Bilinear resize (align_corners = false, TFLite default).
pub fn resize_bilinear(x: &[f32], out: &mut [f32], h: usize, w: usize, oh: usize, ow: usize, c: usize) {
    let sy = h as f32 / oh as f32;
    let sx = w as f32 / ow as f32;
    for oy in 0..oh {
        let fy = ((oy as f32 + 0.5) * sy - 0.5).max(0.0);
        let y0 = (fy as usize).min(h - 1);
        let y1 = (y0 + 1).min(h - 1);
        let wy = fy - y0 as f32;
        for ox in 0..ow {
            let fx = ((ox as f32 + 0.5) * sx - 0.5).max(0.0);
            let x0 = (fx as usize).min(w - 1);
            let x1 = (x0 + 1).min(w - 1);
            let wx = fx - x0 as f32;
            let o_base = (oy * ow + ox) * c;
            let b00 = (y0 * w + x0) * c;
            let b01 = (y0 * w + x1) * c;
            let b10 = (y1 * w + x0) * c;
            let b11 = (y1 * w + x1) * c;
            for ch in 0..c {
                let top = x[b00 + ch] * (1.0 - wx) + x[b01 + ch] * wx;
                let bot = x[b10 + ch] * (1.0 - wx) + x[b11 + ch] * wx;
                out[o_base + ch] = top * (1.0 - wy) + bot * wy;
            }
        }
    }
}

/// Zero-pad spatial dims.
pub fn pad_spatial(x: &[f32], out: &mut [f32], h: usize, w: usize, c: usize, before: (usize, usize), after: (usize, usize)) {
    let ow = w + before.1 + after.1;
    out.fill(0.0);
    for y in 0..h {
        let src = y * w * c;
        let dst = ((y + before.0) * ow + before.1) * c;
        out[dst..dst + w * c].copy_from_slice(&x[src..src + w * c]);
    }
}

/// Standalone ReLU with optional clamp.
pub fn relu(x: &[f32], out: &mut [f32], max: Option<f32>) {
    match max {
        Some(m) => {
            for (o, &v) in out.iter_mut().zip(x.iter()) {
                *o = v.clamp(0.0, m);
            }
        }
        None => {
            for (o, &v) in out.iter_mut().zip(x.iter()) {
                *o = v.max(0.0);
            }
        }
    }
}

/// Sigmoid.
pub fn sigmoid(x: &[f32], out: &mut [f32]) {
    for (o, &v) in out.iter_mut().zip(x.iter()) {
        *o = 1.0 / (1.0 + (-v).exp());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom_same(h: usize, w: usize, k: usize, s: usize) -> Geom {
        let oh = crate::graph::conv_out_dim(h, k, s, 1, Padding::Same);
        let ow = crate::graph::conv_out_dim(w, k, s, 1, Padding::Same);
        Geom::new(h, w, oh, ow, (k, k), (s, s), (1, 1), Padding::Same)
    }

    #[test]
    fn conv_identity_kernel() {
        // 1x1 conv with identity weights returns the input.
        let x: Vec<f32> = (0..4 * 4 * 2).map(|i| i as f32).collect();
        let mut w = vec![0.0; 2 * 2];
        w[0] = 1.0; // c0 -> c0
        w[3] = 1.0; // c1 -> c1
        let b = vec![0.0; 2];
        let mut out = vec![0.0; 4 * 4 * 2];
        let g = geom_same(4, 4, 1, 1);
        conv2d(&x, &w, &b, &mut out, 2, 2, &g, Activation::None);
        assert_eq!(out, x);
    }

    #[test]
    fn conv_3x3_sum_kernel() {
        // All-ones 3x3 kernel on all-ones input: interior = 9, corner = 4.
        let x = vec![1.0; 5 * 5];
        let w = vec![1.0; 9];
        let b = vec![0.0; 1];
        let mut out = vec![0.0; 5 * 5];
        let g = geom_same(5, 5, 3, 1);
        conv2d(&x, &w, &b, &mut out, 1, 1, &g, Activation::None);
        assert_eq!(out[2 * 5 + 2], 9.0);
        assert_eq!(out[0], 4.0);
        assert_eq!(out[4], 4.0);
    }

    #[test]
    fn conv_bias_and_relu() {
        let x = vec![1.0; 4];
        let w = vec![-2.0];
        let b = vec![1.0];
        let mut out = vec![0.0; 4];
        let g = geom_same(2, 2, 1, 1);
        conv2d(&x, &w, &b, &mut out, 1, 1, &g, Activation::Relu);
        assert_eq!(out, vec![0.0; 4]); // 1 - 2 = -1 -> relu 0
    }

    #[test]
    fn dwconv_channels_independent() {
        // 2 channels: ch0 kernel = 1 (center), ch1 kernel = 2 (center).
        let x: Vec<f32> = vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0, 4.0, 40.0];
        let mut w = vec![0.0; 9 * 2];
        w[4 * 2] = 1.0;
        w[4 * 2 + 1] = 2.0;
        let b = vec![0.0; 2];
        let mut out = vec![0.0; 8];
        let g = geom_same(2, 2, 3, 1);
        dwconv2d(&x, &w, &b, &mut out, 2, &g, Activation::None);
        assert_eq!(out, vec![1.0, 20.0, 2.0, 40.0, 3.0, 60.0, 4.0, 80.0]);
    }

    #[test]
    fn pools() {
        let x = vec![1.0, 2.0, 3.0, 4.0]; // 2x2x1
        let g = Geom::new(2, 2, 1, 1, (2, 2), (2, 2), (1, 1), Padding::Valid);
        let mut out = vec![0.0];
        maxpool2d(&x, &mut out, 1, &g);
        assert_eq!(out[0], 4.0);
        avgpool2d(&x, &mut out, 1, &g);
        assert_eq!(out[0], 2.5);
    }

    #[test]
    fn gap() {
        let x = vec![1.0, 10.0, 3.0, 30.0]; // 2 pixels, 2 ch
        let mut out = vec![0.0; 2];
        global_avg_pool(&x, &mut out, 2, 2);
        assert_eq!(out, vec![2.0, 20.0]);
    }

    #[test]
    fn elementwise_and_fc() {
        let mut out = vec![0.0; 3];
        add(&[1.0, 2.0, -3.0], &[1.0, 1.0, 1.0], &mut out, Activation::Relu);
        assert_eq!(out, vec![2.0, 3.0, 0.0]);
        mul(&[2.0, 3.0, 4.0], &[5.0, 6.0, 7.0], &mut out);
        assert_eq!(out, vec![10.0, 18.0, 28.0]);

        // FC: x=[1,2], w=[[1,0],[0,1]] (row-major in*out), b=[10,20]
        let mut fco = vec![0.0; 2];
        fully_connected(&[1.0, 2.0], &[1.0, 0.0, 0.0, 1.0], &[10.0, 20.0], &mut fco, 2, 2, Activation::None);
        assert_eq!(fco, vec![11.0, 22.0]);
    }

    #[test]
    fn softmax_normalizes() {
        let mut out = vec![0.0; 3];
        softmax(&[1.0, 1.0, 1.0], &mut out, 3);
        for v in &out {
            assert!((v - 1.0 / 3.0).abs() < 1e-6);
        }
        softmax(&[0.0, 100.0, 0.0], &mut out, 3);
        assert!(out[1] > 0.999);
    }

    #[test]
    fn concat_interleaves() {
        let a = vec![1.0, 2.0, 10.0, 20.0]; // 2 pixels × 2ch
        let b = vec![5.0, 50.0]; // 2 pixels × 1ch
        let mut out = vec![0.0; 6];
        concat_channels(&[(&a, 2), (&b, 1)], &mut out, 2);
        assert_eq!(out, vec![1.0, 2.0, 5.0, 10.0, 20.0, 50.0]);
    }

    #[test]
    fn resize_identity() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let mut out = vec![0.0; 4];
        resize_bilinear(&x, &mut out, 2, 2, 2, 2, 1);
        assert_eq!(out, x);
    }

    #[test]
    fn resize_upsamples_smoothly() {
        let x = vec![0.0, 1.0]; // 1×2
        let mut out = vec![0.0; 4];
        resize_bilinear(&x, &mut out, 1, 2, 1, 4, 1);
        assert!(out[0] <= out[1] && out[1] <= out[2] && out[2] <= out[3]);
    }

    #[test]
    fn pad_places_block() {
        let x = vec![1.0, 2.0, 3.0, 4.0]; // 2x2x1
        let mut out = vec![9.0; 3 * 3];
        pad_spatial(&x, &mut out, 2, 2, 1, (1, 1), (0, 0));
        assert_eq!(out, vec![0.0, 0.0, 0.0, 0.0, 1.0, 2.0, 0.0, 3.0, 4.0]);
    }

    #[test]
    fn unary_ops() {
        let mut out = vec![0.0; 3];
        relu(&[-1.0, 0.5, 9.0], &mut out, Some(6.0));
        assert_eq!(out, vec![0.0, 0.5, 6.0]);
        sigmoid(&[0.0, 100.0, -100.0], &mut out);
        assert!((out[0] - 0.5).abs() < 1e-6 && out[1] > 0.999 && out[2] < 0.001);
    }
}
