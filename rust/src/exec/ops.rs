//! CPU kernels for the graph op set: a vectorized default family plus a
//! retained scalar reference family.
//!
//! The default kernels are written for auto-vectorization on stable Rust
//! (no intrinsics, no new deps): fixed-width `f32` micro-tiles over the
//! channel dimension keep accumulators in registers, and `conv2d` /
//! [`fully_connected`] share the register-blocked [`matmul_bias`] core
//! (1×1 stride-1 convolutions lower to it directly — im2col-free, the
//! pixel matrix *is* the left operand). Every kernel accumulates each
//! output element in the same order as its scalar reference (bias first,
//! then taps ascending in `(ky, kx, c)`), so the two families agree to
//! the last ulp and the parallel executor can assert bit-identity against
//! sequential runs.
//!
//! The original straight-loop kernels are retained under [`scalar`] as the
//! differential-test oracle and the recorded-baseline path of
//! `benches/serving.rs` (`BENCH_serving.json` keeps both numbers).

use crate::graph::{Activation, Padding};

/// Micro-tile width over the output-channel dimension: 8 `f32` lanes is one
/// AVX2 register / two NEON registers, and small enough that the compiler
/// keeps a [`MR`]×`NR` accumulator block resident.
pub const NR: usize = 8;
/// Register-block height (rows of the left matmul operand per block).
pub const MR: usize = 4;

/// Which kernel family the executor dispatches.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelMode {
    /// The retained straight-loop kernels in [`scalar`] — the
    /// differential-test oracle and the recorded perf baseline.
    Reference,
    /// Register-blocked, lane-chunked kernels (the default).
    #[default]
    Vectorized,
}

/// Apply a fused activation in place (lane-chunked).
#[inline]
pub fn activate(buf: &mut [f32], act: Activation) {
    match act {
        Activation::None => {}
        Activation::Relu => {
            let mut it = buf.chunks_exact_mut(NR);
            for chunk in &mut it {
                for v in chunk.iter_mut() {
                    *v = v.max(0.0);
                }
            }
            for v in it.into_remainder() {
                *v = v.max(0.0);
            }
        }
        Activation::Relu6 => {
            let mut it = buf.chunks_exact_mut(NR);
            for chunk in &mut it {
                for v in chunk.iter_mut() {
                    *v = v.clamp(0.0, 6.0);
                }
            }
            for v in it.into_remainder() {
                *v = v.clamp(0.0, 6.0);
            }
        }
    }
}

/// Spatial geometry of a conv/pool op, precomputed once per call.
pub struct Geom {
    /// Input height.
    pub h: usize,
    /// Input width.
    pub w: usize,
    /// Output height.
    pub oh: usize,
    /// Output width.
    pub ow: usize,
    /// Kernel height.
    pub kh: usize,
    /// Kernel width.
    pub kw: usize,
    /// Vertical stride.
    pub sh: usize,
    /// Horizontal stride.
    pub sw: usize,
    /// Vertical dilation.
    pub dh: usize,
    /// Horizontal dilation.
    pub dw: usize,
    /// Top padding (negative never occurs; `isize` for the inner loops).
    pub ph: isize,
    /// Left padding.
    pub pw: isize,
}

impl Geom {
    /// Precompute the geometry of one conv/pool call.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        h: usize,
        w: usize,
        oh: usize,
        ow: usize,
        kernel: (usize, usize),
        stride: (usize, usize),
        dilation: (usize, usize),
        padding: Padding,
    ) -> Self {
        let (ph, pw) = match padding {
            Padding::Valid => (0, 0),
            Padding::Same => {
                let p = crate::graph::same_padding_pair(h, w, kernel, stride, dilation);
                (p.0 as isize, p.1 as isize)
            }
        };
        Geom {
            h,
            w,
            oh,
            ow,
            kh: kernel.0,
            kw: kernel.1,
            sh: stride.0,
            sw: stride.1,
            dh: dilation.0,
            dw: dilation.1,
            ph,
            pw,
        }
    }

    /// True if this geometry is a stride-1 unpadded 1×1 convolution — the
    /// case that lowers to one [`matmul_bias`] call over the pixel matrix.
    #[inline]
    pub fn is_pointwise(&self) -> bool {
        self.kh == 1 && self.kw == 1 && self.sh == 1 && self.sw == 1 && self.ph == 0 && self.pw == 0
    }
}

/// Register-blocked matmul with bias: `out[m×n] = a[m×k] · w[k×n] + bias[n]`.
///
/// `a` rows are `lda` elements apart (so a strided pixel matrix can feed it
/// without packing); `out` rows are `ldc` apart. Full blocks run as
/// [`MR`]×[`NR`] accumulator tiles held in registers; remainders fall back
/// to narrower tiles. Every output element accumulates `k`-ascending, so
/// the result is bit-identical across block shapes and to a straight
/// triple loop.
#[allow(clippy::too_many_arguments)]
pub fn matmul_bias(
    a: &[f32],
    lda: usize,
    w: &[f32],
    bias: &[f32],
    out: &mut [f32],
    ldc: usize,
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert!(a.len() >= m.saturating_sub(1) * lda + k || m == 0);
    debug_assert!(w.len() >= k * n);
    debug_assert!(bias.len() >= n);
    let mut r = 0;
    while r + MR <= m {
        let mut c0 = 0;
        while c0 + NR <= n {
            let mut acc = [[0f32; NR]; MR];
            for row in acc.iter_mut() {
                row.copy_from_slice(&bias[c0..c0 + NR]);
            }
            for kk in 0..k {
                let wrow = &w[kk * n + c0..kk * n + c0 + NR];
                for (ri, row) in acc.iter_mut().enumerate() {
                    let av = a[(r + ri) * lda + kk];
                    for (ci, &wv) in wrow.iter().enumerate() {
                        row[ci] += av * wv;
                    }
                }
            }
            for (ri, row) in acc.iter().enumerate() {
                let o = (r + ri) * ldc + c0;
                out[o..o + NR].copy_from_slice(row);
            }
            c0 += NR;
        }
        for ri in 0..MR {
            matmul_row_tail(a, (r + ri) * lda, w, bias, out, (r + ri) * ldc, c0, k, n);
        }
        r += MR;
    }
    while r < m {
        let a_off = r * lda;
        let o_off = r * ldc;
        let mut c0 = 0;
        while c0 + NR <= n {
            let mut acc = [0f32; NR];
            acc.copy_from_slice(&bias[c0..c0 + NR]);
            for kk in 0..k {
                let av = a[a_off + kk];
                let wrow = &w[kk * n + c0..kk * n + c0 + NR];
                for (ci, &wv) in wrow.iter().enumerate() {
                    acc[ci] += av * wv;
                }
            }
            out[o_off + c0..o_off + c0 + NR].copy_from_slice(&acc);
            c0 += NR;
        }
        matmul_row_tail(a, a_off, w, bias, out, o_off, c0, k, n);
        r += 1;
    }
}

/// Scalar tail of [`matmul_bias`]: columns `c0..n` of one output row,
/// still `k`-ascending per element.
#[allow(clippy::too_many_arguments)]
#[inline]
fn matmul_row_tail(
    a: &[f32],
    a_off: usize,
    w: &[f32],
    bias: &[f32],
    out: &mut [f32],
    o_off: usize,
    c0: usize,
    k: usize,
    n: usize,
) {
    for ci in c0..n {
        let mut acc = bias[ci];
        for kk in 0..k {
            acc += a[a_off + kk] * w[kk * n + ci];
        }
        out[o_off + ci] = acc;
    }
}

/// Standard convolution, NHWC × [kh,kw,ic,oc] → NHWC. Batch 1.
///
/// Stride-1 unpadded 1×1 kernels lower to [`matmul_bias`] over the pixel
/// matrix; the general path register-blocks the output channels ([`NR`]
/// lanes per tile) and keeps the tile in registers across all kernel taps.
#[allow(clippy::too_many_arguments)]
pub fn conv2d(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    out: &mut [f32],
    ic: usize,
    oc: usize,
    g: &Geom,
    act: Activation,
) {
    debug_assert!(x.len() >= g.h * g.w * ic);
    if g.is_pointwise() {
        matmul_bias(x, ic, w, b, out, oc, g.oh * g.ow, ic, oc);
        activate(&mut out[..g.oh * g.ow * oc], act);
        return;
    }
    for oy in 0..g.oh {
        for ox in 0..g.ow {
            let o_base = (oy * g.ow + ox) * oc;
            let mut c0 = 0;
            while c0 + NR <= oc {
                let mut acc = [0f32; NR];
                acc.copy_from_slice(&b[c0..c0 + NR]);
                conv_taps(x, w, &mut acc, NR, ic, oc, g, oy, ox, c0);
                out[o_base + c0..o_base + c0 + NR].copy_from_slice(&acc);
                c0 += NR;
            }
            if c0 < oc {
                let wn = oc - c0;
                let mut acc = [0f32; NR];
                acc[..wn].copy_from_slice(&b[c0..c0 + wn]);
                conv_taps(x, w, &mut acc, wn, ic, oc, g, oy, ox, c0);
                out[o_base + c0..o_base + oc].copy_from_slice(&acc[..wn]);
            }
        }
    }
    activate(out, act);
}

/// Accumulate all valid kernel taps of one output pixel into an `NR`-wide
/// output-channel tile starting at channel `c0` (`wn` live lanes). Taps
/// run `(ky, kx, c)`-ascending — the scalar reference order.
#[allow(clippy::too_many_arguments)]
#[inline]
fn conv_taps(
    x: &[f32],
    w: &[f32],
    acc: &mut [f32; NR],
    wn: usize,
    ic: usize,
    oc: usize,
    g: &Geom,
    oy: usize,
    ox: usize,
    c0: usize,
) {
    for ky in 0..g.kh {
        let iy = oy as isize * g.sh as isize + ky as isize * g.dh as isize - g.ph;
        if iy < 0 || iy >= g.h as isize {
            continue;
        }
        for kx in 0..g.kw {
            let ix = ox as isize * g.sw as isize + kx as isize * g.dw as isize - g.pw;
            if ix < 0 || ix >= g.w as isize {
                continue;
            }
            let i_base = (iy as usize * g.w + ix as usize) * ic;
            let w_base = (ky * g.kw + kx) * ic * oc;
            if wn == NR {
                for c in 0..ic {
                    let xv = x[i_base + c];
                    let wrow = &w[w_base + c * oc + c0..w_base + c * oc + c0 + NR];
                    for (l, &wv) in wrow.iter().enumerate() {
                        acc[l] += xv * wv;
                    }
                }
            } else {
                for c in 0..ic {
                    let xv = x[i_base + c];
                    let wrow = &w[w_base + c * oc + c0..w_base + c * oc + c0 + wn];
                    for (l, &wv) in wrow.iter().enumerate() {
                        acc[l] += xv * wv;
                    }
                }
            }
        }
    }
}

/// Depthwise convolution (multiplier 1), weights [kh,kw,c,1].
///
/// Channels are independent, so the kernel tiles them [`NR`] at a time and
/// keeps each tile in registers across all taps.
pub fn dwconv2d(x: &[f32], w: &[f32], b: &[f32], out: &mut [f32], c: usize, g: &Geom, act: Activation) {
    for oy in 0..g.oh {
        for ox in 0..g.ow {
            let o_base = (oy * g.ow + ox) * c;
            let mut c0 = 0;
            while c0 < c {
                let wn = NR.min(c - c0);
                let mut acc = [0f32; NR];
                acc[..wn].copy_from_slice(&b[c0..c0 + wn]);
                for ky in 0..g.kh {
                    let iy = oy as isize * g.sh as isize + ky as isize * g.dh as isize - g.ph;
                    if iy < 0 || iy >= g.h as isize {
                        continue;
                    }
                    for kx in 0..g.kw {
                        let ix = ox as isize * g.sw as isize + kx as isize * g.dw as isize - g.pw;
                        if ix < 0 || ix >= g.w as isize {
                            continue;
                        }
                        let i_base = (iy as usize * g.w + ix as usize) * c + c0;
                        let w_base = (ky * g.kw + kx) * c + c0;
                        for l in 0..wn {
                            acc[l] += x[i_base + l] * w[w_base + l];
                        }
                    }
                }
                out[o_base + c0..o_base + c0 + wn].copy_from_slice(&acc[..wn]);
                c0 += wn;
            }
        }
    }
    activate(out, act);
}

/// Max pooling (channel-tiled).
pub fn maxpool2d(x: &[f32], out: &mut [f32], c: usize, g: &Geom) {
    for oy in 0..g.oh {
        for ox in 0..g.ow {
            let o_base = (oy * g.ow + ox) * c;
            let mut c0 = 0;
            while c0 < c {
                let wn = NR.min(c - c0);
                let mut acc = [f32::NEG_INFINITY; NR];
                for ky in 0..g.kh {
                    let iy = oy as isize * g.sh as isize + ky as isize - g.ph;
                    if iy < 0 || iy >= g.h as isize {
                        continue;
                    }
                    for kx in 0..g.kw {
                        let ix = ox as isize * g.sw as isize + kx as isize - g.pw;
                        if ix < 0 || ix >= g.w as isize {
                            continue;
                        }
                        let i_base = (iy as usize * g.w + ix as usize) * c + c0;
                        for l in 0..wn {
                            acc[l] = acc[l].max(x[i_base + l]);
                        }
                    }
                }
                out[o_base + c0..o_base + c0 + wn].copy_from_slice(&acc[..wn]);
                c0 += wn;
            }
        }
    }
}

/// Average pooling (TFLite semantics: average over *valid* taps only),
/// channel-tiled.
pub fn avgpool2d(x: &[f32], out: &mut [f32], c: usize, g: &Geom) {
    for oy in 0..g.oh {
        for ox in 0..g.ow {
            let o_base = (oy * g.ow + ox) * c;
            let mut c0 = 0;
            while c0 < c {
                let wn = NR.min(c - c0);
                let mut acc = [0f32; NR];
                let mut count = 0f32;
                for ky in 0..g.kh {
                    let iy = oy as isize * g.sh as isize + ky as isize - g.ph;
                    if iy < 0 || iy >= g.h as isize {
                        continue;
                    }
                    for kx in 0..g.kw {
                        let ix = ox as isize * g.sw as isize + kx as isize - g.pw;
                        if ix < 0 || ix >= g.w as isize {
                            continue;
                        }
                        count += 1.0;
                        let i_base = (iy as usize * g.w + ix as usize) * c + c0;
                        for l in 0..wn {
                            acc[l] += x[i_base + l];
                        }
                    }
                }
                let inv = 1.0 / count.max(1.0);
                for l in 0..wn {
                    out[o_base + c0 + l] = acc[l] * inv;
                }
                c0 += wn;
            }
        }
    }
}

/// Global average pool: [h*w*c] -> [c], channel-tiled with pixel-ascending
/// accumulation (the scalar reference order).
pub fn global_avg_pool(x: &[f32], out: &mut [f32], hw: usize, c: usize) {
    let inv = 1.0 / hw as f32;
    let mut c0 = 0;
    while c0 < c {
        let wn = NR.min(c - c0);
        let mut acc = [0f32; NR];
        for i in 0..hw {
            let base = i * c + c0;
            for l in 0..wn {
                acc[l] += x[base + l];
            }
        }
        for l in 0..wn {
            out[c0 + l] = acc[l] * inv;
        }
        c0 += wn;
    }
}

/// Elementwise add with fused activation (lane-chunked).
pub fn add(a: &[f32], b: &[f32], out: &mut [f32], act: Activation) {
    let n = out.len().min(a.len()).min(b.len());
    let (oc, orem) = out[..n].split_at_mut(n - n % NR);
    for (i, chunk) in oc.chunks_exact_mut(NR).enumerate() {
        let av = &a[i * NR..i * NR + NR];
        let bv = &b[i * NR..i * NR + NR];
        for l in 0..NR {
            chunk[l] = av[l] + bv[l];
        }
    }
    let base = n - n % NR;
    for (l, o) in orem.iter_mut().enumerate() {
        *o = a[base + l] + b[base + l];
    }
    activate(&mut out[..n], act);
}

/// Elementwise multiply (lane-chunked).
pub fn mul(a: &[f32], b: &[f32], out: &mut [f32]) {
    let n = out.len().min(a.len()).min(b.len());
    let (oc, orem) = out[..n].split_at_mut(n - n % NR);
    for (i, chunk) in oc.chunks_exact_mut(NR).enumerate() {
        let av = &a[i * NR..i * NR + NR];
        let bv = &b[i * NR..i * NR + NR];
        for l in 0..NR {
            chunk[l] = av[l] * bv[l];
        }
    }
    let base = n - n % NR;
    for (l, o) in orem.iter_mut().enumerate() {
        *o = a[base + l] * b[base + l];
    }
}

/// Channel concat: interleave per-pixel channel runs.
pub fn concat_channels(parts: &[(&[f32], usize)], out: &mut [f32], pixels: usize) {
    let oc: usize = parts.iter().map(|&(_, c)| c).sum();
    for p in 0..pixels {
        let mut off = 0;
        for &(buf, c) in parts {
            out[p * oc + off..p * oc + off + c].copy_from_slice(&buf[p * c..(p + 1) * c]);
            off += c;
        }
    }
}

/// Fully connected: [in] × [in,out] + [out] — a 1-row [`matmul_bias`].
pub fn fully_connected(x: &[f32], w: &[f32], b: &[f32], out: &mut [f32], ind: usize, outd: usize, act: Activation) {
    matmul_bias(x, ind, w, b, out, outd, 1, ind, outd);
    activate(&mut out[..outd], act);
}

/// Softmax over the last axis of a [rows, cols] view.
pub fn softmax(x: &[f32], out: &mut [f32], cols: usize) {
    for (xr, or) in x.chunks_exact(cols).zip(out.chunks_exact_mut(cols)) {
        let m = xr.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for (o, &v) in or.iter_mut().zip(xr.iter()) {
            *o = (v - m).exp();
            sum += *o;
        }
        let inv = 1.0 / sum;
        for o in or.iter_mut() {
            *o *= inv;
        }
    }
}

/// Bilinear resize (align_corners = false, TFLite default).
pub fn resize_bilinear(x: &[f32], out: &mut [f32], h: usize, w: usize, oh: usize, ow: usize, c: usize) {
    let sy = h as f32 / oh as f32;
    let sx = w as f32 / ow as f32;
    for oy in 0..oh {
        let fy = ((oy as f32 + 0.5) * sy - 0.5).max(0.0);
        let y0 = (fy as usize).min(h - 1);
        let y1 = (y0 + 1).min(h - 1);
        let wy = fy - y0 as f32;
        for ox in 0..ow {
            let fx = ((ox as f32 + 0.5) * sx - 0.5).max(0.0);
            let x0 = (fx as usize).min(w - 1);
            let x1 = (x0 + 1).min(w - 1);
            let wx = fx - x0 as f32;
            let o_base = (oy * ow + ox) * c;
            let b00 = (y0 * w + x0) * c;
            let b01 = (y0 * w + x1) * c;
            let b10 = (y1 * w + x0) * c;
            let b11 = (y1 * w + x1) * c;
            for ch in 0..c {
                let top = x[b00 + ch] * (1.0 - wx) + x[b01 + ch] * wx;
                let bot = x[b10 + ch] * (1.0 - wx) + x[b11 + ch] * wx;
                out[o_base + ch] = top * (1.0 - wy) + bot * wy;
            }
        }
    }
}

/// Zero-pad spatial dims.
pub fn pad_spatial(x: &[f32], out: &mut [f32], h: usize, w: usize, c: usize, before: (usize, usize), after: (usize, usize)) {
    let ow = w + before.1 + after.1;
    out.fill(0.0);
    for y in 0..h {
        let src = y * w * c;
        let dst = ((y + before.0) * ow + before.1) * c;
        out[dst..dst + w * c].copy_from_slice(&x[src..src + w * c]);
    }
}

/// Standalone ReLU with optional clamp (lane-chunked).
pub fn relu(x: &[f32], out: &mut [f32], max: Option<f32>) {
    let n = out.len().min(x.len());
    match max {
        Some(m) => {
            for (o, &v) in out[..n].iter_mut().zip(x.iter()) {
                *o = v.clamp(0.0, m);
            }
        }
        None => {
            for (o, &v) in out[..n].iter_mut().zip(x.iter()) {
                *o = v.max(0.0);
            }
        }
    }
}

/// Sigmoid.
pub fn sigmoid(x: &[f32], out: &mut [f32]) {
    for (o, &v) in out.iter_mut().zip(x.iter()) {
        *o = 1.0 / (1.0 + (-v).exp());
    }
}

pub mod scalar {
    //! The retained straight-loop reference kernels — the pre-vectorization
    //! executor path, kept verbatim as (a) the oracle for the differential
    //! kernel tests in `tests/kernel_diff.rs` and (b) the recorded scalar
    //! baseline that `benches/serving.rs` measures into `BENCH_serving.json`.
    //!
    //! Per output element these accumulate bias first, then kernel taps
    //! ascending in `(ky, kx, c)` — the same order as the vectorized
    //! family, which is what keeps the two within 1 ulp (the only
    //! divergence is the `x == 0.0` skip below, which changes no finite
    //! value). Do not "improve" these: their job is to stay simple.

    use super::{activate, Geom};
    use crate::graph::Activation;

    /// Reference standard convolution, NHWC × [kh,kw,ic,oc] → NHWC.
    #[allow(clippy::too_many_arguments)]
    pub fn conv2d(
        x: &[f32],
        w: &[f32],
        b: &[f32],
        out: &mut [f32],
        ic: usize,
        oc: usize,
        g: &Geom,
        act: Activation,
    ) {
        debug_assert!(x.len() >= g.h * g.w * ic);
        for oy in 0..g.oh {
            for ox in 0..g.ow {
                let o_base = (oy * g.ow + ox) * oc;
                out[o_base..o_base + oc].copy_from_slice(&b[..oc]);
                for ky in 0..g.kh {
                    let iy = oy as isize * g.sh as isize + ky as isize * g.dh as isize - g.ph;
                    if iy < 0 || iy >= g.h as isize {
                        continue;
                    }
                    for kx in 0..g.kw {
                        let ix = ox as isize * g.sw as isize + kx as isize * g.dw as isize - g.pw;
                        if ix < 0 || ix >= g.w as isize {
                            continue;
                        }
                        let i_base = (iy as usize * g.w + ix as usize) * ic;
                        let w_base = (ky * g.kw + kx) * ic * oc;
                        for c in 0..ic {
                            let xv = x[i_base + c];
                            if xv == 0.0 {
                                continue;
                            }
                            let wrow = &w[w_base + c * oc..w_base + (c + 1) * oc];
                            let orow = &mut out[o_base..o_base + oc];
                            for (o, &wv) in orow.iter_mut().zip(wrow.iter()) {
                                *o += xv * wv;
                            }
                        }
                    }
                }
            }
        }
        activate(out, act);
    }

    /// Reference depthwise convolution (multiplier 1), weights [kh,kw,c,1].
    pub fn dwconv2d(x: &[f32], w: &[f32], b: &[f32], out: &mut [f32], c: usize, g: &Geom, act: Activation) {
        for oy in 0..g.oh {
            for ox in 0..g.ow {
                let o_base = (oy * g.ow + ox) * c;
                out[o_base..o_base + c].copy_from_slice(&b[..c]);
                for ky in 0..g.kh {
                    let iy = oy as isize * g.sh as isize + ky as isize * g.dh as isize - g.ph;
                    if iy < 0 || iy >= g.h as isize {
                        continue;
                    }
                    for kx in 0..g.kw {
                        let ix = ox as isize * g.sw as isize + kx as isize * g.dw as isize - g.pw;
                        if ix < 0 || ix >= g.w as isize {
                            continue;
                        }
                        let i_base = (iy as usize * g.w + ix as usize) * c;
                        let w_base = (ky * g.kw + kx) * c;
                        for ch in 0..c {
                            out[o_base + ch] += x[i_base + ch] * w[w_base + ch];
                        }
                    }
                }
            }
        }
        activate(out, act);
    }

    /// Reference max pooling.
    pub fn maxpool2d(x: &[f32], out: &mut [f32], c: usize, g: &Geom) {
        for oy in 0..g.oh {
            for ox in 0..g.ow {
                let o_base = (oy * g.ow + ox) * c;
                out[o_base..o_base + c].fill(f32::NEG_INFINITY);
                for ky in 0..g.kh {
                    let iy = oy as isize * g.sh as isize + ky as isize - g.ph;
                    if iy < 0 || iy >= g.h as isize {
                        continue;
                    }
                    for kx in 0..g.kw {
                        let ix = ox as isize * g.sw as isize + kx as isize - g.pw;
                        if ix < 0 || ix >= g.w as isize {
                            continue;
                        }
                        let i_base = (iy as usize * g.w + ix as usize) * c;
                        for ch in 0..c {
                            let v = x[i_base + ch];
                            if v > out[o_base + ch] {
                                out[o_base + ch] = v;
                            }
                        }
                    }
                }
            }
        }
    }

    /// Reference average pooling (average over *valid* taps only).
    pub fn avgpool2d(x: &[f32], out: &mut [f32], c: usize, g: &Geom) {
        for oy in 0..g.oh {
            for ox in 0..g.ow {
                let o_base = (oy * g.ow + ox) * c;
                out[o_base..o_base + c].fill(0.0);
                let mut count = 0f32;
                for ky in 0..g.kh {
                    let iy = oy as isize * g.sh as isize + ky as isize - g.ph;
                    if iy < 0 || iy >= g.h as isize {
                        continue;
                    }
                    for kx in 0..g.kw {
                        let ix = ox as isize * g.sw as isize + kx as isize - g.pw;
                        if ix < 0 || ix >= g.w as isize {
                            continue;
                        }
                        count += 1.0;
                        let i_base = (iy as usize * g.w + ix as usize) * c;
                        for ch in 0..c {
                            out[o_base + ch] += x[i_base + ch];
                        }
                    }
                }
                let inv = 1.0 / count.max(1.0);
                for ch in 0..c {
                    out[o_base + ch] *= inv;
                }
            }
        }
    }

    /// Reference global average pool: [h*w*c] -> [c].
    pub fn global_avg_pool(x: &[f32], out: &mut [f32], hw: usize, c: usize) {
        out[..c].fill(0.0);
        for i in 0..hw {
            let base = i * c;
            for ch in 0..c {
                out[ch] += x[base + ch];
            }
        }
        let inv = 1.0 / hw as f32;
        for ch in out[..c].iter_mut() {
            *ch *= inv;
        }
    }

    /// Reference elementwise add with fused activation.
    pub fn add(a: &[f32], b: &[f32], out: &mut [f32], act: Activation) {
        for ((o, &x), &y) in out.iter_mut().zip(a.iter()).zip(b.iter()) {
            *o = x + y;
        }
        activate(out, act);
    }

    /// Reference elementwise multiply.
    pub fn mul(a: &[f32], b: &[f32], out: &mut [f32]) {
        for ((o, &x), &y) in out.iter_mut().zip(a.iter()).zip(b.iter()) {
            *o = x * y;
        }
    }

    /// Reference fully connected: [in] × [in,out] + [out].
    pub fn fully_connected(x: &[f32], w: &[f32], b: &[f32], out: &mut [f32], ind: usize, outd: usize, act: Activation) {
        out[..outd].copy_from_slice(&b[..outd]);
        for (i, &xv) in x[..ind].iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[i * outd..(i + 1) * outd];
            for (o, &wv) in out[..outd].iter_mut().zip(wrow.iter()) {
                *o += xv * wv;
            }
        }
        activate(&mut out[..outd], act);
    }

    /// Reference standalone ReLU with optional clamp.
    pub fn relu(x: &[f32], out: &mut [f32], max: Option<f32>) {
        match max {
            Some(m) => {
                for (o, &v) in out.iter_mut().zip(x.iter()) {
                    *o = v.clamp(0.0, m);
                }
            }
            None => {
                for (o, &v) in out.iter_mut().zip(x.iter()) {
                    *o = v.max(0.0);
                }
            }
        }
    }

    /// Reference sigmoid.
    pub fn sigmoid(x: &[f32], out: &mut [f32]) {
        for (o, &v) in out.iter_mut().zip(x.iter()) {
            *o = 1.0 / (1.0 + (-v).exp());
        }
    }
}

pub mod quant {
    //! Quantized activation kernels: the i8/f16 size-class execution path.
    //!
    //! Quantization here is **activation-only**: weights, io buffers, and
    //! all kernel arithmetic stay `f32`; only the arena-resident
    //! intermediate tensors are stored packed ([`quantize_into`] /
    //! [`dequantize_from`]) at the element width of the request's
    //! [`Dtype`], with per-record affine parameters ([`QParams`]) chosen
    //! from the produced values' own range at the producing step — the
    //! per-record wave boundary of the quantized path. The kernel family
    //! below wraps the vectorized `f32` kernels in exactly that
    //! round-trip, so the retained scalar family stays the accuracy
    //! oracle: every quantized kernel output must sit within one
    //! quantization [`step`] of the oracle run on the same dequantized
    //! operands (`tests/quant_diff.rs`).
    //!
    //! `f16` needs no parameters — it is a bit-level narrowing with
    //! round-to-nearest-even, hand-rolled below (the crate takes no
    //! `half` dependency). `i8` uses a 255-step affine grid whose zero
    //! point is exactly representable, TFLite-style, so ReLU floors and
    //! zero padding round-trip bit-exactly.

    use super::Geom;
    use crate::graph::Activation;
    use crate::planner::Dtype;

    /// Per-record affine quantization parameters:
    /// `real = (code - zero_point) * scale`.
    #[derive(Debug, Clone, Copy, PartialEq)]
    pub struct QParams {
        /// Grid spacing — one quantization step — in real units.
        pub scale: f32,
        /// Grid point representing real zero (always integral, in range).
        pub zero_point: f32,
    }

    impl QParams {
        /// The do-nothing parameters used by the non-affine dtypes
        /// ([`Dtype::F32`] identity and the [`Dtype::F16`] bit narrowing).
        pub const IDENTITY: QParams = QParams { scale: 1.0, zero_point: 0.0 };
    }

    /// Affine parameters covering `[min, max]` on the dtype's grid. The
    /// range is widened to contain zero so real 0.0 is exactly
    /// representable. Only [`Dtype::I8`] is affine; the other dtypes
    /// return [`QParams::IDENTITY`].
    pub fn choose_qparams(dtype: Dtype, min: f32, max: f32) -> QParams {
        if dtype != Dtype::I8 {
            return QParams::IDENTITY;
        }
        let min = min.min(0.0);
        let max = max.max(0.0);
        let scale = ((max - min) / 255.0).max(f32::MIN_POSITIVE);
        let zero_point = (-128.0 - min / scale).round().clamp(-128.0, 127.0);
        QParams { scale, zero_point }
    }

    /// The quantization-step width at value `at` — the error-budget unit
    /// of the differential suite. `i8` grids are uniform (the step is the
    /// scale); `f16` steps are the ulp of the value's binade; `f32` is
    /// the identity path and has no step.
    pub fn step(dtype: Dtype, qp: QParams, at: f32) -> f32 {
        match dtype {
            Dtype::F32 => 0.0,
            Dtype::I8 => qp.scale,
            Dtype::F16 => {
                let e = (f32_to_f16_bits(at.abs()) >> 10) & 0x1f;
                if e >= 0x1e {
                    // Top binade (or overflow to inf): the largest finite
                    // step, 2^5.
                    32.0
                } else {
                    // Subnormals (e == 0) share the fixed 2^-24 spacing of
                    // the e == 1 binade.
                    2f32.powi(i32::from(e.max(1)) - 25)
                }
            }
        }
    }

    /// Narrow an `f32` to IEEE 754 binary16 bits, round-to-nearest-even.
    pub fn f32_to_f16_bits(v: f32) -> u16 {
        let bits = v.to_bits();
        let sign = ((bits >> 16) & 0x8000) as u16;
        let exp = ((bits >> 23) & 0xff) as i32;
        let man = bits & 0x007f_ffff;
        if exp == 0xff {
            // Inf and NaN (payload truncated, kept quiet).
            return sign | 0x7c00 | if man != 0 { 0x0200 } else { 0 };
        }
        let e = exp - 127 + 15;
        if e >= 0x1f {
            return sign | 0x7c00; // overflow -> inf
        }
        if e <= 0 {
            if e < -10 {
                return sign; // underflow -> signed zero
            }
            // Subnormal: shift the full 24-bit significand into place.
            let full = man | 0x0080_0000;
            let shift = (14 - e) as u32;
            let m = full >> shift;
            let rem = full & ((1u32 << shift) - 1);
            let half = 1u32 << (shift - 1);
            let mut h = sign | m as u16;
            if rem > half || (rem == half && (m & 1) == 1) {
                h += 1; // a carry lands on the smallest normal exactly
            }
            return h;
        }
        // Normal: drop 13 mantissa bits with round-to-nearest-even; a
        // mantissa carry walks into the exponent (and, at the top binade,
        // into inf) by bit layout.
        let m = man >> 13;
        let rem = man & 0x1fff;
        let mut h = sign | ((e as u16) << 10) | m as u16;
        if rem > 0x1000 || (rem == 0x1000 && (m & 1) == 1) {
            h += 1;
        }
        h
    }

    /// Widen IEEE 754 binary16 bits back to `f32` (exact).
    pub fn f16_bits_to_f32(h: u16) -> f32 {
        let sign = (u32::from(h) & 0x8000) << 16;
        let exp = u32::from(h >> 10) & 0x1f;
        let man = u32::from(h) & 0x03ff;
        if exp == 0x1f {
            return f32::from_bits(sign | 0x7f80_0000 | (man << 13));
        }
        if exp == 0 {
            // Subnormal (or zero): exact as man * 2^-24.
            let mag = man as f32 * 2f32.powi(-24);
            return if sign != 0 { -mag } else { mag };
        }
        f32::from_bits(sign | ((exp + 127 - 15) << 23) | (man << 13))
    }

    /// Packed `f32`-word footprint of `n` values of `dtype` (4 `i8` codes
    /// or 2 `f16` halves per word).
    pub fn packed_words(dtype: Dtype, n: usize) -> usize {
        match dtype {
            Dtype::F32 => n,
            Dtype::F16 => n.div_ceil(2),
            Dtype::I8 => n.div_ceil(4),
        }
    }

    /// Quantize `src` onto the dtype's grid and pack it into `dst`'s
    /// leading [`packed_words`] words. The arena stripe is
    /// `f32`-addressed, so codes ride in word bit patterns — 4 `i8` codes
    /// or 2 `f16` halves per word, little end first.
    pub fn quantize_into(dtype: Dtype, qp: QParams, src: &[f32], dst: &mut [f32]) {
        debug_assert!(dst.len() >= packed_words(dtype, src.len()));
        match dtype {
            Dtype::F32 => dst[..src.len()].copy_from_slice(src),
            Dtype::F16 => {
                for (word, pair) in dst.iter_mut().zip(src.chunks(2)) {
                    let lo = u32::from(f32_to_f16_bits(pair[0]));
                    let hi = pair.get(1).map_or(0, |&v| u32::from(f32_to_f16_bits(v)));
                    *word = f32::from_bits(lo | (hi << 16));
                }
            }
            Dtype::I8 => {
                for (word, quad) in dst.iter_mut().zip(src.chunks(4)) {
                    let mut bits = 0u32;
                    for (j, &v) in quad.iter().enumerate() {
                        let q =
                            (v / qp.scale + qp.zero_point).round().clamp(-128.0, 127.0) as i8;
                        bits |= u32::from(q as u8) << (8 * j);
                    }
                    *word = f32::from_bits(bits);
                }
            }
        }
    }

    /// Unpack `dst.len()` values of `dtype` from `src`'s packed words and
    /// dequantize them to `f32` — the inverse of [`quantize_into`].
    pub fn dequantize_from(dtype: Dtype, qp: QParams, src: &[f32], dst: &mut [f32]) {
        debug_assert!(src.len() >= packed_words(dtype, dst.len()));
        match dtype {
            Dtype::F32 => dst.copy_from_slice(&src[..dst.len()]),
            Dtype::F16 => {
                for (i, v) in dst.iter_mut().enumerate() {
                    let bits = src[i / 2].to_bits() >> (16 * (i % 2));
                    *v = f16_bits_to_f32((bits & 0xffff) as u16);
                }
            }
            Dtype::I8 => {
                for (i, v) in dst.iter_mut().enumerate() {
                    let code = (src[i / 4].to_bits() >> (8 * (i % 4))) as u8 as i8;
                    *v = (f32::from(code) - qp.zero_point) * qp.scale;
                }
            }
        }
    }

    /// Minimum and maximum of a slice (`(inf, -inf)` when empty;
    /// [`choose_qparams`] widens any range to contain zero).
    pub fn min_max(buf: &[f32]) -> (f32, f32) {
        buf.iter()
            .fold((f32::INFINITY, f32::NEG_INFINITY), |(lo, hi), &v| (lo.min(v), hi.max(v)))
    }

    /// Quantize-dequantize `buf` in place at `dtype` — the round-trip
    /// every arena-resident value undergoes — and return the parameters
    /// used, chosen from the slice's own range.
    pub fn round_trip(dtype: Dtype, buf: &mut [f32]) -> QParams {
        if dtype == Dtype::F32 {
            return QParams::IDENTITY;
        }
        let (lo, hi) = min_max(buf);
        let qp = choose_qparams(dtype, lo, hi);
        let mut packed = vec![0f32; packed_words(dtype, buf.len())];
        quantize_into(dtype, qp, buf, &mut packed);
        dequantize_from(dtype, qp, &packed, buf);
        qp
    }

    /// Quantized standard convolution: the activation input round-trips
    /// through the dtype's grid, the vectorized `f32` kernel runs on the
    /// dequantized values, and the output round-trips back. Weights and
    /// bias stay `f32`. Returns the output's parameters — the step unit
    /// of the differential budget.
    #[allow(clippy::too_many_arguments)]
    pub fn conv2d(
        x: &[f32],
        w: &[f32],
        b: &[f32],
        out: &mut [f32],
        ic: usize,
        oc: usize,
        g: &Geom,
        act: Activation,
        dtype: Dtype,
    ) -> QParams {
        let mut xq = x.to_vec();
        round_trip(dtype, &mut xq);
        super::conv2d(&xq, w, b, out, ic, oc, g, act);
        round_trip(dtype, out)
    }

    /// Quantized depthwise convolution (see [`conv2d`] for the protocol).
    #[allow(clippy::too_many_arguments)]
    pub fn dwconv2d(
        x: &[f32],
        w: &[f32],
        b: &[f32],
        out: &mut [f32],
        c: usize,
        g: &Geom,
        act: Activation,
        dtype: Dtype,
    ) -> QParams {
        let mut xq = x.to_vec();
        round_trip(dtype, &mut xq);
        super::dwconv2d(&xq, w, b, out, c, g, act);
        round_trip(dtype, out)
    }

    /// Quantized max pooling (see [`conv2d`] for the protocol).
    pub fn maxpool2d(x: &[f32], out: &mut [f32], c: usize, g: &Geom, dtype: Dtype) -> QParams {
        let mut xq = x.to_vec();
        round_trip(dtype, &mut xq);
        super::maxpool2d(&xq, out, c, g);
        round_trip(dtype, out)
    }

    /// Quantized average pooling (see [`conv2d`] for the protocol).
    pub fn avgpool2d(x: &[f32], out: &mut [f32], c: usize, g: &Geom, dtype: Dtype) -> QParams {
        let mut xq = x.to_vec();
        round_trip(dtype, &mut xq);
        super::avgpool2d(&xq, out, c, g);
        round_trip(dtype, out)
    }

    /// Quantized global average pool (see [`conv2d`] for the protocol).
    pub fn global_avg_pool(
        x: &[f32],
        out: &mut [f32],
        hw: usize,
        c: usize,
        dtype: Dtype,
    ) -> QParams {
        let mut xq = x.to_vec();
        round_trip(dtype, &mut xq);
        super::global_avg_pool(&xq, out, hw, c);
        round_trip(dtype, out)
    }

    /// Quantized elementwise add: each operand round-trips under its own
    /// parameters (per-record, like the executor's arena stripes).
    pub fn add(a: &[f32], b: &[f32], out: &mut [f32], act: Activation, dtype: Dtype) -> QParams {
        let (mut aq, mut bq) = (a.to_vec(), b.to_vec());
        round_trip(dtype, &mut aq);
        round_trip(dtype, &mut bq);
        super::add(&aq, &bq, out, act);
        round_trip(dtype, out)
    }

    /// Quantized elementwise multiply (see [`add`] for the protocol).
    pub fn mul(a: &[f32], b: &[f32], out: &mut [f32], dtype: Dtype) -> QParams {
        let (mut aq, mut bq) = (a.to_vec(), b.to_vec());
        round_trip(dtype, &mut aq);
        round_trip(dtype, &mut bq);
        super::mul(&aq, &bq, out);
        round_trip(dtype, out)
    }

    /// Quantized fully connected layer (see [`conv2d`] for the protocol).
    #[allow(clippy::too_many_arguments)]
    pub fn fully_connected(
        x: &[f32],
        w: &[f32],
        b: &[f32],
        out: &mut [f32],
        ind: usize,
        outd: usize,
        act: Activation,
        dtype: Dtype,
    ) -> QParams {
        let mut xq = x.to_vec();
        round_trip(dtype, &mut xq);
        super::fully_connected(&xq, w, b, out, ind, outd, act);
        round_trip(dtype, out)
    }

    /// Quantized standalone ReLU (see [`conv2d`] for the protocol).
    pub fn relu(x: &[f32], out: &mut [f32], max: Option<f32>, dtype: Dtype) -> QParams {
        let mut xq = x.to_vec();
        round_trip(dtype, &mut xq);
        super::relu(&xq, out, max);
        round_trip(dtype, out)
    }

    /// Quantized sigmoid (see [`conv2d`] for the protocol).
    pub fn sigmoid(x: &[f32], out: &mut [f32], dtype: Dtype) -> QParams {
        let mut xq = x.to_vec();
        round_trip(dtype, &mut xq);
        super::sigmoid(&xq, out);
        round_trip(dtype, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom_same(h: usize, w: usize, k: usize, s: usize) -> Geom {
        let oh = crate::graph::conv_out_dim(h, k, s, 1, Padding::Same);
        let ow = crate::graph::conv_out_dim(w, k, s, 1, Padding::Same);
        Geom::new(h, w, oh, ow, (k, k), (s, s), (1, 1), Padding::Same)
    }

    #[test]
    fn conv_identity_kernel() {
        // 1x1 conv with identity weights returns the input.
        let x: Vec<f32> = (0..4 * 4 * 2).map(|i| i as f32).collect();
        let mut w = vec![0.0; 2 * 2];
        w[0] = 1.0; // c0 -> c0
        w[3] = 1.0; // c1 -> c1
        let b = vec![0.0; 2];
        let mut out = vec![0.0; 4 * 4 * 2];
        let g = geom_same(4, 4, 1, 1);
        conv2d(&x, &w, &b, &mut out, 2, 2, &g, Activation::None);
        assert_eq!(out, x);
    }

    #[test]
    fn conv_3x3_sum_kernel() {
        // All-ones 3x3 kernel on all-ones input: interior = 9, corner = 4.
        let x = vec![1.0; 5 * 5];
        let w = vec![1.0; 9];
        let b = vec![0.0; 1];
        let mut out = vec![0.0; 5 * 5];
        let g = geom_same(5, 5, 3, 1);
        conv2d(&x, &w, &b, &mut out, 1, 1, &g, Activation::None);
        assert_eq!(out[2 * 5 + 2], 9.0);
        assert_eq!(out[0], 4.0);
        assert_eq!(out[4], 4.0);
    }

    #[test]
    fn conv_bias_and_relu() {
        let x = vec![1.0; 4];
        let w = vec![-2.0];
        let b = vec![1.0];
        let mut out = vec![0.0; 4];
        let g = geom_same(2, 2, 1, 1);
        conv2d(&x, &w, &b, &mut out, 1, 1, &g, Activation::Relu);
        assert_eq!(out, vec![0.0; 4]); // 1 - 2 = -1 -> relu 0
    }

    #[test]
    fn dwconv_channels_independent() {
        // 2 channels: ch0 kernel = 1 (center), ch1 kernel = 2 (center).
        let x: Vec<f32> = vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0, 4.0, 40.0];
        let mut w = vec![0.0; 9 * 2];
        w[4 * 2] = 1.0;
        w[4 * 2 + 1] = 2.0;
        let b = vec![0.0; 2];
        let mut out = vec![0.0; 8];
        let g = geom_same(2, 2, 3, 1);
        dwconv2d(&x, &w, &b, &mut out, 2, &g, Activation::None);
        assert_eq!(out, vec![1.0, 20.0, 2.0, 40.0, 3.0, 60.0, 4.0, 80.0]);
    }

    #[test]
    fn pools() {
        let x = vec![1.0, 2.0, 3.0, 4.0]; // 2x2x1
        let g = Geom::new(2, 2, 1, 1, (2, 2), (2, 2), (1, 1), Padding::Valid);
        let mut out = vec![0.0];
        maxpool2d(&x, &mut out, 1, &g);
        assert_eq!(out[0], 4.0);
        avgpool2d(&x, &mut out, 1, &g);
        assert_eq!(out[0], 2.5);
    }

    #[test]
    fn gap() {
        let x = vec![1.0, 10.0, 3.0, 30.0]; // 2 pixels, 2 ch
        let mut out = vec![0.0; 2];
        global_avg_pool(&x, &mut out, 2, 2);
        assert_eq!(out, vec![2.0, 20.0]);
    }

    #[test]
    fn elementwise_and_fc() {
        let mut out = vec![0.0; 3];
        add(&[1.0, 2.0, -3.0], &[1.0, 1.0, 1.0], &mut out, Activation::Relu);
        assert_eq!(out, vec![2.0, 3.0, 0.0]);
        mul(&[2.0, 3.0, 4.0], &[5.0, 6.0, 7.0], &mut out);
        assert_eq!(out, vec![10.0, 18.0, 28.0]);

        // FC: x=[1,2], w=[[1,0],[0,1]] (row-major in*out), b=[10,20]
        let mut fco = vec![0.0; 2];
        fully_connected(&[1.0, 2.0], &[1.0, 0.0, 0.0, 1.0], &[10.0, 20.0], &mut fco, 2, 2, Activation::None);
        assert_eq!(fco, vec![11.0, 22.0]);
    }

    #[test]
    fn softmax_normalizes() {
        let mut out = vec![0.0; 3];
        softmax(&[1.0, 1.0, 1.0], &mut out, 3);
        for v in &out {
            assert!((v - 1.0 / 3.0).abs() < 1e-6);
        }
        softmax(&[0.0, 100.0, 0.0], &mut out, 3);
        assert!(out[1] > 0.999);
    }

    #[test]
    fn concat_interleaves() {
        let a = vec![1.0, 2.0, 10.0, 20.0]; // 2 pixels × 2ch
        let b = vec![5.0, 50.0]; // 2 pixels × 1ch
        let mut out = vec![0.0; 6];
        concat_channels(&[(&a, 2), (&b, 1)], &mut out, 2);
        assert_eq!(out, vec![1.0, 2.0, 5.0, 10.0, 20.0, 50.0]);
    }

    #[test]
    fn resize_identity() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let mut out = vec![0.0; 4];
        resize_bilinear(&x, &mut out, 2, 2, 2, 2, 1);
        assert_eq!(out, x);
    }

    #[test]
    fn resize_upsamples_smoothly() {
        let x = vec![0.0, 1.0]; // 1×2
        let mut out = vec![0.0; 4];
        resize_bilinear(&x, &mut out, 1, 2, 1, 4, 1);
        assert!(out[0] <= out[1] && out[1] <= out[2] && out[2] <= out[3]);
    }

    #[test]
    fn pad_places_block() {
        let x = vec![1.0, 2.0, 3.0, 4.0]; // 2x2x1
        let mut out = vec![9.0; 3 * 3];
        pad_spatial(&x, &mut out, 2, 2, 1, (1, 1), (0, 0));
        assert_eq!(out, vec![0.0, 0.0, 0.0, 0.0, 1.0, 2.0, 0.0, 3.0, 4.0]);
    }

    #[test]
    fn unary_ops() {
        let mut out = vec![0.0; 3];
        relu(&[-1.0, 0.5, 9.0], &mut out, Some(6.0));
        assert_eq!(out, vec![0.0, 0.5, 6.0]);
        sigmoid(&[0.0, 100.0, -100.0], &mut out);
        assert!((out[0] - 0.5).abs() < 1e-6 && out[1] > 0.999 && out[2] < 0.001);
    }

    #[test]
    fn matmul_matches_triple_loop_at_awkward_shapes() {
        use crate::rng::SplitMix64;
        let mut rng = SplitMix64::new(0x5eed);
        // Shapes chosen to hit every block path: full MR×NR tiles, row
        // remainders, column remainders, and both at once.
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (4, 8, 8), (5, 3, 9), (7, 16, 17), (12, 5, 24), (3, 7, 6)] {
            let mut a = vec![0f32; m * k];
            let mut w = vec![0f32; k * n];
            let mut bias = vec![0f32; n];
            rng.fill_f32(&mut a, 1.0);
            rng.fill_f32(&mut w, 1.0);
            rng.fill_f32(&mut bias, 1.0);
            let mut got = vec![0f32; m * n];
            matmul_bias(&a, k, &w, &bias, &mut got, n, m, k, n);
            for r in 0..m {
                for c in 0..n {
                    let mut want = bias[c];
                    for kk in 0..k {
                        want += a[r * k + kk] * w[kk * n + c];
                    }
                    assert_eq!(got[r * n + c], want, "({m},{k},{n}) at [{r},{c}]");
                }
            }
        }
    }

    #[test]
    fn pointwise_fast_path_matches_scalar_reference() {
        use crate::rng::SplitMix64;
        let mut rng = SplitMix64::new(42);
        let (h, w_, ic, oc) = (6, 5, 7, 11);
        let mut x = vec![0f32; h * w_ * ic];
        let mut wt = vec![0f32; ic * oc];
        let mut b = vec![0f32; oc];
        rng.fill_f32(&mut x, 1.0);
        rng.fill_f32(&mut wt, 1.0);
        rng.fill_f32(&mut b, 1.0);
        let g = Geom::new(h, w_, h, w_, (1, 1), (1, 1), (1, 1), Padding::Valid);
        assert!(g.is_pointwise());
        let mut fast = vec![0f32; h * w_ * oc];
        let mut reference = vec![0f32; h * w_ * oc];
        conv2d(&x, &wt, &b, &mut fast, ic, oc, &g, Activation::Relu);
        scalar::conv2d(&x, &wt, &b, &mut reference, ic, oc, &g, Activation::Relu);
        for (i, (&a, &r)) in fast.iter().zip(reference.iter()).enumerate() {
            assert!((a - r).abs() <= r.abs() * 1e-6 + 1e-6, "elem {i}: {a} vs {r}");
        }
    }

    #[test]
    fn f16_narrowing_matches_reference_encodings() {
        use quant::{f16_bits_to_f32, f32_to_f16_bits};
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xc000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff); // max finite
        assert_eq!(f32_to_f16_bits(65520.0), 0x7c00); // RNE tie carries to inf
        assert_eq!(f32_to_f16_bits(65536.0), 0x7c00); // overflow -> inf
        assert_eq!(f32_to_f16_bits(2f32.powi(-24)), 0x0001); // least subnormal
        assert_eq!(f32_to_f16_bits(2f32.powi(-25)), 0x0000); // tie-to-even -> 0
        // Round-to-nearest-even at the dropped-mantissa boundary.
        assert_eq!(f32_to_f16_bits(1.0 + 2f32.powi(-11)), 0x3c00);
        assert_eq!(f32_to_f16_bits(1.0 + 3.0 * 2f32.powi(-11)), 0x3c02);
        // Widening is exact, so narrow(widen(bits)) is the identity.
        for bits in [0x0000u16, 0x0001, 0x03ff, 0x0400, 0x3c00, 0x7bff, 0x8001, 0xc000] {
            assert_eq!(f32_to_f16_bits(f16_bits_to_f32(bits)), bits);
        }
        assert!(f16_bits_to_f32(0x7c00).is_infinite());
        assert!(f16_bits_to_f32(0x7e00).is_nan());
    }

    #[test]
    fn i8_packing_roundtrips_codes_exactly() {
        use crate::planner::Dtype;
        use quant::{choose_qparams, dequantize_from, packed_words, quantize_into, QParams};
        let qp = choose_qparams(Dtype::I8, -1.0, 3.0);
        // Zero is a grid point and the 255-step grid spans the range.
        assert_eq!((0.0f32 / qp.scale + qp.zero_point).round(), qp.zero_point);
        assert!((qp.scale - 4.0 / 255.0).abs() < 1e-7);
        let src: Vec<f32> = (0..13).map(|i| -1.0 + i as f32 * 4.0 / 12.0).collect();
        let mut packed = vec![0f32; packed_words(Dtype::I8, src.len())];
        assert_eq!(packed.len(), 4);
        quantize_into(Dtype::I8, qp, &src, &mut packed);
        let mut back = vec![0f32; src.len()];
        dequantize_from(Dtype::I8, qp, &packed, &mut back);
        for (&a, &b) in src.iter().zip(&back) {
            assert!((a - b).abs() <= 0.5 * qp.scale + 1e-6, "{a} vs {b}");
        }
        // Re-quantizing the dequantized values is a bit-exact fixed point.
        let mut again = vec![0f32; packed.len()];
        quantize_into(Dtype::I8, qp, &back, &mut again);
        assert_eq!(
            packed.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            again.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        // f16 packs two halves per word and is value-exact on halves.
        let mut p16 = vec![0f32; packed_words(Dtype::F16, 3)];
        assert_eq!(p16.len(), 2);
        quantize_into(Dtype::F16, QParams::IDENTITY, &[0.5, -2.0, 0.25], &mut p16);
        let mut b16 = vec![0f32; 3];
        dequantize_from(Dtype::F16, QParams::IDENTITY, &p16, &mut b16);
        assert_eq!(b16, vec![0.5, -2.0, 0.25]);
    }
}
