//! Dynamic batching: one worker thread per model gathers queued requests
//! into batches bounded by size, deadline, and — when a byte budget is
//! configured — the planned arena peak.
//!
//! Budget-driven admission (MAFAT-style): at spawn the worker asks the
//! engine for the largest batch whose *planned* footprint fits
//! [`BatchPolicy::mem_budget`] and clamps the batch cap to it, so an edge
//! box never forms a batch it cannot host. A pre-batched request larger
//! than the cap is refused with a typed [`ServeError`] instead of OOMing,
//! and every refusal is counted in [`Metrics`].
//!
//! With [`BatchPolicy::continuous`] the worker runs the vLLM scheduling
//! model instead: it owns an in-flight set of decode *lanes* and, at each
//! §7 wave boundary, retires finished lanes (their tail blocks return to
//! the shared [`BlockPool`]) and admits queued requests into the vacated
//! slots — no request waits for the whole batch to drain. The lane cap is
//! the same budget-resolved number (the continuous engine charges
//! `prefix peak + tail_block_demand × live lanes`, see
//! [`Engine::planned_peak`]), so `live ≤ cap` *is* the budget invariant at
//! every wave boundary. A bounded queue ([`BatchPolicy::queue_depth`])
//! exerts backpressure with a typed [`ServeError::QueueFull`] refusal.
//!
//! With [`BatchPolicy::spill`] set to [`SpillPolicy::Spill`] the refusal
//! boundary becomes elastic: a request whose planned peak exceeds the
//! resident budget but fits `budget + spill-tier capacity` (see
//! [`Engine::spill_capacity_bytes`]) is admitted and served solo, with
//! the arena demand-reloading evicted buffers from the compressed tier.
//! Every such admission is counted in [`Metrics`]. The default
//! ([`SpillPolicy::Refuse`]) preserves the strict-refusal behavior
//! bit-for-bit.
//!
//! [`BlockPool`]: crate::arena::paged::BlockPool

use super::{engine::Engine, AdmissionOutcome, Metrics, Request, Response, ServeError, SpillPolicy};
use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Batching policy: close a batch when it reaches `max_batch` samples or
/// when the oldest queued request has waited `max_wait`. With `mem_budget`
/// set, the effective cap is further clamped to the largest batch whose
/// planned arena peak fits the budget (see [`Engine::max_servable_batch`]).
/// An explicit `max_batch: 0` (or an engine cap of 0) is honored as
/// refuse-all, consistent with a budget below the batch-1 peak.
///
/// With `continuous` set the cap bounds *live decode lanes* instead of
/// batch samples, `max_wait` is unused (admission happens at wave
/// boundaries, not deadlines), and `queue_depth` bounds the backlog.
///
/// # Example
///
/// ```
/// use std::time::Duration;
/// use tensorarena::coordinator::{BatchPolicy, EchoEngine, ModelServer};
///
/// let server = ModelServer::spawn(
///     || Box::new(EchoEngine::new(2, 8)),
///     BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1), ..BatchPolicy::default() },
/// )
/// .expect("spawn");
/// let out = server.submit(vec![1.0, 2.0]).recv().unwrap().unwrap();
/// assert_eq!(out, vec![2.0, 4.0]);
/// server.shutdown();
/// ```
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Most samples a batch may hold (further clamped by the engine's own
    /// cap and, when set, the budget). In continuous mode: most decode
    /// lanes live at once. `0` means refuse every request.
    pub max_batch: usize,
    /// Longest the oldest queued request may wait before a partial batch
    /// is flushed. Unused in continuous mode.
    pub max_wait: Duration,
    /// Byte budget for the engine's planned working memory; `None` means
    /// unbounded. Enforced only for engines that can report planned peaks.
    pub mem_budget: Option<usize>,
    /// Run the continuous (lane-granular) scheduler instead of
    /// batch-and-drain. Requires an engine with
    /// [`Engine::supports_lanes`]`() == true`; [`ModelServer::spawn`]
    /// refuses the policy otherwise.
    pub continuous: bool,
    /// Most requests the continuous scheduler will hold queued beyond the
    /// live lanes before refusing with [`ServeError::QueueFull`]. Unused
    /// by the drain worker (its queue is drained into batches instead).
    pub queue_depth: usize,
    /// What to do with a request whose planned peak exceeds `mem_budget`
    /// but fits `budget + spill-tier capacity`: [`SpillPolicy::Refuse`]
    /// (default) refuses it exactly as before; [`SpillPolicy::Spill`]
    /// admits and serves it by demand-reloading evicted arena buffers.
    pub spill: SpillPolicy,
    /// Cap on the shared [`BlockPool`](crate::arena::paged::BlockPool)
    /// freelist the engine shelves decode-tail blocks on. Defaults to
    /// [`DEFAULT_BLOCK_SHELF_CAP`](crate::arena::paged::DEFAULT_BLOCK_SHELF_CAP);
    /// ignored by engines without a block pool.
    pub block_shelf_cap: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            mem_budget: None,
            continuous: false,
            queue_depth: 64,
            spill: SpillPolicy::Refuse,
            block_shelf_cap: crate::arena::paged::DEFAULT_BLOCK_SHELF_CAP,
        }
    }
}

/// A running model server: queue + worker thread + metrics.
pub struct ModelServer {
    tx: Option<Sender<Request>>,
    worker: Option<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    in_elems: usize,
}

impl ModelServer {
    /// Spawn a worker under `policy`. `factory` runs *on the worker thread*
    /// and builds the engine there — this is what lets `!Send` engines
    /// (PJRT executables hold `Rc`s) live behind a threaded server.
    ///
    /// Construction is fallible: a panicking factory, or a `continuous`
    /// policy over an engine without lane support, surfaces as
    /// [`ServeError::Spawn`] instead of poisoning the caller. By the time
    /// `spawn` returns `Ok`, the budget admission envelope is resolved and
    /// (in continuous mode) the lanes are prepared.
    pub fn spawn<F>(factory: F, policy: BatchPolicy) -> Result<Self, ServeError>
    where
        F: FnOnce() -> Box<dyn Engine> + Send + 'static,
    {
        let (tx, rx) = channel::<Request>();
        let metrics = Arc::new(Metrics::default());
        let m = Arc::clone(&metrics);
        let (meta_tx, meta_rx) = channel::<Result<usize, ServeError>>();
        let worker = std::thread::Builder::new()
            .name("model-server".into())
            .spawn(move || {
                // A factory panic must fail `spawn`, not unwind the worker
                // and leave the caller to `.expect()` a dead channel.
                let mut engine =
                    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(factory)) {
                        Ok(e) => e,
                        Err(p) => {
                            let msg = p
                                .downcast_ref::<&str>()
                                .map(|s| (*s).to_string())
                                .or_else(|| p.downcast_ref::<String>().cloned())
                                .unwrap_or_else(|| "opaque panic payload".into());
                            let _ = meta_tx
                                .send(Err(ServeError::Spawn(format!("engine factory panicked: {msg}"))));
                            return;
                        }
                    };
                // Resolve the admission cap once: policy bound, engine
                // bound, then the budget bound (the largest batch whose
                // planned peak fits). Cap 0 — an explicit `max_batch: 0`,
                // an engine cap of 0, or a budget below the batch-1 peak —
                // means every request is refused, none is OOMed and none is
                // silently served at batch 1.
                engine.set_block_shelf_cap(policy.block_shelf_cap);
                let mut cap = policy.max_batch.min(engine.max_batch());
                if let Some(budget) = policy.mem_budget {
                    if let Some(fit) = engine.max_servable_batch(budget) {
                        cap = cap.min(fit);
                    }
                }
                // Under the spill policy the admission envelope is elastic:
                // sizes past the resident cap stay admissible while their
                // planned peak fits `budget + spill-tier capacity` (served
                // by demand-reloading evicted buffers). Walk the extension
                // so the envelope covers it; under Refuse (the default)
                // `spill_cap == cap` and nothing changes.
                let mut spill_cap = cap;
                if policy.spill == SpillPolicy::Spill && policy.mem_budget.is_some() {
                    let hard = policy.max_batch.min(engine.max_batch());
                    while spill_cap < hard
                        && engine.admission(spill_cap + 1, policy.mem_budget, SpillPolicy::Spill)
                            != AdmissionOutcome::Refuse
                    {
                        spill_cap += 1;
                    }
                }
                if policy.mem_budget.is_some() {
                    // Pre-resolve the whole admission envelope: plan every
                    // admissible batch size — plus spill_cap+1, the only
                    // size the refusal path ever probes — now (each lands
                    // in the shared plan cache, and so in any plan
                    // directory persisted later), so the budgeted hot path
                    // never invokes the planner — and a warm-started
                    // restart never re-plans.
                    for b in 1..=spill_cap.saturating_add(1) {
                        let _ = engine.planned_peak(b);
                    }
                }
                if policy.continuous {
                    if !engine.supports_lanes() {
                        let _ = meta_tx.send(Err(ServeError::Spawn(
                            "engine does not support continuous lane serving \
                             (paged decode mode required)"
                                .into(),
                        )));
                        return;
                    }
                    // The lane cap is the elastic bound: under Refuse it
                    // equals `cap`; under Spill the extra lanes are hosted
                    // by demand-reloading from the compressed tier.
                    if spill_cap > 0 {
                        if let Err(e) = engine.lane_prepare(spill_cap) {
                            let _ = meta_tx.send(Err(ServeError::Spawn(format!(
                                "preparing {spill_cap} decode lane(s) failed: {e}"
                            ))));
                            return;
                        }
                    }
                    let _ = meta_tx.send(Ok(engine.in_elems()));
                    worker_continuous(
                        &mut *engine,
                        &rx,
                        spill_cap,
                        policy.mem_budget,
                        policy.queue_depth,
                        &m,
                    )
                } else {
                    let _ = meta_tx.send(Ok(engine.in_elems()));
                    worker_loop(&mut *engine, &rx, cap, spill_cap, policy, &m)
                }
            })
            .expect("spawn model server");
        match meta_rx.recv() {
            Ok(Ok(in_elems)) => Ok(ModelServer {
                tx: Some(tx),
                worker: Some(worker),
                metrics,
                in_elems,
            }),
            Ok(Err(e)) => {
                let _ = worker.join();
                Err(e)
            }
            Err(_) => {
                // The worker died without reporting — e.g. a panic payload
                // that itself panicked on drop. Still a typed failure.
                let _ = worker.join();
                Err(ServeError::Spawn("engine worker exited before reporting readiness".into()))
            }
        }
    }

    /// Submit one request; the reply arrives on the returned channel.
    ///
    /// `input` is one sample, or a client-side pre-batched burst of `k`
    /// concatenated samples. A burst is admitted or refused whole: if its
    /// planned peak does not fit the server's budget (or it exceeds the
    /// batch cap) the reply is a typed [`ServeError`], never a panic.
    pub fn submit(&self, input: Vec<f32>) -> Receiver<Response> {
        let (rtx, rrx) = channel();
        if self.in_elems == 0 || input.is_empty() || input.len() % self.in_elems != 0 {
            let _ = rtx.send(Err(ServeError::BadInput {
                got: input.len(),
                expect: self.in_elems,
            }));
            return rrx;
        }
        let req = Request {
            input,
            enqueued: Instant::now(),
            resp: rtx,
        };
        if let Some(tx) = &self.tx {
            // A send error means the worker died; the caller sees a closed
            // response channel.
            let _ = tx.send(req);
        }
        rrx
    }

    /// Metrics handle.
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Stop accepting requests, drain the queue, join the worker.
    pub fn shutdown(mut self) {
        self.tx.take(); // close the queue
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for ModelServer {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Refuse one request that cannot fit any admissible batch, with the error
/// that names the binding constraint.
fn refuse(
    engine: &dyn Engine,
    metrics: &Metrics,
    req: Request,
    samples: usize,
    cap: usize,
    budget: Option<usize>,
) {
    // Probe the *smallest* refused size, never the client-chosen one: the
    // planner must not run (and cache, and later persist, a plan) for an
    // arbitrary attacker-sized batch as a side effect of refusing it. The
    // probe peak is a lower bound on what `samples` would need, and it
    // exceeds the budget exactly when the budget is the binding constraint.
    let err = match budget {
        Some(b) => {
            let probe = samples.min(cap.saturating_add(1));
            match engine.planned_peak(probe) {
                Some(peak) if peak > b => ServeError::BudgetExceeded {
                    batch: samples,
                    planned_bytes: peak,
                    budget_bytes: b,
                },
                _ => ServeError::BatchTooLarge { batch: samples, cap },
            }
        }
        None => ServeError::BatchTooLarge { batch: samples, cap },
    };
    metrics.record_rejected(1);
    let _ = req.resp.send(Err(err));
}

/// The batching loop. `cap` is the resolved resident sample cap (0 =
/// nothing fits the budget); `spill_cap >= cap` is the elastic bound under
/// [`SpillPolicy::Spill`] (equal to `cap` under Refuse). A request in
/// `(cap, spill_cap]` is served solo — it never joins a formed batch —
/// and counted as a spill admission. The budget is re-checked per formed
/// batch as defense in depth.
fn worker_loop(
    engine: &mut dyn Engine,
    rx: &Receiver<Request>,
    cap: usize,
    spill_cap: usize,
    policy: BatchPolicy,
    metrics: &Metrics,
) {
    let budget = policy.mem_budget;
    let max_wait = policy.max_wait;
    let in_elems = engine.in_elems();
    let out_elems = engine.out_elems();
    let mut batch_buf: Vec<f32> = Vec::with_capacity(cap.max(1) * in_elems);
    // A request drained from the queue that no longer fits the batch being
    // formed; it opens the next batch instead of being dropped or split.
    let mut carry: Option<Request> = None;
    loop {
        let first = match carry.take() {
            Some(r) => r,
            None => match rx.recv() {
                Ok(r) => r,
                Err(_) => return, // queue closed and drained
            },
        };
        // Admission: refuse a burst that can never fit — even the elastic
        // spill bound — before it occupies the batch. A burst in
        // `(cap, spill_cap]` passes through and runs solo: the gathering
        // loops below are guarded by `samples < cap`, so nothing joins it.
        let first_samples = first.input.len() / in_elems;
        if first_samples > spill_cap {
            refuse(&*engine, metrics, first, first_samples, spill_cap, budget);
            continue;
        }
        let deadline = first.enqueued + max_wait;
        let mut samples = first_samples;
        let mut batch = vec![first];
        // Admit `r` into the forming batch, stash it for the next batch,
        // or refuse it outright — shared by the drain and deadline loops.
        let gather = |r: Request,
                          samples: &mut usize,
                          batch: &mut Vec<Request>,
                          carry: &mut Option<Request>,
                          engine: &dyn Engine| {
            let s = r.input.len() / in_elems;
            if s > spill_cap {
                refuse(engine, metrics, r, s, spill_cap, budget);
            } else if *samples + s > cap {
                // Includes spill-sized requests (`cap < s <= spill_cap`):
                // carried, they open the next round as `first` and run solo.
                *carry = Some(r);
            } else {
                *samples += s;
                batch.push(r);
            }
        };
        // Drain whatever is already queued, for free — even when the
        // deadline has long passed (under backlog the queue is full and the
        // batch should be too). §Perf: before this drain, a 64-request
        // closed-loop burst ran at mean batch 1.12; after, it saturates.
        while samples < cap && carry.is_none() {
            match rx.try_recv() {
                Ok(r) => gather(r, &mut samples, &mut batch, &mut carry, &*engine),
                Err(_) => break,
            }
        }
        // Then wait out the remaining deadline for stragglers.
        while samples < cap && carry.is_none() {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => gather(r, &mut samples, &mut batch, &mut carry, &*engine),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }

        // Defense in depth: the cap already encodes the budget, but a
        // planner-managed engine gets the final say before any memory is
        // committed. Under [`SpillPolicy::Spill`] the same typed decision
        // admits over-budget batches that fit the elastic bound — and
        // that Spill outcome is the spill-admission event the metrics
        // count. (Skipped entirely when no budget is set, so the planner
        // is never consulted on the unbudgeted hot path.)
        if let Some(b) = budget {
            match engine.admission(samples, budget, policy.spill) {
                AdmissionOutcome::Admit => {}
                AdmissionOutcome::Spill => metrics.record_spill_admission(),
                AdmissionOutcome::Refuse => {
                    let peak = engine.planned_peak(samples).unwrap_or(0);
                    metrics.record_rejected(batch.len());
                    for r in &batch {
                        let _ = r.resp.send(Err(ServeError::BudgetExceeded {
                            batch: samples,
                            planned_bytes: peak,
                            budget_bytes: b,
                        }));
                    }
                    continue;
                }
            }
        }

        // Assemble and run.
        batch_buf.clear();
        for r in &batch {
            batch_buf.extend_from_slice(&r.input);
        }
        let exec_start = Instant::now();
        let result = engine.run_batch(&batch_buf, samples);
        let done = Instant::now();

        // Only a successful batch feeds the latency / batch-size metrics: a
        // failed batch completed nothing, and counting it would both inflate
        // `completed` and skew the distributions with garbage timings.
        match result {
            Ok(out) => {
                let waits: Vec<Duration> =
                    batch.iter().map(|r| exec_start - r.enqueued).collect();
                let lats: Vec<Duration> = batch.iter().map(|r| done - r.enqueued).collect();
                metrics.record_batch(samples, &waits, &lats);
                let mut off = 0;
                for r in &batch {
                    let k = r.input.len() / in_elems;
                    let _ = r
                        .resp
                        .send(Ok(out[off * out_elems..(off + k) * out_elems].to_vec()));
                    off += k;
                }
            }
            Err(e) => {
                metrics.record_engine_error();
                for r in &batch {
                    let _ = r.resp.send(Err(ServeError::Engine(e.to_string())));
                }
            }
        }
    }
}

/// The continuous-batching loop (vLLM scheduling model): `cap` decode
/// lanes run in-flight; each iteration advances every live lane by one §7
/// wave, retires the lanes that finished (tail blocks return to the shared
/// pool), and admits queued requests into the vacated slots — a request
/// never waits for the whole batch to drain.
///
/// Budget correctness is structural, not re-checked per wave: `cap` was
/// resolved against [`Engine::planned_peak`], which for a continuous
/// engine charges `prefix peak + tail_block_demand × lanes`, so holding
/// `live ≤ cap` keeps every wave boundary inside the budget.
fn worker_continuous(
    engine: &mut dyn Engine,
    rx: &Receiver<Request>,
    cap: usize,
    budget: Option<usize>,
    queue_depth: usize,
    metrics: &Metrics,
) {
    let in_elems = engine.in_elems();
    // Cap 0 (explicit refuse-all policy, engine cap 0, or a budget below
    // the one-lane peak): refuse everything, typed, forever.
    if cap == 0 {
        while let Ok(r) = rx.recv() {
            let s = r.input.len() / in_elems;
            refuse(&*engine, metrics, r, s, 0, budget);
        }
        return;
    }
    struct Lane {
        req: Request,
        admitted: Instant,
    }
    let mut lanes: Vec<Option<Lane>> = Vec::new();
    lanes.resize_with(cap, || None);
    let mut queue: VecDeque<Request> = VecDeque::new();
    let mut live = 0usize;
    let mut open = true;
    loop {
        // Idle: nothing in flight, nothing queued — block until work
        // arrives or the queue closes.
        if open && live == 0 && queue.is_empty() {
            match rx.recv() {
                Ok(r) => queue.push_back(r),
                Err(_) => open = false,
            }
        }
        // Drain new arrivals without blocking the decode loop. The queue
        // is bounded: beyond `queue_depth` the refusal is immediate and
        // typed, instead of the backlog growing without limit.
        while open {
            match rx.try_recv() {
                Ok(r) => {
                    if queue.len() >= queue_depth {
                        metrics.record_rejected(1);
                        let _ = r.resp.send(Err(ServeError::QueueFull { depth: queue_depth }));
                    } else {
                        queue.push_back(r);
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => open = false,
            }
        }
        if !open && live == 0 && queue.is_empty() {
            return; // queue closed and fully drained
        }
        // Wave-boundary admission: fill vacated lanes from the queue.
        while live < cap {
            let Some(r) = queue.pop_front() else { break };
            let samples = r.input.len() / in_elems;
            if samples != 1 {
                // A lane holds exactly one sample; a pre-batched burst
                // cannot join a decode loop mid-flight. Clients that want
                // bursts use the drain worker.
                metrics.record_rejected(1);
                let _ = r.resp.send(Err(ServeError::BatchTooLarge { batch: samples, cap: 1 }));
                continue;
            }
            let lane = lanes
                .iter()
                .position(Option::is_none)
                .expect("live < cap implies a vacant lane");
            match engine.lane_begin(lane, &r.input) {
                Ok(()) => {
                    if live > 0 {
                        // The observable continuous-batching event: this
                        // request joined while other lanes were mid-decode.
                        metrics.record_continuous_admission();
                    }
                    lanes[lane] = Some(Lane { req: r, admitted: Instant::now() });
                    live += 1;
                }
                Err(e) => {
                    metrics.record_engine_error();
                    let _ = r.resp.send(Err(ServeError::Engine(e.to_string())));
                }
            }
        }
        // Advance every live lane one wave; retire the finished ones. The
        // retired lanes' tail blocks are already back in the pool (the
        // executor unmaps a tail record when its last consumer runs), so
        // the vacated slots are admissible on the next iteration.
        for li in 0..lanes.len() {
            if lanes[li].is_none() {
                continue;
            }
            let done = match engine.lane_advance(li) {
                Ok(done) => done,
                Err(e) => {
                    let lane = lanes[li].take().expect("checked live");
                    live -= 1;
                    metrics.record_engine_error();
                    let _ = lane.req.resp.send(Err(ServeError::Engine(e.to_string())));
                    engine.lane_abort(li);
                    continue;
                }
            };
            if !done {
                continue;
            }
            let lane = lanes[li].take().expect("checked live");
            live -= 1;
            match engine.lane_finish(li) {
                Ok(out) => {
                    let now = Instant::now();
                    // Per retired lane: "batch" is the in-flight lane count
                    // at retirement, so mean_batch reads as average decode
                    // concurrency.
                    metrics.record_batch(
                        live + 1,
                        &[lane.admitted - lane.req.enqueued],
                        &[now - lane.req.enqueued],
                    );
                    let _ = lane.req.resp.send(Ok(out));
                }
                Err(e) => {
                    metrics.record_engine_error();
                    let _ = lane.req.resp.send(Err(ServeError::Engine(e.to_string())));
                    engine.lane_abort(li);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EchoEngine;

    #[test]
    fn batches_requests_and_answers_each() {
        let server = ModelServer::spawn(
            || Box::new(EchoEngine::new(2, 8)),
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(20),
                ..BatchPolicy::default()
            },
        )
        .expect("spawn");
        let rxs: Vec<_> = (0..6)
            .map(|i| server.submit(vec![i as f32, i as f32 + 0.5]))
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let out = rx.recv().unwrap().unwrap();
            assert_eq!(out, vec![i as f32 * 2.0, (i as f32 + 0.5) * 2.0]);
        }
        let snap = server.metrics().snapshot();
        assert_eq!(snap.completed, 6);
        assert!(snap.mean_batch >= 1.0);
        server.shutdown();
    }

    #[test]
    fn rejects_wrong_arity_without_touching_engine() {
        let server = ModelServer::spawn(|| Box::new(EchoEngine::new(3, 8)), BatchPolicy::default())
            .expect("spawn");
        let rx = server.submit(vec![1.0]); // not a multiple of 3
        let resp = rx.recv().unwrap();
        assert!(matches!(resp, Err(ServeError::BadInput { got: 1, expect: 3 })));
        server.shutdown();
    }

    #[test]
    fn deadline_flushes_partial_batches() {
        let server = ModelServer::spawn(
            || Box::new(EchoEngine::new(1, 64)),
            BatchPolicy {
                max_batch: 64,
                max_wait: Duration::from_millis(5),
                ..BatchPolicy::default()
            },
        )
        .expect("spawn");
        let rx = server.submit(vec![7.0]);
        // only one request: the deadline, not the size cap, must flush it
        let out = rx.recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        assert_eq!(out, vec![14.0]);
        server.shutdown();
    }

    #[test]
    fn shutdown_drains_gracefully() {
        let server = ModelServer::spawn(|| Box::new(EchoEngine::new(1, 4)), BatchPolicy::default())
            .expect("spawn");
        let rx = server.submit(vec![1.0]);
        server.shutdown();
        assert_eq!(rx.recv().unwrap().unwrap(), vec![2.0]);
    }

    #[test]
    fn pre_batched_request_is_answered_whole() {
        let server = ModelServer::spawn(
            || Box::new(EchoEngine::new(2, 8)),
            BatchPolicy { max_batch: 8, ..BatchPolicy::default() },
        )
        .expect("spawn");
        // 3 samples of 2 elements in one request.
        let rx = server.submit(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let out = rx.recv().unwrap().unwrap();
        assert_eq!(out, vec![2.0, 4.0, 6.0, 8.0, 10.0, 12.0]);
        let snap = server.metrics().snapshot();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.max_batch_seen, 3);
        server.shutdown();
    }

    #[test]
    fn budget_clamps_batches_and_refuses_oversized_bursts() {
        // Budget fits 3 samples (peak 100 B/sample, budget 350 B) against a
        // policy cap of 8: the server must clamp every executed batch to
        // <= 3 and refuse a pre-batched burst of 8 with BudgetExceeded.
        let server = ModelServer::spawn(
            || Box::new(EchoEngine::new(1, 64).with_peak_per_sample(100)),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(5),
                mem_budget: Some(350),
                ..BatchPolicy::default()
            },
        )
        .expect("spawn");
        let rxs: Vec<_> = (0..64).map(|i| server.submit(vec![i as f32])).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().unwrap(), vec![i as f32 * 2.0]);
        }
        let oversized = server.submit(vec![0.5f32; 8]);
        match oversized.recv().unwrap() {
            Err(ServeError::BudgetExceeded { batch, planned_bytes, budget_bytes }) => {
                assert_eq!(batch, 8);
                // The refusal probes the smallest over-budget size (cap+1 =
                // 4 samples), never the client-chosen 8.
                assert_eq!(planned_bytes, 400);
                assert_eq!(budget_bytes, 350);
            }
            other => panic!("expected BudgetExceeded, got {other:?}"),
        }
        let snap = server.metrics().snapshot();
        assert_eq!(snap.completed, 64, "the whole burst must be served");
        assert!(
            snap.max_batch_seen <= 3,
            "batch {} formed over the budget cap",
            snap.max_batch_seen
        );
        assert_eq!(snap.rejected, 1, "the oversized burst must be counted");
        server.shutdown();
    }

    #[test]
    fn budget_below_batch_one_refuses_everything() {
        let server = ModelServer::spawn(
            || Box::new(EchoEngine::new(1, 8).with_peak_per_sample(1000)),
            BatchPolicy { mem_budget: Some(999), ..BatchPolicy::default() },
        )
        .expect("spawn");
        for i in 0..4 {
            let resp = server.submit(vec![i as f32]).recv().unwrap();
            assert!(
                matches!(resp, Err(ServeError::BudgetExceeded { .. })),
                "request {i} was not refused: {resp:?}"
            );
        }
        let snap = server.metrics().snapshot();
        assert_eq!(snap.completed, 0);
        assert_eq!(snap.rejected, 4);
        server.shutdown();
    }

    #[test]
    fn oversized_burst_without_budget_is_batch_too_large() {
        let server = ModelServer::spawn(
            || Box::new(EchoEngine::new(1, 4)),
            BatchPolicy { max_batch: 4, ..BatchPolicy::default() },
        )
        .expect("spawn");
        let resp = server.submit(vec![0.0f32; 5]).recv().unwrap();
        assert!(matches!(resp, Err(ServeError::BatchTooLarge { batch: 5, cap: 4 })));
        assert_eq!(server.metrics().snapshot().rejected, 1);
        server.shutdown();
    }

    #[test]
    fn failing_engine_counts_errors_and_skips_the_batch_metrics() {
        struct FailEngine;
        impl Engine for FailEngine {
            fn in_elems(&self) -> usize {
                1
            }
            fn out_elems(&self) -> usize {
                1
            }
            fn max_batch(&self) -> usize {
                4
            }
            fn run_batch(&mut self, _input: &[f32], _n: usize) -> anyhow::Result<Vec<f32>> {
                anyhow::bail!("injected failure")
            }
        }
        let server =
            ModelServer::spawn(|| Box::new(FailEngine), BatchPolicy::default()).expect("spawn");
        for _ in 0..2 {
            match server.submit(vec![1.0]).recv().unwrap() {
                Err(ServeError::Engine(e)) => assert!(e.contains("injected failure"), "{e}"),
                other => panic!("expected an engine error, got {other:?}"),
            }
        }
        let snap = server.metrics().snapshot();
        assert_eq!(snap.engine_errors, 2);
        assert_eq!(snap.completed, 0, "failed batches must not count as completed");
        assert_eq!(snap.max_batch_seen, 0, "failed batches must not feed the distributions");
        assert_eq!(snap.p99_us, 0, "failed batches must not feed the latency percentiles");
        server.shutdown();
    }

    #[test]
    fn budget_is_ignored_for_engines_that_cannot_report_peaks() {
        // EchoEngine without peaks: the budget cannot bind, requests serve.
        let server = ModelServer::spawn(
            || Box::new(EchoEngine::new(1, 8)),
            BatchPolicy { mem_budget: Some(1), ..BatchPolicy::default() },
        )
        .expect("spawn");
        assert_eq!(server.submit(vec![4.0]).recv().unwrap().unwrap(), vec![8.0]);
        server.shutdown();
    }

    #[test]
    fn panicking_factory_fails_spawn_with_a_typed_error() {
        // Regression: a panicking factory used to take the caller down via
        // `meta_rx.recv().expect(...)`. It must surface as ServeError::Spawn.
        let r = ModelServer::spawn(
            || -> Box<dyn Engine> { panic!("flaky model load") },
            BatchPolicy::default(),
        );
        match r {
            Err(ServeError::Spawn(msg)) => {
                assert!(msg.contains("factory panicked"), "{msg}");
                assert!(msg.contains("flaky model load"), "{msg}");
            }
            other => panic!("expected Spawn error, got {:?}", other.map(|_| "a live server")),
        }
    }

    #[test]
    fn explicit_zero_cap_refuses_instead_of_serving() {
        // Regression: `max_batch: 0` used to be clamped to 1 and served
        // anyway. It must be honored as refuse-all, consistent with the
        // budget-below-batch-1 semantics.
        let server = ModelServer::spawn(
            || Box::new(EchoEngine::new(1, 4)),
            BatchPolicy { max_batch: 0, ..BatchPolicy::default() },
        )
        .expect("spawn");
        for i in 0..3 {
            let resp = server.submit(vec![i as f32]).recv().unwrap();
            assert!(
                matches!(resp, Err(ServeError::BatchTooLarge { batch: 1, cap: 0 })),
                "request {i} was served under an explicit zero cap: {resp:?}"
            );
        }
        let snap = server.metrics().snapshot();
        assert_eq!(snap.completed, 0);
        assert_eq!(snap.rejected, 3);
        server.shutdown();
    }

    #[test]
    fn spill_policy_admits_past_the_resident_budget() {
        // Budget fits 1 sample (100 B/sample, budget 150 B); the spill
        // tier adds 250 B, so the elastic bound is 400 B = 4 samples. A
        // 3-sample burst must be served solo as a spill admission; a
        // 5-sample burst exceeds even the elastic bound and is refused.
        let server = ModelServer::spawn(
            || {
                Box::new(
                    EchoEngine::new(1, 64).with_peak_per_sample(100).with_spill_capacity(250),
                )
            },
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(5),
                mem_budget: Some(150),
                spill: SpillPolicy::Spill,
                ..BatchPolicy::default()
            },
        )
        .expect("spawn");
        let out = server.submit(vec![1.0, 2.0, 3.0]).recv().unwrap().unwrap();
        assert_eq!(out, vec![2.0, 4.0, 6.0], "spill-admitted burst must serve bit-exactly");
        match server.submit(vec![0.5f32; 5]).recv().unwrap() {
            Err(ServeError::BudgetExceeded { batch, planned_bytes, budget_bytes }) => {
                assert_eq!(batch, 5);
                assert_eq!(planned_bytes, 500);
                assert_eq!(budget_bytes, 150);
            }
            other => panic!("expected BudgetExceeded past the elastic bound, got {other:?}"),
        }
        let snap = server.metrics().snapshot();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.spill_admissions, 1, "the over-budget serve must be counted");
        assert_eq!(snap.rejected, 1);
        server.shutdown();
    }

    #[test]
    fn refuse_policy_ignores_the_spill_tier() {
        // Same engine and budget, default policy: the spill capacity must
        // not widen admission — a 3-sample burst is refused exactly as if
        // no tier existed.
        let server = ModelServer::spawn(
            || {
                Box::new(
                    EchoEngine::new(1, 64).with_peak_per_sample(100).with_spill_capacity(250),
                )
            },
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(5),
                mem_budget: Some(150),
                ..BatchPolicy::default()
            },
        )
        .expect("spawn");
        let resp = server.submit(vec![1.0, 2.0, 3.0]).recv().unwrap();
        assert!(
            matches!(resp, Err(ServeError::BudgetExceeded { batch: 3, .. })),
            "refuse policy must keep refusing: {resp:?}"
        );
        let snap = server.metrics().snapshot();
        assert_eq!(snap.spill_admissions, 0);
        assert_eq!(snap.rejected, 1);
        server.shutdown();
    }

    #[test]
    fn policy_defaults_preserve_existing_behavior() {
        let p = BatchPolicy::default();
        assert_eq!(p.spill, SpillPolicy::Refuse);
        assert_eq!(p.block_shelf_cap, crate::arena::paged::DEFAULT_BLOCK_SHELF_CAP);
    }

    #[test]
    fn continuous_policy_requires_a_lane_capable_engine() {
        // EchoEngine cannot decode lane-granularly; the policy must be
        // refused at spawn, not discovered as a panic mid-serve.
        let r = ModelServer::spawn(
            || Box::new(EchoEngine::new(1, 4)),
            BatchPolicy { continuous: true, ..BatchPolicy::default() },
        );
        match r {
            Err(ServeError::Spawn(msg)) => {
                assert!(msg.contains("continuous lane serving"), "{msg}")
            }
            other => panic!("expected Spawn error, got {:?}", other.map(|_| "a live server")),
        }
    }
}
