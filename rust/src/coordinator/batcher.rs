//! Dynamic batching: one worker thread per model gathers queued requests
//! into batches bounded by size and deadline.

use super::{engine::Engine, Metrics, Request, Response};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Batching policy: close a batch when it reaches `max_batch` requests or
/// when the oldest queued request has waited `max_wait`.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// A running model server: queue + worker thread + metrics.
pub struct ModelServer {
    tx: Option<Sender<Request>>,
    worker: Option<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    in_elems: usize,
}

impl ModelServer {
    /// Spawn a worker under `policy`. `factory` runs *on the worker thread*
    /// and builds the engine there — this is what lets `!Send` engines
    /// (PJRT executables hold `Rc`s) live behind a threaded server.
    pub fn spawn<F>(factory: F, policy: BatchPolicy) -> Self
    where
        F: FnOnce() -> Box<dyn Engine> + Send + 'static,
    {
        let (tx, rx) = channel::<Request>();
        let metrics = Arc::new(Metrics::default());
        let m = Arc::clone(&metrics);
        let (meta_tx, meta_rx) = channel::<usize>();
        let worker = std::thread::Builder::new()
            .name("model-server".into())
            .spawn(move || {
                let mut engine = factory();
                let _ = meta_tx.send(engine.in_elems());
                let cap = policy.max_batch.min(engine.max_batch()).max(1);
                worker_loop(&mut *engine, &rx, cap, policy.max_wait, &m)
            })
            .expect("spawn model server");
        let in_elems = meta_rx.recv().expect("engine factory panicked");
        ModelServer {
            tx: Some(tx),
            worker: Some(worker),
            metrics,
            in_elems,
        }
    }

    /// Submit one request; the reply arrives on the returned channel.
    pub fn submit(&self, input: Vec<f32>) -> Receiver<Response> {
        let (rtx, rrx) = channel();
        if input.len() != self.in_elems {
            let _ = rtx.send(Err(format!(
                "input has {} elems, model wants {}",
                input.len(),
                self.in_elems
            )));
            return rrx;
        }
        let req = Request {
            input,
            enqueued: Instant::now(),
            resp: rtx,
        };
        if let Some(tx) = &self.tx {
            // A send error means the worker died; the caller sees a closed
            // response channel.
            let _ = tx.send(req);
        }
        rrx
    }

    /// Metrics handle.
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Stop accepting requests, drain the queue, join the worker.
    pub fn shutdown(mut self) {
        self.tx.take(); // close the queue
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for ModelServer {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// The batching loop.
fn worker_loop(
    engine: &mut dyn Engine,
    rx: &Receiver<Request>,
    max_batch: usize,
    max_wait: Duration,
    metrics: &Metrics,
) {
    let in_elems = engine.in_elems();
    let out_elems = engine.out_elems();
    let mut batch_buf: Vec<f32> = Vec::with_capacity(max_batch * in_elems);
    loop {
        // Block for the first request of the next batch.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // queue closed and drained
        };
        let deadline = first.enqueued + max_wait;
        let mut batch = vec![first];
        // Drain whatever is already queued, for free — even when the
        // deadline has long passed (under backlog the queue is full and the
        // batch should be too). §Perf: before this drain, a 64-request
        // closed-loop burst ran at mean batch 1.12; after, it saturates.
        while batch.len() < max_batch {
            match rx.try_recv() {
                Ok(r) => batch.push(r),
                Err(_) => break,
            }
        }
        // Then wait out the remaining deadline for stragglers.
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }

        // Assemble and run.
        batch_buf.clear();
        for r in &batch {
            batch_buf.extend_from_slice(&r.input);
        }
        let exec_start = Instant::now();
        let result = engine.run_batch(&batch_buf, batch.len());
        let done = Instant::now();

        let waits: Vec<Duration> = batch.iter().map(|r| exec_start - r.enqueued).collect();
        let lats: Vec<Duration> = batch.iter().map(|r| done - r.enqueued).collect();
        metrics.record_batch(batch.len(), &waits, &lats);

        match result {
            Ok(out) => {
                for (i, r) in batch.iter().enumerate() {
                    let _ = r
                        .resp
                        .send(Ok(out[i * out_elems..(i + 1) * out_elems].to_vec()));
                }
            }
            Err(e) => {
                for r in &batch {
                    let _ = r.resp.send(Err(e.to_string()));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EchoEngine;

    #[test]
    fn batches_requests_and_answers_each() {
        let server = ModelServer::spawn(
            || Box::new(EchoEngine::new(2, 8)),
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(20) },
        );
        let rxs: Vec<_> = (0..6)
            .map(|i| server.submit(vec![i as f32, i as f32 + 0.5]))
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let out = rx.recv().unwrap().unwrap();
            assert_eq!(out, vec![i as f32 * 2.0, (i as f32 + 0.5) * 2.0]);
        }
        let snap = server.metrics().snapshot();
        assert_eq!(snap.completed, 6);
        assert!(snap.mean_batch >= 1.0);
        server.shutdown();
    }

    #[test]
    fn rejects_wrong_arity_without_touching_engine() {
        let server = ModelServer::spawn(|| Box::new(EchoEngine::new(3, 8)), BatchPolicy::default());
        let rx = server.submit(vec![1.0]); // wrong size
        let resp = rx.recv().unwrap();
        assert!(resp.is_err());
        server.shutdown();
    }

    #[test]
    fn deadline_flushes_partial_batches() {
        let server = ModelServer::spawn(
            || Box::new(EchoEngine::new(1, 64)),
            BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(5) },
        );
        let rx = server.submit(vec![7.0]);
        // only one request: the deadline, not the size cap, must flush it
        let out = rx.recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        assert_eq!(out, vec![14.0]);
        server.shutdown();
    }

    #[test]
    fn shutdown_drains_gracefully() {
        let server = ModelServer::spawn(|| Box::new(EchoEngine::new(1, 4)), BatchPolicy::default());
        let rx = server.submit(vec![1.0]);
        server.shutdown();
        assert_eq!(rx.recv().unwrap().unwrap(), vec![2.0]);
    }
}
