//! Serving metrics: latency percentiles, batch-size distribution,
//! throughput, and the planner's memory accounting line.

use super::ArenaStats;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// One-line serving-visible rendering of a model's [`ArenaStats`]: arena
/// footprint vs naive, plan-cache hit rate, arena-pool reuse. The `serve`
/// CLI and `benches/serving.rs` both print through this so their output
/// agrees.
pub fn render_arena_stats(s: &ArenaStats) -> String {
    let mut line = format!(
        "arena {:.1} KiB planned vs {:.1} KiB naive ({:.1}x, {}) | plan cache {} hit / {} miss ({:.0}% hit) | arena pool {} reused / {} allocated",
        s.planned_bytes as f64 / 1024.0,
        s.naive_bytes as f64 / 1024.0,
        s.reduction(),
        s.strategy,
        s.cache_hits,
        s.cache_misses,
        s.cache_hit_rate() * 100.0,
        s.pool_reused,
        s.pool_allocated,
    );
    if s.pool_dropped > 0 {
        line.push_str(&format!(" / {} dropped", s.pool_dropped));
    }
    if s.warm_loaded > 0 || s.warm_skipped > 0 {
        line.push_str(&format!(
            " | warm start {} loaded / {} skipped",
            s.warm_loaded, s.warm_skipped
        ));
    }
    if !s.order.is_empty() {
        line.push_str(&format!(
            " | order {} breadth {:.1} KiB vs natural {:.1} KiB ({}{:.1} KiB)",
            s.order,
            s.order_breadth as f64 / 1024.0,
            s.natural_breadth as f64 / 1024.0,
            if s.breadth_delta() >= 0 { "-" } else { "+" },
            s.breadth_delta().unsigned_abs() as f64 / 1024.0,
        ));
    }
    if s.waves > 0 {
        line.push_str(&format!(
            " | dynamic {} wave(s), {} hit / {} re-plan",
            s.waves, s.dynamic_hits, s.dynamic_misses
        ));
        if s.wave_resolutions > 0 {
            line.push_str(&format!(", {} re-resolve(s)", s.wave_resolutions));
        }
    }
    if s.blocks_in_use > 0 {
        line.push_str(&format!(
            " | paged {} block(s) peak, {:.0}% fragmentation",
            s.blocks_in_use,
            s.fragmentation * 100.0
        ));
    }
    if s.threads > 1 {
        line.push_str(&format!(
            " | exec {} thread(s), {} level(s), {} op(s) parallel",
            s.threads, s.levels, s.ops_parallel
        ));
    }
    line
}

/// Thread-safe metrics sink shared between the worker and observers.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    /// Per-request end-to-end latency (queue + exec), microseconds.
    latencies_us: Vec<u64>,
    /// Per-request queue wait, microseconds.
    queue_us: Vec<u64>,
    /// Batch sizes executed.
    batches: Vec<usize>,
    /// Total requests completed.
    completed: u64,
    /// Requests refused by budget-driven admission (never executed).
    rejected: u64,
    /// Batches the engine failed to execute (no requests completed).
    engine_errors: u64,
    started: Option<Instant>,
    finished: Option<Instant>,
}

/// A point-in-time summary.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    /// Requests completed (answered with an output).
    pub completed: u64,
    /// Requests refused by admission control ([`crate::coordinator::ServeError::BudgetExceeded`]
    /// / [`crate::coordinator::ServeError::BatchTooLarge`]) — the count the
    /// paper's edge box reports instead of OOMing.
    pub rejected: u64,
    /// Batches the engine failed on ([`crate::coordinator::ServeError::Engine`]).
    /// Failed batches complete no requests and never skew the latency or
    /// batch-size distributions.
    pub engine_errors: u64,
    /// Median end-to-end latency, microseconds.
    pub p50_us: u64,
    /// 95th-percentile end-to-end latency, microseconds.
    pub p95_us: u64,
    /// 99th-percentile end-to-end latency, microseconds.
    pub p99_us: u64,
    /// Mean queue wait, microseconds.
    pub mean_queue_us: u64,
    /// Mean executed batch size.
    pub mean_batch: f64,
    /// Largest batch actually executed — under a memory budget this stays
    /// at or below the budget-clamped cap.
    pub max_batch_seen: usize,
    /// Completed requests per wall-clock second.
    pub throughput_rps: f64,
}

impl Metrics {
    /// Record one executed batch: per-request latencies and waits.
    pub fn record_batch(&self, batch: usize, waits: &[Duration], latencies: &[Duration]) {
        let mut m = self.inner.lock().unwrap();
        let now = Instant::now();
        m.started.get_or_insert(now);
        m.finished = Some(now);
        m.batches.push(batch);
        m.completed += latencies.len() as u64;
        m.queue_us.extend(waits.iter().map(|d| d.as_micros() as u64));
        m.latencies_us
            .extend(latencies.iter().map(|d| d.as_micros() as u64));
    }

    /// Count `requests` refused by admission control.
    pub fn record_rejected(&self, requests: usize) {
        self.inner.lock().unwrap().rejected += requests as u64;
    }

    /// Count one batch the engine failed to execute.
    pub fn record_engine_error(&self) {
        self.inner.lock().unwrap().engine_errors += 1;
    }

    /// Summarize everything recorded so far.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        let mut lat = m.latencies_us.clone();
        lat.sort_unstable();
        let pct = |p: f64| -> u64 {
            if lat.is_empty() {
                0
            } else {
                lat[((lat.len() as f64 - 1.0) * p) as usize]
            }
        };
        let wall = match (m.started, m.finished) {
            (Some(s), Some(f)) if f > s => (f - s).as_secs_f64(),
            _ => 0.0,
        };
        MetricsSnapshot {
            completed: m.completed,
            rejected: m.rejected,
            engine_errors: m.engine_errors,
            p50_us: pct(0.50),
            p95_us: pct(0.95),
            p99_us: pct(0.99),
            mean_queue_us: if m.queue_us.is_empty() {
                0
            } else {
                m.queue_us.iter().sum::<u64>() / m.queue_us.len() as u64
            },
            mean_batch: if m.batches.is_empty() {
                0.0
            } else {
                m.batches.iter().sum::<usize>() as f64 / m.batches.len() as f64
            },
            max_batch_seen: m.batches.iter().copied().max().unwrap_or(0),
            throughput_rps: if wall > 0.0 { m.completed as f64 / wall } else { 0.0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_percentiles() {
        let m = Metrics::default();
        let lats: Vec<Duration> = (1..=100).map(Duration::from_micros).collect();
        let waits = vec![Duration::from_micros(10); 100];
        m.record_batch(4, &waits, &lats);
        let s = m.snapshot();
        assert_eq!(s.completed, 100);
        assert_eq!(s.p50_us, 50);
        assert_eq!(s.p95_us, 95);
        assert_eq!(s.mean_queue_us, 10);
        assert_eq!(s.mean_batch, 4.0);
        assert_eq!(s.max_batch_seen, 4);
        assert_eq!(s.rejected, 0);
        assert_eq!(s.engine_errors, 0);
        m.record_rejected(3);
        assert_eq!(m.snapshot().rejected, 3);
        m.record_engine_error();
        m.record_engine_error();
        let s = m.snapshot();
        assert_eq!(s.engine_errors, 2);
        // Failed batches never feed the completion or latency counters.
        assert_eq!(s.completed, 100);
    }

    #[test]
    fn arena_stats_render_includes_counters() {
        let s = ArenaStats {
            planned_bytes: 10 * 1024,
            naive_bytes: 75 * 1024,
            strategy: "greedy-size".into(),
            cache_hits: 3,
            cache_misses: 1,
            pool_reused: 2,
            pool_allocated: 2,
            ..ArenaStats::default()
        };
        let line = render_arena_stats(&s);
        assert!(line.contains("7.5x"), "{line}");
        assert!(line.contains("3 hit / 1 miss"), "{line}");
        assert!(line.contains("75% hit"), "{line}");
        assert!(line.contains("2 reused / 2 allocated"), "{line}");
        // The warm-start segment only appears once a plan directory was
        // actually touched, the order segment only for order-planning
        // engines, and the dynamic segment only for wave-aware engines.
        assert!(!line.contains("warm start"), "{line}");
        assert!(!line.contains("order"), "{line}");
        assert!(!line.contains("dynamic"), "{line}");
        assert!(!line.contains("thread(s)"), "{line}");
        let warmed = ArenaStats { warm_loaded: 4, warm_skipped: 1, ..s };
        let line = render_arena_stats(&warmed);
        assert!(line.contains("warm start 4 loaded / 1 skipped"), "{line}");
    }

    #[test]
    fn arena_stats_render_includes_the_paged_segment() {
        let s = ArenaStats {
            planned_bytes: 8 * 1024,
            naive_bytes: 32 * 1024,
            strategy: "greedy-size".into(),
            pool_reused: 2,
            pool_allocated: 2,
            pool_dropped: 3,
            ..ArenaStats::default()
        }
        .with_paged(5, 0.25);
        let line = render_arena_stats(&s);
        assert!(line.contains("2 reused / 2 allocated / 3 dropped"), "{line}");
        assert!(line.contains("paged 5 block(s) peak, 25% fragmentation"), "{line}");
        // Engines that never paged or dropped keep the line clean.
        let clean = render_arena_stats(&ArenaStats::default());
        assert!(!clean.contains("dropped"), "{clean}");
        assert!(!clean.contains("paged"), "{clean}");
    }

    #[test]
    fn arena_stats_render_includes_the_dynamic_waves() {
        let s = ArenaStats {
            planned_bytes: 8 * 1024,
            naive_bytes: 32 * 1024,
            strategy: "greedy-size".into(),
            dynamic_hits: 9,
            dynamic_misses: 3,
            ..ArenaStats::default()
        }
        .with_waves(4, 12);
        let line = render_arena_stats(&s);
        assert!(line.contains("dynamic 4 wave(s)"), "{line}");
        assert!(line.contains("9 hit / 3 re-plan"), "{line}");
        assert!(line.contains("12 re-resolve(s)"), "{line}");
    }

    #[test]
    fn arena_stats_render_includes_the_served_order() {
        let s = ArenaStats {
            planned_bytes: 8 * 1024,
            naive_bytes: 32 * 1024,
            strategy: "greedy-size".into(),
            ..ArenaStats::default()
        }
        .with_order("annealed-s42-t100", 6 * 1024, 5 * 1024);
        assert_eq!(s.breadth_delta(), 1024);
        let line = render_arena_stats(&s);
        assert!(line.contains("order annealed-s42-t100"), "{line}");
        assert!(line.contains("breadth 5.0 KiB vs natural 6.0 KiB (-1.0 KiB)"), "{line}");
    }

    #[test]
    fn arena_stats_render_includes_the_parallel_shape() {
        let s = ArenaStats {
            planned_bytes: 8 * 1024,
            naive_bytes: 32 * 1024,
            strategy: "greedy-size".into(),
            ..ArenaStats::default()
        }
        .with_threads(4, 17, 96);
        let line = render_arena_stats(&s);
        assert!(line.contains("exec 4 thread(s), 17 level(s), 96 op(s) parallel"), "{line}");
        // A sequential engine keeps the line free of the segment.
        let seq = ArenaStats::default().with_threads(1, 17, 0);
        assert!(!render_arena_stats(&seq).contains("thread(s)"));
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.p99_us, 0);
        assert_eq!(s.throughput_rps, 0.0);
    }
}
