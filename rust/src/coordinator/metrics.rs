//! Serving metrics: latency percentiles, batch-size distribution,
//! throughput, and the planner's memory accounting line.

use super::ArenaStats;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// One-line serving-visible rendering of a model's [`ArenaStats`]: arena
/// footprint vs naive, plan-cache hit rate, arena-pool reuse. The `serve`
/// CLI and `benches/serving.rs` both print through this so their output
/// agrees.
pub fn render_arena_stats(s: &ArenaStats) -> String {
    let mut line = format!(
        "arena {:.1} KiB planned vs {:.1} KiB naive ({:.1}x, {}) | plan cache {} hit / {} miss ({:.0}% hit) | arena pool {} reused / {} allocated",
        s.planned_bytes as f64 / 1024.0,
        s.naive_bytes as f64 / 1024.0,
        s.reduction(),
        s.strategy,
        s.cache_hits,
        s.cache_misses,
        s.cache_hit_rate() * 100.0,
        s.pool_reused,
        s.pool_allocated,
    );
    if s.pool_dropped > 0 {
        line.push_str(&format!(" / {} dropped", s.pool_dropped));
    }
    if s.warm_loaded > 0 || s.warm_skipped > 0 {
        line.push_str(&format!(
            " | warm start {} loaded / {} skipped",
            s.warm_loaded, s.warm_skipped
        ));
    }
    if !s.order.is_empty() {
        line.push_str(&format!(
            " | order {} breadth {:.1} KiB vs natural {:.1} KiB ({}{:.1} KiB)",
            s.order,
            s.order_breadth as f64 / 1024.0,
            s.natural_breadth as f64 / 1024.0,
            if s.breadth_delta() >= 0 { "-" } else { "+" },
            s.breadth_delta().unsigned_abs() as f64 / 1024.0,
        ));
    }
    if s.waves > 0 {
        line.push_str(&format!(
            " | dynamic {} wave(s), {} hit / {} re-plan",
            s.waves, s.dynamic_hits, s.dynamic_misses
        ));
        if s.wave_resolutions > 0 {
            line.push_str(&format!(", {} re-resolve(s)", s.wave_resolutions));
        }
    }
    if s.blocks_in_use > 0 {
        line.push_str(&format!(
            " | paged {} block(s) peak, {:.0}% fragmentation",
            s.blocks_in_use,
            s.fragmentation * 100.0
        ));
    }
    if s.spill_evictions > 0 || s.spill_reloads > 0 {
        let ratio = if s.spill_bytes_after == 0 {
            1.0
        } else {
            s.spill_bytes_before as f64 / s.spill_bytes_after as f64
        };
        line.push_str(&format!(
            " | spill {} evicted / {} reloaded, {:.1}x compressed, reload p99 {} us",
            s.spill_evictions, s.spill_reloads, ratio, s.spill_stall_p99_us
        ));
    }
    if s.threads > 1 {
        line.push_str(&format!(
            " | exec {} thread(s), {} level(s), {} op(s) parallel",
            s.threads, s.levels, s.ops_parallel
        ));
    }
    if !s.dtype.is_empty() {
        let elem_bytes = match s.dtype.as_str() {
            "i8" => 1,
            "f16" => 2,
            _ => 4,
        };
        line.push_str(&format!(" | dtype {} ({elem_bytes} B/elem vs 4 B f32)", s.dtype));
    }
    line
}

/// Thread-safe metrics sink shared between the worker and observers.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// Retained latency samples per server. 4096 × 8 bytes keeps the sink
/// around 32 KiB no matter how long the worker runs, while percentile
/// error at p99 stays under ~1% for any arrival process worth serving.
const LATENCY_RESERVOIR_CAP: usize = 4096;

/// Uniform reservoir (Vitter's Algorithm R) over a `u64` stream.
///
/// Until the cap is reached every sample is kept, so percentiles are
/// exact for short runs; past the cap each new sample replaces a random
/// slot with probability `cap / seen`, keeping a uniform sample of the
/// whole stream in O(cap) memory. The RNG is an inline SplitMix64 so the
/// coordinator needs no external crate and stays deterministic per sink.
/// Crate-visible because the spill tier samples reload stalls into the
/// same bounded structure (`arena::spill::SpillTier`).
pub(crate) struct Reservoir {
    samples: Vec<u64>,
    seen: u64,
    rng: u64,
}

impl Default for Reservoir {
    fn default() -> Self {
        Reservoir { samples: Vec::new(), seen: 0, rng: 0x9e37_79b9_7f4a_7c15 }
    }
}

impl Reservoir {
    pub(crate) fn record(&mut self, v: u64) {
        self.seen += 1;
        if self.samples.len() < LATENCY_RESERVOIR_CAP {
            self.samples.push(v);
            return;
        }
        // SplitMix64 step: cheap, full-period, no crate.
        self.rng = self.rng.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.rng;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^= z >> 31;
        let j = (z % self.seen) as usize;
        if j < LATENCY_RESERVOIR_CAP {
            self.samples[j] = v;
        }
    }

    /// Percentile `p` (0.0..=1.0) of the retained samples; 0 when empty.
    pub(crate) fn percentile(&self, p: f64) -> u64 {
        if self.samples.is_empty() {
            return 0;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_unstable();
        sorted[((sorted.len() as f64 - 1.0) * p) as usize]
    }
}

#[derive(Default)]
struct Inner {
    /// Bounded sample of per-request end-to-end latencies (queue + exec),
    /// microseconds. A long-running server must not grow per-request
    /// state, so percentiles come from this reservoir instead of a
    /// keep-everything `Vec`.
    latencies: Reservoir,
    /// Running sum of queue waits, microseconds (u128: a u64 sum would
    /// only overflow after ~584k years of aggregate waiting, but the
    /// wider type makes the "cannot overflow" argument free).
    queue_sum_us: u128,
    /// Requests contributing to `queue_sum_us`.
    queue_count: u64,
    /// Running sum of executed batch sizes.
    batch_sum: u64,
    /// Batches executed.
    batch_count: u64,
    /// Largest batch actually executed.
    max_batch_seen: usize,
    /// Total requests completed.
    completed: u64,
    /// Requests refused by budget-driven admission (never executed).
    rejected: u64,
    /// Batches the engine failed to execute (no requests completed).
    engine_errors: u64,
    /// Requests admitted into an already-running decode loop (continuous
    /// scheduler only; the drain worker never increments this).
    continuous_admissions: u64,
    /// Requests served through the spill tier: over the resident budget,
    /// admitted anyway under `SpillPolicy::Spill` by demand-reloading.
    spill_admissions: u64,
    started: Option<Instant>,
    finished: Option<Instant>,
}

/// A point-in-time summary.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    /// Requests completed (answered with an output).
    pub completed: u64,
    /// Requests refused by admission control ([`crate::coordinator::ServeError::BudgetExceeded`]
    /// / [`crate::coordinator::ServeError::BatchTooLarge`]) — the count the
    /// paper's edge box reports instead of OOMing.
    pub rejected: u64,
    /// Batches the engine failed on ([`crate::coordinator::ServeError::Engine`]).
    /// Failed batches complete no requests and never skew the latency or
    /// batch-size distributions.
    pub engine_errors: u64,
    /// Median end-to-end latency, microseconds.
    pub p50_us: u64,
    /// 95th-percentile end-to-end latency, microseconds.
    pub p95_us: u64,
    /// 99th-percentile end-to-end latency, microseconds.
    pub p99_us: u64,
    /// Mean queue wait, microseconds.
    pub mean_queue_us: u64,
    /// Mean executed batch size.
    pub mean_batch: f64,
    /// Largest batch actually executed — under a memory budget this stays
    /// at or below the budget-clamped cap.
    pub max_batch_seen: usize,
    /// Completed requests per wall-clock second.
    pub throughput_rps: f64,
    /// Requests admitted into an in-flight decode loop at a wave boundary
    /// rather than waiting for the batch to drain. Zero for the
    /// batch-and-drain worker; the continuous scheduler's whole point.
    pub continuous_admissions: u64,
    /// Requests that exceeded the resident budget but were admitted under
    /// [`crate::coordinator::SpillPolicy::Spill`] and served through the
    /// spill tier. Zero under the default refuse policy.
    pub spill_admissions: u64,
}

impl Metrics {
    /// Record one executed batch (or, for the continuous scheduler, one
    /// retired lane): per-request latencies and waits.
    pub fn record_batch(&self, batch: usize, waits: &[Duration], latencies: &[Duration]) {
        let mut m = self.inner.lock().unwrap();
        let now = Instant::now();
        m.started.get_or_insert(now);
        m.finished = Some(now);
        m.batch_sum += batch as u64;
        m.batch_count += 1;
        m.max_batch_seen = m.max_batch_seen.max(batch);
        m.completed += latencies.len() as u64;
        m.queue_count += waits.len() as u64;
        for d in waits {
            m.queue_sum_us += d.as_micros();
        }
        for d in latencies {
            m.latencies.record(d.as_micros() as u64);
        }
    }

    /// Count `requests` refused by admission control.
    pub fn record_rejected(&self, requests: usize) {
        self.inner.lock().unwrap().rejected += requests as u64;
    }

    /// Count one batch the engine failed to execute.
    pub fn record_engine_error(&self) {
        self.inner.lock().unwrap().engine_errors += 1;
    }

    /// Count one request admitted into an already-running decode loop.
    pub fn record_continuous_admission(&self) {
        self.inner.lock().unwrap().continuous_admissions += 1;
    }

    /// Count one over-budget request served through the spill tier.
    pub fn record_spill_admission(&self) {
        self.inner.lock().unwrap().spill_admissions += 1;
    }

    /// Latency samples currently held — bounded by the reservoir cap no
    /// matter how many requests were recorded. Exposed for soak tests.
    pub fn latency_samples_retained(&self) -> usize {
        self.inner.lock().unwrap().latencies.samples.len()
    }

    /// Summarize everything recorded so far.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        let mut lat = m.latencies.samples.clone();
        lat.sort_unstable();
        let pct = |p: f64| -> u64 {
            if lat.is_empty() {
                0
            } else {
                lat[((lat.len() as f64 - 1.0) * p) as usize]
            }
        };
        let wall = match (m.started, m.finished) {
            (Some(s), Some(f)) if f > s => (f - s).as_secs_f64(),
            _ => 0.0,
        };
        MetricsSnapshot {
            completed: m.completed,
            rejected: m.rejected,
            engine_errors: m.engine_errors,
            p50_us: pct(0.50),
            p95_us: pct(0.95),
            p99_us: pct(0.99),
            mean_queue_us: if m.queue_count == 0 {
                0
            } else {
                (m.queue_sum_us / u128::from(m.queue_count)) as u64
            },
            mean_batch: if m.batch_count == 0 {
                0.0
            } else {
                m.batch_sum as f64 / m.batch_count as f64
            },
            max_batch_seen: m.max_batch_seen,
            throughput_rps: if wall > 0.0 { m.completed as f64 / wall } else { 0.0 },
            continuous_admissions: m.continuous_admissions,
            spill_admissions: m.spill_admissions,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_percentiles() {
        let m = Metrics::default();
        let lats: Vec<Duration> = (1..=100).map(Duration::from_micros).collect();
        let waits = vec![Duration::from_micros(10); 100];
        m.record_batch(4, &waits, &lats);
        let s = m.snapshot();
        assert_eq!(s.completed, 100);
        assert_eq!(s.p50_us, 50);
        assert_eq!(s.p95_us, 95);
        assert_eq!(s.mean_queue_us, 10);
        assert_eq!(s.mean_batch, 4.0);
        assert_eq!(s.max_batch_seen, 4);
        assert_eq!(s.rejected, 0);
        assert_eq!(s.engine_errors, 0);
        m.record_rejected(3);
        assert_eq!(m.snapshot().rejected, 3);
        m.record_engine_error();
        m.record_engine_error();
        let s = m.snapshot();
        assert_eq!(s.engine_errors, 2);
        // Failed batches never feed the completion or latency counters.
        assert_eq!(s.completed, 100);
        assert_eq!(s.continuous_admissions, 0);
        m.record_continuous_admission();
        assert_eq!(m.snapshot().continuous_admissions, 1);
        assert_eq!(m.snapshot().spill_admissions, 0);
        m.record_spill_admission();
        assert_eq!(m.snapshot().spill_admissions, 1);
    }

    #[test]
    fn soak_keeps_metrics_memory_bounded() {
        // Regression: Inner used to push every latency/wait/batch into
        // Vecs forever and clone+sort them per snapshot, so a long-lived
        // server leaked and its metrics polls slowed without bound.
        let m = Metrics::default();
        let n: u64 = 100_000;
        for i in 0..n {
            let lat = Duration::from_micros(1 + i % 1000);
            m.record_batch(3, &[Duration::from_micros(7)], &[lat]);
        }
        assert!(
            m.latency_samples_retained() <= LATENCY_RESERVOIR_CAP,
            "reservoir must stay bounded, held {}",
            m.latency_samples_retained()
        );
        let s = m.snapshot();
        assert_eq!(s.completed, n);
        // Running sums stay exact even though the samples are downsampled.
        assert_eq!(s.mean_queue_us, 7);
        assert_eq!(s.mean_batch, 3.0);
        assert_eq!(s.max_batch_seen, 3);
        // The reservoir is a uniform sample of a 1..=1000 stream: any
        // retained value is in range, and the median cannot escape it.
        assert!(s.p50_us >= 1 && s.p50_us <= 1000, "p50 {}", s.p50_us);
        assert!(s.p99_us >= s.p50_us, "p99 {} < p50 {}", s.p99_us, s.p50_us);
    }

    #[test]
    fn arena_stats_render_includes_counters() {
        let s = ArenaStats {
            planned_bytes: 10 * 1024,
            naive_bytes: 75 * 1024,
            strategy: "greedy-size".into(),
            cache_hits: 3,
            cache_misses: 1,
            pool_reused: 2,
            pool_allocated: 2,
            ..ArenaStats::default()
        };
        let line = render_arena_stats(&s);
        assert!(line.contains("7.5x"), "{line}");
        assert!(line.contains("3 hit / 1 miss"), "{line}");
        assert!(line.contains("75% hit"), "{line}");
        assert!(line.contains("2 reused / 2 allocated"), "{line}");
        // The warm-start segment only appears once a plan directory was
        // actually touched, the order segment only for order-planning
        // engines, and the dynamic segment only for wave-aware engines.
        assert!(!line.contains("warm start"), "{line}");
        assert!(!line.contains("order"), "{line}");
        assert!(!line.contains("dynamic"), "{line}");
        assert!(!line.contains("thread(s)"), "{line}");
        assert!(!line.contains("dtype"), "{line}");
        let warmed = ArenaStats { warm_loaded: 4, warm_skipped: 1, ..s };
        let line = render_arena_stats(&warmed);
        assert!(line.contains("warm start 4 loaded / 1 skipped"), "{line}");
    }

    #[test]
    fn arena_stats_render_includes_the_paged_segment() {
        let s = ArenaStats {
            planned_bytes: 8 * 1024,
            naive_bytes: 32 * 1024,
            strategy: "greedy-size".into(),
            pool_reused: 2,
            pool_allocated: 2,
            pool_dropped: 3,
            ..ArenaStats::default()
        }
        .with_paged(5, 0.25);
        let line = render_arena_stats(&s);
        assert!(line.contains("2 reused / 2 allocated / 3 dropped"), "{line}");
        assert!(line.contains("paged 5 block(s) peak, 25% fragmentation"), "{line}");
        // Engines that never paged or dropped keep the line clean.
        let clean = render_arena_stats(&ArenaStats::default());
        assert!(!clean.contains("dropped"), "{clean}");
        assert!(!clean.contains("paged"), "{clean}");
    }

    #[test]
    fn arena_stats_render_includes_the_spill_segment() {
        let s = ArenaStats {
            planned_bytes: 8 * 1024,
            naive_bytes: 32 * 1024,
            strategy: "greedy-size".into(),
            spill_evictions: 6,
            spill_reloads: 4,
            spill_bytes_before: 48_000,
            spill_bytes_after: 6_000,
            spill_stall_p99_us: 37,
            ..ArenaStats::default()
        };
        let line = render_arena_stats(&s);
        assert!(
            line.contains("spill 6 evicted / 4 reloaded, 8.0x compressed, reload p99 37 us"),
            "{line}"
        );
        // The byte-identity mechanism for the default refuse policy: no
        // spill traffic, no segment.
        let clean = render_arena_stats(&ArenaStats::default());
        assert!(!clean.contains("spill"), "{clean}");
    }

    #[test]
    fn arena_stats_render_includes_the_dynamic_waves() {
        let s = ArenaStats {
            planned_bytes: 8 * 1024,
            naive_bytes: 32 * 1024,
            strategy: "greedy-size".into(),
            dynamic_hits: 9,
            dynamic_misses: 3,
            ..ArenaStats::default()
        }
        .with_waves(4, 12);
        let line = render_arena_stats(&s);
        assert!(line.contains("dynamic 4 wave(s)"), "{line}");
        assert!(line.contains("9 hit / 3 re-plan"), "{line}");
        assert!(line.contains("12 re-resolve(s)"), "{line}");
    }

    #[test]
    fn arena_stats_render_includes_the_served_order() {
        let s = ArenaStats {
            planned_bytes: 8 * 1024,
            naive_bytes: 32 * 1024,
            strategy: "greedy-size".into(),
            ..ArenaStats::default()
        }
        .with_order("annealed-s42-t100", 6 * 1024, 5 * 1024);
        assert_eq!(s.breadth_delta(), 1024);
        let line = render_arena_stats(&s);
        assert!(line.contains("order annealed-s42-t100"), "{line}");
        assert!(line.contains("breadth 5.0 KiB vs natural 6.0 KiB (-1.0 KiB)"), "{line}");
    }

    #[test]
    fn arena_stats_render_includes_the_parallel_shape() {
        let s = ArenaStats {
            planned_bytes: 8 * 1024,
            naive_bytes: 32 * 1024,
            strategy: "greedy-size".into(),
            ..ArenaStats::default()
        }
        .with_threads(4, 17, 96);
        let line = render_arena_stats(&s);
        assert!(line.contains("exec 4 thread(s), 17 level(s), 96 op(s) parallel"), "{line}");
        // A sequential engine keeps the line free of the segment.
        let seq = ArenaStats::default().with_threads(1, 17, 0);
        assert!(!render_arena_stats(&seq).contains("thread(s)"));
    }

    #[test]
    fn arena_stats_render_includes_the_dtype_segment() {
        use crate::planner::Dtype;
        let s = ArenaStats {
            planned_bytes: 2 * 1024,
            naive_bytes: 32 * 1024,
            strategy: "greedy-size".into(),
            ..ArenaStats::default()
        }
        .with_dtype(Dtype::I8);
        let line = render_arena_stats(&s);
        assert!(line.contains("dtype i8 (1 B/elem vs 4 B f32)"), "{line}");
        let f16 = render_arena_stats(&ArenaStats::default().with_dtype(Dtype::F16));
        assert!(f16.contains("dtype f16 (2 B/elem vs 4 B f32)"), "{f16}");
        // f32 serving clears the field and renders no segment.
        let f32_line = render_arena_stats(&ArenaStats::default().with_dtype(Dtype::F32));
        assert!(!f32_line.contains("dtype"), "{f32_line}");
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.p99_us, 0);
        assert_eq!(s.throughput_rps, 0.0);
    }
}
