//! Engines: what actually computes a batch.

use super::ArenaStats;
use crate::exec::Executor;
use crate::graph::Graph;
use crate::planner::OffsetPlanner;
use crate::runtime::VariantSet;
use anyhow::Result;

/// A batched compute backend for one model.
///
/// Engines are *not* required to be `Send`: PJRT executables hold `Rc`s, so
/// [`super::ModelServer::spawn`] takes a `Send` **factory** and constructs
/// the engine on its worker thread, where it stays for its whole life.
pub trait Engine {
    /// Flat input element count per sample.
    fn in_elems(&self) -> usize;
    /// Flat output element count per sample.
    fn out_elems(&self) -> usize;
    /// Largest batch worth forming (the batcher's cap).
    fn max_batch(&self) -> usize;
    /// Run `n` samples (input holds `n * in_elems`); return `n * out_elems`.
    fn run_batch(&mut self, input: &[f32], n: usize) -> Result<Vec<f32>>;
    /// Planner-derived memory accounting, if the engine owns an arena.
    fn arena_stats(&self) -> ArenaStats {
        ArenaStats::default()
    }
}

/// PJRT-backed engine over AOT batch-size variants (the production path).
pub struct PjrtEngine {
    variants: VariantSet,
    in_elems: usize,
    out_elems: usize,
    stats: ArenaStats,
}

impl PjrtEngine {
    /// Wrap a loaded [`VariantSet`]; `stats` comes from planning the L2
    /// graph (see `examples/serve_e2e.rs`).
    pub fn new(variants: VariantSet, stats: ArenaStats) -> Self {
        let v0 = &variants.variants[0];
        PjrtEngine {
            in_elems: v0.in_elems,
            out_elems: v0.out_elems,
            variants,
            stats,
        }
    }
}

impl Engine for PjrtEngine {
    fn in_elems(&self) -> usize {
        self.in_elems
    }
    fn out_elems(&self) -> usize {
        self.out_elems
    }
    fn max_batch(&self) -> usize {
        self.variants.max_batch()
    }
    fn run_batch(&mut self, input: &[f32], n: usize) -> Result<Vec<f32>> {
        let var = self.variants.pick(n);
        let mut out;
        if var.batch == n {
            out = var.run(input)?;
        } else {
            // Pad the partial batch up to the variant's batch.
            let mut padded = vec![0f32; var.batch * self.in_elems];
            padded[..n * self.in_elems].copy_from_slice(input);
            out = var.run(&padded)?;
            out.truncate(n * self.out_elems);
        }
        Ok(out)
    }
    fn arena_stats(&self) -> ArenaStats {
        self.stats.clone()
    }
}

/// Pure-Rust engine: the arena [`Executor`] run per-sample (batch = loop).
/// Used by `benches/locality.rs` and anywhere artifacts are unavailable.
pub struct ExecutorEngine {
    exec: Executor,
    in_elems: usize,
    out_elems: usize,
    strategy: &'static str,
    max_batch: usize,
}

impl ExecutorEngine {
    /// Plan `graph` with `planner` and wrap the executor. Uses the first
    /// graph output as the response payload.
    pub fn new(graph: &Graph, planner: &dyn OffsetPlanner, strategy: &'static str, seed: u64) -> Result<Self> {
        let exec = Executor::new(graph, planner, seed).map_err(anyhow::Error::msg)?;
        let in_elems = graph.tensor(graph.inputs[0]).num_elements();
        let out_elems = graph.tensor(graph.outputs[0]).num_elements();
        Ok(ExecutorEngine {
            exec,
            in_elems,
            out_elems,
            strategy,
            max_batch: 8,
        })
    }
}

impl Engine for ExecutorEngine {
    fn in_elems(&self) -> usize {
        self.in_elems
    }
    fn out_elems(&self) -> usize {
        self.out_elems
    }
    fn max_batch(&self) -> usize {
        self.max_batch
    }
    fn run_batch(&mut self, input: &[f32], n: usize) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(n * self.out_elems);
        for i in 0..n {
            let sample = &input[i * self.in_elems..(i + 1) * self.in_elems];
            let mut res = self.exec.run(&[sample]);
            out.append(&mut res[0]);
        }
        Ok(out)
    }
    fn arena_stats(&self) -> ArenaStats {
        ArenaStats {
            planned_bytes: self.exec.arena_bytes(),
            naive_bytes: self.exec.naive_bytes(),
            strategy: self.strategy,
        }
    }
}

/// Trivial engine for coordinator unit tests: output = input scaled by 2.
pub struct EchoEngine {
    pub elems: usize,
    pub max_batch: usize,
    /// Batch sizes observed, for batching-policy assertions.
    pub seen_batches: Vec<usize>,
}

impl EchoEngine {
    pub fn new(elems: usize, max_batch: usize) -> Self {
        EchoEngine { elems, max_batch, seen_batches: Vec::new() }
    }
}

impl Engine for EchoEngine {
    fn in_elems(&self) -> usize {
        self.elems
    }
    fn out_elems(&self) -> usize {
        self.elems
    }
    fn max_batch(&self) -> usize {
        self.max_batch
    }
    fn run_batch(&mut self, input: &[f32], n: usize) -> Result<Vec<f32>> {
        self.seen_batches.push(n);
        Ok(input[..n * self.elems].iter().map(|v| v * 2.0).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::offset::GreedyBySize;

    #[test]
    fn echo_engine_scales() {
        let mut e = EchoEngine::new(2, 4);
        let out = e.run_batch(&[1.0, 2.0, 3.0, 4.0], 2).unwrap();
        assert_eq!(out, vec![2.0, 4.0, 6.0, 8.0]);
        assert_eq!(e.seen_batches, vec![2]);
    }

    #[test]
    fn executor_engine_runs_blazeface() {
        let g = crate::models::blazeface();
        let mut e = ExecutorEngine::new(&g, &GreedyBySize, "Greedy by Size", 3).unwrap();
        let x = vec![0.1f32; 2 * e.in_elems()];
        let out = e.run_batch(&x, 2).unwrap();
        assert_eq!(out.len(), 2 * e.out_elems());
        // identical samples give identical outputs
        assert_eq!(out[..e.out_elems()], out[e.out_elems()..]);
        assert!(e.arena_stats().reduction() > 2.0);
    }
}
