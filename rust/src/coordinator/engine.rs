//! Engines: what actually computes a batch.

use super::{AdmissionOutcome, ArenaStats, SpillPolicy};
use crate::arena::paged::BLOCK_WORDS;
use crate::exec::Executor;
use crate::graph::Graph;
use crate::planner::{
    apply_order, AppliedOrder, Dtype, DynamicMode, DynamicRecords, OrderStrategy, PlanRequest,
    PlanService,
};
use crate::records::UsageRecords;
#[cfg(feature = "pjrt")]
use crate::runtime::VariantSet;
use anyhow::Result;
use std::sync::Arc;

/// A batched compute backend for one model.
///
/// Engines are *not* required to be `Send`: PJRT executables hold `Rc`s, so
/// [`super::ModelServer::spawn`] takes a `Send` **factory** and constructs
/// the engine on its worker thread, where it stays for its whole life.
pub trait Engine {
    /// Flat input element count per sample.
    fn in_elems(&self) -> usize;
    /// Flat output element count per sample.
    fn out_elems(&self) -> usize;
    /// Largest batch worth forming (the batcher's cap).
    fn max_batch(&self) -> usize;
    /// Run `n` samples (input holds `n * in_elems`); return `n * out_elems`.
    fn run_batch(&mut self, input: &[f32], n: usize) -> Result<Vec<f32>>;
    /// Planner-derived memory accounting, if the engine owns an arena.
    fn arena_stats(&self) -> ArenaStats {
        ArenaStats::default()
    }
    /// Planned arena peak (bytes) for a batch of `batch` samples, if the
    /// engine's working memory is planner-managed. `None` means the engine
    /// cannot predict its footprint, so a memory budget cannot bind it.
    fn planned_peak(&self, batch: usize) -> Option<usize> {
        let _ = batch;
        None
    }
    /// Largest batch whose planned peak fits `budget_bytes`: the
    /// admission cap [`super::ModelServer`] resolves at spawn when
    /// [`super::BatchPolicy::mem_budget`] is set. `Some(0)` means even a
    /// single sample does not fit; `None` means the engine cannot answer
    /// (no planning), so the budget is not enforced.
    fn max_servable_batch(&self, budget_bytes: usize) -> Option<usize> {
        let _ = budget_bytes;
        None
    }
    /// Bytes the engine's spill tier could absorb
    /// ([`crate::arena::spill::SpillTier::capacity_bytes`]); 0 for engines
    /// without a tier. This is the *elastic* half of the admission bound:
    /// under [`SpillPolicy::Spill`] a batch fits if its planned peak is at
    /// most `budget + spill_capacity_bytes()`.
    fn spill_capacity_bytes(&self) -> usize {
        0
    }
    /// Bound the shared block-pool freelist backing this engine's paged
    /// decode tails ([`super::BatchPolicy::block_shelf_cap`], CLI
    /// `--block-cap`). A no-op for engines without a block pool.
    fn set_block_shelf_cap(&mut self, cap: usize) {
        let _ = cap;
    }
    /// Typed admission decision for a batch of `batch` samples under
    /// `budget_bytes` and `policy`. The default implementation is the one
    /// decision table every engine shares (see `docs/ARCHITECTURE.md` §3):
    /// no budget, or an engine that cannot predict its footprint, admits
    /// (a budget cannot bind what cannot be planned — exactly the
    /// pre-spill behavior); a planned peak within the budget admits; over
    /// the budget, [`SpillPolicy::Spill`] serves through the tier when the
    /// peak fits `budget + spill capacity`, and everything else refuses.
    fn admission(
        &self,
        batch: usize,
        budget_bytes: Option<usize>,
        policy: SpillPolicy,
    ) -> AdmissionOutcome {
        let Some(budget) = budget_bytes else {
            return AdmissionOutcome::Admit;
        };
        let Some(peak) = self.planned_peak(batch) else {
            return AdmissionOutcome::Admit;
        };
        if peak <= budget {
            return AdmissionOutcome::Admit;
        }
        if policy == SpillPolicy::Spill
            && peak <= budget.saturating_add(self.spill_capacity_bytes())
        {
            return AdmissionOutcome::Spill;
        }
        AdmissionOutcome::Refuse
    }
    /// True when this engine serves requests as independently-advancing
    /// decode lanes ([`Self::lane_begin`] / [`Self::lane_advance`] /
    /// [`Self::lane_finish`]) — what the continuous-batching scheduler
    /// ([`super::BatchPolicy::continuous`]) requires. Engines answering
    /// `true` must also account per live lane in
    /// [`Self::planned_peak`], since the scheduler admits up to the
    /// budget-resolved cap *simultaneously*.
    fn supports_lanes(&self) -> bool {
        false
    }
    /// Size the engine for `lanes` concurrent decode lanes (e.g. stripe
    /// the resident arena) — called once at spawn, before any admission,
    /// so the hot path never re-plans.
    fn lane_prepare(&mut self, lanes: usize) -> Result<()> {
        let _ = lanes;
        Ok(())
    }
    /// Admit one single-sample request (`in_elems` elements) into the
    /// idle lane `lane`.
    fn lane_begin(&mut self, lane: usize, input: &[f32]) -> Result<()> {
        let _ = (lane, input);
        anyhow::bail!("engine does not support lane-granular serving")
    }
    /// Advance an open lane through its next decode wave; `Ok(true)` once
    /// the lane has executed every step and is ready to finish.
    fn lane_advance(&mut self, lane: usize) -> Result<bool> {
        let _ = lane;
        anyhow::bail!("engine does not support lane-granular serving")
    }
    /// Collect a finished lane's output (`out_elems` elements) and
    /// release the lane (tail memory returns to its pool).
    fn lane_finish(&mut self, lane: usize) -> Result<Vec<f32>> {
        let _ = lane;
        anyhow::bail!("engine does not support lane-granular serving")
    }
    /// Drop an open lane without collecting output (scheduler error
    /// recovery); must leave the lane admissible again.
    fn lane_abort(&mut self, lane: usize) {
        let _ = lane;
    }
}

/// PJRT-backed engine over AOT batch-size variants (the production path).
///
/// Since the `PlanRequest` redesign this engine shares the same
/// [`PlanService`] as [`ExecutorEngine`]: construct it with
/// [`PjrtEngine::with_request`] and its working-set accounting
/// ([`Engine::planned_peak`] / [`Engine::max_servable_batch`] /
/// [`Engine::arena_stats`]) resolves through the shared plan cache —
/// live counters, budget admission, and warm starts all behave exactly
/// like the pure-Rust path — instead of through a frozen [`ArenaStats`]
/// snapshot taken at load time.
#[cfg(feature = "pjrt")]
pub struct PjrtEngine {
    variants: VariantSet,
    in_elems: usize,
    out_elems: usize,
    /// The shared planning handle + typed request + batch-1 records of the
    /// planner twin graph (the request-routed path); `None` on the
    /// deprecated frozen-snapshot path.
    planned: Option<(Arc<PlanService>, PlanRequest, UsageRecords)>,
    /// Planned/naive footprint at the request's batch, resolved **once**
    /// at construction: stats renders must not probe the shared cache (a
    /// metrics poller would inflate the very hit counters the stats
    /// report).
    planned_bytes: usize,
    naive_bytes: usize,
    /// Frozen snapshot for the deprecated [`PjrtEngine::new`] path (and
    /// the zeroed fallback when `planned` is set but a probe fails).
    stats: ArenaStats,
    /// Reusable padding buffer for partial batches, shared across every
    /// batch variant: PJRT donates input buffers on execute, so keeping
    /// one donation-eligible scratch sized for the largest variant avoids
    /// a fresh allocation per padded batch.
    scratch: Vec<f32>,
}

#[cfg(feature = "pjrt")]
impl PjrtEngine {
    /// Wrap a loaded [`VariantSet`] and route all working-set accounting
    /// through the shared `service`: `records` are the batch-1 usage
    /// records of the planner twin of the compiled model (the graph whose
    /// arena the planner manages — e.g. `models::l2_cnn()` for the AOT CNN
    /// artifacts), already reordered under `req.order()` if non-natural,
    /// and `req` is the typed plan identity every peak/budget probe is
    /// keyed by. The request's batch is pre-planned so the serving arena
    /// number is resident before the first batch arrives.
    pub fn with_request(
        variants: VariantSet,
        service: Arc<PlanService>,
        records: UsageRecords,
        req: &PlanRequest,
    ) -> Result<Self> {
        let in_elems = variants.variants[0].in_elems;
        let out_elems = variants.variants[0].out_elems;
        let req = req.with_dynamic(DynamicMode::Static);
        // Pre-plan the request's own batch once: the construction-time
        // planner invocation every later lookup amortizes, and the stats
        // footprint every render reuses. A failure here must fail
        // construction — degrading to a zero footprint would silently
        // disable budget admission.
        let planned_bytes = service.plan(&records, &req)?.total;
        let naive_bytes = records.naive_total().saturating_mul(req.batch());
        Ok(PjrtEngine {
            in_elems,
            out_elems,
            variants,
            planned: Some((service, req, records)),
            planned_bytes,
            naive_bytes,
            stats: ArenaStats::default(),
            scratch: Vec::new(),
        })
    }

    /// Wrap a loaded [`VariantSet`] with a frozen accounting snapshot.
    #[deprecated(
        since = "0.3.0",
        note = "construct with with_request(service, records, req) so accounting goes \
                through the shared PlanService"
    )]
    pub fn new(variants: VariantSet, stats: ArenaStats) -> Self {
        let v0 = &variants.variants[0];
        PjrtEngine {
            in_elems: v0.in_elems,
            out_elems: v0.out_elems,
            variants,
            planned: None,
            planned_bytes: 0,
            naive_bytes: 0,
            stats,
            scratch: Vec::new(),
        }
    }
}

#[cfg(feature = "pjrt")]
impl Engine for PjrtEngine {
    fn in_elems(&self) -> usize {
        self.in_elems
    }
    fn out_elems(&self) -> usize {
        self.out_elems
    }
    fn max_batch(&self) -> usize {
        self.variants.max_batch()
    }
    fn run_batch(&mut self, input: &[f32], n: usize) -> Result<Vec<f32>> {
        let var = self.variants.pick(n);
        let mut out;
        if var.batch == n {
            out = var.run(input)?;
        } else {
            // Pad the partial batch up to the variant's batch, reusing one
            // donation-eligible scratch across calls and batch variants.
            let need = var.batch * self.in_elems;
            if self.scratch.len() < need {
                self.scratch.resize(need, 0.0);
            }
            self.scratch[..n * self.in_elems].copy_from_slice(input);
            for v in &mut self.scratch[n * self.in_elems..need] {
                *v = 0.0;
            }
            out = var.run(&self.scratch[..need])?;
            out.truncate(n * self.out_elems);
        }
        Ok(out)
    }
    fn arena_stats(&self) -> ArenaStats {
        match &self.planned {
            Some((service, req, _)) => {
                // The construction-time footprint plus *live* service
                // counters, exactly like ExecutorEngine reading its
                // resident executor state — rendering stats never probes
                // the cache (that would inflate the hit counters being
                // reported).
                ArenaStats::from_service(
                    self.planned_bytes,
                    self.naive_bytes,
                    req.strategy(),
                    service.stats(),
                )
            }
            None => self.stats.clone(),
        }
    }
    fn planned_peak(&self, batch: usize) -> Option<usize> {
        let (service, req, records) = self.planned.as_ref()?;
        if batch == 0 {
            return Some(0);
        }
        let naive = records.naive_total().max(1);
        if batch > usize::MAX / naive {
            return None;
        }
        service
            .plan(records, &req.with_batch(batch))
            .ok()
            .map(|p| p.total)
    }
    fn max_servable_batch(&self, budget_bytes: usize) -> Option<usize> {
        let (service, req, records) = self.planned.as_ref()?;
        service.max_servable_batch(records, req, budget_bytes).ok()
    }
}

/// Default batch cap for [`ExecutorEngine`] (override with
/// [`ExecutorEngine::with_max_batch`]).
pub const DEFAULT_EXECUTOR_MAX_BATCH: usize = 8;

/// Pure-Rust engine: the arena [`Executor`] run batched against one
/// lane-striped resident arena. Plans come from the shared
/// [`PlanService`]'s cache and arena buffers from its pool, so replicas of
/// the same model plan once and recycle memory. Used by
/// `benches/locality.rs`, the `serve` CLI's artifact-free path, and
/// anywhere PJRT artifacts are unavailable.
pub struct ExecutorEngine {
    exec: Executor,
    in_elems: usize,
    out_elems: usize,
    /// The typed plan identity (strategy + order, static mode) every
    /// lookup this engine performs is keyed by.
    req: PlanRequest,
    service: Arc<PlanService>,
    max_batch: usize,
    /// Batch-1 usage records of the *served* (order-applied) graph, the
    /// input to every budget query.
    records: UsageRecords,
    /// Receipt of the applied order: canonical key + breadth movement,
    /// reported in [`ArenaStats`].
    applied: AppliedOrder,
    /// §7 dynamic profile of the served (order-applied) graph, when this
    /// engine serves in wave-aware mode — the input to every dynamic budget
    /// query (`planned_peak` / `max_servable_batch` resolve against the
    /// worst-wave peak, not a static plan).
    dynamic: Option<DynamicRecords>,
    /// Serve the decode tail from the shared block pool instead of the
    /// resident arena: the arena holds only the static prefix, and budget
    /// admission charges prefix peak + tail block demand.
    paged: bool,
    /// Expose the paged executor's lane API to the continuous-batching
    /// scheduler, and charge the tail's block demand per live lane
    /// (simultaneously-open lanes each hold a private mapping).
    continuous: bool,
}

impl ExecutorEngine {
    /// Plan `graph` under `strategy` (any registry key or display name)
    /// through `service` and wrap the executor, serving the natural
    /// execution order — shorthand for [`Self::for_request`] with a
    /// default request at that strategy. Uses the first graph output as
    /// the response payload.
    pub fn new(
        graph: &Graph,
        service: Arc<PlanService>,
        strategy: &str,
        seed: u64,
    ) -> Result<Self> {
        let req = PlanRequest::new().with_strategy(strategy)?;
        Self::for_request(graph, service, &req, seed)
    }

    /// Build the engine a [`PlanRequest`] describes: the graph is
    /// reordered under `req.order()` *before* record extraction and
    /// planning, so the executor runs ops in that order and every plan —
    /// including the budget-admission envelope resolved at
    /// [`super::ModelServer::spawn`] — comes from the request-keyed cache
    /// slot. The request must be static; for §7 wave-aware serving pass a
    /// decode-tail start to [`Self::for_request_dynamic`].
    pub fn for_request(
        graph: &Graph,
        service: Arc<PlanService>,
        req: &PlanRequest,
        seed: u64,
    ) -> Result<Self> {
        if !req.dynamic().is_static() {
            anyhow::bail!(
                "dynamic request '{req}' needs a decode profile; use for_request_dynamic"
            );
        }
        Self::construct(graph, service, req, None, false, seed)
    }

    /// [`Self::for_request`] in the §7 **wave-aware** mode: the served
    /// (order-applied) graph's records get the decode-tail dynamic profile
    /// starting at `decode_from` (see [`DynamicRecords::decode_tail`]), the
    /// executor sizes its pooled arena at the worst-wave multi-pass peak
    /// and re-resolves offsets through the plan cache at every wave
    /// boundary, and budget admission ([`Engine::planned_peak`] /
    /// [`Engine::max_servable_batch`]) resolves under that worst-wave peak.
    /// Repeat inferences over the same resolved prefixes perform zero
    /// planner invocations — the decode-step amortization MAFAT-style
    /// serving needs. The request's own [`DynamicMode`] is immaterial: the
    /// engine derives each lookup's resolution state itself. Quantized
    /// requests ([`PlanRequest::with_dtype`]) are rejected: i8/f16 size
    /// classes serve statically only.
    pub fn for_request_dynamic(
        graph: &Graph,
        service: Arc<PlanService>,
        req: &PlanRequest,
        decode_from: usize,
        seed: u64,
    ) -> Result<Self> {
        if req.dtype() != Dtype::F32 {
            anyhow::bail!(
                "quantized request '{req}' cannot serve wave-aware: i8/f16 size classes are \
                 static-mode only"
            );
        }
        Self::construct(graph, service, req, Some(decode_from), false, seed)
    }

    /// [`Self::for_request_dynamic`] in **paged** mode: the resident arena
    /// is sized at the *static-prefix* peak only, and every decode-tail
    /// tensor lives in fixed-size blocks acquired from the shared
    /// [`BlockPool`] at the wave boundary that materializes it and released
    /// the step it dies (see [`Executor::with_request_paged`]). Steady-state
    /// resident bytes are strictly below the worst-wave preallocation
    /// whenever the tail grows the peak, at the cost of gather/scatter
    /// copies on tail-touching ops; outputs stay bit-identical. Budget
    /// admission charges `prefix peak + tail block demand × block bytes`.
    /// Quantized requests ([`PlanRequest::with_dtype`]) are rejected: i8/f16
    /// size classes serve statically only.
    ///
    /// [`BlockPool`]: crate::arena::paged::BlockPool
    pub fn for_request_paged(
        graph: &Graph,
        service: Arc<PlanService>,
        req: &PlanRequest,
        decode_from: usize,
        seed: u64,
    ) -> Result<Self> {
        if req.dtype() != Dtype::F32 {
            anyhow::bail!(
                "quantized request '{req}' cannot serve paged: i8/f16 size classes are \
                 static-mode only"
            );
        }
        Self::construct(graph, service, req, Some(decode_from), true, seed)
    }

    /// [`Self::for_request`] with untyped `(strategy, order)` arguments.
    #[deprecated(since = "0.3.0", note = "build a PlanRequest and call for_request")]
    pub fn with_order(
        graph: &Graph,
        service: Arc<PlanService>,
        strategy: &str,
        order: OrderStrategy,
        seed: u64,
    ) -> Result<Self> {
        let req = PlanRequest::new().with_strategy(strategy)?.with_order(order);
        Self::for_request(graph, service, &req, seed)
    }

    /// [`Self::for_request_dynamic`] with untyped `(strategy, order)`
    /// arguments.
    #[deprecated(since = "0.3.0", note = "build a PlanRequest and call for_request_dynamic")]
    pub fn with_dynamic(
        graph: &Graph,
        service: Arc<PlanService>,
        strategy: &str,
        order: OrderStrategy,
        decode_from: usize,
        seed: u64,
    ) -> Result<Self> {
        let req = PlanRequest::new().with_strategy(strategy)?.with_order(order);
        Self::for_request_dynamic(graph, service, &req, decode_from, seed)
    }

    fn construct(
        graph: &Graph,
        service: Arc<PlanService>,
        req: &PlanRequest,
        decode_from: Option<usize>,
        paged: bool,
        seed: u64,
    ) -> Result<Self> {
        let req = req.with_dynamic(DynamicMode::Static);
        if graph.inputs.len() != 1 || graph.outputs.is_empty() {
            anyhow::bail!(
                "ExecutorEngine serves single-input graphs with at least one output; \
                 '{}' has {} inputs / {} outputs",
                graph.name,
                graph.inputs.len(),
                graph.outputs.len()
            );
        }
        let (ordered, applied) = apply_order(graph, req.order());
        let dynamic = decode_from.map(|from| {
            DynamicRecords::decode_tail(&UsageRecords::from_graph(&ordered), from)
        });
        let exec = if paged {
            let d = dynamic.clone().expect("paged construction always has a decode profile");
            Executor::with_request_paged(&ordered, Arc::clone(&service), &req, d, seed)
                .map_err(anyhow::Error::msg)?
        } else {
            Executor::with_request(&ordered, Arc::clone(&service), &req, dynamic.clone(), seed)
                .map_err(anyhow::Error::msg)?
        };
        let in_elems = ordered.tensor(ordered.inputs[0]).num_elements();
        let out_elems = ordered.tensor(ordered.outputs[0]).num_elements();
        let records = exec.base_records().clone();
        Ok(ExecutorEngine {
            exec,
            in_elems,
            out_elems,
            req,
            service,
            max_batch: DEFAULT_EXECUTOR_MAX_BATCH,
            records,
            applied,
            dynamic,
            paged,
            continuous: false,
        })
    }

    /// Cap the batches the batcher may form (default
    /// [`DEFAULT_EXECUTOR_MAX_BATCH`]); clamped to at least 1.
    pub fn with_max_batch(mut self, max_batch: usize) -> Self {
        self.max_batch = max_batch.max(1);
        self
    }

    /// Run the executor with `threads` worker threads (clamped to at least
    /// 1): batches execute in lockstep lane parallelism and single samples
    /// through the level schedule — see [`Executor::set_threads`]. The
    /// `serve --threads` flag lands here.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.exec.set_threads(threads);
        self
    }

    /// Serve **continuously**: expose the paged executor's lane API
    /// ([`Engine::supports_lanes`]) so the coordinator can admit requests
    /// into in-flight decode loops at wave boundaries instead of draining
    /// the batch ([`super::BatchPolicy::continuous`]), and switch budget
    /// admission from the drain-mode tail charge (one lane — lanes page
    /// sequentially) to the continuous charge (`batch ×` the tail — every
    /// live lane keeps its own blocks mapped across wave boundaries).
    /// Only meaningful on a paged engine ([`Self::for_request_paged`]);
    /// otherwise lanes stay unsupported and the flag is inert. The `serve
    /// --continuous` flag lands here.
    pub fn with_continuous(mut self) -> Self {
        self.continuous = true;
        self
    }
}

impl Engine for ExecutorEngine {
    fn in_elems(&self) -> usize {
        self.in_elems
    }
    fn out_elems(&self) -> usize {
        self.out_elems
    }
    fn max_batch(&self) -> usize {
        self.max_batch
    }
    fn run_batch(&mut self, input: &[f32], n: usize) -> Result<Vec<f32>> {
        self.exec.run_batch(input, n).map_err(anyhow::Error::msg)
    }
    fn arena_stats(&self) -> ArenaStats {
        let mut stats = ArenaStats::from_service(
            self.exec.arena_bytes(),
            self.exec.naive_bytes(),
            self.req.strategy(),
            self.service.stats(),
        );
        // Only wave-aware configurations report the dynamic segment, only
        // order-planning configurations the order segment, and only
        // quantized configurations the dtype segment: plain natural-order
        // static f32 serving keeps the rendered stats line unchanged.
        if self.dynamic.is_some() {
            stats = stats.with_waves(self.exec.wave_passes(), self.exec.wave_resolutions());
        }
        if self.paged {
            let blocks = self.service.pool().blocks();
            stats = stats.with_paged(blocks.peak_blocks() as u64, blocks.fragmentation());
        }
        if self.exec.threads() > 1 {
            stats = stats.with_threads(
                self.exec.threads(),
                self.exec.levels(),
                self.exec.ops_parallel(),
            );
        }
        stats = stats.with_dtype(self.req.dtype());
        if self.req.order().is_natural() {
            return stats;
        }
        stats.with_order(
            self.applied.key(),
            self.applied.natural_breadth,
            self.applied.order_breadth,
        )
    }
    fn planned_peak(&self, batch: usize) -> Option<usize> {
        if batch == 0 {
            return Some(0);
        }
        // A batch whose scaled footprint cannot even be represented would
        // overflow inside planning; it certainly fits no budget, and `None`
        // keeps the refusal path panic-free.
        let naive = self.records.naive_total().max(1);
        if batch > usize::MAX / naive {
            return None;
        }
        match &self.dynamic {
            // Paged serving admits against what it actually holds resident:
            // the static-prefix plan plus the decode tail's peak block
            // demand. In drain mode the tail term is batch-invariant —
            // lanes page their tails one at a time — while continuous mode
            // keeps every live lane's tail mapped across wave boundaries,
            // so each of the `batch` admissible lanes is charged its own
            // tail. Either way the charge is what wave-boundary state can
            // actually reach, so admission under a budget never exceeds it.
            Some(d) if self.paged => {
                let prefix = self
                    .service
                    .plan_dynamic(
                        d,
                        &self.req.with_batch(batch).with_dynamic(DynamicMode::Resolved(0)),
                    )
                    .ok()?;
                let lanes = if self.continuous { batch } else { 1 };
                let tail =
                    d.tail_block_demand_lanes(BLOCK_WORDS, lanes).checked_mul(BLOCK_WORDS * 4)?;
                prefix.peak.checked_add(tail)
            }
            // Wave-aware serving must admit against the worst-wave peak:
            // mid-inference waves only ever grow the arena.
            Some(d) => self
                .service
                .plan_dynamic(
                    d,
                    &self.req.with_batch(batch).with_dynamic(DynamicMode::FullyResolved),
                )
                .ok()
                .map(|p| p.peak),
            None => self
                .service
                .plan(&self.records, &self.req.with_batch(batch))
                .ok()
                .map(|p| p.total),
        }
    }
    fn max_servable_batch(&self, budget_bytes: usize) -> Option<usize> {
        if self.paged {
            // The paged footprint (prefix peak plus a tail term that is
            // flat in drain mode and linear in continuous mode) is
            // monotone in the batch, so a bounded linear walk finds the
            // largest admissible size; the engine's own cap bounds the
            // walk, and a probe failure ends it conservatively. In
            // continuous mode the result doubles as the *lane cap*: with
            // at most that many lanes live, wave-boundary memory is
            // bounded by this walk's admitted peak, hence by the budget.
            let mut best = 0;
            for b in 1..=self.max_batch {
                match self.planned_peak(b) {
                    Some(p) if p <= budget_bytes => best = b,
                    _ => break,
                }
            }
            return Some(best);
        }
        match &self.dynamic {
            Some(d) => self
                .service
                .max_servable_batch_dynamic(d, &self.req, budget_bytes)
                .ok(),
            None => self
                .service
                .max_servable_batch(&self.records, &self.req, budget_bytes)
                .ok(),
        }
    }
    fn spill_capacity_bytes(&self) -> usize {
        self.service.pool().spill_tier().map(|t| t.capacity_bytes()).unwrap_or(0)
    }
    fn set_block_shelf_cap(&mut self, cap: usize) {
        self.service.pool().blocks().set_shelf_cap(cap);
    }
    fn supports_lanes(&self) -> bool {
        // The lane API lives on the paged executor, and only a
        // continuous-constructed engine charges its budget per live lane
        // — both must hold before the scheduler may interleave lanes.
        self.paged && self.continuous
    }
    fn lane_prepare(&mut self, lanes: usize) -> Result<()> {
        self.exec.ensure_batch(lanes).map_err(anyhow::Error::msg)
    }
    fn lane_begin(&mut self, lane: usize, input: &[f32]) -> Result<()> {
        self.exec.lane_open(lane, input).map_err(anyhow::Error::msg)
    }
    fn lane_advance(&mut self, lane: usize) -> Result<bool> {
        self.exec.lane_advance(lane).map_err(anyhow::Error::msg)
    }
    fn lane_finish(&mut self, lane: usize) -> Result<Vec<f32>> {
        self.exec.lane_finish(lane).map_err(anyhow::Error::msg)
    }
    fn lane_abort(&mut self, lane: usize) {
        self.exec.lane_abort(lane);
    }
}

/// Trivial engine for coordinator unit tests: output = input scaled by 2.
pub struct EchoEngine {
    /// Elements per sample (both input and output).
    pub elems: usize,
    /// Largest batch the engine accepts.
    pub max_batch: usize,
    /// Batch sizes observed, for batching-policy assertions.
    pub seen_batches: Vec<usize>,
    /// Pretend planned peak per sample, so budget-admission tests get a
    /// linear, fully predictable footprint without a real model.
    pub peak_per_sample: Option<usize>,
    /// Pretend spill-tier capacity (bytes), so spill-admission tests get
    /// a predictable elastic bound without a real pool.
    pub spill_capacity: usize,
}

impl EchoEngine {
    /// Engine of `elems` elements per sample, accepting up to `max_batch`.
    pub fn new(elems: usize, max_batch: usize) -> Self {
        EchoEngine {
            elems,
            max_batch,
            seen_batches: Vec::new(),
            peak_per_sample: None,
            spill_capacity: 0,
        }
    }

    /// Report a linear planned peak of `bytes` per sample.
    pub fn with_peak_per_sample(mut self, bytes: usize) -> Self {
        self.peak_per_sample = Some(bytes);
        self
    }

    /// Report a spill-tier capacity of `bytes` (the elastic admission
    /// bound under [`SpillPolicy::Spill`]).
    pub fn with_spill_capacity(mut self, bytes: usize) -> Self {
        self.spill_capacity = bytes;
        self
    }
}

impl Engine for EchoEngine {
    fn in_elems(&self) -> usize {
        self.elems
    }
    fn out_elems(&self) -> usize {
        self.elems
    }
    fn max_batch(&self) -> usize {
        self.max_batch
    }
    fn run_batch(&mut self, input: &[f32], n: usize) -> Result<Vec<f32>> {
        self.seen_batches.push(n);
        Ok(input[..n * self.elems].iter().map(|v| v * 2.0).collect())
    }
    fn planned_peak(&self, batch: usize) -> Option<usize> {
        self.peak_per_sample.map(|p| p * batch)
    }
    fn max_servable_batch(&self, budget_bytes: usize) -> Option<usize> {
        self.peak_per_sample.map(|p| if p == 0 { usize::MAX } else { budget_bytes / p })
    }
    fn spill_capacity_bytes(&self) -> usize {
        self.spill_capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn echo_engine_scales() {
        let mut e = EchoEngine::new(2, 4);
        let out = e.run_batch(&[1.0, 2.0, 3.0, 4.0], 2).unwrap();
        assert_eq!(out, vec![2.0, 4.0, 6.0, 8.0]);
        assert_eq!(e.seen_batches, vec![2]);
    }

    #[test]
    fn executor_engine_runs_blazeface() {
        let g = crate::models::blazeface();
        let svc = PlanService::shared();
        let mut e = ExecutorEngine::new(&g, svc, "Greedy by Size", 3)
            .unwrap()
            .with_max_batch(4);
        assert_eq!(e.max_batch(), 4);
        let x = vec![0.1f32; 2 * e.in_elems()];
        let out = e.run_batch(&x, 2).unwrap();
        assert_eq!(out.len(), 2 * e.out_elems());
        // identical samples give identical outputs
        assert_eq!(out[..e.out_elems()], out[e.out_elems()..]);
        assert!(e.arena_stats().reduction() > 2.0);
    }

    #[test]
    fn threaded_engine_matches_sequential_and_reports_the_shape() {
        let g = crate::models::blazeface();
        let mut seq = ExecutorEngine::new(&g, PlanService::shared(), "greedy-size", 3).unwrap();
        let mut par = ExecutorEngine::new(&g, PlanService::shared(), "greedy-size", 3)
            .unwrap()
            .with_threads(4);
        let x = vec![0.1f32; 3 * seq.in_elems()];
        assert_eq!(
            seq.run_batch(&x, 3).unwrap(),
            par.run_batch(&x, 3).unwrap(),
            "threads changed the numbers"
        );
        let st = par.arena_stats();
        assert_eq!(st.threads, 4);
        assert!(st.levels > 0);
        assert!(st.ops_parallel > 0);
        // Sequential serving keeps the stats line thread-free.
        assert_eq!(seq.arena_stats().threads, 0);
    }

    #[test]
    fn two_engines_same_batch_plan_once() {
        // The acceptance check behind the PlanService refactor: a second
        // engine for the same (model, batch, strategy) must not invoke the
        // planner again.
        let svc = PlanService::shared();
        let g = crate::models::blazeface();
        let _a = ExecutorEngine::new(&g, Arc::clone(&svc), "greedy-size", 1).unwrap();
        let _b = ExecutorEngine::new(&g, Arc::clone(&svc), "greedy-size", 2).unwrap();
        let st = svc.stats();
        assert_eq!(st.cache_misses, 1, "second engine re-ran the planner");
        assert_eq!(st.cache_hits, 1);
    }

    #[test]
    fn unknown_strategy_rejected_at_construction() {
        let g = crate::models::blazeface();
        assert!(ExecutorEngine::new(&g, PlanService::shared(), "belady", 1).is_err());
    }

    #[test]
    fn ordered_engine_matches_natural_outputs_and_reports_the_order() {
        // Reordering changes *when* each op runs, never *what* it computes:
        // the same DAG with the same synthesized weights must produce
        // bit-identical outputs under any valid order.
        let g = crate::models::blazeface();
        let order = OrderStrategy::Annealed { seed: 5, budget: 20 };
        let mut nat = ExecutorEngine::new(&g, PlanService::shared(), "greedy-size", 3).unwrap();
        let req = PlanRequest::new().with_order(order);
        let mut ann =
            ExecutorEngine::for_request(&g, PlanService::shared(), &req, 3).unwrap();
        assert_eq!((nat.in_elems(), nat.out_elems()), (ann.in_elems(), ann.out_elems()));
        let x = vec![0.1f32; 2 * nat.in_elems()];
        assert_eq!(nat.run_batch(&x, 2).unwrap(), ann.run_batch(&x, 2).unwrap());
        let st = ann.arena_stats();
        assert_eq!(st.order, order.key());
        assert!(
            st.order_breadth <= st.natural_breadth,
            "annealed breadth {} regressed natural {}",
            st.order_breadth,
            st.natural_breadth
        );
        assert!(st.breadth_delta() >= 0);
        // Natural-order serving keeps the stats line order-free.
        assert!(nat.arena_stats().order.is_empty());
    }

    #[test]
    fn dynamic_engine_matches_static_outputs_and_reports_waves() {
        let g = crate::models::blazeface();
        let decode_from = g.num_ops() / 2;
        let mut stat = ExecutorEngine::new(&g, PlanService::shared(), "greedy-size", 3).unwrap();
        let svc = PlanService::shared();
        let mut dynr = ExecutorEngine::for_request_dynamic(
            &g,
            Arc::clone(&svc),
            &PlanRequest::new(),
            decode_from,
            3,
        )
        .unwrap();
        let x = vec![0.1f32; 2 * stat.in_elems()];
        assert_eq!(
            stat.run_batch(&x, 2).unwrap(),
            dynr.run_batch(&x, 2).unwrap(),
            "wave-aware execution changed the numbers"
        );
        let st = dynr.arena_stats();
        assert!(st.waves >= 2, "decode tail must plan multiple waves: {st:?}");
        assert!(st.wave_resolutions > 0);
        assert!(st.dynamic_misses > 0);
        // Static serving keeps the stats line dynamic-free.
        assert_eq!(stat.arena_stats().waves, 0);
        // A second burst re-resolves every wave from the cache.
        let misses = svc.stats().dynamic_misses;
        dynr.run_batch(&x, 2).unwrap();
        assert_eq!(
            svc.stats().dynamic_misses,
            misses,
            "repeat burst must perform zero planner invocations"
        );
    }

    #[test]
    fn dynamic_engine_budget_resolves_under_the_worst_wave_peak() {
        let g = crate::models::blazeface();
        let decode_from = g.num_ops() / 2;
        let svc = PlanService::shared();
        let e = ExecutorEngine::for_request_dynamic(
            &g,
            Arc::clone(&svc),
            &PlanRequest::new(),
            decode_from,
            3,
        )
        .unwrap();
        let p1 = e.planned_peak(1).unwrap();
        assert!(p1 > 0);
        let cap = e.max_servable_batch(3 * p1).unwrap();
        assert!(cap >= 1);
        assert!(e.planned_peak(cap).unwrap() <= 3 * p1);
        assert!(e.planned_peak(cap + 1).unwrap() > 3 * p1);
        assert_eq!(e.max_servable_batch(p1 - 1), Some(0));
        // The admitted peak is the multi-pass worst-wave peak — exactly
        // what the wave-aware executor sized its resident arena to.
        assert_eq!(p1, e.arena_stats().planned_bytes);
    }

    #[test]
    fn paged_engine_matches_dynamic_outputs_and_reports_blocks() {
        let g = crate::models::blazeface();
        let decode_from = g.num_ops() / 2;
        let mut dynr = ExecutorEngine::for_request_dynamic(
            &g,
            PlanService::shared(),
            &PlanRequest::new(),
            decode_from,
            3,
        )
        .unwrap();
        let svc = PlanService::shared();
        let mut paged = ExecutorEngine::for_request_paged(
            &g,
            Arc::clone(&svc),
            &PlanRequest::new(),
            decode_from,
            3,
        )
        .unwrap();
        let x = vec![0.1f32; 2 * dynr.in_elems()];
        assert_eq!(
            dynr.run_batch(&x, 2).unwrap(),
            paged.run_batch(&x, 2).unwrap(),
            "paging the decode tail changed the numbers"
        );
        let st = paged.arena_stats();
        // The resident arena holds only the static prefix, never more than
        // the worst-wave preallocation the resident engine sized itself to.
        assert!(st.planned_bytes <= dynr.arena_stats().planned_bytes);
        assert!(st.blocks_in_use > 0, "the decode tail must have paged: {st:?}");
        assert!((0.0..1.0).contains(&st.fragmentation), "{st:?}");
        assert!(st.waves >= 2, "paged serving still reports the wave shape: {st:?}");
        // Between bursts every tail block is back in the shared pool.
        assert_eq!(svc.pool().blocks().blocks_in_use(), 0);
        // The resident engine keeps its stats line block-free.
        assert_eq!(dynr.arena_stats().blocks_in_use, 0);
    }

    #[test]
    fn paged_engine_budget_charges_prefix_plus_tail_blocks() {
        let g = crate::models::blazeface();
        let decode_from = g.num_ops() / 2;
        let svc = PlanService::shared();
        let e = ExecutorEngine::for_request_paged(
            &g,
            Arc::clone(&svc),
            &PlanRequest::new(),
            decode_from,
            3,
        )
        .unwrap();
        let d = DynamicRecords::decode_tail(&UsageRecords::from_graph(&g), decode_from);
        let prefix = svc
            .plan_dynamic(&d, &PlanRequest::new().with_dynamic(DynamicMode::Resolved(0)))
            .unwrap()
            .peak;
        let tail = d.tail_block_demand(BLOCK_WORDS) * BLOCK_WORDS * 4;
        assert!(tail > 0, "the decode tail must demand blocks");
        assert_eq!(e.planned_peak(1), Some(prefix + tail));
        // The admission walk is monotone and budget-exact.
        let p1 = prefix + tail;
        let cap = e.max_servable_batch(3 * p1).unwrap();
        assert!(cap >= 1);
        assert!(e.planned_peak(cap).unwrap() <= 3 * p1);
        assert!(e.planned_peak(cap + 1).unwrap() > 3 * p1);
        assert_eq!(e.max_servable_batch(p1 - 1), Some(0));
    }

    #[test]
    fn continuous_engine_charges_tail_per_live_lane_and_serves_lanes() {
        let g = crate::models::blazeface();
        let decode_from = g.num_ops() / 2;
        let svc = PlanService::shared();
        let mut e = ExecutorEngine::for_request_paged(
            &g,
            Arc::clone(&svc),
            &PlanRequest::new(),
            decode_from,
            3,
        )
        .unwrap()
        .with_continuous();
        assert!(e.supports_lanes());
        // Drain-mode paged engines and non-paged engines never advertise
        // lanes: the scheduler must not interleave what is not charged
        // (or striped) per live lane.
        let mut drain = ExecutorEngine::for_request_paged(
            &g,
            Arc::clone(&svc),
            &PlanRequest::new(),
            decode_from,
            3,
        )
        .unwrap();
        assert!(!drain.supports_lanes());
        let resident = ExecutorEngine::new(&g, Arc::clone(&svc), "greedy-size", 3)
            .unwrap()
            .with_continuous();
        assert!(!resident.supports_lanes());
        // Budget charge: prefix(b) + b × tail for continuous mode, versus
        // the drain-mode prefix(b) + tail.
        let d = DynamicRecords::decode_tail(&UsageRecords::from_graph(&g), decode_from);
        let tail = d.tail_block_demand(BLOCK_WORDS) * BLOCK_WORDS * 4;
        assert!(tail > 0, "the decode tail must demand blocks");
        let prefix2 = svc
            .plan_dynamic(
                &d,
                &PlanRequest::new().with_batch(2).with_dynamic(DynamicMode::Resolved(0)),
            )
            .unwrap()
            .peak;
        assert_eq!(e.planned_peak(2), Some(prefix2 + 2 * tail));
        assert_eq!(drain.planned_peak(2), Some(prefix2 + tail));
        // End-to-end: two interleaved lanes, admitted mid-stream, match
        // the batch-and-drain outputs bit for bit.
        let n_in = e.in_elems();
        let out_elems = e.out_elems();
        let a = vec![0.1f32; n_in];
        let b = vec![0.2f32; n_in];
        let flat: Vec<f32> = a.iter().chain(b.iter()).copied().collect();
        let want = drain.run_batch(&flat, 2).unwrap();
        e.lane_prepare(2).unwrap();
        e.lane_begin(0, &a).unwrap();
        let mut f0 = e.lane_advance(0).unwrap();
        assert!(!f0, "blazeface must hit a wave boundary before the end");
        e.lane_begin(1, &b).unwrap();
        let mut f1 = false;
        for _ in 0..256 {
            if !f1 {
                f1 = e.lane_advance(1).unwrap();
            }
            if !f0 {
                f0 = e.lane_advance(0).unwrap();
            }
            if f0 && f1 {
                break;
            }
        }
        assert!(f0 && f1, "lanes did not finish within the step budget");
        assert_eq!(e.lane_finish(0).unwrap().as_slice(), &want[..out_elems]);
        assert_eq!(e.lane_finish(1).unwrap().as_slice(), &want[out_elems..]);
        assert_eq!(svc.pool().blocks().blocks_in_use(), 0, "lane blocks leaked");
        // Engines without lane support refuse the lane API with a typed
        // error instead of panicking, and abort is a safe no-op.
        let mut echo = EchoEngine::new(1, 4);
        assert!(!echo.supports_lanes());
        assert!(echo.lane_prepare(2).is_ok());
        assert!(echo.lane_begin(0, &[1.0]).is_err());
        assert!(echo.lane_advance(0).is_err());
        assert!(echo.lane_finish(0).is_err());
        echo.lane_abort(0);
    }

    #[test]
    fn quantized_engine_shrinks_the_peak_and_raises_the_admission_cap() {
        let g = crate::models::blazeface();
        let svc = PlanService::shared();
        let base = PlanRequest::new().with_strategy("greedy-size").unwrap();
        let f = ExecutorEngine::for_request(&g, Arc::clone(&svc), &base, 3).unwrap();
        let mut q = ExecutorEngine::for_request(
            &g,
            Arc::clone(&svc),
            &base.with_dtype(Dtype::I8),
            3,
        )
        .unwrap();
        // i8 plans a strictly smaller peak at the same batch...
        let pf = f.planned_peak(2).unwrap();
        let pq = q.planned_peak(2).unwrap();
        assert!(pq * 3 <= pf, "i8 peak {pq} must shrink the f32 peak {pf} by >=3x");
        // ...so the same budget admits a strictly larger batch — the
        // `serve --dtype i8 --mem-budget` acceptance property.
        let budget = f.planned_peak(3).unwrap();
        let cap_f = f.max_servable_batch(budget).unwrap();
        let cap_q = q.max_servable_batch(budget).unwrap();
        assert!(cap_f >= 3);
        assert!(cap_q > cap_f, "i8 cap {cap_q} must beat the f32 cap {cap_f} under {budget} B");
        // The stats line reports the size class; f32 serving stays clean.
        assert_eq!(q.arena_stats().dtype, "i8");
        assert!(f.arena_stats().dtype.is_empty());
        // The quantized engine still serves finite outputs.
        let x = vec![0.1f32; q.in_elems()];
        let out = q.run_batch(&x, 1).unwrap();
        assert_eq!(out.len(), q.out_elems());
        assert!(out.iter().all(|v| v.is_finite()));
        // Wave-aware and paged construction refuse quantized requests.
        let dec = g.num_ops() / 2;
        let qreq = base.with_dtype(Dtype::F16);
        let e = ExecutorEngine::for_request_dynamic(&g, Arc::clone(&svc), &qreq, dec, 3)
            .err()
            .expect("dynamic quantized construction must fail");
        assert!(e.to_string().contains("static-mode only"), "{e}");
        let e = ExecutorEngine::for_request_paged(&g, Arc::clone(&svc), &qreq, dec, 3)
            .err()
            .expect("paged quantized construction must fail");
        assert!(e.to_string().contains("static-mode only"), "{e}");
    }

    #[test]
    fn admission_decision_table_is_typed_and_policy_gated() {
        // 100 B/sample, 150 B resident budget, 250 B spill capacity:
        // batch 1 fits resident, batches 2..=4 fit resident + spillable,
        // batch 5 fits nothing.
        let e = EchoEngine::new(1, 8).with_peak_per_sample(100).with_spill_capacity(250);
        let b = Some(150);
        assert_eq!(e.admission(1, b, SpillPolicy::Refuse), AdmissionOutcome::Admit);
        assert_eq!(e.admission(1, b, SpillPolicy::Spill), AdmissionOutcome::Admit);
        // The default policy keeps today's refusal cliff bit-for-bit.
        assert_eq!(e.admission(2, b, SpillPolicy::Refuse), AdmissionOutcome::Refuse);
        assert_eq!(e.admission(2, b, SpillPolicy::Spill), AdmissionOutcome::Spill);
        assert_eq!(e.admission(4, b, SpillPolicy::Spill), AdmissionOutcome::Spill);
        assert_eq!(e.admission(5, b, SpillPolicy::Spill), AdmissionOutcome::Refuse);
        // No budget, or no footprint prediction: always admit (a budget
        // cannot bind what cannot be planned).
        assert_eq!(e.admission(8, None, SpillPolicy::Refuse), AdmissionOutcome::Admit);
        let blind = EchoEngine::new(1, 8);
        assert_eq!(blind.admission(8, b, SpillPolicy::Refuse), AdmissionOutcome::Admit);
        // An engine without a tier never spills, whatever the policy asks.
        let tierless = EchoEngine::new(1, 8).with_peak_per_sample(100);
        assert_eq!(tierless.admission(2, b, SpillPolicy::Spill), AdmissionOutcome::Refuse);
    }

    #[test]
    fn executor_engine_exposes_the_pool_spill_tier_and_block_cap() {
        use crate::arena::spill::SpillTier;
        let g = crate::models::blazeface();
        let svc = PlanService::shared();
        let mut e = ExecutorEngine::new(&g, Arc::clone(&svc), "greedy-size", 3).unwrap();
        assert_eq!(e.spill_capacity_bytes(), 0, "no tier configured yet");
        svc.pool().configure_spill(Arc::new(SpillTier::new()), 1 << 20);
        assert_eq!(e.spill_capacity_bytes(), usize::MAX, "tier capacity defaults unbounded");
        svc.pool().spill_tier().unwrap().set_capacity_bytes(4096);
        assert_eq!(e.spill_capacity_bytes(), 4096);
        e.set_block_shelf_cap(7);
        assert_eq!(svc.pool().blocks().shelf_cap(), 7);
    }

    #[test]
    fn executor_engine_reports_planned_peaks_for_budget_admission() {
        let g = crate::models::blazeface();
        let svc = PlanService::shared();
        let e = ExecutorEngine::new(&g, Arc::clone(&svc), "greedy-size", 3).unwrap();
        let p1 = e.planned_peak(1).unwrap();
        let p4 = e.planned_peak(4).unwrap();
        assert!(p4 > p1, "peak must grow with batch ({p1} vs {p4})");
        // The resolved cap fits the budget; the next batch would not.
        let cap = e.max_servable_batch(2 * p1).unwrap();
        assert!(cap >= 1);
        assert!(e.planned_peak(cap).unwrap() <= 2 * p1);
        assert!(e.planned_peak(cap + 1).unwrap() > 2 * p1);
        assert_eq!(e.max_servable_batch(p1 - 1), Some(0));
        // Engines without planning cannot answer, so budgets cannot bind.
        assert_eq!(EchoEngine::new(1, 4).planned_peak(2), None);
        assert_eq!(EchoEngine::new(1, 4).max_servable_batch(1024), None);
        let echo = EchoEngine::new(1, 4).with_peak_per_sample(100);
        assert_eq!(echo.planned_peak(3), Some(300));
        assert_eq!(echo.max_servable_batch(350), Some(3));
    }
}
