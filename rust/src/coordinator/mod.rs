//! Serving coordinator: request routing, dynamic and continuous batching,
//! metrics.
//!
//! The L3 layer of the stack. Inference requests enter through a
//! [`Router`], are queued per model, and answered over per-request
//! channels; Python never appears here. Per model one of two schedulers
//! runs on the worker thread:
//!
//! * **batch-and-drain** (the default): requests are gathered into batches
//!   bounded by size, deadline, and — under a `--mem-budget` — the planned
//!   arena peak, then executed whole on an [`engine::Engine`] (the PJRT
//!   executable for the AOT path, or the arena [`crate::exec::Executor`]
//!   for the pure-Rust path);
//! * **continuous** ([`BatchPolicy::continuous`], vLLM scheduling model):
//!   the worker owns an in-flight set of decode *lanes*, advances them
//!   wave by wave (§7), retires finished lanes at wave boundaries — their
//!   tail blocks return to the shared block pool — and admits queued
//!   requests into the vacated slots, so no request waits for a batch to
//!   drain. A bounded queue refuses overload with a typed
//!   [`ServeError::QueueFull`].
//!
//! The paper's planner shows up twice:
//! * the engine's working memory is a planned arena, reported per model in
//!   [`ArenaStats`] (the serving-visible version of Tables 1–2);
//! * batch-size variants multiply every intermediate tensor by the batch,
//!   so plan quality directly bounds the largest servable batch on a
//!   memory-constrained edge box.
//!
//! Built on `std::thread` + `mpsc` (the offline vendored registry has no
//! tokio); one worker thread per model keeps the design identical to an
//! async runtime with a single-consumer queue.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod router;

pub use batcher::{BatchPolicy, ModelServer};
pub use engine::{EchoEngine, Engine, ExecutorEngine};
pub use metrics::{render_arena_stats, Metrics, MetricsSnapshot};
pub use router::Router;

use std::time::Instant;

/// What admission does with a request whose planned peak exceeds the
/// resident budget (`serve --spill-policy`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum SpillPolicy {
    /// Refuse over-budget work with a typed [`ServeError::BudgetExceeded`]
    /// — today's behavior, bit-for-bit (the default).
    #[default]
    Refuse,
    /// Admit work that fits `resident + spill capacity`: cold pool buffers
    /// are evicted into the compressed spill tier and demand-reloaded, so
    /// the budget boundary degrades into reload stalls instead of a
    /// refusal cliff.
    Spill,
}

impl SpillPolicy {
    /// Parse a `--spill-policy` argument (`"refuse"` / `"spill"`).
    pub fn parse(s: &str) -> Option<SpillPolicy> {
        match s {
            "refuse" => Some(SpillPolicy::Refuse),
            "spill" => Some(SpillPolicy::Spill),
            _ => None,
        }
    }
}

/// Typed admission decision for one batch size under a memory budget —
/// what [`engine::Engine::admission`] resolves a `(batch, budget, policy)`
/// triple into.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionOutcome {
    /// The planned peak fits the resident budget: serve from the resident
    /// arena as always.
    Admit,
    /// Over the resident budget but within `resident + spill capacity`
    /// under [`SpillPolicy::Spill`]: serve by demand-reloading through the
    /// spill tier.
    Spill,
    /// Does not fit even the elastic bound (or the policy is
    /// [`SpillPolicy::Refuse`]): refuse typed.
    Refuse,
}

/// Typed serving failure — what a [`Request`] can be refused with.
///
/// Budget-driven admission (MAFAT-style) depends on the refusal being
/// machine-readable: a client that receives [`ServeError::BudgetExceeded`]
/// can re-shard its burst below the reported budget instead of parsing a
/// message string.
///
/// # Example
///
/// ```
/// use tensorarena::coordinator::{BatchPolicy, EchoEngine, ModelServer, ServeError};
///
/// // Planned peak 100 B/sample under a 250 B budget: at most 2 samples
/// // fit, so a pre-batched burst of 4 is refused — typed, never OOMed.
/// let server = ModelServer::spawn(
///     || Box::new(EchoEngine::new(1, 8).with_peak_per_sample(100)),
///     BatchPolicy { mem_budget: Some(250), ..BatchPolicy::default() },
/// )
/// .expect("spawn");
/// match server.submit(vec![0.0; 4]).recv().unwrap() {
///     Err(ServeError::BudgetExceeded { batch, budget_bytes, .. }) => {
///         assert_eq!((batch, budget_bytes), (4, 250));
///     }
///     other => panic!("expected a typed refusal, got {other:?}"),
/// }
/// server.shutdown();
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Input length is not a non-zero multiple of the model's per-sample
    /// arity.
    BadInput {
        /// Elements submitted.
        got: usize,
        /// Elements per sample the model expects.
        expect: usize,
    },
    /// No model registered under this name.
    UnknownModel(String),
    /// The batch's planned arena peak does not fit the server's byte
    /// budget — the admission refusal that replaces an OOM.
    BudgetExceeded {
        /// Samples in the refused batch.
        batch: usize,
        /// Planned arena bytes of the smallest over-budget batch — a lower
        /// bound on what `batch` would need. (The refusal path never plans
        /// the client-chosen size itself.)
        planned_bytes: usize,
        /// The server's configured budget.
        budget_bytes: usize,
    },
    /// A pre-batched request larger than the server's batch cap (the cap
    /// was policy- or engine-bound, not budget-bound).
    BatchTooLarge {
        /// Samples in the refused request.
        batch: usize,
        /// Largest admissible batch.
        cap: usize,
    },
    /// The continuous scheduler's bounded queue is full — the backpressure
    /// refusal that replaces unbounded backlog growth. A client seeing
    /// this retries later (or against a replica); the drain worker never
    /// produces it.
    QueueFull {
        /// Configured queue depth ([`BatchPolicy::queue_depth`]) that the
        /// backlog had already reached.
        depth: usize,
    },
    /// The server could not be constructed: the engine factory panicked,
    /// or the policy is incompatible with the engine (e.g. `continuous`
    /// over an engine without lane support). Returned by
    /// [`ModelServer::spawn`] / [`Router::register`], never by `submit`.
    Spawn(String),
    /// A model is already registered under this name. Replacing a live
    /// server (and its in-flight requests) must be explicit — see
    /// [`Router::replace`].
    AlreadyRegistered(String),
    /// The engine failed while executing the batch.
    Engine(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::BadInput { got, expect } => {
                write!(f, "input has {got} elems, model wants a non-zero multiple of {expect}")
            }
            ServeError::UnknownModel(m) => write!(f, "unknown model '{m}'"),
            ServeError::BudgetExceeded { batch, planned_bytes, budget_bytes } => write!(
                f,
                "batch {batch} needs at least {planned_bytes} planned bytes, over the {budget_bytes}-byte budget"
            ),
            ServeError::BatchTooLarge { batch, cap } => {
                write!(f, "batch {batch} exceeds the server's cap of {cap}")
            }
            ServeError::QueueFull { depth } => {
                write!(f, "server queue is full ({depth} requests already waiting)")
            }
            ServeError::Spawn(e) => write!(f, "server spawn failed: {e}"),
            ServeError::AlreadyRegistered(m) => {
                write!(f, "model '{m}' is already registered; replacement must be explicit")
            }
            ServeError::Engine(e) => write!(f, "engine error: {e}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Planner-derived memory accounting for a served model, including the
/// plan-cache and arena-pool reuse counters of the [`PlanService`] behind
/// the engine (the serving-visible version of Tables 1–2).
///
/// [`PlanService`]: crate::planner::PlanService
#[derive(Debug, Clone, Default)]
pub struct ArenaStats {
    /// Arena bytes under the configured strategy.
    pub planned_bytes: usize,
    /// Bytes the Naive plan would need.
    pub naive_bytes: usize,
    /// Strategy name.
    pub strategy: String,
    /// Plan-cache hits (planner invocations avoided).
    pub cache_hits: u64,
    /// Plan-cache misses (planner invocations).
    pub cache_misses: u64,
    /// Arena buffers recycled from the pool.
    pub pool_reused: u64,
    /// Arena buffers freshly allocated.
    pub pool_allocated: u64,
    /// Plans warm-started from a plan directory (planner invocations a
    /// restart avoided).
    pub warm_loaded: u64,
    /// Plan-directory files skipped at warm start for a suspect reason
    /// (corrupt, truncated, or stale-strategy — never served, never
    /// fatal; foreign and stale-order files are not counted here).
    pub warm_skipped: u64,
    /// Canonical key of the execution order the served plan was produced
    /// under (empty when the engine does not plan orders).
    pub order: String,
    /// §5.1 max operator breadth under the natural (stored) order.
    pub natural_breadth: usize,
    /// Max operator breadth under the served order — ≤ `natural_breadth`
    /// for annealed orders (annealing only accepts improvements).
    pub order_breadth: usize,
    /// Planner passes of the served §7 multi-pass plan (0 = static
    /// serving; the `planned_bytes` of a dynamic engine is the worst-wave
    /// peak).
    pub waves: usize,
    /// Wave-boundary offset re-resolutions the engine performed (each one
    /// a decode-step plan-cache lookup).
    pub wave_resolutions: u64,
    /// Dynamic plan-cache hits (decode-step re-plans answered with zero
    /// planner invocations).
    pub dynamic_hits: u64,
    /// Dynamic plan-cache misses (multi-pass planner invocations).
    pub dynamic_misses: u64,
    /// Worker threads the engine's executor runs with (1 = sequential).
    pub threads: usize,
    /// Dataflow depth of the served graph (level sets in the parallel
    /// schedule; 0 when the engine does not schedule levels).
    pub levels: usize,
    /// Op executions the executor dispatched to parallel workers.
    pub ops_parallel: u64,
    /// Peak decode-tail blocks the shared [`BlockPool`] served at once
    /// (0 = the engine does not page its decode tail).
    ///
    /// [`BlockPool`]: crate::arena::paged::BlockPool
    pub blocks_in_use: u64,
    /// Internal fragmentation at the block peak: the fraction of the
    /// paged footprint that was round-up slack rather than live tensor
    /// words (0.0 when nothing was paged).
    pub fragmentation: f64,
    /// Arena buffers the pool refused to keep at release time because the
    /// size-class shelf was full (dropped on the floor, not leaked).
    pub pool_dropped: u64,
    /// Canonical key of the quantized element size class the model serves
    /// under (`"i8"` / `"f16"`; empty = ordinary f32 serving). The
    /// `planned_bytes` of a quantized engine already reflect the shrunk
    /// records — see [`crate::records::UsageRecords::scaled_for`].
    pub dtype: String,
    /// Pool buffers evicted into the compressed spill tier (0 with no
    /// tier configured — the segment renders only with spill traffic).
    pub spill_evictions: u64,
    /// Pool buffers demand-reloaded out of the spill tier.
    pub spill_reloads: u64,
    /// Raw bytes of everything evicted so far (before compression).
    pub spill_bytes_before: u64,
    /// Stored bytes of everything evicted so far (after compression) —
    /// `before / after` is the compression ratio the metrics line prints.
    pub spill_bytes_after: u64,
    /// 99th-percentile spill reload stall, microseconds (sampled into the
    /// same bounded reservoir as serving latencies).
    pub spill_stall_p99_us: u64,
}

impl ArenaStats {
    /// Accounting line for a served model: footprint numbers plus the
    /// shared [`PlanService`]'s reuse counters — the one way counters flow
    /// from the planner layer into serving stats.
    ///
    /// [`PlanService`]: crate::planner::PlanService
    pub fn from_service(
        planned_bytes: usize,
        naive_bytes: usize,
        strategy: impl Into<String>,
        service: crate::planner::PlanServiceStats,
    ) -> Self {
        ArenaStats {
            planned_bytes,
            naive_bytes,
            strategy: strategy.into(),
            cache_hits: service.cache_hits,
            cache_misses: service.cache_misses,
            pool_reused: service.pool_reused,
            pool_allocated: service.pool_allocated,
            warm_loaded: service.warm_loaded,
            warm_skipped: service.warm_skipped,
            dynamic_hits: service.dynamic_hits,
            dynamic_misses: service.dynamic_misses,
            pool_dropped: service.pool_dropped,
            spill_evictions: service.spill_evictions,
            spill_reloads: service.spill_reloads,
            spill_bytes_before: service.spill_bytes_before,
            spill_bytes_after: service.spill_bytes_after,
            spill_stall_p99_us: service.spill_stall_p99_us,
            ..ArenaStats::default()
        }
    }

    /// Record that the served plan is a §7 multi-pass plan: how many waves
    /// it planned and how many wave-boundary re-resolutions the engine has
    /// performed. `planned_bytes` is then read as the worst-wave peak.
    pub fn with_waves(mut self, waves: usize, wave_resolutions: u64) -> Self {
        self.waves = waves;
        self.wave_resolutions = wave_resolutions;
        self
    }

    /// Record that the engine pages its decode tail through the shared
    /// block pool: the peak number of blocks in use at once and the
    /// internal fragmentation measured at that peak. `planned_bytes` is
    /// then read as prefix peak + tail block demand.
    pub fn with_paged(mut self, blocks_in_use: u64, fragmentation: f64) -> Self {
        self.blocks_in_use = blocks_in_use;
        self.fragmentation = fragmentation;
        self
    }

    /// Record the execution order the served plan was produced under and
    /// its §5.1 breadth movement (see
    /// [`crate::planner::AppliedOrder`]).
    pub fn with_order(
        mut self,
        order: impl Into<String>,
        natural_breadth: usize,
        order_breadth: usize,
    ) -> Self {
        self.order = order.into();
        self.natural_breadth = natural_breadth;
        self.order_breadth = order_breadth;
        self
    }

    /// Record the parallel-execution shape of the serving engine: worker
    /// threads, dataflow depth, and ops dispatched to workers so far.
    pub fn with_threads(mut self, threads: usize, levels: usize, ops_parallel: u64) -> Self {
        self.threads = threads;
        self.levels = levels;
        self.ops_parallel = ops_parallel;
        self
    }

    /// Record the quantized element size class the model serves under
    /// ([`Dtype::F32`] clears the field — f32 serving renders no segment).
    ///
    /// [`Dtype::F32`]: crate::planner::Dtype::F32
    pub fn with_dtype(mut self, dtype: crate::planner::Dtype) -> Self {
        self.dtype = if dtype == crate::planner::Dtype::F32 {
            String::new()
        } else {
            dtype.key().to_string()
        };
        self
    }

    /// Bytes the served order shaved off the §5.1 lower bound (negative =
    /// regression; 0 for the natural order).
    pub fn breadth_delta(&self) -> i64 {
        self.natural_breadth as i64 - self.order_breadth as i64
    }

    /// Naive / planned — the paper's headline ratio.
    pub fn reduction(&self) -> f64 {
        if self.planned_bytes == 0 {
            1.0
        } else {
            self.naive_bytes as f64 / self.planned_bytes as f64
        }
    }

    /// Plan-cache hits / lookups, or 0.0 before the first lookup.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }
}

/// One inference request travelling through the coordinator.
pub struct Request {
    /// Flat input: one sample, or a client-side pre-batched burst of `k`
    /// concatenated samples (the length must be a non-zero multiple of the
    /// model's per-sample arity). A pre-batched burst is admitted or
    /// refused as a unit — it is never split across engine batches.
    pub input: Vec<f32>,
    /// Enqueue timestamp, for queue-wait metrics.
    pub enqueued: Instant,
    /// Response channel.
    pub resp: std::sync::mpsc::Sender<Response>,
}

/// The answer to a [`Request`].
pub type Response = Result<Vec<f32>, ServeError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_stats_reduction() {
        let s = ArenaStats {
            planned_bytes: 10,
            naive_bytes: 75,
            strategy: "x".into(),
            ..ArenaStats::default()
        };
        assert!((s.reduction() - 7.5).abs() < 1e-12);
        assert_eq!(ArenaStats::default().reduction(), 1.0);
        assert_eq!(s.cache_hit_rate(), 0.0);
        let t = ArenaStats { cache_hits: 3, cache_misses: 1, ..ArenaStats::default() };
        assert!((t.cache_hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn serve_error_display_carries_the_numbers() {
        let e = ServeError::BudgetExceeded { batch: 8, planned_bytes: 4096, budget_bytes: 1024 };
        let s = e.to_string();
        assert!(s.contains("batch 8"), "{s}");
        assert!(s.contains("4096"), "{s}");
        assert!(s.contains("1024-byte budget"), "{s}");
        assert!(ServeError::UnknownModel("x".into()).to_string().contains("unknown model 'x'"));
        let q = ServeError::QueueFull { depth: 64 }.to_string();
        assert!(q.contains("64 requests already waiting"), "{q}");
        assert!(ServeError::Spawn("boom".into()).to_string().contains("spawn failed: boom"));
        let a = ServeError::AlreadyRegistered("m".into()).to_string();
        assert!(a.contains("'m' is already registered"), "{a}");
    }
}
