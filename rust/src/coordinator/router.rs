//! Multi-model routing: name → [`ModelServer`].

use super::{BatchPolicy, Engine, ModelServer, Response};
use std::collections::HashMap;
use std::sync::mpsc::Receiver;

/// Routes requests to per-model servers (the leader's front door).
#[derive(Default)]
pub struct Router {
    servers: HashMap<String, ModelServer>,
}

impl Router {
    /// Empty router.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a model under `name`, spawning its worker. The factory runs
    /// on the worker thread (see [`ModelServer::spawn`]).
    pub fn register<F>(&mut self, name: impl Into<String>, factory: F, policy: BatchPolicy)
    where
        F: FnOnce() -> Box<dyn Engine> + Send + 'static,
    {
        self.servers.insert(name.into(), ModelServer::spawn(factory, policy));
    }

    /// Route one request. Unknown models answer immediately with an error.
    pub fn submit(&self, model: &str, input: Vec<f32>) -> Receiver<Response> {
        match self.servers.get(model) {
            Some(s) => s.submit(input),
            None => {
                let (tx, rx) = std::sync::mpsc::channel();
                let _ = tx.send(Err(format!("unknown model '{model}'")));
                rx
            }
        }
    }

    /// Access a model's server (metrics, stats).
    pub fn server(&self, model: &str) -> Option<&ModelServer> {
        self.servers.get(model)
    }

    /// Registered model names, sorted.
    pub fn models(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.servers.keys().map(String::as_str).collect();
        v.sort_unstable();
        v
    }

    /// Shut every server down, draining queues.
    pub fn shutdown(self) {
        for (_, s) in self.servers {
            s.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EchoEngine;

    #[test]
    fn routes_by_name() {
        let mut r = Router::new();
        r.register("a", || Box::new(EchoEngine::new(1, 4)), BatchPolicy::default());
        r.register("b", || Box::new(EchoEngine::new(2, 4)), BatchPolicy::default());
        assert_eq!(r.models(), vec!["a", "b"]);
        assert_eq!(r.submit("a", vec![3.0]).recv().unwrap().unwrap(), vec![6.0]);
        assert_eq!(
            r.submit("b", vec![1.0, 2.0]).recv().unwrap().unwrap(),
            vec![2.0, 4.0]
        );
        r.shutdown();
    }

    #[test]
    fn unknown_model_errors() {
        let r = Router::new();
        let resp = r.submit("ghost", vec![1.0]).recv().unwrap();
        assert!(resp.unwrap_err().contains("unknown model"));
    }
}
