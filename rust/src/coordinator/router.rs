//! Multi-model routing: name → [`ModelServer`].

use super::{BatchPolicy, Engine, ModelServer, Response, ServeError};
use std::collections::HashMap;
use std::sync::mpsc::Receiver;

/// Routes requests to per-model servers (the leader's front door).
#[derive(Default)]
pub struct Router {
    servers: HashMap<String, ModelServer>,
}

impl Router {
    /// Empty router.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a model under `name`, spawning its worker. The factory runs
    /// on the worker thread (see [`ModelServer::spawn`]).
    ///
    /// Refuses a `name` that is already registered with
    /// [`ServeError::AlreadyRegistered`]: the old behavior Drop-joined the
    /// live server mid-registration, stranding its in-flight requests.
    /// Deliberate swaps go through [`Router::replace`]. Spawn failures
    /// (panicking factory, incompatible policy) pass through as
    /// [`ServeError::Spawn`].
    pub fn register<F>(
        &mut self,
        name: impl Into<String>,
        factory: F,
        policy: BatchPolicy,
    ) -> Result<(), ServeError>
    where
        F: FnOnce() -> Box<dyn Engine> + Send + 'static,
    {
        let name = name.into();
        if self.servers.contains_key(&name) {
            return Err(ServeError::AlreadyRegistered(name));
        }
        let server = ModelServer::spawn(factory, policy)?;
        self.servers.insert(name, server);
        Ok(())
    }

    /// Replace the server under `name`, returning the previous one (still
    /// live) for the caller to drain on its own schedule — typically
    /// [`ModelServer::shutdown`] after the cut-over. The new server spawns
    /// *before* the old one is unhooked, so a spawn failure leaves the old
    /// registration serving untouched. `Ok(None)` means nothing was
    /// registered under `name` (a plain registration).
    pub fn replace<F>(
        &mut self,
        name: impl Into<String>,
        factory: F,
        policy: BatchPolicy,
    ) -> Result<Option<ModelServer>, ServeError>
    where
        F: FnOnce() -> Box<dyn Engine> + Send + 'static,
    {
        let server = ModelServer::spawn(factory, policy)?;
        Ok(self.servers.insert(name.into(), server))
    }

    /// Route one request. Unknown models answer immediately with an error.
    pub fn submit(&self, model: &str, input: Vec<f32>) -> Receiver<Response> {
        match self.servers.get(model) {
            Some(s) => s.submit(input),
            None => {
                let (tx, rx) = std::sync::mpsc::channel();
                let _ = tx.send(Err(ServeError::UnknownModel(model.to_string())));
                rx
            }
        }
    }

    /// Access a model's server (metrics, stats).
    pub fn server(&self, model: &str) -> Option<&ModelServer> {
        self.servers.get(model)
    }

    /// Registered model names, sorted.
    pub fn models(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.servers.keys().map(String::as_str).collect();
        v.sort_unstable();
        v
    }

    /// Shut every server down, draining queues.
    pub fn shutdown(self) {
        for (_, s) in self.servers {
            s.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EchoEngine;

    #[test]
    fn routes_by_name() {
        let mut r = Router::new();
        r.register("a", || Box::new(EchoEngine::new(1, 4)), BatchPolicy::default())
            .expect("register a");
        r.register("b", || Box::new(EchoEngine::new(2, 4)), BatchPolicy::default())
            .expect("register b");
        assert_eq!(r.models(), vec!["a", "b"]);
        assert_eq!(r.submit("a", vec![3.0]).recv().unwrap().unwrap(), vec![6.0]);
        assert_eq!(
            r.submit("b", vec![1.0, 2.0]).recv().unwrap().unwrap(),
            vec![2.0, 4.0]
        );
        r.shutdown();
    }

    #[test]
    fn unknown_model_errors() {
        let r = Router::new();
        let resp = r.submit("ghost", vec![1.0]).recv().unwrap();
        assert_eq!(resp.unwrap_err(), ServeError::UnknownModel("ghost".into()));
    }

    #[test]
    fn duplicate_registration_is_refused_and_replacement_is_explicit() {
        // Regression: register used to silently Drop-join the live server
        // under the same name, stranding its in-flight requests.
        let mut r = Router::new();
        r.register("m", || Box::new(EchoEngine::new(1, 4)), BatchPolicy::default())
            .expect("register");
        let dup = r.register("m", || Box::new(EchoEngine::new(1, 4)), BatchPolicy::default());
        assert_eq!(dup.unwrap_err(), ServeError::AlreadyRegistered("m".into()));
        // The original server is untouched by the refused registration.
        assert_eq!(r.submit("m", vec![3.0]).recv().unwrap().unwrap(), vec![6.0]);

        // Explicit replacement hands the old server back, still able to
        // answer; the name now routes to the replacement (arity 2).
        let old = r
            .replace("m", || Box::new(EchoEngine::new(2, 4)), BatchPolicy::default())
            .expect("replace")
            .expect("an old server was registered");
        assert_eq!(old.submit(vec![5.0]).recv().unwrap().unwrap(), vec![10.0]);
        old.shutdown();
        assert_eq!(r.submit("m", vec![1.0, 2.0]).recv().unwrap().unwrap(), vec![2.0, 4.0]);

        // Replacing an unregistered name is a plain registration.
        let none = r
            .replace("fresh", || Box::new(EchoEngine::new(1, 4)), BatchPolicy::default())
            .expect("replace fresh");
        assert!(none.is_none());
        r.shutdown();
    }

    #[test]
    fn serving_same_model_twice_plans_once() {
        // Acceptance: two served replicas of one model at the same batch
        // size share a single planner invocation through the PlanService.
        use crate::coordinator::engine::ExecutorEngine;
        use crate::planner::PlanService;
        use std::sync::Arc;

        let svc = PlanService::shared();
        let mut r = Router::new();
        for name in ["blaze-a", "blaze-b"] {
            let svc = Arc::clone(&svc);
            r.register(
                name,
                move || {
                    let g = crate::models::blazeface();
                    Box::new(ExecutorEngine::new(&g, svc, "greedy-size", 7).expect("engine"))
                },
                BatchPolicy {
                    max_batch: 1,
                    max_wait: std::time::Duration::from_micros(10),
                    ..BatchPolicy::default()
                },
            )
            .expect("register");
        }
        let in_elems = crate::models::blazeface()
            .tensor(crate::models::blazeface().inputs[0])
            .num_elements();
        let x = vec![0.1f32; in_elems];
        let a = r.submit("blaze-a", x.clone()).recv().unwrap().unwrap();
        let b = r.submit("blaze-b", x).recv().unwrap().unwrap();
        assert_eq!(a, b, "replicas disagree");
        let st = svc.stats();
        assert_eq!(st.cache_misses, 1, "replica re-ran the planner");
        assert_eq!(st.cache_hits, 1);
        r.shutdown();
    }
}
