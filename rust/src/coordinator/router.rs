//! Multi-model routing: name → [`ModelServer`].

use super::{BatchPolicy, Engine, ModelServer, Response, ServeError};
use std::collections::HashMap;
use std::sync::mpsc::Receiver;

/// Routes requests to per-model servers (the leader's front door).
#[derive(Default)]
pub struct Router {
    servers: HashMap<String, ModelServer>,
}

impl Router {
    /// Empty router.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a model under `name`, spawning its worker. The factory runs
    /// on the worker thread (see [`ModelServer::spawn`]).
    pub fn register<F>(&mut self, name: impl Into<String>, factory: F, policy: BatchPolicy)
    where
        F: FnOnce() -> Box<dyn Engine> + Send + 'static,
    {
        self.servers.insert(name.into(), ModelServer::spawn(factory, policy));
    }

    /// Route one request. Unknown models answer immediately with an error.
    pub fn submit(&self, model: &str, input: Vec<f32>) -> Receiver<Response> {
        match self.servers.get(model) {
            Some(s) => s.submit(input),
            None => {
                let (tx, rx) = std::sync::mpsc::channel();
                let _ = tx.send(Err(ServeError::UnknownModel(model.to_string())));
                rx
            }
        }
    }

    /// Access a model's server (metrics, stats).
    pub fn server(&self, model: &str) -> Option<&ModelServer> {
        self.servers.get(model)
    }

    /// Registered model names, sorted.
    pub fn models(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.servers.keys().map(String::as_str).collect();
        v.sort_unstable();
        v
    }

    /// Shut every server down, draining queues.
    pub fn shutdown(self) {
        for (_, s) in self.servers {
            s.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EchoEngine;

    #[test]
    fn routes_by_name() {
        let mut r = Router::new();
        r.register("a", || Box::new(EchoEngine::new(1, 4)), BatchPolicy::default());
        r.register("b", || Box::new(EchoEngine::new(2, 4)), BatchPolicy::default());
        assert_eq!(r.models(), vec!["a", "b"]);
        assert_eq!(r.submit("a", vec![3.0]).recv().unwrap().unwrap(), vec![6.0]);
        assert_eq!(
            r.submit("b", vec![1.0, 2.0]).recv().unwrap().unwrap(),
            vec![2.0, 4.0]
        );
        r.shutdown();
    }

    #[test]
    fn unknown_model_errors() {
        let r = Router::new();
        let resp = r.submit("ghost", vec![1.0]).recv().unwrap();
        assert_eq!(resp.unwrap_err(), ServeError::UnknownModel("ghost".into()));
    }

    #[test]
    fn serving_same_model_twice_plans_once() {
        // Acceptance: two served replicas of one model at the same batch
        // size share a single planner invocation through the PlanService.
        use crate::coordinator::engine::ExecutorEngine;
        use crate::planner::PlanService;
        use std::sync::Arc;

        let svc = PlanService::shared();
        let mut r = Router::new();
        for name in ["blaze-a", "blaze-b"] {
            let svc = Arc::clone(&svc);
            r.register(
                name,
                move || {
                    let g = crate::models::blazeface();
                    Box::new(ExecutorEngine::new(&g, svc, "greedy-size", 7).expect("engine"))
                },
                BatchPolicy {
                    max_batch: 1,
                    max_wait: std::time::Duration::from_micros(10),
                    ..BatchPolicy::default()
                },
            );
        }
        let in_elems = crate::models::blazeface()
            .tensor(crate::models::blazeface().inputs[0])
            .num_elements();
        let x = vec![0.1f32; in_elems];
        let a = r.submit("blaze-a", x.clone()).recv().unwrap().unwrap();
        let b = r.submit("blaze-b", x).recv().unwrap().unwrap();
        assert_eq!(a, b, "replicas disagree");
        let st = svc.stats();
        assert_eq!(st.cache_misses, 1, "replica re-ran the planner");
        assert_eq!(st.cache_hits, 1);
        r.shutdown();
    }
}
