//! Execution-order optimization — the paper's §7.1 future work.
//!
//! "The operator index in tensor usage records and intervals are defined by
//! the topological sort of the neural network. Optimizing the sorting
//! algorithm for the smallest possible memory footprint is a potential
//! future research topic."
//!
//! Both §5.1's lower bound (max operator breadth) and the achievable arena
//! size depend on *which* topological order executes the graph: a branchy
//! graph (Inception) can hold both branches live (breadth = sum) or finish
//! one before starting the other (breadth = max + ε). This module explores
//! that order space:
//!
//! * [`memory_aware_order`] — a deterministic greedy scheduler: among ready
//!   ops, always run the one minimizing live-set growth (frees first, then
//!   smallest new allocation). This is the classic Sethi-style heuristic
//!   (the paper cites Sethi 1975 for NP-completeness of the underlying
//!   problem — exact optimization is hopeless, heuristics are the game).
//! * [`anneal_order`] — local search on top: randomized neighbour swaps of
//!   the priority ordering, keeping the best max-breadth found. Seeded and
//!   budgeted, so results are reproducible.
//! * [`reorder_graph`] — rebuild a `Graph` with ops renumbered into a given
//!   valid order, so the existing §4/§5 planners apply unchanged.
//! * [`apply_order`] — the serving entry point: resolve a registry
//!   [`OrderStrategy`] into a reordered graph plus an [`AppliedOrder`]
//!   receipt (the breadth delta `ArenaStats` reports). This is what makes
//!   ordering a first-class plan dimension rather than a bench toy.

use super::registry::OrderStrategy;
use crate::graph::{Graph, OpId, TensorKind};
use crate::records::UsageRecords;
use crate::rng::SplitMix64;

/// A candidate execution order (a permutation of op indices that respects
/// data dependencies).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExecutionOrder(pub Vec<OpId>);

/// The identity order — the stored (builder/TFLite) topological order.
pub fn natural_order(graph: &Graph) -> ExecutionOrder {
    ExecutionOrder((0..graph.ops.len()).map(OpId).collect())
}

/// Resolve a registry [`OrderStrategy`] into a concrete execution order.
pub fn compute_order(graph: &Graph, strategy: OrderStrategy) -> ExecutionOrder {
    match strategy {
        OrderStrategy::Natural => natural_order(graph),
        OrderStrategy::MemoryAware => memory_aware_order(graph),
        OrderStrategy::Annealed { seed, budget } => anneal_order(graph, seed, budget),
    }
}

/// Receipt of [`apply_order`]: which strategy was applied and how it moved
/// the §5.1 lower bound (max operator breadth).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AppliedOrder {
    /// The strategy that produced the order.
    pub strategy: OrderStrategy,
    /// Max operator breadth under the natural (stored) order.
    pub natural_breadth: usize,
    /// Max operator breadth under the applied order. Never exceeds
    /// `natural_breadth` for [`OrderStrategy::Annealed`] (annealing starts
    /// from the natural order and only accepts improvements).
    pub order_breadth: usize,
}

impl AppliedOrder {
    /// Canonical key of the applied order (see [`OrderStrategy::key`]).
    pub fn key(&self) -> String {
        self.strategy.key()
    }

    /// Bytes the order shaved off the §5.1 lower bound; negative means the
    /// order regressed it (possible for `memory-aware` on adversarial
    /// graphs, never for `annealed`).
    pub fn breadth_delta(&self) -> i64 {
        self.natural_breadth as i64 - self.order_breadth as i64
    }
}

/// Apply `strategy` to `graph`: compute the order, validate it, rebuild the
/// graph with ops renumbered into it, and report the breadth movement.
/// `Natural` is the identity (the graph is cloned, never reordered), so
/// record lifetimes — and plan fingerprints — are untouched.
pub fn apply_order(graph: &Graph, strategy: OrderStrategy) -> (Graph, AppliedOrder) {
    let natural_breadth = order_max_breadth(graph, &natural_order(graph));
    if strategy.is_natural() {
        let applied = AppliedOrder {
            strategy,
            natural_breadth,
            order_breadth: natural_breadth,
        };
        return (graph.clone(), applied);
    }
    let order = compute_order(graph, strategy);
    assert!(
        is_valid_order(graph, &order),
        "scheduler produced an invalid order for {}",
        graph.name
    );
    let order_breadth = order_max_breadth(graph, &order);
    let applied = AppliedOrder {
        strategy,
        natural_breadth,
        order_breadth,
    };
    (reorder_graph(graph, &order), applied)
}

/// Compute the max operator breadth (the §5.1 lower bound) a given valid
/// order would produce, without materializing a new graph.
pub fn order_max_breadth(graph: &Graph, order: &ExecutionOrder) -> usize {
    let pos = position_of(graph, order);
    // first/last positions per intermediate tensor under the new order.
    let mut first = vec![usize::MAX; graph.tensors.len()];
    let mut last = vec![0usize; graph.tensors.len()];
    for op in &graph.ops {
        let p = pos[op.id.0];
        for &t in op.inputs.iter().chain(op.outputs.iter()) {
            first[t.0] = first[t.0].min(p);
            last[t.0] = last[t.0].max(p);
        }
    }
    // Sweep breadth over positions: +size at first, -size after last.
    let n = graph.ops.len();
    let mut delta = vec![0isize; n + 1];
    for t in graph.intermediates() {
        if first[t.id.0] == usize::MAX {
            continue;
        }
        delta[first[t.id.0]] += t.aligned_size() as isize;
        delta[last[t.id.0] + 1] -= t.aligned_size() as isize;
    }
    let mut cur = 0isize;
    let mut max = 0isize;
    for d in delta.iter().take(n) {
        cur += d;
        max = max.max(cur);
    }
    max as usize
}

fn position_of(graph: &Graph, order: &ExecutionOrder) -> Vec<usize> {
    let mut pos = vec![usize::MAX; graph.ops.len()];
    for (p, op) in order.0.iter().enumerate() {
        pos[op.0] = p;
    }
    assert!(
        pos.iter().all(|&p| p != usize::MAX),
        "order must cover every op"
    );
    pos
}

/// Is `order` a valid topological order of `graph`?
pub fn is_valid_order(graph: &Graph, order: &ExecutionOrder) -> bool {
    if order.0.len() != graph.ops.len() {
        return false;
    }
    let pos = position_of(graph, order);
    let mut produced_at = vec![usize::MAX; graph.tensors.len()];
    for op in &graph.ops {
        for &o in &op.outputs {
            produced_at[o.0] = pos[op.id.0];
        }
    }
    for op in &graph.ops {
        for &i in &op.inputs {
            let t = graph.tensor(i);
            if matches!(t.kind, TensorKind::Input | TensorKind::Weight) {
                continue;
            }
            if produced_at[i.0] == usize::MAX || produced_at[i.0] >= pos[op.id.0] {
                return false;
            }
        }
    }
    true
}

/// Greedy memory-aware topological order: repeatedly pick the ready op with
/// the best `(live-set delta, tie: op index)`. The delta counts bytes the
/// op frees (tensors whose last consumer it is) minus bytes it allocates
/// (its outputs).
pub fn memory_aware_order(graph: &Graph) -> ExecutionOrder {
    schedule(graph, |scores| {
        // pick min (delta, op index)
        scores
            .iter()
            .min_by_key(|&&(op, delta)| (delta, op))
            .map(|&(op, _)| op)
            .unwrap()
    })
}

/// Generic list scheduler: maintains the ready set, lets `pick` choose.
fn schedule<F>(graph: &Graph, mut pick: F) -> ExecutionOrder
where
    F: FnMut(&[(usize, isize)]) -> usize,
{
    let n = graph.ops.len();
    // consumers[t] = ops reading intermediate t; remaining input counts.
    let mut remaining_inputs = vec![0usize; n];
    let mut consumers_of: Vec<Vec<usize>> = vec![Vec::new(); graph.tensors.len()];
    let mut producer = vec![usize::MAX; graph.tensors.len()];
    for op in &graph.ops {
        for &o in &op.outputs {
            producer[o.0] = op.id.0;
        }
    }
    for op in &graph.ops {
        for &i in &op.inputs {
            let t = graph.tensor(i);
            if matches!(t.kind, TensorKind::Input | TensorKind::Weight) {
                continue;
            }
            consumers_of[i.0].push(op.id.0);
            remaining_inputs[op.id.0] += 1;
        }
    }
    // reads_left[t] = consumers not yet scheduled (for free accounting).
    let mut reads_left: Vec<usize> = consumers_of.iter().map(Vec::len).collect();

    let mut ready: Vec<usize> = (0..n).filter(|&i| remaining_inputs[i] == 0).collect();
    let mut order = Vec::with_capacity(n);
    let mut scheduled = vec![false; n];
    while !ready.is_empty() {
        // Score each ready op: outputs allocated minus inputs it frees.
        let scores: Vec<(usize, isize)> = ready
            .iter()
            .map(|&opi| {
                let op = &graph.ops[opi];
                let alloc: isize = op
                    .outputs
                    .iter()
                    .map(|&o| graph.tensor(o).aligned_size() as isize)
                    .sum();
                let freed: isize = op
                    .inputs
                    .iter()
                    .filter(|&&i| {
                        graph.tensor(i).kind == TensorKind::Intermediate && reads_left[i.0] == 1
                    })
                    .map(|&i| graph.tensor(i).aligned_size() as isize)
                    .sum();
                (opi, alloc - freed)
            })
            .collect();
        let chosen = pick(&scores);
        ready.retain(|&o| o != chosen);
        scheduled[chosen] = true;
        order.push(OpId(chosen));
        let op = &graph.ops[chosen];
        for &i in &op.inputs {
            if graph.tensor(i).kind == TensorKind::Intermediate {
                reads_left[i.0] = reads_left[i.0].saturating_sub(1);
            }
        }
        for &o in &op.outputs {
            for &c in &consumers_of[o.0] {
                remaining_inputs[c] -= 1;
                if remaining_inputs[c] == 0 && !scheduled[c] {
                    ready.push(c);
                }
            }
        }
    }
    assert_eq!(order.len(), n, "graph has a cycle");
    ExecutionOrder(order)
}

/// Randomized local search over orders: start from the better of the
/// natural and [`memory_aware_order`] starts, propose random ready-op
/// choices, keep the best max-breadth. `budget` is the number of random
/// schedules tried.
///
/// Seeding from the *natural* order (not just the greedy one) guarantees
/// the result never has a higher max breadth than the stored order — the
/// invariant the ordering property tests and order-keyed serving rely on.
/// Deterministic: equal `(graph, seed, budget)` give byte-identical orders.
pub fn anneal_order(graph: &Graph, seed: u64, budget: usize) -> ExecutionOrder {
    let mut best = natural_order(graph);
    let mut best_cost = order_max_breadth(graph, &best);
    let greedy = memory_aware_order(graph);
    let greedy_cost = order_max_breadth(graph, &greedy);
    if greedy_cost < best_cost {
        best = greedy;
        best_cost = greedy_cost;
    }
    let mut rng = SplitMix64::new(seed);
    for _ in 0..budget {
        // ε-greedy randomized scheduler: mostly greedy, sometimes random.
        let cand = schedule(graph, |scores| {
            if rng.next_below(100) < 20 {
                scores[rng.next_below(scores.len())].0
            } else {
                scores
                    .iter()
                    .min_by_key(|&&(op, delta)| (delta, op))
                    .map(|&(op, _)| op)
                    .unwrap()
            }
        });
        let cost = order_max_breadth(graph, &cand);
        if cost < best_cost {
            best_cost = cost;
            best = cand;
        }
    }
    best
}

/// Rebuild the graph with ops renumbered to `order` (tensors keep their
/// ids), so every existing planner/record API applies to the new order.
pub fn reorder_graph(graph: &Graph, order: &ExecutionOrder) -> Graph {
    assert!(is_valid_order(graph, order), "invalid execution order");
    let mut g = graph.clone();
    g.ops = order
        .0
        .iter()
        .enumerate()
        .map(|(new_idx, &old)| {
            let mut op = graph.ops[old.0].clone();
            op.id = OpId(new_idx);
            op
        })
        .collect();
    g.validate().expect("reordered graph must stay valid");
    g
}

/// Convenience: arena footprint (offset Greedy by Size) under the stored
/// order vs the memory-aware order vs `budget` annealing trials.
pub fn order_ablation(graph: &Graph, seed: u64, budget: usize) -> (usize, usize, usize) {
    use crate::planner::offset::GreedyBySize;
    use crate::planner::OffsetPlanner;
    let base = GreedyBySize
        .plan(&UsageRecords::from_graph(graph))
        .total_size();
    let greedy_graph = reorder_graph(graph, &memory_aware_order(graph));
    let greedy = GreedyBySize
        .plan(&UsageRecords::from_graph(&greedy_graph))
        .total_size();
    let annealed_graph = reorder_graph(graph, &anneal_order(graph, seed, budget));
    let annealed = GreedyBySize
        .plan(&UsageRecords::from_graph(&annealed_graph))
        .total_size();
    (base, greedy, annealed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Activation, DType, GraphBuilder, Padding};
    use crate::models;

    #[test]
    fn identity_order_is_valid_and_matches_lower_bound() {
        let g = models::example_net();
        let order = ExecutionOrder((0..g.num_ops()).map(OpId).collect());
        assert!(is_valid_order(&g, &order));
        let recs = UsageRecords::from_graph(&g);
        assert_eq!(
            order_max_breadth(&g, &order),
            recs.profiles().offset_lower_bound()
        );
    }

    #[test]
    fn invalid_orders_are_rejected() {
        let g = models::example_net();
        let mut rev: Vec<OpId> = (0..g.num_ops()).map(OpId).collect();
        rev.reverse();
        assert!(!is_valid_order(&g, &ExecutionOrder(rev)));
        // too short
        assert!(!is_valid_order(&g, &ExecutionOrder(vec![OpId(0)])));
    }

    #[test]
    fn memory_aware_order_is_valid_on_the_zoo() {
        for g in models::all_zoo() {
            let order = memory_aware_order(&g);
            assert!(is_valid_order(&g, &order), "{}", g.name);
            let re = reorder_graph(&g, &order);
            assert!(re.validate().is_ok());
        }
    }

    /// A diamond where order matters: running branches serially keeps only
    /// one branch live at a time.
    fn diamond() -> crate::graph::Graph {
        let mut b = GraphBuilder::new("diamond", DType::F32);
        let x = b.input("x", vec![1, 8, 8, 4]);
        let stem = b.conv2d("stem", x, 4, (1, 1), (1, 1), Padding::Same, Activation::None);
        // two long branches
        let mut l = stem;
        for i in 0..3 {
            l = b.conv2d(format!("l{i}"), l, 4, (3, 3), (1, 1), Padding::Same, Activation::Relu);
        }
        let mut r = stem;
        for i in 0..3 {
            r = b.conv2d(format!("r{i}"), r, 4, (3, 3), (1, 1), Padding::Same, Activation::Relu);
        }
        let m = b.concat("merge", &[l, r]);
        b.mark_output(m);
        b.finish()
    }

    #[test]
    fn scheduler_never_worse_than_a_bad_interleaving() {
        let g = diamond();
        // Interleave branches manually: stem l0 r0 l1 r1 l2 r2 merge.
        let interleaved = ExecutionOrder(
            [0usize, 1, 4, 2, 5, 3, 6, 7].iter().map(|&i| OpId(i)).collect(),
        );
        assert!(is_valid_order(&g, &interleaved));
        let bad = order_max_breadth(&g, &interleaved);
        let good = order_max_breadth(&g, &memory_aware_order(&g));
        assert!(
            good <= bad,
            "memory-aware order {good} worse than interleaved {bad}"
        );
    }

    #[test]
    fn annealing_never_regresses_the_greedy_start() {
        for g in [models::example_net(), diamond(), models::blazeface()] {
            let greedy = order_max_breadth(&g, &memory_aware_order(&g));
            let ann = order_max_breadth(&g, &anneal_order(&g, 42, 50));
            assert!(ann <= greedy, "{}: {ann} > {greedy}", g.name);
        }
    }

    #[test]
    fn ablation_reports_consistent_triple() {
        let g = diamond();
        let (base, greedy, annealed) = order_ablation(&g, 7, 30);
        assert!(base > 0 && greedy > 0 && annealed > 0);
        assert!(annealed <= greedy.max(base));
    }

    #[test]
    fn apply_order_natural_is_the_identity() {
        let g = models::example_net();
        let (re, applied) = apply_order(&g, OrderStrategy::Natural);
        assert_eq!(applied.key(), "natural");
        assert_eq!(applied.natural_breadth, applied.order_breadth);
        assert_eq!(applied.breadth_delta(), 0);
        let a = UsageRecords::from_graph(&g);
        let b = UsageRecords::from_graph(&re);
        for (x, y) in a.records.iter().zip(b.records.iter()) {
            assert_eq!((x.first_op, x.last_op, x.size), (y.first_op, y.last_op, y.size));
        }
    }

    #[test]
    fn apply_order_annealed_never_regresses_the_natural_breadth() {
        for g in [models::example_net(), diamond(), models::blazeface()] {
            let (re, applied) = apply_order(
                &g,
                OrderStrategy::Annealed { seed: 11, budget: 30 },
            );
            assert!(re.validate().is_ok());
            assert!(
                applied.order_breadth <= applied.natural_breadth,
                "{}: {} > {}",
                g.name,
                applied.order_breadth,
                applied.natural_breadth
            );
            assert!(applied.breadth_delta() >= 0);
            // The reordered graph's own §5.1 lower bound is the reported one.
            let recs = UsageRecords::from_graph(&re);
            assert_eq!(recs.profiles().offset_lower_bound(), applied.order_breadth);
        }
    }

    #[test]
    fn reorder_preserves_planning_feasibility() {
        use crate::planner::{table2_strategies, OffsetPlanner};
        let g = models::posenet();
        let order = anneal_order(&g, 3, 10);
        let re = reorder_graph(&g, &order);
        let recs = UsageRecords::from_graph(&re);
        for strat in table2_strategies() {
            let plan = OffsetPlanner::plan(strat.as_ref(), &recs);
            plan.validate(&recs).unwrap();
        }
    }
}
