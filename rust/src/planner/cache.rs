//! Batch-aware plan cache: plan once per `(graph, batch, strategy, order)`,
//! reuse forever.
//!
//! The paper's arena is planned once and cheaply reused for every inference
//! (§5); serving multiplies that by batch-size variants and engine
//! replicas. The cache keys plans by the FNV-1a fingerprint of the usage
//! records (the planner's entire input), the batch the records are scaled
//! to, the registry strategy key, and the execution-order strategy the
//! records were extracted under, so two executors serving the same model at
//! the same batch share one `Arc<OffsetPlan>` and the planner runs exactly
//! once. The order is a key dimension in its own right: two orders that
//! happen to coincide (annealing found nothing) still occupy distinct
//! slots, so order-keyed persistence stays unambiguous.
//!
//! Plans can be spilled to / loaded from the [`super::serialize`] text
//! format (compute offline, ship with the model), and
//! [`PlanCache::max_servable_batch`] answers the serving-era question the
//! follow-up work (FlashMem, MAFAT) poses: what is the largest batch whose
//! *planned* footprint fits a byte budget?
//!
//! **Dynamic shapes** (§7) get their own cache dimension: multi-pass plans
//! are keyed by the fingerprint of the **resolved-size prefix** — the
//! static records plus the sizes known so far — so decode-step re-plans
//! with an unchanged prefix are cache hits with zero planner invocations
//! ([`PlanCache::get_or_plan_dynamic_resolved`]), and budget admission for
//! dynamic engines resolves under the worst-wave peak
//! ([`PlanCache::max_servable_batch_dynamic`]).

use super::dynamic::{DynamicRecords, MultiPassPlan, MultiPassPlanner};
use super::registry::OrderStrategy;
use super::serialize::{self, LoadError};
use super::{registry, OffsetPlan, PlanError};
use crate::records::UsageRecords;
use std::collections::{HashMap, VecDeque};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Errors from the plan cache / plan service.
#[derive(Debug)]
pub enum PlanServiceError {
    /// The strategy name is not in the registry.
    UnknownStrategy(String),
    /// The strategy produced an infeasible plan (a planner bug).
    Infeasible(PlanError),
    /// A spilled plan failed to load.
    Load(LoadError),
}

impl std::fmt::Display for PlanServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanServiceError::UnknownStrategy(s) => {
                write!(
                    f,
                    "unknown offset strategy '{s}' (known: {})",
                    registry::OFFSET_KEYS.join(", ")
                )
            }
            PlanServiceError::Infeasible(e) => write!(f, "strategy produced infeasible plan: {e}"),
            PlanServiceError::Load(e) => write!(f, "loading spilled plan: {e}"),
        }
    }
}

impl std::error::Error for PlanServiceError {}

/// Cache key: records fingerprint × batch × canonical strategy key ×
/// execution-order strategy.
type Key = (u64, usize, &'static str, OrderStrategy);

/// Dynamic-plan cache key: **resolved-size-prefix fingerprint** × batch ×
/// canonical strategy key × execution-order strategy. The fingerprint
/// ([`serialize::resolved_prefix_fingerprint`]) covers the op count, every
/// record's interval and `known_at`, and the sizes resolved so far — so
/// decode steps between wave boundaries, and any two sequences whose
/// resolved sizes agree, share one slot regardless of their (still
/// unknown) tails.
type DynamicKey = (u64, usize, &'static str, OrderStrategy);

/// Most dynamic (multi-pass) plans kept resident. Static cache keys are
/// bounded by the served model/batch/strategy set, but resolved-size
/// prefixes are unbounded by nature — every new sequence may resolve new
/// sizes — so without a bound a long-lived dynamic server would grow the
/// map forever. The dynamic slots are therefore a FIFO window: inserting
/// past the cap evicts the oldest entry (an evicted prefix simply costs
/// one re-plan if it ever recurs). A few thousand plans of a few KiB each
/// bound the cache to single-digit MiB while covering every
/// (boundary × batch) pair of realistic serving.
const DYNAMIC_PLAN_CAP: usize = 4096;

/// The FIFO-bounded dynamic plan slots (see [`DYNAMIC_PLAN_CAP`]).
#[derive(Default)]
struct DynamicSlots {
    plans: HashMap<DynamicKey, Arc<MultiPassPlan>>,
    /// Insertion order, oldest first; `fifo.len() == plans.len()`.
    fifo: VecDeque<DynamicKey>,
}

/// Outcome of [`PlanCache::warm_start`]: how many plan files seeded the
/// cache and why the rest were skipped. Skips are never fatal — a corrupt
/// file must cost a planner invocation, not a crashed server.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WarmStartReport {
    /// Plans loaded into the cache (planner invocations avoided).
    pub loaded: usize,
    /// Files whose fingerprint names a different record set (another
    /// model's plans sharing the directory) — left alone, not a defect.
    pub skipped_foreign: usize,
    /// Files naming a strategy no longer in the registry.
    pub skipped_stale_strategy: usize,
    /// Files written under a different execution order than the one this
    /// service serves — their record lifetimes (and therefore offsets) do
    /// not apply here. A directory written by an `annealed` server is
    /// skipped, counted, and left intact by a `natural` restart. Like
    /// foreign files, these belong to another valid serving configuration
    /// (fleets share directories), so they are not "suspect".
    pub skipped_stale_order: usize,
    /// Files that failed to parse or verify (truncated, checksum-corrupt,
    /// record-mismatched, unparseable or pre-bump-version name).
    pub skipped_corrupt: usize,
}

impl WarmStartReport {
    /// Everything skipped for a *suspect* reason (foreign and stale-order
    /// files belong to other valid configurations and are not suspect).
    pub fn skipped(&self) -> usize {
        self.skipped_stale_strategy + self.skipped_corrupt
    }
}

/// Outcome of [`PlanCache::persist_dir`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PersistReport {
    /// Plan files written (atomically) into the directory.
    pub written: usize,
    /// Resident plans that could not be serialized because their source
    /// records were not retained (not produced by this cache's miss/load
    /// paths).
    pub skipped: usize,
}

/// Thread-safe memoization of offset plans, keyed by
/// `(records fingerprint, batch, strategy, order)` — plus the §7 dynamic
/// slots keyed by the resolved-size prefix.
///
/// Lock order: `plans` before `records`, everywhere both are held.
///
/// # Example
///
/// ```
/// use tensorarena::planner::PlanCache;
/// use tensorarena::records::UsageRecords;
///
/// let records = UsageRecords::from_triples(&[(0, 1, 64), (1, 2, 128)]);
/// let cache = PlanCache::new();
/// let plan = cache.get_or_plan(&records, 4, "greedy-size").unwrap();
/// assert!(plan.total_size() <= 4 * records.naive_total());
/// assert_eq!((cache.misses(), cache.hits()), (1, 0));
/// cache.get_or_plan(&records, 4, "greedy-size").unwrap(); // cache hit
/// assert_eq!((cache.misses(), cache.hits()), (1, 1));
/// ```
#[derive(Default)]
pub struct PlanCache {
    plans: Mutex<HashMap<Key, Arc<OffsetPlan>>>,
    /// Batch-1 records per fingerprint — what [`Self::persist_dir`] needs
    /// to serialize a resident plan next to the records it plans.
    records: Mutex<HashMap<u64, UsageRecords>>,
    /// §7 multi-pass plans, keyed by the resolved-size prefix (see
    /// [`DynamicKey`]). In-memory only: dynamic plans are not persisted to
    /// the plan directory (their resolved sizes are transient by nature).
    dynamic: Mutex<DynamicSlots>,
    hits: AtomicU64,
    misses: AtomicU64,
    dynamic_hits: AtomicU64,
    dynamic_misses: AtomicU64,
    warm_loaded: AtomicU64,
    warm_skipped: AtomicU64,
}

impl PlanCache {
    /// Empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Cache hits so far.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cache misses (= planner invocations) so far.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Dynamic (multi-pass) plan-cache hits so far — decode-step re-plans
    /// answered with zero planner invocations.
    pub fn dynamic_hits(&self) -> u64 {
        self.dynamic_hits.load(Ordering::Relaxed)
    }

    /// Dynamic plan-cache misses (= multi-pass planner invocations) so far.
    pub fn dynamic_misses(&self) -> u64 {
        self.dynamic_misses.load(Ordering::Relaxed)
    }

    /// Plans seeded from a plan directory by [`Self::warm_start`] so far.
    pub fn warm_loaded(&self) -> u64 {
        self.warm_loaded.load(Ordering::Relaxed)
    }

    /// Plan-directory files skipped by [`Self::warm_start`] so far
    /// (corrupt, truncated, or stale-strategy; foreign files not counted).
    pub fn warm_skipped(&self) -> u64 {
        self.warm_skipped.load(Ordering::Relaxed)
    }

    /// Number of distinct plans resident.
    pub fn len(&self) -> usize {
        self.plans.lock().unwrap().len()
    }

    /// True if no plan has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn key(
        records: &UsageRecords,
        batch: usize,
        strategy: &str,
        order: OrderStrategy,
    ) -> Result<Key, PlanServiceError> {
        let key = registry::offset_key(strategy)
            .ok_or_else(|| PlanServiceError::UnknownStrategy(strategy.to_string()))?;
        Ok((serialize::records_fingerprint(records), batch, key, order))
    }

    /// [`Self::get_or_plan_ordered`] for the natural execution order.
    pub fn get_or_plan(
        &self,
        records: &UsageRecords,
        batch: usize,
        strategy: &str,
    ) -> Result<Arc<OffsetPlan>, PlanServiceError> {
        self.get_or_plan_ordered(records, batch, strategy, OrderStrategy::Natural)
    }

    /// The plan for `records` scaled to `batch` under `strategy`, planning
    /// (and validating) on first use. `records` are always the *batch-1*
    /// records — for a non-natural `order`, the records of the graph
    /// *reordered under that order* (the caller applies the order; the
    /// cache keys on it so coinciding orders cannot cross-contaminate
    /// persistence). Scaling is the cache's job so every caller agrees on
    /// the key. Planning happens under the cache lock, which guarantees
    /// exactly one planner invocation per key even under concurrent
    /// lookups.
    pub fn get_or_plan_ordered(
        &self,
        records: &UsageRecords,
        batch: usize,
        strategy: &str,
        order: OrderStrategy,
    ) -> Result<Arc<OffsetPlan>, PlanServiceError> {
        let key = Self::key(records, batch, strategy, order)?;
        let mut plans = self.plans.lock().unwrap();
        if let Some(plan) = plans.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(plan));
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let planner = registry::offset_strategy(key.2).expect("canonical key resolves");
        let scaled = records.scaled(batch);
        let plan = planner.plan(&scaled);
        plan.validate(&scaled).map_err(PlanServiceError::Infeasible)?;
        let plan = Arc::new(plan);
        plans.insert(key, Arc::clone(&plan));
        self.retain_records(key.0, records);
        Ok(plan)
    }

    /// [`Self::get_or_plan_dynamic_resolved`] with every wave resolved: the
    /// **complete** §7 multi-pass plan — what the wave-aware executor sizes
    /// its arena from and what budget admission resolves against (the plan's
    /// [`MultiPassPlan::peak`] is the worst-wave peak).
    pub fn get_or_plan_dynamic(
        &self,
        dynamic: &DynamicRecords,
        batch: usize,
        strategy: &str,
        order: OrderStrategy,
    ) -> Result<Arc<MultiPassPlan>, PlanServiceError> {
        self.get_or_plan_dynamic_resolved(dynamic, usize::MAX, batch, strategy, order)
    }

    /// The §7 multi-pass plan of the waves resolved once op
    /// `resolved_through` has executed, through the resolved-prefix-keyed
    /// cache slot. `dynamic` are the *batch-1* records of the (order-applied)
    /// graph; scaling to `batch` is the cache's job, exactly as for static
    /// plans.
    ///
    /// The slot key is the [`serialize::resolved_prefix_fingerprint`] — so
    /// successive decode steps with an unchanged resolved prefix (no wave
    /// boundary crossed, same resolved sizes) are **cache hits with zero
    /// planner invocations**, as are later sequences whose resolved sizes
    /// repeat; a step that resolves a new size (or a different value for a
    /// previously-seen wave — a stale prefix) misses and re-plans. Soundness
    /// rests on the freeze invariant (see [`super::dynamic`]): a prefix plan
    /// never depends on unresolved sizes, so slot sharing across sequences
    /// with different tails is exact, not approximate.
    ///
    /// Complete plans (every wave resolved) are validated against the final
    /// scaled records before being cached; prefix plans are covered by the
    /// freeze invariant (they are byte-identical prefixes of a validated
    /// complete plan). `strategy` namespaces the slot like the static cache
    /// key — within-wave placement itself is always Algorithm 3's
    /// size-descending best-fit. Dynamic plans live in memory only; they are
    /// never spilled to a plan directory.
    pub fn get_or_plan_dynamic_resolved(
        &self,
        dynamic: &DynamicRecords,
        resolved_through: usize,
        batch: usize,
        strategy: &str,
        order: OrderStrategy,
    ) -> Result<Arc<MultiPassPlan>, PlanServiceError> {
        let strategy_key = registry::offset_key(strategy)
            .ok_or_else(|| PlanServiceError::UnknownStrategy(strategy.to_string()))?;
        let fp = serialize::resolved_prefix_fingerprint(dynamic, resolved_through);
        let key: DynamicKey = (fp, batch, strategy_key, order);
        let mut slots = self.dynamic.lock().unwrap();
        if let Some(plan) = slots.plans.get(&key) {
            self.dynamic_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(plan));
        }
        self.dynamic_misses.fetch_add(1, Ordering::Relaxed);
        let scaled = dynamic.scaled(batch);
        let plan = MultiPassPlanner.plan_resolved(&scaled, resolved_through);
        if let Some(complete) = plan.offset_plan() {
            complete
                .validate(&scaled.final_records())
                .map_err(PlanServiceError::Infeasible)?;
        }
        let plan = Arc::new(plan);
        slots.plans.insert(key, Arc::clone(&plan));
        slots.fifo.push_back(key);
        if slots.fifo.len() > DYNAMIC_PLAN_CAP {
            if let Some(oldest) = slots.fifo.pop_front() {
                slots.plans.remove(&oldest);
            }
        }
        Ok(plan)
    }

    /// Largest batch whose **worst-wave** multi-pass peak fits
    /// `budget_bytes` — the §7 analogue of
    /// [`Self::max_servable_batch_ordered`]. Budget admission for a
    /// dynamic-shape engine must resolve against this peak, not the static
    /// plan, because mid-inference waves can only grow the arena.
    pub fn max_servable_batch_dynamic(
        &self,
        dynamic: &DynamicRecords,
        strategy: &str,
        budget_bytes: usize,
        order: OrderStrategy,
    ) -> Result<usize, PlanServiceError> {
        if registry::offset_key(strategy).is_none() {
            return Err(PlanServiceError::UnknownStrategy(strategy.to_string()));
        }
        let finals = dynamic.final_records();
        let max_size = finals.records.iter().map(|r| r.size).max().unwrap_or(0);
        max_batch_fitting(max_size, finals.naive_total(), budget_bytes, |b| {
            Ok(self
                .get_or_plan_dynamic(dynamic, b, strategy, order)?
                .peak
                <= budget_bytes)
        })
    }

    /// Remember the batch-1 records behind `fingerprint`, so
    /// [`Self::persist_dir`] can serialize this plan later. Caller may hold
    /// the `plans` lock (lock order: `plans` then `records`).
    fn retain_records(&self, fingerprint: u64, records: &UsageRecords) {
        self.records
            .lock()
            .unwrap()
            .entry(fingerprint)
            .or_insert_with(|| records.clone());
    }

    /// Serialize the plan for `(records, batch, strategy)` in the
    /// [`super::serialize`] text format (natural order), planning it first
    /// if not resident — ship the result next to the model and
    /// [`Self::load`] it at serve time.
    pub fn spill(
        &self,
        records: &UsageRecords,
        batch: usize,
        strategy: &str,
    ) -> Result<String, PlanServiceError> {
        let plan = self.get_or_plan(records, batch, strategy)?;
        Ok(serialize::offset_plan_to_string(&plan, &records.scaled(batch)))
    }

    /// [`Self::load_ordered`] for the natural execution order.
    pub fn load(
        &self,
        text: &str,
        records: &UsageRecords,
        batch: usize,
        strategy: &str,
    ) -> Result<Arc<OffsetPlan>, PlanServiceError> {
        self.load_ordered(text, records, batch, strategy, OrderStrategy::Natural)
    }

    /// Seed the cache from a previously spilled plan. The caller-supplied
    /// key is never trusted on its own: the record set embedded in the
    /// text is verified field by field — count, full id coverage (no
    /// dropped or duplicated lines), every `(size, first_op, last_op)` —
    /// against `records.scaled(batch)`, which is exactly the fingerprint
    /// input, plus checksum, feasibility, and (v2) the canonical order key
    /// in the header, which must match `order`. A plan spilled for one
    /// model, another batch, or another execution order can therefore
    /// never be filed under this key.
    ///
    /// The text format carries no strategy tag, so the caller's `strategy`
    /// names the slot the plan is filed under — loading a spill produced by
    /// a different strategy is not detectable (it is still a *valid* plan,
    /// just not that strategy's); keep spill files per strategy.
    pub fn load_ordered(
        &self,
        text: &str,
        records: &UsageRecords,
        batch: usize,
        strategy: &str,
        order: OrderStrategy,
    ) -> Result<Arc<OffsetPlan>, PlanServiceError> {
        let key = Self::key(records, batch, strategy, order)?;
        let scaled = records.scaled(batch);
        let plan = Arc::new(
            serialize::offset_plan_from_str_ordered(text, &scaled, &order.key())
                .map_err(PlanServiceError::Load)?,
        );
        self.plans
            .lock()
            .unwrap()
            .insert(key, Arc::clone(&plan));
        self.retain_records(key.0, records);
        Ok(plan)
    }

    /// Persist every resident plan into `dir` in the plan-directory format
    /// (see [`super::serialize`]'s module docs): one
    /// `<fingerprint>-b<batch>-<strategy>@<order>.plan` file per cache key,
    /// each written to a `.tmp` sibling and atomically renamed into place,
    /// so a concurrent [`Self::warm_start`] never observes a torn file.
    /// Existing files for the same key are replaced.
    pub fn persist_dir(&self, dir: &Path) -> std::io::Result<PersistReport> {
        std::fs::create_dir_all(dir)?;
        let plans: Vec<(Key, Arc<OffsetPlan>)> = self
            .plans
            .lock()
            .unwrap()
            .iter()
            .map(|(k, p)| (*k, Arc::clone(p)))
            .collect();
        let records = self.records.lock().unwrap().clone();
        let mut report = PersistReport::default();
        for ((fingerprint, batch, strategy, order), plan) in plans {
            let Some(base) = records.get(&fingerprint) else {
                report.skipped += 1;
                continue;
            };
            let order_key = order.key();
            let text = serialize::offset_plan_to_string_ordered(
                &plan,
                &base.scaled(batch),
                &order_key,
            );
            let name = serialize::plan_file_name(fingerprint, batch, strategy, &order_key);
            // Per-process tmp name: two servers persisting into a shared
            // fleet directory must not clobber each other's half-written
            // file before the atomic rename.
            let tmp = dir.join(format!(".{name}.{}.tmp", std::process::id()));
            std::fs::write(&tmp, text.as_bytes())?;
            std::fs::rename(&tmp, dir.join(&name))?;
            report.written += 1;
        }
        Ok(report)
    }

    /// [`Self::warm_start_ordered`] for the natural execution order.
    pub fn warm_start(
        &self,
        dir: &Path,
        records: &UsageRecords,
    ) -> std::io::Result<WarmStartReport> {
        self.warm_start_ordered(dir, records, OrderStrategy::Natural)
    }

    /// Seed the cache from a plan directory: every file whose name carries
    /// `records`' fingerprint **and** `order`'s canonical key is loaded
    /// through [`Self::load_ordered`] (full verification — checksum,
    /// field-by-field record match with exact id coverage, bounded header
    /// fields, order match, feasibility). Files for other models are left
    /// alone; files written under a different execution order are skipped
    /// silently with their own counter, exactly like foreign files (their
    /// offsets are meaningless for this service's record lifetimes, but
    /// they belong to another valid configuration sharing the directory);
    /// files that name an unregistered strategy or fail verification are
    /// **skipped with a warning**, never served and never fatal. A missing
    /// directory is an ordinary cold start.
    ///
    /// After a warm start against the directory a previous run persisted,
    /// every previously-seen `(batch, strategy, order)` plan is a cache
    /// hit: zero planner invocations on the restart path.
    pub fn warm_start_ordered(
        &self,
        dir: &Path,
        records: &UsageRecords,
        order: OrderStrategy,
    ) -> std::io::Result<WarmStartReport> {
        let fingerprint = serialize::records_fingerprint(records);
        let order_key = order.key();
        let mut report = WarmStartReport::default();
        let entries = match std::fs::read_dir(dir) {
            Ok(entries) => entries,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(report),
            Err(e) => return Err(e),
        };
        for entry in entries {
            let entry = entry?;
            let file_name = entry.file_name();
            let Some(name) = file_name.to_str() else { continue };
            if !name.ends_with(".plan") {
                continue; // .tmp leftovers, READMEs, ...
            }
            let Some((file_fp, batch, strategy, file_order)) =
                serialize::parse_plan_file_name(name)
            else {
                report.skipped_corrupt += 1;
                self.warm_skipped.fetch_add(1, Ordering::Relaxed);
                eprintln!("warm-start: skipping '{name}': unparseable plan file name");
                continue;
            };
            // The order check runs before the fingerprint check: a
            // different order of the *same* model yields different records
            // (and so a different fingerprint), which would otherwise be
            // indistinguishable from a foreign model's file. Like foreign
            // files, stale-order files belong to another valid serving
            // configuration sharing the directory — counted in their own
            // field, left intact, no per-file warning.
            if file_order != order_key {
                report.skipped_stale_order += 1;
                continue;
            }
            if file_fp != fingerprint {
                report.skipped_foreign += 1;
                continue;
            }
            if registry::offset_key(&strategy) != Some(strategy.as_str()) {
                report.skipped_stale_strategy += 1;
                self.warm_skipped.fetch_add(1, Ordering::Relaxed);
                eprintln!(
                    "warm-start: skipping '{name}': strategy '{strategy}' is not a registered key"
                );
                continue;
            }
            let text = match std::fs::read_to_string(entry.path()) {
                Ok(text) => text,
                Err(e) => {
                    report.skipped_corrupt += 1;
                    self.warm_skipped.fetch_add(1, Ordering::Relaxed);
                    eprintln!("warm-start: skipping '{name}': {e}");
                    continue;
                }
            };
            match self.load_ordered(&text, records, batch, &strategy, order) {
                Ok(_) => {
                    report.loaded += 1;
                    self.warm_loaded.fetch_add(1, Ordering::Relaxed);
                }
                Err(e) => {
                    report.skipped_corrupt += 1;
                    self.warm_skipped.fetch_add(1, Ordering::Relaxed);
                    eprintln!("warm-start: skipping '{name}': {e}");
                }
            }
        }
        Ok(report)
    }

    /// [`Self::max_servable_batch_ordered`] for the natural execution
    /// order.
    pub fn max_servable_batch(
        &self,
        records: &UsageRecords,
        strategy: &str,
        budget_bytes: usize,
    ) -> Result<usize, PlanServiceError> {
        self.max_servable_batch_ordered(records, strategy, budget_bytes, OrderStrategy::Natural)
    }

    /// Largest batch whose **planned** (not naive) footprint under
    /// `strategy` fits in `budget_bytes`; 0 if even batch 1 does not fit.
    /// `records` and `order` must agree (the caller passes the reordered
    /// graph's records), so the answer — and every probe plan it caches —
    /// is resolved under the same order the engine will serve.
    ///
    /// Uses the bound `planned(b) >= b * max_tensor_size` to cap the search
    /// range, then binary-searches with real plans (each probe lands in the
    /// cache, so a later `get_or_plan` at the answer is free). Planned
    /// footprints grow monotonically with batch for every registry strategy
    /// — uniform scaling preserves every size comparison the heuristics
    /// make.
    pub fn max_servable_batch_ordered(
        &self,
        records: &UsageRecords,
        strategy: &str,
        budget_bytes: usize,
        order: OrderStrategy,
    ) -> Result<usize, PlanServiceError> {
        if registry::offset_key(strategy).is_none() {
            return Err(PlanServiceError::UnknownStrategy(strategy.to_string()));
        }
        let max_size = records.records.iter().map(|r| r.size).max().unwrap_or(0);
        max_batch_fitting(max_size, records.naive_total(), budget_bytes, |b| {
            Ok(self.get_or_plan_ordered(records, b, strategy, order)?.total <= budget_bytes)
        })
    }
}

/// The monotone binary search behind every `max_servable_batch*` query:
/// the largest batch for which `fits` holds. `planned(b) >= b * max_size`
/// caps what can fit `budget_bytes`, and keeping `b * naive_total`
/// representable keeps every size, offset, and total a probe computes free
/// of overflow (all are bounded by the scaled naive sum). `usize::MAX` when
/// `max_size == 0` (nothing to place: any batch fits); 0 when even batch 1
/// does not fit. Every probe plans through the caller's cache, so a later
/// lookup at the answer is free.
fn max_batch_fitting(
    max_size: usize,
    naive_total: usize,
    budget_bytes: usize,
    mut fits: impl FnMut(usize) -> Result<bool, PlanServiceError>,
) -> Result<usize, PlanServiceError> {
    if max_size == 0 {
        return Ok(usize::MAX);
    }
    let cap = (budget_bytes / max_size).min(usize::MAX / naive_total);
    if cap == 0 {
        return Ok(0);
    }
    if !fits(1)? {
        return Ok(0);
    }
    // Invariant: fits(lo), !fits(hi). hi = cap + 1 cannot fit by the
    // max_size bound above.
    let (mut lo, mut hi) = (1usize, cap + 1);
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if fits(mid)? {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    Ok(lo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::example_records;

    #[test]
    fn second_lookup_is_a_hit_and_shares_the_plan() {
        let recs = example_records();
        let cache = PlanCache::new();
        let a = cache.get_or_plan(&recs, 1, "greedy-size").unwrap();
        let b = cache.get_or_plan(&recs, 1, "greedy-size").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn display_name_and_key_share_a_cache_slot() {
        let recs = example_records();
        let cache = PlanCache::new();
        let a = cache.get_or_plan(&recs, 1, "greedy-size").unwrap();
        let b = cache.get_or_plan(&recs, 1, "Greedy by Size").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.misses(), 1);
    }

    #[test]
    fn distinct_batches_get_distinct_plans() {
        let recs = example_records();
        let cache = PlanCache::new();
        let p1 = cache.get_or_plan(&recs, 1, "greedy-size").unwrap();
        let p4 = cache.get_or_plan(&recs, 4, "greedy-size").unwrap();
        assert_eq!(cache.misses(), 2);
        assert!(p4.total > p1.total);
        p4.validate(&recs.scaled(4)).unwrap();
    }

    #[test]
    fn unknown_strategy_is_an_error() {
        let recs = example_records();
        let cache = PlanCache::new();
        let err = cache.get_or_plan(&recs, 1, "belady").unwrap_err();
        assert!(matches!(err, PlanServiceError::UnknownStrategy(_)));
        assert_eq!(cache.misses(), 0);
    }

    #[test]
    fn spill_load_roundtrip_seeds_a_fresh_cache() {
        let recs = example_records();
        let warm = PlanCache::new();
        let text = warm.spill(&recs, 2, "greedy-size").unwrap();
        let cold = PlanCache::new();
        let loaded = cold.load(&text, &recs, 2, "greedy-size").unwrap();
        assert_eq!(*loaded, *warm.get_or_plan(&recs, 2, "greedy-size").unwrap());
        // The load seeded the cache: the next lookup is a hit, no planning.
        let again = cold.get_or_plan(&recs, 2, "greedy-size").unwrap();
        assert!(Arc::ptr_eq(&loaded, &again));
        assert_eq!(cold.misses(), 0);
        assert_eq!(cold.hits(), 1);
    }

    #[test]
    fn stale_spill_fails_to_load() {
        let recs = example_records();
        let cache = PlanCache::new();
        let text = cache.spill(&recs, 1, "greedy-size").unwrap();
        let mut changed = recs.clone();
        changed.records[0].size += 64;
        assert!(matches!(
            PlanCache::new().load(&text, &changed, 1, "greedy-size"),
            Err(PlanServiceError::Load(_))
        ));
    }

    fn scratch_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("tensorarena-cache-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn persist_dir_then_warm_start_restores_every_plan_without_planning() {
        let dir = scratch_dir("roundtrip");
        let recs = example_records();
        let warm = PlanCache::new();
        for strategy in ["greedy-size", "greedy-breadth"] {
            for batch in [1usize, 2, 4] {
                warm.get_or_plan(&recs, batch, strategy).unwrap();
            }
        }
        let persisted = warm.persist_dir(&dir).unwrap();
        assert_eq!(persisted, PersistReport { written: 6, skipped: 0 });

        let cold = PlanCache::new();
        let report = cold.warm_start(&dir, &recs).unwrap();
        assert_eq!(report.loaded, 6, "{report:?}");
        assert_eq!(report.skipped(), 0, "{report:?}");
        assert_eq!(cold.warm_loaded(), 6);
        for strategy in ["greedy-size", "greedy-breadth"] {
            for batch in [1usize, 2, 4] {
                let a = cold.get_or_plan(&recs, batch, strategy).unwrap();
                let b = warm.get_or_plan(&recs, batch, strategy).unwrap();
                assert_eq!(*a, *b, "{strategy} batch {batch} diverged across restart");
            }
        }
        assert_eq!(cold.misses(), 0, "warm start must avoid every planner invocation");
        assert_eq!(cold.hits(), 6);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn warm_start_on_missing_dir_is_an_ordinary_cold_start() {
        let dir = scratch_dir("missing");
        let cache = PlanCache::new();
        let report = cache.warm_start(&dir, &example_records()).unwrap();
        assert_eq!(report, WarmStartReport::default());
        assert!(cache.is_empty());
    }

    #[test]
    fn warm_started_cache_can_re_persist() {
        // A restarted server that loads a plan dir and then shuts down must
        // be able to write the same dir back (records retained on load).
        let dir = scratch_dir("repersist");
        let recs = example_records();
        let warm = PlanCache::new();
        warm.get_or_plan(&recs, 2, "greedy-size").unwrap();
        warm.persist_dir(&dir).unwrap();

        let cold = PlanCache::new();
        assert_eq!(cold.warm_start(&dir, &recs).unwrap().loaded, 1);
        let again = cold.persist_dir(&dir).unwrap();
        assert_eq!(again, PersistReport { written: 1, skipped: 0 });
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn order_is_a_cache_dimension_even_when_records_coincide() {
        // Identical records under two order keys occupy distinct slots: a
        // plan produced for the natural order must never answer an annealed
        // lookup (their persistence files are keyed apart too).
        let recs = example_records();
        let cache = PlanCache::new();
        let order = OrderStrategy::Annealed { seed: 1, budget: 5 };
        let a = cache.get_or_plan(&recs, 1, "greedy-size").unwrap();
        let b = cache
            .get_or_plan_ordered(&recs, 1, "greedy-size", order)
            .unwrap();
        assert_eq!(*a, *b, "same records, same strategy: same plan content");
        assert_eq!(cache.misses(), 2, "but distinct cache slots");
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn ordered_persist_then_ordered_warm_start_roundtrips() {
        let dir = scratch_dir("ordered-roundtrip");
        let recs = example_records();
        let order = OrderStrategy::MemoryAware;
        let warm = PlanCache::new();
        warm.get_or_plan_ordered(&recs, 2, "greedy-size", order).unwrap();
        assert_eq!(warm.persist_dir(&dir).unwrap().written, 1);

        // A natural warm start skips the file with the stale-order counter…
        let cold = PlanCache::new();
        let report = cold.warm_start(&dir, &recs).unwrap();
        assert_eq!(
            (report.loaded, report.skipped_stale_order),
            (0, 1),
            "{report:?}"
        );
        // …which, like a foreign file, is not a *suspect* skip.
        assert_eq!(report.skipped(), 0);
        assert!(cold.is_empty());
        // …the matching order loads it without planning.
        let cold = PlanCache::new();
        let report = cold.warm_start_ordered(&dir, &recs, order).unwrap();
        assert_eq!(report.loaded, 1, "{report:?}");
        cold.get_or_plan_ordered(&recs, 2, "greedy-size", order).unwrap();
        assert_eq!(cold.misses(), 0, "ordered warm start must avoid the planner");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    fn decode_dynamic() -> DynamicRecords {
        use super::super::dynamic::DynamicRecord;
        use crate::records::UsageRecord;
        // A chain with a two-wave tail: sizes of records 2 and 3 resolve
        // after ops 2 and 4 execute.
        DynamicRecords::new(
            vec![
                DynamicRecord {
                    record: UsageRecord { id: 0, tensor: None, first_op: 0, last_op: 2, size: 128 },
                    known_at: 0,
                },
                DynamicRecord {
                    record: UsageRecord { id: 1, tensor: None, first_op: 1, last_op: 3, size: 64 },
                    known_at: 0,
                },
                DynamicRecord {
                    record: UsageRecord { id: 2, tensor: None, first_op: 3, last_op: 5, size: 192 },
                    known_at: 2,
                },
                DynamicRecord {
                    record: UsageRecord { id: 3, tensor: None, first_op: 5, last_op: 6, size: 64 },
                    known_at: 4,
                },
            ],
            7,
        )
    }

    #[test]
    fn decode_steps_with_unchanged_prefix_hit_the_dynamic_cache() {
        let cache = PlanCache::new();
        let dynamic = decode_dynamic();
        // A decode loop: one lookup per op. Steps between wave boundaries
        // share a resolved prefix, so the first loop plans once per
        // distinct prefix (waves 0, 2, 4 -> 3 misses)...
        for step in 0..dynamic.num_ops {
            let order = OrderStrategy::Natural;
            cache
                .get_or_plan_dynamic_resolved(&dynamic, step, 1, "greedy-size", order)
                .unwrap();
        }
        assert_eq!(cache.dynamic_misses(), 3, "one planner invocation per distinct prefix");
        let hits_after_first = cache.dynamic_hits();
        // ...and a second pass over the same resolved prefixes performs
        // zero planner invocations.
        for step in 0..dynamic.num_ops {
            let order = OrderStrategy::Natural;
            cache
                .get_or_plan_dynamic_resolved(&dynamic, step, 1, "greedy-size", order)
                .unwrap();
        }
        assert_eq!(cache.dynamic_misses(), 3, "second decode pass must not re-plan");
        assert_eq!(cache.dynamic_hits(), hits_after_first + dynamic.num_ops as u64);
        // Static counters are untouched: the dimensions do not bleed.
        assert_eq!((cache.hits(), cache.misses()), (0, 0));
    }

    #[test]
    fn dynamic_slots_are_fifo_bounded() {
        use super::super::dynamic::DynamicRecord;
        use crate::records::UsageRecord;
        let cache = PlanCache::new();
        let order = OrderStrategy::Natural;
        let mk = |size: usize| {
            DynamicRecords::new(
                vec![DynamicRecord {
                    record: UsageRecord { id: 0, tensor: None, first_op: 0, last_op: 1, size },
                    known_at: 0,
                }],
                2,
            )
        };
        // One more distinct resolved prefix than the cap fits.
        for i in 0..=DYNAMIC_PLAN_CAP {
            cache
                .get_or_plan_dynamic(&mk(64 * (i + 1)), 1, "greedy-size", order)
                .unwrap();
        }
        let resident = cache.dynamic.lock().unwrap().plans.len();
        assert_eq!(resident, DYNAMIC_PLAN_CAP, "cap must bound the dynamic slots");
        // The newest entry is resident: re-requesting it is a pure hit…
        let misses = cache.dynamic_misses();
        cache
            .get_or_plan_dynamic(&mk(64 * (DYNAMIC_PLAN_CAP + 1)), 1, "greedy-size", order)
            .unwrap();
        assert_eq!(cache.dynamic_misses(), misses);
        // …the oldest was evicted: recurring costs one re-plan, never a
        // wrong hit, and re-enters the window.
        let misses = cache.dynamic_misses();
        cache.get_or_plan_dynamic(&mk(64), 1, "greedy-size", order).unwrap();
        assert_eq!(cache.dynamic_misses(), misses + 1);
    }

    #[test]
    fn complete_dynamic_plan_is_validated_and_batch_scaled() {
        let cache = PlanCache::new();
        let dynamic = decode_dynamic();
        let full = cache
            .get_or_plan_dynamic(&dynamic, 1, "greedy-size", OrderStrategy::Natural)
            .unwrap();
        assert!(full.is_complete());
        full.offset_plan()
            .unwrap()
            .validate(&dynamic.final_records())
            .unwrap();
        let b4 = cache
            .get_or_plan_dynamic(&dynamic, 4, "greedy-size", OrderStrategy::Natural)
            .unwrap();
        assert_eq!(b4.peak, 4 * full.peak, "uniform scaling scales the multi-pass peak");
        b4.offset_plan()
            .unwrap()
            .validate(&dynamic.scaled(4).final_records())
            .unwrap();
    }

    #[test]
    fn max_servable_batch_dynamic_resolves_under_the_worst_wave_peak() {
        let cache = PlanCache::new();
        let dynamic = decode_dynamic();
        let peak1 = cache
            .get_or_plan_dynamic(&dynamic, 1, "greedy-size", OrderStrategy::Natural)
            .unwrap()
            .peak;
        let budget = 3 * peak1;
        let cap = cache
            .max_servable_batch_dynamic(&dynamic, "greedy-size", budget, OrderStrategy::Natural)
            .unwrap();
        assert!(cap >= 1);
        let at_cap = cache
            .get_or_plan_dynamic(&dynamic, cap, "greedy-size", OrderStrategy::Natural)
            .unwrap()
            .peak;
        let above = cache
            .get_or_plan_dynamic(&dynamic, cap + 1, "greedy-size", OrderStrategy::Natural)
            .unwrap()
            .peak;
        assert!(at_cap <= budget && above > budget);
        let order = OrderStrategy::Natural;
        assert_eq!(
            cache
                .max_servable_batch_dynamic(&dynamic, "greedy-size", peak1 - 1, order)
                .unwrap(),
            0
        );
        assert!(matches!(
            cache.max_servable_batch_dynamic(&dynamic, "belady", budget, OrderStrategy::Natural),
            Err(PlanServiceError::UnknownStrategy(_))
        ));
    }

    #[test]
    fn max_servable_batch_boundaries() {
        let recs = example_records();
        let cache = PlanCache::new();
        let t1 = cache.get_or_plan(&recs, 1, "greedy-size").unwrap().total;
        // Exactly the batch-1 footprint: batch 1 fits, batch 2 cannot.
        assert_eq!(cache.max_servable_batch(&recs, "greedy-size", t1).unwrap(), 1);
        // Below the batch-1 footprint: nothing fits.
        assert_eq!(cache.max_servable_batch(&recs, "greedy-size", t1 - 1).unwrap(), 0);
        // A generous budget fits proportionally more.
        let b = cache.max_servable_batch(&recs, "greedy-size", 10 * t1).unwrap();
        assert!(b >= 10, "10x budget fits only batch {b}");
        assert!(cache.get_or_plan(&recs, b, "greedy-size").unwrap().total <= 10 * t1);
    }
}
