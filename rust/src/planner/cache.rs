//! Batch-aware plan cache: plan once per `(graph, batch, strategy)`, reuse
//! forever.
//!
//! The paper's arena is planned once and cheaply reused for every inference
//! (§5); serving multiplies that by batch-size variants and engine
//! replicas. The cache keys plans by the FNV-1a fingerprint of the usage
//! records (the planner's entire input), the batch the records are scaled
//! to, and the registry strategy key, so two executors serving the same
//! model at the same batch share one `Arc<OffsetPlan>` and the planner runs
//! exactly once.
//!
//! Plans can be spilled to / loaded from the [`super::serialize`] text
//! format (compute offline, ship with the model), and
//! [`PlanCache::max_servable_batch`] answers the serving-era question the
//! follow-up work (FlashMem, MAFAT) poses: what is the largest batch whose
//! *planned* footprint fits a byte budget?

use super::serialize::{self, LoadError};
use super::{registry, OffsetPlan, PlanError};
use crate::records::UsageRecords;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Errors from the plan cache / plan service.
#[derive(Debug)]
pub enum PlanServiceError {
    /// The strategy name is not in the registry.
    UnknownStrategy(String),
    /// The strategy produced an infeasible plan (a planner bug).
    Infeasible(PlanError),
    /// A spilled plan failed to load.
    Load(LoadError),
}

impl std::fmt::Display for PlanServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanServiceError::UnknownStrategy(s) => {
                write!(
                    f,
                    "unknown offset strategy '{s}' (known: {})",
                    registry::OFFSET_KEYS.join(", ")
                )
            }
            PlanServiceError::Infeasible(e) => write!(f, "strategy produced infeasible plan: {e}"),
            PlanServiceError::Load(e) => write!(f, "loading spilled plan: {e}"),
        }
    }
}

impl std::error::Error for PlanServiceError {}

/// Cache key: records fingerprint × batch × canonical strategy key.
type Key = (u64, usize, &'static str);

/// Thread-safe memoization of offset plans, keyed by
/// `(records fingerprint, batch, strategy)`.
#[derive(Default)]
pub struct PlanCache {
    plans: Mutex<HashMap<Key, Arc<OffsetPlan>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    /// Empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Cache hits so far.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cache misses (= planner invocations) so far.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Number of distinct plans resident.
    pub fn len(&self) -> usize {
        self.plans.lock().unwrap().len()
    }

    /// True if no plan has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn key(records: &UsageRecords, batch: usize, strategy: &str) -> Result<Key, PlanServiceError> {
        let key = registry::offset_key(strategy)
            .ok_or_else(|| PlanServiceError::UnknownStrategy(strategy.to_string()))?;
        Ok((serialize::records_fingerprint(records), batch, key))
    }

    /// The plan for `records` scaled to `batch` under `strategy`, planning
    /// (and validating) on first use. `records` are always the *batch-1*
    /// records; scaling is the cache's job so every caller agrees on the
    /// key. Planning happens under the cache lock, which guarantees exactly
    /// one planner invocation per key even under concurrent lookups.
    pub fn get_or_plan(
        &self,
        records: &UsageRecords,
        batch: usize,
        strategy: &str,
    ) -> Result<Arc<OffsetPlan>, PlanServiceError> {
        let key = Self::key(records, batch, strategy)?;
        let mut plans = self.plans.lock().unwrap();
        if let Some(plan) = plans.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(plan));
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let planner = registry::offset_strategy(key.2).expect("canonical key resolves");
        let scaled = records.scaled(batch);
        let plan = planner.plan(&scaled);
        plan.validate(&scaled).map_err(PlanServiceError::Infeasible)?;
        let plan = Arc::new(plan);
        plans.insert(key, Arc::clone(&plan));
        Ok(plan)
    }

    /// Serialize the plan for `(records, batch, strategy)` in the
    /// [`super::serialize`] text format, planning it first if not resident —
    /// ship the result next to the model and [`Self::load`] it at serve
    /// time.
    pub fn spill(
        &self,
        records: &UsageRecords,
        batch: usize,
        strategy: &str,
    ) -> Result<String, PlanServiceError> {
        let plan = self.get_or_plan(records, batch, strategy)?;
        Ok(serialize::offset_plan_to_string(&plan, &records.scaled(batch)))
    }

    /// Seed the cache from a previously spilled plan. The text is verified
    /// against the batch-scaled records (checksum, record match,
    /// feasibility) before insertion, so a stale plan for a changed model
    /// fails loudly instead of serving corrupted offsets.
    ///
    /// The v1 text format carries no strategy tag, so the caller's
    /// `strategy` names the slot the plan is filed under — loading a spill
    /// produced by a different strategy is not detectable (it is still a
    /// *valid* plan, just not that strategy's); keep spill files per
    /// strategy.
    pub fn load(
        &self,
        text: &str,
        records: &UsageRecords,
        batch: usize,
        strategy: &str,
    ) -> Result<Arc<OffsetPlan>, PlanServiceError> {
        let key = Self::key(records, batch, strategy)?;
        let scaled = records.scaled(batch);
        let plan = Arc::new(
            serialize::offset_plan_from_str(text, &scaled).map_err(PlanServiceError::Load)?,
        );
        self.plans
            .lock()
            .unwrap()
            .insert(key, Arc::clone(&plan));
        Ok(plan)
    }

    /// Largest batch whose **planned** (not naive) footprint under
    /// `strategy` fits in `budget_bytes`; 0 if even batch 1 does not fit.
    ///
    /// Uses the bound `planned(b) >= b * max_tensor_size` to cap the search
    /// range, then binary-searches with real plans (each probe lands in the
    /// cache, so a later `get_or_plan` at the answer is free). Planned
    /// footprints grow monotonically with batch for every registry strategy
    /// — uniform scaling preserves every size comparison the heuristics
    /// make.
    pub fn max_servable_batch(
        &self,
        records: &UsageRecords,
        strategy: &str,
        budget_bytes: usize,
    ) -> Result<usize, PlanServiceError> {
        if registry::offset_key(strategy).is_none() {
            return Err(PlanServiceError::UnknownStrategy(strategy.to_string()));
        }
        let max_size = records.records.iter().map(|r| r.size).max().unwrap_or(0);
        if max_size == 0 {
            // Nothing to place: any batch fits.
            return Ok(usize::MAX);
        }
        // Cap the probe range twice: `planned(b) >= b * max_size` bounds
        // what can fit the budget, and `b * naive_total <= usize::MAX`
        // keeps every size, offset, and total computed for a probed batch
        // free of overflow (all are bounded by the scaled naive sum).
        let cap = (budget_bytes / max_size).min(usize::MAX / records.naive_total());
        if cap == 0 {
            return Ok(0);
        }
        let fits = |b: usize| -> Result<bool, PlanServiceError> {
            Ok(self.get_or_plan(records, b, strategy)?.total <= budget_bytes)
        };
        if !fits(1)? {
            return Ok(0);
        }
        // Invariant: fits(lo), !fits(hi). hi = cap + 1 cannot fit by the
        // max_size bound above.
        let (mut lo, mut hi) = (1usize, cap + 1);
        while hi - lo > 1 {
            let mid = lo + (hi - lo) / 2;
            if fits(mid)? {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        Ok(lo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::example_records;

    #[test]
    fn second_lookup_is_a_hit_and_shares_the_plan() {
        let recs = example_records();
        let cache = PlanCache::new();
        let a = cache.get_or_plan(&recs, 1, "greedy-size").unwrap();
        let b = cache.get_or_plan(&recs, 1, "greedy-size").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn display_name_and_key_share_a_cache_slot() {
        let recs = example_records();
        let cache = PlanCache::new();
        let a = cache.get_or_plan(&recs, 1, "greedy-size").unwrap();
        let b = cache.get_or_plan(&recs, 1, "Greedy by Size").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.misses(), 1);
    }

    #[test]
    fn distinct_batches_get_distinct_plans() {
        let recs = example_records();
        let cache = PlanCache::new();
        let p1 = cache.get_or_plan(&recs, 1, "greedy-size").unwrap();
        let p4 = cache.get_or_plan(&recs, 4, "greedy-size").unwrap();
        assert_eq!(cache.misses(), 2);
        assert!(p4.total > p1.total);
        p4.validate(&recs.scaled(4)).unwrap();
    }

    #[test]
    fn unknown_strategy_is_an_error() {
        let recs = example_records();
        let cache = PlanCache::new();
        let err = cache.get_or_plan(&recs, 1, "belady").unwrap_err();
        assert!(matches!(err, PlanServiceError::UnknownStrategy(_)));
        assert_eq!(cache.misses(), 0);
    }

    #[test]
    fn spill_load_roundtrip_seeds_a_fresh_cache() {
        let recs = example_records();
        let warm = PlanCache::new();
        let text = warm.spill(&recs, 2, "greedy-size").unwrap();
        let cold = PlanCache::new();
        let loaded = cold.load(&text, &recs, 2, "greedy-size").unwrap();
        assert_eq!(*loaded, *warm.get_or_plan(&recs, 2, "greedy-size").unwrap());
        // The load seeded the cache: the next lookup is a hit, no planning.
        let again = cold.get_or_plan(&recs, 2, "greedy-size").unwrap();
        assert!(Arc::ptr_eq(&loaded, &again));
        assert_eq!(cold.misses(), 0);
        assert_eq!(cold.hits(), 1);
    }

    #[test]
    fn stale_spill_fails_to_load() {
        let recs = example_records();
        let cache = PlanCache::new();
        let text = cache.spill(&recs, 1, "greedy-size").unwrap();
        let mut changed = recs.clone();
        changed.records[0].size += 64;
        assert!(matches!(
            PlanCache::new().load(&text, &changed, 1, "greedy-size"),
            Err(PlanServiceError::Load(_))
        ));
    }

    #[test]
    fn max_servable_batch_boundaries() {
        let recs = example_records();
        let cache = PlanCache::new();
        let t1 = cache.get_or_plan(&recs, 1, "greedy-size").unwrap().total;
        // Exactly the batch-1 footprint: batch 1 fits, batch 2 cannot.
        assert_eq!(cache.max_servable_batch(&recs, "greedy-size", t1).unwrap(), 1);
        // Below the batch-1 footprint: nothing fits.
        assert_eq!(cache.max_servable_batch(&recs, "greedy-size", t1 - 1).unwrap(), 0);
        // A generous budget fits proportionally more.
        let b = cache.max_servable_batch(&recs, "greedy-size", 10 * t1).unwrap();
        assert!(b >= 10, "10x budget fits only batch {b}");
        assert!(cache.get_or_plan(&recs, b, "greedy-size").unwrap().total <= 10 * t1);
    }
}
