//! Batch-aware plan cache: plan once per `(records fingerprint,
//! PlanRequest)`, reuse forever.
//!
//! The paper's arena is planned once and cheaply reused for every inference
//! (§5); serving multiplies that by batch-size variants and engine
//! replicas. The cache keys plans by the FNV-1a fingerprint of the usage
//! records (the planner's entire input) and the typed
//! [`PlanRequest`] — strategy, order, batch, dtype, dynamic mode in one
//! value — so two executors serving the same model at the same batch share
//! one
//! `Arc<OffsetPlan>` and the planner runs exactly once. The order is a key
//! dimension in its own right: two orders that happen to coincide
//! (annealing found nothing) still occupy distinct slots, so order-keyed
//! persistence stays unambiguous.
//!
//! Plans can be spilled to / loaded from the [`super::serialize`] text
//! format (compute offline, ship with the model), and
//! [`PlanCache::max_servable_batch`] answers the serving-era question the
//! follow-up work (FlashMem, MAFAT) poses: what is the largest batch whose
//! *planned* footprint fits a byte budget?
//!
//! **Dynamic shapes** (§7) get their own cache dimension: multi-pass plans
//! are keyed by the fingerprint of the **resolved-size prefix** — the
//! static records plus the sizes known so far — so decode-step re-plans
//! with an unchanged prefix are cache hits with zero planner invocations
//! ([`PlanCache::get_or_plan_dynamic`]), and budget admission for
//! dynamic engines resolves under the worst-wave peak
//! ([`PlanCache::max_servable_batch_dynamic`]).

use super::dynamic::{DynamicRecords, MultiPassPlan, MultiPassPlanner};
use super::registry::OrderStrategy;
use super::request::{Dtype, DynamicMode, ParseRequestError, PlanRequest};
use super::serialize::{self, LoadError};
use super::{registry, OffsetPlan, PlanError};
use crate::records::UsageRecords;
use std::collections::{HashMap, VecDeque};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Errors from the plan cache / plan service.
#[derive(Debug)]
pub enum PlanServiceError {
    /// The strategy name is not in the registry.
    UnknownStrategy(String),
    /// The request's shape does not fit the entry point — e.g. a
    /// [`DynamicMode`]-carrying request handed to a static lookup, or a
    /// static request handed to a dynamic one.
    InvalidRequest(String),
    /// The strategy produced an infeasible plan (a planner bug).
    Infeasible(PlanError),
    /// A spilled plan failed to load.
    Load(LoadError),
}

impl std::fmt::Display for PlanServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanServiceError::UnknownStrategy(s) => {
                write!(
                    f,
                    "unknown offset strategy '{s}' (known: {})",
                    registry::OFFSET_KEYS.join(", ")
                )
            }
            PlanServiceError::InvalidRequest(s) => write!(f, "invalid plan request: {s}"),
            PlanServiceError::Infeasible(e) => write!(f, "strategy produced infeasible plan: {e}"),
            PlanServiceError::Load(e) => write!(f, "loading spilled plan: {e}"),
        }
    }
}

impl std::error::Error for PlanServiceError {}

/// Static cache key: records fingerprint × [`PlanRequest`]. Only static
/// requests (`req.dynamic() == DynamicMode::Static`) are ever stored, so
/// the request half of the key is exactly what [`PlanRequest`]'s `Display`
/// writes into a plan-directory file name.
type Key = (u64, PlanRequest);

/// Dynamic-plan cache key: **resolved-size-prefix fingerprint** × batch ×
/// canonical strategy key × execution-order strategy × element dtype. The
/// fingerprint
/// ([`serialize::resolved_prefix_fingerprint`]) covers the op count, every
/// record's interval and `known_at`, and the sizes resolved so far — so
/// decode steps between wave boundaries, and any two sequences whose
/// resolved sizes agree, share one slot regardless of their (still
/// unknown) tails. The request's [`DynamicMode`] participates through the
/// fingerprint, never as a raw field: `Resolved(op)` values between the
/// same wave boundaries (and `FullyResolved` past the last one) must share
/// a slot — that sharing *is* the §7 amortization.
type DynamicKey = (u64, usize, &'static str, OrderStrategy, Dtype);

/// Most dynamic (multi-pass) plans kept resident. Static cache keys are
/// bounded by the served model/batch/strategy set, but resolved-size
/// prefixes are unbounded by nature — every new sequence may resolve new
/// sizes — so without a bound a long-lived dynamic server would grow the
/// map forever. The dynamic slots are therefore a FIFO window: inserting
/// past the cap evicts the oldest entry (an evicted prefix simply costs
/// one re-plan if it ever recurs). A few thousand plans of a few KiB each
/// bound the cache to single-digit MiB while covering every
/// (boundary × batch) pair of realistic serving.
const DYNAMIC_PLAN_CAP: usize = 4096;

/// The FIFO-bounded dynamic plan slots (see [`DYNAMIC_PLAN_CAP`]).
#[derive(Default)]
struct DynamicSlots {
    plans: HashMap<DynamicKey, Arc<MultiPassPlan>>,
    /// Insertion order, oldest first; `fifo.len() == plans.len()`.
    fifo: VecDeque<DynamicKey>,
}

/// Outcome of [`PlanCache::warm_start`]: how many plan files seeded the
/// cache and why the rest were skipped. Skips are never fatal — a corrupt
/// file must cost a planner invocation, not a crashed server.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WarmStartReport {
    /// Plans loaded into the cache (planner invocations avoided).
    pub loaded: usize,
    /// Files whose fingerprint names a different record set (another
    /// model's plans sharing the directory) — left alone, not a defect.
    pub skipped_foreign: usize,
    /// Files naming a strategy no longer in the registry.
    pub skipped_stale_strategy: usize,
    /// Files written under a different execution order than the one this
    /// service serves — their record lifetimes (and therefore offsets) do
    /// not apply here. A directory written by an `annealed` server is
    /// skipped, counted, and left intact by a `natural` restart. Like
    /// foreign files, these belong to another valid serving configuration
    /// (fleets share directories), so they are not "suspect".
    pub skipped_stale_order: usize,
    /// Files written under a quantized size class ([`Dtype`] key) this
    /// build does not recognize — a newer build's plans sharing the
    /// directory. Forward compatibility exactly like stale-order files:
    /// counted, left intact, never suspect.
    pub skipped_stale_dtype: usize,
    /// Files that failed to parse or verify (truncated, checksum-corrupt,
    /// record-mismatched, unparseable or pre-bump-version name).
    pub skipped_corrupt: usize,
}

impl WarmStartReport {
    /// Everything skipped for a *suspect* reason (foreign, stale-order,
    /// and stale-dtype files belong to other valid configurations and are
    /// not suspect).
    pub fn skipped(&self) -> usize {
        self.skipped_stale_strategy + self.skipped_corrupt
    }
}

/// Outcome of [`PlanCache::persist_dir`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PersistReport {
    /// Plan files written (atomically) into the directory.
    pub written: usize,
    /// Resident plans that could not be serialized because their source
    /// records were not retained (not produced by this cache's miss/load
    /// paths).
    pub skipped: usize,
}

/// Thread-safe memoization of offset plans, keyed by
/// `(records fingerprint, PlanRequest)` — plus the §7 dynamic slots keyed
/// by the resolved-size prefix.
///
/// Lock order: `plans` before `records`, everywhere both are held.
///
/// # Example
///
/// ```
/// use tensorarena::planner::{PlanCache, PlanRequest};
/// use tensorarena::records::UsageRecords;
///
/// let records = UsageRecords::from_triples(&[(0, 1, 64), (1, 2, 128)]);
/// let cache = PlanCache::new();
/// let req = PlanRequest::new().with_batch(4); // greedy-size @ natural
/// let plan = cache.get_or_plan(&records, &req).unwrap();
/// assert!(plan.total_size() <= 4 * records.naive_total());
/// assert_eq!((cache.misses(), cache.hits()), (1, 0));
/// cache.get_or_plan(&records, &req).unwrap(); // cache hit
/// assert_eq!((cache.misses(), cache.hits()), (1, 1));
/// ```
#[derive(Default)]
pub struct PlanCache {
    plans: Mutex<HashMap<Key, Arc<OffsetPlan>>>,
    /// Batch-1 records per fingerprint — what [`Self::persist_dir`] needs
    /// to serialize a resident plan next to the records it plans.
    records: Mutex<HashMap<u64, UsageRecords>>,
    /// §7 multi-pass plans, keyed by the resolved-size prefix (see
    /// [`DynamicKey`]). In-memory only: dynamic plans are not persisted to
    /// the plan directory (their resolved sizes are transient by nature).
    dynamic: Mutex<DynamicSlots>,
    hits: AtomicU64,
    misses: AtomicU64,
    dynamic_hits: AtomicU64,
    dynamic_misses: AtomicU64,
    warm_loaded: AtomicU64,
    warm_skipped: AtomicU64,
}

impl PlanCache {
    /// Empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Cache hits so far.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cache misses (= planner invocations) so far.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Dynamic (multi-pass) plan-cache hits so far — decode-step re-plans
    /// answered with zero planner invocations.
    pub fn dynamic_hits(&self) -> u64 {
        self.dynamic_hits.load(Ordering::Relaxed)
    }

    /// Dynamic plan-cache misses (= multi-pass planner invocations) so far.
    pub fn dynamic_misses(&self) -> u64 {
        self.dynamic_misses.load(Ordering::Relaxed)
    }

    /// Plans seeded from a plan directory by [`Self::warm_start`] so far.
    pub fn warm_loaded(&self) -> u64 {
        self.warm_loaded.load(Ordering::Relaxed)
    }

    /// Plan-directory files skipped by [`Self::warm_start`] so far
    /// (corrupt, truncated, or stale-strategy; foreign files not counted).
    pub fn warm_skipped(&self) -> u64 {
        self.warm_skipped.load(Ordering::Relaxed)
    }

    /// Number of distinct plans resident.
    pub fn len(&self) -> usize {
        self.plans.lock().unwrap().len()
    }

    /// True if no plan has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The plan `req` identifies for `records`, planning (and validating)
    /// on first use. `records` are always the *batch-1* records — for a
    /// non-natural order, the records of the graph *reordered under that
    /// order* (the caller applies the order; the cache keys on it so
    /// coinciding orders cannot cross-contaminate persistence). Scaling to
    /// `req.batch()` is the cache's job so every caller agrees on the key.
    /// Planning happens under the cache lock, which guarantees exactly one
    /// planner invocation per key even under concurrent lookups. The
    /// request must be static; dynamic modes go through
    /// [`Self::get_or_plan_dynamic`] with a profile.
    pub fn get_or_plan(
        &self,
        records: &UsageRecords,
        req: &PlanRequest,
    ) -> Result<Arc<OffsetPlan>, PlanServiceError> {
        if !req.dynamic().is_static() {
            return Err(PlanServiceError::InvalidRequest(format!(
                "static lookup for dynamic request '{req}'; use get_or_plan_dynamic \
                 with a DynamicRecords profile"
            )));
        }
        let key: Key = (serialize::records_fingerprint(records), *req);
        let mut plans = self.plans.lock().unwrap();
        if let Some(plan) = plans.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(plan));
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let planner = registry::offset_strategy(req.strategy()).expect("canonical key resolves");
        let scaled = records.scaled_for(req.batch(), req.dtype());
        let plan = planner.plan(&scaled);
        plan.validate(&scaled).map_err(PlanServiceError::Infeasible)?;
        let plan = Arc::new(plan);
        plans.insert(key, Arc::clone(&plan));
        self.retain_records(key.0, records);
        Ok(plan)
    }

    /// [`Self::get_or_plan`] with an untyped `(batch, strategy, order)`
    /// triple.
    #[deprecated(since = "0.3.0", note = "build a PlanRequest and call get_or_plan")]
    pub fn get_or_plan_ordered(
        &self,
        records: &UsageRecords,
        batch: usize,
        strategy: &str,
        order: OrderStrategy,
    ) -> Result<Arc<OffsetPlan>, PlanServiceError> {
        let req = PlanRequest::new().with_strategy(strategy)?.with_batch(batch).with_order(order);
        self.get_or_plan(records, &req)
    }

    /// The §7 multi-pass plan `req` identifies for `dynamic`, through the
    /// resolved-prefix-keyed cache slot. `dynamic` are the *batch-1*
    /// records of the (order-applied) graph; scaling to `req.batch()` is
    /// the cache's job, exactly as for static plans. The request's
    /// [`DynamicMode`] selects the resolution state:
    /// [`DynamicMode::FullyResolved`] yields the **complete** plan — what
    /// the wave-aware executor sizes its arena from and what budget
    /// admission resolves against ([`MultiPassPlan::peak`] is the
    /// worst-wave peak) — and [`DynamicMode::Resolved`]`(op)` the prefix
    /// plan of the waves resolved once `op` has executed (the decode-step
    /// re-plan). A static request is an [`PlanServiceError::InvalidRequest`].
    ///
    /// The slot key is the [`serialize::resolved_prefix_fingerprint`] — so
    /// successive decode steps with an unchanged resolved prefix (no wave
    /// boundary crossed, same resolved sizes) are **cache hits with zero
    /// planner invocations**, as are later sequences whose resolved sizes
    /// repeat; a step that resolves a new size (or a different value for a
    /// previously-seen wave — a stale prefix) misses and re-plans. Soundness
    /// rests on the freeze invariant (see [`super::dynamic`]): a prefix plan
    /// never depends on unresolved sizes, so slot sharing across sequences
    /// with different tails is exact, not approximate.
    ///
    /// Complete plans (every wave resolved) are validated against the final
    /// scaled records before being cached; prefix plans are covered by the
    /// freeze invariant (they are byte-identical prefixes of a validated
    /// complete plan). The strategy namespaces the slot like the static
    /// cache key — within-wave placement itself is always Algorithm 3's
    /// size-descending best-fit. Dynamic plans live in memory only; they are
    /// never spilled to a plan directory.
    pub fn get_or_plan_dynamic(
        &self,
        dynamic: &DynamicRecords,
        req: &PlanRequest,
    ) -> Result<Arc<MultiPassPlan>, PlanServiceError> {
        let mode = req.dynamic();
        if mode.is_static() {
            return Err(PlanServiceError::InvalidRequest(format!(
                "dynamic lookup for static request '{req}'; set a DynamicMode \
                 (Resolved(op) or FullyResolved)"
            )));
        }
        let fp = serialize::resolved_prefix_fingerprint(dynamic, mode);
        let key: DynamicKey = (fp, req.batch(), req.strategy(), req.order(), req.dtype());
        let mut slots = self.dynamic.lock().unwrap();
        if let Some(plan) = slots.plans.get(&key) {
            self.dynamic_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(plan));
        }
        self.dynamic_misses.fetch_add(1, Ordering::Relaxed);
        let scaled = dynamic.scaled_for(req.batch(), req.dtype());
        let plan = MultiPassPlanner.plan_resolved(&scaled, mode);
        if let Some(complete) = plan.offset_plan() {
            complete
                .validate(&scaled.final_records())
                .map_err(PlanServiceError::Infeasible)?;
        }
        let plan = Arc::new(plan);
        slots.plans.insert(key, Arc::clone(&plan));
        slots.fifo.push_back(key);
        if slots.fifo.len() > DYNAMIC_PLAN_CAP {
            if let Some(oldest) = slots.fifo.pop_front() {
                slots.plans.remove(&oldest);
            }
        }
        Ok(plan)
    }

    /// [`Self::get_or_plan_dynamic`] with an untyped `resolved_through`
    /// op index (`usize::MAX` meaning fully resolved).
    #[deprecated(
        since = "0.3.0",
        note = "build a PlanRequest with a DynamicMode and call get_or_plan_dynamic"
    )]
    pub fn get_or_plan_dynamic_resolved(
        &self,
        dynamic: &DynamicRecords,
        resolved_through: usize,
        batch: usize,
        strategy: &str,
        order: OrderStrategy,
    ) -> Result<Arc<MultiPassPlan>, PlanServiceError> {
        let req = PlanRequest::new()
            .with_strategy(strategy)?
            .with_batch(batch)
            .with_order(order)
            .with_dynamic(DynamicMode::from_resolved_through(resolved_through));
        self.get_or_plan_dynamic(dynamic, &req)
    }

    /// Largest batch whose **worst-wave** multi-pass peak fits
    /// `budget_bytes` — the §7 analogue of [`Self::max_servable_batch`].
    /// Budget admission for a dynamic-shape engine must resolve against
    /// this peak, not the static plan, because mid-inference waves can only
    /// grow the arena; the request's batch and [`DynamicMode`] are
    /// therefore immaterial — every probe plans the complete
    /// ([`DynamicMode::FullyResolved`]) multi-pass plan at the probed
    /// batch.
    pub fn max_servable_batch_dynamic(
        &self,
        dynamic: &DynamicRecords,
        req: &PlanRequest,
        budget_bytes: usize,
    ) -> Result<usize, PlanServiceError> {
        let req = req.with_dynamic(DynamicMode::FullyResolved);
        let finals = dynamic.final_records();
        let max_size = finals.records.iter().map(|r| r.size).max().unwrap_or(0);
        max_batch_fitting(max_size, finals.naive_total(), budget_bytes, |b| {
            Ok(self
                .get_or_plan_dynamic(dynamic, &req.with_batch(b))?
                .peak
                <= budget_bytes)
        })
    }

    /// Remember the batch-1 records behind `fingerprint`, so
    /// [`Self::persist_dir`] can serialize this plan later. Caller may hold
    /// the `plans` lock (lock order: `plans` then `records`).
    fn retain_records(&self, fingerprint: u64, records: &UsageRecords) {
        self.records
            .lock()
            .unwrap()
            .entry(fingerprint)
            .or_insert_with(|| records.clone());
    }

    /// Serialize the plan `req` identifies for `records` in the
    /// [`super::serialize`] text format, planning it first if not resident
    /// — ship the result next to the model and [`Self::load`] it at serve
    /// time.
    pub fn spill(
        &self,
        records: &UsageRecords,
        req: &PlanRequest,
    ) -> Result<String, PlanServiceError> {
        let plan = self.get_or_plan(records, req)?;
        Ok(serialize::offset_plan_to_string(
            &plan,
            &records.scaled_for(req.batch(), req.dtype()),
            req,
        ))
    }

    /// Seed the cache from a previously spilled plan, filing it under
    /// `(records fingerprint, req)`. The caller-supplied key is never
    /// trusted on its own: the record set embedded in the text is verified
    /// field by field — count, full id coverage (no dropped or duplicated
    /// lines), every `(size, first_op, last_op)` — against
    /// `records.scaled_for(req.batch(), req.dtype())`, plus checksum,
    /// feasibility, and (v2) the canonical order key
    /// in the header, which must match `req.order()`. A plan spilled for
    /// one model, another batch, or another execution order can therefore
    /// never be filed under this key.
    ///
    /// The text format carries no strategy tag, so the request's strategy
    /// names the slot the plan is filed under — loading a spill produced by
    /// a different strategy is not detectable (it is still a *valid* plan,
    /// just not that strategy's); keep spill files per strategy.
    pub fn load(
        &self,
        text: &str,
        records: &UsageRecords,
        req: &PlanRequest,
    ) -> Result<Arc<OffsetPlan>, PlanServiceError> {
        if !req.dynamic().is_static() {
            return Err(PlanServiceError::InvalidRequest(format!(
                "dynamic request '{req}' cannot be loaded from a spill; \
                 dynamic plans are in-memory only"
            )));
        }
        let key: Key = (serialize::records_fingerprint(records), *req);
        let scaled = records.scaled_for(req.batch(), req.dtype());
        let plan = Arc::new(
            serialize::offset_plan_from_str(text, &scaled, req).map_err(PlanServiceError::Load)?,
        );
        self.plans
            .lock()
            .unwrap()
            .insert(key, Arc::clone(&plan));
        self.retain_records(key.0, records);
        Ok(plan)
    }

    /// [`Self::load`] with an untyped `(batch, strategy, order)` triple.
    #[deprecated(since = "0.3.0", note = "build a PlanRequest and call load")]
    pub fn load_ordered(
        &self,
        text: &str,
        records: &UsageRecords,
        batch: usize,
        strategy: &str,
        order: OrderStrategy,
    ) -> Result<Arc<OffsetPlan>, PlanServiceError> {
        let req = PlanRequest::new().with_strategy(strategy)?.with_batch(batch).with_order(order);
        self.load(text, records, &req)
    }

    /// Persist every resident plan into `dir` in the plan-directory format
    /// (see [`super::serialize`]'s module docs): one
    /// `<fingerprint>-<request>.plan` file per cache key, each written to a
    /// `.tmp` sibling and atomically renamed into place, so a concurrent
    /// [`Self::warm_start`] never observes a torn file. Existing files for
    /// the same key are replaced. Dynamic plans are never persisted.
    pub fn persist_dir(&self, dir: &Path) -> std::io::Result<PersistReport> {
        std::fs::create_dir_all(dir)?;
        let plans: Vec<(Key, Arc<OffsetPlan>)> = self
            .plans
            .lock()
            .unwrap()
            .iter()
            .map(|(k, p)| (*k, Arc::clone(p)))
            .collect();
        let records = self.records.lock().unwrap().clone();
        let mut report = PersistReport::default();
        for ((fingerprint, req), plan) in plans {
            let Some(base) = records.get(&fingerprint) else {
                report.skipped += 1;
                continue;
            };
            let text = serialize::offset_plan_to_string(
                &plan,
                &base.scaled_for(req.batch(), req.dtype()),
                &req,
            );
            let name = serialize::plan_file_name(fingerprint, &req);
            // Per-process tmp name: two servers persisting into a shared
            // fleet directory must not clobber each other's half-written
            // file before the atomic rename.
            let tmp = dir.join(format!(".{name}.{}.tmp", std::process::id()));
            // Remove the tmp on every error path: a failed write or rename
            // must not leave a partial `.tmp` file in the directory for a
            // later warm start (or a directory listing) to trip on.
            let written = std::fs::write(&tmp, text.as_bytes())
                .and_then(|()| std::fs::rename(&tmp, dir.join(&name)));
            if let Err(e) = written {
                let _ = std::fs::remove_file(&tmp);
                return Err(e);
            }
            report.written += 1;
        }
        Ok(report)
    }

    /// Seed the cache from a plan directory: every file whose name carries
    /// `records`' fingerprint **and** `req.order()`'s canonical key is
    /// loaded through [`Self::load`] (full verification — checksum,
    /// field-by-field record match with exact id coverage, bounded header
    /// fields, order match, feasibility). Only the request's *order*
    /// dimension gates loading: every `(batch, strategy)` combination in
    /// the directory is seeded regardless of `req.batch()` /
    /// `req.strategy()`, because a warm start exists to cover the whole
    /// envelope a previous run planned. Files for other models are left
    /// alone; files written under a different execution order are skipped
    /// silently with their own counter, exactly like foreign files (their
    /// offsets are meaningless for this service's record lifetimes, but
    /// they belong to another valid configuration sharing the directory);
    /// files that name an unregistered strategy or fail verification are
    /// **skipped with a warning**, never served and never fatal. A missing
    /// directory is an ordinary cold start.
    ///
    /// After a warm start against the directory a previous run persisted,
    /// every previously-seen `(batch, strategy, order)` plan is a cache
    /// hit: zero planner invocations on the restart path.
    pub fn warm_start(
        &self,
        dir: &Path,
        records: &UsageRecords,
        req: &PlanRequest,
    ) -> std::io::Result<WarmStartReport> {
        let fingerprint = serialize::records_fingerprint(records);
        let mut report = WarmStartReport::default();
        let entries = match std::fs::read_dir(dir) {
            Ok(entries) => entries,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(report),
            Err(e) => return Err(e),
        };
        for entry in entries {
            let entry = entry?;
            let file_name = entry.file_name();
            let Some(name) = file_name.to_str() else { continue };
            if !name.ends_with(".plan") {
                continue; // .tmp leftovers, READMEs, ...
            }
            let file_req = match serialize::parse_plan_file_name(name) {
                Ok((file_fp, file_req)) if file_req.dynamic().is_static() => {
                    // The order check runs before the fingerprint check: a
                    // different order of the *same* model yields different
                    // records (and so a different fingerprint), which would
                    // otherwise be indistinguishable from a foreign model's
                    // file. Like foreign files, stale-order files belong to
                    // another valid serving configuration sharing the
                    // directory — counted in their own field, left intact,
                    // no per-file warning.
                    if file_req.order() != req.order() {
                        report.skipped_stale_order += 1;
                        continue;
                    }
                    if file_fp != fingerprint {
                        report.skipped_foreign += 1;
                        continue;
                    }
                    file_req
                }
                Ok(_) => {
                    // A dynamic-mode request has no business on disk —
                    // dynamic plans are in-memory only.
                    report.skipped_corrupt += 1;
                    self.warm_skipped.fetch_add(1, Ordering::Relaxed);
                    eprintln!("warm-start: skipping '{name}': dynamic plan file name");
                    continue;
                }
                Err(ParseRequestError::UnknownOrder(_)) => {
                    // Forward compatibility: an order strategy this build
                    // does not know (a newer build's plans sharing the
                    // directory) gates exactly like any other-order file —
                    // silent, counted, left intact, never suspect.
                    report.skipped_stale_order += 1;
                    continue;
                }
                Err(ParseRequestError::UnknownDtype(_)) => {
                    // Forward compatibility again: a quantized size class
                    // this build does not know. Counted in its own field,
                    // left intact, never suspect — pre-dtype names carry no
                    // `~` segment at all and parse as f32, so they never
                    // reach this arm.
                    report.skipped_stale_dtype += 1;
                    continue;
                }
                Err(ParseRequestError::UnknownStrategy(strategy)) => {
                    // Keep the pre-redesign taxonomy: order and fingerprint
                    // gate *before* the strategy check, so an unknown
                    // strategy in another configuration's file (different
                    // order, or another model's fingerprint) is not ours to
                    // warn about. The typed parse rejects the whole name at
                    // once, so re-derive those fields leniently here.
                    let stem = name.strip_suffix(".plan").unwrap_or(name);
                    // Any '+' in a name that parsed this far is a valid
                    // dynamic tag (malformed tags never reach the
                    // UnknownStrategy arm) — and dynamic plans must never
                    // exist on disk, so that trumps the stale strategy.
                    if stem.contains('+') {
                        report.skipped_corrupt += 1;
                        self.warm_skipped.fetch_add(1, Ordering::Relaxed);
                        eprintln!("warm-start: skipping '{name}': dynamic plan file name");
                        continue;
                    }
                    let file_order = stem.rsplit_once('@').map(|(_, o)| o);
                    if file_order != Some(req.order().key().as_str()) {
                        report.skipped_stale_order += 1;
                        continue;
                    }
                    let file_fp = stem
                        .split_once('-')
                        .and_then(|(h, _)| u64::from_str_radix(h, 16).ok());
                    if file_fp != Some(fingerprint) {
                        report.skipped_foreign += 1;
                        continue;
                    }
                    report.skipped_stale_strategy += 1;
                    self.warm_skipped.fetch_add(1, Ordering::Relaxed);
                    eprintln!(
                        "warm-start: skipping '{name}': strategy '{strategy}' is not a \
                         registered key"
                    );
                    continue;
                }
                Err(ParseRequestError::Malformed(_)) => {
                    report.skipped_corrupt += 1;
                    self.warm_skipped.fetch_add(1, Ordering::Relaxed);
                    eprintln!("warm-start: skipping '{name}': unparseable plan file name");
                    continue;
                }
            };
            let text = match std::fs::read_to_string(entry.path()) {
                Ok(text) => text,
                Err(e) => {
                    report.skipped_corrupt += 1;
                    self.warm_skipped.fetch_add(1, Ordering::Relaxed);
                    eprintln!("warm-start: skipping '{name}': {e}");
                    continue;
                }
            };
            match self.load(&text, records, &file_req) {
                Ok(_) => {
                    report.loaded += 1;
                    self.warm_loaded.fetch_add(1, Ordering::Relaxed);
                }
                Err(e) => {
                    report.skipped_corrupt += 1;
                    self.warm_skipped.fetch_add(1, Ordering::Relaxed);
                    eprintln!("warm-start: skipping '{name}': {e}");
                }
            }
        }
        Ok(report)
    }

    /// [`Self::warm_start`] with an untyped order.
    #[deprecated(since = "0.3.0", note = "build a PlanRequest and call warm_start")]
    pub fn warm_start_ordered(
        &self,
        dir: &Path,
        records: &UsageRecords,
        order: OrderStrategy,
    ) -> std::io::Result<WarmStartReport> {
        self.warm_start(dir, records, &PlanRequest::new().with_order(order))
    }

    /// Largest batch whose **planned** (not naive) footprint under the
    /// request's strategy fits in `budget_bytes`; 0 if even batch 1 does
    /// not fit. `records` and `req.order()` must agree (the caller passes
    /// the reordered graph's records), so the answer — and every probe
    /// plan it caches — is resolved under the same order the engine will
    /// serve. The request's batch is immaterial: the query *searches over*
    /// batches.
    ///
    /// Uses the bound `planned(b) >= b * max_tensor_size` to cap the search
    /// range, then binary-searches with real plans (each probe lands in the
    /// cache, so a later `get_or_plan` at the answer is free). Planned
    /// footprints grow monotonically with batch for every registry strategy
    /// — uniform scaling preserves every size comparison the heuristics
    /// make.
    pub fn max_servable_batch(
        &self,
        records: &UsageRecords,
        req: &PlanRequest,
        budget_bytes: usize,
    ) -> Result<usize, PlanServiceError> {
        let max_size = records.records.iter().map(|r| r.size).max().unwrap_or(0);
        max_batch_fitting(max_size, records.naive_total(), budget_bytes, |b| {
            Ok(self.get_or_plan(records, &req.with_batch(b))?.total <= budget_bytes)
        })
    }

    /// [`Self::max_servable_batch`] with an untyped `(strategy, order)`
    /// pair.
    #[deprecated(since = "0.3.0", note = "build a PlanRequest and call max_servable_batch")]
    pub fn max_servable_batch_ordered(
        &self,
        records: &UsageRecords,
        strategy: &str,
        budget_bytes: usize,
        order: OrderStrategy,
    ) -> Result<usize, PlanServiceError> {
        let req = PlanRequest::new().with_strategy(strategy)?.with_order(order);
        self.max_servable_batch(records, &req, budget_bytes)
    }
}

/// The monotone binary search behind every `max_servable_batch*` query:
/// the largest batch for which `fits` holds. `planned(b) >= b * max_size`
/// caps what can fit `budget_bytes`, and keeping `b * naive_total`
/// representable keeps every size, offset, and total a probe computes free
/// of overflow (all are bounded by the scaled naive sum). `usize::MAX` when
/// `max_size == 0` (nothing to place: any batch fits); 0 when even batch 1
/// does not fit. Every probe plans through the caller's cache, so a later
/// lookup at the answer is free.
fn max_batch_fitting(
    max_size: usize,
    naive_total: usize,
    budget_bytes: usize,
    mut fits: impl FnMut(usize) -> Result<bool, PlanServiceError>,
) -> Result<usize, PlanServiceError> {
    if max_size == 0 {
        return Ok(usize::MAX);
    }
    let cap = (budget_bytes / max_size).min(usize::MAX / naive_total);
    if cap == 0 {
        return Ok(0);
    }
    if !fits(1)? {
        return Ok(0);
    }
    // Invariant: fits(lo), !fits(hi). hi = cap + 1 cannot fit by the
    // max_size bound above.
    let (mut lo, mut hi) = (1usize, cap + 1);
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if fits(mid)? {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    Ok(lo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::example_records;

    /// Batch-1 greedy-size @ natural — the test workhorse.
    fn req() -> PlanRequest {
        PlanRequest::new()
    }

    #[test]
    fn second_lookup_is_a_hit_and_shares_the_plan() {
        let recs = example_records();
        let cache = PlanCache::new();
        let a = cache.get_or_plan(&recs, &req()).unwrap();
        let b = cache.get_or_plan(&recs, &req()).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn display_name_and_key_share_a_cache_slot() {
        let recs = example_records();
        let cache = PlanCache::new();
        let a = cache.get_or_plan(&recs, &req().with_strategy("greedy-size").unwrap()).unwrap();
        let b = cache
            .get_or_plan(&recs, &req().with_strategy("Greedy by Size").unwrap())
            .unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.misses(), 1);
    }

    #[test]
    fn distinct_batches_get_distinct_plans() {
        let recs = example_records();
        let cache = PlanCache::new();
        let p1 = cache.get_or_plan(&recs, &req()).unwrap();
        let p4 = cache.get_or_plan(&recs, &req().with_batch(4)).unwrap();
        assert_eq!(cache.misses(), 2);
        assert!(p4.total > p1.total);
        p4.validate(&recs.scaled(4)).unwrap();
    }

    #[test]
    fn unknown_strategy_is_rejected_at_request_construction() {
        // The stringly lookup failure now happens where the request is
        // built, before any cache traffic.
        let err = req().with_strategy("belady").unwrap_err();
        assert!(matches!(err, PlanServiceError::UnknownStrategy(_)));
    }

    #[test]
    fn mode_mismatched_requests_are_invalid() {
        let recs = example_records();
        let cache = PlanCache::new();
        // Static entry point refuses a dynamic request...
        assert!(matches!(
            cache.get_or_plan(&recs, &req().with_dynamic(DynamicMode::FullyResolved)),
            Err(PlanServiceError::InvalidRequest(_))
        ));
        // ...and the dynamic entry point refuses a static one.
        let dynamic = decode_dynamic();
        assert!(matches!(
            cache.get_or_plan_dynamic(&dynamic, &req()),
            Err(PlanServiceError::InvalidRequest(_))
        ));
        assert_eq!((cache.misses(), cache.dynamic_misses()), (0, 0));
    }

    #[test]
    fn spill_load_roundtrip_seeds_a_fresh_cache() {
        let recs = example_records();
        let b2 = req().with_batch(2);
        let warm = PlanCache::new();
        let text = warm.spill(&recs, &b2).unwrap();
        let cold = PlanCache::new();
        let loaded = cold.load(&text, &recs, &b2).unwrap();
        assert_eq!(*loaded, *warm.get_or_plan(&recs, &b2).unwrap());
        // The load seeded the cache: the next lookup is a hit, no planning.
        let again = cold.get_or_plan(&recs, &b2).unwrap();
        assert!(Arc::ptr_eq(&loaded, &again));
        assert_eq!(cold.misses(), 0);
        assert_eq!(cold.hits(), 1);
    }

    #[test]
    fn stale_spill_fails_to_load() {
        let recs = example_records();
        let cache = PlanCache::new();
        let text = cache.spill(&recs, &req()).unwrap();
        let mut changed = recs.clone();
        changed.records[0].size += 64;
        assert!(matches!(
            PlanCache::new().load(&text, &changed, &req()),
            Err(PlanServiceError::Load(_))
        ));
    }

    fn scratch_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("tensorarena-cache-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn persist_dir_then_warm_start_restores_every_plan_without_planning() {
        let dir = scratch_dir("roundtrip");
        let recs = example_records();
        let warm = PlanCache::new();
        for strategy in ["greedy-size", "greedy-breadth"] {
            for batch in [1usize, 2, 4] {
                let r = req().with_strategy(strategy).unwrap().with_batch(batch);
                warm.get_or_plan(&recs, &r).unwrap();
            }
        }
        let persisted = warm.persist_dir(&dir).unwrap();
        assert_eq!(persisted, PersistReport { written: 6, skipped: 0 });

        let cold = PlanCache::new();
        let report = cold.warm_start(&dir, &recs, &req()).unwrap();
        assert_eq!(report.loaded, 6, "{report:?}");
        assert_eq!(report.skipped(), 0, "{report:?}");
        assert_eq!(cold.warm_loaded(), 6);
        for strategy in ["greedy-size", "greedy-breadth"] {
            for batch in [1usize, 2, 4] {
                let r = req().with_strategy(strategy).unwrap().with_batch(batch);
                let a = cold.get_or_plan(&recs, &r).unwrap();
                let b = warm.get_or_plan(&recs, &r).unwrap();
                assert_eq!(*a, *b, "{strategy} batch {batch} diverged across restart");
            }
        }
        assert_eq!(cold.misses(), 0, "warm start must avoid every planner invocation");
        assert_eq!(cold.hits(), 6);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn warm_start_on_missing_dir_is_an_ordinary_cold_start() {
        let dir = scratch_dir("missing");
        let cache = PlanCache::new();
        let report = cache.warm_start(&dir, &example_records(), &req()).unwrap();
        assert_eq!(report, WarmStartReport::default());
        assert!(cache.is_empty());
    }

    #[test]
    fn warm_started_cache_can_re_persist() {
        // A restarted server that loads a plan dir and then shuts down must
        // be able to write the same dir back (records retained on load).
        let dir = scratch_dir("repersist");
        let recs = example_records();
        let warm = PlanCache::new();
        warm.get_or_plan(&recs, &req().with_batch(2)).unwrap();
        warm.persist_dir(&dir).unwrap();

        let cold = PlanCache::new();
        assert_eq!(cold.warm_start(&dir, &recs, &req()).unwrap().loaded, 1);
        let again = cold.persist_dir(&dir).unwrap();
        assert_eq!(again, PersistReport { written: 1, skipped: 0 });
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn order_is_a_cache_dimension_even_when_records_coincide() {
        // Identical records under two order keys occupy distinct slots: a
        // plan produced for the natural order must never answer an annealed
        // lookup (their persistence files are keyed apart too).
        let recs = example_records();
        let cache = PlanCache::new();
        let order = OrderStrategy::Annealed { seed: 1, budget: 5 };
        let a = cache.get_or_plan(&recs, &req()).unwrap();
        let b = cache.get_or_plan(&recs, &req().with_order(order)).unwrap();
        assert_eq!(*a, *b, "same records, same strategy: same plan content");
        assert_eq!(cache.misses(), 2, "but distinct cache slots");
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn ordered_persist_then_ordered_warm_start_roundtrips() {
        let dir = scratch_dir("ordered-roundtrip");
        let recs = example_records();
        let order = OrderStrategy::MemoryAware;
        let ordered = req().with_order(order);
        let warm = PlanCache::new();
        warm.get_or_plan(&recs, &ordered.with_batch(2)).unwrap();
        assert_eq!(warm.persist_dir(&dir).unwrap().written, 1);

        // A natural warm start skips the file with the stale-order counter…
        let cold = PlanCache::new();
        let report = cold.warm_start(&dir, &recs, &req()).unwrap();
        assert_eq!(
            (report.loaded, report.skipped_stale_order),
            (0, 1),
            "{report:?}"
        );
        // …which, like a foreign file, is not a *suspect* skip.
        assert_eq!(report.skipped(), 0);
        assert!(cold.is_empty());
        // …the matching order loads it without planning.
        let cold = PlanCache::new();
        let report = cold.warm_start(&dir, &recs, &ordered).unwrap();
        assert_eq!(report.loaded, 1, "{report:?}");
        cold.get_or_plan(&recs, &ordered.with_batch(2)).unwrap();
        assert_eq!(cold.misses(), 0, "ordered warm start must avoid the planner");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    fn decode_dynamic() -> DynamicRecords {
        use super::super::dynamic::DynamicRecord;
        use crate::records::UsageRecord;
        // A chain with a two-wave tail: sizes of records 2 and 3 resolve
        // after ops 2 and 4 execute.
        DynamicRecords::new(
            vec![
                DynamicRecord {
                    record: UsageRecord { id: 0, tensor: None, first_op: 0, last_op: 2, size: 128 },
                    known_at: 0,
                },
                DynamicRecord {
                    record: UsageRecord { id: 1, tensor: None, first_op: 1, last_op: 3, size: 64 },
                    known_at: 0,
                },
                DynamicRecord {
                    record: UsageRecord { id: 2, tensor: None, first_op: 3, last_op: 5, size: 192 },
                    known_at: 2,
                },
                DynamicRecord {
                    record: UsageRecord { id: 3, tensor: None, first_op: 5, last_op: 6, size: 64 },
                    known_at: 4,
                },
            ],
            7,
        )
    }

    #[test]
    fn decode_steps_with_unchanged_prefix_hit_the_dynamic_cache() {
        let cache = PlanCache::new();
        let dynamic = decode_dynamic();
        // A decode loop: one lookup per op. Steps between wave boundaries
        // share a resolved prefix, so the first loop plans once per
        // distinct prefix (waves 0, 2, 4 -> 3 misses)...
        for step in 0..dynamic.num_ops {
            cache
                .get_or_plan_dynamic(&dynamic, &req().with_dynamic(DynamicMode::Resolved(step)))
                .unwrap();
        }
        assert_eq!(cache.dynamic_misses(), 3, "one planner invocation per distinct prefix");
        let hits_after_first = cache.dynamic_hits();
        // ...and a second pass over the same resolved prefixes performs
        // zero planner invocations.
        for step in 0..dynamic.num_ops {
            cache
                .get_or_plan_dynamic(&dynamic, &req().with_dynamic(DynamicMode::Resolved(step)))
                .unwrap();
        }
        assert_eq!(cache.dynamic_misses(), 3, "second decode pass must not re-plan");
        assert_eq!(cache.dynamic_hits(), hits_after_first + dynamic.num_ops as u64);
        // Static counters are untouched: the dimensions do not bleed.
        assert_eq!((cache.hits(), cache.misses()), (0, 0));
    }

    #[test]
    fn fully_resolved_and_past_the_last_boundary_share_a_slot() {
        // The typed FullyResolved mode and a Resolved(op) past the last
        // wave fingerprint identically, so the old `usize::MAX` sentinel's
        // slot-sharing survives the typed redesign.
        let cache = PlanCache::new();
        let dynamic = decode_dynamic();
        let full = req().with_dynamic(DynamicMode::FullyResolved);
        cache.get_or_plan_dynamic(&dynamic, &full).unwrap();
        assert_eq!(cache.dynamic_misses(), 1);
        let last = req().with_dynamic(DynamicMode::Resolved(dynamic.num_ops - 1));
        cache.get_or_plan_dynamic(&dynamic, &last).unwrap();
        assert_eq!(cache.dynamic_misses(), 1, "past-the-last-boundary must be a hit");
        assert_eq!(cache.dynamic_hits(), 1);
    }

    #[test]
    fn dynamic_slots_are_fifo_bounded() {
        use super::super::dynamic::DynamicRecord;
        use crate::records::UsageRecord;
        let cache = PlanCache::new();
        let full = req().with_dynamic(DynamicMode::FullyResolved);
        let mk = |size: usize| {
            DynamicRecords::new(
                vec![DynamicRecord {
                    record: UsageRecord { id: 0, tensor: None, first_op: 0, last_op: 1, size },
                    known_at: 0,
                }],
                2,
            )
        };
        // One more distinct resolved prefix than the cap fits.
        for i in 0..=DYNAMIC_PLAN_CAP {
            cache.get_or_plan_dynamic(&mk(64 * (i + 1)), &full).unwrap();
        }
        let resident = cache.dynamic.lock().unwrap().plans.len();
        assert_eq!(resident, DYNAMIC_PLAN_CAP, "cap must bound the dynamic slots");
        // The newest entry is resident: re-requesting it is a pure hit…
        let misses = cache.dynamic_misses();
        cache
            .get_or_plan_dynamic(&mk(64 * (DYNAMIC_PLAN_CAP + 1)), &full)
            .unwrap();
        assert_eq!(cache.dynamic_misses(), misses);
        // …the oldest was evicted: recurring costs one re-plan, never a
        // wrong hit, and re-enters the window.
        let misses = cache.dynamic_misses();
        cache.get_or_plan_dynamic(&mk(64), &full).unwrap();
        assert_eq!(cache.dynamic_misses(), misses + 1);
    }

    #[test]
    fn complete_dynamic_plan_is_validated_and_batch_scaled() {
        let cache = PlanCache::new();
        let dynamic = decode_dynamic();
        let fullr = req().with_dynamic(DynamicMode::FullyResolved);
        let full = cache.get_or_plan_dynamic(&dynamic, &fullr).unwrap();
        assert!(full.is_complete());
        full.offset_plan()
            .unwrap()
            .validate(&dynamic.final_records())
            .unwrap();
        let b4 = cache.get_or_plan_dynamic(&dynamic, &fullr.with_batch(4)).unwrap();
        assert_eq!(b4.peak, 4 * full.peak, "uniform scaling scales the multi-pass peak");
        b4.offset_plan()
            .unwrap()
            .validate(&dynamic.scaled(4).final_records())
            .unwrap();
    }

    #[test]
    fn max_servable_batch_dynamic_resolves_under_the_worst_wave_peak() {
        let cache = PlanCache::new();
        let dynamic = decode_dynamic();
        let fullr = req().with_dynamic(DynamicMode::FullyResolved);
        let peak1 = cache.get_or_plan_dynamic(&dynamic, &fullr).unwrap().peak;
        let budget = 3 * peak1;
        let cap = cache.max_servable_batch_dynamic(&dynamic, &req(), budget).unwrap();
        assert!(cap >= 1);
        let at_cap = cache
            .get_or_plan_dynamic(&dynamic, &fullr.with_batch(cap))
            .unwrap()
            .peak;
        let above = cache
            .get_or_plan_dynamic(&dynamic, &fullr.with_batch(cap + 1))
            .unwrap()
            .peak;
        assert!(at_cap <= budget && above > budget);
        assert_eq!(
            cache.max_servable_batch_dynamic(&dynamic, &req(), peak1 - 1).unwrap(),
            0
        );
    }

    #[test]
    fn dtype_is_a_cache_dimension_with_a_proportionally_smaller_plan() {
        // Sizes divisible by 4 with 64-aligned quotients, so the i8
        // shrink is exactly 4x (no alignment slack) and the greedy-size
        // heuristic sees the same comparisons at both widths.
        let recs = UsageRecords::from_triples(&[(0, 2, 4096), (1, 3, 2048), (2, 5, 1024)]);
        let cache = PlanCache::new();
        let f32p = cache.get_or_plan(&recs, &req().with_batch(2)).unwrap();
        let i8p = cache
            .get_or_plan(&recs, &req().with_batch(2).with_dtype(Dtype::I8))
            .unwrap();
        assert_eq!(cache.misses(), 2, "each dtype occupies its own slot");
        assert_eq!(4 * i8p.total, f32p.total, "i8 arena is exactly 4x smaller");
        i8p.validate(&recs.scaled_for(2, Dtype::I8)).unwrap();
        // Budget admission resolves a strictly larger cap under i8.
        let budget = f32p.total;
        let cap_f32 = cache.max_servable_batch(&recs, &req(), budget).unwrap();
        let cap_i8 = cache
            .max_servable_batch(&recs, &req().with_dtype(Dtype::I8), budget)
            .unwrap();
        assert!(cap_f32 >= 1);
        assert!(cap_i8 >= 4 * cap_f32, "i8 cap {cap_i8} vs f32 cap {cap_f32}");
    }

    #[test]
    fn quantized_plans_persist_and_warm_start() {
        let dir = scratch_dir("dtype-roundtrip");
        let recs = example_records();
        let warm = PlanCache::new();
        let quant = req().with_dtype(Dtype::I8).with_batch(2);
        warm.get_or_plan(&recs, &quant).unwrap();
        assert_eq!(warm.persist_dir(&dir).unwrap().written, 1);
        // The warm-start request's dtype does not gate loading (only the
        // order does): the i8 plan seeds an f32-request warm start too.
        let cold = PlanCache::new();
        let report = cold.warm_start(&dir, &recs, &req()).unwrap();
        assert_eq!(report.loaded, 1, "{report:?}");
        cold.get_or_plan(&recs, &quant).unwrap();
        assert_eq!(cold.misses(), 0, "quantized warm start must avoid the planner");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn max_servable_batch_boundaries() {
        let recs = example_records();
        let cache = PlanCache::new();
        let t1 = cache.get_or_plan(&recs, &req()).unwrap().total;
        // Exactly the batch-1 footprint: batch 1 fits, batch 2 cannot.
        assert_eq!(cache.max_servable_batch(&recs, &req(), t1).unwrap(), 1);
        // Below the batch-1 footprint: nothing fits.
        assert_eq!(cache.max_servable_batch(&recs, &req(), t1 - 1).unwrap(), 0);
        // A generous budget fits proportionally more.
        let b = cache.max_servable_batch(&recs, &req(), 10 * t1).unwrap();
        assert!(b >= 10, "10x budget fits only batch {b}");
        assert!(cache.get_or_plan(&recs, &req().with_batch(b)).unwrap().total <= 10 * t1);
    }
}
