//! Ordered set of *disjoint* closed intervals with O(log n) overlap and
//! nearest-gap queries.
//!
//! §4.2 notes the greedy planners drop from O(kn²) to O(kn log n) "with an
//! interval tree for each shared object that stores the usage intervals of
//! all tensors". Because the intervals stored per shared object are mutually
//! disjoint by construction (that is the feasibility invariant), a balanced
//! ordered map keyed by interval start is a complete interval tree for this
//! use case: any query interval can overlap at most its predecessor and its
//! successors, so overlap tests and nearest-neighbour (gap) queries are
//! single map lookups.

use std::collections::BTreeMap;

/// A set of pairwise-disjoint closed intervals `[first, last]`.
#[derive(Debug, Clone, Default)]
pub struct DisjointIntervalSet {
    /// start -> end, all disjoint.
    map: BTreeMap<usize, usize>,
}

impl DisjointIntervalSet {
    /// Empty set.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of stored intervals.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True if no intervals are stored.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Does `[first, last]` intersect any stored interval?
    pub fn overlaps(&self, first: usize, last: usize) -> bool {
        // Predecessor (greatest start <= last): overlaps iff its end >= first.
        if let Some((_, &end)) = self.map.range(..=last).next_back() {
            if end >= first {
                return true;
            }
        }
        false
    }

    /// Insert `[first, last]`; panics in debug builds if it overlaps an
    /// existing interval (callers must check [`Self::overlaps`] first).
    pub fn insert(&mut self, first: usize, last: usize) {
        debug_assert!(first <= last);
        debug_assert!(
            !self.overlaps(first, last),
            "inserting overlapping interval [{first}, {last}]"
        );
        self.map.insert(first, last);
    }

    /// Distance from `[first, last]` to the nearest stored interval — the
    /// "time gap when shared object is not in use" minimized by Greedy by
    /// Size Improved (§4.4). `None` if the set is empty or the query
    /// overlaps a stored interval (no gap exists).
    pub fn nearest_gap(&self, first: usize, last: usize) -> Option<usize> {
        if self.is_empty() || self.overlaps(first, last) {
            return None;
        }
        let mut best: Option<usize> = None;
        // Nearest interval entirely to the left: end < first.
        if let Some((_, &end)) = self.map.range(..first).next_back() {
            debug_assert!(end < first);
            best = Some(first - end);
        }
        // Nearest interval entirely to the right: start > last.
        if let Some((&start, _)) = self.map.range(last + 1..).next() {
            let d = start - last;
            best = Some(best.map_or(d, |b| b.min(d)));
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlap_detection() {
        let mut s = DisjointIntervalSet::new();
        assert!(!s.overlaps(0, 10));
        s.insert(5, 8);
        assert!(s.overlaps(8, 9));
        assert!(s.overlaps(0, 5));
        assert!(s.overlaps(6, 7));
        assert!(!s.overlaps(0, 4));
        assert!(!s.overlaps(9, 12));
        s.insert(0, 2);
        assert!(s.overlaps(2, 3));
        assert!(!s.overlaps(3, 4));
    }

    #[test]
    fn nearest_gap_queries() {
        let mut s = DisjointIntervalSet::new();
        assert_eq!(s.nearest_gap(3, 4), None);
        s.insert(0, 2);
        s.insert(10, 12);
        // between: distance 1 to the left interval, 4 to the right
        assert_eq!(s.nearest_gap(3, 6), Some(1));
        assert_eq!(s.nearest_gap(6, 9), Some(1));
        assert_eq!(s.nearest_gap(4, 5), Some(2));
        // overlapping query -> None
        assert_eq!(s.nearest_gap(2, 3), None);
        // right side only
        assert_eq!(s.nearest_gap(14, 20), Some(2));
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn debug_insert_overlap_panics() {
        let mut s = DisjointIntervalSet::new();
        s.insert(0, 5);
        s.insert(5, 6);
    }
}
