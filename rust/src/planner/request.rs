//! `PlanRequest`: the one typed plan identity.
//!
//! Every plan in this crate is identified by five dimensions — offset
//! **strategy** (§5/§6), execution **order** (§7.1), **batch** (serving
//! scales every record uniformly), element **dtype** ([`Dtype`] — the
//! quantized size class every record footprint is divided by), and §7
//! **dynamic resolution state**
//! ([`DynamicMode`]). Before this type each dimension arrived as another
//! positional argument and another method suffix (`_ordered`, `_dynamic`,
//! `_dynamic_resolved`); a [`PlanRequest`] bundles them into a single
//! builder-style value that is simultaneously:
//!
//! * the **cache key** ([`PlanCache`](super::cache::PlanCache) memoizes one
//!   plan per `(records fingerprint, PlanRequest)`),
//! * the **`.plan` v2 file-name grammar** (the
//!   [`Display`](std::fmt::Display)/[`FromStr`] roundtrip below *is* the
//!   on-disk name format, prefixed by the records fingerprint — see
//!   [`super::serialize::plan_file_name`]), and
//! * the **construction argument** of every consumer
//!   ([`PlanService`](super::service::PlanService) methods,
//!   [`Executor::with_request`](crate::exec::Executor::with_request),
//!   `ExecutorEngine::for_request`, `PjrtEngine::with_request`).
//!
//! # Grammar
//!
//! ```text
//! request = "b" batch "-" strategy "@" order [ "~" dtype ] [ "+" dynamic ]
//! batch    = positive decimal integer
//! strategy = canonical registry key          ; e.g. "greedy-size"
//! order    = canonical order key             ; "natural" | "memory-aware" |
//!                                            ; "annealed-s<seed>-t<trials>"
//! dtype    = "f32" | "f16" | "i8"            ; absent = f32
//! dynamic  = "r" op-index | "full"           ; absent = static
//! ```
//!
//! `@`, `~`, and `+` never appear in strategy, order, or dtype keys, so the
//! last `@`, `~`, and `+` split unambiguously; batch is digits-only, so the
//! first `-` after it ends the batch field even though strategy keys
//! contain `-`. [`Dtype::F32`] requests render *no* dtype segment, so f32
//! requests (and static f32 requests in particular) render exactly the
//! pre-redesign `b<batch>-<strategy>@<order>` segment — every `.plan` v2
//! directory written before this type (or before the dtype dimension)
//! existed still parses as f32 and warm-starts today.
//!
//! # Example
//!
//! ```
//! use tensorarena::planner::{DynamicMode, OrderStrategy, PlanRequest};
//!
//! let req = PlanRequest::new()            // greedy-size @ natural, batch 1
//!     .with_strategy("greedy-breadth").unwrap()
//!     .with_order(OrderStrategy::MemoryAware)
//!     .with_batch(4);
//! assert_eq!(req.to_string(), "b4-greedy-breadth@memory-aware");
//! assert_eq!(req.to_string().parse::<PlanRequest>().unwrap(), req);
//!
//! // The §7 resolution state is part of the identity (and the grammar):
//! let step = req.with_dynamic(DynamicMode::Resolved(17));
//! assert_eq!(step.to_string(), "b4-greedy-breadth@memory-aware+r17");
//! assert!("b4-greedy-breadth@memory-aware+full".parse::<PlanRequest>().is_ok());
//! assert!("b0-greedy-size@natural".parse::<PlanRequest>().is_err()); // batch 0
//!
//! // So is the quantized size class; f32 renders no segment at all:
//! use tensorarena::planner::Dtype;
//! let quant = req.with_dtype(Dtype::I8);
//! assert_eq!(quant.to_string(), "b4-greedy-breadth@memory-aware~i8");
//! assert_eq!(req.with_dtype(Dtype::F32), req);
//! ```

use super::registry::{self, OrderStrategy};
use std::fmt;
use std::str::FromStr;

/// How much of a §7 dynamic-shape profile the request is resolved against
/// — the typed replacement for the old `resolved_through: usize` parameter
/// and its `usize::MAX` "everything" sentinel.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum DynamicMode {
    /// No dynamic dimension: an ordinary static offset plan.
    #[default]
    Static,
    /// The waves resolved once the given op has executed — a decode-step
    /// prefix plan (see
    /// [`MultiPassPlanner`](super::dynamic::MultiPassPlanner)).
    Resolved(usize),
    /// Every wave resolved: the complete multi-pass plan, whose worst-wave
    /// peak sizes arenas and answers budget admission.
    FullyResolved,
}

impl DynamicMode {
    /// True for [`DynamicMode::Static`].
    pub fn is_static(&self) -> bool {
        matches!(self, DynamicMode::Static)
    }

    /// Translate the retired `resolved_through: usize` convention —
    /// `usize::MAX` meant "every wave" — into the typed mode. Exists for
    /// the deprecated positional-argument shims; new code should name the
    /// mode directly.
    pub fn from_resolved_through(resolved_through: usize) -> Self {
        if resolved_through == usize::MAX {
            DynamicMode::FullyResolved
        } else {
            DynamicMode::Resolved(resolved_through)
        }
    }

    /// Whether a record whose size becomes known after op `known_at` is
    /// resolved under this mode. Statically-known records (`known_at ==
    /// 0`) are resolved under every mode.
    pub fn resolves(&self, known_at: usize) -> bool {
        match self {
            DynamicMode::Static => known_at == 0,
            DynamicMode::Resolved(op) => known_at <= *op,
            DynamicMode::FullyResolved => true,
        }
    }
}

/// Element size class a plan is sized and executed under — the quantized
/// tensor dimension of a [`PlanRequest`].
///
/// The planner never touches element values: the dtype only divides every
/// [`UsageRecords`](crate::records::UsageRecords) byte footprint
/// (re-aligned to the 64-byte grid) before planning, so arenas shrink ~4×
/// under [`Dtype::I8`] and ~2× under [`Dtype::F16`] and `--mem-budget`
/// admits proportionally larger batches. The executor quantizes
/// per-record at wave boundaries (`exec::ops::quant`) with the f32 scalar
/// kernels kept as the accuracy oracle (`tests/quant_diff.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Dtype {
    /// 32-bit float — exact, the default, and byte-identical to the
    /// pre-dtype grammar (renders no `~` segment).
    #[default]
    F32,
    /// 16-bit IEEE 754 half-precision float: ~2× smaller arenas.
    F16,
    /// 8-bit signed integer with per-record scale/zero-point: ~4× smaller
    /// arenas.
    I8,
}

impl Dtype {
    /// Every size class, in grammar order — for sweeps and tests.
    pub const ALL: [Dtype; 3] = [Dtype::F32, Dtype::F16, Dtype::I8];

    /// Canonical grammar key (`"f32"` | `"f16"` | `"i8"`).
    pub fn key(&self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::F16 => "f16",
            Dtype::I8 => "i8",
        }
    }

    /// Bytes per element (4, 2, or 1) — what divides the f32 record sizes.
    pub fn element_bytes(&self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::F16 => 2,
            Dtype::I8 => 1,
        }
    }
}

impl fmt::Display for Dtype {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.key())
    }
}

impl FromStr for Dtype {
    type Err = ParseRequestError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "f32" => Ok(Dtype::F32),
            "f16" => Ok(Dtype::F16),
            "i8" => Ok(Dtype::I8),
            other => Err(ParseRequestError::UnknownDtype(other.to_string())),
        }
    }
}

/// A typed plan identity: strategy × order × batch × dtype × dynamic mode.
///
/// Construct with [`PlanRequest::new`] (or
/// [`PlanService::request`](super::service::PlanService::request) to seed
/// the service's default strategy) and refine with the `with_*` builders —
/// each returns a new value, so a base request for a serving configuration
/// can be re-batched or re-resolved per lookup without mutation. See the
/// [module docs](crate::planner::request) for the grammar its
/// [`Display`](std::fmt::Display)/[`FromStr`] pair speaks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PlanRequest {
    /// Canonical registry key — typed at construction, so no lookup on the
    /// hot path ever re-parses a strategy string.
    strategy: &'static str,
    order: OrderStrategy,
    batch: usize,
    dtype: Dtype,
    dynamic: DynamicMode,
}

impl Default for PlanRequest {
    fn default() -> Self {
        Self::new()
    }
}

/// Why a string failed to parse as a [`PlanRequest`] (or as a `.plan` file
/// name). The cases are distinguished because plan-directory readers count
/// them differently: an unknown strategy or order key is a *stale* file
/// (another build's plans sharing the directory — forward compatibility),
/// anything structurally wrong is corrupt.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ParseRequestError {
    /// The grammar parsed but the strategy key is not registered.
    UnknownStrategy(String),
    /// The grammar parsed but the order key is not recognized (e.g. a
    /// newer build's order strategy sharing the directory).
    UnknownOrder(String),
    /// The grammar parsed but the dtype key after `~` is not a known size
    /// class (a newer build's quantization sharing the directory — a
    /// forward-compatibility *skip*, not corruption).
    UnknownDtype(String),
    /// The text does not speak the request grammar at all (this includes
    /// pre-v2 names without an `@<order>` segment and batch 0).
    Malformed(String),
}

impl fmt::Display for ParseRequestError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ParseRequestError::UnknownStrategy(s) => {
                write!(f, "unknown offset strategy '{s}' in plan request")
            }
            ParseRequestError::UnknownOrder(o) => {
                write!(f, "unknown order key '{o}' in plan request")
            }
            ParseRequestError::UnknownDtype(d) => {
                write!(f, "unknown dtype key '{d}' in plan request")
            }
            ParseRequestError::Malformed(s) => write!(f, "malformed plan request '{s}'"),
        }
    }
}

impl std::error::Error for ParseRequestError {}

impl PlanRequest {
    /// The §6-recommended default strategy every fresh request starts
    /// from (also
    /// [`PlanService::DEFAULT_STRATEGY`](super::service::PlanService::DEFAULT_STRATEGY)).
    pub const DEFAULT_STRATEGY: &'static str = "greedy-size";

    /// Batch-1 static request for the default strategy under the natural
    /// order.
    pub fn new() -> Self {
        PlanRequest {
            strategy: Self::DEFAULT_STRATEGY,
            order: OrderStrategy::Natural,
            batch: 1,
            dtype: Dtype::F32,
            dynamic: DynamicMode::Static,
        }
    }

    /// Replace the strategy (any registry key or Table-2 display name; the
    /// canonical key is stored).
    pub fn with_strategy(
        self,
        strategy: &str,
    ) -> Result<Self, super::cache::PlanServiceError> {
        let key = registry::offset_key(strategy).ok_or_else(|| {
            super::cache::PlanServiceError::UnknownStrategy(strategy.to_string())
        })?;
        Ok(PlanRequest { strategy: key, ..self })
    }

    /// Replace the strategy with an already-canonical registry key.
    pub(crate) fn with_strategy_key(self, key: &'static str) -> Self {
        PlanRequest { strategy: key, ..self }
    }

    /// Replace the execution order.
    pub fn with_order(self, order: OrderStrategy) -> Self {
        PlanRequest { order, ..self }
    }

    /// Replace the batch (clamped to at least 1 — batch-0 plans do not
    /// exist).
    pub fn with_batch(self, batch: usize) -> Self {
        PlanRequest { batch: batch.max(1), ..self }
    }

    /// Replace the §7 dynamic resolution state.
    pub fn with_dynamic(self, dynamic: DynamicMode) -> Self {
        PlanRequest { dynamic, ..self }
    }

    /// Replace the quantized element size class.
    pub fn with_dtype(self, dtype: Dtype) -> Self {
        PlanRequest { dtype, ..self }
    }

    /// Canonical registry key of the offset strategy.
    pub fn strategy(&self) -> &'static str {
        self.strategy
    }

    /// Execution-order strategy.
    pub fn order(&self) -> OrderStrategy {
        self.order
    }

    /// Batch size the records are scaled to (≥ 1).
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// §7 dynamic resolution state.
    pub fn dynamic(&self) -> DynamicMode {
        self.dynamic
    }

    /// Quantized element size class ([`Dtype::F32`] unless set).
    pub fn dtype(&self) -> Dtype {
        self.dtype
    }
}

impl fmt::Display for PlanRequest {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "b{}-{}@{}", self.batch, self.strategy, self.order.key())?;
        if self.dtype != Dtype::F32 {
            write!(f, "~{}", self.dtype.key())?;
        }
        match self.dynamic {
            DynamicMode::Static => Ok(()),
            DynamicMode::Resolved(op) => write!(f, "+r{op}"),
            DynamicMode::FullyResolved => write!(f, "+full"),
        }
    }
}

impl FromStr for PlanRequest {
    type Err = ParseRequestError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let malformed = || ParseRequestError::Malformed(s.to_string());
        // The last '+' (never part of a strategy or order key) splits off
        // the optional dynamic segment.
        let (core, dynamic) = match s.rsplit_once('+') {
            None => (s, DynamicMode::Static),
            Some((core, "full")) => (core, DynamicMode::FullyResolved),
            Some((core, tail)) => {
                let op = tail
                    .strip_prefix('r')
                    .filter(|d| !d.is_empty() && d.bytes().all(|b| b.is_ascii_digit()))
                    .and_then(|d| d.parse().ok())
                    .ok_or_else(malformed)?;
                (core, DynamicMode::Resolved(op))
            }
        };
        // The last '~' (never part of a strategy or order key) splits off
        // the optional dtype segment; an unknown key is a typed
        // forward-compatibility skip, not corruption.
        let (core, dtype) = match core.rsplit_once('~') {
            None => (core, Dtype::F32),
            Some((_, key)) if key.is_empty() || key.contains(char::is_whitespace) => {
                return Err(malformed());
            }
            Some((head, key)) => (head, key.parse::<Dtype>()?),
        };
        // The last '@' splits strategy from order.
        let (rest, order_key) = core.rsplit_once('@').ok_or_else(malformed)?;
        if order_key.is_empty() || order_key.contains(char::is_whitespace) {
            return Err(malformed());
        }
        let order = registry::order_strategy(order_key)
            .ok_or_else(|| ParseRequestError::UnknownOrder(order_key.to_string()))?;
        // "b<batch>-<strategy>": batch is digits-only, so the first '-'
        // ends it even though strategy keys contain '-'.
        let rest = rest.strip_prefix('b').ok_or_else(malformed)?;
        let (batch_str, strategy) = rest.split_once('-').ok_or_else(malformed)?;
        if batch_str.is_empty() || !batch_str.bytes().all(|b| b.is_ascii_digit()) {
            return Err(malformed());
        }
        let batch: usize = batch_str.parse().map_err(|_| malformed())?;
        if batch == 0 || strategy.is_empty() {
            return Err(malformed());
        }
        let strategy = registry::offset_key(strategy)
            .ok_or_else(|| ParseRequestError::UnknownStrategy(strategy.to_string()))?;
        Ok(PlanRequest { strategy, order, batch, dtype, dynamic })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_and_accessors() {
        let req = PlanRequest::new();
        assert_eq!(req.strategy(), "greedy-size");
        assert_eq!(req.order(), OrderStrategy::Natural);
        assert_eq!(req.batch(), 1);
        assert!(req.dynamic().is_static());
        // Display names canonicalize; unknown strategies are typed errors.
        assert_eq!(req.with_strategy("Greedy by Breadth").unwrap().strategy(), "greedy-breadth");
        assert!(req.with_strategy("belady").is_err());
        // Batch 0 clamps rather than panicking.
        assert_eq!(req.with_batch(0).batch(), 1);
    }

    #[test]
    fn display_roundtrips_through_fromstr() {
        for strategy in registry::OFFSET_KEYS {
            for order in [
                OrderStrategy::Natural,
                OrderStrategy::MemoryAware,
                OrderStrategy::Annealed { seed: 7, budget: 25 },
            ] {
                for batch in [1usize, 2, 64] {
                    for dtype in Dtype::ALL {
                        for dynamic in [
                            DynamicMode::Static,
                            DynamicMode::Resolved(0),
                            DynamicMode::Resolved(123),
                            DynamicMode::FullyResolved,
                        ] {
                            let req = PlanRequest::new()
                                .with_strategy(strategy)
                                .unwrap()
                                .with_order(order)
                                .with_batch(batch)
                                .with_dtype(dtype)
                                .with_dynamic(dynamic);
                            let text = req.to_string();
                            assert_eq!(text.parse::<PlanRequest>(), Ok(req), "{text}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn static_display_matches_the_pre_redesign_grammar() {
        // Backwards compatibility anchor: the static rendering is exactly
        // the `b<batch>-<strategy>@<order>` segment of pre-PR-5 plan-file
        // names, so old plan directories keep warm-starting.
        let req = PlanRequest::new()
            .with_strategy("greedy-breadth")
            .unwrap()
            .with_order(OrderStrategy::Annealed { seed: 42, budget: 100 })
            .with_batch(8);
        assert_eq!(req.to_string(), "b8-greedy-breadth@annealed-s42-t100");
    }

    #[test]
    fn malformed_and_stale_requests_are_distinguished() {
        // Stale: grammar fine, strategy or order unknown (forward
        // compatibility — another build's plans sharing a directory).
        assert_eq!(
            "b1-belady@natural".parse::<PlanRequest>(),
            Err(ParseRequestError::UnknownStrategy("belady".into()))
        );
        assert_eq!(
            "b1-greedy-size@profile-guided".parse::<PlanRequest>(),
            Err(ParseRequestError::UnknownOrder("profile-guided".into()))
        );
        // Malformed: everything else.
        for bad in [
            "",
            "b1-greedy-size",              // v1-era: no order segment
            "b0-greedy-size@natural",      // batch 0
            "b-greedy-size@natural",       // empty batch
            "bx-greedy-size@natural",      // non-numeric batch
            "b+1-greedy-size@natural",     // signed batch
            "b1-@natural",                 // empty strategy
            "b1-greedy-size@",             // empty order
            "1-greedy-size@natural",       // missing 'b'
            "b1-greedy-size@natural+r",    // dynamic tag without an index
            "b1-greedy-size@natural+rx",   // non-numeric index
            "b1-greedy-size@natural+half", // unknown dynamic tag
            "b1-greedy-size@natural~",     // empty dtype segment
        ] {
            assert!(
                matches!(bad.parse::<PlanRequest>(), Err(ParseRequestError::Malformed(_))),
                "{bad:?}"
            );
        }
    }

    #[test]
    fn dtype_segment_grammar() {
        // f32 is the default and renders no segment — byte-identical to
        // the pre-dtype grammar — but an explicit `~f32` still parses.
        let base = PlanRequest::new().with_batch(2);
        assert_eq!(base.dtype(), Dtype::F32);
        assert_eq!(base.to_string(), "b2-greedy-size@natural");
        assert_eq!("b2-greedy-size@natural~f32".parse::<PlanRequest>(), Ok(base));
        // Non-f32 dtypes render before the dynamic segment and roundtrip.
        let quant = base.with_dtype(Dtype::I8).with_dynamic(DynamicMode::FullyResolved);
        assert_eq!(quant.to_string(), "b2-greedy-size@natural~i8+full");
        assert_eq!(quant.to_string().parse::<PlanRequest>(), Ok(quant));
        assert_eq!(
            base.with_dtype(Dtype::F16).to_string(),
            "b2-greedy-size@natural~f16"
        );
        // Unknown dtype keys are a typed forward-compatibility skip.
        assert_eq!(
            "b2-greedy-size@natural~i4".parse::<PlanRequest>(),
            Err(ParseRequestError::UnknownDtype("i4".into()))
        );
        // Element widths divide the f32 baseline.
        assert_eq!(
            Dtype::ALL.map(|d| d.element_bytes()),
            [4, 2, 1]
        );
    }

    #[test]
    fn dynamic_mode_resolution_predicate() {
        assert!(DynamicMode::Static.resolves(0));
        assert!(!DynamicMode::Static.resolves(1));
        assert!(DynamicMode::Resolved(3).resolves(3));
        assert!(!DynamicMode::Resolved(3).resolves(4));
        assert!(DynamicMode::FullyResolved.resolves(usize::MAX));
    }
}
