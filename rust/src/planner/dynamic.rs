//! Multi-pass planning for dynamically-sized tensors — §7.
//!
//! The paper's algorithms assume every intermediate tensor size is known
//! up front, which fails for e.g. recurrent networks: "For such cases, the
//! algorithms need to be run multiple times, saving information about
//! allocation from all runs in one place. The first run will allocate only
//! those tensors whose sizes are known at the beginning, and the second run
//! will allocate those tensors whose sizes become known after calculation
//! of the first dynamic tensor, etc."
//!
//! [`MultiPassPlanner`] implements exactly that protocol on top of the
//! Algorithm-3 gap logic: earlier passes' placements are frozen, later
//! passes best-fit around them.

use super::offset::GreedyBySize;
use super::{OffsetPlan, OffsetPlanner};
use crate::records::{UsageRecord, UsageRecords};

/// A usage record whose size becomes known only once op `known_at` has
/// executed (`known_at == 0` means statically known).
#[derive(Debug, Clone, Copy)]
pub struct DynamicRecord {
    pub record: UsageRecord,
    pub known_at: usize,
}

/// Outcome of multi-pass planning.
#[derive(Debug, Clone)]
pub struct MultiPassPlan {
    /// Final offsets, indexed by record id.
    pub plan: OffsetPlan,
    /// Number of planner passes executed (= distinct `known_at` values).
    pub passes: usize,
    /// Arena high-water mark after each pass.
    pub growth: Vec<usize>,
}

/// §7 multi-pass offset planner. Records are planned in waves of increasing
/// `known_at`; each wave is size-ordered and best-fit placed around every
/// previously frozen allocation (which may belong to tensors whose usage
/// intervals already passed — their storage cannot be re-planned because
/// inference is already running when later sizes resolve).
#[derive(Debug, Default, Clone, Copy)]
pub struct MultiPassPlanner;

impl MultiPassPlanner {
    /// Plan all records. The returned offsets satisfy the usual §5
    /// feasibility (validated against the *final* sizes).
    pub fn plan(&self, dynamic: &[DynamicRecord], num_ops: usize) -> MultiPassPlan {
        let records = UsageRecords {
            records: dynamic.iter().map(|d| d.record).collect(),
            num_ops,
        };
        let mut waves: Vec<usize> = dynamic.iter().map(|d| d.known_at).collect();
        waves.sort_unstable();
        waves.dedup();

        let mut store = super::offset::OffsetStore::new(&records);
        let mut growth = Vec::with_capacity(waves.len());
        let mut high = 0usize;
        for &wave in &waves {
            // Newly-known records, size-descending (Algorithm 3's order).
            let mut ids: Vec<usize> = dynamic
                .iter()
                .enumerate()
                .filter(|(_, d)| d.known_at == wave)
                .map(|(i, _)| i)
                .collect();
            crate::records::profile::sort_ids_by_size_desc(&records.records, &mut ids);
            for id in ids {
                let r = &records.records[id];
                let off = store.best_fit_offset(r);
                store.place(r, off);
                high = high.max(off + r.size);
            }
            growth.push(high);
        }
        MultiPassPlan {
            plan: store.into_plan(),
            passes: waves.len(),
            growth,
        }
    }

    /// Footprint penalty of not knowing sizes up front: ratio of the
    /// multi-pass arena to the oracle single-pass arena.
    pub fn overhead_vs_oracle(&self, dynamic: &[DynamicRecord], num_ops: usize) -> f64 {
        let records = UsageRecords {
            records: dynamic.iter().map(|d| d.record).collect(),
            num_ops,
        };
        let oracle = GreedyBySize.plan(&records).total_size();
        let multi = self.plan(dynamic, num_ops).plan.total_size();
        if oracle == 0 {
            1.0
        } else {
            multi as f64 / oracle as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::records::UsageRecords;

    fn rec(id: usize, f: usize, l: usize, s: usize) -> UsageRecord {
        UsageRecord { id, tensor: None, first_op: f, last_op: l, size: s }
    }

    #[test]
    fn all_static_equals_single_pass() {
        let dynamic: Vec<DynamicRecord> = [(0, 1, 32), (1, 2, 28), (2, 5, 8), (3, 4, 40)]
            .iter()
            .enumerate()
            .map(|(i, &(f, l, s))| DynamicRecord { record: rec(i, f, l, s), known_at: 0 })
            .collect();
        let mp = MultiPassPlanner.plan(&dynamic, 6);
        assert_eq!(mp.passes, 1);
        let records = UsageRecords {
            records: dynamic.iter().map(|d| d.record).collect(),
            num_ops: 6,
        };
        mp.plan.validate(&records).unwrap();
        assert_eq!(
            mp.plan.total_size(),
            super::GreedyBySize.plan(&records).total_size()
        );
    }

    #[test]
    fn late_known_sizes_plan_in_second_pass() {
        let dynamic = vec![
            DynamicRecord { record: rec(0, 0, 2, 100), known_at: 0 },
            DynamicRecord { record: rec(1, 1, 3, 50), known_at: 0 },
            // becomes known after op 1 executes (e.g. LSTM output length)
            DynamicRecord { record: rec(2, 2, 4, 70), known_at: 1 },
        ];
        let mp = MultiPassPlanner.plan(&dynamic, 5);
        assert_eq!(mp.passes, 2);
        assert!(mp.growth[0] <= mp.growth[1]);
        let records = UsageRecords {
            records: dynamic.iter().map(|d| d.record).collect(),
            num_ops: 5,
        };
        mp.plan.validate(&records).unwrap();
    }

    #[test]
    fn overhead_is_at_least_one_ish() {
        let dynamic = vec![
            DynamicRecord { record: rec(0, 0, 2, 10), known_at: 0 },
            DynamicRecord { record: rec(1, 3, 4, 10), known_at: 2 },
        ];
        let ratio = MultiPassPlanner.overhead_vs_oracle(&dynamic, 5);
        assert!(ratio >= 0.999);
    }
}
