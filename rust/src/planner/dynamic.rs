//! Multi-pass planning for dynamically-sized tensors — §7.
//!
//! The paper's algorithms assume every intermediate tensor size is known
//! up front, which fails for e.g. recurrent networks: "For such cases, the
//! algorithms need to be run multiple times, saving information about
//! allocation from all runs in one place. The first run will allocate only
//! those tensors whose sizes are known at the beginning, and the second run
//! will allocate those tensors whose sizes become known after calculation
//! of the first dynamic tensor, etc."
//!
//! [`MultiPassPlanner`] implements exactly that protocol on top of the
//! Algorithm-3 gap logic: earlier passes' placements are frozen, later
//! passes best-fit around them. Placement within a wave is Algorithm 3's
//! size-descending best-fit, so a fully static record set degenerates to
//! the §5 Greedy-by-Size plan.
//!
//! # The freeze invariant (what makes decode-step caching sound)
//!
//! A wave's placements depend only on the placements of *earlier* waves
//! (inference is already running when later sizes resolve, so earlier
//! storage cannot move). Therefore the plan of the resolved prefix — waves
//! whose `known_at` has passed — is byte-identical whether or not any later
//! wave is known yet: [`MultiPassPlanner::plan_resolved`] at wave *w* is a
//! frozen prefix of every fuller plan of the same records. This is the
//! property that lets the [`PlanCache`](super::cache::PlanCache) key
//! decode-step re-plans by the fingerprint of the resolved-size prefix and
//! answer repeats from cache with zero planner invocations (see
//! [`PlanCache::get_or_plan_dynamic`]).
//!
//! [`PlanCache::get_or_plan_dynamic`]:
//!   super::cache::PlanCache::get_or_plan_dynamic

use super::offset::GreedyBySize;
use super::request::DynamicMode;
use super::{OffsetPlan, OffsetPlanner};
use crate::records::{UsageRecord, UsageRecords};

/// A usage record whose size becomes known only once op `known_at` has
/// executed (`known_at == 0` means statically known).
#[derive(Debug, Clone, Copy)]
pub struct DynamicRecord {
    /// The underlying usage record, carrying the *final* (resolved) size.
    pub record: UsageRecord,
    /// Index of the op whose execution resolves this record's size; 0 for
    /// statically-known sizes. Must be `< first_op` for the wave-aware
    /// executor to serve the record (the offset has to exist before the
    /// producer runs).
    pub known_at: usize,
}

/// A full set of [`DynamicRecord`]s plus the op count — the §7 analogue of
/// [`UsageRecords`], and the input to every dynamic-planning entry point
/// ([`MultiPassPlanner`], the dynamic slots of the plan cache, the
/// wave-aware executor).
#[derive(Debug, Clone)]
pub struct DynamicRecords {
    /// The records; `records[i].record.id == i` (dense, like
    /// [`UsageRecords`]).
    pub records: Vec<DynamicRecord>,
    /// Number of ops in the graph the records were extracted from.
    pub num_ops: usize,
}

impl DynamicRecords {
    /// Build from records; asserts ids are dense and every `known_at` is a
    /// valid op index.
    pub fn new(records: Vec<DynamicRecord>, num_ops: usize) -> Self {
        for (i, d) in records.iter().enumerate() {
            assert_eq!(d.record.id, i, "dynamic record ids must be dense");
            assert!(
                num_ops == 0 || d.known_at < num_ops,
                "record {i}: known_at {} past the {num_ops}-op range",
                d.known_at
            );
        }
        DynamicRecords { records, num_ops }
    }

    /// The decode-tail profile: every record produced at or after `from_op`
    /// resolves its size just in time — one op before its producer runs
    /// (`known_at = first_op - 1`) — modelling an autoregressive tail whose
    /// step sizes become known mid-inference. Records produced before
    /// `from_op` (and any record produced by op 0) stay static.
    pub fn decode_tail(records: &UsageRecords, from_op: usize) -> Self {
        Self::new(
            records
                .records
                .iter()
                .map(|r| DynamicRecord {
                    record: *r,
                    known_at: if r.first_op >= from_op.max(1) { r.first_op - 1 } else { 0 },
                })
                .collect(),
            records.num_ops,
        )
    }

    /// The oracle view: the same records with every (final) size known up
    /// front — what a size-omniscient single-pass planner would consume,
    /// and what the complete multi-pass plan is validated against.
    pub fn final_records(&self) -> UsageRecords {
        UsageRecords {
            records: self.records.iter().map(|d| d.record).collect(),
            num_ops: self.num_ops,
        }
    }

    /// The same records with every size multiplied by `batch` (liveness and
    /// `known_at` untouched) — mirrors [`UsageRecords::scaled`].
    pub fn scaled(&self, batch: usize) -> DynamicRecords {
        assert!(batch > 0, "batch must be positive");
        DynamicRecords {
            records: self
                .records
                .iter()
                .map(|d| DynamicRecord {
                    record: UsageRecord {
                        size: d
                            .record
                            .size
                            .checked_mul(batch)
                            .expect("batch-scaled size overflows"),
                        ..d.record
                    },
                    known_at: d.known_at,
                })
                .collect(),
            num_ops: self.num_ops,
        }
    }

    /// The same records scaled for `batch` lanes of `dtype` elements —
    /// mirrors [`UsageRecords::scaled_for`]: per-sample sizes first shrink
    /// by the dtype's element width (re-aligned to the 64-byte grid), then
    /// multiply by `batch`. Liveness and `known_at` are untouched;
    /// [`super::Dtype::F32`] is the identity with [`DynamicRecords::scaled`].
    pub fn scaled_for(&self, batch: usize, dtype: super::Dtype) -> DynamicRecords {
        if dtype == super::Dtype::F32 {
            return self.scaled(batch);
        }
        assert!(batch > 0, "batch must be positive");
        let divisor = 4 / dtype.element_bytes();
        DynamicRecords {
            records: self
                .records
                .iter()
                .map(|d| DynamicRecord {
                    record: UsageRecord {
                        size: (d.record.size.div_ceil(divisor).div_ceil(64) * 64)
                            .checked_mul(batch)
                            .expect("batch-scaled size overflows"),
                        ..d.record
                    },
                    known_at: d.known_at,
                })
                .collect(),
            num_ops: self.num_ops,
        }
    }

    /// Distinct `known_at` values, ascending — one planner wave per entry.
    pub fn waves(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.records.iter().map(|d| d.known_at).collect();
        w.sort_unstable();
        w.dedup();
        w
    }

    /// Distinct *non-zero* `known_at` values, ascending: the op indices
    /// after which the wave-aware executor must re-resolve offsets.
    pub fn boundaries(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self
            .records
            .iter()
            .map(|d| d.known_at)
            .filter(|&k| k > 0)
            .collect();
        w.sort_unstable();
        w.dedup();
        w
    }

    /// Records whose size resolves only mid-inference (`known_at > 0`).
    pub fn num_dynamic(&self) -> usize {
        self.records.iter().filter(|d| d.known_at > 0).count()
    }

    /// Peak simultaneous block demand of the decode tail under paged
    /// execution with `block_words`-word blocks: the maximum over ops of
    /// the summed block counts (`ceil(size / 4 / block_words)`) of every
    /// *dynamic* record (`known_at > 0`) live at that op. Under paging a
    /// tail record holds blocks exactly over its usage interval — mapped
    /// at its producing wave boundary, freed at its last use — so this,
    /// not the worst-wave arena peak, is what budget admission charges
    /// the tail. Computed on these records' sizes as-is. This is the
    /// demand of **one** lane: the sequential batch loop maps one lane's
    /// stripes at a time, so per-sample records give its demand for any
    /// batch, while continuous serving keeps several lanes' tails mapped
    /// at once — charge [`Self::tail_block_demand_lanes`] there.
    pub fn tail_block_demand(&self, block_words: usize) -> usize {
        assert!(block_words > 0, "block size must be positive");
        (0..self.num_ops)
            .map(|op| {
                self.records
                    .iter()
                    .filter(|d| {
                        d.known_at > 0 && d.record.first_op <= op && op <= d.record.last_op
                    })
                    .map(|d| (d.record.size / 4).div_ceil(block_words))
                    .sum()
            })
            .max()
            .unwrap_or(0)
    }

    /// Peak simultaneous block demand of `lanes` concurrently-decoding
    /// requests: continuous serving admits each request into its own lane
    /// with a private tail block mapping, so at a wave boundary up to
    /// `lanes` tails hold their worst-op block sets at once. Each lane
    /// maps the same per-sample records onto disjoint block regions, so
    /// the bound is exactly `lanes ×` the single-lane demand (saturating;
    /// the budget walk treats overflow as unservable).
    pub fn tail_block_demand_lanes(&self, block_words: usize, lanes: usize) -> usize {
        self.tail_block_demand(block_words).saturating_mul(lanes)
    }

    /// Number of records.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// True if there are no records.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }
}

/// Outcome of multi-pass planning: offsets for every record whose wave has
/// been planned, possibly a *prefix* plan when later waves are still
/// unresolved (see [`MultiPassPlanner::plan_resolved`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MultiPassPlan {
    /// `offsets[record_id]` = byte offset inside the arena; `None` while
    /// the record's wave is unresolved.
    pub offsets: Vec<Option<usize>>,
    /// Arena high-water mark over every placed record. For a complete plan
    /// this is the arena size — the **worst-wave peak** budget admission
    /// must resolve against, since growth is monotone across waves.
    pub peak: usize,
    /// Number of planner passes executed (= distinct resolved `known_at`
    /// values).
    pub passes: usize,
    /// Record ids placed in each planned wave (waves ascending by
    /// `known_at`), in placement (Algorithm-3 size-descending) order.
    pub wave_records: Vec<Vec<usize>>,
    /// Arena high-water mark after each planned wave (monotone).
    pub growth: Vec<usize>,
}

impl MultiPassPlan {
    /// True once every record is placed (all waves resolved).
    pub fn is_complete(&self) -> bool {
        self.offsets.iter().all(Option::is_some)
    }

    /// Offset of one record, `None` while its wave is unresolved.
    pub fn offset_of(&self, record_id: usize) -> Option<usize> {
        self.offsets.get(record_id).copied().flatten()
    }

    /// Collapse a *complete* plan into an ordinary [`OffsetPlan`] (what the
    /// arena is built from); `None` if any wave is still unresolved.
    pub fn offset_plan(&self) -> Option<OffsetPlan> {
        let offsets: Option<Vec<usize>> = self.offsets.iter().copied().collect();
        offsets.map(|offsets| OffsetPlan { offsets, total: self.peak })
    }
}

/// §7 multi-pass offset planner. Records are planned in waves of increasing
/// `known_at`; each wave is size-ordered and best-fit placed around every
/// previously frozen allocation (which may belong to tensors whose usage
/// intervals already passed — their storage cannot be re-planned because
/// inference is already running when later sizes resolve).
#[derive(Debug, Default, Clone, Copy)]
pub struct MultiPassPlanner;

impl MultiPassPlanner {
    /// Plan every wave. The returned plan is complete and its
    /// [`MultiPassPlan::offset_plan`] satisfies the usual §5 feasibility
    /// (validated against the *final* sizes by the plan cache).
    pub fn plan(&self, dynamic: &DynamicRecords) -> MultiPassPlan {
        self.plan_resolved(dynamic, DynamicMode::FullyResolved)
    }

    /// Plan only the waves `mode` resolves — the §7 protocol stopped
    /// mid-decode ([`DynamicMode::Resolved`]; the typed replacement for
    /// the former `resolved_through: usize` with its `usize::MAX`
    /// sentinel). By the freeze invariant (module docs) the returned
    /// offsets are a byte-identical prefix of every fuller plan of the
    /// same records, which is what makes caching prefix plans per
    /// resolved-size fingerprint sound.
    pub fn plan_resolved(&self, dynamic: &DynamicRecords, mode: DynamicMode) -> MultiPassPlan {
        let records = dynamic.final_records();
        let mut waves: Vec<usize> = dynamic
            .records
            .iter()
            .map(|d| d.known_at)
            .filter(|&w| mode.resolves(w))
            .collect();
        waves.sort_unstable();
        waves.dedup();

        let mut store = super::offset::OffsetStore::new(&records);
        let mut growth = Vec::with_capacity(waves.len());
        let mut wave_records: Vec<Vec<usize>> = Vec::with_capacity(waves.len());
        let mut high = 0usize;
        for &wave in &waves {
            // Newly-known records, size-descending (Algorithm 3's order).
            let mut ids: Vec<usize> = dynamic
                .records
                .iter()
                .enumerate()
                .filter(|(_, d)| d.known_at == wave)
                .map(|(i, _)| i)
                .collect();
            crate::records::profile::sort_ids_by_size_desc(&records.records, &mut ids);
            for &id in &ids {
                let r = &records.records[id];
                let off = store.best_fit_offset(r);
                store.place(r, off);
                high = high.max(off + r.size);
            }
            growth.push(high);
            wave_records.push(ids);
        }
        let (offsets, _) = store.into_partial();
        MultiPassPlan {
            offsets,
            peak: high,
            passes: waves.len(),
            wave_records,
            growth,
        }
    }

    /// Footprint penalty of not knowing sizes up front: ratio of the
    /// multi-pass arena to the oracle single-pass arena. Defined for every
    /// input: an empty/zero-size record set (oracle arena 0) reports 1.0 —
    /// no penalty — instead of `NaN`/`inf`.
    pub fn overhead_vs_oracle(&self, dynamic: &DynamicRecords) -> f64 {
        let oracle = GreedyBySize.plan(&dynamic.final_records()).total_size();
        let multi = self.plan(dynamic).peak;
        if oracle == 0 {
            1.0
        } else {
            multi as f64 / oracle as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: usize, f: usize, l: usize, s: usize) -> UsageRecord {
        UsageRecord { id, tensor: None, first_op: f, last_op: l, size: s }
    }

    fn dyn_set(entries: &[(usize, usize, usize, usize)], num_ops: usize) -> DynamicRecords {
        DynamicRecords::new(
            entries
                .iter()
                .enumerate()
                .map(|(i, &(f, l, s, k))| DynamicRecord { record: rec(i, f, l, s), known_at: k })
                .collect(),
            num_ops,
        )
    }

    #[test]
    fn all_static_equals_single_pass() {
        let dynamic = dyn_set(
            &[(0, 1, 32, 0), (1, 2, 28, 0), (2, 5, 8, 0), (3, 4, 40, 0)],
            6,
        );
        let mp = MultiPassPlanner.plan(&dynamic);
        assert_eq!(mp.passes, 1);
        assert!(mp.is_complete());
        let records = dynamic.final_records();
        let plan = mp.offset_plan().unwrap();
        plan.validate(&records).unwrap();
        assert_eq!(plan.total_size(), GreedyBySize.plan(&records).total_size());
    }

    #[test]
    fn late_known_sizes_plan_in_second_pass() {
        let dynamic = dyn_set(
            &[
                (0, 2, 100, 0),
                (1, 3, 50, 0),
                // becomes known after op 1 executes (e.g. LSTM output length)
                (2, 4, 70, 1),
            ],
            5,
        );
        let mp = MultiPassPlanner.plan(&dynamic);
        assert_eq!(mp.passes, 2);
        assert!(mp.growth[0] <= mp.growth[1]);
        assert_eq!(mp.peak, *mp.growth.last().unwrap());
        mp.offset_plan().unwrap().validate(&dynamic.final_records()).unwrap();
    }

    #[test]
    fn prefix_plan_is_a_frozen_prefix_of_the_full_plan() {
        let dynamic = dyn_set(
            &[
                (0, 2, 128, 0),
                (1, 3, 64, 0),
                (2, 4, 192, 1),
                (3, 5, 64, 3),
                (4, 6, 256, 3),
                (5, 7, 64, 4),
            ],
            8,
        );
        let full = MultiPassPlanner.plan(&dynamic);
        assert!(full.is_complete());
        for &w in &dynamic.waves() {
            let prefix = MultiPassPlanner.plan_resolved(&dynamic, DynamicMode::Resolved(w));
            assert_eq!(prefix.passes, dynamic.waves().iter().filter(|&&x| x <= w).count());
            for d in &dynamic.records {
                let id = d.record.id;
                if d.known_at <= w {
                    assert_eq!(
                        prefix.offset_of(id),
                        full.offset_of(id),
                        "wave-{w} prefix moved record {id}: the freeze invariant is broken"
                    );
                } else {
                    assert_eq!(prefix.offset_of(id), None, "unresolved record {id} placed early");
                }
            }
            assert!(prefix.peak <= full.peak);
        }
    }

    #[test]
    fn overhead_is_at_least_one_ish() {
        let dynamic = dyn_set(&[(0, 2, 10, 0), (3, 4, 10, 2)], 5);
        let ratio = MultiPassPlanner.overhead_vs_oracle(&dynamic);
        assert!(ratio >= 0.999);
    }

    #[test]
    fn overhead_vs_oracle_is_defined_when_the_oracle_arena_is_zero() {
        // Zero-size records (or no records at all) give the oracle a 0-byte
        // arena; the ratio must be the defined 1.0, not NaN/inf.
        let zero = dyn_set(&[(0, 1, 0, 0), (1, 2, 0, 1)], 3);
        assert_eq!(MultiPassPlanner.overhead_vs_oracle(&zero), 1.0);
        let empty = DynamicRecords::new(Vec::new(), 0);
        assert_eq!(MultiPassPlanner.overhead_vs_oracle(&empty), 1.0);
    }

    #[test]
    fn tail_block_demand_is_the_peak_over_live_dynamic_records() {
        // 64-byte blocks = 16 words. Sizes in bytes: 64 B = 1 block,
        // 256 B = 4 blocks, 100 B = 2 blocks (ceil).
        let dynamic = dyn_set(
            &[
                (0, 5, 4096, 0), // static: never charged to the tail
                (2, 3, 64, 1),   // 1 block, live at ops 2–3
                (3, 4, 256, 2),  // 4 blocks, live at ops 3–4
                (5, 6, 100, 4),  // 2 blocks, live at ops 5–6
            ],
            7,
        );
        // Peak is op 3: records 1 and 2 overlap (1 + 4 blocks).
        assert_eq!(dynamic.tail_block_demand(16), 5);
        // Bigger blocks: every region rounds to one block; peak is 2.
        assert_eq!(dynamic.tail_block_demand(4096), 2);
        // All-static sets have no tail demand.
        let static_set = dyn_set(&[(0, 2, 128, 0), (1, 3, 128, 0)], 4);
        assert_eq!(static_set.tail_block_demand(16), 0);
        // Continuous lanes each hold a private mapping: the multi-lane
        // demand scales linearly, and overflow saturates instead of
        // wrapping into a fake small budget.
        assert_eq!(dynamic.tail_block_demand_lanes(16, 1), 5);
        assert_eq!(dynamic.tail_block_demand_lanes(16, 3), 15);
        assert_eq!(dynamic.tail_block_demand_lanes(16, 0), 0);
        assert_eq!(static_set.tail_block_demand_lanes(16, 8), 0);
        assert_eq!(dynamic.tail_block_demand_lanes(16, usize::MAX), usize::MAX);
    }

    #[test]
    fn decode_tail_resolves_just_in_time() {
        let records = UsageRecords::from_triples(&[(0, 2, 64), (2, 3, 64), (3, 5, 128)]);
        let dynamic = DynamicRecords::decode_tail(&records, 2);
        assert_eq!(dynamic.records[0].known_at, 0, "head of the graph stays static");
        assert_eq!(dynamic.records[1].known_at, 1, "tail resolves one op early");
        assert_eq!(dynamic.records[2].known_at, 2);
        assert_eq!(dynamic.num_dynamic(), 2);
        assert_eq!(dynamic.boundaries(), vec![1, 2]);
        // Every dynamic record resolves before its producer runs.
        for d in &dynamic.records {
            assert!(d.known_at == 0 || d.known_at < d.record.first_op);
        }
    }
}
