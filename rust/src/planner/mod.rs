//! Memory planners — the paper's contribution (§4, §5).
//!
//! Two approaches are implemented, matching the paper's taxonomy:
//!
//! * **Shared Objects** ([`shared`], §4): every intermediate tensor is
//!   assigned to one of *k* reusable buffers ("shared objects"); an object's
//!   size is the max of its tensors' sizes; the objective is to minimize the
//!   total object size. Suitable for GPU textures, which must be used as a
//!   whole.
//! * **Offset Calculation** ([`offset`], §5): all tensors are placed at byte
//!   offsets inside one arena; the objective is to minimize the arena size.
//!   Suitable for CPU memory and GPU buffers. Any Shared-Objects solution
//!   converts to an Offset solution by laying the objects out contiguously
//!   ([`SharedObjectPlan::to_offset_plan`]); the converse is not true.
//!
//! Every planner consumes only a [`UsageRecords`] — the paper's abstraction
//! boundary — and returns a plan that can be validated independently
//! ([`validate`]) and materialized by `crate::arena`.
//!
//! Two further dimensions extend the taxonomy into serving:
//! **execution order** ([`order`], §7.1 — which topological sort the
//! records are extracted under) and **dynamic shapes** ([`dynamic`], §7 —
//! multi-pass planning when sizes resolve mid-inference, cached per
//! resolved-size prefix). A fifth dimension, the quantized element size
//! class ([`request::Dtype`]), divides every record footprint before
//! planning. All five dimensions — strategy, order, batch, dtype,
//! dynamic resolution state — travel together as one typed
//! [`request::PlanRequest`], which is simultaneously the
//! [`cache::PlanCache`] key behind [`service::PlanService`], the `.plan`
//! v2 file-name grammar, and the construction argument of every engine.

pub mod cache;
pub mod dynamic;
pub mod interval_tree;
pub mod offset;
pub mod order;
pub mod registry;
pub mod request;
pub mod serialize;
pub mod service;
pub mod shared;
pub mod validate;

use crate::records::UsageRecords;

pub use cache::{PersistReport, PlanCache, PlanServiceError, WarmStartReport};
pub use dynamic::{DynamicRecord, DynamicRecords, MultiPassPlan, MultiPassPlanner};
pub use order::{apply_order, AppliedOrder};
pub use registry::{order_strategy, OrderStrategy};
pub use request::{Dtype, DynamicMode, ParseRequestError, PlanRequest};
pub use service::{PlanService, PlanServiceStats};
pub use validate::PlanError;

/// A solution to the Shared Objects problem (§4).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SharedObjectPlan {
    /// Final size of each shared object, in bytes (or the records' units).
    pub object_sizes: Vec<usize>,
    /// `assignment[record_id]` = index into `object_sizes`.
    pub assignment: Vec<usize>,
}

impl SharedObjectPlan {
    /// The objective value: total size of all shared objects.
    pub fn total_size(&self) -> usize {
        self.object_sizes.iter().sum()
    }

    /// Number of shared objects used.
    pub fn num_objects(&self) -> usize {
        self.object_sizes.len()
    }

    /// Check the plan against the records (§4's feasibility conditions).
    pub fn validate(&self, records: &UsageRecords) -> Result<(), PlanError> {
        validate::validate_shared(self, records)
    }

    /// §5: convert by placing the shared objects contiguously in one arena.
    pub fn to_offset_plan(&self, records: &UsageRecords) -> OffsetPlan {
        let mut base = vec![0usize; self.object_sizes.len()];
        let mut acc = 0;
        for (i, &s) in self.object_sizes.iter().enumerate() {
            base[i] = acc;
            acc += s;
        }
        OffsetPlan {
            offsets: records
                .records
                .iter()
                .map(|r| base[self.assignment[r.id]])
                .collect(),
            total: acc,
        }
    }
}

/// A solution to the Offset Calculation problem (§5).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OffsetPlan {
    /// `offsets[record_id]` = byte offset of the tensor inside the arena.
    pub offsets: Vec<usize>,
    /// Arena size: `max(offset + size)` over all records.
    pub total: usize,
}

impl OffsetPlan {
    /// The objective value: the arena size.
    pub fn total_size(&self) -> usize {
        self.total
    }

    /// Check the plan against the records (no two time-overlapping tensors
    /// may overlap in memory).
    pub fn validate(&self, records: &UsageRecords) -> Result<(), PlanError> {
        validate::validate_offset(self, records)
    }
}

/// A Shared-Objects strategy (§4).
pub trait SharedObjectPlanner {
    /// Human-readable strategy name as used in Table 1.
    fn name(&self) -> &'static str;
    /// Produce an assignment of every record to a shared object.
    fn plan(&self, records: &UsageRecords) -> SharedObjectPlan;
}

/// An Offset-Calculation strategy (§5).
pub trait OffsetPlanner {
    /// Human-readable strategy name as used in Table 2.
    fn name(&self) -> &'static str;
    /// Produce an offset for every record.
    fn plan(&self, records: &UsageRecords) -> OffsetPlan;
}

/// All Shared-Objects strategies of Table 1, in row order. Thin alias for
/// [`registry::shared_strategies`] — the registry is the single source of
/// truth for which strategies exist.
pub fn table1_strategies() -> Vec<Box<dyn SharedObjectPlanner>> {
    registry::shared_strategies()
}

/// All Offset-Calculation strategies of Table 2, in row order. Thin alias
/// for [`registry::offset_strategies`].
pub fn table2_strategies() -> Vec<Box<dyn OffsetPlanner>> {
    registry::offset_strategies()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::example_records;

    #[test]
    fn shared_plan_converts_to_offset_plan() {
        let recs = example_records();
        let plan = SharedObjectPlan {
            // one object per record — the naive plan
            object_sizes: recs.records.iter().map(|r| r.size).collect(),
            assignment: (0..recs.len()).collect(),
        };
        plan.validate(&recs).unwrap();
        let off = plan.to_offset_plan(&recs);
        off.validate(&recs).unwrap();
        assert_eq!(off.total_size(), plan.total_size());
    }

    #[test]
    fn registries_cover_the_tables() {
        assert_eq!(table1_strategies().len(), 6);
        assert_eq!(table2_strategies().len(), 5);
    }
}
