//! Offset-Calculation strategies (§5).

mod greedy_breadth;
mod greedy_size;
mod naive;
mod strip_packing;
mod tflite_greedy;

pub use greedy_breadth::GreedyByBreadth;
pub use greedy_size::GreedyBySize;
pub use naive::NaiveOffset;
pub use strip_packing::StripPackingBestFit;
pub use tflite_greedy::TfLiteGreedy;

use crate::planner::OffsetPlan;
use crate::records::{UsageRecord, UsageRecords};

/// Incremental offset assignment state shared by all §5 strategies: records
/// placed so far, kept sorted by offset, plus the running high-water mark.
pub(crate) struct OffsetStore<'r> {
    records: &'r [UsageRecord],
    /// (offset, record id), sorted by offset ascending (ties: id).
    allocated: Vec<(usize, usize)>,
    offsets: Vec<Option<usize>>,
    total: usize,
}

impl<'r> OffsetStore<'r> {
    /// Empty store over `records`, nothing placed yet.
    pub fn new(records: &'r UsageRecords) -> Self {
        OffsetStore {
            records: &records.records,
            allocated: Vec::new(),
            offsets: vec![None; records.records.len()],
            total: 0,
        }
    }

    /// Algorithm 3's inner loop (L.7–20): scan already-placed,
    /// time-overlapping tensors in offset order; return the start of the
    /// smallest gap that fits `r` (best-fit), or the first offset past the
    /// last conflicting tensor if no gap fits.
    pub fn best_fit_offset(&self, r: &UsageRecord) -> usize {
        let mut prev_offset = 0usize; // high-water mark of conflicts scanned so far
        let mut best_offset: Option<usize> = None;
        let mut smallest_gap = usize::MAX;
        for &(offset, xid) in &self.allocated {
            let x = &self.records[xid];
            if !r.overlaps(x) {
                continue;
            }
            if offset > prev_offset {
                let gap = offset - prev_offset;
                if gap >= r.size && gap < smallest_gap {
                    smallest_gap = gap;
                    best_offset = Some(prev_offset);
                }
            }
            prev_offset = prev_offset.max(offset + x.size);
        }
        best_offset.unwrap_or(prev_offset)
    }

    /// Place `r` at `offset` (as computed by [`Self::best_fit_offset`], or
    /// seeded externally for incremental planning).
    pub fn place(&mut self, r: &UsageRecord, offset: usize) {
        debug_assert!(self.offsets[r.id].is_none(), "record placed twice");
        let pos = self
            .allocated
            .binary_search(&(offset, r.id))
            .unwrap_err();
        self.allocated.insert(pos, (offset, r.id));
        self.offsets[r.id] = Some(offset);
        self.total = self.total.max(offset + r.size);
    }

    /// Is the record already placed?
    pub fn is_placed(&self, r: &UsageRecord) -> bool {
        self.offsets[r.id].is_some()
    }

    /// Finish an incremental — possibly *partial* — assignment: offsets of
    /// the records placed so far (`None` for the rest) plus the high-water
    /// mark over them. Used by the §7 multi-pass planner, whose decode-step
    /// prefix plans legitimately leave later-wave records unplaced.
    pub fn into_partial(self) -> (Vec<Option<usize>>, usize) {
        (self.offsets, self.total)
    }

    /// Finish; every record must have been placed.
    pub fn into_plan(self) -> OffsetPlan {
        OffsetPlan {
            offsets: self
                .offsets
                .into_iter()
                .map(|o| o.expect("planner left a record unplaced"))
                .collect(),
            total: self.total,
        }
    }
}

/// Run the common loop: best-fit place each record in `order`.
pub(crate) fn assign_in_order(records: &UsageRecords, order: &[usize]) -> OffsetPlan {
    let mut store = OffsetStore::new(records);
    for &id in order {
        let r = &records.records[id];
        if store.is_placed(r) {
            continue;
        }
        let off = store.best_fit_offset(r);
        store.place(r, off);
    }
    store.into_plan()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_fit_finds_smallest_gap() {
        let recs = UsageRecords::from_triples(&[
            (0, 5, 10), // placed at 0
            (0, 5, 10), // placed at 30 (leaving a hole 10..30)
            (0, 5, 8),  // candidate: hole fits (gap 20)
        ]);
        let mut store = OffsetStore::new(&recs);
        store.place(&recs.records[0], 0);
        store.place(&recs.records[1], 30);
        assert_eq!(store.best_fit_offset(&recs.records[2]), 10);
    }

    #[test]
    fn best_fit_ignores_non_overlapping() {
        let recs = UsageRecords::from_triples(&[
            (0, 1, 10), // time 0-1
            (3, 4, 10), // time 3-4, no conflict
        ]);
        let mut store = OffsetStore::new(&recs);
        store.place(&recs.records[0], 0);
        assert_eq!(store.best_fit_offset(&recs.records[1]), 0);
    }

    #[test]
    fn appends_past_conflicts_when_no_gap_fits() {
        let recs = UsageRecords::from_triples(&[
            (0, 5, 10),
            (0, 5, 10),
            (0, 5, 25),
        ]);
        let mut store = OffsetStore::new(&recs);
        store.place(&recs.records[0], 0);
        store.place(&recs.records[1], 12); // gap 10..12 too small for 25
        assert_eq!(store.best_fit_offset(&recs.records[2]), 22);
    }
}
