//! Naive baseline for Offset Calculation (Table 2, last row).

use crate::planner::{OffsetPlan, OffsetPlanner};
use crate::records::UsageRecords;

/// Sequential, never-reused offsets: tensor *i* lives at the prefix sum of
/// the sizes before it. Arena size equals the sum of all intermediate
/// tensor sizes — the paper's strategies cut this by up to 10.5×.
#[derive(Debug, Default, Clone, Copy)]
pub struct NaiveOffset;

impl OffsetPlanner for NaiveOffset {
    fn name(&self) -> &'static str {
        "Naive"
    }

    fn plan(&self, records: &UsageRecords) -> OffsetPlan {
        let mut offsets = Vec::with_capacity(records.len());
        let mut acc = 0usize;
        for r in &records.records {
            offsets.push(acc);
            acc += r.size;
        }
        OffsetPlan { offsets, total: acc }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::example_records;

    #[test]
    fn equals_sum_of_sizes() {
        let recs = example_records();
        let plan = NaiveOffset.plan(&recs);
        plan.validate(&recs).unwrap();
        assert_eq!(plan.total_size(), 242);
    }
}
