//! "Greedy" prior-work baseline (Lee et al. 2019) for Offset Calculation —
//! Table 2 row 3.

use super::assign_in_order;
use crate::planner::{OffsetPlan, OffsetPlanner};
use crate::records::UsageRecords;

/// Allocation-order greedy: tensors are placed in the order their storage
/// materializes during inference (`first_op` ascending; larger first within
/// an op), each taking the best-fit gap among time-overlapping placements.
/// This is how an online arena planner without lookahead behaves; the
/// paper's size-ordered Algorithm 3 beats it by up to 25% (Inception v3 in
/// Table 2) because late large tensors no longer fragment around early
/// small ones.
#[derive(Debug, Default, Clone, Copy)]
pub struct TfLiteGreedy;

impl OffsetPlanner for TfLiteGreedy {
    fn name(&self) -> &'static str {
        "Greedy (Lee et al., 2019)"
    }

    fn plan(&self, records: &UsageRecords) -> OffsetPlan {
        let mut order: Vec<usize> = (0..records.len()).collect();
        order.sort_by(|&a, &b| {
            let (ra, rb) = (&records.records[a], &records.records[b]);
            ra.first_op
                .cmp(&rb.first_op)
                .then(rb.size.cmp(&ra.size))
                .then(ra.id.cmp(&rb.id))
        });
        assign_in_order(records, &order)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::example_records;
    use crate::planner::offset::GreedyBySize;
    use crate::records::UsageRecords;

    #[test]
    fn feasible_on_example() {
        let recs = example_records();
        let plan = TfLiteGreedy.plan(&recs);
        plan.validate(&recs).unwrap();
        assert!(plan.total_size() >= recs.profiles().offset_lower_bound());
    }

    #[test]
    fn size_order_beats_execution_order_on_fragmentation() {
        // Small tensor first in time fragments the arena for the big one.
        // t0 (0,2,10), t1 (1,2,100), t2 (0,1,50).
        // Exec order: t0@0; t2 (size 50) overlaps t0 -> @10; t1 (100):
        // overlaps both -> @60 -> total 160.
        // Size order: t1@0; t2: overlaps t1 (at 1) -> @100; t0: overlaps
        // t1,t2 -> gap? conflicts at 0(100),100(50): -> @150 total 160.
        // (Both 160 here; the real gap shows on the zoo.) Just assert the
        // documented invariant: GbS <= exec-order on this family.
        let recs = UsageRecords::from_triples(&[(0, 2, 10), (1, 2, 100), (0, 1, 50)]);
        let a = GreedyBySize.plan(&recs);
        let b = TfLiteGreedy.plan(&recs);
        a.validate(&recs).unwrap();
        b.validate(&recs).unwrap();
        assert!(a.total_size() <= b.total_size());
    }
}
