//! Strip Packing Best-Fit (Sekiyama et al. 2018) — Table 2 row 4.
//!
//! §5 observes that Offset Calculation is a special case of the
//! two-dimensional strip-packing problem: each tensor is a rectangle with a
//! fixed extent on the time axis (its usage interval) and free position on
//! the memory axis; minimize the strip's memory width. Sekiyama et al.
//! attack it with the *best-fit* skyline heuristic from the strip-packing
//! literature (Burke et al.): instead of committing to a static item order,
//! repeatedly take the lowest usable position in the partial packing and
//! place the best candidate into it.

use super::OffsetStore;
use crate::planner::{OffsetPlan, OffsetPlanner};
use crate::records::UsageRecords;

/// Best-fit strip packing, adapted to fixed time intervals: at every step,
/// compute each unplaced tensor's lowest feasible offset, then commit the
/// tensor whose feasible offset is lowest (ties: the larger tensor, then
/// record id). Placing lowest-first keeps the skyline flat, which is what
/// lets it edge out size-ordering on tall-narrow profiles (DeepLab v3 in
/// Table 2), at the cost of an extra O(n) factor.
#[derive(Debug, Default, Clone, Copy)]
pub struct StripPackingBestFit;

impl OffsetPlanner for StripPackingBestFit {
    fn name(&self) -> &'static str {
        "Strip Packing (Sekiyama et al., 2018)"
    }

    fn plan(&self, records: &UsageRecords) -> OffsetPlan {
        let n = records.len();
        let mut store = OffsetStore::new(records);
        let mut unplaced: Vec<usize> = (0..n).collect();
        while !unplaced.is_empty() {
            // (offset, Reverse(size), id) minimized.
            let mut best: Option<(usize, usize, usize)> = None; // (offset, idx into unplaced, id)
            for (idx, &id) in unplaced.iter().enumerate() {
                let r = &records.records[id];
                let off = store.best_fit_offset(r);
                let better = match best {
                    None => true,
                    Some((boff, bidx, _)) => {
                        let br = &records.records[unplaced[bidx]];
                        off < boff
                            || (off == boff
                                && (r.size > br.size || (r.size == br.size && id < unplaced[bidx])))
                    }
                };
                if better {
                    best = Some((off, idx, id));
                }
            }
            let (off, idx, id) = best.unwrap();
            store.place(&records.records[id], off);
            unplaced.swap_remove(idx);
        }
        store.into_plan()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::example_records;
    use crate::records::UsageRecords;

    #[test]
    fn feasible_and_bounded_on_example() {
        let recs = example_records();
        let plan = StripPackingBestFit.plan(&recs);
        plan.validate(&recs).unwrap();
        let p = recs.profiles();
        assert!(plan.total_size() >= p.offset_lower_bound());
        assert!(plan.total_size() <= recs.naive_total());
    }

    #[test]
    fn keeps_skyline_flat() {
        // Two parallel chains; best-fit should interleave them at the bottom.
        let recs = UsageRecords::from_triples(&[
            (0, 1, 10),
            (2, 3, 10),
            (0, 1, 10),
            (2, 3, 10),
        ]);
        let plan = StripPackingBestFit.plan(&recs);
        plan.validate(&recs).unwrap();
        assert_eq!(plan.total_size(), 20);
    }

    #[test]
    fn deterministic() {
        let recs = example_records();
        assert_eq!(StripPackingBestFit.plan(&recs), StripPackingBestFit.plan(&recs));
    }
}
