//! Greedy by Size for Offset Calculation — Algorithm 3 (§5.2).

use super::assign_in_order;
use crate::planner::{OffsetPlan, OffsetPlanner};
use crate::records::{profile::sort_ids_by_size_desc, UsageRecords};

/// §5.2: visit tensors in non-increasing size order; for each, scan the
/// already-placed, time-overlapping tensors in offset order and take the
/// smallest gap that fits (best-fit), else place past the last conflict.
///
/// This is the strategy Table 2 recommends: it reaches the theoretical
/// lower bound (max operator breadth) on five of the six evaluation
/// networks and stays within 8% on DeepLab v3.
#[derive(Debug, Default, Clone, Copy)]
pub struct GreedyBySize;

impl OffsetPlanner for GreedyBySize {
    fn name(&self) -> &'static str {
        "Greedy by Size"
    }

    fn plan(&self, records: &UsageRecords) -> OffsetPlan {
        let mut order: Vec<usize> = (0..records.len()).collect();
        sort_ids_by_size_desc(&records.records, &mut order);
        assign_in_order(records, &order)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::example_records;
    use crate::records::UsageRecords;

    #[test]
    fn example_reaches_lower_bound() {
        let recs = example_records();
        let plan = GreedyBySize.plan(&recs);
        plan.validate(&recs).unwrap();
        // Offset lower bound on the fixture is max breadth = 114 (op5).
        assert_eq!(plan.total_size(), 114);
    }

    #[test]
    fn never_below_lower_bound_and_never_above_naive() {
        let recs = example_records();
        let plan = GreedyBySize.plan(&recs);
        let p = recs.profiles();
        assert!(plan.total_size() >= p.offset_lower_bound());
        assert!(plan.total_size() <= recs.naive_total());
    }

    #[test]
    fn chain_reuses_in_place() {
        // Alternating chain of equal tensors: arena = 2 tensors.
        let triples: Vec<(usize, usize, usize)> = (0..16).map(|i| (i, i + 1, 10)).collect();
        let recs = UsageRecords::from_triples(&triples);
        let plan = GreedyBySize.plan(&recs);
        plan.validate(&recs).unwrap();
        assert_eq!(plan.total_size(), 20);
    }

    #[test]
    fn residual_connection_is_handled() {
        // A long-lived skip tensor plus a chain under it.
        let recs = UsageRecords::from_triples(&[
            (0, 6, 10), // skip
            (0, 1, 30),
            (1, 2, 30),
            (2, 3, 30),
            (5, 6, 5),
        ]);
        let plan = GreedyBySize.plan(&recs);
        plan.validate(&recs).unwrap();
        assert_eq!(plan.total_size(), recs.profiles().offset_lower_bound());
    }

    #[test]
    fn empty() {
        let recs = UsageRecords::from_triples(&[]);
        let plan = GreedyBySize.plan(&recs);
        plan.validate(&recs).unwrap();
        assert_eq!(plan.total_size(), 0);
    }
}
