//! Greedy by Breadth for Offset Calculation — §5.3.

use super::OffsetStore;
use crate::planner::{OffsetPlan, OffsetPlanner};
use crate::records::UsageRecords;

/// §5.3: iterate operators in non-increasing breadth order; within each
/// profile, place not-yet-assigned tensors largest-first using the same
/// smallest-gap logic as Algorithm 3.
///
/// The paper notes this "does not perform well for Offset Calculation
/// compared to Greedy by Size ... but still outperforms the prior work on
/// some networks, e.g. MobileNet v2" — Table 2 confirms both rows tie on
/// four of six networks.
#[derive(Debug, Default, Clone, Copy)]
pub struct GreedyByBreadth;

impl OffsetPlanner for GreedyByBreadth {
    fn name(&self) -> &'static str {
        "Greedy by Breadth"
    }

    fn plan(&self, records: &UsageRecords) -> OffsetPlan {
        let profiles = records.profiles();
        let mut store = OffsetStore::new(records);
        for op in profiles.ops_by_breadth_desc() {
            for &id in profiles.profile(op) {
                let r = &records.records[id];
                if store.is_placed(r) {
                    continue;
                }
                let off = store.best_fit_offset(r);
                store.place(r, off);
            }
        }
        store.into_plan()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::example_records;
    use crate::records::UsageRecords;

    #[test]
    fn example_is_feasible_and_bounded() {
        let recs = example_records();
        let plan = GreedyByBreadth.plan(&recs);
        plan.validate(&recs).unwrap();
        let p = recs.profiles();
        assert!(plan.total_size() >= p.offset_lower_bound());
        assert!(plan.total_size() <= recs.naive_total());
    }

    #[test]
    fn widest_op_first_gives_tight_packing_for_its_profile() {
        // One very wide op: its profile should be packed contiguously.
        let recs = UsageRecords::from_triples(&[
            (0, 0, 10),
            (0, 0, 20),
            (0, 0, 30),
            (1, 1, 5),
        ]);
        let plan = GreedyByBreadth.plan(&recs);
        plan.validate(&recs).unwrap();
        assert_eq!(plan.total_size(), 60); // 10+20+30, the 5 reuses a hole
    }

    #[test]
    fn deterministic() {
        let recs = example_records();
        assert_eq!(GreedyByBreadth.plan(&recs), GreedyByBreadth.plan(&recs));
    }
}
