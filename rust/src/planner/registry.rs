//! Name-keyed strategy registry — the single source of truth for which
//! planning strategies exist.
//!
//! Every consumer of "the list of strategies" (the CLI's `plan`/`table1`/
//! `table2` commands, `crate::report`, the benches, the plan cache) routes
//! through this module, so adding a strategy is a one-file change. Each
//! strategy is addressable by a stable kebab-case key (what the CLI and the
//! [`crate::planner::cache::PlanCache`] use) and by its human-readable
//! Table 1/2 display name (what `Planner::name()` returns).

use super::offset;
use super::shared;
use super::{OffsetPlanner, SharedObjectPlanner};

/// Stable keys of the Shared-Objects strategies, in Table 1 row order: the
/// paper's three, then prior work (Lee et al. 2019), then the Naive
/// baseline.
pub const SHARED_KEYS: [&str; 6] = [
    "greedy-size",
    "greedy-size-improved",
    "greedy-breadth",
    "tflite-greedy",
    "mincost-flow",
    "naive",
];

/// Stable keys of the Offset-Calculation strategies, in Table 2 row order:
/// the paper's two, then prior work (Lee et al. 2019; Sekiyama et al.
/// 2018), then the Naive baseline.
pub const OFFSET_KEYS: [&str; 5] = [
    "greedy-size",
    "greedy-breadth",
    "tflite-greedy",
    "strip-packing",
    "naive",
];

fn shared_entry(name: &str) -> Option<(&'static str, Box<dyn SharedObjectPlanner>)> {
    let (key, planner): (&'static str, Box<dyn SharedObjectPlanner>) = match name {
        "greedy-size" | "Greedy by Size" => ("greedy-size", Box::new(shared::GreedyBySize)),
        "greedy-size-improved" | "Greedy by Size Improved" => {
            ("greedy-size-improved", Box::new(shared::GreedyBySizeImproved))
        }
        "greedy-breadth" | "Greedy by Breadth" => {
            ("greedy-breadth", Box::new(shared::GreedyByBreadth))
        }
        "tflite-greedy" | "Greedy (Lee et al., 2019)" => {
            ("tflite-greedy", Box::new(shared::TfLiteGreedy))
        }
        "mincost-flow" | "Min-cost Flow (Lee et al., 2019)" => {
            ("mincost-flow", Box::new(shared::MinCostFlow))
        }
        "naive" | "Naive" => ("naive", Box::new(shared::NaiveShared)),
        _ => return None,
    };
    Some((key, planner))
}

fn offset_entry(name: &str) -> Option<(&'static str, Box<dyn OffsetPlanner>)> {
    let (key, planner): (&'static str, Box<dyn OffsetPlanner>) = match name {
        "greedy-size" | "Greedy by Size" => ("greedy-size", Box::new(offset::GreedyBySize)),
        "greedy-breadth" | "Greedy by Breadth" => {
            ("greedy-breadth", Box::new(offset::GreedyByBreadth))
        }
        "tflite-greedy" | "Greedy (Lee et al., 2019)" => {
            ("tflite-greedy", Box::new(offset::TfLiteGreedy))
        }
        "strip-packing" | "Strip Packing (Sekiyama et al., 2018)" => {
            ("strip-packing", Box::new(offset::StripPackingBestFit))
        }
        "naive" | "Naive" => ("naive", Box::new(offset::NaiveOffset)),
        _ => return None,
    };
    Some((key, planner))
}

/// Look up a Shared-Objects strategy by key or display name.
pub fn shared_strategy(name: &str) -> Option<Box<dyn SharedObjectPlanner>> {
    shared_entry(name).map(|(_, p)| p)
}

/// Look up an Offset-Calculation strategy by key or display name.
pub fn offset_strategy(name: &str) -> Option<Box<dyn OffsetPlanner>> {
    offset_entry(name).map(|(_, p)| p)
}

/// Canonical key of a Shared-Objects strategy (accepts key or display name).
pub fn shared_key(name: &str) -> Option<&'static str> {
    shared_entry(name).map(|(k, _)| k)
}

/// Canonical key of an Offset-Calculation strategy (accepts key or display
/// name).
pub fn offset_key(name: &str) -> Option<&'static str> {
    offset_entry(name).map(|(k, _)| k)
}

/// All Shared-Objects strategies, in Table 1 row order.
pub fn shared_strategies() -> Vec<Box<dyn SharedObjectPlanner>> {
    SHARED_KEYS
        .iter()
        .map(|k| shared_strategy(k).expect("registry key resolves"))
        .collect()
}

/// All Offset-Calculation strategies, in Table 2 row order.
pub fn offset_strategies() -> Vec<Box<dyn OffsetPlanner>> {
    OFFSET_KEYS
        .iter()
        .map(|k| offset_strategy(k).expect("registry key resolves"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_key_resolves_and_roundtrips_through_display_name() {
        for key in SHARED_KEYS {
            let p = shared_strategy(key).unwrap_or_else(|| panic!("shared key {key}"));
            assert_eq!(shared_key(p.name()), Some(key), "display name of {key}");
            assert_eq!(shared_key(key), Some(key));
        }
        for key in OFFSET_KEYS {
            let p = offset_strategy(key).unwrap_or_else(|| panic!("offset key {key}"));
            assert_eq!(offset_key(p.name()), Some(key), "display name of {key}");
            assert_eq!(offset_key(key), Some(key));
        }
    }

    #[test]
    fn unknown_names_are_rejected() {
        assert!(shared_strategy("belady").is_none());
        assert!(offset_strategy("belady").is_none());
        assert!(offset_key("").is_none());
    }

    #[test]
    fn registries_cover_the_tables() {
        assert_eq!(shared_strategies().len(), 6);
        assert_eq!(offset_strategies().len(), 5);
    }
}
