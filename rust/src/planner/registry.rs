//! Name-keyed strategy registry — the single source of truth for which
//! planning strategies exist.
//!
//! Every consumer of "the list of strategies" (the CLI's `plan`/`table1`/
//! `table2` commands, `crate::report`, the benches, the plan cache) routes
//! through this module, so adding a strategy is a one-file change. Each
//! strategy is addressable by a stable kebab-case key (what the CLI and the
//! [`crate::planner::cache::PlanCache`] use) and by its human-readable
//! Table 1/2 display name (what `Planner::name()` returns).
//!
//! Besides the allocation strategies, the registry also names the
//! **execution-order strategies** ([`OrderStrategy`]): the paper's §7.1
//! future-work lever, implemented in [`super::order`]. Orders change every
//! record's lifetime, so the plan cache and the on-disk plan directory key
//! on the canonical order key exactly like they key on the allocation
//! strategy.
//!
//! The §7 **dynamic-shape** planner ([`dynamic_planner`]) is registered
//! here too. It is not an [`OffsetPlanner`] — it consumes
//! [`DynamicRecords`](super::dynamic::DynamicRecords), not `UsageRecords` —
//! so it has a single fixed entry rather than a keyed family: within-wave
//! placement is always Algorithm 3's size-descending best-fit, and the
//! plan cache's dynamic slots reuse the *offset* strategy key purely as a
//! namespace.

use super::dynamic::MultiPassPlanner;
use super::offset;
use super::shared;
use super::{OffsetPlanner, SharedObjectPlanner};

/// An execution-order strategy — which topological order of the graph the
/// usage records (and therefore every plan) are extracted under.
///
/// The annealed variant is parameterized by its RNG seed and trial budget;
/// both are part of the canonical key ([`OrderStrategy::key`]) because two
/// annealing runs with different seeds may settle on different orders, and
/// a cached plan is only valid under the exact order that produced it.
///
/// # Example
///
/// Canonical keys round-trip through [`order_strategy`], which is what
/// keeps plan-directory file names and CLI flags unambiguous:
///
/// ```
/// use tensorarena::planner::{order_strategy, OrderStrategy};
///
/// assert_eq!(OrderStrategy::Natural.key(), "natural");
/// let annealed = order_strategy("annealed-s7-t25").unwrap();
/// assert_eq!(annealed, OrderStrategy::Annealed { seed: 7, budget: 25 });
/// assert_eq!(order_strategy(&annealed.key()), Some(annealed)); // round-trips
/// assert!(order_strategy("belady").is_none());
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum OrderStrategy {
    /// The stored (builder/TFLite) topological order.
    #[default]
    Natural,
    /// Sethi-style greedy list scheduling: among ready ops, always run the
    /// one minimizing live-set growth ([`super::order::memory_aware_order`]).
    MemoryAware,
    /// ε-greedy randomized local search seeded from the natural and
    /// memory-aware orders, keeping the best max-breadth found
    /// ([`super::order::anneal_order`]). Deterministic for a fixed seed.
    Annealed { seed: u64, budget: usize },
}

/// Stable base keys of the order strategies (the annealed key is
/// parameterized: `annealed-s<seed>-t<trials>`; the bare `annealed` resolves
/// to the default seed/budget).
pub const ORDER_KEYS: [&str; 3] = ["natural", "memory-aware", "annealed"];

impl OrderStrategy {
    /// Seed the bare `annealed` key resolves to.
    pub const DEFAULT_ANNEAL_SEED: u64 = 42;
    /// Trial budget the bare `annealed` key resolves to.
    pub const DEFAULT_ANNEAL_BUDGET: usize = 100;

    /// Canonical kebab-case key: `natural`, `memory-aware`, or
    /// `annealed-s<seed>-t<trials>`. Filename-safe (ASCII alphanumerics and
    /// `-` only) — it is embedded verbatim in plan-directory file names and
    /// in the v2 plan header.
    pub fn key(&self) -> String {
        match self {
            OrderStrategy::Natural => "natural".to_string(),
            OrderStrategy::MemoryAware => "memory-aware".to_string(),
            OrderStrategy::Annealed { seed, budget } => format!("annealed-s{seed}-t{budget}"),
        }
    }

    /// True for the identity order (no reordering applied).
    pub fn is_natural(&self) -> bool {
        matches!(self, OrderStrategy::Natural)
    }
}

/// Look up an order strategy by key: `natural`, `memory-aware`, `annealed`
/// (default seed/budget), or the fully-parameterized
/// `annealed-s<seed>-t<trials>`. Round-trips with [`OrderStrategy::key`].
pub fn order_strategy(name: &str) -> Option<OrderStrategy> {
    match name {
        "natural" => Some(OrderStrategy::Natural),
        "memory-aware" => Some(OrderStrategy::MemoryAware),
        "annealed" => Some(OrderStrategy::Annealed {
            seed: OrderStrategy::DEFAULT_ANNEAL_SEED,
            budget: OrderStrategy::DEFAULT_ANNEAL_BUDGET,
        }),
        _ => {
            let rest = name.strip_prefix("annealed-s")?;
            let (seed, budget) = rest.split_once("-t")?;
            Some(OrderStrategy::Annealed {
                seed: seed.parse().ok()?,
                budget: budget.parse().ok()?,
            })
        }
    }
}

/// Canonical key of an order strategy name; `None` if unknown.
pub fn order_key(name: &str) -> Option<String> {
    order_strategy(name).map(|o| o.key())
}

/// Stable keys of the Shared-Objects strategies, in Table 1 row order: the
/// paper's three, then prior work (Lee et al. 2019), then the Naive
/// baseline.
pub const SHARED_KEYS: [&str; 6] = [
    "greedy-size",
    "greedy-size-improved",
    "greedy-breadth",
    "tflite-greedy",
    "mincost-flow",
    "naive",
];

/// Stable keys of the Offset-Calculation strategies, in Table 2 row order:
/// the paper's two, then prior work (Lee et al. 2019; Sekiyama et al.
/// 2018), then the Naive baseline.
pub const OFFSET_KEYS: [&str; 5] = [
    "greedy-size",
    "greedy-breadth",
    "tflite-greedy",
    "strip-packing",
    "naive",
];

fn shared_entry(name: &str) -> Option<(&'static str, Box<dyn SharedObjectPlanner>)> {
    let (key, planner): (&'static str, Box<dyn SharedObjectPlanner>) = match name {
        "greedy-size" | "Greedy by Size" => ("greedy-size", Box::new(shared::GreedyBySize)),
        "greedy-size-improved" | "Greedy by Size Improved" => {
            ("greedy-size-improved", Box::new(shared::GreedyBySizeImproved))
        }
        "greedy-breadth" | "Greedy by Breadth" => {
            ("greedy-breadth", Box::new(shared::GreedyByBreadth))
        }
        "tflite-greedy" | "Greedy (Lee et al., 2019)" => {
            ("tflite-greedy", Box::new(shared::TfLiteGreedy))
        }
        "mincost-flow" | "Min-cost Flow (Lee et al., 2019)" => {
            ("mincost-flow", Box::new(shared::MinCostFlow))
        }
        "naive" | "Naive" => ("naive", Box::new(shared::NaiveShared)),
        _ => return None,
    };
    Some((key, planner))
}

fn offset_entry(name: &str) -> Option<(&'static str, Box<dyn OffsetPlanner>)> {
    let (key, planner): (&'static str, Box<dyn OffsetPlanner>) = match name {
        "greedy-size" | "Greedy by Size" => ("greedy-size", Box::new(offset::GreedyBySize)),
        "greedy-breadth" | "Greedy by Breadth" => {
            ("greedy-breadth", Box::new(offset::GreedyByBreadth))
        }
        "tflite-greedy" | "Greedy (Lee et al., 2019)" => {
            ("tflite-greedy", Box::new(offset::TfLiteGreedy))
        }
        "strip-packing" | "Strip Packing (Sekiyama et al., 2018)" => {
            ("strip-packing", Box::new(offset::StripPackingBestFit))
        }
        "naive" | "Naive" => ("naive", Box::new(offset::NaiveOffset)),
        _ => return None,
    };
    Some((key, planner))
}

/// Look up a Shared-Objects strategy by key or display name.
pub fn shared_strategy(name: &str) -> Option<Box<dyn SharedObjectPlanner>> {
    shared_entry(name).map(|(_, p)| p)
}

/// Look up an Offset-Calculation strategy by key or display name.
pub fn offset_strategy(name: &str) -> Option<Box<dyn OffsetPlanner>> {
    offset_entry(name).map(|(_, p)| p)
}

/// Canonical key of a Shared-Objects strategy (accepts key or display name).
pub fn shared_key(name: &str) -> Option<&'static str> {
    shared_entry(name).map(|(k, _)| k)
}

/// Canonical key of an Offset-Calculation strategy (accepts key or display
/// name).
pub fn offset_key(name: &str) -> Option<&'static str> {
    offset_entry(name).map(|(k, _)| k)
}

/// All Shared-Objects strategies, in Table 1 row order.
pub fn shared_strategies() -> Vec<Box<dyn SharedObjectPlanner>> {
    SHARED_KEYS
        .iter()
        .map(|k| shared_strategy(k).expect("registry key resolves"))
        .collect()
}

/// The §7 multi-pass planner — the one dynamic-shape strategy. Exposed
/// through the registry so "which planners exist" stays a one-module
/// question even though its input type differs.
pub fn dynamic_planner() -> MultiPassPlanner {
    MultiPassPlanner
}

/// All Offset-Calculation strategies, in Table 2 row order.
pub fn offset_strategies() -> Vec<Box<dyn OffsetPlanner>> {
    OFFSET_KEYS
        .iter()
        .map(|k| offset_strategy(k).expect("registry key resolves"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_key_resolves_and_roundtrips_through_display_name() {
        for key in SHARED_KEYS {
            let p = shared_strategy(key).unwrap_or_else(|| panic!("shared key {key}"));
            assert_eq!(shared_key(p.name()), Some(key), "display name of {key}");
            assert_eq!(shared_key(key), Some(key));
        }
        for key in OFFSET_KEYS {
            let p = offset_strategy(key).unwrap_or_else(|| panic!("offset key {key}"));
            assert_eq!(offset_key(p.name()), Some(key), "display name of {key}");
            assert_eq!(offset_key(key), Some(key));
        }
    }

    #[test]
    fn unknown_names_are_rejected() {
        assert!(shared_strategy("belady").is_none());
        assert!(offset_strategy("belady").is_none());
        assert!(offset_key("").is_none());
    }

    #[test]
    fn registries_cover_the_tables() {
        assert_eq!(shared_strategies().len(), 6);
        assert_eq!(offset_strategies().len(), 5);
    }

    #[test]
    fn order_keys_resolve_and_roundtrip() {
        for name in ORDER_KEYS {
            let o = order_strategy(name).unwrap_or_else(|| panic!("order key {name}"));
            assert_eq!(
                order_strategy(&o.key()),
                Some(o),
                "canonical key of {name} must resolve back to the same strategy"
            );
        }
        // Parameterized annealed keys carry their seed and budget.
        let o = order_strategy("annealed-s7-t25").unwrap();
        assert_eq!(o, OrderStrategy::Annealed { seed: 7, budget: 25 });
        assert_eq!(o.key(), "annealed-s7-t25");
        // The bare key resolves to the defaults.
        assert_eq!(
            order_strategy("annealed"),
            Some(OrderStrategy::Annealed {
                seed: OrderStrategy::DEFAULT_ANNEAL_SEED,
                budget: OrderStrategy::DEFAULT_ANNEAL_BUDGET,
            })
        );
        assert_eq!(order_key("memory-aware").as_deref(), Some("memory-aware"));
    }

    #[test]
    fn unknown_order_names_are_rejected() {
        for bad in ["belady", "", "annealed-s-t5", "annealed-sx-t5", "annealed-s5", "Natural"] {
            assert_eq!(order_strategy(bad), None, "{bad:?}");
        }
    }

    #[test]
    fn order_default_is_natural() {
        assert!(OrderStrategy::default().is_natural());
        assert!(!OrderStrategy::MemoryAware.is_natural());
    }
}
