//! Greedy by Size Improved for Shared Objects — §4.4.

use super::ObjectStore;
use crate::planner::{SharedObjectPlan, SharedObjectPlanner};
use crate::records::UsageRecords;

/// §4.4's two refinements over Greedy by Size:
///
/// 1. **Stages by positional maximum.** The lower bound (§4.1) is the sum of
///    positional maximums, and observed near-optimal solutions use objects
///    of exactly those sizes. Tensors are therefore processed in stages:
///    first all tensors with size equal to the largest positional maximum,
///    then all tensors strictly between the first and second maxima, then
///    those equal to the second maximum, and so on. Tensors within one stage
///    have "almost equal significance".
/// 2. **Gap-minimizing pairing inside a stage.** Within a stage, repeatedly
///    assign the (tensor, suitable object) pair whose usage interval sits
///    closest to an interval already on the object — minimizing the time the
///    object would sit idle. Tensors for which no suitable object exists get
///    fresh objects.
///
/// The paper reports this strategy "provides us with better or the same
/// result, compared to the original without improvements". The staged
/// heuristic alone cannot *guarantee* that on adversarial graphs (our
/// property tests found rare 0.2%-worse cases on random residual graphs),
/// so `plan` computes both and returns the better one — which makes the
/// paper's statement hold by construction while leaving the staged result
/// in place whenever it wins or ties (always, on the six zoo networks).
#[derive(Debug, Default, Clone, Copy)]
pub struct GreedyBySizeImproved;

impl SharedObjectPlanner for GreedyBySizeImproved {
    fn name(&self) -> &'static str {
        "Greedy by Size Improved"
    }

    fn plan(&self, records: &UsageRecords) -> SharedObjectPlan {
        let staged = self.plan_staged(records);
        // §Perf: when the staged plan hits the §4.1 lower bound it is
        // provably optimal — skip the fallback comparison entirely (this is
        // the common case on the six zoo networks).
        if staged.total_size() == records.profiles().shared_objects_lower_bound() {
            return staged;
        }
        let plain = super::GreedyBySize.plan(records);
        if plain.total_size() < staged.total_size() {
            plain
        } else {
            staged
        }
    }
}

impl GreedyBySizeImproved {
    /// The §4.4 staged algorithm itself (no fallback).
    pub fn plan_staged(&self, records: &UsageRecords) -> SharedObjectPlan {
        let profiles = records.profiles();
        let mut maxima: Vec<usize> = profiles.positional_maximums().to_vec();
        maxima.dedup(); // already non-increasing by construction
        let stages = stage_of_sizes(records, &maxima);

        let mut store = ObjectStore::new(records.len());
        for stage in stages {
            assign_stage(records, &mut store, stage);
        }
        store.into_plan()
    }
}

/// Partition record ids into §4.4 stages: for positional maxima
/// `p1 > p2 > ...`, the stages are `{size == p1}`, `{p2 < size < p1}`,
/// `{size == p2}`, ... followed by `{size < p_last}`.
fn stage_of_sizes(records: &UsageRecords, maxima: &[usize]) -> Vec<Vec<usize>> {
    let mut stages: Vec<Vec<usize>> = vec![Vec::new(); 2 * maxima.len() + 1];
    for r in &records.records {
        let mut stage = 2 * maxima.len(); // below all maxima
        for (i, &p) in maxima.iter().enumerate() {
            if r.size == p {
                stage = 2 * i;
                break;
            }
            if r.size > p {
                // strictly between p_{i-1} and p_i (i>0 guaranteed: sizes
                // cannot exceed the first positional maximum).
                debug_assert!(i > 0, "tensor larger than first positional maximum");
                stage = 2 * i - 1;
                break;
            }
        }
        stages[stage].push(r.id);
    }
    stages.retain(|s| !s.is_empty());
    stages
}

/// Assign all records of one stage using the gap-minimizing pairing.
///
/// §Perf: a per-tensor cache of the best `(gap, object)` replaces the naive
/// full rescan per assignment. Assigning to object *o* only changes *o*'s
/// interval set, so a cached best on another object stays valid as long as
/// *o* is re-compared (it may have become better) and entries whose best
/// *was* *o* are recomputed. Recorded in EXPERIMENTS.md §Perf: 41.5 ms →
/// 3.9 ms on a 1024-record synthetic graph, identical plans.
fn assign_stage(records: &UsageRecords, store: &mut ObjectStore, mut pending: Vec<usize>) {
    // Deterministic base order: size desc, then id.
    pending.sort_by(|&a, &b| {
        let (ra, rb) = (&records.records[a], &records.records[b]);
        rb.size.cmp(&ra.size).then(ra.id.cmp(&rb.id))
    });

    // Best suitable (gap, obj) per pending tensor, min over all objects with
    // (gap, obj) lexicographic ordering (ties to the older object, exactly
    // like the rescan formulation).
    let full_best = |store: &ObjectStore, id: usize| -> Option<(usize, usize)> {
        let r = &records.records[id];
        let mut best: Option<(usize, usize)> = None;
        for obj in 0..store.num_objects() {
            if !store.suitable(obj, r) {
                continue;
            }
            if let Some(gap) = store.nearest_gap(obj, r) {
                let cand = (gap, obj);
                if best.map_or(true, |b| cand < b) {
                    best = Some(cand);
                }
            }
        }
        best
    };
    let mut best: Vec<Option<(usize, usize)>> =
        pending.iter().map(|&id| full_best(store, id)).collect();

    while !pending.is_empty() {
        // Smallest (gap, pending position, obj) — same tie order as the
        // rescan version: gap, then larger tensor (earlier position), then
        // lower object index (already folded into `best`).
        let mut pick: Option<(usize, usize, usize)> = None; // (gap, pi, obj)
        for (pi, b) in best.iter().enumerate() {
            if let Some((gap, obj)) = *b {
                let cand = (gap, pi, obj);
                if pick.map_or(true, |p| cand < p) {
                    pick = Some(cand);
                }
            }
        }
        let changed_obj = match pick {
            Some((_, pi, obj)) => {
                let id = pending.remove(pi);
                best.remove(pi);
                store.assign(obj, &records.records[id]);
                obj
            }
            None => {
                // No tensor in the stage fits any existing object: open a
                // new object for the largest pending tensor and loop (later
                // stage members may now pair with it).
                let id = pending.remove(0);
                best.remove(0);
                store.create_for(&records.records[id])
            }
        };
        // Repair the cache against the one object whose intervals changed.
        for (pi, &id) in pending.iter().enumerate() {
            match best[pi] {
                Some((_, obj)) if obj == changed_obj => {
                    best[pi] = full_best(store, id);
                }
                cached => {
                    let r = &records.records[id];
                    if store.suitable(changed_obj, r) {
                        if let Some(gap) = store.nearest_gap(changed_obj, r) {
                            let cand = (gap, changed_obj);
                            if cached.map_or(true, |b| cand < b) {
                                best[pi] = Some(cand);
                            }
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::example_records;
    use crate::planner::shared::GreedyBySize;

    #[test]
    fn example_reaches_lower_bound() {
        let recs = example_records();
        let plan = GreedyBySizeImproved.plan(&recs);
        plan.validate(&recs).unwrap();
        assert_eq!(plan.total_size(), 120); // sum of positional maxima
        let mut sizes = plan.object_sizes.clone();
        sizes.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(sizes, vec![64, 40, 16]);
    }

    #[test]
    fn stages_partition_all_records() {
        let recs = example_records();
        let maxima = vec![64, 40, 16];
        let stages = stage_of_sizes(&recs, &maxima);
        let total: usize = stages.iter().map(Vec::len).sum();
        assert_eq!(total, recs.len());
        // stage boundaries: {64}, {40<s<64}, {40}, {16<s<40}, {16}, {10<s<16}∅, {<16 rest}
        // sizes: 64 | — | 40 | 36,32,28 | 16,16 | 10
        let stage_sizes: Vec<Vec<usize>> = stages
            .iter()
            .map(|s| s.iter().map(|&i| recs.records[i].size).collect())
            .collect();
        assert_eq!(stage_sizes[0], vec![64]);
        assert_eq!(stage_sizes[1], vec![40]);
        assert_eq!(
            {
                let mut v = stage_sizes[2].clone();
                v.sort_unstable_by(|a, b| b.cmp(a));
                v
            },
            vec![36, 32, 28]
        );
        assert_eq!(stage_sizes[3], vec![16, 16]);
        assert_eq!(stage_sizes[4], vec![10]);
    }

    #[test]
    fn not_worse_than_greedy_by_size_on_example() {
        let recs = example_records();
        let a = GreedyBySizeImproved.plan(&recs).total_size();
        let b = GreedyBySize.plan(&recs).total_size();
        assert!(a <= b);
    }

    #[test]
    fn handles_all_equal_sizes() {
        let recs = UsageRecords::from_triples(&[(0, 1, 8), (1, 2, 8), (2, 3, 8), (3, 4, 8)]);
        let plan = GreedyBySizeImproved.plan(&recs);
        plan.validate(&recs).unwrap();
        assert_eq!(plan.total_size(), 16); // two alternating objects
    }

    #[test]
    fn empty_is_fine() {
        let recs = UsageRecords::from_triples(&[]);
        let plan = GreedyBySizeImproved.plan(&recs);
        plan.validate(&recs).unwrap();
        assert_eq!(plan.num_objects(), 0);
    }
}
