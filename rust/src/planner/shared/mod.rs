//! Shared-Objects strategies (§4).

mod greedy_breadth;
mod greedy_size;
mod greedy_size_improved;
mod mincost_flow;
mod naive;
mod tflite_greedy;

pub use greedy_breadth::GreedyByBreadth;
pub use greedy_size::GreedyBySize;
pub use greedy_size_improved::GreedyBySizeImproved;
pub use mincost_flow::MinCostFlow;
pub use naive::NaiveShared;
pub use tflite_greedy::TfLiteGreedy;

use super::interval_tree::DisjointIntervalSet;
use super::SharedObjectPlan;
use crate::records::{UsageRecord, UsageRecords};

/// Mutable shared-object state used by all greedy strategies: the current
/// size of every object plus, per object, the interval tree of its assigned
/// tensors' usage intervals (the O(kn log n) structure of §4.2).
pub(crate) struct ObjectStore {
    sizes: Vec<usize>,
    intervals: Vec<DisjointIntervalSet>,
    assignment: Vec<Option<usize>>,
}

impl ObjectStore {
    /// Empty store over `num_records` unassigned records.
    pub fn new(num_records: usize) -> Self {
        ObjectStore {
            sizes: Vec::new(),
            intervals: Vec::new(),
            assignment: vec![None; num_records],
        }
    }

    /// Current number of objects.
    pub fn num_objects(&self) -> usize {
        self.sizes.len()
    }

    /// Current size of object `obj`.
    pub fn size(&self, obj: usize) -> usize {
        self.sizes[obj]
    }

    /// §4.2: object `obj` is *suitable* for record `r` iff no tensor already
    /// assigned to it has a usage interval intersecting `r`'s.
    pub fn suitable(&self, obj: usize, r: &UsageRecord) -> bool {
        !self.intervals[obj].overlaps(r.first_op, r.last_op)
    }

    /// Gap between `r`'s interval and the nearest interval already on `obj`
    /// (§4.4). `None` when `obj` is empty or unsuitable.
    pub fn nearest_gap(&self, obj: usize, r: &UsageRecord) -> Option<usize> {
        self.intervals[obj].nearest_gap(r.first_op, r.last_op)
    }

    /// Assign `r` to `obj`, growing the object if needed.
    pub fn assign(&mut self, obj: usize, r: &UsageRecord) {
        debug_assert!(self.suitable(obj, r));
        self.intervals[obj].insert(r.first_op, r.last_op);
        self.sizes[obj] = self.sizes[obj].max(r.size);
        self.assignment[r.id] = Some(obj);
    }

    /// Create a fresh object of `r`'s size and assign `r` to it.
    pub fn create_for(&mut self, r: &UsageRecord) -> usize {
        let obj = self.sizes.len();
        self.sizes.push(r.size);
        let mut set = DisjointIntervalSet::new();
        set.insert(r.first_op, r.last_op);
        self.intervals.push(set);
        self.assignment[r.id] = Some(obj);
        obj
    }

    /// Has `r` been assigned yet?
    pub fn is_assigned(&self, r: &UsageRecord) -> bool {
        self.assignment[r.id].is_some()
    }

    /// Finish: every record must be assigned.
    pub fn into_plan(self) -> SharedObjectPlan {
        SharedObjectPlan {
            object_sizes: self.sizes,
            assignment: self
                .assignment
                .into_iter()
                .map(|a| a.expect("planner left a record unassigned"))
                .collect(),
        }
    }
}

/// The shared best-object selection of §4.2/§4.3, given a candidate record:
///
/// 1. among suitable objects with `size >= size_t`, pick the smallest;
/// 2. otherwise, among suitable objects (all smaller), pick the largest —
///    enlarging it wastes the least;
/// 3. otherwise signal `None` (caller creates a new object).
///
/// Ties break to the lower object index (oldest object), matching the
/// deterministic reference implementation in TFLite.
pub(crate) fn best_fit_object(store: &ObjectStore, r: &UsageRecord) -> Option<usize> {
    let mut best: Option<usize> = None;
    for obj in 0..store.num_objects() {
        if !store.suitable(obj, r) {
            continue;
        }
        let is_better = match best {
            None => true,
            Some(b) => {
                let (bs, os) = (store.size(b), store.size(obj));
                if bs < r.size {
                    // current best is too small: prefer bigger objects
                    os > bs
                } else {
                    // current best fits: prefer the smallest object that fits
                    os >= r.size && os < bs
                }
            }
        };
        if is_better {
            best = Some(obj);
        }
    }
    best
}

/// Run the common greedy loop over `order` (record ids): best-fit each
/// record, creating objects as needed.
pub(crate) fn greedy_assign(records: &UsageRecords, order: &[usize]) -> SharedObjectPlan {
    let mut store = ObjectStore::new(records.len());
    for &id in order {
        let r = &records.records[id];
        if store.is_assigned(r) {
            continue;
        }
        match best_fit_object(&store, r) {
            Some(obj) => store.assign(obj, r),
            None => {
                store.create_for(r);
            }
        }
    }
    store.into_plan()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn object_store_basics() {
        let recs = UsageRecords::from_triples(&[(0, 1, 10), (2, 3, 6), (1, 2, 4)]);
        let mut store = ObjectStore::new(3);
        let r0 = recs.records[0];
        let r1 = recs.records[1];
        let r2 = recs.records[2];
        let o = store.create_for(&r0);
        assert_eq!(store.size(o), 10);
        assert!(store.suitable(o, &r1));
        assert!(!store.suitable(o, &r2)); // overlaps r0 at op 1
        store.assign(o, &r1);
        assert_eq!(store.size(o), 10); // no growth
        assert_eq!(store.nearest_gap(o, &recs.records[1]), None); // now overlapping
        assert!(store.is_assigned(&r0));
        assert!(!store.is_assigned(&r2));
    }

    #[test]
    fn best_fit_prefers_smallest_that_fits() {
        let recs = UsageRecords::from_triples(&[(0, 0, 100), (0, 0, 50), (1, 1, 40)]);
        let mut store = ObjectStore::new(3);
        store.create_for(&recs.records[0]); // obj0 size 100
        store.create_for(&recs.records[1]); // obj1 size 50
        // record 2 (size 40) fits both; smallest that fits is obj1
        assert_eq!(best_fit_object(&store, &recs.records[2]), Some(1));
    }

    #[test]
    fn best_fit_grows_largest_when_nothing_fits() {
        let recs = UsageRecords::from_triples(&[(0, 0, 10), (0, 0, 30), (1, 1, 40)]);
        let mut store = ObjectStore::new(3);
        store.create_for(&recs.records[0]);
        store.create_for(&recs.records[1]);
        // nothing fits 40; grow the largest (obj1, size 30)
        assert_eq!(best_fit_object(&store, &recs.records[2]), Some(1));
    }

    #[test]
    fn best_fit_none_when_all_unsuitable() {
        let recs = UsageRecords::from_triples(&[(0, 2, 10), (1, 3, 30)]);
        let mut store = ObjectStore::new(2);
        store.create_for(&recs.records[0]);
        assert_eq!(best_fit_object(&store, &recs.records[1]), None);
    }
}
