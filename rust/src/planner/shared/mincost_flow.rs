//! "Min-cost Flow" prior-work baseline from Lee et al. 2019, reimplemented
//! for Table 1.
//!
//! Lee et al. cast shared-object assignment as a minimum-cost-flow problem:
//! decompose the tensors into chains (one chain = one shared object), where
//! tensor *j* may follow tensor *i* in a chain iff their usage intervals are
//! disjoint with `last_op_i < first_op_j`. Starting a chain at *j* costs
//! `size_j`; extending a chain from *i* to *j* costs `max(0, size_j -
//! size_i)` — the object growth. The sum of these costs upper-bounds the
//! true objective (an object's size is the *max* along its chain, and the
//! telescoped increments overcount non-monotone chains), which is exactly
//! why the paper's direct greedy strategies can beat this formulation.
//!
//! We solve the relaxation exactly with successive shortest augmenting paths
//! (SPFA + Johnson potentials) on the bipartite reuse graph, then rebuild
//! the chains and report the *true* object sizes.

use crate::planner::{SharedObjectPlan, SharedObjectPlanner};
use crate::records::UsageRecords;

/// Min-cost-flow shared-object planner (prior work, Lee et al. 2019).
#[derive(Debug, Default, Clone, Copy)]
pub struct MinCostFlow;

impl SharedObjectPlanner for MinCostFlow {
    fn name(&self) -> &'static str {
        "Min-cost Flow (Lee et al., 2019)"
    }

    fn plan(&self, records: &UsageRecords) -> SharedObjectPlan {
        let n = records.len();
        if n == 0 {
            return SharedObjectPlan { object_sizes: vec![], assignment: vec![] };
        }
        // Node ids: 0 = source, 1 = sink, 2+i = "supply side" of record i
        // (its buffer after death), 2+n+j = "demand side" of record j.
        let mut g = McmfGraph::new(2 + 2 * n);
        const S: usize = 0;
        const T: usize = 1;
        for i in 0..n {
            g.add_edge(S, 2 + i, 1, 0); // each dead buffer reusable once
        }
        for j in 0..n {
            let rj = &records.records[j];
            // "fresh allocation" arc
            g.add_edge(S, 2 + n + j, 1, rj.size as i64);
            g.add_edge(2 + n + j, T, 1, 0);
        }
        for (i, ri) in records.records.iter().enumerate() {
            for (j, rj) in records.records.iter().enumerate() {
                if ri.last_op < rj.first_op {
                    let cost = rj.size.saturating_sub(ri.size) as i64;
                    g.add_edge(2 + i, 2 + n + j, 1, cost);
                }
            }
        }
        g.min_cost_flow(S, T, n as i64);

        // Recover predecessor choices: demand j took either the fresh arc or
        // some supply arc i.
        let mut pred: Vec<Option<usize>> = vec![None; n];
        for (i, edges) in g.adj.iter().enumerate() {
            if i < 2 || i >= 2 + n {
                continue;
            }
            let supply = i - 2;
            for &eid in edges {
                let e = &g.edges[eid];
                if e.to >= 2 + n && e.to < 2 + 2 * n && e.flow > 0 {
                    pred[e.to - 2 - n] = Some(supply);
                }
            }
        }
        // Build chains => objects.
        let mut assignment = vec![usize::MAX; n];
        let mut object_sizes: Vec<usize> = Vec::new();
        // Roots are records with no predecessor.
        let mut succ: Vec<Option<usize>> = vec![None; n];
        for (j, p) in pred.iter().enumerate() {
            if let Some(i) = p {
                debug_assert!(succ[*i].is_none());
                succ[*i] = Some(j);
            }
        }
        for root in 0..n {
            if pred[root].is_some() {
                continue;
            }
            let obj = object_sizes.len();
            let mut cur = Some(root);
            let mut maxsz = 0;
            while let Some(c) = cur {
                assignment[c] = obj;
                maxsz = maxsz.max(records.records[c].size);
                cur = succ[c];
            }
            object_sizes.push(maxsz);
        }
        SharedObjectPlan { object_sizes, assignment }
    }
}

/// One directed edge with residual bookkeeping.
struct Edge {
    to: usize,
    cap: i64,
    flow: i64,
    cost: i64,
}

/// Minimal successive-shortest-paths min-cost-flow solver (SPFA variant —
/// costs start non-negative but residual arcs go negative, so Bellman-Ford
/// style relaxation is used).
struct McmfGraph {
    edges: Vec<Edge>,
    adj: Vec<Vec<usize>>,
}

impl McmfGraph {
    fn new(n: usize) -> Self {
        McmfGraph { edges: Vec::new(), adj: vec![Vec::new(); n] }
    }

    fn add_edge(&mut self, from: usize, to: usize, cap: i64, cost: i64) {
        self.adj[from].push(self.edges.len());
        self.edges.push(Edge { to, cap, flow: 0, cost });
        self.adj[to].push(self.edges.len());
        self.edges.push(Edge { to: from, cap: 0, flow: 0, cost: -cost });
    }

    /// Push up to `want` units from `s` to `t`; returns (flow, cost).
    fn min_cost_flow(&mut self, s: usize, t: usize, want: i64) -> (i64, i64) {
        let n = self.adj.len();
        let mut flow = 0;
        let mut cost = 0;
        while flow < want {
            // SPFA shortest path on residual graph.
            let mut dist = vec![i64::MAX; n];
            let mut in_queue = vec![false; n];
            let mut pre: Vec<Option<usize>> = vec![None; n];
            dist[s] = 0;
            let mut queue = std::collections::VecDeque::new();
            queue.push_back(s);
            in_queue[s] = true;
            while let Some(u) = queue.pop_front() {
                in_queue[u] = false;
                for &eid in &self.adj[u] {
                    let e = &self.edges[eid];
                    if e.cap - e.flow > 0 && dist[u] != i64::MAX && dist[u] + e.cost < dist[e.to] {
                        dist[e.to] = dist[u] + e.cost;
                        pre[e.to] = Some(eid);
                        if !in_queue[e.to] {
                            queue.push_back(e.to);
                            in_queue[e.to] = true;
                        }
                    }
                }
            }
            if dist[t] == i64::MAX {
                break; // no more augmenting paths
            }
            // Bottleneck along the path.
            let mut push = want - flow;
            let mut v = t;
            while let Some(eid) = pre[v] {
                let e = &self.edges[eid];
                push = push.min(e.cap - e.flow);
                v = self.edges[eid ^ 1].to;
            }
            let mut v = t;
            while let Some(eid) = pre[v] {
                self.edges[eid].flow += push;
                self.edges[eid ^ 1].flow -= push;
                v = self.edges[eid ^ 1].to;
            }
            flow += push;
            cost += push * dist[t];
        }
        (flow, cost)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::example_records;
    use crate::records::UsageRecords;

    #[test]
    fn feasible_on_example() {
        let recs = example_records();
        let plan = MinCostFlow.plan(&recs);
        plan.validate(&recs).unwrap();
        let lb = recs.profiles().shared_objects_lower_bound();
        assert!(plan.total_size() >= lb);
        // The relaxation is exact on this small fixture.
        assert_eq!(plan.total_size(), 120);
    }

    #[test]
    fn chain_network_uses_two_objects() {
        let triples: Vec<(usize, usize, usize)> = (0..10).map(|i| (i, i + 1, 5)).collect();
        let recs = UsageRecords::from_triples(&triples);
        let plan = MinCostFlow.plan(&recs);
        plan.validate(&recs).unwrap();
        assert_eq!(plan.total_size(), 10);
        assert_eq!(plan.num_objects(), 2);
    }

    #[test]
    fn empty_records() {
        let recs = UsageRecords::from_triples(&[]);
        let plan = MinCostFlow.plan(&recs);
        assert_eq!(plan.num_objects(), 0);
    }

    #[test]
    fn non_monotone_chain_overcounting_is_repaired() {
        // sizes 5, 3, 5 in a chain: the flow cost is 5+0+2=7 but the real
        // object max is 5; the plan must report true sizes.
        let recs = UsageRecords::from_triples(&[(0, 0, 5), (1, 1, 3), (2, 2, 5)]);
        let plan = MinCostFlow.plan(&recs);
        plan.validate(&recs).unwrap();
        assert_eq!(plan.total_size(), 5);
        assert_eq!(plan.num_objects(), 1);
    }

    #[test]
    fn solver_finds_cheap_matching() {
        let mut g = McmfGraph::new(4);
        // 0 -> {1,2} -> 3 with different costs
        g.add_edge(0, 1, 1, 5);
        g.add_edge(0, 2, 1, 1);
        g.add_edge(1, 3, 1, 0);
        g.add_edge(2, 3, 1, 0);
        let (f, c) = g.min_cost_flow(0, 3, 1);
        assert_eq!((f, c), (1, 1));
        let (f2, c2) = g.min_cost_flow(0, 3, 1);
        assert_eq!((f2, c2), (1, 5));
    }
}
