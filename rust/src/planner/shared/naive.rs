//! The Naive baseline (Tables 1–2, last row): no sharing at all.

use crate::planner::{SharedObjectPlan, SharedObjectPlanner};
use crate::records::UsageRecords;

/// Every intermediate tensor keeps a private buffer for the whole inference
/// — what an engine without a memory manager does. The paper reports its
/// strategies at up to 7.5× (shared objects) / 10.5× (offsets) below this.
#[derive(Debug, Default, Clone, Copy)]
pub struct NaiveShared;

impl SharedObjectPlanner for NaiveShared {
    fn name(&self) -> &'static str {
        "Naive"
    }

    fn plan(&self, records: &UsageRecords) -> SharedObjectPlan {
        SharedObjectPlan {
            object_sizes: records.records.iter().map(|r| r.size).collect(),
            assignment: (0..records.len()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::example_records;

    #[test]
    fn naive_total_is_sum_of_sizes() {
        let recs = example_records();
        let plan = NaiveShared.plan(&recs);
        plan.validate(&recs).unwrap();
        assert_eq!(plan.total_size(), recs.naive_total());
        assert_eq!(plan.total_size(), 242);
        assert_eq!(plan.num_objects(), recs.len());
    }
}
