//! Greedy by Size for Shared Objects — Algorithm 2 (§4.3).

use super::greedy_assign;
#[cfg(test)]
use super::ObjectStore;
use crate::planner::{SharedObjectPlan, SharedObjectPlanner};
use crate::records::{profile::sort_ids_by_size_desc, UsageRecords};

/// §4.3: iterate tensors in non-increasing order of size; assign each to the
/// smallest suitable shared object, creating a new object when none is
/// suitable. Because tensors are visited largest-first, object sizes never
/// grow after creation.
#[derive(Debug, Default, Clone, Copy)]
pub struct GreedyBySize;

impl SharedObjectPlanner for GreedyBySize {
    fn name(&self) -> &'static str {
        "Greedy by Size"
    }

    fn plan(&self, records: &UsageRecords) -> SharedObjectPlan {
        let mut order: Vec<usize> = (0..records.len()).collect();
        sort_ids_by_size_desc(&records.records, &mut order);
        greedy_assign(records, &order)
    }
}

/// Internal invariant check used by tests: with size-descending order, no
/// object ever grows, so every object's final size equals the size of the
/// first tensor assigned to it.
#[cfg(test)]
pub(crate) fn object_sizes_monotone(records: &UsageRecords) -> bool {
    let mut order: Vec<usize> = (0..records.len()).collect();
    sort_ids_by_size_desc(&records.records, &mut order);
    let mut store = ObjectStore::new(records.len());
    for &id in &order {
        let r = &records.records[id];
        match super::best_fit_object(&store, r) {
            Some(obj) => {
                if store.size(obj) < r.size {
                    return false; // would have grown
                }
                store.assign(obj, r);
            }
            None => {
                store.create_for(r);
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::example_records;

    #[test]
    fn example_plan_is_feasible_and_small() {
        let recs = example_records();
        let plan = GreedyBySize.plan(&recs);
        plan.validate(&recs).unwrap();
        let lb = recs.profiles().shared_objects_lower_bound();
        assert!(plan.total_size() >= lb);
        // Figure 4 achieves three objects on the example; our fixture's
        // optimum is the lower bound 120 = 64 + 40 + 16, and Greedy by Size
        // reaches it: 64 hosts {t5,t2-or...}, etc.
        assert_eq!(plan.total_size(), 120, "objects: {:?}", plan.object_sizes);
        assert_eq!(plan.num_objects(), 3);
    }

    #[test]
    fn never_grows_objects() {
        assert!(object_sizes_monotone(&example_records()));
    }

    #[test]
    fn single_tensor() {
        let recs = UsageRecords::from_triples(&[(0, 1, 7)]);
        let plan = GreedyBySize.plan(&recs);
        plan.validate(&recs).unwrap();
        assert_eq!(plan.total_size(), 7);
        assert_eq!(plan.num_objects(), 1);
    }

    #[test]
    fn chain_reuses_two_buffers() {
        // A pure chain: t_i = (i, i+1, 10). Alternating reuse needs 2 objects.
        let triples: Vec<(usize, usize, usize)> = (0..20).map(|i| (i, i + 1, 10)).collect();
        let recs = UsageRecords::from_triples(&triples);
        let plan = GreedyBySize.plan(&recs);
        plan.validate(&recs).unwrap();
        assert_eq!(plan.num_objects(), 2);
        assert_eq!(plan.total_size(), 20);
    }

    #[test]
    fn empty_records_empty_plan() {
        let recs = UsageRecords::from_triples(&[]);
        let plan = GreedyBySize.plan(&recs);
        plan.validate(&recs).unwrap();
        assert_eq!(plan.total_size(), 0);
    }
}
