//! "Greedy" prior-work baseline from Lee et al. 2019 (the TFLite GPU
//! delegate's original memory manager), reimplemented for Table 1.

use super::greedy_assign;
use crate::planner::{SharedObjectPlan, SharedObjectPlanner};
use crate::records::UsageRecords;

/// The TFLite GPU delegate's greedy manager assigns buffers **in allocation
/// (execution) order** rather than in size or breadth order: tensors are
/// visited by `first_op` (the moment their storage must materialize), and
/// each takes the best-fit suitable object (smallest that fits, else grow
/// the largest, else create).
///
/// This is the strategy the paper's §4 algorithms are measured against in
/// Table 1 (rows "Greedy (Lee et al., 2019)"). Its weakness — and the
/// paper's motivation — is that a small early tensor can claim an object
/// that a large later tensor then cannot use, inflating totals on nets with
/// residual connections (MobileNet v2, DeepLab v3 in Table 1).
#[derive(Debug, Default, Clone, Copy)]
pub struct TfLiteGreedy;

impl SharedObjectPlanner for TfLiteGreedy {
    fn name(&self) -> &'static str {
        "Greedy (Lee et al., 2019)"
    }

    fn plan(&self, records: &UsageRecords) -> SharedObjectPlan {
        let mut order: Vec<usize> = (0..records.len()).collect();
        // Execution order: first use ascending; within one op, larger
        // tensors first; then id for determinism.
        order.sort_by(|&a, &b| {
            let (ra, rb) = (&records.records[a], &records.records[b]);
            ra.first_op
                .cmp(&rb.first_op)
                .then(rb.size.cmp(&ra.size))
                .then(ra.id.cmp(&rb.id))
        });
        greedy_assign(records, &order)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::example_records;
    use crate::records::UsageRecords;

    #[test]
    fn feasible_on_example() {
        let recs = example_records();
        let plan = TfLiteGreedy.plan(&recs);
        plan.validate(&recs).unwrap();
        assert!(plan.total_size() >= recs.profiles().shared_objects_lower_bound());
    }

    #[test]
    fn execution_order_can_lose_to_size_order() {
        // A small tensor allocated first grabs the only reusable slot the
        // later large tensor needed; size order avoids the growth.
        // t0 (0,1,10); t1 (0,3,100); t2 (2,3,90).
        // Execution order: t1(100) -> A=100; t0(10) -> B=10; t2(90): A
        // unsuitable (overlap t1), B suitable -> grows B to 90. Total 190.
        // Greedy by Size: t1=100 -> A; t2=90 -> B(90); t0=10: A unsuitable
        // (0..1 vs 0..3), B unsuitable (0..1 vs 2..3 disjoint!) -> B. 190?
        // B holds t2 (2,3); t0 (0,1) disjoint -> reuse, total 190 both.
        // Use a sharper construction:
        // t0 (0,0,10); t1 (1,1,100); t2 (0,1,1).
        // Exec order: op0 first: t0(10)->A, t2(1)->B(1); t1(100): A suitable
        // (0,0) vs (1,1)? disjoint -> fits? A=10 < 100 -> grow A to 100.
        // Total 101. Size order: t1(100)->A; t0: A? (0,0) vs (1,1) disjoint
        // -> A; t2 (0,1): overlaps both -> B(1). Total 101. Equal again —
        // on tiny cases they often tie; just assert feasibility + ordering
        // sensitivity is covered by the zoo benches.
        let recs = UsageRecords::from_triples(&[(0, 0, 10), (1, 1, 100), (0, 1, 1)]);
        let plan = TfLiteGreedy.plan(&recs);
        plan.validate(&recs).unwrap();
        assert_eq!(plan.total_size(), 101);
    }

    #[test]
    fn deterministic() {
        let recs = example_records();
        let a = TfLiteGreedy.plan(&recs);
        let b = TfLiteGreedy.plan(&recs);
        assert_eq!(a, b);
    }
}
