//! Greedy by Breadth for Shared Objects — Algorithm 1 (§4.2).

use super::{best_fit_object, ObjectStore};
use crate::planner::{SharedObjectPlan, SharedObjectPlanner};
use crate::records::UsageRecords;

/// §4.2: operator breadths correlate with final memory consumption more than
/// allocation order does, so tensors are assigned operator-by-operator in
/// non-increasing breadth order. Within an operator's profile, unassigned
/// tensors are taken largest-first; each gets the best-fit suitable shared
/// object (smallest that fits, else the largest to grow, else a new one).
#[derive(Debug, Default, Clone, Copy)]
pub struct GreedyByBreadth;

impl SharedObjectPlanner for GreedyByBreadth {
    fn name(&self) -> &'static str {
        "Greedy by Breadth"
    }

    fn plan(&self, records: &UsageRecords) -> SharedObjectPlan {
        let profiles = records.profiles();
        let mut store = ObjectStore::new(records.len());
        for op in profiles.ops_by_breadth_desc() {
            // profile(op) is already sorted by size descending (§3).
            for &id in profiles.profile(op) {
                let r = &records.records[id];
                if store.is_assigned(r) {
                    continue;
                }
                match best_fit_object(&store, r) {
                    Some(obj) => store.assign(obj, r),
                    None => {
                        store.create_for(r);
                    }
                }
            }
        }
        store.into_plan()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::example_records;
    use crate::records::UsageRecords;

    #[test]
    fn example_plan_matches_hand_trace() {
        // Hand-traced Algorithm 1 on the Figure-1 fixture (see example.rs):
        // breadth order op5(114), op1(84), op2(80), op3(80), op4(80), ...
        // yields objects {64, 40, 16} = 120, the lower bound.
        let recs = example_records();
        let plan = GreedyByBreadth.plan(&recs);
        plan.validate(&recs).unwrap();
        assert_eq!(plan.total_size(), 120);
        let mut sizes = plan.object_sizes.clone();
        sizes.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(sizes, vec![64, 40, 16]);
    }

    #[test]
    fn grows_object_when_profile_demands() {
        // Two ops; op0 has breadth 30 (one tensor of 30), op1 has breadth 29
        // (tensor of 29). Breadth order visits the 30 first; the 29 then
        // reuses the same object without growth.
        let recs = UsageRecords::from_triples(&[(0, 0, 30), (1, 1, 29)]);
        let plan = GreedyByBreadth.plan(&recs);
        plan.validate(&recs).unwrap();
        assert_eq!(plan.total_size(), 30);
        assert_eq!(plan.num_objects(), 1);
    }

    #[test]
    fn growth_path_is_exercised() {
        // Low-breadth op owns the *larger* tensor, forcing a grow.
        // op0: {10}, op1: {12} but op0 also holds a 5 so breadth(0)=15,
        // breadth(1)=12. Visit order: op0 first. Tensor (1,1,12) then grows
        // the size-10 object (largest suitable) to 12.
        let recs = UsageRecords::from_triples(&[(0, 0, 10), (0, 0, 5), (1, 1, 12)]);
        let plan = GreedyByBreadth.plan(&recs);
        plan.validate(&recs).unwrap();
        assert_eq!(plan.total_size(), 12 + 5);
    }

    #[test]
    fn feasible_on_dense_overlaps() {
        // All tensors overlap: plan must degenerate to naive.
        let recs = UsageRecords::from_triples(&[(0, 9, 8), (0, 9, 4), (0, 9, 2), (0, 9, 1)]);
        let plan = GreedyByBreadth.plan(&recs);
        plan.validate(&recs).unwrap();
        assert_eq!(plan.total_size(), 15);
        assert_eq!(plan.num_objects(), 4);
    }
}
